//! Regenerate every hardware table/figure of the paper in one run:
//! Fig. 2c, S1, S4, S5, Eq. 2/3, Fig. 4 (16/8-bit), Fig. 5, the §4
//! on-board comparison, and the S8 accelerator table.
//!
//!     cargo run --release --example fpga_report

use addernet::report;

fn main() -> anyhow::Result<()> {
    let art = std::path::Path::new("artifacts");
    report::run("hw-all", art, "lenet5", 256)?;
    println!("(accuracy figures: run `repro train`/train_e2e first, then \
              `repro report fig2|fig3ab|fig3d|s7`)");
    Ok(())
}
