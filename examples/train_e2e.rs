//! End-to-end driver (DESIGN.md E15 + the mandated full-stack proof):
//!
//!   1. train AdderNet LeNet-5 AND the CNN twin for several hundred steps
//!      on the synthetic-10 dataset, entirely from Rust via the AOT
//!      train-step graph (Pallas kernel -> JAX train step -> HLO -> PJRT);
//!   2. log both loss curves (Fig. S9 analogue) and eval accuracies;
//!   3. quantize the trained AdderNet int8 with the shared scaling factor
//!      and run the bit-accurate FPGA functional datapath on the test set;
//!   4. report the hardware deltas (LUTs / energy / fmax) for the same
//!      workload from the accelerator model.
//!
//! Results land in artifacts/results.json and EXPERIMENTS.md cites this
//! run.  Override steps with TRAIN_STEPS (default 400).
//!
//!     make artifacts && cargo run --release --example train_e2e

use anyhow::Result;

use addernet::coordinator::{Manifest, Trainer};
use addernet::hw::KernelKind;
use addernet::quant::Mode;
use addernet::report::{quantrep, Results};
use addernet::runtime::Runtime;
use addernet::sim::functional::{Arch, QuantCfg, SimKernel};
use addernet::sim::onchip;
use addernet::{data, nn};

fn main() -> Result<()> {
    let art = std::path::Path::new("artifacts");
    let manifest = Manifest::load(art)?;
    let steps: usize = std::env::var("TRAIN_STEPS").ok()
        .and_then(|s| s.parse().ok()).unwrap_or(400);
    let eval_n = 512usize;
    let mut results = Results::load(art);

    // ---- 1+2: train both kernels, log curves --------------------------
    for kernel in ["adder", "mult"] {
        let mut rt = Runtime::new(art)?;
        let mut trainer = Trainer::new(&manifest, &mut rt, "lenet5", kernel)?;
        println!("== training lenet5/{kernel} for {steps} steps (batch {}) ==",
                 trainer.batch_size);
        let mut stream = data::BatchStream::new(1, trainer.batch_size);
        let t0 = std::time::Instant::now();
        for s in 0..steps {
            let batch = stream.next_batch();
            let (loss, acc) = trainer.train_step(&rt, &batch)?;
            if s % 50 == 0 || s + 1 == steps {
                println!("  step {s:4}  loss {loss:.4}  batch-acc {acc:.3}");
            }
        }
        let dt = t0.elapsed().as_secs_f64();
        let acc = {
            let ev = data::eval_set(eval_n, 1);
            trainer.evaluate(&rt, &ev.images, &ev.labels)?
        };
        println!("  {} steps in {dt:.1}s ({:.1} steps/s); eval acc {acc:.3}",
                 steps, steps as f64 / dt);
        trainer.save_params(&manifest, &quantrep::trained_file("lenet5", kernel))?;
        results.set(&format!("acc/lenet5_{kernel}"), acc);
        results.set(&format!("steps_per_s/lenet5_{kernel}"), steps as f64 / dt);
        // persist the loss curve (S9 analogue) as a CSV next to artifacts
        let csv: String = trainer.history.iter()
            .map(|r| format!("{},{},{}\n", r.step, r.loss, r.acc))
            .collect();
        std::fs::write(art.join(format!("losscurve_lenet5_{kernel}.csv")), csv)?;
    }

    // ---- 3: int8 shared-scale quantization through the functional sim --
    println!("\n== int8 shared-scale quantization (FPGA functional datapath) ==");
    let (params, _) = quantrep::load_params(&manifest, "lenet5", "adder")?;
    let (calib, fp32_acc) = quantrep::calibrate(&params, Arch::Lenet5,
                                                SimKernel::Adder, 256);
    for bits in [8u32, 6, 4] {
        let qacc = quantrep::quant_accuracy(
            &params, Arch::Lenet5, SimKernel::Adder, &calib,
            QuantCfg { bits, mode: Mode::SharedScale }, 256);
        println!("  int{bits}: acc {qacc:.3} (fp32 {fp32_acc:.3}, {:+.1}pp)",
                 (qacc - fp32_acc) * 100.0);
        results.set(&format!("quant/lenet5_adder_int{bits}"), qacc);
    }
    results.set("quant/lenet5_adder_fp32", fp32_acc);

    // ---- 4: hardware deltas for this exact workload -------------------
    println!("\n== hardware deltas for LeNet-5 (Fig. 5 design, 16-bit) ==");
    let s = onchip::savings(16);
    println!("  LUT savings   : conv1 {:.1}%  conv2 {:.1}%  total {:.1}%",
             s.conv1_luts * 100.0, s.conv2_luts * 100.0, s.total_luts * 100.0);
    println!("  energy savings: conv1 {:.1}%  conv2 {:.1}%  total {:.1}%",
             s.conv1_energy * 100.0, s.conv2_energy * 100.0, s.total_energy * 100.0);
    let a = addernet::hw::timing::analyse(
        &addernet::hw::PeArray::new(6, 16, 16, KernelKind::Adder2A));
    let c = addernet::hw::timing::analyse(
        &addernet::hw::PeArray::new(6, 16, 16, KernelKind::Mult));
    println!("  fmax          : adder {:.0} MHz vs mult {:.0} MHz", a.fmax_mhz, c.fmax_mhz);
    println!("  network       : {:.3} GOP/inference", nn::lenet5().gops());

    results.save(art)?;
    println!("\n[train_e2e] OK — results recorded to artifacts/results.json");
    Ok(())
}
