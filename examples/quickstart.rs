//! Quickstart: load an AOT artifact, run one batch of AdderNet inference,
//! and sanity-check the Layer-1 kernel demo graph against the Rust
//! functional simulator.
//!
//!     make artifacts && cargo run --release --example quickstart

use anyhow::Result;

use addernet::coordinator::Manifest;
use addernet::data;
use addernet::runtime::{self, Runtime};
use addernet::sim::functional::{ConvW, Tensor};

fn main() -> Result<()> {
    let art = std::path::Path::new("artifacts");
    let manifest = Manifest::load(art)?;
    let mut rt = Runtime::new(art)?;

    // --- 1. the Layer-1 kernel itself: pallas L1-GEMM vs rust oracle ----
    let demo = manifest.graph("l1gemm_demo")?.clone();
    rt.load("l1gemm_demo", &demo.file)?;
    let (m, k, n) = (16usize, 32, 8);
    let mut rng = addernet::util::XorShift64::new(42);
    let a: Vec<f32> = (0..m * k).map(|_| rng.next_f32_sym(2.0)).collect();
    let b: Vec<f32> = (0..k * n).map(|_| rng.next_f32_sym(2.0)).collect();
    let outs = rt.execute("l1gemm_demo", &[
        runtime::literal_f32(&[m, k], &a)?,
        runtime::literal_f32(&[k, n], &b)?,
    ])?;
    let got = runtime::to_vec_f32(&outs[0])?;
    // oracle: out[i,j] = -sum_k |a[i,k] - b[k,j]|
    let mut max_err = 0f32;
    for i in 0..m {
        for j in 0..n {
            let want: f32 = -(0..k).map(|kk| (a[i * k + kk] - b[kk * n + j]).abs()).sum::<f32>();
            max_err = max_err.max((got[i * n + j] - want).abs());
        }
    }
    println!("[quickstart] pallas L1-GEMM vs rust oracle: max |err| = {max_err:.2e}");
    assert!(max_err < 1e-3, "kernel mismatch");

    // --- 2. AdderNet LeNet-5 inference through the AOT eval graph -------
    let gname = "lenet5_adder_eval";
    let ginfo = manifest.graph(gname)?.clone();
    rt.load(gname, &ginfo.file)?;
    let layout = manifest.layout("lenet5")?.clone();
    // trained weights if available (run `repro train` / train_e2e), else init
    let wfile = "lenet5_adder_trained.bin";
    let pfile = if art.join(wfile).exists() { wfile.to_string() } else { layout.init_file };
    let raw = manifest.read_param_file("lenet5", &pfile)?;
    let params: Vec<xla::Literal> = raw.iter()
        .map(|(_, s, d)| runtime::literal_f32(s, d))
        .collect::<Result<_>>()?;

    let batch = data::eval_set(ginfo.batch, 9);
    let x = runtime::literal_f32(&[ginfo.batch, 32, 32, 1], &batch.images)?;
    let mut inputs: Vec<&xla::Literal> = params.iter().collect();
    inputs.push(&x);
    let logits = runtime::to_vec_f32(&rt.execute(gname, &inputs)?[0])?;
    let correct = (0..ginfo.batch).filter(|&i| {
        let row = &logits[i * 10..(i + 1) * 10];
        let pred = row.iter().enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0;
        pred == batch.labels[i] as usize
    }).count();
    println!("[quickstart] AdderNet LeNet-5 ({pfile}): {}/{} correct", correct, ginfo.batch);

    // --- 3. same conv through the bit-accurate functional sim -----------
    let params_map = manifest.read_params("lenet5", &pfile)?;
    let (ws, wd) = &params_map["conv1/conv_w"];
    let w = ConvW { data: wd, kh: ws[0], kw: ws[1], cin: ws[2], cout: ws[3] };
    let xt = Tensor::new((ginfo.batch, 32, 32, 1), batch.images.clone());
    let y = addernet::sim::functional::conv2d(
        &xt, &w, 1, addernet::nn::Padding::Valid,
        addernet::sim::functional::SimKernel::Adder);
    println!("[quickstart] functional adder conv1 output shape {:?} (first={:.3})",
             y.shape, y.data[0]);
    println!("[quickstart] OK — all three layers compose");
    Ok(())
}
