//! Serving example: start the batching inference server with two model
//! variants (AdderNet + CNN LeNet-5), fire a mixed request load, and
//! report latency/throughput — the "general-purpose accelerator in
//! deployment" scenario of the paper's §4, with the Rust coordinator
//! playing the ARM-PS role and PJRT the PL role.
//!
//!     make artifacts && cargo run --release --example serve

use std::time::Duration;

use anyhow::Result;

use addernet::coordinator::{server, Manifest, VariantCfg};
use addernet::data;
use addernet::report::quantrep;

fn main() -> Result<()> {
    let art = std::path::Path::new("artifacts");
    let manifest = Manifest::load(art)?;
    let n_req: usize = std::env::var("REQUESTS").ok()
        .and_then(|s| s.parse().ok()).unwrap_or(512);

    let variants: Vec<VariantCfg> = ["lenet5_adder", "lenet5_mult"].iter().map(|m| {
        let (arch, kernel) = m.split_once('_').unwrap();
        let w = quantrep::trained_file(arch, kernel);
        VariantCfg {
            model: m.to_string(),
            weights: art.join(&w).exists().then_some(w),
        }
    }).collect();

    println!("[serve] starting {} variants, 2ms batch window", variants.len());
    let handle = server::start(&manifest, &variants, Duration::from_millis(2))?;
    let names = handle.variants();

    // warm-up (compile + first batch)
    let warm = data::eval_set(4, 11);
    for v in &names {
        handle.submit(v, warm.images[..1024].to_vec())?.recv()?;
    }

    let load = data::eval_set(n_req, 3);
    let t0 = std::time::Instant::now();
    let mut pending = Vec::with_capacity(n_req);
    for i in 0..n_req {
        let img = load.images[i * 1024..(i + 1) * 1024].to_vec();
        pending.push((i, handle.submit(&names[i % names.len()], img)?));
    }
    let mut correct = 0usize;
    for (i, rx) in pending {
        let resp = rx.recv()?;
        let pred = resp.logits.iter().enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0;
        if pred == load.labels[i] as usize {
            correct += 1;
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    println!("[serve] {n_req} requests in {dt:.2}s = {:.0} img/s, acc {:.3}",
             n_req as f64 / dt, correct as f64 / n_req as f64);

    let metrics = handle.metrics.lock().unwrap().clone();
    for (name, m) in &metrics {
        println!("  {name}: {} reqs in {} batches (mean {:.1}/batch), \
                  queue p50 {}us, exec p50 {}us, e2e p99 {}us",
                 m.requests, m.batches, m.mean_batch_size(),
                 m.queue_lat.quantile_us(0.5), m.exec_lat.quantile_us(0.5),
                 m.e2e_lat.quantile_us(0.99));
    }
    drop(metrics);
    handle.shutdown();
    println!("[serve] OK");
    Ok(())
}
