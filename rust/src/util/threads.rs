//! Persistent worker pool (the offline vendored crate set has no
//! rayon): fan a list of equally-sized output chunks out to OS threads.
//!
//! The functional-sim engine parallelizes convolutions across
//! batch x output-row tasks; each task owns one disjoint `&mut` chunk of
//! the output buffer, so a `Mutex` over the `chunks_mut` iterator hands
//! every worker exclusive slices.
//!
//! Workers are spawned ONCE, on first parallel use, and reused for every
//! subsequent call ([`parallel_chunks`] used to spawn a scoped pool per
//! conv layer; under serving load that meant thousands of
//! spawn/join cycles per second).  The calling thread always
//! participates in the drain, so a call never blocks waiting for pool
//! capacity, and a completion latch guarantees every helper task has
//! finished before `parallel_chunks` returns — which is what makes the
//! (contained) lifetime transmute below sound: helpers only touch the
//! borrowed closure/iterator through references that are provably live
//! until the latch opens.
//!
//! `ADDERNET_THREADS` keeps its semantics: it caps the *effective*
//! concurrency of each call (re-read per call, so tests may change it at
//! runtime); `0`/garbage fall back as before, and `1` runs inline
//! without touching the pool at all.
//!
//! Reentrancy: a `parallel_chunks` call from INSIDE a pool worker task
//! runs inline (detected via a thread-local flag) — queueing nested
//! helper tasks while every worker waits on its own latch could
//! deadlock, so nesting degrades to sequential execution instead.

use std::panic::{self, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Sender};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Threads the engine may use: `ADDERNET_THREADS` override, else the
/// machine's available parallelism.
pub fn num_threads() -> usize {
    if let Ok(v) = std::env::var("ADDERNET_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map_or(1, |n| n.get())
}

type Task = Box<dyn FnOnce() + Send + 'static>;

thread_local! {
    /// True on pool worker threads — nested `parallel_chunks` calls
    /// detect this and run inline instead of deadlocking on the queue.
    static IN_POOL_WORKER: std::cell::Cell<bool> = std::cell::Cell::new(false);
}

struct Pool {
    tx: Mutex<Sender<Task>>,
    workers: usize,
}

static POOL: OnceLock<Option<Pool>> = OnceLock::new();

/// The process-wide pool, spawned on first use.  `None` when the host
/// has a single core (or every spawn failed) — callers then run inline.
fn pool() -> Option<&'static Pool> {
    POOL.get_or_init(|| {
        // The caller participates in every drain, so N-1 workers give
        // N-way parallelism on an N-core machine.
        let n = std::thread::available_parallelism()
            .map_or(1, |v| v.get())
            .saturating_sub(1);
        if n == 0 {
            return None;
        }
        let (tx, rx) = mpsc::channel::<Task>();
        let rx = Arc::new(Mutex::new(rx));
        let mut spawned = 0usize;
        for i in 0..n {
            let rx = Arc::clone(&rx);
            let ok = std::thread::Builder::new()
                .name(format!("addernet-pool-{i}"))
                .spawn(move || {
                    IN_POOL_WORKER.with(|f| f.set(true));
                    loop {
                        // Hold the lock only while dequeuing; run unlocked.
                        let task = { rx.lock().unwrap().recv() };
                        match task {
                            Ok(t) => t(),
                            Err(_) => break, // sender gone: process teardown
                        }
                    }
                })
                .is_ok();
            if ok {
                spawned += 1;
            }
        }
        if spawned == 0 {
            None
        } else {
            Some(Pool { tx: Mutex::new(tx), workers: spawned })
        }
    })
    .as_ref()
}

/// Worker threads in the persistent engine pool (0 when the host is
/// single-core and everything runs inline).  Serving replicas share
/// this pool, so the load-test report records it alongside replica
/// counts — the two together bound real parallelism.
pub fn pool_workers() -> usize {
    pool().map_or(0, |p| p.workers)
}

/// Countdown latch: `wait` opens once `arrive` has been called `n` times.
struct Latch {
    left: Mutex<usize>,
    cv: Condvar,
}

impl Latch {
    fn new(n: usize) -> Latch {
        Latch { left: Mutex::new(n), cv: Condvar::new() }
    }

    fn arrive(&self) {
        let mut left = self.left.lock().unwrap();
        *left -= 1;
        if *left == 0 {
            self.cv.notify_all();
        }
    }

    fn wait(&self) {
        let mut left = self.left.lock().unwrap();
        while *left > 0 {
            left = self.cv.wait(left).unwrap();
        }
    }
}

/// Waits for the latch even if the caller's own drain panics — helpers
/// must be done with the borrowed state before this frame unwinds.
struct WaitGuard<'a>(&'a Latch);

impl Drop for WaitGuard<'_> {
    fn drop(&mut self) {
        self.0.wait();
    }
}

/// Claim-and-run loop shared by the caller and every helper task.
fn drain<'d, T, F>(
    work: &Mutex<std::iter::Enumerate<std::slice::ChunksMut<'d, T>>>,
    f: &F,
) where
    F: Fn(usize, &mut [T]),
{
    loop {
        let item = work.lock().unwrap().next();
        match item {
            Some((i, chunk)) => f(i, chunk),
            None => break,
        }
    }
}

/// Split `data` into `chunk_len`-sized pieces and run `f(chunk_index,
/// chunk)` over them, on the persistent pool plus the calling thread,
/// using up to `max_threads` effective threads.
///
/// `data.len()` must be a multiple of `chunk_len`.  With one effective
/// thread (small task counts, `max_threads == 1`, single-core hosts) the
/// work runs inline with zero pool traffic.  Chunks are claimed
/// dynamically, so uneven per-chunk costs still balance, and the claim
/// order never affects results (each chunk is written exactly once).
/// A panic inside `f` — on the caller or any helper — propagates to the
/// caller after all helpers have stopped touching the shared state.
pub fn parallel_chunks<T, F>(data: &mut [T], chunk_len: usize, max_threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(chunk_len > 0, "chunk_len must be positive");
    assert_eq!(data.len() % chunk_len, 0, "data not a multiple of chunk_len");
    let n_chunks = data.len() / chunk_len;
    let threads = num_threads().min(max_threads).min(n_chunks).max(1);
    // Nested calls from a pool worker run inline (see module docs).
    let nested = IN_POOL_WORKER.with(|f| f.get());
    let pool = if threads > 1 && !nested { pool() } else { None };
    let helpers = match pool {
        Some(p) => (threads - 1).min(p.workers),
        None => 0,
    };
    if helpers == 0 {
        for (i, chunk) in data.chunks_mut(chunk_len).enumerate() {
            f(i, chunk);
        }
        return;
    }
    let pool = pool.unwrap();

    let work = Mutex::new(data.chunks_mut(chunk_len).enumerate());
    let latch = Latch::new(helpers);
    let poisoned = AtomicBool::new(false);
    {
        let tx = pool.tx.lock().unwrap();
        for _ in 0..helpers {
            let task: Box<dyn FnOnce() + Send + '_> = Box::new(|| {
                let r = panic::catch_unwind(AssertUnwindSafe(|| drain(&work, &f)));
                if r.is_err() {
                    poisoned.store(true, Ordering::SeqCst);
                }
                // Last touch of the borrowed state: after this arrives,
                // the caller may return and drop `work`/`f`.
                latch.arrive();
            });
            // SAFETY: the task borrows `work`, `f`, `latch` and
            // `poisoned`, all owned by this stack frame.  The frame
            // cannot return (or unwind past the WaitGuard below) until
            // the latch has opened, and each task calls `latch.arrive()`
            // as its final action on the borrowed state — so every
            // borrow is dead before the referents are.  Erasing the
            // lifetime to 'static is only to cross the channel.
            let task: Task = unsafe { std::mem::transmute(task) };
            if tx.send(task).is_err() {
                // Channel closed (cannot happen while POOL is alive, but
                // never leave the latch hanging).
                latch.arrive();
            }
        }
    }
    // The caller is always one of the drain threads; the guard makes the
    // latch-wait unconditional, including on unwind.
    let guard = WaitGuard(&latch);
    drain(&work, &f);
    drop(guard);
    if poisoned.load(Ordering::SeqCst) {
        panic!("parallel_chunks: a pool worker task panicked");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_every_chunk_once() {
        let mut data = vec![0u32; 64 * 7];
        parallel_chunks(&mut data, 7, usize::MAX, |i, chunk| {
            for v in chunk.iter_mut() {
                *v += i as u32 + 1;
            }
        });
        for (i, chunk) in data.chunks(7).enumerate() {
            assert!(chunk.iter().all(|&v| v == i as u32 + 1), "chunk {i}");
        }
    }

    #[test]
    fn single_thread_path_matches() {
        let mut a = vec![0i64; 24];
        let mut b = vec![0i64; 24];
        parallel_chunks(&mut a, 3, 1, |i, c| c.iter_mut().for_each(|v| *v = i as i64));
        parallel_chunks(&mut b, 3, usize::MAX, |i, c| {
            c.iter_mut().for_each(|v| *v = i as i64)
        });
        assert_eq!(a, b);
    }

    #[test]
    fn num_threads_positive() {
        assert!(num_threads() >= 1);
    }

    #[test]
    fn empty_data_is_a_no_op() {
        // zero chunks: the split must not panic or touch the pool.
        let mut data: Vec<u8> = Vec::new();
        parallel_chunks(&mut data, 4, usize::MAX, |_, _| panic!("no chunks"));
        parallel_chunks(&mut data, 4, 1, |_, _| panic!("no chunks"));
    }

    #[test]
    fn oversubscribed_thread_request_clamps_to_chunk_count() {
        // far more threads than chunks: every chunk still runs exactly
        // once and the call returns (no idle-worker deadlock).
        let mut data = vec![0u32; 3 * 5];
        parallel_chunks(&mut data, 5, 1000, |i, chunk| {
            for v in chunk.iter_mut() {
                *v += i as u32 + 1;
            }
        });
        for (i, chunk) in data.chunks(5).enumerate() {
            assert!(chunk.iter().all(|&v| v == i as u32 + 1), "chunk {i}");
        }
    }

    #[test]
    fn single_chunk_runs_inline() {
        let mut data = vec![0u64; 8];
        parallel_chunks(&mut data, 8, usize::MAX, |i, chunk| {
            assert_eq!(i, 0);
            chunk.iter_mut().for_each(|v| *v = 7);
        });
        assert!(data.iter().all(|&v| v == 7));
    }

    #[test]
    fn pool_survives_many_sequential_calls() {
        // The persistent pool must drain thousands of back-to-back jobs
        // (the serving pattern: one parallel conv per request batch)
        // without leaking, deadlocking or corrupting results.
        for round in 0..200u64 {
            let mut data = vec![0u64; 16 * 4];
            parallel_chunks(&mut data, 4, usize::MAX, |i, chunk| {
                for v in chunk.iter_mut() {
                    *v = round * 1000 + i as u64;
                }
            });
            for (i, chunk) in data.chunks(4).enumerate() {
                assert!(chunk.iter().all(|&v| v == round * 1000 + i as u64),
                        "round {round} chunk {i}");
            }
        }
    }

    #[test]
    fn nested_calls_do_not_deadlock() {
        // Inner calls from pool workers run inline; inner calls from
        // the (non-worker) caller thread use the pool normally.  Both
        // must terminate with correct results.
        let mut outer = vec![0u32; 8 * 4];
        parallel_chunks(&mut outer, 4, usize::MAX, |i, chunk| {
            let mut inner = vec![0u32; 4 * 2];
            parallel_chunks(&mut inner, 2, usize::MAX, |j, c| {
                c.iter_mut().for_each(|v| *v = j as u32 + 10);
            });
            for (j, c) in inner.chunks(2).enumerate() {
                assert!(c.iter().all(|&v| v == j as u32 + 10), "inner {j}");
            }
            chunk.iter_mut().for_each(|v| *v = i as u32 + 1);
        });
        for (i, chunk) in outer.chunks(4).enumerate() {
            assert!(chunk.iter().all(|&v| v == i as u32 + 1), "outer {i}");
        }
    }

    #[test]
    fn concurrent_callers_share_the_pool() {
        // Several OS threads (the serving workers) issue parallel jobs
        // at once; each must see only its own chunks.
        let handles: Vec<_> = (0..4u64)
            .map(|t| {
                std::thread::spawn(move || {
                    for round in 0..50u64 {
                        let mut data = vec![0u64; 8 * 3];
                        parallel_chunks(&mut data, 3, usize::MAX, |i, chunk| {
                            for v in chunk.iter_mut() {
                                *v = t * 100_000 + round * 100 + i as u64;
                            }
                        });
                        for (i, chunk) in data.chunks(3).enumerate() {
                            let want = t * 100_000 + round * 100 + i as u64;
                            assert!(chunk.iter().all(|&v| v == want),
                                    "caller {t} round {round} chunk {i}");
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }
}
