//! Minimal scoped worker pool (the offline vendored crate set has no
//! rayon): fan a list of equally-sized output chunks out to OS threads.
//!
//! The functional-sim engine parallelizes convolutions across
//! batch x output-row tasks; each task owns one disjoint `&mut` chunk of
//! the output buffer, so the pool needs no unsafe code — a `Mutex` over
//! the `chunks_mut` iterator hands every worker exclusive slices.

use std::sync::Mutex;

/// Threads the engine may use: `ADDERNET_THREADS` override, else the
/// machine's available parallelism.
pub fn num_threads() -> usize {
    if let Ok(v) = std::env::var("ADDERNET_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map_or(1, |n| n.get())
}

/// Split `data` into `chunk_len`-sized pieces and run `f(chunk_index,
/// chunk)` over them on up to `max_threads` scoped worker threads.
///
/// `data.len()` must be a multiple of `chunk_len`.  With one effective
/// thread (small task counts, `max_threads == 1`, single-core hosts) the
/// work runs inline with zero spawn overhead.  Chunks are claimed
/// dynamically, so uneven per-chunk costs still balance.
pub fn parallel_chunks<T, F>(data: &mut [T], chunk_len: usize, max_threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(chunk_len > 0, "chunk_len must be positive");
    assert_eq!(data.len() % chunk_len, 0, "data not a multiple of chunk_len");
    let n_chunks = data.len() / chunk_len;
    let threads = num_threads().min(max_threads).min(n_chunks).max(1);
    if threads <= 1 {
        for (i, chunk) in data.chunks_mut(chunk_len).enumerate() {
            f(i, chunk);
        }
        return;
    }
    let work = Mutex::new(data.chunks_mut(chunk_len).enumerate());
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let item = work.lock().unwrap().next();
                match item {
                    Some((i, chunk)) => f(i, chunk),
                    None => break,
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_every_chunk_once() {
        let mut data = vec![0u32; 64 * 7];
        parallel_chunks(&mut data, 7, usize::MAX, |i, chunk| {
            for v in chunk.iter_mut() {
                *v += i as u32 + 1;
            }
        });
        for (i, chunk) in data.chunks(7).enumerate() {
            assert!(chunk.iter().all(|&v| v == i as u32 + 1), "chunk {i}");
        }
    }

    #[test]
    fn single_thread_path_matches() {
        let mut a = vec![0i64; 24];
        let mut b = vec![0i64; 24];
        parallel_chunks(&mut a, 3, 1, |i, c| c.iter_mut().for_each(|v| *v = i as i64));
        parallel_chunks(&mut b, 3, usize::MAX, |i, c| {
            c.iter_mut().for_each(|v| *v = i as i64)
        });
        assert_eq!(a, b);
    }

    #[test]
    fn num_threads_positive() {
        assert!(num_threads() >= 1);
    }

    #[test]
    fn empty_data_is_a_no_op() {
        // zero chunks: the split must not panic or spawn anything.
        let mut data: Vec<u8> = Vec::new();
        parallel_chunks(&mut data, 4, usize::MAX, |_, _| panic!("no chunks"));
        parallel_chunks(&mut data, 4, 1, |_, _| panic!("no chunks"));
    }

    #[test]
    fn oversubscribed_thread_request_clamps_to_chunk_count() {
        // far more threads than chunks: every chunk still runs exactly
        // once and the call returns (no idle-worker deadlock).
        let mut data = vec![0u32; 3 * 5];
        parallel_chunks(&mut data, 5, 1000, |i, chunk| {
            for v in chunk.iter_mut() {
                *v += i as u32 + 1;
            }
        });
        for (i, chunk) in data.chunks(5).enumerate() {
            assert!(chunk.iter().all(|&v| v == i as u32 + 1), "chunk {i}");
        }
    }

    #[test]
    fn single_chunk_runs_inline() {
        let mut data = vec![0u64; 8];
        parallel_chunks(&mut data, 8, usize::MAX, |i, chunk| {
            assert_eq!(i, 0);
            chunk.iter_mut().for_each(|v| *v = 7);
        });
        assert!(data.iter().all(|&v| v == 7));
    }
}
