//! Minimal recursive-descent JSON parser (serde is not in the offline
//! vendored crate set, so the manifest loader carries its own).
//!
//! Supports the full JSON grammar needed by `artifacts/manifest.json`:
//! objects, arrays, strings (with escapes), numbers, booleans, null.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    /// Path accessor: `j.at(&["graphs", "lenet5_adder_train", "file"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in path {
            cur = cur.get(k)?;
        }
        Some(cur)
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => write!(f, "{n}"),
            Json::Str(s) => write!(f, "{s:?}"),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{k:?}:{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.into() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected literal {s}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("eof in \\u"))?;
                            let d = (c as char).to_digit(16)
                                .ok_or_else(|| self.err("bad hex in \\u"))?;
                            code = code * 16 + d;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) => s.push(c as char),
                None => return Err(self.err("eof in string")),
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        txt.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": {}}"#).unwrap();
        assert_eq!(j.at(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.at(&["a"]).unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("x")
        );
        assert!(j.get("c").unwrap().as_obj().unwrap().is_empty());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn roundtrip_manifest_shape() {
        let src = r#"{"graphs": {"g": {"file": "g.hlo.txt", "batch": 32,
                      "outputs": [{"shape": [32, 10], "dtype": "f32"}]}},
                      "params": {}}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.at(&["graphs", "g", "batch"]).unwrap().as_usize(), Some(32));
        let shape = j.at(&["graphs", "g", "outputs"]).unwrap().as_arr().unwrap()[0]
            .get("shape").unwrap().as_arr().unwrap();
        assert_eq!(shape.len(), 2);
    }
}
