//! Small deterministic RNGs (the `rand` crate is not vendored).
//!
//! * [`Lcg31`] — the 31-bit LCG shared bit-exactly with
//!   `python/compile/data.py` for dataset generation.
//! * [`XorShift64`] — fast general-purpose generator for shuffling,
//!   workload synthesis and benchmark inputs.

/// The dataset LCG: `state = (state * 1103515245 + 12345) mod 2^31`.
#[derive(Debug, Clone, Copy)]
pub struct Lcg31 {
    pub state: u64,
}

pub const LCG_A: u64 = 1_103_515_245;
pub const LCG_C: u64 = 12_345;
pub const LCG_M: u64 = 1 << 31;

impl Lcg31 {
    pub fn new(state: u64) -> Self {
        Self { state: state % LCG_M }
    }

    /// Advance and return the new state (matches data.py `_lcg_next`).
    pub fn next_state(&mut self) -> u64 {
        self.state = (self.state.wrapping_mul(LCG_A).wrapping_add(LCG_C)) % LCG_M;
        self.state
    }
}

/// xorshift64* — fast, good-enough distribution for benchmarks/shuffles.
#[derive(Debug, Clone, Copy)]
pub struct XorShift64 {
    state: u64,
}

impl XorShift64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed.max(1) }
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in [-scale, scale).
    pub fn next_f32_sym(&mut self, scale: f32) -> f32 {
        (self.next_f64() as f32 * 2.0 - 1.0) * scale
    }

    /// Uniform integer in [0, n).
    pub fn next_below(&mut self, n: u64) -> u64 {
        self.next_u64() % n.max(1)
    }

    /// Standard normal via Box-Muller.
    pub fn next_normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lcg_matches_python_constants() {
        // First two steps from state 1:
        // (1*1103515245 + 12345) % 2^31 = 1103527590
        let mut l = Lcg31::new(1);
        assert_eq!(l.next_state(), 1_103_527_590);
        let expect = (1_103_527_590u64 * LCG_A + LCG_C) % LCG_M;
        assert_eq!(l.next_state(), expect);
    }

    #[test]
    fn xorshift_deterministic_and_distributed() {
        let mut a = XorShift64::new(7);
        let mut b = XorShift64::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut r = XorShift64::new(42);
        let mean = (0..10_000).map(|_| r.next_f64()).sum::<f64>() / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = XorShift64::new(3);
        let xs: Vec<f64> = (0..20_000).map(|_| r.next_normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / xs.len() as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn next_below_in_range() {
        let mut r = XorShift64::new(1);
        for _ in 0..1000 {
            assert!(r.next_below(10) < 10);
        }
    }
}
