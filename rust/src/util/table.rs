//! Plain-text table renderer for the paper-style reports.

/// A simple aligned-column table.
#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Self {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: title.to_string(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn rows_len(&self) -> usize {
        self.rows.len()
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let line = |cells: &[String], widths: &[usize]| -> String {
            let mut s = String::from("| ");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:<w$} | ", c, w = widths[i]));
            }
            s.trim_end().to_string()
        };
        out.push_str(&line(&self.header, &widths));
        out.push('\n');
        let sep: String = widths.iter()
            .map(|w| format!("|{}", "-".repeat(w + 2)))
            .collect::<String>() + "|";
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

/// Format a float with `d` decimals.
pub fn f(v: f64, d: usize) -> String {
    format!("{v:.d$}")
}

/// Format a percentage (0.676 -> "67.6%").
pub fn pct(v: f64) -> String {
    format!("{:.1}%", v * 100.0)
}

/// Format a large count with thousands separators (168234 -> "168,234").
pub fn thousands(v: u64) -> String {
    let s = v.to_string();
    let mut out = String::new();
    for (i, c) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["longer".into(), "22".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("| name   | value |"));
        assert!(s.lines().count() == 5);
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn formatters() {
        assert_eq!(pct(0.676), "67.6%");
        assert_eq!(f(3.14159, 2), "3.14");
        assert_eq!(thousands(168_234), "168,234");
        assert_eq!(thousands(42), "42");
        assert_eq!(thousands(1_000_000), "1,000,000");
    }
}
