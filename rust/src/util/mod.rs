//! In-tree utilities: JSON parsing, deterministic RNGs, table rendering
//! (the offline vendored crate set has no serde/rand/prettytable).

pub mod json;
pub mod rng;
pub mod table;
pub mod threads;

pub use json::Json;
pub use rng::{Lcg31, XorShift64};
pub use table::Table;
