//! Request tracing: a per-thread ring-buffer span recorder exporting
//! Chrome trace-event JSON (loadable in Perfetto / `chrome://tracing`).
//!
//! Every participating thread takes a [`TraceHandle`] from the shared
//! [`TraceSink`]; recording a span touches only that thread's own ring
//! (one uncontended mutex acquire — the export path is the only other
//! reader), so tracing never serializes replicas against each other.
//! Rings overwrite their oldest spans when full; the export reports how
//! many were dropped.
//!
//! Span vocabulary on the serving path: `request` (submit → response
//! sent), `collect` (batcher wait), `batch` (exec + respond for one
//! collected batch), `exec`, `respond`, and per-layer spans from
//! [`TraceObserver`] when layer tracing is on.

use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::sim::exec::{ActStats, ExecObserver};

/// Default per-thread ring capacity (spans).
pub const DEFAULT_CAPACITY: usize = 65_536;

/// One completed span, timestamped in µs since the sink's epoch.
#[derive(Debug, Clone)]
pub struct Span {
    pub name: String,
    pub cat: &'static str,
    pub ts_us: u64,
    pub dur_us: u64,
}

struct Ring {
    events: Vec<Span>,
    written: u64,
}

/// One thread's span ring, registered with the sink at handle creation.
pub struct ThreadBuf {
    tid: u64,
    name: String,
    capacity: usize,
    ring: Mutex<Ring>,
}

impl ThreadBuf {
    fn record(&self, span: Span) {
        let mut r = self.ring.lock().unwrap();
        let idx = (r.written % self.capacity as u64) as usize;
        if r.events.len() < self.capacity {
            r.events.push(span);
        } else {
            r.events[idx] = span;
        }
        r.written += 1;
    }
}

/// Shared trace collector: owns the epoch and the thread registry.
pub struct TraceSink {
    epoch: Instant,
    capacity: usize,
    bufs: Mutex<Vec<Arc<ThreadBuf>>>,
    next_tid: AtomicU64,
}

impl TraceSink {
    pub fn new() -> Arc<Self> {
        Self::with_capacity(DEFAULT_CAPACITY)
    }

    pub fn with_capacity(capacity: usize) -> Arc<Self> {
        Arc::new(Self {
            epoch: Instant::now(),
            capacity: capacity.max(1),
            bufs: Mutex::new(Vec::new()),
            next_tid: AtomicU64::new(1),
        })
    }

    /// Register a new per-thread buffer and return a recording handle.
    pub fn handle(self: &Arc<Self>, thread_name: &str) -> TraceHandle {
        let tid = self.next_tid.fetch_add(1, Ordering::Relaxed);
        let buf = Arc::new(ThreadBuf {
            tid,
            name: thread_name.to_string(),
            capacity: self.capacity,
            ring: Mutex::new(Ring { events: Vec::new(), written: 0 }),
        });
        self.bufs.lock().unwrap().push(Arc::clone(&buf));
        TraceHandle { sink: Arc::clone(self), buf }
    }

    pub fn epoch(&self) -> Instant {
        self.epoch
    }

    /// Spans lost to ring overwrite across all threads.
    pub fn dropped(&self) -> u64 {
        self.bufs.lock().unwrap().iter()
            .map(|b| {
                let r = b.ring.lock().unwrap();
                r.written - r.events.len() as u64
            })
            .sum()
    }

    /// All retained spans as `(tid, thread_name, span)` rows.
    pub fn spans(&self) -> Vec<(u64, String, Span)> {
        let mut out = Vec::new();
        for b in self.bufs.lock().unwrap().iter() {
            let r = b.ring.lock().unwrap();
            for s in &r.events {
                out.push((b.tid, b.name.clone(), s.clone()));
            }
        }
        out
    }

    /// Chrome trace-event JSON (object form): thread-name metadata
    /// events plus `"ph":"X"` complete events, ts/dur in µs.
    pub fn export_json(&self) -> String {
        let mut out = String::from("{\"traceEvents\":[");
        let mut first = true;
        let bufs = self.bufs.lock().unwrap();
        for b in bufs.iter() {
            if !first {
                out.push(',');
            }
            first = false;
            out.push_str(&format!(
                "{{\"ph\":\"M\",\"pid\":1,\"tid\":{},\"name\":\
                 \"thread_name\",\"args\":{{\"name\":{:?}}}}}",
                b.tid, b.name
            ));
            let r = b.ring.lock().unwrap();
            for s in &r.events {
                out.push_str(&format!(
                    ",{{\"ph\":\"X\",\"pid\":1,\"tid\":{},\"ts\":{},\
                     \"dur\":{},\"name\":{:?},\"cat\":{:?}}}",
                    b.tid, s.ts_us, s.dur_us, s.name, s.cat
                ));
            }
        }
        drop(bufs);
        out.push_str(&format!(
            "],\"displayTimeUnit\":\"ms\",\"droppedSpans\":{}}}",
            self.dropped()
        ));
        out
    }

    pub fn write_json(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("creating {}", dir.display()))?;
            }
        }
        std::fs::write(path, self.export_json())
            .with_context(|| format!("writing trace to {}", path.display()))
    }
}

/// A thread's recording handle (cheap to clone; clones share the ring).
#[derive(Clone)]
pub struct TraceHandle {
    sink: Arc<TraceSink>,
    buf: Arc<ThreadBuf>,
}

impl TraceHandle {
    /// Record a completed span from its start instant and duration.
    /// Starts before the sink's epoch clamp to ts 0.
    pub fn record(&self, name: &str, cat: &'static str, start: Instant,
                  dur: Duration) {
        let ts_us =
            start.saturating_duration_since(self.sink.epoch).as_micros() as u64;
        self.buf.record(Span {
            name: name.to_string(),
            cat,
            ts_us,
            dur_us: dur.as_micros() as u64,
        });
    }
}

/// [`ExecObserver`] that records one `layer`-category span per op into
/// a trace handle — the per-layer rows inside each `exec` span.
pub struct TraceObserver<'a> {
    pub trace: &'a TraceHandle,
}

impl ExecObserver for TraceObserver<'_> {
    fn op_done(&mut self, _index: usize, label: &str, start: Instant,
               wall: Duration, _stats: ActStats) {
        self.trace.record(label, "layer", start, wall);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn export_parses_and_keeps_thread_names() {
        let sink = TraceSink::new();
        let h = sink.handle("worker-0");
        let t0 = Instant::now();
        h.record("exec", "serve", t0, Duration::from_micros(250));
        let j = Json::parse(&sink.export_json()).unwrap();
        let events = j.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 2); // metadata + one span
        assert_eq!(events[0].get("ph").unwrap().as_str(), Some("M"));
        let x = &events[1];
        assert_eq!(x.get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(x.get("name").unwrap().as_str(), Some("exec"));
        assert_eq!(x.get("dur").unwrap().as_usize(), Some(250));
        assert_eq!(j.get("droppedSpans").unwrap().as_usize(), Some(0));
    }

    #[test]
    fn ring_overwrites_oldest_and_counts_drops() {
        let sink = TraceSink::with_capacity(4);
        let h = sink.handle("w");
        let t0 = Instant::now();
        for i in 0..10 {
            h.record(&format!("s{i}"), "t", t0, Duration::from_micros(1));
        }
        assert_eq!(sink.dropped(), 6);
        let names: Vec<String> =
            sink.spans().into_iter().map(|(_, _, s)| s.name).collect();
        assert_eq!(names.len(), 4);
        assert!(names.contains(&"s9".to_string()));
        assert!(!names.contains(&"s0".to_string()));
    }

    #[test]
    fn pre_epoch_starts_clamp_to_zero() {
        let t0 = Instant::now();
        std::thread::sleep(Duration::from_millis(2));
        let sink = TraceSink::new();
        let h = sink.handle("w");
        h.record("early", "t", t0, Duration::from_micros(5));
        let (_, _, s) = sink.spans().pop().unwrap();
        assert_eq!(s.ts_us, 0);
    }
}
