//! Process-wide metrics registry: named atomic counters, gauges and
//! lock-free latency histograms, with a stable JSON snapshot and a
//! Prometheus text exposition rendered from the SAME values.
//!
//! Metric names may carry Prometheus-style labels inline
//! (`addernet_requests_total{variant="lenet5_adder"}`); the renderer
//! splits the base name off to emit `# HELP` / `# TYPE` once per family
//! even when many label sets share it.  The JSON snapshot keeps the
//! full labeled name as the key, so the two expositions are two views
//! of one map — pinned by `tests/obs.rs`.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Duration;

use crate::coordinator::metrics::LatencyHistogram;
use crate::util::json::Json;

/// Snapshot schema tag (bump on breaking JSON layout changes).
pub const SCHEMA: &str = "addernet-metrics-v1";

/// Monotonic counter.  `set` exists for bridge exports that publish an
/// externally-aggregated total (e.g. merged `ServerMetrics` shards).
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn set(&self, n: u64) {
        self.0.store(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Instantaneous value: an f64 stored as bits in an AtomicU64.
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Lock-free sibling of [`LatencyHistogram`]: the SAME 32-bucket
/// log-spaced layout (bucket i counts latencies in [2^i, 2^(i+1)) µs),
/// recorded with relaxed atomics so replicas never serialize on a
/// mutex.  `snapshot()` bridges back into the locked type for
/// quantiles; equivalence under concurrent hammering is pinned by
/// `tests/obs.rs`.
#[derive(Debug)]
pub struct AtomicHistogram {
    buckets: [AtomicU64; 32],
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

impl Default for AtomicHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl AtomicHistogram {
    pub fn new() -> Self {
        Self {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }

    pub fn record(&self, d: Duration) {
        self.record_us(d.as_micros() as u64);
    }

    pub fn record_us(&self, us: u64) {
        // identical bucket math to LatencyHistogram::record
        let idx = (64 - us.max(1).leading_zeros() as usize - 1).min(31);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Bridge into the locked histogram (for quantiles/mean).
    pub fn snapshot(&self) -> LatencyHistogram {
        let buckets: Vec<u64> =
            self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        LatencyHistogram::from_parts(
            buckets,
            self.count.load(Ordering::Relaxed),
            self.sum_us.load(Ordering::Relaxed),
            self.max_us.load(Ordering::Relaxed),
        )
    }

    /// Overwrite from a locked histogram (bridge exports: publish a
    /// merged shard aggregate into the registry).
    pub fn set_from(&self, h: &LatencyHistogram) {
        for (b, &v) in self.buckets.iter().zip(h.bucket_counts()) {
            b.store(v, Ordering::Relaxed);
        }
        self.count.store(h.count(), Ordering::Relaxed);
        self.sum_us.store(h.sum_us(), Ordering::Relaxed);
        self.max_us.store(h.max_us(), Ordering::Relaxed);
    }

    /// Fold another atomic histogram into this one.
    pub fn merge(&self, other: &AtomicHistogram) {
        for (b, o) in self.buckets.iter().zip(&other.buckets) {
            b.fetch_add(o.load(Ordering::Relaxed), Ordering::Relaxed);
        }
        self.count.fetch_add(other.count.load(Ordering::Relaxed),
                             Ordering::Relaxed);
        self.sum_us.fetch_add(other.sum_us.load(Ordering::Relaxed),
                              Ordering::Relaxed);
        self.max_us.fetch_max(other.max_us.load(Ordering::Relaxed),
                              Ordering::Relaxed);
    }
}

type Family<T> = Mutex<BTreeMap<String, (Arc<T>, &'static str)>>;

/// Named metric registry.  `counter`/`gauge`/`histogram` are
/// get-or-create: the first caller's help string wins, every caller
/// shares the same atomic cell.
#[derive(Debug, Default)]
pub struct Registry {
    counters: Family<Counter>,
    gauges: Family<Gauge>,
    histograms: Family<AtomicHistogram>,
}

fn get_or_create<T: Default>(family: &Family<T>, name: &str,
                             help: &'static str) -> Arc<T> {
    let mut m = family.lock().unwrap();
    let (cell, _) = m.entry(name.to_string())
        .or_insert_with(|| (Arc::new(T::default()), help));
    Arc::clone(cell)
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn counter(&self, name: &str, help: &'static str) -> Arc<Counter> {
        get_or_create(&self.counters, name, help)
    }

    pub fn gauge(&self, name: &str, help: &'static str) -> Arc<Gauge> {
        get_or_create(&self.gauges, name, help)
    }

    pub fn histogram(&self, name: &str, help: &'static str)
                     -> Arc<AtomicHistogram> {
        get_or_create(&self.histograms, name, help)
    }

    /// Stable JSON snapshot: `{schema, counters{}, gauges{},
    /// histograms{name: {count, sum_us, mean_us, max_us, p50_us,
    /// p99_us}}}`.  Keys are the full labeled metric names; BTreeMap
    /// ordering makes the rendering deterministic.
    pub fn snapshot(&self) -> Json {
        let mut top = BTreeMap::new();
        top.insert("schema".into(), Json::Str(SCHEMA.into()));
        let counters: BTreeMap<String, Json> = self.counters.lock().unwrap()
            .iter()
            .map(|(k, (c, _))| (k.clone(), Json::Num(c.get() as f64)))
            .collect();
        top.insert("counters".into(), Json::Obj(counters));
        let gauges: BTreeMap<String, Json> = self.gauges.lock().unwrap()
            .iter()
            .map(|(k, (g, _))| (k.clone(), Json::Num(g.get())))
            .collect();
        top.insert("gauges".into(), Json::Obj(gauges));
        let mut hists = BTreeMap::new();
        for (k, (h, _)) in self.histograms.lock().unwrap().iter() {
            let s = h.snapshot();
            let mut m = BTreeMap::new();
            m.insert("count".into(), Json::Num(s.count() as f64));
            m.insert("sum_us".into(), Json::Num(s.sum_us() as f64));
            m.insert("mean_us".into(), Json::Num(s.mean_us()));
            m.insert("max_us".into(), Json::Num(s.max_us() as f64));
            m.insert("p50_us".into(), Json::Num(s.quantile_us(0.5) as f64));
            m.insert("p99_us".into(), Json::Num(s.quantile_us(0.99) as f64));
            hists.insert(k.clone(), Json::Obj(m));
        }
        top.insert("histograms".into(), Json::Obj(hists));
        Json::Obj(top)
    }

    /// Prometheus text exposition (text/plain; version 0.0.4).
    /// `# HELP`/`# TYPE` are emitted once per metric family even when
    /// several label sets share the base name; histograms render as
    /// summaries (p50/p99 quantiles + `_sum`/`_count`).
    pub fn render_prometheus(&self) -> String {
        let mut out = String::new();
        let mut last = String::new();
        for (name, (c, help)) in self.counters.lock().unwrap().iter() {
            let (base, labels) = split_name(name);
            head(&mut out, &mut last, base, help, "counter");
            out.push_str(&format!("{} {}\n", sample(base, labels, None),
                                  c.get()));
        }
        last.clear();
        for (name, (g, help)) in self.gauges.lock().unwrap().iter() {
            let (base, labels) = split_name(name);
            head(&mut out, &mut last, base, help, "gauge");
            out.push_str(&format!("{} {}\n", sample(base, labels, None),
                                  g.get()));
        }
        last.clear();
        for (name, (h, help)) in self.histograms.lock().unwrap().iter() {
            let (base, labels) = split_name(name);
            head(&mut out, &mut last, base, help, "summary");
            let s = h.snapshot();
            for (q, v) in [("0.5", s.quantile_us(0.5)),
                           ("0.99", s.quantile_us(0.99))] {
                let tag = format!("quantile=\"{q}\"");
                out.push_str(&format!("{} {v}\n",
                                      sample(base, labels, Some(&tag))));
            }
            let base_sum = format!("{base}_sum");
            out.push_str(&format!("{} {}\n", sample(&base_sum, labels, None),
                                  s.sum_us()));
            let base_count = format!("{base}_count");
            out.push_str(&format!("{} {}\n", sample(&base_count, labels, None),
                                  s.count()));
        }
        out
    }
}

/// Split `name{label="x"}` into the base family name and the raw label
/// body (without braces).
fn split_name(name: &str) -> (&str, Option<&str>) {
    match name.find('{') {
        Some(i) => (&name[..i], Some(name[i + 1..].trim_end_matches('}'))),
        None => (name, None),
    }
}

/// Emit HELP/TYPE once per family (callers iterate name-sorted maps, so
/// label sets of one family are adjacent).
fn head(out: &mut String, last: &mut String, base: &str, help: &str,
        kind: &str) {
    if *last != base {
        out.push_str(&format!("# HELP {base} {help}\n"));
        out.push_str(&format!("# TYPE {base} {kind}\n"));
        *last = base.to_string();
    }
}

/// Rebuild a sample name from base + labels (+ an extra label).
fn sample(base: &str, labels: Option<&str>, extra: Option<&str>) -> String {
    match (labels, extra) {
        (None, None) => base.to_string(),
        (Some(l), None) => format!("{base}{{{l}}}"),
        (None, Some(e)) => format!("{base}{{{e}}}"),
        (Some(l), Some(e)) => format!("{base}{{{l},{e}}}"),
    }
}

/// The process-wide registry (CLI subcommands and tests share it; the
/// serving handle can also export into a private one).
pub fn global() -> &'static Registry {
    static G: OnceLock<Registry> = OnceLock::new();
    G.get_or_init(Registry::new)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges_share_cells() {
        let r = Registry::new();
        r.counter("c_total", "a counter").add(2);
        r.counter("c_total", "a counter").inc();
        assert_eq!(r.counter("c_total", "a counter").get(), 3);
        r.gauge("g", "a gauge").set(0.5);
        assert_eq!(r.gauge("g", "a gauge").get(), 0.5);
    }

    #[test]
    fn atomic_histogram_matches_locked_single_thread() {
        let a = AtomicHistogram::new();
        let mut l = LatencyHistogram::new();
        for us in [1u64, 7, 63, 900, 70_000, 5_000_000] {
            a.record_us(us);
            l.record(Duration::from_micros(us));
        }
        let s = a.snapshot();
        assert_eq!(s.count(), l.count());
        assert_eq!(s.sum_us(), l.sum_us());
        assert_eq!(s.max_us(), l.max_us());
        assert_eq!(s.bucket_counts(), l.bucket_counts());
        for q in [0.5, 0.9, 0.99] {
            assert_eq!(s.quantile_us(q), l.quantile_us(q));
        }
    }

    #[test]
    fn set_from_round_trips() {
        let mut l = LatencyHistogram::new();
        for us in [10u64, 500, 90_000] {
            l.record(Duration::from_micros(us));
        }
        let a = AtomicHistogram::new();
        a.set_from(&l);
        let s = a.snapshot();
        assert_eq!(s.bucket_counts(), l.bucket_counts());
        assert_eq!(s.sum_us(), l.sum_us());
    }

    #[test]
    fn prometheus_dedups_family_headers() {
        let r = Registry::new();
        r.counter("req_total{variant=\"a\"}", "requests").add(1);
        r.counter("req_total{variant=\"b\"}", "requests").add(2);
        let text = r.render_prometheus();
        assert_eq!(text.matches("# HELP req_total").count(), 1);
        assert_eq!(text.matches("# TYPE req_total").count(), 1);
        assert!(text.contains("req_total{variant=\"a\"} 1"));
        assert!(text.contains("req_total{variant=\"b\"} 2"));
    }

    #[test]
    fn snapshot_has_schema_and_sections() {
        let r = Registry::new();
        r.histogram("lat_us", "latency").record_us(100);
        let j = r.snapshot();
        assert_eq!(j.get("schema").unwrap().as_str(), Some(SCHEMA));
        let reparsed = Json::parse(&j.to_string()).unwrap();
        let h = reparsed.at(&["histograms", "lat_us", "count"]).unwrap();
        assert_eq!(h.as_usize(), Some(1));
    }
}
