//! Observability layer: metrics registry, per-layer profiling and
//! request tracing.
//!
//! The paper's argument is an accounting argument — adder kernels win
//! because you can measure where the cycles, logic and energy go (§4).
//! This module gives the reproduction the same discipline at runtime:
//!
//! * [`registry`] — a process-wide registry of atomic counters, gauges
//!   and lock-free latency histograms with a stable JSON snapshot and a
//!   Prometheus text exposition;
//! * [`profile`] — per-layer wall-time + activation stats from the
//!   [`crate::sim::exec::ExecObserver`] hook, joined against the
//!   accelerator schedule's simulated cycles (measured vs modeled);
//! * [`trace`] — a per-thread ring-buffer span recorder exporting
//!   Chrome trace-event JSON loadable in Perfetto.
//!
//! No new dependencies: the crate stays anyhow-only.

pub mod profile;
pub mod registry;
pub mod trace;
