//! Per-layer profiles: measured wall-time from the observed graph walk
//! joined against the accelerator schedule's simulated cycles.
//!
//! One [`ProfileObserver`] serves every executor — the f32
//! [`Runner`], the integer [`PlanRunner`] and the hardware-backed
//! [`HwPlanRunner`] all drive the SAME instrumentation point
//! ([`crate::sim::exec::run_graph_observed`]) — so a profile row's label
//! is the graph's canonical op name, which is also the accelerator
//! schedule's row name.  The join invariant (pinned by `tests/obs.rs`):
//! the `hw_cycles` column, summed over the rows that have one, equals
//! the schedule's `total_cycles` EXACTLY, because [`LayerRun`] now
//! carries its post-conv pass in `post_cycles` and
//! `Σ (cycles + post_cycles) == total_cycles` by construction.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::nn::graph::Op;
use crate::quant::plan::QuantPlan;
use crate::sim::exec::{ActStats, ExecObserver};
use crate::sim::functional::{Runner, Tensor};
use crate::sim::hwsim::HwPlanRunner;
use crate::sim::kernels::KernelStrategy;
use crate::util::json::Json;
use crate::util::table::{self, Table};

/// Profile JSON schema tag.
pub const SCHEMA: &str = "addernet-profile-v1";

/// [`ExecObserver`] that collects one row per executed op.
#[derive(Debug, Default)]
pub struct ProfileObserver {
    rows: Vec<(usize, String, Duration, ActStats)>,
}

impl ProfileObserver {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn rows(&self) -> &[(usize, String, Duration, ActStats)] {
        &self.rows
    }
}

impl ExecObserver for ProfileObserver {
    fn op_done(&mut self, index: usize, label: &str, _start: Instant,
               wall: Duration, stats: ActStats) {
        self.rows.push((index, label.to_string(), wall, stats));
    }
}

/// One profiled op: measured side always present, modeled side
/// (`hw_cycles`) only for ops the accelerator schedule has a row for
/// (conv/dense/pool — relu, flatten and residual bookkeeping are free
/// on the array).
#[derive(Debug, Clone)]
pub struct LayerProfile {
    pub index: usize,
    pub label: String,
    pub wall_us: f64,
    pub elems: usize,
    pub mean_abs: f64,
    pub hw_cycles: Option<u64>,
    /// Concrete inner-kernel engine this layer's conv/dense dispatched
    /// to under the profiled strategy (`Auto` and the Winograd shape
    /// guard resolve per layer, so the pick is otherwise invisible).
    /// `None` for ops with no kernel (relu, pool, flatten, residual).
    pub kernel: Option<String>,
}

/// A full forward-pass profile.
#[derive(Debug, Clone)]
pub struct Profile {
    pub arch: String,
    pub mode: String,
    pub kernel: String,
    pub layers: Vec<LayerProfile>,
    pub wall_us_total: f64,
    /// The schedule's `total_cycles` (None for pure-f32 profiles with
    /// no hardware model attached).
    pub hw_total_cycles: Option<u64>,
    pub hw_fmax_mhz: Option<f64>,
    pub hw_latency_ms: Option<f64>,
}

impl Profile {
    fn from_rows(arch: String, mode: String, kernel: String,
                 obs: ProfileObserver, kernels: &BTreeMap<String, String>,
                 hw: Option<(&BTreeMap<String, u64>, u64, f64, f64)>)
                 -> Profile {
        let cycles_by_name = hw.map(|(m, _, _, _)| m);
        let layers: Vec<LayerProfile> = obs.rows.into_iter()
            .map(|(index, label, wall, stats)| LayerProfile {
                index,
                label: label.clone(),
                wall_us: wall.as_secs_f64() * 1e6,
                elems: stats.elems,
                mean_abs: stats.mean_abs,
                hw_cycles: cycles_by_name.and_then(|m| m.get(&label).copied()),
                kernel: kernels.get(&label).cloned(),
            })
            .collect();
        let wall_us_total = layers.iter().map(|l| l.wall_us).sum();
        Profile {
            arch,
            mode,
            kernel,
            layers,
            wall_us_total,
            hw_total_cycles: hw.map(|(_, t, _, _)| t),
            hw_fmax_mhz: hw.map(|(_, _, f, _)| f),
            hw_latency_ms: hw.map(|(_, _, _, l)| l),
        }
    }

    /// Sum of the `hw_cycles` column over the rows that carry one —
    /// equals `hw_total_cycles` exactly when the profile is hw-joined.
    pub fn hw_layer_cycle_sum(&self) -> Option<u64> {
        if self.hw_total_cycles.is_none() {
            return None;
        }
        Some(self.layers.iter().filter_map(|l| l.hw_cycles).sum())
    }

    /// Stable JSON (`addernet-profile-v1`).
    pub fn to_json(&self) -> Json {
        let opt_u64 =
            |v: Option<u64>| v.map_or(Json::Null, |x| Json::Num(x as f64));
        let mut top = BTreeMap::new();
        top.insert("schema".into(), Json::Str(SCHEMA.into()));
        top.insert("arch".into(), Json::Str(self.arch.clone()));
        top.insert("mode".into(), Json::Str(self.mode.clone()));
        top.insert("kernel".into(), Json::Str(self.kernel.clone()));
        top.insert("wall_us_total".into(), Json::Num(self.wall_us_total));
        top.insert("hw_total_cycles".into(), opt_u64(self.hw_total_cycles));
        top.insert("hw_fmax_mhz".into(),
                   self.hw_fmax_mhz.map_or(Json::Null, Json::Num));
        top.insert("hw_latency_ms".into(),
                   self.hw_latency_ms.map_or(Json::Null, Json::Num));
        let layers: Vec<Json> = self.layers.iter()
            .map(|l| {
                let mut m = BTreeMap::new();
                m.insert("index".into(), Json::Num(l.index as f64));
                m.insert("layer".into(), Json::Str(l.label.clone()));
                m.insert("wall_us".into(), Json::Num(l.wall_us));
                m.insert("elems".into(), Json::Num(l.elems as f64));
                m.insert("mean_abs".into(), Json::Num(l.mean_abs));
                m.insert("hw_cycles".into(), opt_u64(l.hw_cycles));
                m.insert("kernel".into(), l.kernel.clone()
                    .map_or(Json::Null, Json::Str));
                Json::Obj(m)
            })
            .collect();
        top.insert("layers".into(), Json::Arr(layers));
        Json::Obj(top)
    }

    /// Per-layer table: wall-µs rows align with hw cycle rows by graph
    /// op name; the cycle column sums to the schedule total.
    pub fn table(&self) -> Table {
        let title = format!("profile {} {} ({} kernel)", self.arch, self.mode,
                            self.kernel);
        let mut t = Table::new(
            &title,
            &["layer", "kernel", "wall us", "wall %", "elems", "mean|act|",
              "hw cycles"]);
        for l in &self.layers {
            let share = if self.wall_us_total > 0.0 {
                l.wall_us / self.wall_us_total
            } else {
                0.0
            };
            t.row(&[l.label.clone(),
                    l.kernel.clone().unwrap_or_else(|| "-".into()),
                    table::f(l.wall_us, 1),
                    table::pct(share),
                    table::thousands(l.elems as u64),
                    table::f(l.mean_abs, 4),
                    l.hw_cycles.map_or("-".into(), table::thousands)]);
        }
        let hw_total =
            self.hw_total_cycles.map_or("-".into(), table::thousands);
        t.row(&["TOTAL".into(),
                "".into(),
                table::f(self.wall_us_total, 1),
                table::pct(1.0),
                "".into(),
                "".into(),
                hw_total]);
        t
    }
}

/// Cycle map `layer name -> cycles + post_cycles` from a schedule.
fn schedule_cycles(report: &crate::sim::accelerator::RunReport)
                   -> BTreeMap<String, u64> {
    let mut m = BTreeMap::new();
    for l in &report.layers {
        *m.entry(l.name.clone()).or_insert(0) += l.cycles + l.post_cycles;
    }
    m
}

/// Kernel map `layer name -> concrete engine label` for an integer
/// plan: convs resolve through the shape-aware conv dispatch (so the
/// Winograd guard sees each layer's geometry and kernel family), dense
/// heads through the row dispatch.
fn plan_kernel_map(plan: &QuantPlan, strategy: KernelStrategy)
                   -> BTreeMap<String, String> {
    let mut m = BTreeMap::new();
    for (name, c) in &plan.convs {
        let r = strategy.resolve_conv(c.cout, c.kh, c.kw, c.stride, c.cin,
                                      plan.kind);
        m.insert(name.clone(), r.label().to_string());
    }
    for (name, d) in &plan.dense {
        m.insert(name.clone(), strategy.resolve(d.dout).label().to_string());
    }
    m
}

/// Kernel map for the f32 path: float convs never take the Winograd
/// transform (it would reassociate float sums and break bit-compat), so
/// every op resolves through the row dispatch.
fn f32_kernel_map(runner: &Runner) -> BTreeMap<String, String> {
    let mut m = BTreeMap::new();
    for op in &runner.arch.graph().ops {
        match op {
            Op::ConvBn(c) | Op::ResidualClose { shortcut: Some(c) } => {
                m.insert(c.name.clone(),
                         runner.strategy.resolve(c.cout).label().to_string());
            }
            Op::Dense(d) => {
                m.insert(d.name.clone(),
                         runner.strategy.resolve(d.dout).label().to_string());
            }
            _ => {}
        }
    }
    m
}

/// Export `addernet_layer_kernel{arch=...,layer=...,kernel=...} = 1`
/// info-gauges to the global registry so scrapes can see the concrete
/// per-layer engine picks alongside the dispatch counters.
fn export_kernel_gauges(arch: &str, map: &BTreeMap<String, String>) {
    for (layer, kernel) in map {
        crate::obs::registry::global()
            .gauge(&format!("addernet_layer_kernel{{arch=\"{arch}\",\
                             layer=\"{layer}\",kernel=\"{kernel}\"}}"),
                   "concrete kernel engine resolved per layer")
            .set(1.0);
    }
}

/// Profile an f32 forward pass (no hardware join — the float path has
/// no accelerator schedule).
pub fn profile_f32(runner: &mut Runner, x: &Tensor) -> Profile {
    let mut obs = ProfileObserver::new();
    runner.forward_observed(x, &mut obs);
    let kernels = f32_kernel_map(runner);
    export_kernel_gauges(runner.arch.name(), &kernels);
    Profile::from_rows(runner.arch.name().to_string(), "f32".to_string(),
                       runner.kind.label().to_string(), obs, &kernels, None)
}

/// Profile an integer plan on the simulated accelerator: measured
/// wall-µs per op from the observed walk, modeled cycles per layer from
/// the plan's schedule, joined by canonical op name.
pub fn profile_plan(plan: &QuantPlan, strategy: KernelStrategy,
                    parallelism: u64, x: &Tensor) -> Result<Profile> {
    let hw = HwPlanRunner::new(plan, strategy, parallelism)?;
    let mut obs = ProfileObserver::new();
    let (_, cost) = hw.forward_observed(x, &mut obs);
    let cycles = schedule_cycles(hw.report());
    let mode = format!("int{}", plan.cfg.bits);
    let kernels = plan_kernel_map(plan, strategy);
    export_kernel_gauges(plan.arch.name(), &kernels);
    Ok(Profile::from_rows(
        plan.arch.name().to_string(), mode, plan.kind.label().to_string(),
        obs, &kernels,
        Some((&cycles, hw.report().total_cycles, cost.fmax_mhz,
              hw.report().latency_ms()))))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{Calibration, LayerCalib, Mode};
    use crate::sim::functional::{synth_params, Arch, ExecMode, QuantCfg,
                                 SimKernel};
    use crate::util::XorShift64;

    fn lenet_plan() -> QuantPlan {
        let params = synth_params(Arch::Lenet5, 3);
        let mut calib = Calibration::new();
        calib.insert("conv1".into(),
                     LayerCalib { feat_max_abs: 1.0, weight_max_abs: 0.5 });
        calib.insert("conv2".into(),
                     LayerCalib { feat_max_abs: 16.0, weight_max_abs: 0.5 });
        QuantPlan::build(&params, Arch::Lenet5, SimKernel::Adder,
                         QuantCfg { bits: 8, mode: Mode::SharedScale },
                         &calib)
            .unwrap()
    }

    fn image(seed: u64) -> Tensor {
        let mut rng = XorShift64::new(seed);
        Tensor::new((1, 32, 32, 1),
                    (0..1024).map(|_| rng.next_f32_sym(1.0)).collect())
    }

    #[test]
    fn plan_profile_cycle_column_sums_to_schedule_total() {
        let plan = lenet_plan();
        let p = profile_plan(&plan, KernelStrategy::Auto, 1024, &image(3))
            .unwrap();
        assert_eq!(p.hw_layer_cycle_sum(), p.hw_total_cycles);
        assert!(p.hw_total_cycles.unwrap() > 0);
        // one row per graph op, labels join the schedule's conv rows
        assert!(p.layers.iter().any(|l| l.label == "conv1"
                                    && l.hw_cycles.is_some()));
        assert!(p.layers.iter().any(|l| l.label == "relu"
                                    && l.hw_cycles.is_none()));
        assert!(p.wall_us_total > 0.0);
    }

    #[test]
    fn f32_profile_has_rows_but_no_hw_side() {
        let params = synth_params(Arch::Lenet5, 3);
        let mut runner = Runner {
            params: &params,
            arch: Arch::Lenet5,
            kind: SimKernel::Adder,
            strategy: KernelStrategy::Auto,
            mode: ExecMode::F32,
            calib: None,
            observe: None,
        };
        let p = profile_f32(&mut runner, &image(4));
        assert!(p.layers.len() > 4);
        assert!(p.layers.iter().all(|l| l.hw_cycles.is_none()));
        assert_eq!(p.hw_layer_cycle_sum(), None);
        assert!(p.layers.iter().all(|l| l.elems > 0));
    }

    #[test]
    fn profile_json_round_trips() {
        let plan = lenet_plan();
        let p = profile_plan(&plan, KernelStrategy::Auto, 1024, &image(5))
            .unwrap();
        let j = Json::parse(&p.to_json().to_string()).unwrap();
        assert_eq!(j.get("schema").unwrap().as_str(), Some(SCHEMA));
        assert_eq!(j.get("arch").unwrap().as_str(), Some("lenet5"));
        assert_eq!(j.get("mode").unwrap().as_str(), Some("int8"));
        let layers = j.get("layers").unwrap().as_arr().unwrap();
        assert_eq!(layers.len(), p.layers.len());
        let total = j.get("hw_total_cycles").unwrap().as_usize().unwrap();
        assert_eq!(total as u64, p.hw_total_cycles.unwrap());
        // table renders one row per layer plus the TOTAL line
        assert_eq!(p.table().rows_len(), p.layers.len() + 1);
    }

    #[test]
    fn kernel_column_reports_concrete_engine_per_layer() {
        let plan = lenet_plan();
        // lenet's 5x5 convs fail the Winograd shape guard, so the
        // column records the heuristic fallback pick per layer
        // (deterministically — Winograd dispatch never consults
        // ADDERNET_KERNEL).
        let p = profile_plan(&plan, KernelStrategy::Winograd, 1024, &image(6))
            .unwrap();
        let kernel_of = |name: &str| {
            p.layers.iter().find(|l| l.label == name).unwrap().kernel.clone()
        };
        assert_eq!(kernel_of("conv1").as_deref(), Some("tiled")); // cout 6
        assert_eq!(kernel_of("conv2").as_deref(), Some("simd")); // cout 16
        assert_eq!(kernel_of("fc1").as_deref(), Some("simd")); // dout 120
        assert!(kernel_of("relu").is_none());
        // explicit strategies pin every kernel-bearing row
        let p2 = profile_plan(&plan, KernelStrategy::Naive, 1024, &image(6))
            .unwrap();
        assert!(p2.layers.iter().any(|l| l.kernel.is_some()));
        assert!(p2.layers.iter()
            .filter(|l| l.kernel.is_some())
            .all(|l| l.kernel.as_deref() == Some("naive")));
        // the JSON layer objects carry the kernel key additively
        let j = Json::parse(&p.to_json().to_string()).unwrap();
        let layers = j.get("layers").unwrap().as_arr().unwrap();
        assert!(layers.iter().any(|l| {
            l.get("kernel").and_then(|k| k.as_str()) == Some("tiled")
        }));
    }
}
