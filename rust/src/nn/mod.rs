//! Network descriptors: shapes, MACs, parameter counts.
//!
//! These drive the FPGA accelerator simulator (which layers to tile, how
//! many ops to schedule, how much data to move) and the S8 comparison
//! table.  Descriptors cover the paper's evaluation workloads: LeNet-5
//! (Fig. 5), ResNet-18 (on-board E8), ResNet-20/50 (quantization
//! experiments) plus VGG-16/AlexNet (S8 comparison rows).
//!
//! Every topology is encoded ONCE, as a compiled op program in
//! [`graph`]; the [`NetworkDesc`] values here are derived from those
//! programs ([`graph::NetGraph::to_desc`]), so descriptor naming and
//! runtime naming cannot diverge.

pub mod builders;
pub mod graph;

pub use builders::*;

/// Spatial padding mode (mirrors the JAX layer conventions).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Padding {
    Same,
    Valid,
}

/// Output extent of one spatial dimension under `padding`.  A VALID
/// kernel larger than the input yields zero outputs (the degenerate
/// all-padding case the oracle's edge grid exercises) rather than a
/// usize underflow.
pub fn conv_out_dim(in_sz: usize, k: usize, stride: usize, padding: Padding) -> usize {
    match padding {
        Padding::Same => in_sz.div_ceil(stride),
        Padding::Valid => in_sz.checked_sub(k).map_or(0, |d| d / stride + 1),
    }
}

/// Output extent of one spatial dimension under VALID window pooling —
/// the geometry the descriptors, the graph walk and the accelerator
/// schedule all share.  (The runtime executors keep their floor+clamp
/// semantics; for every window == stride pool the two agree, and the
/// descriptor side must not overcount outputs when they don't.)
pub fn pool_out_dim(in_sz: usize, window: usize, stride: usize) -> usize {
    conv_out_dim(in_sz, window, stride, Padding::Valid)
}

/// (before, after) zero padding for one spatial dimension — SAME mode
/// centres the kernel the way JAX/TF do (extra pad goes after).
pub fn same_pad(in_sz: usize, k: usize, stride: usize) -> (usize, usize) {
    let out = in_sz.div_ceil(stride);
    let total = ((out - 1) * stride + k).saturating_sub(in_sz);
    (total / 2, total - total / 2)
}

/// Full 2-D conv geometry: (pad_top, pad_left, h_out, w_out).  The single
/// source of truth shared by the layer descriptors below and the
/// functional-sim engine.
pub fn conv_geometry(
    h: usize,
    w: usize,
    kh: usize,
    kw: usize,
    stride: usize,
    padding: Padding,
) -> (usize, usize, usize, usize) {
    let (pt, pl) = match padding {
        Padding::Same => (same_pad(h, kh, stride).0, same_pad(w, kw, stride).0),
        Padding::Valid => (0, 0),
    };
    (pt, pl, conv_out_dim(h, kh, stride, padding), conv_out_dim(w, kw, stride, padding))
}

/// One convolution workload.
#[derive(Debug, Clone)]
pub struct ConvLayer {
    pub name: String,
    pub kh: usize,
    pub kw: usize,
    pub cin: usize,
    pub cout: usize,
    pub h_in: usize,
    pub w_in: usize,
    pub stride: usize,
    pub padding: Padding,
}

impl ConvLayer {
    pub fn h_out(&self) -> usize {
        conv_out_dim(self.h_in, self.kh, self.stride, self.padding)
    }

    pub fn w_out(&self) -> usize {
        conv_out_dim(self.w_in, self.kw, self.stride, self.padding)
    }

    /// Multiply-accumulate (or add-accumulate) count for one image.
    pub fn macs(&self) -> u64 {
        (self.kh * self.kw * self.cin * self.cout * self.h_out() * self.w_out()) as u64
    }

    pub fn params(&self) -> u64 {
        (self.kh * self.kw * self.cin * self.cout) as u64
    }

    /// Input feature bytes at data width `dw_bits`.
    pub fn input_bytes(&self, dw_bits: u32) -> u64 {
        (self.h_in * self.w_in * self.cin) as u64 * dw_bits as u64 / 8
    }

    pub fn output_bytes(&self, dw_bits: u32) -> u64 {
        (self.h_out() * self.w_out() * self.cout) as u64 * dw_bits as u64 / 8
    }

    pub fn weight_bytes(&self, dw_bits: u32) -> u64 {
        self.params() * dw_bits as u64 / 8
    }
}

/// Non-conv layers tracked for op/traffic accounting.
#[derive(Debug, Clone)]
pub enum Layer {
    Conv(ConvLayer),
    /// Window pooling (avg or max — same cost model).
    Pool { name: String, window: usize, stride: usize, h_in: usize, w_in: usize, ch: usize },
    Dense { name: String, din: usize, dout: usize },
    GlobalPool { name: String, ch: usize, h_in: usize, w_in: usize },
}

impl Layer {
    pub fn macs(&self) -> u64 {
        match self {
            Layer::Conv(c) => c.macs(),
            Layer::Dense { din, dout, .. } => (din * dout) as u64,
            Layer::Pool { window, h_in, w_in, ch, stride, .. } => {
                (pool_out_dim(*h_in, *window, *stride)
                    * pool_out_dim(*w_in, *window, *stride)
                    * ch * window * window) as u64 / 2
            }
            Layer::GlobalPool { ch, h_in, w_in, .. } => {
                (ch * h_in * w_in) as u64 / 2
            }
        }
    }

    pub fn params(&self) -> u64 {
        match self {
            Layer::Conv(c) => c.params() + c.cout as u64, // + BN scale
            Layer::Dense { din, dout, .. } => (din * dout + dout) as u64,
            _ => 0,
        }
    }

    pub fn name(&self) -> &str {
        match self {
            Layer::Conv(c) => &c.name,
            Layer::Pool { name, .. } => name,
            Layer::Dense { name, .. } => name,
            Layer::GlobalPool { name, .. } => name,
        }
    }
}

/// A whole network workload.
#[derive(Debug, Clone)]
pub struct NetworkDesc {
    pub name: String,
    /// Input (h, w, c).
    pub input: (usize, usize, usize),
    pub layers: Vec<Layer>,
}

impl NetworkDesc {
    /// Total operations per image, counting 1 MAC = 2 ops (paper's GOP).
    pub fn ops(&self) -> u64 {
        2 * self.layers.iter().map(|l| l.macs()).sum::<u64>()
    }

    pub fn gops(&self) -> f64 {
        self.ops() as f64 / 1e9
    }

    pub fn params(&self) -> u64 {
        self.layers.iter().map(|l| l.params()).sum()
    }

    pub fn conv_layers(&self) -> impl Iterator<Item = &ConvLayer> {
        self.layers.iter().filter_map(|l| match l {
            Layer::Conv(c) => Some(c),
            _ => None,
        })
    }

    /// Share of ops in convolutions (the part the PE array accelerates).
    pub fn conv_op_fraction(&self) -> f64 {
        let conv: u64 = self.conv_layers().map(|c| c.macs()).sum();
        let total: u64 = self.layers.iter().map(|l| l.macs()).sum();
        conv as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geometry_helpers() {
        assert_eq!(conv_out_dim(32, 3, 1, Padding::Same), 32);
        assert_eq!(conv_out_dim(32, 5, 1, Padding::Valid), 28);
        assert_eq!(same_pad(32, 3, 1), (1, 1));
        assert_eq!(same_pad(5, 2, 2), (0, 1));
        let (pt, pl, ho, wo) = conv_geometry(9, 7, 3, 3, 2, Padding::Same);
        assert_eq!((ho, wo), (5, 4));
        assert_eq!((pt, pl), (1, 1));
    }

    #[test]
    fn pool_geometry_valid_semantics() {
        // window == stride, divisible: matches the old floor formula.
        assert_eq!(pool_out_dim(28, 2, 2), 14);
        assert_eq!(pool_out_dim(14, 2, 2), 7);
        // window != stride: floor would say 112/2 = 56; a valid 3-wide
        // window only fits 55 times (the ResNet-18/50 stem pool).
        assert_eq!(pool_out_dim(112, 3, 2), 55);
        assert_eq!(pool_out_dim(55, 3, 2), 27);
        // non-divisible input: a 2/2 window leaves the odd tail out.
        assert_eq!(pool_out_dim(5, 2, 2), 2);
        // degenerate: window larger than the input yields zero outputs.
        assert_eq!(pool_out_dim(2, 3, 2), 0);
    }

    #[test]
    fn pool_layer_macs_use_valid_geometry() {
        let p = Layer::Pool {
            name: "pool1".into(), window: 3, stride: 2,
            h_in: 112, w_in: 112, ch: 64,
        };
        assert_eq!(p.macs(), (55 * 55 * 64 * 9) as u64 / 2);
    }

    #[test]
    fn kernel_larger_than_input() {
        // VALID with k > input: zero outputs, no underflow.
        assert_eq!(conv_out_dim(3, 5, 1, Padding::Valid), 0);
        assert_eq!(conv_out_dim(3, 5, 2, Padding::Valid), 0);
        let (_, _, ho, wo) = conv_geometry(3, 3, 5, 5, 1, Padding::Valid);
        assert_eq!((ho, wo), (0, 0));
        // SAME keeps the spatial grid; the border rows are all padding.
        assert_eq!(conv_out_dim(3, 5, 1, Padding::Same), 3);
        assert_eq!(same_pad(3, 5, 1), (2, 2));
    }

    #[test]
    fn conv_shapes() {
        let c = ConvLayer {
            name: "c".into(), kh: 5, kw: 5, cin: 1, cout: 6,
            h_in: 32, w_in: 32, stride: 1, padding: Padding::Valid,
        };
        assert_eq!(c.h_out(), 28);
        assert_eq!(c.macs(), 5 * 5 * 6 * 28 * 28);
        let s = ConvLayer { stride: 2, padding: Padding::Same, ..c };
        assert_eq!(s.h_out(), 16);
    }

    /// S8 anchor: ResNet-18 at 224x224 is ~3.4-3.7 GOP, ~11.6M params.
    #[test]
    fn resnet18_matches_s8_row() {
        let net = resnet18();
        let gop = net.gops();
        assert!((3.3..=3.8).contains(&gop), "resnet18 {gop} GOP");
        let mp = net.params() as f64 / 1e6;
        assert!((11.0..=12.2).contains(&mp), "resnet18 {mp}M params");
    }

    /// S8 anchors for the comparison rows.
    #[test]
    fn vgg16_alexnet_match_s8_rows() {
        let v = vgg16();
        assert!((29.0..=32.0).contains(&v.gops()), "vgg16 {} GOP", v.gops());
        assert!((135.0..=140.0).contains(&(v.params() as f64 / 1e6)));
        let a = alexnet();
        assert!((1.2..=1.6).contains(&a.gops()), "alexnet {} GOP", a.gops());
        assert!((58.0..=63.0).contains(&(a.params() as f64 / 1e6)),
                "alexnet {}M", a.params() as f64 / 1e6);
    }

    #[test]
    fn resnet50_scale() {
        let n = resnet50();
        assert!((7.0..=8.5).contains(&n.gops()), "resnet50 {} GOP", n.gops());
        assert!((24.0..=27.0).contains(&(n.params() as f64 / 1e6)));
    }

    #[test]
    fn lenet5_tiny() {
        let n = lenet5();
        assert!(n.ops() < 2_000_000);
        assert_eq!(n.conv_layers().count(), 2);
        let c: Vec<_> = n.conv_layers().collect();
        assert_eq!((c[0].cin, c[0].cout), (1, 6));
        assert_eq!((c[1].cin, c[1].cout), (6, 16));
    }

    #[test]
    fn conv_dominates_big_nets() {
        for net in [resnet18(), vgg16(), resnet50()] {
            assert!(net.conv_op_fraction() > 0.95, "{}", net.name);
        }
    }

    #[test]
    fn resnet20_cifar_scale() {
        let n = resnet20();
        assert!((0.26..=0.30).contains(&(n.params() as f64 / 1e6)),
                "{}M", n.params() as f64 / 1e6);
    }
}
