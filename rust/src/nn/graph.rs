//! The layer-graph IR: ONE compiled op program per architecture.
//!
//! The paper's pipeline (adder conv → folded BN → ReLU → pool/residual,
//! §3.1) used to be transcribed by hand in four executors plus the
//! hardware descriptors, all of which had to stay in lock-step.  This
//! module is now the single place a topology is encoded:
//!
//! * [`NetGraph`] — a linearized op program ([`Op`]) with canonical
//!   layer names, strides, padding and channel geometry, compiled once
//!   per network from the declarative builders below and cached in a
//!   process-wide registry ([`by_name`] / [`all`]);
//! * [`Arch`] — the runtime-servable subset of that registry (the
//!   networks the functional simulator, the quantization planner and
//!   the serving backend execute); `Arch::graph()` is the program every
//!   forward pass walks;
//! * [`NetGraph::to_desc`] — derives the [`NetworkDesc`] the FPGA
//!   simulator and the S8 comparison tables consume, so report naming
//!   and runtime naming cannot diverge (`s0b0/c1` everywhere).
//!
//! Executors never match on an architecture: they implement the
//! numeric-domain hooks of [`crate::sim::exec::Domain`] and let
//! [`crate::sim::exec::run_graph`] drive them.  Adding a network is one
//! builder function + one registry entry (and, to serve it, one `Arch`
//! variant) — no executor, planner or synthesizer edits.

use std::sync::OnceLock;

use super::{conv_out_dim, pool_out_dim, ConvLayer, Layer, NetworkDesc, Padding};

/// One conv + batch-norm stage: the unit both the f32 path (eval-mode
/// BN) and the int path (BN folded into the accumulator) execute.
#[derive(Debug, Clone)]
pub struct ConvBnSpec {
    /// Canonical parameter/calibration key ("conv1", "s0b0/c1", ...).
    pub name: String,
    pub kh: usize,
    pub kw: usize,
    pub cin: usize,
    pub cout: usize,
    pub stride: usize,
    pub padding: Padding,
}

/// One dense (classifier-head) layer.
#[derive(Debug, Clone)]
pub struct DenseSpec {
    pub name: String,
    pub din: usize,
    pub dout: usize,
}

/// One op of the linearized network program.  Residual blocks are
/// expressed as an Open/Close bracket: `ResidualOpen` saves the current
/// activation, `ResidualClose` adds it back (through the optional
/// projection conv when the channel count or stride changes).
#[derive(Debug, Clone)]
pub enum Op {
    ConvBn(ConvBnSpec),
    Relu,
    /// 2x2/2 average pooling (the LeNet/cnv6 downsampler).
    AvgPool2,
    /// Window max pooling — only the descriptor-only ImageNet networks
    /// use it today, but both execution domains implement it.
    MaxPool { window: usize, stride: usize },
    GlobalAvgPool,
    /// NHWC reshape to (n, 1, 1, h*w*c) before a dense head.
    Flatten,
    ResidualOpen,
    ResidualClose { shortcut: Option<ConvBnSpec> },
    Dense(DenseSpec),
}

/// A compiled network program plus its identity and input geometry.
#[derive(Debug, Clone)]
pub struct NetGraph {
    /// Registry/CLI id ("resnet20").
    pub id: &'static str,
    /// Display name ("ResNet-20").
    pub display: &'static str,
    /// Input (h, w, c).
    pub input: (usize, usize, usize),
    pub ops: Vec<Op>,
}

impl NetGraph {
    /// Conv specs in forward order; a residual block's projection conv
    /// follows the block's main-path convs (the order `synth_params`
    /// draws random weights in — part of the golden-equivalence
    /// contract with the pre-graph synthesizer).
    pub fn conv_specs(&self) -> Vec<&ConvBnSpec> {
        let mut out = Vec::new();
        for op in &self.ops {
            match op {
                Op::ConvBn(c) => out.push(c),
                Op::ResidualClose { shortcut: Some(c) } => out.push(c),
                _ => {}
            }
        }
        out
    }

    /// Dense specs in forward order.
    pub fn dense_specs(&self) -> Vec<&DenseSpec> {
        self.ops.iter()
            .filter_map(|op| match op {
                Op::Dense(d) => Some(d),
                _ => None,
            })
            .collect()
    }

    /// Derive the hardware-model descriptor from the program: conv,
    /// pool, global-pool and dense layers with spatial geometry tracked
    /// through the walk.  Layer names are the graph's canonical names,
    /// so `Params` keys and report rows agree by construction.
    pub fn to_desc(&self) -> NetworkDesc {
        fn push_conv(layers: &mut Vec<Layer>, c: &ConvBnSpec, h_in: usize,
                     w_in: usize) {
            layers.push(Layer::Conv(ConvLayer {
                name: c.name.clone(),
                kh: c.kh,
                kw: c.kw,
                cin: c.cin,
                cout: c.cout,
                h_in,
                w_in,
                stride: c.stride,
                padding: c.padding,
            }));
        }
        let (mut h, mut w, mut ch) = self.input;
        let mut pools = 0usize;
        let mut saved: Vec<(usize, usize)> = Vec::new();
        let mut layers = Vec::new();
        for op in &self.ops {
            match op {
                Op::ConvBn(c) => {
                    push_conv(&mut layers, c, h, w);
                    h = conv_out_dim(h, c.kh, c.stride, c.padding);
                    w = conv_out_dim(w, c.kw, c.stride, c.padding);
                    ch = c.cout;
                }
                Op::AvgPool2 => {
                    pools += 1;
                    layers.push(Layer::Pool {
                        name: format!("pool{pools}"),
                        window: 2,
                        stride: 2,
                        h_in: h,
                        w_in: w,
                        ch,
                    });
                    h = pool_out_dim(h, 2, 2);
                    w = pool_out_dim(w, 2, 2);
                }
                Op::MaxPool { window, stride } => {
                    pools += 1;
                    layers.push(Layer::Pool {
                        name: format!("pool{pools}"),
                        window: *window,
                        stride: *stride,
                        h_in: h,
                        w_in: w,
                        ch,
                    });
                    h = pool_out_dim(h, *window, *stride);
                    w = pool_out_dim(w, *window, *stride);
                }
                Op::GlobalAvgPool => {
                    layers.push(Layer::GlobalPool {
                        name: "gap".into(),
                        ch,
                        h_in: h,
                        w_in: w,
                    });
                    h = 1;
                    w = 1;
                }
                Op::ResidualOpen => saved.push((h, w)),
                Op::ResidualClose { shortcut } => {
                    let (sh, sw) = saved.pop()
                        .expect("ResidualClose without ResidualOpen");
                    if let Some(c) = shortcut {
                        push_conv(&mut layers, c, sh, sw);
                        ch = c.cout;
                    }
                }
                Op::Dense(d) => {
                    layers.push(Layer::Dense {
                        name: d.name.clone(),
                        din: d.din,
                        dout: d.dout,
                    });
                }
                Op::Relu | Op::Flatten => {}
            }
        }
        NetworkDesc {
            name: self.display.to_string(),
            input: self.input,
            layers,
        }
    }
}

// ---------------------------------------------------------------------------
// The runtime-servable architectures
// ---------------------------------------------------------------------------

/// Model architectures the functional runner, the quantization planner
/// and the serving backend execute (32x32x1 synthetic-10 input).  Every
/// variant maps to a registry graph; executors contain NO per-arch
/// code, so a new entry here + a builder below serves end-to-end
/// (f32, per-call quant, int8/int16 plans, calibration, benches) with
/// zero executor edits.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Arch {
    Lenet5,
    /// VGG-style plain 6-conv stack (graph-description payoff proof).
    Cnv6,
    Resnet8,
    Resnet20,
    /// Deeper CIFAR-style residual net (5 blocks per stage).
    Resnet32,
}

impl Arch {
    pub const ALL: [Arch; 5] = [
        Arch::Lenet5,
        Arch::Cnv6,
        Arch::Resnet8,
        Arch::Resnet20,
        Arch::Resnet32,
    ];

    /// Registry/CLI id.
    pub fn name(self) -> &'static str {
        match self {
            Arch::Lenet5 => "lenet5",
            Arch::Cnv6 => "cnv6",
            Arch::Resnet8 => "resnet8",
            Arch::Resnet20 => "resnet20",
            Arch::Resnet32 => "resnet32",
        }
    }

    pub fn parse(s: &str) -> Option<Arch> {
        Arch::ALL.into_iter().find(|a| a.name() == s)
    }

    /// `lenet5|cnv6|...` — for CLI error messages.
    pub fn names_label() -> String {
        Arch::ALL.map(|a| a.name()).join("|")
    }

    /// The compiled op program every forward pass, plan build and
    /// parameter synthesis walks.
    pub fn graph(self) -> &'static NetGraph {
        by_name(self.name()).expect("every Arch is registered")
    }
}

// ---------------------------------------------------------------------------
// Declarative builders (the ONE place each topology is encoded)
// ---------------------------------------------------------------------------

fn conv(name: &str, k: usize, cin: usize, cout: usize, stride: usize,
        padding: Padding) -> ConvBnSpec {
    ConvBnSpec { name: name.into(), kh: k, kw: k, cin, cout, stride, padding }
}

fn dense(name: &str, din: usize, dout: usize) -> DenseSpec {
    DenseSpec { name: name.into(), din, dout }
}

/// Dense stack with ReLU between layers (not after the logits).
fn head(ops: &mut Vec<Op>, stack: &[(&str, usize, usize)]) {
    for (i, &(name, din, dout)) in stack.iter().enumerate() {
        if i > 0 {
            ops.push(Op::Relu);
        }
        ops.push(Op::Dense(dense(name, din, dout)));
    }
}

/// LeNet-5 on 32x32x1 — the fully-on-chip workload of Fig. 5.
fn lenet5() -> NetGraph {
    let mut ops = vec![
        Op::ConvBn(conv("conv1", 5, 1, 6, 1, Padding::Valid)), // -> 28x28x6
        Op::Relu,
        Op::AvgPool2,                                          // -> 14x14x6
        Op::ConvBn(conv("conv2", 5, 6, 16, 1, Padding::Valid)), // -> 10x10x16
        Op::Relu,
        Op::AvgPool2,                                          // -> 5x5x16
        Op::Flatten,
    ];
    head(&mut ops, &[("fc1", 400, 120), ("fc2", 120, 84), ("fc3", 84, 10)]);
    NetGraph { id: "lenet5", display: "LeNet-5", input: (32, 32, 1), ops }
}

/// VGG-style plain stack: conv pairs at 16/32/64 channels with 2x2
/// average-pool downsampling — no residuals, multi-conv stages.
fn cnv6() -> NetGraph {
    let mut ops = vec![
        Op::ConvBn(conv("c1", 3, 1, 16, 1, Padding::Same)),
        Op::Relu,
        Op::ConvBn(conv("c2", 3, 16, 16, 1, Padding::Same)),
        Op::Relu,
        Op::AvgPool2, // -> 16x16
        Op::ConvBn(conv("c3", 3, 16, 32, 1, Padding::Same)),
        Op::Relu,
        Op::ConvBn(conv("c4", 3, 32, 32, 1, Padding::Same)),
        Op::Relu,
        Op::AvgPool2, // -> 8x8
        Op::ConvBn(conv("c5", 3, 32, 64, 1, Padding::Same)),
        Op::Relu,
        Op::ConvBn(conv("c6", 3, 64, 64, 1, Padding::Same)),
        Op::Relu,
        Op::GlobalAvgPool,
    ];
    head(&mut ops, &[("fc", 64, 10)]);
    NetGraph { id: "cnv6", display: "CNV-6", input: (32, 32, 1), ops }
}

/// CIFAR-style residual family (stem + 16/32/64 stages of basic
/// blocks): resnet8 (1 block/stage), resnet20 (3), resnet32 (5).
fn residual(id: &'static str, display: &'static str,
            blocks_per_stage: usize) -> NetGraph {
    let mut ops = vec![
        Op::ConvBn(conv("stem", 3, 1, 16, 1, Padding::Same)),
        Op::Relu,
    ];
    let mut cin = 16usize;
    for (s, cout) in [16usize, 32, 64].into_iter().enumerate() {
        for b in 0..blocks_per_stage {
            let pre = format!("s{s}b{b}");
            let stride = if s > 0 && b == 0 { 2 } else { 1 };
            ops.push(Op::ResidualOpen);
            ops.push(Op::ConvBn(conv(&format!("{pre}/c1"), 3, cin, cout,
                                     stride, Padding::Same)));
            ops.push(Op::Relu);
            ops.push(Op::ConvBn(conv(&format!("{pre}/c2"), 3, cout, cout, 1,
                                     Padding::Same)));
            let shortcut = (cin != cout).then(|| {
                conv(&format!("{pre}/sc"), 1, cin, cout, stride, Padding::Same)
            });
            ops.push(Op::ResidualClose { shortcut });
            ops.push(Op::Relu);
            cin = cout;
        }
    }
    ops.push(Op::GlobalAvgPool);
    head(&mut ops, &[("fc", 64, 10)]);
    NetGraph { id, display, input: (32, 32, 1), ops }
}

/// ImageNet residual family (descriptor-only: drives the FPGA model and
/// the S8 table, no runtime parameters exist).
fn resnet_imagenet(id: &'static str, display: &'static str, blocks: &[usize],
                   bottleneck: bool) -> NetGraph {
    let mut ops = vec![
        Op::ConvBn(conv("stem", 7, 3, 64, 2, Padding::Same)), // -> 112
        Op::Relu,
        Op::MaxPool { window: 3, stride: 2 }, // -> 56
    ];
    let widths = [64usize, 128, 256, 512];
    let expansion = if bottleneck { 4 } else { 1 };
    let mut cin = 64usize;
    for (s, &n) in blocks.iter().enumerate() {
        let width = widths[s];
        for b in 0..n {
            let pre = format!("s{s}b{b}");
            let stride = if s > 0 && b == 0 { 2 } else { 1 };
            ops.push(Op::ResidualOpen);
            if bottleneck {
                ops.push(Op::ConvBn(conv(&format!("{pre}/c1"), 1, cin, width,
                                         1, Padding::Same)));
                ops.push(Op::Relu);
                ops.push(Op::ConvBn(conv(&format!("{pre}/c2"), 3, width, width,
                                         stride, Padding::Same)));
                ops.push(Op::Relu);
                ops.push(Op::ConvBn(conv(&format!("{pre}/c3"), 1, width,
                                         width * 4, 1, Padding::Same)));
            } else {
                ops.push(Op::ConvBn(conv(&format!("{pre}/c1"), 3, cin, width,
                                         stride, Padding::Same)));
                ops.push(Op::Relu);
                ops.push(Op::ConvBn(conv(&format!("{pre}/c2"), 3, width, width,
                                         1, Padding::Same)));
            }
            let cout = width * expansion;
            let shortcut = (cin != cout).then(|| {
                conv(&format!("{pre}/sc"), 1, cin, cout, stride, Padding::Same)
            });
            ops.push(Op::ResidualClose { shortcut });
            ops.push(Op::Relu);
            cin = cout;
        }
    }
    ops.push(Op::GlobalAvgPool);
    head(&mut ops, &[("fc", cin, 1000)]);
    NetGraph { id, display, input: (224, 224, 3), ops }
}

/// VGG-16 at 224x224 (S8 comparison rows): conv groups separated by
/// 2x2 max pools, three-layer dense head.
fn vgg16() -> NetGraph {
    // (cout per conv) per group; cin chains within the plain stack
    let groups: &[&[usize]] = &[
        &[64, 64],
        &[128, 128],
        &[256, 256, 256],
        &[512, 512, 512],
        &[512, 512, 512],
    ];
    let mut ops = Vec::new();
    let mut cin = 3usize;
    let mut i = 0usize;
    for g in groups {
        for &cout in *g {
            i += 1;
            ops.push(Op::ConvBn(conv(&format!("conv{i}"), 3, cin, cout, 1,
                                     Padding::Same)));
            ops.push(Op::Relu);
            cin = cout;
        }
        ops.push(Op::MaxPool { window: 2, stride: 2 });
    }
    ops.push(Op::Flatten);
    head(&mut ops, &[("fc6", 512 * 7 * 7, 4096), ("fc7", 4096, 4096),
                     ("fc8", 4096, 1000)]);
    NetGraph { id: "vgg16", display: "VGG-16", input: (224, 224, 3), ops }
}

/// AlexNet (S8 comparison rows).  conv2/4/5 use the original 2-way
/// grouped convolutions, modelled as halved cin — which is why conv
/// specs carry explicit channel geometry instead of chaining it.
fn alexnet() -> NetGraph {
    let mut ops = vec![
        Op::ConvBn(ConvBnSpec {
            name: "conv1".into(), kh: 11, kw: 11, cin: 3, cout: 96,
            stride: 4, padding: Padding::Valid, // -> 55x55
        }),
        Op::Relu,
        Op::MaxPool { window: 3, stride: 2 }, // -> 27x27
        Op::ConvBn(conv("conv2", 5, 48, 256, 1, Padding::Same)),
        Op::Relu,
        Op::MaxPool { window: 3, stride: 2 }, // -> 13x13
        Op::ConvBn(conv("conv3", 3, 256, 384, 1, Padding::Same)),
        Op::Relu,
        Op::ConvBn(conv("conv4", 3, 192, 384, 1, Padding::Same)),
        Op::Relu,
        Op::ConvBn(conv("conv5", 3, 192, 256, 1, Padding::Same)),
        Op::Relu,
        Op::Flatten,
    ];
    head(&mut ops, &[("fc6", 256 * 6 * 6, 4096), ("fc7", 4096, 4096),
                     ("fc8", 4096, 1000)]);
    NetGraph { id: "alexnet", display: "AlexNet", input: (227, 227, 3), ops }
}

// ---------------------------------------------------------------------------
// Registry
// ---------------------------------------------------------------------------

/// Every compiled graph, runtime-servable and descriptor-only alike.
pub fn all() -> &'static [NetGraph] {
    static REGISTRY: OnceLock<Vec<NetGraph>> = OnceLock::new();
    REGISTRY.get_or_init(|| {
        vec![
            lenet5(),
            cnv6(),
            residual("resnet8", "ResNet-8", 1),
            residual("resnet20", "ResNet-20", 3),
            residual("resnet32", "ResNet-32", 5),
            resnet_imagenet("resnet18", "ResNet-18", &[2, 2, 2, 2], false),
            resnet_imagenet("resnet50", "ResNet-50", &[3, 4, 6, 3], true),
            vgg16(),
            alexnet(),
        ]
    })
}

/// Look up a compiled graph by registry id.
pub fn by_name(name: &str) -> Option<&'static NetGraph> {
    all().iter().find(|g| g.id == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_ids_unique_and_resolvable() {
        let ids: Vec<&str> = all().iter().map(|g| g.id).collect();
        for id in &ids {
            assert!(by_name(id).is_some(), "{id}");
        }
        let mut dedup = ids.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), ids.len(), "duplicate registry ids");
    }

    #[test]
    fn every_arch_is_registered_and_parses() {
        for a in Arch::ALL {
            assert_eq!(Arch::parse(a.name()), Some(a));
            assert_eq!(a.graph().id, a.name());
            assert_eq!(a.graph().input, (32, 32, 1));
        }
        assert_eq!(Arch::parse("nope"), None);
        assert!(Arch::names_label().contains("cnv6"));
    }

    #[test]
    fn residual_brackets_balance() {
        for g in all() {
            let mut depth = 0i32;
            for op in &g.ops {
                match op {
                    Op::ResidualOpen => depth += 1,
                    Op::ResidualClose { .. } => {
                        depth -= 1;
                        assert!(depth >= 0, "{}: close before open", g.id);
                    }
                    _ => {}
                }
            }
            assert_eq!(depth, 0, "{}: unbalanced residual brackets", g.id);
        }
    }

    #[test]
    fn conv_channels_chain_through_the_program() {
        // Walking the program, every conv's cin must equal the live
        // channel count (AlexNet is exempt: grouped convs halve cin).
        for g in all().iter().filter(|g| g.id != "alexnet") {
            let mut ch = g.input.2;
            let mut saved = Vec::new();
            for op in &g.ops {
                match op {
                    Op::ConvBn(c) => {
                        assert_eq!(c.cin, ch, "{}: {}", g.id, c.name);
                        ch = c.cout;
                    }
                    Op::ResidualOpen => saved.push(ch),
                    Op::ResidualClose { shortcut } => {
                        let at_open = saved.pop().unwrap();
                        if let Some(c) = shortcut {
                            assert_eq!(c.cin, at_open, "{}: {}", g.id, c.name);
                            assert_eq!(c.cout, ch, "{}: {}", g.id, c.name);
                        } else {
                            assert_eq!(at_open, ch, "{}: identity shortcut \
                                                     with channel change", g.id);
                        }
                    }
                    _ => {}
                }
            }
        }
    }

    #[test]
    fn resnet20_graph_matches_paper_shape() {
        let g = Arch::Resnet20.graph();
        // stem + 9 blocks x 2 convs + 2 projection shortcuts
        assert_eq!(g.conv_specs().len(), 1 + 9 * 2 + 2);
        assert_eq!(g.dense_specs().len(), 1);
        let names: Vec<&str> =
            g.conv_specs().iter().map(|c| c.name.as_str()).collect();
        assert!(names.contains(&"s1b0/sc"));
        assert!(names.contains(&"s2b2/c2"));
        assert!(!names.contains(&"s0b0/sc"), "s0 keeps identity shortcuts");
    }

    #[test]
    fn desc_geometry_matches_graph_walk() {
        let d = Arch::Lenet5.graph().to_desc();
        let convs: Vec<_> = d.conv_layers().collect();
        assert_eq!(convs.len(), 2);
        assert_eq!((convs[0].h_in, convs[0].cin, convs[0].cout), (32, 1, 6));
        assert_eq!((convs[1].h_in, convs[1].cin, convs[1].cout), (14, 6, 16));
        let d32 = Arch::Resnet32.graph().to_desc();
        // 1 stem + 15 blocks x 2 + 2 shortcuts
        assert_eq!(d32.conv_layers().count(), 1 + 15 * 2 + 2);
        let dc = Arch::Cnv6.graph().to_desc();
        assert_eq!(dc.conv_layers().count(), 6);
        // spatial chain 32 -> 16 -> 8 survives into the descriptor
        let hs: Vec<usize> = dc.conv_layers().map(|c| c.h_in).collect();
        assert_eq!(hs, vec![32, 32, 16, 16, 8, 8]);
    }

    #[test]
    fn imagenet_stem_pool_uses_valid_geometry() {
        // ResNet-18 stem: 224 -(7/2 Same)-> 112 -(MaxPool 3/2)-> 55.
        // The floor formula would claim 56; a valid 3-wide window at
        // stride 2 only fits 55 times.
        let d = by_name("resnet18").unwrap().to_desc();
        let first_block = d.conv_layers()
            .find(|c| c.name == "s0b0/c1")
            .expect("resnet18 has s0b0/c1");
        assert_eq!((first_block.h_in, first_block.w_in), (55, 55));
        // Pool rows carry graph-canonical names for LayerRun joins.
        assert!(d.layers.iter().any(|l| l.name() == "pool1"));
        assert!(d.layers.iter().any(|l| l.name() == "gap"));
    }
}
