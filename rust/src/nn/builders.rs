//! Builders for the paper's workload networks.

use super::{ConvLayer, Layer, NetworkDesc, Padding};

fn conv(name: &str, kh: usize, cin: usize, cout: usize, h: usize, w: usize,
        stride: usize, padding: Padding) -> Layer {
    Layer::Conv(ConvLayer {
        name: name.into(), kh, kw: kh, cin, cout, h_in: h, w_in: w, stride, padding,
    })
}

/// LeNet-5 on 32x32x1 — the fully-on-chip workload of Fig. 5 (and the
/// architecture the Rust trainer + functional simulator execute).
pub fn lenet5() -> NetworkDesc {
    NetworkDesc {
        name: "LeNet-5".into(),
        input: (32, 32, 1),
        layers: vec![
            conv("conv1", 5, 1, 6, 32, 32, 1, Padding::Valid), // -> 28x28x6
            Layer::Pool { name: "pool1".into(), window: 2, stride: 2, h_in: 28, w_in: 28, ch: 6 },
            conv("conv2", 5, 6, 16, 14, 14, 1, Padding::Valid), // -> 10x10x16
            Layer::Pool { name: "pool2".into(), window: 2, stride: 2, h_in: 10, w_in: 10, ch: 16 },
            Layer::Dense { name: "fc1".into(), din: 400, dout: 120 },
            Layer::Dense { name: "fc2".into(), din: 120, dout: 84 },
            Layer::Dense { name: "fc3".into(), din: 84, dout: 10 },
        ],
    }
}

/// CIFAR-style ResNet-20 (the paper's Fig. 2/7 quantization workload).
pub fn resnet20() -> NetworkDesc {
    let mut layers = vec![conv("stem", 3, 3, 16, 32, 32, 1, Padding::Same)];
    let mut cin = 16;
    let mut hw = 32;
    for (s, cout) in [16usize, 32, 64].into_iter().enumerate() {
        for b in 0..3 {
            let stride = if s > 0 && b == 0 { 2 } else { 1 };
            let h_in = hw;
            if stride == 2 {
                hw /= 2;
            }
            layers.push(conv(&format!("s{s}b{b}c1"), 3, cin, cout, h_in, h_in, stride, Padding::Same));
            layers.push(conv(&format!("s{s}b{b}c2"), 3, cout, cout, hw, hw, 1, Padding::Same));
            if cin != cout {
                layers.push(conv(&format!("s{s}b{b}sc"), 1, cin, cout, h_in, h_in, stride, Padding::Same));
            }
            cin = cout;
        }
    }
    layers.push(Layer::GlobalPool { ch: 64, h_in: 8, w_in: 8 });
    layers.push(Layer::Dense { name: "fc".into(), din: 64, dout: 10 });
    NetworkDesc { name: "ResNet-20".into(), input: (32, 32, 3), layers }
}

fn resnet_imagenet(name: &str, blocks: &[usize], bottleneck: bool) -> NetworkDesc {
    let mut layers = vec![conv("stem", 7, 3, 64, 224, 224, 2, Padding::Same)];
    layers.push(Layer::Pool { name: "maxpool".into(), window: 3, stride: 2, h_in: 112, w_in: 112, ch: 64 });
    let mut hw = 56usize;
    let widths = [64usize, 128, 256, 512];
    let expansion = if bottleneck { 4 } else { 1 };
    let mut cin = 64;
    for (s, &n) in blocks.iter().enumerate() {
        let width = widths[s];
        for b in 0..n {
            let stride = if s > 0 && b == 0 { 2 } else { 1 };
            let h_in = hw;
            if stride == 2 {
                hw /= 2;
            }
            let pre = format!("s{s}b{b}");
            if bottleneck {
                layers.push(conv(&format!("{pre}c1"), 1, cin, width, h_in, h_in, 1, Padding::Same));
                layers.push(conv(&format!("{pre}c2"), 3, width, width, h_in, h_in, stride, Padding::Same));
                layers.push(conv(&format!("{pre}c3"), 1, width, width * 4, hw, hw, 1, Padding::Same));
            } else {
                layers.push(conv(&format!("{pre}c1"), 3, cin, width, h_in, h_in, stride, Padding::Same));
                layers.push(conv(&format!("{pre}c2"), 3, width, width, hw, hw, 1, Padding::Same));
            }
            let cout = width * expansion;
            if cin != cout {
                layers.push(conv(&format!("{pre}sc"), 1, cin, cout, h_in, h_in, stride, Padding::Same));
            }
            cin = cout;
        }
    }
    layers.push(Layer::GlobalPool { ch: cin, h_in: 7, w_in: 7 });
    layers.push(Layer::Dense { name: "fc".into(), din: cin, dout: 1000 });
    NetworkDesc { name: name.into(), input: (224, 224, 3), layers }
}

/// ImageNet ResNet-18 — the on-board workload of §4 / S8 "this work" row.
pub fn resnet18() -> NetworkDesc {
    resnet_imagenet("ResNet-18", &[2, 2, 2, 2], false)
}

/// ImageNet ResNet-50 — the S6 quantization workload.
pub fn resnet50() -> NetworkDesc {
    resnet_imagenet("ResNet-50", &[3, 4, 6, 3], true)
}

/// VGG-16 at 224x224 (S8 comparison rows [11], [42], [36]).
pub fn vgg16() -> NetworkDesc {
    let cfg: &[(usize, usize, usize)] = &[
        // (cin, cout, h_in) per conv; pools between groups
        (3, 64, 224), (64, 64, 224),
        (64, 128, 112), (128, 128, 112),
        (128, 256, 56), (256, 256, 56), (256, 256, 56),
        (256, 512, 28), (512, 512, 28), (512, 512, 28),
        (512, 512, 14), (512, 512, 14), (512, 512, 14),
    ];
    let mut layers = Vec::new();
    for (i, &(cin, cout, h)) in cfg.iter().enumerate() {
        layers.push(conv(&format!("conv{}", i + 1), 3, cin, cout, h, h, 1, Padding::Same));
    }
    layers.push(Layer::Dense { name: "fc6".into(), din: 512 * 7 * 7, dout: 4096 });
    layers.push(Layer::Dense { name: "fc7".into(), din: 4096, dout: 4096 });
    layers.push(Layer::Dense { name: "fc8".into(), din: 4096, dout: 1000 });
    NetworkDesc { name: "VGG-16".into(), input: (224, 224, 3), layers }
}

/// AlexNet (S8 comparison rows [28], [26], [2]).  conv2/4/5 use the
/// original 2-way grouped convolutions (modelled as halved cin).
pub fn alexnet() -> NetworkDesc {
    NetworkDesc {
        name: "AlexNet".into(),
        input: (227, 227, 3),
        layers: vec![
            Layer::Conv(ConvLayer { name: "conv1".into(), kh: 11, kw: 11, cin: 3, cout: 96,
                h_in: 227, w_in: 227, stride: 4, padding: Padding::Valid }), // -> 55x55
            Layer::Pool { name: "pool1".into(), window: 3, stride: 2, h_in: 55, w_in: 55, ch: 96 },
            Layer::Conv(ConvLayer { name: "conv2".into(), kh: 5, kw: 5, cin: 48, cout: 256,
                h_in: 27, w_in: 27, stride: 1, padding: Padding::Same }),
            Layer::Pool { name: "pool2".into(), window: 3, stride: 2, h_in: 27, w_in: 27, ch: 256 },
            conv("conv3", 3, 256, 384, 13, 13, 1, Padding::Same),
            conv("conv4", 3, 192, 384, 13, 13, 1, Padding::Same),
            conv("conv5", 3, 192, 256, 13, 13, 1, Padding::Same),
            Layer::Dense { name: "fc6".into(), din: 256 * 6 * 6, dout: 4096 },
            Layer::Dense { name: "fc7".into(), din: 4096, dout: 4096 },
            Layer::Dense { name: "fc8".into(), din: 4096, dout: 1000 },
        ],
    }
}

/// Small synthetic-10 ResNet-8 (the CI-scale model the trainer runs).
pub fn resnet8() -> NetworkDesc {
    let mut layers = vec![conv("stem", 3, 1, 16, 32, 32, 1, Padding::Same)];
    let mut cin = 16;
    let mut hw = 32;
    for (s, cout) in [16usize, 32, 64].into_iter().enumerate() {
        let stride = if s > 0 { 2 } else { 1 };
        let h_in = hw;
        if stride == 2 {
            hw /= 2;
        }
        layers.push(conv(&format!("s{s}b0c1"), 3, cin, cout, h_in, h_in, stride, Padding::Same));
        layers.push(conv(&format!("s{s}b0c2"), 3, cout, cout, hw, hw, 1, Padding::Same));
        if cin != cout {
            layers.push(conv(&format!("s{s}b0sc"), 1, cin, cout, h_in, h_in, stride, Padding::Same));
        }
        cin = cout;
    }
    layers.push(Layer::GlobalPool { ch: 64, h_in: 8, w_in: 8 });
    layers.push(Layer::Dense { name: "fc".into(), din: 64, dout: 10 });
    NetworkDesc { name: "ResNet-8".into(), input: (32, 32, 1), layers }
}

/// Look up a network by CLI name.
pub fn by_name(name: &str) -> Option<NetworkDesc> {
    match name {
        "lenet5" => Some(lenet5()),
        "resnet8" => Some(resnet8()),
        "resnet18" => Some(resnet18()),
        "resnet20" => Some(resnet20()),
        "resnet50" => Some(resnet50()),
        "vgg16" => Some(vgg16()),
        "alexnet" => Some(alexnet()),
        _ => None,
    }
}
