//! Builders for the paper's workload networks — thin derivations from
//! the compiled layer graphs in [`super::graph`], which is the single
//! place each topology is encoded.  Deriving the hardware descriptors
//! from the same programs the executors walk keeps report naming and
//! runtime naming identical (`s0b0/c1` everywhere).

use super::{graph, NetworkDesc};

fn desc(id: &str) -> NetworkDesc {
    graph::by_name(id)
        .unwrap_or_else(|| panic!("graph {id} is not registered"))
        .to_desc()
}

/// LeNet-5 on 32x32x1 — the fully-on-chip workload of Fig. 5 (and the
/// architecture the Rust trainer + functional simulator execute).
pub fn lenet5() -> NetworkDesc {
    desc("lenet5")
}

/// VGG-style plain 6-conv stack on 32x32x1 (runtime-servable).
pub fn cnv6() -> NetworkDesc {
    desc("cnv6")
}

/// Small synthetic-10 ResNet-8 (the CI-scale model the trainer runs).
pub fn resnet8() -> NetworkDesc {
    desc("resnet8")
}

/// CIFAR-style ResNet-20 (the paper's Fig. 2/7 quantization workload),
/// on the runtime's 32x32x1 synthetic-10 input.
pub fn resnet20() -> NetworkDesc {
    desc("resnet20")
}

/// Deeper CIFAR-style ResNet-32 (5 basic blocks per stage).
pub fn resnet32() -> NetworkDesc {
    desc("resnet32")
}

/// ImageNet ResNet-18 — the on-board workload of §4 / S8 "this work" row.
pub fn resnet18() -> NetworkDesc {
    desc("resnet18")
}

/// ImageNet ResNet-50 — the S6 quantization workload.
pub fn resnet50() -> NetworkDesc {
    desc("resnet50")
}

/// VGG-16 at 224x224 (S8 comparison rows [11], [42], [36]).
pub fn vgg16() -> NetworkDesc {
    desc("vgg16")
}

/// AlexNet (S8 comparison rows [28], [26], [2]).  conv2/4/5 use the
/// original 2-way grouped convolutions (modelled as halved cin).
pub fn alexnet() -> NetworkDesc {
    desc("alexnet")
}

/// Look up a network by CLI name (any registered graph).
pub fn by_name(name: &str) -> Option<NetworkDesc> {
    graph::by_name(name).map(|g| g.to_desc())
}
