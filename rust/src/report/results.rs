//! Tiny persistent results store (`artifacts/results.json`): measured
//! numbers flow from `repro train` / examples into the report tables.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::Result;

use crate::util::json::Json;

/// Flat key -> number store.
#[derive(Debug, Clone, Default)]
pub struct Results {
    pub values: BTreeMap<String, f64>,
}

impl Results {
    pub fn load<P: AsRef<Path>>(dir: P) -> Results {
        let path = dir.as_ref().join("results.json");
        let mut out = Results::default();
        if let Ok(text) = std::fs::read_to_string(&path) {
            if let Ok(Json::Obj(m)) = Json::parse(&text) {
                for (k, v) in m {
                    if let Some(n) = v.as_f64() {
                        out.values.insert(k, n);
                    }
                }
            }
        }
        out
    }

    pub fn save<P: AsRef<Path>>(&self, dir: P) -> Result<()> {
        let mut s = String::from("{\n");
        for (i, (k, v)) in self.values.iter().enumerate() {
            s.push_str(&format!(" \"{}\": {}{}\n", k, v,
                                if i + 1 == self.values.len() { "" } else { "," }));
        }
        s.push('}');
        std::fs::write(dir.as_ref().join("results.json"), s)?;
        Ok(())
    }

    pub fn set(&mut self, key: &str, v: f64) {
        self.values.insert(key.to_string(), v);
    }

    pub fn get(&self, key: &str) -> Option<f64> {
        self.values.get(key).copied()
    }

    /// Format a stored accuracy as "93.1%" or "-" if absent.
    pub fn fmt_acc(&self, key: &str) -> String {
        match self.get(key) {
            Some(v) => format!("{:.1}%", v * 100.0),
            None => "-".into(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join(format!("addernet_res_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut r = Results::default();
        r.set("acc/lenet5_adder", 0.93);
        r.set("loss/final", 0.21);
        r.save(&dir).unwrap();
        let r2 = Results::load(&dir);
        assert_eq!(r2.get("acc/lenet5_adder"), Some(0.93));
        assert_eq!(r2.fmt_acc("acc/lenet5_adder"), "93.0%");
        assert_eq!(r2.fmt_acc("missing"), "-");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn load_missing_is_empty() {
        let r = Results::load("/nonexistent_dir_xyz");
        assert!(r.values.is_empty());
    }
}
