//! FPGA-level reports: Eq. (2)/(3), Fig. 4, Fig. 5, §4 on-board, S8.

use crate::hw::array::PeArray;
use crate::hw::kernelcircuit::KernelKind;
use crate::nn;
use crate::sim::accelerator::{self, AccelConfig};
use crate::sim::onchip;
use crate::util::table::{f, pct, thousands, Table};

/// Eq. (2)/(3): theoretical resource model + headline saving.
pub fn eq23() -> Table {
    let mut t = Table::new(
        "Eq. 2/3 — theoretical logic consumption per output lane (paper: 81.6% off at DW=16, Pin=64)",
        &["Pin", "DW", "AdderNet eq2", "CNN eq3", "saving", "precise-model saving"],
    );
    for pin in [16u64, 32, 64, 128] {
        for dw in [8u32, 16] {
            let a = PeArray::eq2_addernet(pin, 1, dw);
            let c = PeArray::eq3_cnn(pin, 1, dw);
            let adder = PeArray::new(pin, 1, dw, KernelKind::Adder2A);
            let cnn = PeArray::new(pin, 1, dw, KernelKind::Mult);
            let precise = 1.0 - adder.luts() as f64 / cnn.luts() as f64;
            t.row(&[
                pin.to_string(),
                dw.to_string(),
                thousands(a),
                thousands(c),
                pct(1.0 - a as f64 / c as f64),
                pct(precise),
            ]);
        }
    }
    t
}

/// Fig. 4(c1/c2 or d1/d2): component breakdown vs parallelism.
pub fn fig4_components(dw: u32, kernel: KernelKind) -> Table {
    let mut t = Table::new(
        &format!("Fig. 4 components — {}bit {} accelerator LUTs vs parallelism",
                 dw, kernel.label()),
        &["P", "conv kernel", "adder tree", "storage", "control", "others",
          "total", "compute share"],
    );
    for p in [128u64, 256, 512, 1024, 2048, 4096] {
        let r = accelerator::resources(&AccelConfig::zcu104(p, dw, kernel));
        t.row(&[
            p.to_string(),
            thousands(r.conv_kernel_luts),
            thousands(r.adder_tree_luts),
            thousands(r.storage_luts),
            thousands(r.control_luts),
            thousands(r.other_luts),
            thousands(r.total()),
            pct(r.compute_share()),
        ]);
    }
    t
}

/// Fig. 4(c3/d3): AdderNet-vs-CNN savings vs parallelism.
pub fn fig4_savings(dw: u32) -> Table {
    let paper = if dw == 16 {
        "paper @2048: conv 80%-off, total 67.6%-off"
    } else {
        "paper: conv ~70%-off, total ~58%-off"
    };
    let mut t = Table::new(
        &format!("Fig. 4 savings — {dw}bit AdderNet vs CNN ({paper})"),
        &["P", "conv-part saving", "total saving"],
    );
    for p in [128u64, 256, 512, 1024, 2048, 4096] {
        let a = accelerator::resources(&AccelConfig::zcu104(p, dw, KernelKind::Adder2A));
        let c = accelerator::resources(&AccelConfig::zcu104(p, dw, KernelKind::Mult));
        t.row(&[
            p.to_string(),
            pct(1.0 - a.compute_luts() as f64 / c.compute_luts() as f64),
            pct(1.0 - a.total() as f64 / c.total() as f64),
        ]);
    }
    t
}

/// Fig. 5(b/c): on-chip LeNet-5 per-layer savings.
pub fn fig5() -> Vec<Table> {
    let mut out = Vec::new();
    for dw in [16u32, 8] {
        let s = onchip::savings(dw);
        let paper: (&str, &str, &str, &str, &str, &str) = if dw == 16 {
            ("70.3%", "80.32%", "71.4%", "70.22%", "88.29%", "77.91%")
        } else {
            ("46.76%", "66.86%", "61.63%", "48.33%", "72.96%", "56.57%")
        };
        let mut t = Table::new(
            &format!("Fig. 5 — on-chip LeNet-5, {dw}bit: AdderNet savings vs CNN"),
            &["metric", "conv1", "conv2", "total", "paper conv1", "paper conv2", "paper total"],
        );
        t.row(&["LUTs".into(), pct(s.conv1_luts), pct(s.conv2_luts), pct(s.total_luts),
                paper.0.into(), paper.1.into(), paper.2.into()]);
        t.row(&["energy".into(), pct(s.conv1_energy), pct(s.conv2_energy), pct(s.total_energy),
                paper.3.into(), paper.4.into(), paper.5.into()]);
        // absolute resources for context
        let a = onchip::design(KernelKind::Adder2A, dw);
        let c = onchip::design(KernelKind::Mult, dw);
        t.row(&["LUTs abs (A/C)".into(),
                format!("{}/{}", a.layers[0].luts, c.layers[0].luts),
                format!("{}/{}", a.layers[1].luts, c.layers[1].luts),
                format!("{}/{}", a.total_luts(), c.total_luts()),
                "-".into(), "-".into(), "-".into()]);
        out.push(t);
    }
    out
}

/// §4 on-board run: ResNet-18 at P=1024 on ZCU104, both kernels.
pub fn onboard() -> Table {
    let net = nn::resnet18();
    let mut t = Table::new(
        "On-board ResNet-18 (ZCU104, P=1024, 16bit) — measured model vs paper",
        &["metric", "CNN (model)", "AdderNet (model)", "CNN (paper)", "AdderNet (paper)"],
    );
    let c = accelerator::run(&AccelConfig::zcu104(1024, 16, KernelKind::Mult), &net);
    let a = accelerator::run(&AccelConfig::zcu104(1024, 16, KernelKind::Adder2A), &net);
    t.row(&["fmax (MHz)".into(), f(c.fmax_mhz, 0), f(a.fmax_mhz, 0),
            "214".into(), "250".into()]);
    t.row(&["conv GOPs".into(), f(c.conv_gops(), 0), f(a.conv_gops(), 0),
            "424".into(), "495".into()]);
    t.row(&["whole-net GOPs".into(), f(c.total_gops(), 0), f(a.total_gops(), 0),
            "307".into(), "358.6".into()]);
    t.row(&["latency/img (ms)".into(), f(c.latency_ms(), 2), f(a.latency_ms(), 2),
            "-".into(), "9.47".into()]);
    t.row(&["intrinsic power (W)".into(), f(c.power.total_w(), 2), f(a.power.total_w(), 2),
            "2.57".into(), "1.34".into()]);
    let saving = 1.0 - a.power.total_w() / c.power.total_w();
    t.row(&["power saving".into(), "-".into(), pct(saving), "-".into(), "47.85%".into()]);
    t.row(&["speed-up".into(), "1.0x".into(),
            format!("{:.2}x", a.total_gops() / c.total_gops()),
            "1.0x".into(), "1.16x".into()]);
    t
}

/// S8 (Fig. 13): FPGA accelerator comparison — cited rows + our row.
pub fn s8() -> Table {
    let mut t = Table::new(
        "S8 / Fig. 13 — FPGA NN accelerator comparison (cited rows + this repro)",
        &["design", "model", "platform", "clock MHz", "GOP", "params M",
          "precision", "latency ms", "GOPS"],
    );
    let cited: &[[&str; 9]] = &[
        ["[28]", "AlexNet", "Virtex-7 VC707", "160", "1.33", "2.33", "fix32", "-", "147.82"],
        ["[26]", "AlexNet", "Virtex-7 VC709", "156", "1.46", "60.95", "fix16", "2.56", "565.94"],
        ["[2]", "AlexNet", "Arria10 GX1150", "303", "1.46", "60.95", "fp16", "-", "1380 (FLOPS)"],
        ["[11]", "VGG-16", "Zynq XC7Z045", "150", "30.76", "50.18", "fix16", "224.6", "136.97"],
        ["[42]", "VGG-16", "Virtex-7 VX690t", "150", "30.95", "138.3", "fix16", "151.8", "203.9"],
        ["[36]", "VGG-16", "Arria10 GT1150", "231.85", "30.95", "138.3", "fix8-16", "26.85", "1171.3"],
        ["[10]", "ResNet-152", "Stratix-V GSMD5", "150", "22.62", "60.4", "fix16", "-", "226.47"],
    ];
    for row in cited {
        t.row(&row.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    }
    // our measured row from the simulator
    let net = nn::resnet18();
    let a = accelerator::run(&AccelConfig::zcu104(1024, 16, KernelKind::Adder2A), &net);
    t.row(&[
        "this repro (AdderNet)".into(),
        "ResNet-18".into(),
        "ZCU104 (model)".into(),
        f(a.fmax_mhz, 0),
        f(net.gops(), 2),
        f(net.params() as f64 / 1e6, 1),
        "fix16".into(),
        f(a.latency_ms(), 2),
        f(a.total_gops(), 1),
    ]);
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_tables_render() {
        assert!(eq23().render().contains("81"));
        assert!(fig4_components(16, KernelKind::Mult).rows_len() == 6);
        assert!(fig4_savings(16).render().contains("%"));
        assert_eq!(fig5().len(), 2);
        let ob = onboard().render();
        assert!(ob.contains("fmax"));
        assert!(s8().render().contains("this repro"));
    }

    #[test]
    fn eq23_headline_in_table() {
        let s = eq23().render();
        // the DW=16 Pin=64 row must show ~81.x% saving
        assert!(s.contains("81."), "{s}");
    }
}
