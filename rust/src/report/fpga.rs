//! FPGA-level reports: Eq. (2)/(3), Fig. 4, Fig. 5, §4 on-board, S8,
//! and the plan-backed hardware table behind `repro report fpga`.

use anyhow::Result;

use crate::hw::array::PeArray;
use crate::hw::kernelcircuit::KernelKind;
use crate::nn;
use crate::quant::{plan::QuantPlan, Mode};
use crate::sim::accelerator::{self, AccelConfig, ResourceBreakdown, RunReport};
use crate::sim::functional::{synth_params, Arch, QuantCfg};
use crate::sim::hwsim::{self, HwCost};
use crate::sim::kernels::SimKernel;
use crate::sim::onchip;
use crate::util::table::{f, pct, thousands, Table};

use super::quantrep;

/// Eq. (2)/(3): theoretical resource model + headline saving.
pub fn eq23() -> Table {
    let mut t = Table::new(
        "Eq. 2/3 — theoretical logic consumption per output lane (paper: 81.6% off at DW=16, Pin=64)",
        &["Pin", "DW", "AdderNet eq2", "CNN eq3", "saving", "precise-model saving"],
    );
    for pin in [16u64, 32, 64, 128] {
        for dw in [8u32, 16] {
            let a = PeArray::eq2_addernet(pin, 1, dw);
            let c = PeArray::eq3_cnn(pin, 1, dw);
            let adder = PeArray::new(pin, 1, dw, KernelKind::Adder2A);
            let cnn = PeArray::new(pin, 1, dw, KernelKind::Mult);
            let precise = 1.0 - adder.luts() as f64 / cnn.luts() as f64;
            t.row(&[
                pin.to_string(),
                dw.to_string(),
                thousands(a),
                thousands(c),
                pct(1.0 - a as f64 / c as f64),
                pct(precise),
            ]);
        }
    }
    t
}

/// Fig. 4(c1/c2 or d1/d2): component breakdown vs parallelism.
pub fn fig4_components(dw: u32, kernel: KernelKind) -> Table {
    let mut t = Table::new(
        &format!("Fig. 4 components — {}bit {} accelerator LUTs vs parallelism",
                 dw, kernel.label()),
        &["P", "conv kernel", "adder tree", "storage", "control", "others",
          "total", "compute share"],
    );
    for p in [128u64, 256, 512, 1024, 2048, 4096] {
        let r = accelerator::resources(&AccelConfig::zcu104(p, dw, kernel));
        t.row(&[
            p.to_string(),
            thousands(r.conv_kernel_luts),
            thousands(r.adder_tree_luts),
            thousands(r.storage_luts),
            thousands(r.control_luts),
            thousands(r.other_luts),
            thousands(r.total()),
            pct(r.compute_share()),
        ]);
    }
    t
}

/// Fig. 4(c3/d3): AdderNet-vs-CNN savings vs parallelism.
pub fn fig4_savings(dw: u32) -> Table {
    let paper = if dw == 16 {
        "paper @2048: conv 80%-off, total 67.6%-off"
    } else {
        "paper: conv ~70%-off, total ~58%-off"
    };
    let mut t = Table::new(
        &format!("Fig. 4 savings — {dw}bit AdderNet vs CNN ({paper})"),
        &["P", "conv-part saving", "total saving"],
    );
    for p in [128u64, 256, 512, 1024, 2048, 4096] {
        let a = accelerator::resources(&AccelConfig::zcu104(p, dw, KernelKind::Adder2A));
        let c = accelerator::resources(&AccelConfig::zcu104(p, dw, KernelKind::Mult));
        t.row(&[
            p.to_string(),
            pct(1.0 - a.compute_luts() as f64 / c.compute_luts() as f64),
            pct(1.0 - a.total() as f64 / c.total() as f64),
        ]);
    }
    t
}

/// Fig. 5(b/c): on-chip LeNet-5 per-layer savings.
pub fn fig5() -> Vec<Table> {
    let mut out = Vec::new();
    for dw in [16u32, 8] {
        let s = onchip::savings(dw);
        let paper: (&str, &str, &str, &str, &str, &str) = if dw == 16 {
            ("70.3%", "80.32%", "71.4%", "70.22%", "88.29%", "77.91%")
        } else {
            ("46.76%", "66.86%", "61.63%", "48.33%", "72.96%", "56.57%")
        };
        let mut t = Table::new(
            &format!("Fig. 5 — on-chip LeNet-5, {dw}bit: AdderNet savings vs CNN"),
            &["metric", "conv1", "conv2", "total", "paper conv1", "paper conv2", "paper total"],
        );
        t.row(&["LUTs".into(), pct(s.conv1_luts), pct(s.conv2_luts), pct(s.total_luts),
                paper.0.into(), paper.1.into(), paper.2.into()]);
        t.row(&["energy".into(), pct(s.conv1_energy), pct(s.conv2_energy), pct(s.total_energy),
                paper.3.into(), paper.4.into(), paper.5.into()]);
        // absolute resources for context
        let a = onchip::design(KernelKind::Adder2A, dw);
        let c = onchip::design(KernelKind::Mult, dw);
        t.row(&["LUTs abs (A/C)".into(),
                format!("{}/{}", a.layers[0].luts, c.layers[0].luts),
                format!("{}/{}", a.layers[1].luts, c.layers[1].luts),
                format!("{}/{}", a.total_luts(), c.total_luts()),
                "-".into(), "-".into(), "-".into()]);
        out.push(t);
    }
    out
}

/// The §4 on-board run pair — (CNN multiplier, AdderNet 2A) ResNet-18
/// at P=1024/16bit on ZCU104.  Shared by the `onboard` table, the
/// `report fpga` JSON artifact and the paper-anchor tests so they can
/// never drift apart.
pub fn onboard_runs() -> (RunReport, RunReport) {
    let net = nn::resnet18();
    let c = accelerator::run(&AccelConfig::zcu104(1024, 16, KernelKind::Mult), &net);
    let a = accelerator::run(&AccelConfig::zcu104(1024, 16, KernelKind::Adder2A), &net);
    (c, a)
}

/// §4 on-board run: ResNet-18 at P=1024 on ZCU104, both kernels.
pub fn onboard() -> Table {
    let mut t = Table::new(
        "On-board ResNet-18 (ZCU104, P=1024, 16bit) — measured model vs paper",
        &["metric", "CNN (model)", "AdderNet (model)", "CNN (paper)", "AdderNet (paper)"],
    );
    let (c, a) = onboard_runs();
    t.row(&["fmax (MHz)".into(), f(c.fmax_mhz, 0), f(a.fmax_mhz, 0),
            "214".into(), "250".into()]);
    t.row(&["conv GOPs".into(), f(c.conv_gops(), 0), f(a.conv_gops(), 0),
            "424".into(), "495".into()]);
    t.row(&["whole-net GOPs".into(), f(c.total_gops(), 0), f(a.total_gops(), 0),
            "307".into(), "358.6".into()]);
    t.row(&["latency/img (ms)".into(), f(c.latency_ms(), 2), f(a.latency_ms(), 2),
            "-".into(), "9.47".into()]);
    t.row(&["intrinsic power (W)".into(), f(c.power.total_w(), 2), f(a.power.total_w(), 2),
            "2.57".into(), "1.34".into()]);
    let saving = 1.0 - a.power.total_w() / c.power.total_w();
    t.row(&["power saving".into(), "-".into(), pct(saving), "-".into(), "47.85%".into()]);
    t.row(&["speed-up".into(), "1.0x".into(),
            format!("{:.2}x", a.total_gops() / c.total_gops()),
            "1.0x".into(), "1.16x".into()]);
    t
}

/// S8 (Fig. 13): FPGA accelerator comparison — cited rows + our row.
pub fn s8() -> Table {
    let mut t = Table::new(
        "S8 / Fig. 13 — FPGA NN accelerator comparison (cited rows + this repro)",
        &["design", "model", "platform", "clock MHz", "GOP", "params M",
          "precision", "latency ms", "GOPS"],
    );
    let cited: &[[&str; 9]] = &[
        ["[28]", "AlexNet", "Virtex-7 VC707", "160", "1.33", "2.33", "fix32", "-", "147.82"],
        ["[26]", "AlexNet", "Virtex-7 VC709", "156", "1.46", "60.95", "fix16", "2.56", "565.94"],
        ["[2]", "AlexNet", "Arria10 GX1150", "303", "1.46", "60.95", "fp16", "-", "1380 (FLOPS)"],
        ["[11]", "VGG-16", "Zynq XC7Z045", "150", "30.76", "50.18", "fix16", "224.6", "136.97"],
        ["[42]", "VGG-16", "Virtex-7 VX690t", "150", "30.95", "138.3", "fix16", "151.8", "203.9"],
        ["[36]", "VGG-16", "Arria10 GT1150", "231.85", "30.95", "138.3", "fix8-16", "26.85", "1171.3"],
        ["[10]", "ResNet-152", "Stratix-V GSMD5", "150", "22.62", "60.4", "fix16", "-", "226.47"],
    ];
    for row in cited {
        t.row(&row.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    }
    // our measured row from the simulator
    let net = nn::resnet18();
    let a = accelerator::run(&AccelConfig::zcu104(1024, 16, KernelKind::Adder2A), &net);
    t.row(&[
        "this repro (AdderNet)".into(),
        "ResNet-18".into(),
        "ZCU104 (model)".into(),
        f(a.fmax_mhz, 0),
        f(net.gops(), 2),
        f(net.params() as f64 / 1e6, 1),
        "fix16".into(),
        f(a.latency_ms(), 2),
        f(a.total_gops(), 1),
    ]);
    t
}

/// One row of the plan-backed hardware table: the cycle-accurate cost
/// of serving a single compiled [`QuantPlan`] on the accelerator.
#[derive(Debug, Clone)]
pub struct PlanHwRow {
    /// `{arch}-{kernel}-int{bits}` — the serving variant id.
    pub name: String,
    pub arch: &'static str,
    pub kernel: &'static str,
    pub bits: u32,
    pub parallelism: u64,
    pub cost: HwCost,
    pub conv_gops: f64,
    pub total_gops: f64,
    pub resources: ResourceBreakdown,
}

/// Cost one compiled plan at `parallelism` lanes (geometry is
/// cross-checked against the arch graph inside [`hwsim::plan_schedule`]).
pub fn plan_hw_row(plan: &QuantPlan, parallelism: u64) -> Result<PlanHwRow> {
    let (cfg, report) = hwsim::plan_schedule(plan, parallelism)?;
    Ok(PlanHwRow {
        name: format!("{}-{}-int{}", plan.arch.name(), plan.kind.label(),
                      plan.cfg.bits),
        arch: plan.arch.name(),
        kernel: plan.kind.label(),
        bits: plan.cfg.bits,
        parallelism: cfg.parallelism(),
        cost: hwsim::cost_of(&report, cfg.parallelism()),
        conv_gops: report.conv_gops(),
        total_gops: report.total_gops(),
        resources: accelerator::resources(&cfg),
    })
}

/// The serving kernel/width matrix `report fpga` sweeps by default:
/// adder int8, adder int16, and the multiplier int8 baseline (the mult
/// path caps at 8 bits), with the quantization modes the accuracy
/// reports use for each kernel.
pub const PLAN_MATRIX: &[(SimKernel, Mode, u32)] = &[
    (SimKernel::Adder, Mode::SharedScale, 8),
    (SimKernel::Adder, Mode::SharedScale, 16),
    (SimKernel::Mult, Mode::SeparateScale, 8),
];

/// Default `report fpga` sweep: every registered arch × [`PLAN_MATRIX`],
/// plans compiled from synthetic weights after a calibration pass —
/// the same recipe the quantization accuracy reports use.
pub fn default_plan_rows(parallelism: u64, n_calib: usize) -> Result<Vec<PlanHwRow>> {
    let mut rows = Vec::new();
    for arch in Arch::ALL {
        let params = synth_params(arch, 42);
        for &(kind, mode, bits) in PLAN_MATRIX {
            if !QuantPlan::supports(kind, bits) {
                continue;
            }
            let (calib, _) = quantrep::calibrate(&params, arch, kind, n_calib);
            let plan = QuantPlan::build(&params, arch, kind,
                                        QuantCfg { bits, mode }, &calib)?;
            rows.push(plan_hw_row(&plan, parallelism)?);
        }
    }
    Ok(rows)
}

/// Render plan rows as the paper-comparison table (per arch × width ×
/// kernel: throughput, latency, power, LUT split — the §4 columns).
pub fn plan_table(rows: &[PlanHwRow]) -> Table {
    let mut t = Table::new(
        "Plan-backed hardware serving — cycle-accurate cost per compiled QuantPlan",
        &["plan", "P", "fmax MHz", "cycles/img", "conv GOPs", "net GOPs",
          "latency ms", "power W", "util", "compute LUTs", "total LUTs"],
    );
    for r in rows {
        t.row(&[
            r.name.clone(),
            r.parallelism.to_string(),
            f(r.cost.fmax_mhz, 0),
            thousands(r.cost.cycles),
            f(r.conv_gops, 1),
            f(r.total_gops, 1),
            f(r.cost.latency_ms, 3),
            f(r.cost.power_w, 2),
            pct(r.cost.utilization),
            thousands(r.resources.compute_luts()),
            thousands(r.resources.total()),
        ]);
    }
    t
}

/// Hand-assembled JSON artifact for `repro report fpga --out`: the plan
/// rows plus the §4 ResNet-18 anchor pair, so CI can diff the hardware
/// model against the paper without re-running the simulator.
pub fn fpga_report_json(rows: &[PlanHwRow], parallelism: u64) -> String {
    let anchor = |r: &RunReport| {
        format!(
            "{{\"fmax_mhz\": {:.3}, \"conv_gops\": {:.3}, \"total_gops\": {:.3}, \
             \"latency_ms\": {:.4}, \"power_w\": {:.4}}}",
            r.fmax_mhz, r.conv_gops(), r.total_gops(), r.latency_ms(),
            r.power.total_w())
    };
    let (c, a) = onboard_runs();
    let mut s = String::new();
    s.push_str("{\n  \"report\": \"fpga\",\n");
    s.push_str(&format!("  \"parallelism\": {parallelism},\n"));
    s.push_str(&format!(
        "  \"anchors_resnet18\": {{\n    \"cnn\": {},\n    \"addernet\": {}\n  }},\n",
        anchor(&c), anchor(&a)));
    s.push_str("  \"plans\": [\n");
    for (i, r) in rows.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"name\": \"{}\", \"arch\": \"{}\", \"kernel\": \"{}\", \
             \"bits\": {}, \"parallelism\": {}, \"cycles\": {}, \
             \"dram_bytes\": {}, \"fmax_mhz\": {:.3}, \"conv_gops\": {:.3}, \
             \"total_gops\": {:.3}, \"latency_ms\": {:.5}, \"power_w\": {:.4}, \
             \"utilization\": {:.4}, \"compute_luts\": {}, \"total_luts\": {}}}{}\n",
            r.name, r.arch, r.kernel, r.bits, r.parallelism, r.cost.cycles,
            r.cost.dram_bytes, r.cost.fmax_mhz, r.conv_gops, r.total_gops,
            r.cost.latency_ms, r.cost.power_w, r.cost.utilization,
            r.resources.compute_luts(), r.resources.total(),
            if i + 1 == rows.len() { "" } else { "," }));
    }
    s.push_str("  ]\n}\n");
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{Calibration, LayerCalib};
    use crate::util::json::Json;

    #[test]
    fn all_tables_render() {
        assert!(eq23().render().contains("81"));
        assert!(fig4_components(16, KernelKind::Mult).rows_len() == 6);
        assert!(fig4_savings(16).render().contains("%"));
        assert_eq!(fig5().len(), 2);
        let ob = onboard().render();
        assert!(ob.contains("fmax"));
        assert!(s8().render().contains("this repro"));
    }

    #[test]
    fn eq23_headline_in_table() {
        let s = eq23().render();
        // the DW=16 Pin=64 row must show ~81.x% saving
        assert!(s.contains("81."), "{s}");
    }

    fn lenet_plan(kind: SimKernel, mode: Mode, bits: u32) -> QuantPlan {
        let params = synth_params(Arch::Lenet5, 3);
        let mut calib = Calibration::new();
        calib.insert("conv1".into(),
                     LayerCalib { feat_max_abs: 1.0, weight_max_abs: 0.5 });
        calib.insert("conv2".into(),
                     LayerCalib { feat_max_abs: 16.0, weight_max_abs: 0.5 });
        QuantPlan::build(&params, Arch::Lenet5, kind,
                         QuantCfg { bits, mode }, &calib)
            .unwrap()
    }

    #[test]
    fn plan_row_matches_direct_accelerator_run() {
        let plan = lenet_plan(SimKernel::Adder, Mode::SharedScale, 8);
        let row = plan_hw_row(&plan, 1024).unwrap();
        assert_eq!(row.name, "lenet5-adder-int8");
        assert_eq!(row.parallelism, 1024);
        // the row must be the same schedule hwsim costs for serving
        let direct = hwsim::per_image_cost(&plan, 1024).unwrap();
        assert_eq!(row.cost.cycles, direct.cycles);
        assert_eq!(row.cost.fmax_mhz, direct.fmax_mhz);
        assert!(row.conv_gops > 0.0 && row.total_gops > 0.0);
        assert!(row.resources.total() > row.resources.compute_luts());
    }

    #[test]
    fn plan_table_and_json_artifact_render() {
        let rows = vec![
            plan_hw_row(&lenet_plan(SimKernel::Adder, Mode::SharedScale, 8),
                        1024).unwrap(),
            plan_hw_row(&lenet_plan(SimKernel::Mult, Mode::SeparateScale, 8),
                        1024).unwrap(),
        ];
        let t = plan_table(&rows).render();
        assert!(t.contains("lenet5-adder-int8"), "{t}");
        assert!(t.contains("lenet5-mult-int8"), "{t}");
        // the artifact must parse with the repo's own JSON reader and
        // carry both the plan rows and the §4 anchor pair
        let s = fpga_report_json(&rows, 1024);
        let j = Json::parse(&s).unwrap();
        assert_eq!(j.at(&["plans"]).unwrap().as_arr().unwrap().len(), 2);
        let addernet = j.at(&["anchors_resnet18", "addernet"]).unwrap();
        assert!(addernet.get("power_w").unwrap().as_f64().unwrap() > 0.0);
        let cnn_gops = j.at(&["anchors_resnet18", "cnn", "total_gops"])
            .unwrap().as_f64().unwrap();
        assert!(cnn_gops > 0.0);
    }

    /// §4 anchors through the report path: the AdderNet run must beat
    /// the CNN on throughput and power, inside the paper's bands.
    #[test]
    fn onboard_runs_hold_paper_anchors() {
        let (c, a) = onboard_runs();
        assert!((a.total_gops() - 358.6).abs() / 358.6 < 0.25,
                "adder net GOPs {}", a.total_gops());
        assert!((c.total_gops() - 307.0).abs() / 307.0 < 0.25,
                "cnn net GOPs {}", c.total_gops());
        assert!((a.power.total_w() - 1.34).abs() < 0.75, "{}", a.power.total_w());
        assert!((c.power.total_w() - 2.57).abs() < 1.00, "{}", c.power.total_w());
        assert!(a.fmax_mhz > c.fmax_mhz);
    }
}
