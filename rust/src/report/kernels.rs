//! Kernel-level reports: Fig. 2(b,c), S1, S4 (Fig. 11), S5 (Fig. 12).

use crate::hw::kernelcircuit::KernelKind;
use crate::util::table::{f, Table};

use super::results::Results;

/// Fig. 2(c): energy per kernel operation for each network kind.
pub fn fig2c() -> Table {
    let mut t = Table::new(
        "Fig. 2c — energy per kernel operation (pJ, ASIC scale)",
        &["kernel", "8bit", "16bit", "32bit", "paper anchor"],
    );
    let rows: Vec<(KernelKind, &str)> = vec![
        (KernelKind::Xnor, "<0.01 (1bit)"),
        (KernelKind::Memristor, "~0.01 excl. DAC/ADC"),
        (KernelKind::Adder1C1A, "0.04 / 0.07 / 0.14"),
        (KernelKind::Adder2A, "0.06 / 0.1 / 0.2"),
        (KernelKind::Shift { weight_bits: 1 }, "0.054 / ~0.105 / 0.23"),
        (KernelKind::Shift { weight_bits: 6 }, "0.324 / 0.63 / 1.38"),
        (KernelKind::Mult, "0.2 / - / 3.1"),
    ];
    for (k, anchor) in rows {
        t.row(&[
            k.label(),
            f(k.lane_energy_pj(8), 3),
            f(k.lane_energy_pj(16), 3),
            f(k.lane_energy_pj(32), 3),
            anchor.into(),
        ]);
    }
    t
}

/// S4 (Fig. 11): detailed energy table, model vs paper cells.
pub fn s4() -> Table {
    let mut t = Table::new(
        "S4 / Fig. 11 — kernel energy (pJ): model vs paper",
        &["data width", "1C1A model", "1C1A paper", "2A model", "2A paper",
          "mult model", "mult paper"],
    );
    let paper: &[(u32, &str, &str, &str)] = &[
        (8, "0.04", "0.06", "0.2"),
        (16, "0.07", "0.1", "-"),
        (32, "0.14", "0.2", "3.1"),
    ];
    for &(dw, p1, p2, pm) in paper {
        t.row(&[
            format!("{dw}bit"),
            f(KernelKind::Adder1C1A.lane_energy_pj(dw), 3), p1.into(),
            f(KernelKind::Adder2A.lane_energy_pj(dw), 3), p2.into(),
            f(KernelKind::Mult.lane_energy_pj(dw), 3), pm.into(),
        ]);
    }
    t
}

/// S5 (Fig. 12): circuit area table, model vs paper cells.
pub fn s5() -> Table {
    let mut t = Table::new(
        "S5 / Fig. 12 — kernel circuit area (units): model vs paper",
        &["data width", "1C1A model", "1C1A paper", "2A model", "2A paper",
          "mult model", "mult paper"],
    );
    let paper: &[(u32, &str, &str, &str)] = &[
        (8, "58", "72", "282"),
        (16, "112", "134", "-"),
        (32, "227", "274", "3495"),
    ];
    for &(dw, p1, p2, pm) in paper {
        t.row(&[
            format!("{dw}bit"),
            f(KernelKind::Adder1C1A.lane_cost(dw).area_units, 0), p1.into(),
            f(KernelKind::Adder2A.lane_cost(dw).area_units, 0), p2.into(),
            f(KernelKind::Mult.lane_cost(dw).area_units, 0), pm.into(),
        ]);
    }
    t
}

/// S1: the 1C1A vs 2A design trade-off (area vs speed).
pub fn s1() -> Table {
    let mut t = Table::new(
        "S1 — adder kernel schemes: 1C1A (smaller) vs 2A (faster; deployed)",
        &["scheme", "dw", "LUTs", "area units", "energy pJ", "delay ns"],
    );
    for dw in [8u32, 16, 32] {
        for k in [KernelKind::Adder1C1A, KernelKind::Adder2A] {
            let c = k.lane_cost(dw);
            t.row(&[
                k.label(),
                dw.to_string(),
                c.luts.to_string(),
                f(c.area_units, 0),
                f(c.energy_pj, 3),
                f(c.delay_ns, 2),
            ]);
        }
    }
    t
}

/// Fig. 2(a/b): recognition accuracy of the trained kernels.  Measured
/// rows come from `repro train` results on synthetic-10; the paper's
/// ImageNet/CIFAR numbers are reproduced as citation columns.
pub fn fig2(results: &Results) -> Table {
    let mut t = Table::new(
        "Fig. 2a/b — kernel accuracy: measured (synthetic-10) vs paper (cited)",
        &["kernel", "LeNet-5 (meas)", "ResNet-8 (meas)",
          "paper ResNet-50 ImageNet top-1", "paper note"],
    );
    let rows = [
        ("adder", "76.8%", "AdderNet == or > CNN"),
        ("mult", "76.13%", "CNN baseline"),
        ("shift", "~75%", "DeepShift ~1% drop (6b)"),
        ("xnor", "51.2%", "XNOR large drop"),
    ];
    for (k, paper, note) in rows {
        t.row(&[
            k.into(),
            results.fmt_acc(&format!("acc/lenet5_{k}")),
            results.fmt_acc(&format!("acc/resnet8_{k}")),
            paper.into(),
            note.into(),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tables_render() {
        for t in [fig2c(), s4(), s5(), s1()] {
            let s = t.render();
            assert!(s.len() > 100);
            assert!(t.rows_len() >= 3);
        }
    }

    #[test]
    fn fig2_uses_results() {
        let mut r = Results::default();
        r.set("acc/lenet5_adder", 0.912);
        let t = fig2(&r);
        assert!(t.render().contains("91.2%"));
    }
}
