//! Paper-style report renderers: one entry point per table/figure
//! (DESIGN.md §4 experiment index).  `repro report <exp>` dispatches here.

#[cfg(feature = "pjrt")]
pub mod evalrt;
pub mod fpga;
pub mod kernels;
pub mod labrep;
pub mod quantrep;
pub mod results;

use std::path::Path;

use anyhow::Result;

pub use results::Results;

/// All experiment ids `repro report` accepts.
pub const EXPERIMENTS: &[&str] = &[
    "fig2", "fig2c", "fig3ab", "fig3d", "s6", "s7", "quantplan", "eq23",
    "fig4c", "fig4d", "fig5", "onboard", "s1", "s4", "s5", "s8", "hw-all",
    "fpga",
];

/// Render one experiment to stdout.
pub fn run(exp: &str, art_dir: &Path, arch: &str, n_eval: usize) -> Result<()> {
    match exp {
        #[cfg(feature = "pjrt")]
        "fig2" => match evalrt::fig2_measured(art_dir, n_eval) {
            Ok(t) => t.print(),
            Err(e) => {
                eprintln!("[report] runtime fig2 unavailable ({e}); using results.json");
                kernels::fig2(&Results::load(art_dir)).print();
            }
        },
        #[cfg(not(feature = "pjrt"))]
        "fig2" => {
            eprintln!("[report] built without the pjrt feature; fig2 uses results.json");
            kernels::fig2(&Results::load(art_dir)).print();
        }
        "fig2c" => kernels::fig2c().print(),
        "s1" => kernels::s1().print(),
        "s4" => kernels::s4().print(),
        "s5" => kernels::s5().print(),
        "eq23" => fpga::eq23().print(),
        "fig4c" => {
            fpga::fig4_components(16, crate::hw::KernelKind::Mult).print();
            fpga::fig4_components(16, crate::hw::KernelKind::Adder2A).print();
            fpga::fig4_savings(16).print();
        }
        "fig4d" => {
            fpga::fig4_components(8, crate::hw::KernelKind::Mult).print();
            fpga::fig4_components(8, crate::hw::KernelKind::Adder2A).print();
            fpga::fig4_savings(8).print();
        }
        "fig5" => {
            for t in fpga::fig5() {
                t.print();
            }
        }
        "onboard" => fpga::onboard().print(),
        // default sweep; `repro report fpga` in main.rs adds --plan /
        // --parallelism / --out on top of the same helpers
        "fpga" => {
            fpga::onboard().print();
            let rows = fpga::default_plan_rows(
                crate::sim::hwsim::DEFAULT_PARALLELISM, n_eval.min(64))?;
            fpga::plan_table(&rows).print();
        }
        "s8" => fpga::s8().print(),
        #[cfg(feature = "pjrt")]
        "fig3ab" => {
            for t in quantrep::fig3ab(art_dir, arch)? {
                t.print();
            }
        }
        #[cfg(not(feature = "pjrt"))]
        "fig3ab" => anyhow::bail!(
            "fig3ab needs the probe graph: uncomment the xla dependency in \
             rust/Cargo.toml and rebuild with --features pjrt"),
        "fig3d" => quantrep::fig3d(art_dir, arch, n_eval)?.print(),
        "s6" => quantrep::fig3d(art_dir, "resnet8", n_eval)?.print(),
        "s7" => quantrep::s7(art_dir, arch, n_eval)?.print(),
        "quantplan" => quantrep::quantplan(art_dir, arch, n_eval)?.print(),
        "hw-all" => {
            for e in ["fig2c", "s1", "s4", "s5", "eq23", "fig4c", "fig4d",
                      "fig5", "onboard", "s8"] {
                run(e, art_dir, arch, n_eval)?;
            }
        }
        other => anyhow::bail!("unknown experiment {other}; choose from {EXPERIMENTS:?}"),
    }
    Ok(())
}
