//! Runtime-backed evaluation helpers: accuracy of any (arch, kernel)
//! variant through its AOT eval graph, on clean or noise-perturbed
//! inputs.  Backs the measured columns of Fig. 2 — on synthetic-10 every
//! kernel saturates clean accuracy at LeNet scale, so the paper's
//! "generalization capability" ordering is exposed via input-noise
//! robustness instead (documented in EXPERIMENTS.md E1).

use anyhow::Result;

use crate::coordinator::Manifest;
use crate::data;
use crate::runtime::{self, Runtime};
use crate::util::table::{pct, Table};
use crate::util::XorShift64;

use super::quantrep::trained_file;

/// Accuracy of `arch_kernel`'s eval graph over (images, labels), using
/// trained weights when present (init otherwise; returns the flag).
pub fn eval_acc(manifest: &Manifest, rt: &mut Runtime, arch: &str, kernel: &str,
                images: &[f32], labels: &[i32]) -> Result<(f64, bool)> {
    let gname = format!("{arch}_{kernel}_eval");
    let g = manifest.graph(&gname)?.clone();
    rt.load(&gname, &g.file)?;
    let layout = manifest.layout(arch)?.clone();
    let wfile = trained_file(arch, kernel);
    let trained = manifest.dir.join(&wfile).exists();
    let pfile = if trained { wfile } else { layout.init_file };
    let raw = manifest.read_param_file(arch, &pfile)?;
    let lits: Vec<xla::Literal> = raw.iter()
        .map(|(_, s, d)| runtime::literal_f32(s, d))
        .collect::<Result<_>>()?;
    let b = g.batch;
    let n = labels.len() / b * b;
    anyhow::ensure!(n > 0, "need at least one batch of {b}");
    let mut correct = 0usize;
    for c in 0..n / b {
        let x = runtime::literal_f32(&[b, 32, 32, 1],
                                     &images[c * b * 1024..(c + 1) * b * 1024])?;
        let mut inputs: Vec<&xla::Literal> = lits.iter().collect();
        inputs.push(&x);
        let logits = runtime::to_vec_f32(&rt.execute(&gname, &inputs)?[0])?;
        for i in 0..b {
            let row = &logits[i * 10..(i + 1) * 10];
            let pred = row.iter().enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0;
            if pred == labels[c * b + i] as usize {
                correct += 1;
            }
        }
    }
    Ok((correct as f64 / n as f64, trained))
}

/// Add uniform noise of amplitude `sigma` and clamp back to [-1, 1].
pub fn perturb(images: &[f32], sigma: f32, seed: u64) -> Vec<f32> {
    let mut rng = XorShift64::new(seed);
    images.iter()
        .map(|&v| (v + rng.next_f32_sym(sigma)).clamp(-1.0, 1.0))
        .collect()
}

/// Fig. 2 measured table: clean + noise-perturbed accuracy for all four
/// trained kernels, next to the paper's cited ImageNet column.
pub fn fig2_measured(art_dir: &std::path::Path, n_eval: usize) -> Result<Table> {
    let manifest = Manifest::load(art_dir)?;
    let mut rt = Runtime::new(art_dir)?;
    let ev = data::eval_set(n_eval, 7);
    let noisy1 = perturb(&ev.images, 0.6, 101);
    let noisy2 = perturb(&ev.images, 1.0, 202);
    let mut t = Table::new(
        "Fig. 2a/b — kernel comparison: measured on synthetic-10 (clean / noise 0.6 / noise 1.0) vs paper (cited)",
        &["kernel", "clean", "noise 0.6", "noise 1.0", "trained?",
          "paper ImageNet top-1 (cited)"],
    );
    let paper = [
        ("adder", "76.8 (ResNet-50, == or > CNN)"),
        ("mult", "76.13 (CNN baseline)"),
        ("shift", "~75 (DeepShift 6b, ~1% drop)"),
        ("xnor", "51.2 (XNOR, large drop)"),
    ];
    for (kernel, cited) in paper {
        let (clean, trained) = eval_acc(&manifest, &mut rt, "lenet5", kernel,
                                        &ev.images, &ev.labels)?;
        let (a1, _) = eval_acc(&manifest, &mut rt, "lenet5", kernel, &noisy1,
                               &ev.labels)?;
        let (a2, _) = eval_acc(&manifest, &mut rt, "lenet5", kernel, &noisy2,
                               &ev.labels)?;
        t.row(&[kernel.into(), pct(clean), pct(a1), pct(a2),
                trained.to_string(), cited.into()]);
    }
    Ok(t)
}
