//! Perf-trajectory report over the lab store (`repro lab report`):
//! one row per recorded run, oldest first, one column per gated key —
//! the "did the last five PRs actually make it faster?" view that a
//! single hand-edited baseline file could never answer.

use anyhow::Result;

use crate::lab::store::{fmt_val, Store};
use crate::lab::{gate_class, GateClass};
use crate::util::table::Table;

/// Render the trajectory table.  `keys` selects the columns; `None`
/// defaults to every Floor/Ceiling-classed key of the newest run.
pub fn trajectory(store: &Store, keys: Option<&[String]>) -> Result<Table> {
    let runs = store.list()?;
    anyhow::ensure!(!runs.is_empty(),
                    "lab store {} has no runs — `repro lab run --spec \
                     ci-sweep` first", store.root().display());
    let latest = runs.last().expect("non-empty");
    let keys: Vec<String> = match keys {
        Some(ks) if !ks.is_empty() => ks.to_vec(),
        _ => latest.keys.keys()
            .filter(|k| gate_class(k) != GateClass::Info)
            .cloned()
            .collect(),
    };
    anyhow::ensure!(!keys.is_empty(),
                    "no gated keys in run {} — pass --keys k1,k2",
                    latest.run_id);
    let mut header: Vec<String> =
        vec!["run".to_string(), "spec".to_string()];
    header.extend(keys.iter().cloned());
    let hrefs: Vec<&str> = header.iter().map(String::as_str).collect();
    let mut t = Table::new("lab perf trajectory (oldest -> newest)", &hrefs);
    for r in &runs {
        let mut row = vec![r.short_id(), r.spec_name.clone()];
        for k in &keys {
            row.push(r.keys.get(k).map_or_else(|| "-".to_string(),
                                               |v| fmt_val(*v)));
        }
        t.row(&row);
    }
    Ok(t)
}
