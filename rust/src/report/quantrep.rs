//! Quantization reports: Fig. 3(a/b) distributions, Fig. 3(d) bit sweep,
//! S6 (deeper net sweep), S7 (AdderNet-vs-CNN quantized contrast) and
//! the plan-vs-per-call serving comparison (`quantplan`).

use std::path::Path;

use anyhow::Result;

use crate::coordinator::Manifest;
use crate::data;
use crate::quant::plan::QuantPlan;
use crate::quant::{Calibration, Mode};
#[cfg(feature = "pjrt")]
use crate::runtime::{self, Runtime};
use crate::sim::functional::{self, Arch, ExecMode, KernelStrategy, QuantCfg, Runner,
                             SimKernel, Tensor};
use crate::sim::intpath;
use crate::util::table::{pct, Table};

/// Weights file naming convention shared with `repro train`.
pub fn trained_file(arch: &str, kernel: &str) -> String {
    format!("{arch}_{kernel}_trained.bin")
}

/// Load trained weights if present, else fall back to init (with a note).
pub fn load_params(manifest: &Manifest, arch: &str, kernel: &str)
                   -> Result<(functional::Params, bool)> {
    let file = trained_file(arch, kernel);
    if manifest.dir.join(&file).exists() {
        Ok((manifest.read_params(arch, &file)?, true))
    } else {
        let layout = manifest.layout(arch)?;
        eprintln!("[report] no {file}; using INIT weights — run `repro train \
                   --arch {arch} --kernel {kernel}` first for meaningful accuracy");
        Ok((manifest.read_params(arch, &layout.init_file.clone())?, false))
    }
}

fn eval_tensor(n: usize) -> (Tensor, Vec<i32>) {
    let b = data::eval_set(n, 7);
    (Tensor::new((b.n, 32, 32, 1), b.images), b.labels)
}

/// Parameters for reports that must run artifact-free: manifest weights
/// when present, else deterministic synthetic parameters.  Returns
/// (params, trained, synthetic).
pub fn params_or_synth(art_dir: &Path, arch: Arch, arch_name: &str,
                       kernel: &str) -> (functional::Params, bool, bool) {
    if let Ok(manifest) = Manifest::load(art_dir) {
        match load_params(&manifest, arch_name, kernel) {
            Ok((p, trained)) => return (p, trained, false),
            Err(e) => eprintln!("[report] could not read parameters ({e:#}); \
                                 using synthetic weights"),
        }
    }
    (functional::synth_params(arch, 42), false, true)
}

/// Calibration pass: run f32 forward over a calibration set, recording
/// per-layer feature/weight ranges.
pub fn calibrate(params: &functional::Params, arch: Arch, kind: SimKernel,
                 n: usize) -> (Calibration, f64) {
    let (x, labels) = eval_tensor(n);
    let mut calib = Calibration::new();
    let acc = {
        let mut runner = Runner {
            params,
            arch,
            kind,
            strategy: KernelStrategy::Auto,
            mode: ExecMode::F32,
            calib: None,
            observe: Some(&mut calib),
        };
        functional::accuracy(&mut runner, &x, &labels)
    };
    (calib, acc)
}

/// Accuracy at a given quantization config.
pub fn quant_accuracy(params: &functional::Params, arch: Arch, kind: SimKernel,
                      calib: &Calibration, cfg: QuantCfg, n: usize) -> f64 {
    let (x, labels) = eval_tensor(n);
    let mut runner = Runner {
        params,
        arch,
        kind,
        strategy: KernelStrategy::Auto,
        mode: ExecMode::Quant(cfg),
        calib: Some(calib),
        observe: None,
    };
    functional::accuracy(&mut runner, &x, &labels)
}

/// Fig. 3(d): quantized AdderNet accuracy vs bit width (+ S6 for the
/// deeper variant via `arch`).
pub fn fig3d(art_dir: &Path, arch_name: &str, n_eval: usize) -> Result<Table> {
    let manifest = Manifest::load(art_dir)?;
    let arch = Arch::parse(arch_name)
        .ok_or_else(|| anyhow::anyhow!("unknown arch {arch_name}"))?;
    let (params, trained) = load_params(&manifest, arch_name, "adder")?;
    let (calib, fp32_acc) = calibrate(&params, arch, SimKernel::Adder, n_eval);

    let paper = "paper ResNet-18 top-1: fp32 68.8, 8b 68.8, 5b 65.5, 4b degrades";
    let mut t = Table::new(
        &format!("Fig. 3d — shared-scale quantized AdderNet {arch_name} \
                  (trained={trained}; {paper})"),
        &["precision", "accuracy (synthetic-10)", "delta vs fp32"],
    );
    t.row(&["fp32".into(), pct(fp32_acc), "-".into()]);
    for bits in [16u32, 8, 7, 6, 5, 4] {
        let acc = quant_accuracy(&params, arch, SimKernel::Adder, &calib,
                                 QuantCfg { bits, mode: Mode::SharedScale }, n_eval);
        t.row(&[format!("int{bits}"), pct(acc), format!("{:+.1}pp", (acc - fp32_acc) * 100.0)]);
    }
    Ok(t)
}

/// S7: AdderNet (shared scale) vs CNN (separate scale) at 8/4 bit.
pub fn s7(art_dir: &Path, arch_name: &str, n_eval: usize) -> Result<Table> {
    let manifest = Manifest::load(art_dir)?;
    let arch = Arch::parse(arch_name)
        .ok_or_else(|| anyhow::anyhow!("unknown arch {arch_name}"))?;
    let mut t = Table::new(
        &format!("S7 — quantized AdderNet vs CNN on {arch_name} \
                  (paper ResNet-20: CNN 91.76/89.54, AdderNet 91.78/87.57 at 8/4 bit)"),
        &["kernel", "mode", "fp32", "int8", "int4", "4bit drop"],
    );
    for (kname, kind, mode) in [
        ("adder", SimKernel::Adder, Mode::SharedScale),
        ("mult", SimKernel::Mult, Mode::SeparateScale),
    ] {
        let (params, _) = load_params(&manifest, arch_name, kname)?;
        let (calib, fp32_acc) = calibrate(&params, arch, kind, n_eval);
        let a8 = quant_accuracy(&params, arch, kind, &calib,
                                QuantCfg { bits: 8, mode }, n_eval);
        let a4 = quant_accuracy(&params, arch, kind, &calib,
                                QuantCfg { bits: 4, mode }, n_eval);
        t.row(&[
            kname.into(),
            format!("{mode:?}"),
            pct(fp32_acc),
            pct(a8),
            pct(a4),
            format!("{:+.1}pp", (a4 - fp32_acc) * 100.0),
        ]);
    }
    Ok(t)
}

/// Plan-based vs per-call quantized serving: the same calibration and
/// bit-widths executed two ways — the per-call path (weights re-gridded
/// every forward, activations round-tripped through f32 between layers)
/// against the compiled [`QuantPlan`] int path (weights quantized once,
/// folded BN, activations i32 across the conv stack).  The paper's
/// claim (§3.1) is that shared-scale int8/int16 keeps accuracy; this
/// table shows the *serving* pipeline keeps it too.
pub fn quantplan(art_dir: &Path, arch_name: &str, n_eval: usize) -> Result<Table> {
    let arch = Arch::parse(arch_name)
        .ok_or_else(|| anyhow::anyhow!("unknown arch {arch_name}"))?;
    let (params, trained, synthetic) =
        params_or_synth(art_dir, arch, arch_name, "adder");
    let (calib, fp32_acc) = calibrate(&params, arch, SimKernel::Adder, n_eval);
    let (x, labels) = eval_tensor(n_eval);
    let mut t = Table::new(
        &format!("quantplan — per-call vs plan-compiled int serving on \
                  {arch_name} adder (trained={trained} synthetic={synthetic})"),
        &["precision", "per-call acc", "plan acc", "plan vs fp32"],
    );
    t.row(&["fp32".into(), pct(fp32_acc), "-".into(), "-".into()]);
    for bits in [16u32, 8] {
        let cfg = QuantCfg { bits, mode: Mode::SharedScale };
        let percall = quant_accuracy(&params, arch, SimKernel::Adder, &calib,
                                     cfg, n_eval);
        let plan = QuantPlan::build(&params, arch, SimKernel::Adder, cfg, &calib)?;
        let pacc = intpath::plan_accuracy(&plan, KernelStrategy::Auto, &x, &labels);
        t.row(&[
            format!("int{bits}"),
            pct(percall),
            pct(pacc),
            format!("{:+.1}pp", (pacc - fp32_acc) * 100.0),
        ]);
    }
    Ok(t)
}

/// Fig. 3(a/b): per-layer feature and weight log2-magnitude distributions
/// of the trained AdderNet, via the AOT probe graph (features) and the
/// parameter buffers (weights).  Needs the PJRT runtime.
#[cfg(feature = "pjrt")]
pub fn fig3ab(art_dir: &Path, arch_name: &str) -> Result<Vec<Table>> {
    use anyhow::Context;

    use crate::quant::{self, Log2Histogram};
    use crate::util::table::f;

    let manifest = Manifest::load(art_dir)?;
    let gname = format!("{arch_name}_adder_probe");
    let ginfo = manifest.graph(&gname)?.clone();
    let mut rt = Runtime::new(art_dir)?;
    rt.load(&gname, &ginfo.file).context("loading probe graph")?;

    let (params, _) = load_params(&manifest, arch_name, "adder")?;
    // probe inputs: params (sorted) + x
    let layout = manifest.layout(arch_name)?;
    let wfile = trained_file(arch_name, "adder");
    let pfile = if manifest.dir.join(&wfile).exists() { wfile } else { layout.init_file.clone() };
    let raw = manifest.read_param_file(arch_name, &pfile)?;
    let lits: Vec<xla::Literal> = raw.iter()
        .map(|(_, s, d)| runtime::literal_f32(s, d))
        .collect::<Result<_>>()?;
    let batch = data::generate(ginfo.batch, 7, 2_000_000);
    let x = runtime::literal_f32(&[ginfo.batch, 32, 32, 1], &batch.images)?;
    let mut inputs: Vec<&xla::Literal> = lits.iter().collect();
    inputs.push(&x);
    let feats = rt.execute(&gname, &inputs)?;

    // feature histogram table (Fig. 3a)
    let lo = -8;
    let hi = 5;
    let mut ta = Table::new(
        &format!("Fig. 3a — {arch_name} AdderNet input-feature |x| log2 distribution \
                  (paper: >90% within 2^-4..2^2)"),
        &["layer", "in [2^-4,2^2)", "in clip [2^-5,2^3)", "zero/tiny"],
    );
    for (i, lname) in ginfo.layers.iter().enumerate() {
        let v = runtime::to_vec_f32(&feats[i])?;
        let mut h = Log2Histogram::new(lo, hi);
        h.add(&v);
        ta.row(&[
            lname.clone(),
            pct(h.fraction_in(-4, 2)),
            pct(h.fraction_in(-5, 3)),
            pct(h.zero_or_tiny as f64 / h.total as f64),
        ]);
    }

    // weight histogram table (Fig. 3b)
    let mut tb = Table::new(
        &format!("Fig. 3b — {arch_name} AdderNet weight |w| log2 distribution \
                  (paper: majority within 2^-2..2^3)"),
        &["layer", "in [2^-2,2^3)", "in clip [2^-5,2^3)", "max |w|"],
    );
    for lname in &ginfo.layers {
        if let Some((_, d)) = params.get(&format!("{lname}/conv_w")) {
            let mut h = Log2Histogram::new(lo, hi);
            h.add(d);
            tb.row(&[
                lname.clone(),
                pct(h.fraction_in(-2, 3)),
                pct(h.fraction_in(-5, 3)),
                f(quant::max_abs(d) as f64, 3),
            ]);
        }
    }
    Ok(vec![ta, tb])
}
