//! PJRT runtime: load AOT-compiled HLO text, compile once, execute from
//! the Layer-3 hot path.
//!
//! Interchange format is HLO **text** (not serialized proto): jax >= 0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the
//! text parser reassigns ids (see python/compile/aot.py and
//! /opt/xla-example/README.md).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{Context, Result};
use xla::{ElementType, Literal, PjRtClient, PjRtLoadedExecutable};

/// Global execute lock: entry into the xla_extension FFI is serialized
/// across serving workers as a precaution (the 0.5.1 C bindings make no
/// thread-safety promises for concurrent `execute` from multiple
/// clients).  PJRT still parallelises *inside* each computation via its
/// own thread pool, so on CPU this costs little.
static EXECUTE_LOCK: Mutex<()> = Mutex::new(());

/// Compile-once executable cache over a PJRT CPU client.
pub struct Runtime {
    client: PjRtClient,
    exes: HashMap<String, PjRtLoadedExecutable>,
    art_dir: PathBuf,
}

impl Runtime {
    /// Create a runtime rooted at the artifacts directory.
    pub fn new<P: AsRef<Path>>(art_dir: P) -> Result<Self> {
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client, exes: HashMap::new(), art_dir: art_dir.as_ref().to_path_buf() })
    }

    pub fn art_dir(&self) -> &Path {
        &self.art_dir
    }

    /// Load + compile `file` (HLO text) under key `name`; no-op if cached.
    pub fn load(&mut self, name: &str, file: &str) -> Result<()> {
        if self.exes.contains_key(name) {
            return Ok(());
        }
        let path = self.art_dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        self.exes.insert(name.to_string(), exe);
        Ok(())
    }

    pub fn is_loaded(&self, name: &str) -> bool {
        self.exes.contains_key(name)
    }

    /// Execute a loaded graph.  All our graphs are lowered with
    /// `return_tuple=True`, so the single output literal is a tuple that
    /// gets decomposed into per-leaf literals.
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self, name: &str, inputs: &[L],
    ) -> Result<Vec<Literal>> {
        let exe = self.exes.get(name)
            .ok_or_else(|| anyhow::anyhow!("graph {name} not loaded"))?;
        let _guard = EXECUTE_LOCK.lock().unwrap();
        let bufs = exe.execute::<L>(inputs)
            .with_context(|| format!("executing {name}"))?;
        let mut out = bufs[0][0].to_literal_sync()?;
        out.decompose_tuple().map_err(Into::into)
    }
}

// ---------------------------------------------------------------------------
// Literal conversion helpers
// ---------------------------------------------------------------------------

/// Build an f32 literal of the given shape from a flat slice.
pub fn literal_f32(shape: &[usize], data: &[f32]) -> Result<Literal> {
    let n: usize = shape.iter().product();
    anyhow::ensure!(n == data.len(), "shape {:?} vs {} elements", shape, data.len());
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    };
    Literal::create_from_shape_and_untyped_data(ElementType::F32, shape, bytes)
        .map_err(Into::into)
}

/// Build an i32 literal of the given shape.
pub fn literal_i32(shape: &[usize], data: &[i32]) -> Result<Literal> {
    let n: usize = shape.iter().product();
    anyhow::ensure!(n == data.len(), "shape {:?} vs {} elements", shape, data.len());
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    };
    Literal::create_from_shape_and_untyped_data(ElementType::S32, shape, bytes)
        .map_err(Into::into)
}

/// Scalar i32 literal (e.g. the train-step counter).
pub fn literal_scalar_i32(v: i32) -> Literal {
    Literal::scalar(v)
}

/// Extract an f32 vector from a literal.
pub fn to_vec_f32(l: &Literal) -> Result<Vec<f32>> {
    l.to_vec::<f32>().map_err(Into::into)
}

/// Extract the single f32 scalar from a literal.
pub fn scalar_f32(l: &Literal) -> Result<f32> {
    l.get_first_element::<f32>().map_err(Into::into)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let data = vec![1.0f32, -2.5, 3.25, 0.0, 7.5, -0.125];
        let l = literal_f32(&[2, 3], &data).unwrap();
        assert_eq!(l.element_count(), 6);
        assert_eq!(to_vec_f32(&l).unwrap(), data);
    }

    #[test]
    fn literal_roundtrip_i32() {
        let data = vec![1i32, -2, 3];
        let l = literal_i32(&[3], &data).unwrap();
        assert_eq!(l.to_vec::<i32>().unwrap(), data);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(literal_f32(&[2, 2], &[1.0]).is_err());
    }

    #[test]
    fn scalar_literal() {
        let l = literal_scalar_i32(42);
        assert_eq!(l.get_first_element::<i32>().unwrap(), 42);
    }
}
