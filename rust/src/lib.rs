//! # addernet — AdderNet + minimalist hardware, full-system reproduction
//!
//! Three-layer architecture (see DESIGN.md):
//!
//! * **Layer 1/2** live in `python/` (Pallas kernels + JAX models) and are
//!   AOT-lowered to HLO text by `make artifacts`.
//! * **Layer 3** is this crate: the PJRT [`runtime`], the training/serving
//!   [`coordinator`], and the paper's hardware contribution modelled by
//!   [`hw`] (gate-level FPGA substrate) and [`sim`] (accelerator
//!   simulator with a bit-accurate integer functional mode).
//!
//! Python never runs on the request path; the `repro` binary is
//! self-contained once artifacts are built.
//!
//! The PJRT/XLA layer ([`runtime`], the trainer, the graph-backed
//! reports) is optional: it compiles only with the `pjrt` feature so the
//! crate builds, tests and serves (through the functional-sim backend)
//! on machines with no XLA toolchain.

pub mod coordinator;
pub mod data;
pub mod hw;
pub mod lab;
pub mod nn;
pub mod obs;
pub mod quant;
pub mod report;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod sim;
pub mod util;
