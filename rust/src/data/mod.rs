//! Synthetic 10-class 32x32 dataset — bit-exact mirror of
//! `python/compile/data.py` (same 31-bit LCG, same integer patterns), so
//! the Rust training driver, the quantization sweeps and the Python
//! build/test path all see identical images.
//!
//! This dataset substitutes CIFAR-100/ImageNet (DESIGN.md §2): the paper
//! claims we must preserve are *relative* (AdderNet vs CNN, bit-width
//! orderings), which any learnable classification task exposes.

use crate::util::rng::{Lcg31, LCG_M};

pub const IMG: usize = 32;
pub const N_CLASSES: usize = 10;
pub const PIXELS: usize = IMG * IMG;

const HI: i64 = 220;
const LO: i64 = 35;

/// One generated batch: NHWC f32 images in [-1, 1] + int labels.
#[derive(Debug, Clone)]
pub struct Batch {
    /// (n, 32, 32, 1) row-major.
    pub images: Vec<f32>,
    pub labels: Vec<i32>,
    pub n: usize,
}

/// Per-image initial LCG state (matches data.py `_seed_for`).
fn sample_seed(seed: u64, idx: u64) -> u64 {
    (seed.wrapping_mul(2_654_435_761).wrapping_add(idx.wrapping_mul(97)).wrapping_add(1)) % LCG_M
}

/// Base pattern value for class `cls` at pixel (y, x), given the two
/// per-sample style draws s1, s2. Pure integer math — mirrors
/// `data.py::_base_pattern` exactly.
fn base_pattern(cls: usize, y: i64, x: i64, s1: i64, s2: i64, blocks: &[i64; 16]) -> i64 {
    let stripes = |coord: i64| -> i64 {
        let p = 4 + s1 % 4;
        if ((coord + s2).rem_euclid(p)) * 2 < p { HI } else { LO }
    };
    match cls {
        0 => stripes(y),
        1 => stripes(x),
        2 => stripes(x + y),
        3 => stripes(x - y + 64),
        4 => {
            let c = 3 + s1 % 4;
            if ((x / c) + (y / c)) % 2 == 0 { HI } else { LO }
        }
        5 | 6 => {
            let dx = x - (16 + s2 % 7 - 3);
            let dy = y - (16 + (s2 / 7) % 7 - 3);
            let d2 = dx * dx + dy * dy;
            let r = 6 + s1 % 7;
            if cls == 5 {
                if d2 <= r * r { HI } else { LO }
            } else {
                let band = 2 + s1 % 3;
                if (d2 - r * r).abs() <= band * r { HI } else { LO }
            }
        }
        7 => {
            let m = 4 + s1 % 5;
            let frame_t = 1 + s2 % 2;
            let edge = |mm: i64| -> bool {
                let hi = IMG as i64 - 1 - mm;
                ((x == mm || x == hi) && y >= mm && y <= hi)
                    || ((y == mm || y == hi) && x >= mm && x <= hi)
            };
            let mut on = edge(m);
            for t in 0..3i64 {
                if t <= frame_t && edge(m + t) {
                    on = true;
                }
            }
            if on { HI } else { LO }
        }
        8 => {
            let t = 2 + s1 % 3;
            let cxx = 16 + s2 % 5 - 2;
            if (x - cxx).abs() < t || (y - cxx).abs() < t { HI } else { LO }
        }
        9 => blocks[((y / 8) * 4 + (x / 8)) as usize],
        _ => unreachable!("class {cls}"),
    }
}

/// Generate `n` samples starting at dataset index `offset`.
pub fn generate(n: usize, seed: u64, offset: usize) -> Batch {
    let mut images = vec![0f32; n * PIXELS];
    let mut labels = vec![0i32; n];
    for i in 0..n {
        let idx = (offset + i) as u64;
        let cls = (idx % N_CLASSES as u64) as usize;
        labels[i] = cls as i32;
        let mut lcg = Lcg31::new(sample_seed(seed, idx));
        let s1 = ((lcg.next_state() >> 7) % 1000) as i64;
        let s2 = ((lcg.next_state() >> 7) % 1000) as i64;
        // class-9 block chain is seeded from s1 and advanced 16 times
        // (row-major over the 4x4 block grid), independent of the noise
        // chain — mirror data.py exactly.
        let mut blocks = [LO; 16];
        let mut st = Lcg31::new(((s1 * 31 + 7) as u64) % LCG_M);
        for b in blocks.iter_mut() {
            let v = st.next_state();
            *b = if (v >> 5) % 2 == 0 { HI } else { LO };
        }
        for p in 0..PIXELS {
            let y = (p / IMG) as i64;
            let x = (p % IMG) as i64;
            let base = base_pattern(cls, y, x, s1, s2, &blocks);
            let noise = ((lcg.next_state() >> 7) % 41) as i64 - 20;
            let px = (base + noise).clamp(0, 255);
            images[i * PIXELS + p] = px as f32 / 127.5 - 1.0;
        }
    }
    Batch { images, labels, n }
}

/// Stream of training batches: endless fresh samples (the synthetic set
/// is procedurally infinite, which replaces the paper's crop/flip
/// augmentation — every step sees new draws from the same distribution).
pub struct BatchStream {
    seed: u64,
    batch: usize,
    cursor: usize,
}

impl BatchStream {
    pub fn new(seed: u64, batch: usize) -> Self {
        Self { seed, batch, cursor: 0 }
    }

    pub fn next_batch(&mut self) -> Batch {
        let b = generate(self.batch, self.seed, self.cursor);
        self.cursor += self.batch;
        b
    }
}

/// A fixed held-out evaluation set (disjoint index range from any
/// training stream that starts at offset 0 and runs < 10^6 samples).
pub fn eval_set(n: usize, seed: u64) -> Batch {
    generate(n, seed, 1_000_000)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn to_u8(v: f32) -> u8 {
        ((v + 1.0) * 127.5).round() as u8
    }

    /// Cross-language goldens — SAME constants as
    /// python/tests/test_data.py::GOLDENS (seed=42).
    #[test]
    fn golden_pixels_match_python() {
        let b = generate(12, 42, 0);
        let at = |s: usize, y: usize, x: usize| to_u8(b.images[s * PIXELS + y * IMG + x]);
        assert_eq!(at(0, 0, 0), 29);
        assert_eq!(at(0, 13, 17), 30);
        assert_eq!(at(3, 5, 5), 222);
        assert_eq!(at(9, 31, 31), 35);
        assert_eq!(at(7, 16, 2), 55);
        assert_eq!(at(5, 10, 20), 27);
    }

    #[test]
    fn labels_cycle() {
        let b = generate(25, 0, 3);
        for (i, &l) in b.labels.iter().enumerate() {
            assert_eq!(l as usize, (3 + i) % 10);
        }
    }

    #[test]
    fn offset_consistency() {
        let a = generate(20, 5, 0);
        let c = generate(8, 5, 12);
        assert_eq!(&a.images[12 * PIXELS..], &c.images[..]);
    }

    #[test]
    fn value_range() {
        let b = generate(30, 1, 0);
        for &v in &b.images {
            assert!((-1.0..=1.0).contains(&v));
        }
    }

    #[test]
    fn classes_distinguishable() {
        // intra-class mean distance < inter-class centroid distance
        let b = generate(200, 9, 0);
        let mut cents = vec![vec![0f64; PIXELS]; 10];
        let mut counts = [0usize; 10];
        for i in 0..b.n {
            let c = b.labels[i] as usize;
            counts[c] += 1;
            for p in 0..PIXELS {
                cents[c][p] += b.images[i * PIXELS + p] as f64;
            }
        }
        for c in 0..10 {
            for p in 0..PIXELS {
                cents[c][p] /= counts[c] as f64;
            }
        }
        let mut inter = 0.0;
        let mut cnt = 0;
        for i in 0..10 {
            for j in i + 1..10 {
                let d: f64 = (0..PIXELS)
                    .map(|p| (cents[i][p] - cents[j][p]).powi(2))
                    .sum::<f64>()
                    .sqrt();
                inter += d;
                cnt += 1;
            }
        }
        assert!(inter / cnt as f64 > 1.0, "inter {}", inter / cnt as f64);
    }

    #[test]
    fn stream_advances() {
        let mut s = BatchStream::new(3, 8);
        let b1 = s.next_batch();
        let b2 = s.next_batch();
        assert_ne!(b1.images, b2.images);
        // stream batches equal direct generation at matching offsets
        let d = generate(8, 3, 8);
        assert_eq!(b2.images, d.images);
    }

    #[test]
    fn eval_set_disjoint_from_train_prefix() {
        let e = eval_set(16, 3);
        let t = generate(16, 3, 0);
        assert_ne!(e.images, t.images);
    }
}
