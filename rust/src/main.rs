//! `repro` — the Layer-3 leader binary.
//!
//! Subcommands:
//!   report <exp>       regenerate a paper table/figure (see DESIGN.md §4)
//!   train              drive the AOT train-step graph, save weights
//!   serve              start the batching inference server + load test
//!                      (--mode int8|int16 serves plan-compiled variants;
//!                      --plan FILE serves an exported plan with zero
//!                      calibration; --replicas/--queue-depth size the
//!                      fleet; --swap-plan hot-swaps a plan mid-drive)
//!   loadtest           open-loop synthetic traffic at a fixed QPS against
//!                      a fresh server; p50/p99/shed-rate written to JSON
//!   loadtest check     CI gate over a loadtest JSON artifact
//!                      (--p99-slo-ms / --max-shed-rate add SLO bounds)
//!   profile            per-layer wall-time for one forward pass; int
//!                      modes join the plan schedule's simulated cycles
//!                      per layer (the cycle column sums to the
//!                      schedule's total exactly)
//!   calibrate          record per-layer ranges, write a calibration JSON
//!   plan               compile a QuantPlan and export it as a portable
//!                      JSON artifact (serve it with serve --plan)
//!   quantize           shared-scale quantized accuracy via functional sim
//!   simulate           run the FPGA accelerator simulator on a network
//!   bench check        compare target/hotpath.json against a committed
//!                      baseline; nonzero exit on speedup regressions
//!   lab                the experiment subsystem: `lab run --spec` executes
//!                      a declarative sweep into the content-addressed
//!                      `.lab/` store; `lab list`/`lab diff` inspect and
//!                      compare recorded runs (deterministic hw keys must
//!                      match bit-for-bit); `lab check` is the CI gate
//!                      against a committed baseline record; `lab promote`
//!                      cuts a new baseline from a run; `lab report` renders
//!                      the perf trajectory
//!   info               list artifacts, graphs and networks
//!
//! No external CLI crate is vendored; parsing is a tiny flag scanner.

use std::collections::HashMap;
use std::path::PathBuf;
use std::time::Duration;

use anyhow::{Context, Result};

use addernet::coordinator::{server, Manifest};
#[cfg(feature = "pjrt")]
use addernet::coordinator::{Trainer, VariantCfg};
use addernet::hw::KernelKind;
use addernet::obs;
use addernet::report;
#[cfg(feature = "pjrt")]
use addernet::runtime;
use addernet::quant;
use addernet::sim::accelerator::{self, AccelConfig};
use addernet::sim::functional::{Arch, ExecMode, KernelStrategy, Params, QuantCfg,
                                Runner, SimKernel, Tensor};
use addernet::util::table::{f, pct, Table};
use addernet::{data, nn};

/// Minimal flag parser: positional args + `--key value` pairs.
struct Args {
    positional: Vec<String>,
    flags: HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Args {
        let mut positional = Vec::new();
        let mut flags = HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    flags.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(key.to_string(), "true".into());
                    i += 1;
                }
            } else {
                positional.push(a.clone());
                i += 1;
            }
        }
        Args { positional, flags }
    }

    fn get(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    fn get_usize(&self, key: &str, default: usize) -> usize {
        self.flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

fn art_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.get("artifacts", "artifacts"))
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        usage();
        std::process::exit(2);
    }
    let cmd = argv[0].clone();
    let args = Args::parse(&argv[1..]);
    let r = match cmd.as_str() {
        "report" => cmd_report(&args),
        "train" => cmd_train(&args),
        "serve" => cmd_serve(&args),
        "loadtest" => cmd_loadtest(&args),
        "profile" => cmd_profile(&args),
        "calibrate" => cmd_calibrate(&args),
        "plan" => cmd_plan(&args),
        "quantize" => cmd_quantize(&args),
        "simulate" => cmd_simulate(&args),
        "bench" => cmd_bench(&args),
        "lab" => cmd_lab(&args),
        "info" => cmd_info(&args),
        "help" | "--help" | "-h" => {
            usage();
            Ok(())
        }
        other => {
            eprintln!("unknown command {other}");
            usage();
            std::process::exit(2);
        }
    };
    if let Err(e) = r {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() {
    eprintln!(
        "repro — AdderNet minimalist-hardware reproduction (see DESIGN.md)\n\
         usage:\n  \
         repro report <exp> [--arch lenet5] [--eval-n 256] [--artifacts DIR]\n    \
         exps: {}\n  \
         repro report fpga [--plan PLAN.json[,PLAN2.json]] [--parallelism 1024] \
                     [--out target/fpga_report.json]\n  \
         repro train [--arch lenet5] [--kernel adder] [--steps 400] [--eval-n 512]\n  \
         repro serve [--backend functional|hwsim|pjrt] \
                     [--models lenet5_adder,lenet5_mult] \
                     [--kernel naive|tiled|simd|winograd|auto] [--mode f32|int8|int16] \
                     [--calib FILE.json] [--plan PLAN.json[,PLAN2.json]] \
                     [--hw-parallelism 1024] \
                     [--replicas 1] [--queue-depth 1024] [--swap-plan PLAN.json] \
                     [--requests 512] [--window-ms 2] [--max-batch 32] \
                     [--trace-out trace.json] [--metrics-out metrics.json]\n  \
         repro loadtest [--models lenet5_adder] [--plan PLAN.json[,PLAN2.json]] \
                     [--kernel naive|tiled|simd|winograd|auto] [--replicas 1] \
                     [--queue-depth 1024] [--qps 200] [--duration-s 3] \
                     [--window-ms 2] [--max-batch 32] [--out target/loadtest.json] \
                     [--trace-out trace.json]\n  \
         repro loadtest check --file target/loadtest.json \
                     [--p99-slo-ms 50] [--max-shed-rate 0.25]\n  \
         repro profile [--arch resnet8] [--kernel adder] [--mode f32|int8|int16] \
                     [--strategy naive|tiled|simd|winograd|auto] \
                     [--calib FILE.json] [--hw-parallelism 1024] [--out prof.json]\n  \
         repro calibrate [--arch lenet5] [--kernel adder] [--calib-n 256] \
                     [--out target/calibration.json]\n  \
         repro plan [--arch lenet5] [--kernel adder] [--mode int8|int16] \
                     [--calib FILE.json] [--out target/plan.json]\n  \
         repro quantize [--arch lenet5] [--kernel adder] [--bits 8] [--mode shared|separate]\n  \
         repro simulate [--net resnet18] [--kernel adder|mult] [--dw 16] [--parallelism 1024]\n  \
         repro bench check --baseline bench_baseline.json \
                     [--current target/hotpath.json] [--tolerance 0.25]\n  \
         repro lab run --spec ci-sweep|ci-smoke|FILE.json [--store .lab] [--force]\n  \
         repro lab list [--store .lab]\n  \
         repro lab diff [RUN_A RUN_B] [--latest] [--baseline FILE.json] [--store .lab]\n  \
         repro lab check --baseline lab_baseline.json [--run ID] \
                     [--tolerance 0.25] [--store .lab]\n  \
         repro lab promote [--run ID] [--out lab_baseline.json] [--all-keys]\n  \
         repro lab report [--keys k1,k2] [--store .lab]\n  \
         repro info",
        report::EXPERIMENTS.join(" ")
    );
}

fn cmd_report(args: &Args) -> Result<()> {
    let exp = args.positional.first()
        .context("report needs an experiment id")?;
    if exp == "fpga" {
        // fpga takes flags the generic dispatcher has no slots for
        // (--plan/--parallelism/--out) and writes a JSON artifact
        return cmd_report_fpga(args);
    }
    report::run(exp, &art_dir(args), &args.get("arch", "lenet5"),
                args.get_usize("eval-n", 256))
}

/// `repro report fpga`: the paper-comparison hardware table (§4) for
/// compiled QuantPlans — per arch × width × kernel GOPs, latency, power
/// and LUT split — plus a JSON artifact CI archives.  `--plan` costs
/// exported plan files; without it, every registered arch is swept over
/// the adder int8/int16 + mult int8 matrix on synthetic weights.
fn cmd_report_fpga(args: &Args) -> Result<()> {
    use addernet::report::fpga;

    let parallelism = args.get_usize(
        "parallelism", addernet::sim::hwsim::DEFAULT_PARALLELISM as usize) as u64;
    let out = args.get("out", "target/fpga_report.json");
    fpga::onboard().print();
    let rows = match args.flags.get("plan") {
        Some(paths) => {
            let mut rows = Vec::new();
            for path in paths.split(',') {
                let path = path.trim();
                let plan = quant::plan::plan_from_json(
                    &std::fs::read_to_string(path)
                        .with_context(|| format!("reading plan {path}"))?)
                    .with_context(|| format!("importing plan {path}"))?;
                rows.push(fpga::plan_hw_row(&plan, parallelism)
                    .with_context(|| format!("costing plan {path}"))?);
            }
            rows
        }
        None => {
            println!("[report] no --plan files; sweeping every registered \
                      arch over the adder int8/int16 + mult int8 matrix on \
                      synthetic weights");
            fpga::default_plan_rows(parallelism, 32)?
        }
    };
    fpga::plan_table(&rows).print();
    let doc = fpga::fpga_report_json(&rows, parallelism);
    if let Some(parent) = std::path::Path::new(&out).parent() {
        let _ = std::fs::create_dir_all(parent);
    }
    std::fs::write(&out, &doc).with_context(|| format!("writing {out}"))?;
    println!("[report] fpga hardware report written to {out}");
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_train(_args: &Args) -> Result<()> {
    anyhow::bail!("`repro train` drives the AOT train-step graph and needs \
                   the PJRT runtime: uncomment the xla dependency in \
                   rust/Cargo.toml and rebuild with --features pjrt")
}

#[cfg(feature = "pjrt")]
fn cmd_train(args: &Args) -> Result<()> {
    use addernet::report::Results;

    let arch = args.get("arch", "lenet5");
    let kernel = args.get("kernel", "adder");
    let dir = art_dir(args);
    let manifest = Manifest::load(&dir)?;
    let mut rt = runtime::Runtime::new(&dir)?;
    let mut trainer = Trainer::new(&manifest, &mut rt, &arch, &kernel)?;
    let ginfo = manifest.graph(&format!("{arch}_{kernel}_train"))?;
    let steps = args.get_usize("steps", ginfo.total_steps.max(1));
    let eval_n = args.get_usize("eval-n", 512);
    let seed = args.get_usize("seed", 1) as u64;

    println!("[train] {arch}/{kernel}: {steps} steps, batch {}", trainer.batch_size);
    let mut stream = data::BatchStream::new(seed, trainer.batch_size);
    let t0 = std::time::Instant::now();
    for s in 0..steps {
        let batch = stream.next_batch();
        let (loss, acc) = trainer.train_step(&rt, &batch)?;
        if s % 20 == 0 || s + 1 == steps {
            println!("  step {s:4}  loss {loss:.4}  batch-acc {acc:.3}");
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    println!("[train] {steps} steps in {dt:.1}s ({:.1} steps/s)", steps as f64 / dt);

    let ev = data::eval_set(eval_n, seed);
    let acc = trainer.evaluate(&rt, &ev.images, &ev.labels)?;
    println!("[train] eval accuracy over {eval_n}: {:.3}", acc);

    let wfile = report::quantrep::trained_file(&arch, &kernel);
    trainer.save_params(&manifest, &wfile)?;
    println!("[train] weights saved to {}", dir.join(&wfile).display());

    let mut results = Results::load(&dir);
    results.set(&format!("acc/{arch}_{kernel}"), acc);
    results.set(&format!("loss/{arch}_{kernel}"),
                trainer.history.last().map(|r| r.loss as f64).unwrap_or(0.0));
    results.save(&dir)?;
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    match args.get("backend", "functional").as_str() {
        "functional" => serve_functional(args, false),
        // hwsim = the functional plan path plus the cycle-accurate
        // accelerator schedule: identical logits, each response carries
        // the simulated hardware cost
        "hwsim" => serve_functional(args, true),
        #[cfg(feature = "pjrt")]
        "pjrt" => serve_pjrt(args),
        other => anyhow::bail!(
            "unknown serve backend {other} (functional and hwsim are always \
             available; pjrt needs the xla dependency uncommented in \
             rust/Cargo.toml and a build with --features pjrt)"),
    }
}

/// Serve through the tiled functional-sim engine: batched Runner
/// inference, no artifacts or XLA required (synthetic weights stand in
/// when no parameter files exist).  `--mode int8|int16` compiles each
/// variant into a `QuantPlan` (weights quantized once, activations i32
/// through the conv stack) from `--calib FILE.json` — or, without a
/// file, from a fresh calibration pass over the synthetic eval set.
/// With `hwsim` every variant also gets a cycle schedule on the
/// simulated accelerator at `--hw-parallelism` lanes, and responses
/// carry the hardware cost (logits stay bit-identical to functional).
fn serve_functional(args: &Args, hwsim: bool) -> Result<()> {
    let dir = art_dir(args);
    let backend = if hwsim { "hwsim" } else { "functional" };
    let hw_parallelism = hwsim.then(|| {
        args.get_usize("hw-parallelism",
                       addernet::sim::hwsim::DEFAULT_PARALLELISM as usize) as u64
    });
    let models = args.get("models", "lenet5_adder,lenet5_mult");
    let n_req = args.get_usize("requests", 512);
    let window = Duration::from_millis(args.get_usize("window-ms", 2) as u64);
    let max_batch = args.get_usize("max-batch", 32);
    let replicas = args.get_usize("replicas", 1).max(1);
    let queue_depth = args.get_usize("queue-depth", server::DEFAULT_QUEUE_DEPTH).max(1);
    // --trace-out: record request/batch/exec/per-layer spans into a
    // ring-buffer sink and write Chrome trace-event JSON on exit.
    // --metrics-out: snapshot the metrics registry to a JSON file.
    let trace_out = args.flags.get("trace-out").cloned();
    let metrics_out = args.flags.get("metrics-out").cloned();
    let sink = trace_out.is_some().then(obs::trace::TraceSink::new);
    // --swap-plan PLAN.json: mid-drive, hot-swap the matching quantized
    // variant onto this plan while requests are in flight — the CLI
    // control path for ServerHandle::swap_plan.
    let swap = match args.flags.get("swap-plan") {
        Some(path) => Some(quant::plan::plan_from_json(
            &std::fs::read_to_string(path)
                .with_context(|| format!("reading swap plan {path}"))?)
            .with_context(|| format!("importing swap plan {path}"))?),
        None => None,
    };
    // --kernel pins the inner-kernel strategy; default Auto defers to
    // the ADDERNET_KERNEL env override and then the shape heuristic.
    let strategy = match args.flags.get("kernel") {
        Some(s) => KernelStrategy::parse(s).with_context(
            || format!("serve's --kernel selects the inner-kernel STRATEGY \
                        (naive|tiled|simd|winograd|auto), got {s}; adder-vs-mult \
                        is chosen per model via --models (e.g. lenet5_mult)"))?,
        None => KernelStrategy::Auto,
    };
    // --plan serves exported QuantPlan artifacts: the cold-start path
    // with zero calibration (the quantized weights ARE the plan).  It
    // replaces --models/--mode/--calib, which all describe how to BUILD
    // a plan this invocation already has.
    if let Some(paths) = args.flags.get("plan") {
        anyhow::ensure!(!args.flags.contains_key("calib"),
                        "--plan and --calib are mutually exclusive (a plan \
                         already carries its quantized weights)");
        anyhow::ensure!(!args.flags.contains_key("mode"),
                        "--plan and --mode are mutually exclusive (the plan \
                         records its serving width)");
        if args.flags.contains_key("models") {
            eprintln!("[serve] --plan given; ignoring --models (plan files \
                       define the served variants)");
        }
        let mut variants = Vec::new();
        for path in paths.split(',') {
            let path = path.trim();
            let plan = quant::plan::plan_from_json(
                &std::fs::read_to_string(path)
                    .with_context(|| format!("reading plan {path}"))?)
                .with_context(|| format!("importing plan {path}"))?;
            let name = format!("{}_{}_int{}", plan.arch.name(),
                               plan.kind.label(), plan.cfg.bits);
            println!("[serve] {name}: plan-compiled variant from {path} \
                      (no calibration file needed)");
            // no synthetic params: a plan-mounted worker never reads
            // them (the quantized weights live in the plan)
            variants.push(server::FunctionalVariantCfg {
                name: name.clone(),
                arch: plan.arch,
                kind: plan.kind,
                strategy,
                params: Params::new(),
                mode: ExecMode::Quant(plan.cfg),
                calib: None,
                input_hwc: plan.arch.graph().input,
                max_batch: max_batch.max(1),
                plan: Some(plan),
                replicas,
                queue_depth,
                hw_parallelism,
            });
        }
        println!("[serve] {backend} backend: {} plan variants x {replicas} \
                  replicas, kernel {}, window {:?}, max batch {}, queue depth \
                  {queue_depth}",
                 variants.len(), strategy.label(), window, max_batch);
        let handle = server::start_functional_observed(variants, window, sink)?;
        return drive_load(handle, n_req, swap, trace_out.as_deref(),
                          metrics_out.as_deref());
    }
    let mode = args.get("mode", if hwsim { "int8" } else { "f32" });
    let qcfg = match mode.as_str() {
        "f32" => None,
        "int8" => Some(QuantCfg { bits: 8, mode: quant::Mode::SharedScale }),
        "int16" => Some(QuantCfg { bits: 16, mode: quant::Mode::SharedScale }),
        m => anyhow::bail!("serve's --mode takes f32|int8|int16, got {m}"),
    };
    anyhow::ensure!(!(hwsim && qcfg.is_none()),
                    "the hwsim backend executes compiled plans — pick --mode \
                     int8|int16 or mount plan files with --plan (f32 variants \
                     have no hardware schedule)");
    let calib_table = match args.flags.get("calib") {
        Some(path) => Some(quant::plan::calibration_from_json(
            &std::fs::read_to_string(path)
                .with_context(|| format!("reading calibration table {path}"))?)
            .with_context(|| format!("parsing calibration table {path}"))?),
        None => None,
    };
    let manifest = Manifest::load(&dir).ok();
    let mut variants = Vec::new();
    for m in models.split(',') {
        let name = m.trim().to_string();
        let (arch_s, kernel_s) = name.split_once('_').unwrap_or((name.as_str(), "adder"));
        let arch = Arch::parse(arch_s).with_context(
            || format!("functional backend serves {}, got {arch_s}",
                       Arch::names_label()))?;
        let kind = match kernel_s {
            "adder" => SimKernel::Adder,
            "mult" => SimKernel::Mult,
            k => anyhow::bail!("functional backend serves adder|mult kernels, got {k}"),
        };
        let mut cfg = server::FunctionalVariantCfg::synthetic(&name, arch, kind, 42);
        cfg.strategy = strategy;
        cfg.max_batch = max_batch.max(1);
        cfg.replicas = replicas;
        cfg.queue_depth = queue_depth;
        cfg.hw_parallelism = hw_parallelism;
        let loaded = manifest.as_ref().and_then(|man| {
            let wfile = report::quantrep::trained_file(arch_s, kernel_s);
            let file = if man.dir.join(&wfile).exists() {
                Some(wfile)
            } else {
                man.params.get(arch_s).map(|l| l.init_file.clone())
            };
            file.and_then(|f2| man.read_params(arch_s, &f2).ok())
        });
        match loaded {
            Some(p) => cfg.params = p,
            None => eprintln!("[serve] {name}: no parameter file under {}; \
                               using synthetic weights", dir.display()),
        }
        if let Some(q) = qcfg {
            // skip variants the plan compiler cannot serve at this
            // width instead of failing the whole server — the default
            // model list pairs an adder and a mult variant.
            if !quant::QuantPlan::supports(kind, q.bits) {
                eprintln!("[serve] {name}: skipped — no int{} plan for this \
                           kernel (mult caps at 8-bit operands)", q.bits);
                continue;
            }
            let calib = match &calib_table {
                Some(c) => c.clone(),
                None => {
                    eprintln!("[serve] {name}: no --calib table; calibrating \
                               on 128 synthetic eval images");
                    report::quantrep::calibrate(&cfg.params, arch, kind, 128).0
                }
            };
            cfg.mode = ExecMode::Quant(q);
            cfg.calib = Some(calib);
        }
        variants.push(cfg);
    }
    anyhow::ensure!(!variants.is_empty(),
                    "no servable variants left for --mode {mode} (mult-kernel \
                     plans cap at int8; try --models lenet5_adder)");
    println!("[serve] {backend} backend: {} variants x {replicas} replicas, \
              kernel {}, mode {}, window {:?}, max batch {}, queue depth \
              {queue_depth}",
             variants.len(), strategy.label(), mode, window, max_batch);
    let handle = server::start_functional_observed(variants, window, sink)?;
    drive_load(handle, n_req, swap, trace_out.as_deref(), metrics_out.as_deref())
}

/// Record per-layer feature/weight ranges over the synthetic eval set
/// and write them as a calibration JSON — the build input `repro serve
/// --mode int8 --calib FILE` compiles into a serving plan.
fn cmd_calibrate(args: &Args) -> Result<()> {
    let dir = art_dir(args);
    let arch_name = args.get("arch", "lenet5");
    let kernel = args.get("kernel", "adder");
    let n = args.get_usize("calib-n", 256);
    let out = args.get("out", "target/calibration.json");
    let arch = Arch::parse(&arch_name)
        .with_context(|| format!("arch must be one of {}", Arch::names_label()))?;
    let kind = match kernel.as_str() {
        "adder" => SimKernel::Adder,
        "mult" => SimKernel::Mult,
        k => anyhow::bail!("functional sim supports adder|mult, got {k}"),
    };
    let (params, trained, synthetic) =
        report::quantrep::params_or_synth(&dir, arch, &arch_name, &kernel);
    let (calib, fp32) = report::quantrep::calibrate(&params, arch, kind, n);
    let doc = quant::plan::calibration_to_json(&calib);
    if let Some(parent) = std::path::Path::new(&out).parent() {
        let _ = std::fs::create_dir_all(parent);
    }
    std::fs::write(&out, &doc).with_context(|| format!("writing {out}"))?;
    println!("[calibrate] {arch_name}/{kernel}: {} conv layers over {n} images \
              (trained={trained} synthetic={synthetic}, fp32 acc {fp32:.3})",
             calib.len());
    let mut t = Table::new("per-layer calibration (int8 shared exponents)",
                           &["layer", "feat max|x|", "weight max|w|", "2^e"]);
    for (name, lc) in &calib {
        t.row(&[name.clone(), f(lc.feat_max_abs as f64, 4),
                f(lc.weight_max_abs as f64, 4),
                format!("2^{}", lc.shared_exp(8))]);
    }
    t.print();
    println!("[calibrate] table written to {out}");
    Ok(())
}

/// Compile a `QuantPlan` (params + calibration + quant config) and
/// export it as a portable, versioned JSON artifact.  `repro serve
/// --plan FILE` then cold-starts from it with no calibration table, no
/// parameter files and no quantization work at startup.
fn cmd_plan(args: &Args) -> Result<()> {
    let dir = art_dir(args);
    let arch_name = args.get("arch", "lenet5");
    let kernel = args.get("kernel", "adder");
    let mode = args.get("mode", "int8");
    let out = args.get("out", "target/plan.json");
    let arch = Arch::parse(&arch_name)
        .with_context(|| format!("arch must be one of {}", Arch::names_label()))?;
    let kind = SimKernel::parse(&kernel)
        .with_context(|| format!("functional sim supports adder|mult, got {kernel}"))?;
    let bits = match mode.as_str() {
        "int8" => 8,
        "int16" => 16,
        m => anyhow::bail!("plan's --mode takes int8|int16, got {m}"),
    };
    anyhow::ensure!(quant::QuantPlan::supports(kind, bits),
                    "mult-kernel plans cap at 8-bit operands (i32 accumulator \
                     overflow at int{bits}); use --kernel adder for int16");
    let qcfg = QuantCfg { bits, mode: quant::Mode::SharedScale };
    let (params, trained, synthetic) =
        report::quantrep::params_or_synth(&dir, arch, &arch_name, &kernel);
    let calib = match args.flags.get("calib") {
        Some(path) => quant::plan::calibration_from_json(
            &std::fs::read_to_string(path)
                .with_context(|| format!("reading calibration table {path}"))?)
            .with_context(|| format!("parsing calibration table {path}"))?,
        None => {
            eprintln!("[plan] no --calib table; calibrating on 128 synthetic \
                       eval images");
            report::quantrep::calibrate(&params, arch, kind, 128).0
        }
    };
    let plan = quant::QuantPlan::build(&params, arch, kind, qcfg, &calib)
        .context("compiling the quantization plan")?;
    let doc = quant::plan::plan_to_json(&plan);
    if let Some(parent) = std::path::Path::new(&out).parent() {
        let _ = std::fs::create_dir_all(parent);
    }
    std::fs::write(&out, &doc).with_context(|| format!("writing {out}"))?;
    println!("[plan] {arch_name}/{kernel} int{bits}: {} conv + {} dense \
              layers, {} bytes -> {out} (trained={trained} \
              synthetic={synthetic})",
             plan.convs.len(), plan.dense.len(), doc.len());
    println!("[plan] serve it with `repro serve --plan {out}` — no \
              calibration file needed");
    Ok(())
}

/// `repro bench check`: compare the freshly-recorded hotpath JSON
/// against a committed baseline snapshot and exit nonzero when a gated
/// row regressed past the tolerance — the CI bench-regression gate.
/// Gated fields are RATIOS (machine-portable) plus the simulated
/// accelerator's deterministic cycle counts — never absolute medians.
fn cmd_bench(args: &Args) -> Result<()> {
    match args.positional.first().map(|s| s.as_str()) {
        Some("check") => bench_check(args),
        _ => anyhow::bail!(
            "usage: repro bench check --baseline FILE \
             [--current target/hotpath.json] [--tolerance 0.25]"),
    }
}

fn bench_check(args: &Args) -> Result<()> {
    let baseline_path = args.flags.get("baseline").cloned()
        .context("bench check needs --baseline FILE (the committed snapshot, \
                  e.g. rust/bench_baseline.json)")?;
    let current_path = args.get("current", "target/hotpath.json");
    let tol: f64 = args.get("tolerance", "0.25").parse()
        .context("--tolerance takes a fraction, e.g. 0.25")?;
    anyhow::ensure!((0.0..1.0).contains(&tol),
                    "--tolerance takes a fraction in [0, 1)");
    let load = |p: &str| -> Result<addernet::util::Json> {
        addernet::util::Json::parse(
            &std::fs::read_to_string(p).with_context(|| format!("reading {p} \
                (run `cargo bench --bench hotpath` first?)"))?)
            .with_context(|| format!("parsing {p}"))
    };
    let base = load(&baseline_path)?;
    let cur = load(&current_path)?;
    // Floor gates: RATIOS where higher is better — the speedup families
    // the engine promises (blocking+parallelism, the lane kernel, the
    // Winograd transform-domain engine, the compiled int8 serving path)
    // plus the accelerator's mult/adder latency ratio.  Fail when
    // current < baseline*(1-tol).
    const FLOOR_GATES: &[(&str, &[&str])] = &[
        ("f32 adder: tiled vs naive",
         &["results", "f32_adder", "tiled_vs_naive"]),
        ("f32 adder: simd vs tiled",
         &["results", "f32_adder", "simd_vs_tiled"]),
        ("int8 adder: tiled vs naive",
         &["results", "int8_adder", "tiled_vs_naive"]),
        ("int8 adder: simd vs tiled",
         &["results", "int8_adder", "simd_vs_tiled"]),
        ("int8 mult: winograd vs simd",
         &["derived", "winograd_vs_simd"]),
        ("int8 plan vs f32 (whole model)",
         &["derived", "plan_vs_f32"]),
        ("hwsim: mult/adder latency ratio (resnet8 dw16)",
         &["derived", "hw_mult_over_adder_latency"]),
    ];
    // Ceiling gates: per-image cycle counts on the simulated
    // accelerator — deterministic and machine-portable, so the baseline
    // is exact; lower is better.  Fail when current > baseline*(1+tol).
    const CEILING_GATES: &[(&str, &[&str])] = &[
        ("hwsim cycles: lenet5 adder int8",
         &["derived", "hw_cycles_lenet5_int8"]),
        ("hwsim cycles: cnv6 adder int8",
         &["derived", "hw_cycles_cnv6_int8"]),
        ("hwsim cycles: resnet8 adder int8",
         &["derived", "hw_cycles_resnet8_int8"]),
        ("hwsim cycles: resnet8 mult int8",
         &["derived", "hw_cycles_resnet8_mult_int8"]),
    ];
    let fetch = |doc: &addernet::util::Json, which: &str,
                 path: &[&str]| -> Result<f64> {
        doc.at(path).and_then(|v| v.as_f64())
            .ok_or_else(|| anyhow::anyhow!("{which}: missing {}",
                                           path.join(".")))
    };
    let mut t = Table::new(
        &format!("hotpath bench-regression gate (tolerance {:.0}%)",
                 tol * 100.0),
        &["gated row", "baseline", "bound", "current", "status"]);
    let mut failed = Vec::new();
    for (label, path) in FLOOR_GATES {
        let b = fetch(&base, &baseline_path, path)?;
        let c = fetch(&cur, &current_path, path)?;
        let floor = b * (1.0 - tol);
        let ok = c >= floor;
        t.row(&[label.to_string(), f(b, 2), f(floor, 2), f(c, 2),
                if ok { "ok" } else { "REGRESSED" }.to_string()]);
        if !ok {
            failed.push(format!("{label}: {c:.2} < floor {floor:.2}"));
        }
    }
    for (label, path) in CEILING_GATES {
        let b = fetch(&base, &baseline_path, path)?;
        let c = fetch(&cur, &current_path, path)?;
        let cap = b * (1.0 + tol);
        let ok = c <= cap;
        t.row(&[label.to_string(), f(b, 0), f(cap, 0), f(c, 0),
                if ok { "ok" } else { "REGRESSED" }.to_string()]);
        if !ok {
            failed.push(format!("{label}: {c:.0} > ceiling {cap:.0}"));
        }
    }
    t.print();
    anyhow::ensure!(failed.is_empty(),
                    "hotpath bench regression: {}", failed.join("; "));
    println!("[bench] all {} gated rows within {:.0}% of the baseline",
             FLOOR_GATES.len() + CEILING_GATES.len(), tol * 100.0);
    Ok(())
}

/// `repro lab` — the experiment subsystem (see `src/lab/`): declarative
/// sweeps into a content-addressed store, diffs against recorded
/// history, and the history-sourced CI gate that replaced `bench check`.
fn cmd_lab(args: &Args) -> Result<()> {
    use addernet::lab::{self, diff as labdiff, job, spec::SweepSpec,
                        store::Store};
    use std::path::Path;

    let store_dir = args.get("store", lab::DEFAULT_STORE);
    let open_store = || Store::open(Path::new(&store_dir));
    match args.positional.first().map(|s| s.as_str()) {
        Some("run") => {
            let spec_arg = args.flags.get("spec").context(
                "lab run needs --spec NAME|FILE.json (builtin specs: \
                 ci-sweep, ci-smoke)")?;
            let spec = SweepSpec::resolve(spec_arg)?;
            let store = open_store()?;
            let force = args.flags.contains_key("force");
            match job::run_spec(&store, &spec, force)? {
                job::RunOutcome::Deduped(rec) => {
                    println!("[lab] run {} already recorded for this spec + \
                              environment — deduped, nothing re-measured \
                              (--force records a new generation)",
                             rec.run_id);
                }
                job::RunOutcome::Ran(rec) => {
                    rec.key_table().print();
                    println!("[lab] recorded run {} ({} keys, {} jobs ok, {} \
                              skipped) in {}",
                             rec.run_id, rec.keys.len(), rec.jobs_ok(),
                             rec.jobs_skipped(), store_dir);
                }
            }
            Ok(())
        }
        Some("list") => {
            let store = open_store()?;
            let runs = store.list()?;
            let mut t = Table::new(
                &format!("lab store {store_dir} ({} runs)", runs.len()),
                &["run", "spec", "created_unix", "jobs ok", "skipped",
                  "keys"]);
            for r in &runs {
                t.row(&[r.run_id.clone(), r.spec_name.clone(),
                        r.created_unix.to_string(), r.jobs_ok().to_string(),
                        r.jobs_skipped().to_string(),
                        r.keys.len().to_string()]);
            }
            t.print();
            Ok(())
        }
        Some("diff") => {
            let store = open_store()?;
            let ids: Vec<&String> = args.positional.iter().skip(1).collect();
            let (a, b) = if let Some(base) = args.flags.get("baseline") {
                // committed baseline on the left, a run (named or
                // latest) on the right
                let a = Store::load_file(Path::new(base))?;
                let b = match ids.first() {
                    Some(id) => store.load(id)?,
                    None => store.latest(1)?.pop().context(
                        "lab store is empty — `repro lab run` first")?,
                };
                (a, b)
            } else if ids.len() >= 2 {
                (store.load(ids[0])?, store.load(ids[1])?)
            } else {
                // default / --latest: the two most recent runs,
                // older on the left
                let mut latest = store.latest(2)?;
                anyhow::ensure!(latest.len() == 2,
                                "lab diff needs two runs in the store (or \
                                 two ids, or --baseline FILE)");
                let b = latest.remove(0);
                let a = latest.remove(0);
                (a, b)
            };
            let report = labdiff::diff_records(&a, &b);
            report.table(&a.short_id(), &b.short_id()).print();
            let drift = report.drift();
            anyhow::ensure!(
                drift.is_empty(),
                "deterministic keys drifted between {} and {}: {} — the \
                 accelerator model is pure arithmetic, so this is a code \
                 change, not noise",
                a.run_id, b.run_id,
                drift.iter().map(|r| r.key.as_str())
                    .collect::<Vec<_>>().join(", "));
            println!("[lab] no drift on deterministic keys ({} keys \
                      compared)", report.rows.len());
            Ok(())
        }
        Some("check") => {
            let base_path = args.flags.get("baseline").context(
                "lab check needs --baseline FILE (the committed run record, \
                 e.g. rust/lab_baseline.json)")?;
            let baseline = Store::load_file(Path::new(base_path))?;
            let store = open_store()?;
            let current = match args.flags.get("run") {
                Some(id) => store.load(id)?,
                None => store.latest(1)?.pop().context(
                    "lab store is empty — `repro lab run --spec ci-sweep` \
                     first")?,
            };
            let tol: f64 = args.get("tolerance", "0.25").parse()
                .context("--tolerance takes a fraction, e.g. 0.25")?;
            let (t, failed, gated) =
                labdiff::check_records(&current, &baseline, tol)?;
            t.print();
            anyhow::ensure!(failed.is_empty(),
                            "lab bench regression vs {base_path}: {}",
                            failed.join("; "));
            println!("[lab] all {gated} gated keys within {:.0}% of \
                      baseline {base_path}", tol * 100.0);
            Ok(())
        }
        Some("promote") => {
            let store = open_store()?;
            let run = match args.flags.get("run") {
                Some(id) => store.load(id)?,
                None => store.latest(1)?.pop().context(
                    "lab store is empty — nothing to promote")?,
            };
            let out = args.get("out", "lab_baseline.json");
            let all_keys = args.flags.contains_key("all-keys");
            let baseline = labdiff::promote(&run, all_keys);
            std::fs::write(&out, baseline.to_json())
                .with_context(|| format!("writing {out}"))?;
            println!("[lab] promoted run {} -> {out} ({} keys); commit it \
                      to move the CI gate", run.run_id, baseline.keys.len());
            Ok(())
        }
        Some("report") => {
            let store = open_store()?;
            let keys: Option<Vec<String>> = args.flags.get("keys")
                .map(|s| s.split(',').map(|k| k.trim().to_string())
                     .filter(|k| !k.is_empty()).collect());
            report::labrep::trajectory(&store, keys.as_deref())?.print();
            Ok(())
        }
        _ => anyhow::bail!(
            "usage: repro lab run|list|diff|check|promote|report (see \
             `repro help`)"),
    }
}

/// Serve through the AOT eval graphs on the PJRT runtime.
#[cfg(feature = "pjrt")]
fn serve_pjrt(args: &Args) -> Result<()> {
    let dir = art_dir(args);
    let manifest = Manifest::load(&dir)?;
    let models = args.get("models", "lenet5_adder,lenet5_mult");
    let n_req = args.get_usize("requests", 512);
    let window = Duration::from_millis(args.get_usize("window-ms", 2) as u64);
    let variants: Vec<VariantCfg> = models.split(',').map(|m| {
        let m = m.trim().to_string();
        let (arch, kernel) = m.split_once('_').unwrap_or((m.as_str(), "adder"));
        let w = report::quantrep::trained_file(arch, kernel);
        VariantCfg {
            model: m.clone(),
            weights: dir.join(&w).exists().then_some(w),
        }
    }).collect();

    println!("[serve] pjrt backend: {} variants, window {:?}", variants.len(), window);
    let handle = server::start(&manifest, &variants, window)?;
    drive_load(handle, n_req, None, None, None)
}

/// Resolve which served variant a hot-swap plan targets: the plan-file
/// naming scheme first (`resnet8_adder_int8`), then the bare
/// `arch_kernel` route `--mode int8` serving uses.
fn swap_target(names: &[String], plan: &addernet::quant::QuantPlan) -> Result<String> {
    let candidates = [
        format!("{}_{}_int{}", plan.arch.name(), plan.kind.label(), plan.cfg.bits),
        format!("{}_{}", plan.arch.name(), plan.kind.label()),
    ];
    candidates.iter().find(|c| names.iter().any(|n| n == *c)).cloned()
        .ok_or_else(|| anyhow::anyhow!(
            "--swap-plan targets {} or {}, but the server only serves: {}",
            candidates[0], candidates[1], names.join(", ")))
}

/// Fire a synthetic round-robin load at a running server and print the
/// latency/throughput metrics table.  When `swap` carries a plan, it is
/// hot-swapped onto the matching variant at the halfway point — with
/// requests in flight — to exercise the zero-downtime path.  When
/// `trace_out` / `metrics_out` name files, the Chrome trace and the
/// registry snapshot are written before returning.
fn drive_load(handle: server::ServerHandle, n_req: usize,
              mut swap: Option<addernet::quant::QuantPlan>,
              trace_out: Option<&str>, metrics_out: Option<&str>) -> Result<()> {
    let names = handle.variants();
    let eval = data::eval_set(n_req, 3);
    let t0 = std::time::Instant::now();
    let mut pending = Vec::new();
    for i in 0..n_req {
        let img = eval.images[i * 1024..(i + 1) * 1024].to_vec();
        let v = &names[i % names.len()];
        if i == n_req / 2 {
            if let Some(plan) = swap.take() {
                let target = swap_target(&names, &plan)?;
                handle.swap_plan(&target, plan)?;
                println!("[serve] hot-swapped plan onto {target} at request {i} \
                          (traffic in flight)");
            }
        }
        // the queue is bounded now: a shed is the server telling an
        // open-loop driver to back off, not a fatal error
        let rx = loop {
            match handle.submit(v, img.clone()) {
                Ok(rx) => break rx,
                Err(server::SubmitError::Overloaded { .. }) => {
                    std::thread::sleep(Duration::from_micros(200));
                }
                Err(e) => return Err(e.into()),
            }
        };
        pending.push((i, rx));
    }
    if let Some(plan) = swap.take() {
        // n_req == 0 or 1: the midpoint never fired, still honour the flag
        let target = swap_target(&names, &plan)?;
        handle.swap_plan(&target, plan)?;
    }
    let mut correct = 0usize;
    for (i, rx) in pending {
        let resp = rx.recv().context("response channel closed")?;
        let pred = resp.logits.iter().enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0;
        if pred == eval.labels[i] as usize {
            correct += 1;
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    println!("[serve] {n_req} requests in {dt:.2}s = {:.0} img/s, acc {:.3}",
             n_req as f64 / dt, correct as f64 / n_req as f64);

    let metrics = handle.metrics_snapshot();
    let mut t = Table::new("serving metrics", &[
        "variant", "requests", "batches", "mean batch", "shed", "swaps",
        "queue p50 us", "exec p50 us", "e2e p50 us", "e2e p99 us",
    ]);
    for (name, m) in &metrics {
        t.row(&[
            name.clone(),
            m.requests.to_string(),
            m.batches.to_string(),
            f(m.mean_batch_size(), 1),
            m.shed.to_string(),
            m.swaps.to_string(),
            m.queue_lat.quantile_us(0.5).to_string(),
            m.exec_lat.quantile_us(0.5).to_string(),
            m.e2e_lat.quantile_us(0.5).to_string(),
            m.e2e_lat.quantile_us(0.99).to_string(),
        ]);
    }
    t.print();
    // hwsim variants: the accumulated cycle-accurate accelerator cost
    if metrics.iter().any(|(_, m)| m.hw_cycles > 0) {
        let mut ht = Table::new("simulated hardware (cycle-accurate accelerator)", &[
            "variant", "cycles", "fmax MHz", "lat/img ms", "power W",
            "util", "DRAM MB",
        ]);
        for (name, m) in &metrics {
            if m.hw_cycles == 0 {
                continue;
            }
            ht.row(&[
                name.clone(),
                m.hw_cycles.to_string(),
                f(m.hw_fmax_mhz, 0),
                f(m.hw_latency_per_image_ms(), 3),
                f(m.hw_power_w, 2),
                pct(m.hw_utilization),
                f(m.hw_dram_bytes as f64 / 1e6, 1),
            ]);
        }
        ht.print();
    }
    if let Some(path) = metrics_out {
        let reg = obs::registry::Registry::new();
        handle.export_registry(&reg);
        if let Some(parent) = std::path::Path::new(path).parent() {
            let _ = std::fs::create_dir_all(parent);
        }
        std::fs::write(path, reg.snapshot().to_string())
            .with_context(|| format!("writing {path}"))?;
        println!("[serve] metrics snapshot written to {path}");
    }
    let sink = handle.trace().cloned();
    handle.shutdown();
    // write after shutdown so the workers' final spans are in the sink
    if let (Some(path), Some(sink)) = (trace_out, sink) {
        sink.write_json(std::path::Path::new(path))?;
        println!("[serve] chrome trace written to {path} (open it at \
                  https://ui.perfetto.dev)");
    }
    Ok(())
}

/// `repro loadtest`: start a fresh functional server (f32 `--models`
/// and/or `--plan` variants — unlike serve, the two compose, so one rig
/// can probe a mixed f32 + int fleet), fire open-loop traffic at a
/// fixed QPS, and persist p50/p99/shed-rate to a JSON artifact.
/// `repro loadtest check --file X.json` is the CI gate over it.
fn cmd_loadtest(args: &Args) -> Result<()> {
    use addernet::coordinator::loadtest;

    if args.positional.first().map(|s| s.as_str()) == Some("check") {
        let file = args.flags.get("file")
            .context("loadtest check needs --file target/loadtest.json")?;
        // optional SLO bounds on top of the structural checks
        let slo = loadtest::CheckSlo {
            p99_slo_ms: match args.flags.get("p99-slo-ms") {
                Some(v) => Some(v.parse()
                    .context("--p99-slo-ms takes milliseconds, e.g. 50")?),
                None => None,
            },
            max_shed_rate: match args.flags.get("max-shed-rate") {
                Some(v) => Some(v.parse()
                    .context("--max-shed-rate takes a fraction, e.g. 0.25")?),
                None => None,
            },
        };
        return loadtest::check(std::path::Path::new(file), &slo);
    }
    let window = Duration::from_millis(args.get_usize("window-ms", 2) as u64);
    let max_batch = args.get_usize("max-batch", 32).max(1);
    let replicas = args.get_usize("replicas", 1).max(1);
    let queue_depth = args.get_usize("queue-depth", server::DEFAULT_QUEUE_DEPTH).max(1);
    let qps: f64 = args.get("qps", "200").parse().context("--qps takes a number")?;
    let duration = Duration::from_secs(args.get_usize("duration-s", 3) as u64);
    let out = args.get("out", "target/loadtest.json");
    let trace_out = args.flags.get("trace-out").cloned();
    let sink = trace_out.is_some().then(obs::trace::TraceSink::new);
    let strategy = match args.flags.get("kernel") {
        Some(s) => KernelStrategy::parse(s)
            .with_context(|| format!("--kernel takes naive|tiled|simd|winograd|\
                                      auto, got {s}"))?,
        None => KernelStrategy::Auto,
    };

    let mut variants = Vec::new();
    // f32 variants on synthetic weights: the load rig needs no artifacts
    if let Some(models) = args.flags.get("models") {
        for m in models.split(',') {
            let name = m.trim().to_string();
            let (arch_s, kernel_s) =
                name.split_once('_').unwrap_or((name.as_str(), "adder"));
            let arch = Arch::parse(arch_s).with_context(
                || format!("loadtest serves {}, got {arch_s}", Arch::names_label()))?;
            let kind = SimKernel::parse(kernel_s).with_context(
                || format!("loadtest serves adder|mult kernels, got {kernel_s}"))?;
            let mut cfg = server::FunctionalVariantCfg::synthetic(&name, arch, kind, 42);
            cfg.strategy = strategy;
            cfg.max_batch = max_batch;
            cfg.replicas = replicas;
            cfg.queue_depth = queue_depth;
            variants.push(cfg);
        }
    }
    if let Some(paths) = args.flags.get("plan") {
        for path in paths.split(',') {
            let path = path.trim();
            let plan = quant::plan::plan_from_json(
                &std::fs::read_to_string(path)
                    .with_context(|| format!("reading plan {path}"))?)
                .with_context(|| format!("importing plan {path}"))?;
            let name = format!("{}_{}_int{}", plan.arch.name(),
                               plan.kind.label(), plan.cfg.bits);
            variants.push(server::FunctionalVariantCfg {
                name,
                arch: plan.arch,
                kind: plan.kind,
                strategy,
                params: Params::new(),
                mode: ExecMode::Quant(plan.cfg),
                calib: None,
                input_hwc: plan.arch.graph().input,
                max_batch,
                plan: Some(plan),
                replicas,
                queue_depth,
                hw_parallelism: None,
            });
        }
    }
    anyhow::ensure!(!variants.is_empty(),
                    "loadtest needs --models and/or --plan variants");

    let names: Vec<String> = variants.iter().map(|v| v.name.clone()).collect();
    println!("[loadtest] {} variants x {replicas} replicas, {qps} qps for \
              {:?}, queue depth {queue_depth}", names.len(), duration);
    let handle = server::start_functional_observed(variants, window, sink)?;
    let report = loadtest::run(&handle, &names,
                               &loadtest::LoadtestCfg { qps, duration, replicas })?;
    let sink = handle.trace().cloned();
    handle.shutdown();
    if let (Some(path), Some(sink)) = (trace_out.as_deref(), sink) {
        sink.write_json(std::path::Path::new(path))?;
        println!("[loadtest] chrome trace written to {path} (open it at \
                  https://ui.perfetto.dev)");
    }

    let mut t = Table::new("loadtest (open loop — sheds are never retried)", &[
        "variant", "sent", "ok", "shed", "shed rate", "errors", "peak q",
        "p50 us", "p99 us", "max us",
    ]);
    for (name, o) in &report.variants {
        t.row(&[
            name.clone(),
            o.sent.to_string(),
            o.ok.to_string(),
            o.shed.to_string(),
            f(o.shed_rate(), 3),
            o.errors.to_string(),
            o.peak_queue.to_string(),
            o.lat.quantile_us(0.5).to_string(),
            o.lat.quantile_us(0.99).to_string(),
            o.lat.max_us().to_string(),
        ]);
    }
    t.print();
    println!("[loadtest] requested {:.0} qps, achieved {:.0} qps over {:.2}s \
              ({} pool workers)",
             report.requested_qps, report.achieved_qps,
             report.wall.as_secs_f64(), report.pool_workers);
    report.write_json(std::path::Path::new(&out))?;
    println!("[loadtest] report written to {out} (gate it with `repro \
              loadtest check --file {out}`)");
    Ok(())
}

/// `repro profile`: one observed forward pass through the functional
/// engine, printed as a per-layer table.  f32 mode profiles the float
/// Runner (wall-time only); int modes compile a QuantPlan, run it on
/// the hardware-backed runner and join each measured row against the
/// accelerator schedule's simulated cycles by canonical op name — the
/// cycle column sums to the schedule's `total_cycles` exactly.
fn cmd_profile(args: &Args) -> Result<()> {
    let dir = art_dir(args);
    let arch_name = args.get("arch", "resnet8");
    let kernel = args.get("kernel", "adder");
    let mode = args.get("mode", "int8");
    let arch = Arch::parse(&arch_name)
        .with_context(|| format!("arch must be one of {}", Arch::names_label()))?;
    let kind = SimKernel::parse(&kernel)
        .with_context(|| format!("functional sim supports adder|mult, got {kernel}"))?;
    // --strategy pins the inner-kernel engine the profile's "kernel"
    // column reports; default Auto defers to ADDERNET_KERNEL and the
    // shape heuristic, exactly like serving.
    let strategy = match args.flags.get("strategy") {
        Some(s) => KernelStrategy::parse(s).with_context(
            || format!("--strategy takes naive|tiled|simd|winograd|auto, \
                        got {s}"))?,
        None => KernelStrategy::Auto,
    };
    let parallelism = args.get_usize(
        "hw-parallelism", addernet::sim::hwsim::DEFAULT_PARALLELISM as usize) as u64;
    let (params, trained, synthetic) =
        report::quantrep::params_or_synth(&dir, arch, &arch_name, &kernel);
    let (h, w, c) = arch.graph().input;
    let ev = data::eval_set(1, 7);
    let x = Tensor::new((1, h, w, c), ev.images[..h * w * c].to_vec());
    let profile = match mode.as_str() {
        "f32" => {
            let mut runner = Runner {
                params: &params,
                arch,
                kind,
                strategy,
                mode: ExecMode::F32,
                calib: None,
                observe: None,
            };
            obs::profile::profile_f32(&mut runner, &x)
        }
        "int8" | "int16" => {
            let bits = if mode == "int8" { 8 } else { 16 };
            anyhow::ensure!(quant::QuantPlan::supports(kind, bits),
                            "mult-kernel plans cap at 8-bit operands; use \
                             --kernel adder for int16");
            let calib = match args.flags.get("calib") {
                Some(path) => quant::plan::calibration_from_json(
                    &std::fs::read_to_string(path)
                        .with_context(|| format!("reading calibration table \
                                                  {path}"))?)
                    .with_context(|| format!("parsing calibration table {path}"))?,
                None => report::quantrep::calibrate(&params, arch, kind, 128).0,
            };
            let qcfg = QuantCfg { bits, mode: quant::Mode::SharedScale };
            let plan = quant::QuantPlan::build(&params, arch, kind, qcfg, &calib)
                .context("compiling the quantization plan")?;
            obs::profile::profile_plan(&plan, strategy, parallelism, &x)
                .context("profiling the plan on the simulated accelerator")?
        }
        m => anyhow::bail!("profile's --mode takes f32|int8|int16, got {m}"),
    };
    println!("[profile] {arch_name}/{kernel} {mode} (trained={trained} \
              synthetic={synthetic})");
    profile.table().print();
    if let Some(cyc) = profile.hw_total_cycles {
        println!("[profile] schedule total {} cycles @ {:.0} MHz -> {:.3} ms/img",
                 cyc, profile.hw_fmax_mhz.unwrap_or(0.0),
                 profile.hw_latency_ms.unwrap_or(0.0));
    }
    if let Some(out) = args.flags.get("out") {
        if let Some(parent) = std::path::Path::new(out).parent() {
            let _ = std::fs::create_dir_all(parent);
        }
        std::fs::write(out, profile.to_json().to_string())
            .with_context(|| format!("writing {out}"))?;
        println!("[profile] profile written to {out}");
    }
    Ok(())
}

fn cmd_quantize(args: &Args) -> Result<()> {
    let dir = art_dir(args);
    let arch = args.get("arch", "lenet5");
    let bits: u32 = args.get("bits", "8").parse().context("--bits")?;
    let kernel = args.get("kernel", "adder");
    let mode = match args.get("mode", "shared").as_str() {
        "shared" => addernet::quant::Mode::SharedScale,
        "separate" => addernet::quant::Mode::SeparateScale,
        m => anyhow::bail!("unknown mode {m}"),
    };
    let n_eval = args.get_usize("eval-n", 256);

    let manifest = Manifest::load(&dir)?;
    let sarch = addernet::sim::functional::Arch::parse(&arch)
        .with_context(|| format!("arch must be one of {}", Arch::names_label()))?;
    let kind = match kernel.as_str() {
        "adder" => addernet::sim::functional::SimKernel::Adder,
        "mult" => addernet::sim::functional::SimKernel::Mult,
        k => anyhow::bail!("functional sim supports adder|mult, got {k}"),
    };
    // the per-call experiment path enforces the same kernel/width
    // policy as the plan compiler (mult tap products overflow i32 past
    // 8-bit operands) — refuse here with a proper error instead of
    // panicking inside the runner.
    anyhow::ensure!(addernet::quant::QuantPlan::supports(kind, bits),
                    "mult-kernel quantization caps at 8-bit operands \
                     (i32 accumulator overflow at int{bits}); use \
                     --kernel adder for wider grids");
    let (params, trained) = report::quantrep::load_params(&manifest, &arch, &kernel)?;
    let (calib, fp32) = report::quantrep::calibrate(&params, sarch, kind, n_eval);
    let qacc = report::quantrep::quant_accuracy(
        &params, sarch, kind, &calib,
        addernet::sim::functional::QuantCfg { bits, mode }, n_eval);
    println!("[quantize] {arch}/{kernel} trained={trained} mode={mode:?}");
    println!("  fp32 acc {fp32:.3}  int{bits} acc {qacc:.3}  delta {:+.1}pp",
             (qacc - fp32) * 100.0);
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let net_name = args.get("net", "resnet18");
    let net = nn::by_name(&net_name)
        .with_context(|| format!("unknown network {net_name}"))?;
    let kernel = match args.get("kernel", "adder").as_str() {
        "adder" => KernelKind::Adder2A,
        "adder1c1a" => KernelKind::Adder1C1A,
        "mult" => KernelKind::Mult,
        "xnor" => KernelKind::Xnor,
        k => anyhow::bail!("unknown kernel {k}"),
    };
    let dw: u32 = args.get("dw", "16").parse()?;
    let p: u64 = args.get("parallelism", "1024").parse()?;
    let cfg = AccelConfig::zcu104(p, dw, kernel);
    let res = accelerator::resources(&cfg);
    let run = accelerator::run(&cfg, &net);

    println!("[simulate] {} on {} P={p} DW={dw} kernel={}",
             net.name, cfg.device.name, kernel.label());
    println!("  network: {:.2} GOP, {:.1}M params", net.gops(),
             net.params() as f64 / 1e6);
    println!("  LUTs: compute {} + other {} = {} ({:.1}% of device)",
             res.compute_luts(), res.total() - res.compute_luts(), res.total(),
             100.0 * cfg.device.lut_utilization(res.total()));
    println!("  fmax {:.0} MHz | conv {:.0} GOPs | total {:.0} GOPs | \
              latency {:.2} ms | DRAM {:.1} MB/img",
             run.fmax_mhz, run.conv_gops(), run.total_gops(), run.latency_ms(),
             run.dram_bytes as f64 / 1e6);
    let p = &run.power;
    println!("  power: compute {:.2} + bram {:.2} + dram {:.2} + clock {:.2} \
              = {:.2} W", p.compute_w, p.bram_w, p.dram_w, p.clock_w, p.total_w());

    let mut t = Table::new("per-layer schedule (top 12 by cycles)",
                           &["layer", "ops", "compute cyc", "dma cyc", "cycles"]);
    let mut layers = run.layers.clone();
    layers.sort_by_key(|l| std::cmp::Reverse(l.cycles));
    for l in layers.iter().take(12) {
        t.row(&[l.name.clone(), l.ops.to_string(), l.compute_cycles.to_string(),
                l.dma_cycles.to_string(), l.cycles.to_string()]);
    }
    t.print();
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let dir = art_dir(args);
    match Manifest::load(&dir) {
        Ok(m) => {
            println!("artifacts: {} ({} graphs, impl={})", dir.display(),
                     m.graphs.len(), m.impl_name);
            for (name, g) in &m.graphs {
                println!("  {name:28} kind={:8} batch={}", g.kind, g.batch);
            }
        }
        Err(e) => println!("no artifacts at {} ({e}); run `make artifacts`",
                           dir.display()),
    }
    println!("\nnetworks (from the layer-graph registry):");
    for g in nn::graph::all() {
        let net = g.to_desc();
        let servable = Arch::parse(g.id).is_some();
        println!("  {:10} {:8.2} GOP {:8.1}M params{}", g.id, net.gops(),
                 net.params() as f64 / 1e6,
                 if servable { "  [servable]" } else { "" });
    }
    Ok(())
}
