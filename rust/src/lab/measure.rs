//! Measurement cores shared by the lab job runner and
//! `benches/hotpath.rs` — ONE implementation of each fixture and
//! timing loop, so "what the bench measures" and "what the lab
//! records" can never drift apart.
//!
//! Two regimes live here:
//!
//! * wall-clock medians ([`time_it`], [`LayerBench`], [`ModelBench`])
//!   — machine-bound, informational;
//! * deterministic accelerator numbers ([`hw_cycles`],
//!   [`mult_over_adder_dw16`]) — pure functions of
//!   (arch, bits, kernel, parallelism), bit-identical everywhere,
//!   which is what lets `lab diff` pin them exactly and `lab check`
//!   gate them as absolutes.

use std::time::Instant;

use anyhow::{Context, Result};

use crate::data;
use crate::hw::KernelKind;
use crate::nn;
use crate::quant::plan::QuantPlan;
use crate::quant::{Calibration, LayerCalib, Mode};
use crate::report::quantrep;
use crate::sim::accelerator::{self, AccelConfig};
use crate::sim::functional::{conv2d_quant_with, conv2d_with, synth_params,
                             Arch, ConvW, ExecMode, KernelStrategy, Params,
                             QuantCfg, Runner, SimKernel, Tensor};
use crate::sim::hwsim::{self, HwCost};
use crate::sim::intpath::PlanRunner;
use crate::util::XorShift64;

/// Time `f` `iters` times after `warmup` runs; returns
/// (median_s, mean_s).  Moved here from `benches/common` so the lab
/// and the bench share one timing loop; the bench harness delegates.
pub fn time_it<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> (f64, f64) {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = samples[samples.len() / 2];
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    (median, mean)
}

/// Shared-scale calibration of 1.0/1.0 — the layer fixture's ranges.
pub fn unit_calib() -> LayerCalib {
    LayerCalib { feat_max_abs: 1.0, weight_max_abs: 1.0 }
}

/// The hotpath bench's L3a fixture: a resnet-shape 3x3 16->16 conv on
/// a Bx32x32x16 input, weights and activations drawn from the same
/// seed-1 XorShift stream the bench has always used (at B=8 the tensor
/// bytes are bit-identical to the historical fixture).
pub struct LayerBench {
    pub x: Tensor,
    wdat: Vec<f32>,
}

impl LayerBench {
    pub fn new(batch: usize) -> LayerBench {
        let mut rng = XorShift64::new(1);
        let x = Tensor::new(
            (batch, 32, 32, 16),
            (0..batch * 32 * 32 * 16).map(|_| rng.next_f32_sym(1.0)).collect());
        let wdat: Vec<f32> =
            (0..3 * 3 * 16 * 16).map(|_| rng.next_f32_sym(1.0)).collect();
        LayerBench { x, wdat }
    }

    pub fn conv_w(&self) -> ConvW<'_> {
        ConvW { data: &self.wdat, kh: 3, kw: 3, cin: 16, cout: 16 }
    }

    /// MAC count of one forward through the fixture (for rate lines).
    pub fn macs(&self) -> f64 {
        self.x.shape.0 as f64 * 32.0 * 32.0 * 9.0 * 16.0 * 16.0
    }

    /// Median seconds of the f32 conv under `strategy`.
    pub fn time_f32(&self, strategy: KernelStrategy, kind: SimKernel,
                    warmup: usize, iters: usize) -> f64 {
        let w = self.conv_w();
        let (median, _) = time_it(warmup, iters, || {
            std::hint::black_box(conv2d_with(strategy, &self.x, &w, 1,
                                             nn::Padding::Same, kind));
        });
        median
    }

    /// Median seconds of the per-call quantized conv under `strategy`.
    pub fn time_quant(&self, strategy: KernelStrategy, kind: SimKernel,
                      cfg: QuantCfg, warmup: usize, iters: usize) -> f64 {
        let w = self.conv_w();
        let calib = unit_calib();
        let (median, _) = time_it(warmup, iters, || {
            std::hint::black_box(conv2d_quant_with(strategy, &self.x, &w, 1,
                                                   nn::Padding::Same, kind,
                                                   cfg, &calib));
        });
        median
    }
}

/// Whole-model fixture (the bench's L3a2): synthetic seed-42 params,
/// an n=32 calibration pass, and a deterministic eval batch.
pub struct ModelBench {
    pub arch: Arch,
    pub kind: SimKernel,
    params: Params,
    calib: Calibration,
    x: Tensor,
}

impl ModelBench {
    pub fn new(arch: Arch, kind: SimKernel, batch: usize) -> ModelBench {
        let params = synth_params(arch, 42);
        let (calib, _) = quantrep::calibrate(&params, arch, kind, 32);
        let (h, w, c) = arch.graph().input;
        let ev = data::eval_set(batch, 5);
        assert_eq!(ev.images.len(), batch * h * w * c,
                   "eval_set images must match the {} input shape",
                   arch.name());
        let x = Tensor::new((batch, h, w, c), ev.images);
        ModelBench { arch, kind, params, calib, x }
    }

    /// Median seconds of one f32 engine forward over the batch.
    pub fn time_f32(&self, strategy: KernelStrategy, warmup: usize,
                    iters: usize) -> f64 {
        let (median, _) = time_it(warmup, iters, || {
            let mut r = Runner {
                params: &self.params, arch: self.arch, kind: self.kind,
                strategy, mode: ExecMode::F32, calib: None, observe: None,
            };
            std::hint::black_box(r.forward(&self.x));
        });
        median
    }

    /// Median seconds of the per-call quantized path (requantizes
    /// weights every call).
    pub fn time_percall(&self, strategy: KernelStrategy, cfg: QuantCfg,
                        warmup: usize, iters: usize) -> f64 {
        let (median, _) = time_it(warmup, iters, || {
            let mut r = Runner {
                params: &self.params, arch: self.arch, kind: self.kind,
                strategy, mode: ExecMode::Quant(cfg),
                calib: Some(&self.calib), observe: None,
            };
            std::hint::black_box(r.forward(&self.x));
        });
        median
    }

    /// Compile the fixture into a serving plan at `bits`.
    pub fn plan(&self, bits: u32) -> Result<QuantPlan> {
        let cfg = QuantCfg { bits, mode: Mode::SharedScale };
        QuantPlan::build(&self.params, self.arch, self.kind, cfg, &self.calib)
            .with_context(|| format!("compiling {} {} int{bits} plan",
                                     self.arch.name(), self.kind.label()))
    }

    /// Median seconds of the compiled-plan i32 path.
    pub fn time_plan(&self, plan: &QuantPlan, strategy: KernelStrategy,
                     warmup: usize, iters: usize) -> f64 {
        let (median, _) = time_it(warmup, iters, || {
            let r = PlanRunner { plan, strategy };
            std::hint::black_box(r.forward(&self.x));
        });
        median
    }
}

/// Compile a deterministic serving plan for the hw cycle family:
/// seed-42 synthetic params, an n=16 calibration pass.  (Calibration
/// scales never reach the schedule — cycle counts depend only on the
/// layer geometry and bit width — so the sample count is just "enough
/// to build a valid plan".)
pub fn int_plan(arch: Arch, kind: SimKernel, bits: u32) -> Result<QuantPlan> {
    anyhow::ensure!(QuantPlan::supports(kind, bits),
                    "no {} plans at {bits} bits", kind.label());
    let params = synth_params(arch, 42);
    let (calib, _) = quantrep::calibrate(&params, arch, kind, 16);
    let cfg = QuantCfg { bits, mode: Mode::SharedScale };
    QuantPlan::build(&params, arch, kind, cfg, &calib)
        .with_context(|| format!("compiling {} {} int{bits} plan",
                                 arch.name(), kind.label()))
}

/// Per-image accelerator cost of the `(arch, kind, bits)` plan at
/// parallelism `p` — deterministic (pure schedule arithmetic).
pub fn hw_cycles(arch: Arch, kind: SimKernel, bits: u32, p: u64)
                 -> Result<HwCost> {
    hwsim::per_image_cost(&int_plan(arch, kind, bits)?, p)
}

/// The paper's mult-vs-adder latency penalty at the 16-bit datapath on
/// the resnet8 descriptor (where the mult critical path is the fmax
/// limiter): returns (latency ratio, mult fmax MHz, adder fmax MHz).
/// Deterministic — the accelerator model takes only the descriptor.
pub fn mult_over_adder_dw16(p: u64) -> (f64, f64, f64) {
    let desc = nn::resnet8();
    let mult = accelerator::run(&AccelConfig::zcu104(p, 16, KernelKind::Mult),
                                &desc);
    let adder = accelerator::run(&AccelConfig::zcu104(p, 16,
                                                      KernelKind::Adder2A),
                                 &desc);
    (mult.latency_ms() / adder.latency_ms(), mult.fmax_mhz, adder.fmax_mhz)
}
