//! The `.lab/` store: content-addressed, append-only run records.
//!
//! Layout:
//!
//! ```text
//! .lab/
//!   specs/{spec_hash}.json               canonical spec (written once)
//!   runs/{spec_hash}-{env_fp}-g{N}.json  immutable addernet-lab-v1 record
//! ```
//!
//! A run's identity is its spec hash plus an environment fingerprint
//! (crate version, `ADDERNET_KERNEL` resolution, pool workers, the
//! Winograd-adder opt-in) plus a generation counter.  Records are
//! NEVER overwritten: re-running the same spec in the same environment
//! dedupes to the existing record, and `--force` appends `g{N+1}`.
//! Key values serialize through Rust's shortest-roundtrip `{}` float
//! formatting, so a record read back compares bit-equal to the run
//! that wrote it — the property `lab diff` relies on to pin the
//! deterministic `hw_*` keys exactly.

use std::collections::BTreeMap;
use std::fs;
use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use super::{fnv64, gate_class, is_deterministic, GateClass};
use crate::sim::kernels::winograd;
use crate::sim::functional::KernelStrategy;
use crate::util::json::Json;
use crate::util::table::Table;
use crate::util::threads;

pub const SCHEMA: &str = "addernet-lab-v1";

/// The measurement environment a record was taken in.  Fingerprinted
/// into the run id so records from different kernel-env legs or pool
/// sizes never dedupe against each other.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct EnvInfo {
    pub version: String,
    /// `ADDERNET_KERNEL` resolution (`auto` when unset).
    pub kernel_env: String,
    pub pool_workers: usize,
    /// `exact` normally; `approx` under the `ADDERNET_WINOGRAD_ADDER`
    /// opt-in (changes which engine Winograd points exercise).
    pub winograd_adder: String,
}

impl EnvInfo {
    pub fn current() -> EnvInfo {
        EnvInfo {
            version: env!("CARGO_PKG_VERSION").to_string(),
            kernel_env: KernelStrategy::from_env().label().to_string(),
            pool_workers: threads::pool_workers(),
            winograd_adder: if winograd::adder_l1_opted_in() {
                "approx"
            } else {
                "exact"
            }.to_string(),
        }
    }

    /// 8 hex chars over the canonical field string.
    pub fn fingerprint(&self) -> String {
        let s = format!("v={};k={};t={};wa={}", self.version, self.kernel_env,
                        self.pool_workers, self.winograd_adder);
        format!("{:08x}", fnv64(s.as_bytes()) & 0xffff_ffff)
    }

    pub fn to_map(&self) -> BTreeMap<String, String> {
        BTreeMap::from([
            ("version".to_string(), self.version.clone()),
            ("kernel_env".to_string(), self.kernel_env.clone()),
            ("pool_workers".to_string(), self.pool_workers.to_string()),
            ("winograd_adder".to_string(), self.winograd_adder.clone()),
        ])
    }
}

/// One expanded sweep point's outcome line (executed or skipped).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JobLine {
    pub job: String,
    /// `ok` | `skipped`.
    pub status: String,
    /// Why a point was skipped (empty for `ok`).
    pub note: String,
}

impl JobLine {
    pub fn ok(job: String) -> JobLine {
        JobLine { job, status: "ok".to_string(), note: String::new() }
    }

    pub fn skipped(job: String, note: String) -> JobLine {
        JobLine { job, status: "skipped".to_string(), note }
    }
}

/// One immutable run record.
#[derive(Debug, Clone, PartialEq)]
pub struct RunRecord {
    pub run_id: String,
    pub spec_name: String,
    pub spec_hash: String,
    pub env_fp: String,
    pub created_unix: u64,
    pub env: BTreeMap<String, String>,
    pub jobs: Vec<JobLine>,
    pub keys: BTreeMap<String, f64>,
    /// Set on promoted baseline records: the run they were cut from.
    pub promoted_from: Option<String>,
}

/// `{spec_hash}-{env_fp}-g{N}` — the record's file stem.
pub fn run_id(spec_hash: &str, env_fp: &str, generation: u32) -> String {
    format!("{spec_hash}-{env_fp}-g{generation}")
}

/// Shortest-roundtrip float formatting — `4442` stays `4442`,
/// wall-clock medians keep every bit — so write→read→write is a fixed
/// point and deterministic keys survive the store bit-exactly.
pub fn fmt_num(v: f64) -> String {
    if v.is_finite() { format!("{v}") } else { "0".to_string() }
}

/// Compact display form (`4442`, `1.163`, `0.0031`).
pub fn fmt_val(v: f64) -> String {
    if v == v.trunc() && v.abs() < 1e12 {
        format!("{v:.0}")
    } else if v.abs() >= 0.01 {
        format!("{v:.3}")
    } else {
        format!("{v:.6}")
    }
}

fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push(' '),
            c => out.push(c),
        }
    }
    out
}

impl RunRecord {
    /// Short display id: spec-hash prefix + generation.
    pub fn short_id(&self) -> String {
        let generation = self.run_id.rsplit('-').next().unwrap_or("");
        if self.spec_hash.len() >= 8 {
            format!("{}:{generation}", &self.spec_hash[..8])
        } else {
            self.run_id.clone()
        }
    }

    pub fn jobs_ok(&self) -> usize {
        self.jobs.iter().filter(|j| j.status == "ok").count()
    }

    pub fn jobs_skipped(&self) -> usize {
        self.jobs.iter().filter(|j| j.status == "skipped").count()
    }

    /// Stable hand-assembled JSON (no serializer is vendored); keys
    /// sorted by the BTreeMaps, floats via [`fmt_num`].
    pub fn to_json(&self) -> String {
        let mut s = String::from("{\n");
        s.push_str(&format!("  \"schema\": \"{SCHEMA}\",\n"));
        s.push_str(&format!("  \"run_id\": \"{}\",\n", esc(&self.run_id)));
        s.push_str(&format!("  \"spec_name\": \"{}\",\n",
                            esc(&self.spec_name)));
        s.push_str(&format!("  \"spec_hash\": \"{}\",\n",
                            esc(&self.spec_hash)));
        s.push_str(&format!("  \"env_fp\": \"{}\",\n", esc(&self.env_fp)));
        s.push_str(&format!("  \"created_unix\": {},\n", self.created_unix));
        if let Some(p) = &self.promoted_from {
            s.push_str(&format!("  \"promoted_from\": \"{}\",\n", esc(p)));
        }
        let env: Vec<String> = self.env.iter()
            .map(|(k, v)| format!("\"{}\": \"{}\"", esc(k), esc(v)))
            .collect();
        s.push_str(&format!("  \"env\": {{{}}},\n", env.join(", ")));
        let jobs: Vec<String> = self.jobs.iter()
            .map(|j| format!(
                "    {{\"job\": \"{}\", \"status\": \"{}\", \"note\": \"{}\"}}",
                esc(&j.job), esc(&j.status), esc(&j.note)))
            .collect();
        if jobs.is_empty() {
            s.push_str("  \"jobs\": [],\n");
        } else {
            s.push_str(&format!("  \"jobs\": [\n{}\n  ],\n", jobs.join(",\n")));
        }
        let keys: Vec<String> = self.keys.iter()
            .map(|(k, v)| format!("    \"{}\": {}", esc(k), fmt_num(*v)))
            .collect();
        if keys.is_empty() {
            s.push_str("  \"keys\": {}\n");
        } else {
            s.push_str(&format!("  \"keys\": {{\n{}\n  }}\n", keys.join(",\n")));
        }
        s.push_str("}\n");
        s
    }

    pub fn from_json(text: &str) -> Result<RunRecord> {
        let j = Json::parse(text)
            .map_err(|e| anyhow::anyhow!("run record JSON: {e:?}"))?;
        let schema = j.at(&["schema"]).and_then(Json::as_str).unwrap_or("");
        anyhow::ensure!(schema == SCHEMA,
                        "run record schema {schema:?}, expected {SCHEMA:?}");
        let req_str = |key: &str| -> Result<String> {
            j.at(&[key]).and_then(Json::as_str).map(str::to_string)
                .with_context(|| format!("run record needs string {key:?}"))
        };
        let run_id = req_str("run_id")?;
        let spec_name = req_str("spec_name")?;
        let spec_hash = req_str("spec_hash")?;
        let env_fp = req_str("env_fp")?;
        let created_unix = j.at(&["created_unix"]).and_then(Json::as_f64)
            .unwrap_or(0.0) as u64;
        let promoted_from = j.at(&["promoted_from"]).and_then(Json::as_str)
            .map(str::to_string);
        let mut env = BTreeMap::new();
        if let Some(obj) = j.at(&["env"]).and_then(Json::as_obj) {
            for (k, v) in obj {
                if let Some(s) = v.as_str() {
                    env.insert(k.clone(), s.to_string());
                }
            }
        }
        let mut jobs = Vec::new();
        if let Some(arr) = j.at(&["jobs"]).and_then(Json::as_arr) {
            for e in arr {
                jobs.push(JobLine {
                    job: e.at(&["job"]).and_then(Json::as_str)
                        .unwrap_or("").to_string(),
                    status: e.at(&["status"]).and_then(Json::as_str)
                        .unwrap_or("ok").to_string(),
                    note: e.at(&["note"]).and_then(Json::as_str)
                        .unwrap_or("").to_string(),
                });
            }
        }
        let mut keys = BTreeMap::new();
        let kobj = j.at(&["keys"]).and_then(Json::as_obj)
            .context("run record needs a \"keys\" object")?;
        for (k, v) in kobj {
            let n = v.as_f64().with_context(|| {
                format!("run record key {k:?} must be a number")
            })?;
            keys.insert(k.clone(), n);
        }
        Ok(RunRecord {
            run_id, spec_name, spec_hash, env_fp, created_unix, env, jobs,
            keys, promoted_from,
        })
    }

    /// All recorded keys with their gate class and determinism flag.
    pub fn key_table(&self) -> Table {
        let mut t = Table::new(
            &format!("lab run {} (spec {})", self.run_id, self.spec_name),
            &["key", "value", "gate", "deterministic"]);
        for (k, v) in &self.keys {
            let gate = match gate_class(k) {
                GateClass::Floor => "floor",
                GateClass::Ceiling => "ceiling",
                GateClass::Info => "-",
            };
            let det = if is_deterministic(k) { "yes" } else { "-" };
            t.row(&[k.clone(), fmt_val(*v), gate.to_string(),
                    det.to_string()]);
        }
        t
    }
}

/// Filesystem store rooted at a `.lab/` directory.
pub struct Store {
    root: PathBuf,
}

impl Store {
    pub fn open(root: &Path) -> Result<Store> {
        let store = Store { root: root.to_path_buf() };
        fs::create_dir_all(store.runs_dir())
            .with_context(|| format!("creating {}",
                                     store.runs_dir().display()))?;
        fs::create_dir_all(store.specs_dir())
            .with_context(|| format!("creating {}",
                                     store.specs_dir().display()))?;
        Ok(store)
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    fn runs_dir(&self) -> PathBuf {
        self.root.join("runs")
    }

    fn specs_dir(&self) -> PathBuf {
        self.root.join("specs")
    }

    /// Write the canonical spec file if it is not already stored;
    /// returns the spec hash either way.
    pub fn put_spec(&self, spec: &super::spec::SweepSpec) -> Result<String> {
        let hash = spec.hash();
        let path = self.specs_dir().join(format!("{hash}.json"));
        if !path.exists() {
            let mut normalized = spec.clone();
            normalized.normalize();
            fs::write(&path, normalized.canonical_json() + "\n")
                .with_context(|| format!("writing {}", path.display()))?;
        }
        Ok(hash)
    }

    /// Generations already recorded for `(spec_hash, env_fp)`, sorted.
    pub fn generations(&self, spec_hash: &str, env_fp: &str)
                       -> Result<Vec<u32>> {
        let prefix = format!("{spec_hash}-{env_fp}-g");
        let mut gens = Vec::new();
        for entry in fs::read_dir(self.runs_dir())
            .with_context(|| format!("reading {}",
                                     self.runs_dir().display()))?
        {
            let name = entry?.file_name().to_string_lossy().to_string();
            if let Some(rest) = name.strip_prefix(&prefix) {
                if let Some(g) = rest.strip_suffix(".json")
                    .and_then(|x| x.parse::<u32>().ok())
                {
                    gens.push(g);
                }
            }
        }
        gens.sort_unstable();
        Ok(gens)
    }

    /// Append-only write: refuses to overwrite an existing record.
    pub fn put_run(&self, rec: &RunRecord) -> Result<PathBuf> {
        let path = self.runs_dir().join(format!("{}.json", rec.run_id));
        let mut f = fs::OpenOptions::new().write(true).create_new(true)
            .open(&path)
            .with_context(|| format!(
                "lab store is append-only — refusing to overwrite {} \
                 (use --force to record a new generation)", path.display()))?;
        f.write_all(rec.to_json().as_bytes())
            .with_context(|| format!("writing {}", path.display()))?;
        Ok(path)
    }

    pub fn load_file(path: &Path) -> Result<RunRecord> {
        let text = fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        RunRecord::from_json(&text)
            .with_context(|| format!("parsing {}", path.display()))
    }

    /// Load by exact run id or unique prefix.
    pub fn load(&self, id_or_prefix: &str) -> Result<RunRecord> {
        let exact = self.runs_dir().join(format!("{id_or_prefix}.json"));
        if exact.is_file() {
            return Self::load_file(&exact);
        }
        let mut matches = Vec::new();
        for entry in fs::read_dir(self.runs_dir())
            .with_context(|| format!("reading {}",
                                     self.runs_dir().display()))?
        {
            let entry = entry?;
            let name = entry.file_name().to_string_lossy().to_string();
            if name.starts_with(id_or_prefix) && name.ends_with(".json") {
                matches.push(entry.path());
            }
        }
        match matches.len() {
            0 => anyhow::bail!("no lab run matches {id_or_prefix:?} in {}",
                               self.root.display()),
            1 => Self::load_file(&matches[0]),
            n => {
                let mut names: Vec<String> = matches.iter()
                    .filter_map(|p| p.file_stem())
                    .map(|s| s.to_string_lossy().to_string())
                    .collect();
                names.sort();
                anyhow::bail!("{n} lab runs match {id_or_prefix:?}: {}",
                              names.join(", "))
            }
        }
    }

    /// Every record, oldest first (created_unix, then run_id).
    pub fn list(&self) -> Result<Vec<RunRecord>> {
        let mut recs = Vec::new();
        for entry in fs::read_dir(self.runs_dir())
            .with_context(|| format!("reading {}",
                                     self.runs_dir().display()))?
        {
            let path = entry?.path();
            if path.extension().and_then(|e| e.to_str()) == Some("json") {
                recs.push(Self::load_file(&path)?);
            }
        }
        recs.sort_by(|a, b| {
            a.created_unix.cmp(&b.created_unix)
                .then_with(|| a.run_id.cmp(&b.run_id))
        });
        Ok(recs)
    }

    /// The `n` most recent records, newest first.
    pub fn latest(&self, n: usize) -> Result<Vec<RunRecord>> {
        let mut recs = self.list()?;
        recs.reverse();
        recs.truncate(n);
        Ok(recs)
    }
}
