//! `repro lab` — the experiment subsystem (declarative sweeps, a
//! content-addressed result store, history-sourced CI gating).
//!
//! The bench story used to be one hand-edited `bench_baseline.json`
//! plus a transient `target/hotpath.json`.  The lab replaces that with
//! recorded history, modeled on the repx run/job/store split:
//!
//! * [`spec`] — a declarative [`spec::SweepSpec`] over arch × kernel ×
//!   strategy × mode (f32/int8/int16) × threads × batch ×
//!   hw-parallelism, with a canonical JSON form whose FNV-1a hash
//!   content-addresses the sweep (field order and dimension order never
//!   change the hash).
//! * [`job`] — expands a spec into jobs, skips the points the engine
//!   cannot express (int16 mult plans, Winograd off the int-mult path,
//!   a thread count the ambient pool does not match) with a recorded
//!   note, and executes the rest through the SAME measurement cores the
//!   hotpath bench uses ([`measure`]).
//! * [`store`] — the `.lab/` directory: `specs/{spec_hash}.json` plus
//!   immutable `runs/{spec_hash}-{env_fp}-g{N}.json` records in stable
//!   `addernet-lab-v1` JSON.  Re-running an identical spec in an
//!   identical environment dedupes to the existing record; `--force`
//!   appends a new generation; nothing ever overwrites.
//! * [`diff`] — per-key deltas between two runs (or a run and a
//!   committed baseline record), a drift gate over the deterministic
//!   keys, and the floor/ceiling check that replaces `repro bench
//!   check` in CI with history-sourced gating.
//!
//! Keys split into two regimes.  Wall-clock medians
//! (`layer_*_s`, `e2e_*_s`) vary per machine and are informational.
//! Everything prefixed `hw_` is a pure function of
//! (arch, bits, kernel, parallelism) on the simulated accelerator —
//! bit-identical across runs and machines — so `lab diff` pins those
//! exactly and `lab check` gates them as absolutes.

pub mod diff;
pub mod job;
pub mod measure;
pub mod spec;
pub mod store;

/// Default store directory (relative to the working directory, like
/// `target/`); override with `repro lab --store DIR`.
pub const DEFAULT_STORE: &str = ".lab";

/// How a key participates in `lab check` gating.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GateClass {
    /// Higher is better; fail when `current < baseline * (1 - tol)`.
    /// The speedup-ratio families (`*_vs_*`) and the accelerator's
    /// mult/adder latency ratio.
    Floor,
    /// Lower is better; fail when `current > baseline * (1 + tol)`.
    /// The deterministic `hw_cycles_*` per-image counts.
    Ceiling,
    /// Recorded but never gated: raw wall-clock medians (`*_s`) and
    /// anything else machine-specific.
    Info,
}

/// Classify a result key for gating.  This single rule reproduces the
/// curated FLOOR/CEILING lists `repro bench check` hard-codes: cycle
/// counts are ceilings, ratio keys are floors, raw medians are info.
pub fn gate_class(key: &str) -> GateClass {
    if key.starts_with("hw_cycles_") {
        GateClass::Ceiling
    } else if key == "hw_mult_over_adder_latency"
        || key.starts_with("hw_mult_over_adder_latency_p")
    {
        GateClass::Floor
    } else if !key.ends_with("_s") && key.contains("_vs_") {
        GateClass::Floor
    } else {
        GateClass::Info
    }
}

/// Keys that must be bit-identical across runs of the same spec: the
/// simulated-accelerator family.  `hwsim::per_image_cost` and
/// `accelerator::run` are pure functions of the plan schedule /
/// network descriptor — no wall clock anywhere — so two back-to-back
/// `lab run`s must agree on these exactly, and `lab diff` treats any
/// difference as drift (a nonzero exit).
pub fn is_deterministic(key: &str) -> bool {
    key.starts_with("hw_")
}

/// FNV-1a 64-bit — the store's content hash.  Stable, dependency-free,
/// and good enough for addressing a handful of spec files (this is a
/// cache key, not a security boundary).
pub fn fnv64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}
