//! Run comparison and the history-sourced CI gate.
//!
//! [`diff_records`] lines up every key of two records with per-key
//! ratio deltas; deterministic `hw_*` keys present in both that differ
//! AT ALL are drift (`repro lab diff` exits nonzero on any) — the
//! accelerator model is pure arithmetic, so inequality means the code
//! changed, not the machine.
//!
//! [`check_records`] replaces `repro bench check` in CI: the same
//! floor/ceiling semantics (floors fail below `baseline * (1 - tol)`,
//! ceilings above `baseline * (1 + tol)`), but the baseline is a
//! promoted run record instead of a hand-edited number file, and the
//! gate set is every Floor/Ceiling-classed key the baseline carries —
//! adding a gated key to the baseline is all it takes to gate it.

use anyhow::Result;

use super::store::{fmt_val, RunRecord};
use super::{gate_class, is_deterministic, GateClass};
use crate::util::table::{f, Table};

/// One key lined up across two records.
#[derive(Debug, Clone)]
pub struct DiffRow {
    pub key: String,
    pub a: Option<f64>,
    pub b: Option<f64>,
    pub deterministic: bool,
}

#[derive(Debug, Clone)]
pub struct DiffReport {
    pub rows: Vec<DiffRow>,
}

impl DiffReport {
    /// Deterministic keys present in both records with unequal values
    /// — bitwise inequality, no tolerance.
    pub fn drift(&self) -> Vec<&DiffRow> {
        self.rows.iter()
            .filter(|r| {
                r.deterministic
                    && matches!((r.a, r.b), (Some(x), Some(y)) if x != y)
            })
            .collect()
    }

    pub fn table(&self, label_a: &str, label_b: &str) -> Table {
        let mut t = Table::new(
            &format!("lab diff: {label_a} -> {label_b}"),
            &["key", label_a, label_b, "delta", "status"]);
        for r in &self.rows {
            let cell = |v: Option<f64>| {
                v.map_or_else(|| "-".to_string(), fmt_val)
            };
            let (delta, status) = match (r.a, r.b) {
                (Some(x), Some(y)) => {
                    let delta = if x != 0.0 {
                        format!("{:+.1}%", (y - x) / x * 100.0)
                    } else {
                        "-".to_string()
                    };
                    let status = if x == y {
                        "="
                    } else if r.deterministic {
                        "DRIFT"
                    } else {
                        "~"
                    };
                    (delta, status)
                }
                (Some(_), None) => ("-".to_string(), "only left"),
                (None, Some(_)) => ("-".to_string(), "only right"),
                (None, None) => ("-".to_string(), "-"),
            };
            t.row(&[r.key.clone(), cell(r.a), cell(r.b), delta,
                    status.to_string()]);
        }
        t
    }
}

/// Line up every key of `a` and `b`.
pub fn diff_records(a: &RunRecord, b: &RunRecord) -> DiffReport {
    let mut names: Vec<&String> = a.keys.keys().chain(b.keys.keys()).collect();
    names.sort();
    names.dedup();
    let rows = names.into_iter()
        .map(|k| DiffRow {
            key: k.clone(),
            a: a.keys.get(k).copied(),
            b: b.keys.get(k).copied(),
            deterministic: is_deterministic(k),
        })
        .collect();
    DiffReport { rows }
}

/// The CI gate: every Floor/Ceiling key the baseline carries must be
/// present in `current` and inside its tolerance band.  Returns the
/// render table, the failure list, and the gated-key count; a missing
/// gated key is a hard error (a spec that silently stopped measuring a
/// gated quantity must not pass green).
pub fn check_records(current: &RunRecord, baseline: &RunRecord, tol: f64)
                     -> Result<(Table, Vec<String>, usize)> {
    anyhow::ensure!((0.0..1.0).contains(&tol),
                    "--tolerance takes a fraction in [0, 1)");
    let mut t = Table::new(
        &format!("lab history gate (tolerance {:.0}%, baseline {})",
                 tol * 100.0, baseline.run_id),
        &["gated key", "baseline", "bound", "current", "status"]);
    let mut failed = Vec::new();
    let mut gated = 0usize;
    for (key, &b) in &baseline.keys {
        let class = gate_class(key);
        if class == GateClass::Info {
            continue;
        }
        gated += 1;
        let c = current.keys.get(key).copied().ok_or_else(|| {
            anyhow::anyhow!(
                "run {} lacks gated baseline key {key} (did the sweep spec \
                 drop a measurement family?)", current.run_id)
        })?;
        let (bound, ok, decimals) = match class {
            GateClass::Floor => (b * (1.0 - tol), c >= b * (1.0 - tol), 2),
            GateClass::Ceiling => (b * (1.0 + tol), c <= b * (1.0 + tol), 0),
            GateClass::Info => unreachable!(),
        };
        t.row(&[key.clone(), f(b, decimals), f(bound, decimals),
                f(c, decimals),
                if ok { "ok" } else { "REGRESSED" }.to_string()]);
        if !ok {
            let dir = if class == GateClass::Floor { "<" } else { ">" };
            failed.push(format!("{key}: {c:.3} {dir} bound {bound:.3}"));
        }
    }
    anyhow::ensure!(gated > 0, "baseline {} carries no gated keys",
                    baseline.run_id);
    Ok((t, failed, gated))
}

/// Cut a baseline record from a run: the Floor/Ceiling keys only
/// (or everything with `all_keys`), jobs dropped, provenance kept in
/// `promoted_from`.  Committing the result is "promoting the run".
pub fn promote(run: &RunRecord, all_keys: bool) -> RunRecord {
    let keys = run.keys.iter()
        .filter(|(k, _)| all_keys || gate_class(k) != GateClass::Info)
        .map(|(k, &v)| (k.clone(), v))
        .collect();
    RunRecord {
        run_id: format!("baseline-{}", run.run_id),
        spec_name: run.spec_name.clone(),
        spec_hash: run.spec_hash.clone(),
        env_fp: run.env_fp.clone(),
        created_unix: run.created_unix,
        env: run.env.clone(),
        jobs: Vec::new(),
        keys,
        promoted_from: Some(run.run_id.clone()),
    }
}
