//! Spec expansion and execution: turn a [`SweepSpec`] into jobs, run
//! the expressible ones through the shared measurement cores, record
//! skips with a note, derive the gateable ratio keys, and persist the
//! record.
//!
//! Validity rules (each skip carries its reason into the record):
//!
//! * int16 × mult — per-call/plan quantization caps mult operands at
//!   8 bits ([`QuantPlan::supports`]), so the point has no engine.
//! * Winograd off the (int, mult) path — the transform-domain engine
//!   is exact only on integer mult convs; everywhere else the resolver
//!   falls back to the row kernels, so the measurement would duplicate
//!   the Auto row and be recorded under a misleading key.
//! * a non-ambient thread count — the engine pool is process-wide and
//!   spawned once (`ADDERNET_THREADS`), so a spec cannot re-size it
//!   mid-process; the point is skipped with a how-to-rerun note.

use std::collections::BTreeMap;
use std::time::{Duration, SystemTime, UNIX_EPOCH};

use anyhow::Result;

use super::measure;
use super::spec::{LabMode, SweepSpec};
use super::store::{run_id, EnvInfo, JobLine, RunRecord, Store};
use crate::coordinator::loadtest::{self, LoadtestCfg};
use crate::coordinator::server::{self, FunctionalVariantCfg};
use crate::quant::plan::QuantPlan;
use crate::quant::Mode;
use crate::report::quantrep;
use crate::sim::functional::{Arch, KernelStrategy, QuantCfg, SimKernel};
use crate::sim::hwsim;
use crate::util::threads;

/// What `run_spec` did.
pub enum RunOutcome {
    /// An identical (spec, env) record already existed; no measurement
    /// ran.
    Deduped(RunRecord),
    /// A fresh record was measured and persisted.
    Ran(RunRecord),
}

impl RunOutcome {
    pub fn record(&self) -> &RunRecord {
        match self {
            RunOutcome::Deduped(r) | RunOutcome::Ran(r) => r,
        }
    }
}

/// Execute `spec` against `store`.  Without `force`, an existing
/// record for the same (spec hash, env fingerprint) is returned as-is
/// — the dedupe that makes re-running a committed sweep free; with
/// `force`, a new generation is measured and appended.
pub fn run_spec(store: &Store, spec: &SweepSpec, force: bool)
                -> Result<RunOutcome> {
    let mut spec = spec.clone();
    spec.normalize();
    spec.validate()?;
    let spec_hash = spec.hash();
    let env = EnvInfo::current();
    let env_fp = env.fingerprint();
    let gens = store.generations(&spec_hash, &env_fp)?;
    if !force {
        if let Some(&g) = gens.last() {
            let id = run_id(&spec_hash, &env_fp, g);
            return Ok(RunOutcome::Deduped(store.load(&id)?));
        }
    }
    let generation = gens.last().copied().unwrap_or(0) + 1;
    let id = run_id(&spec_hash, &env_fp, generation);
    store.put_spec(&spec)?;
    println!("[lab] run {id} (spec {}, hash {spec_hash})", spec.name);

    let mut keys = BTreeMap::new();
    let mut jobs = Vec::new();

    // The pool dimension gates the whole wall-clock run: points asking
    // for a worker count the ambient pool doesn't have are skipped —
    // never silently measured on the wrong pool.
    let ambient = threads::pool_workers().max(1);
    let mut threads_ok = false;
    for &t in &spec.threads {
        if t == 0 || t == ambient {
            threads_ok = true;
        } else {
            jobs.push(JobLine::skipped(
                format!("threads {t}"),
                format!("engine pool has {ambient} workers (process-wide); \
                         set ADDERNET_THREADS={t} and re-run")));
        }
    }
    if threads_ok {
        run_layer_family(&spec, &mut keys, &mut jobs);
        run_model_family(&spec, &mut keys, &mut jobs);
        run_hw_family(&spec, &mut keys, &mut jobs)?;
        run_loadtest_family(&spec, &mut keys, &mut jobs)?;
        derive_keys(&spec, &mut keys);
    }

    let created_unix = SystemTime::now().duration_since(UNIX_EPOCH)
        .map(|d| d.as_secs()).unwrap_or(0);
    let rec = RunRecord {
        run_id: id,
        spec_name: spec.name.clone(),
        spec_hash,
        env_fp,
        created_unix,
        env: env.to_map(),
        jobs,
        keys,
        promoted_from: None,
    };
    store.put_run(&rec)?;
    Ok(RunOutcome::Ran(rec))
}

fn insert_key(keys: &mut BTreeMap<String, f64>, key: String, v: f64) {
    if v.is_finite() {
        keys.insert(key, v);
    } else {
        eprintln!("[lab] dropping non-finite value for key {key}");
    }
}

/// Winograd layer points are only distinct from the Auto row kernels
/// on integer mult convs (where the transform-domain engine is exact).
fn winograd_distinct(mode: LabMode, kind: SimKernel) -> bool {
    kind == SimKernel::Mult && mode.bits().is_some()
}

fn run_layer_family(spec: &SweepSpec, keys: &mut BTreeMap<String, f64>,
                    jobs: &mut Vec<JobLine>) {
    if !spec.measure.layer {
        return;
    }
    for &batch in &spec.batches {
        let lb = measure::LayerBench::new(batch);
        for &mode in &spec.modes {
            for &kind in &spec.kernels {
                for &strat in &spec.strategies {
                    let job = format!("layer {} {} {} b{batch}", mode.label(),
                                      kind.label(), strat.label());
                    if let Some(bits) = mode.bits() {
                        if !QuantPlan::supports(kind, bits) {
                            jobs.push(JobLine::skipped(
                                job,
                                format!("{} quantization caps at 8-bit \
                                         operands", kind.label())));
                            continue;
                        }
                    }
                    if strat == KernelStrategy::Winograd
                        && !winograd_distinct(mode, kind)
                    {
                        jobs.push(JobLine::skipped(
                            job,
                            "winograd resolves to the row fallback here — \
                             the point duplicates the auto row kernel"
                                .to_string()));
                        continue;
                    }
                    // the naive oracle is slow — fewer iterations, like
                    // the bench has always done
                    let (warmup, iters) =
                        if strat == KernelStrategy::Naive { (1, 5) } else { (2, 9) };
                    let s = match mode.bits() {
                        None => lb.time_f32(strat, kind, warmup, iters),
                        Some(bits) => {
                            let cfg = QuantCfg { bits, mode: Mode::SharedScale };
                            lb.time_quant(strat, kind, cfg, warmup, iters)
                        }
                    };
                    println!("[lab]   {job}: {:.3} ms", s * 1e3);
                    insert_key(keys,
                               format!("layer_{}_{}_{}_b{batch}_s",
                                       mode.label(), kind.label(),
                                       strat.label()),
                               s);
                    jobs.push(JobLine::ok(job));
                }
            }
        }
    }
}

fn run_model_family(spec: &SweepSpec, keys: &mut BTreeMap<String, f64>,
                    jobs: &mut Vec<JobLine>) {
    if !spec.measure.model {
        return;
    }
    for &arch in &spec.model_archs {
        for &kind in &spec.kernels {
            let mut mb: Option<measure::ModelBench> = None;
            for &mode in &spec.modes {
                let job = format!("model {} {} {} b{}", arch.name(),
                                  kind.label(), mode.label(), spec.model_batch);
                match mode.bits() {
                    None => {
                        let b = mb.get_or_insert_with(|| {
                            measure::ModelBench::new(arch, kind,
                                                     spec.model_batch)
                        });
                        let s = b.time_f32(KernelStrategy::Auto, 1, 7);
                        println!("[lab]   {job}: {:.3} ms", s * 1e3);
                        insert_key(keys,
                                   format!("e2e_f32_{}_{}_s", arch.name(),
                                           kind.label()),
                                   s);
                        jobs.push(JobLine::ok(job));
                    }
                    Some(bits) => {
                        if !QuantPlan::supports(kind, bits) {
                            jobs.push(JobLine::skipped(
                                job,
                                format!("{} quantization caps at 8-bit \
                                         operands", kind.label())));
                            continue;
                        }
                        let b = mb.get_or_insert_with(|| {
                            measure::ModelBench::new(arch, kind,
                                                     spec.model_batch)
                        });
                        let cfg = QuantCfg { bits, mode: Mode::SharedScale };
                        let percall =
                            b.time_percall(KernelStrategy::Auto, cfg, 1, 7);
                        let plan = match b.plan(bits) {
                            Ok(p) => p,
                            Err(e) => {
                                jobs.push(JobLine::skipped(
                                    job, format!("plan build failed: {e:#}")));
                                continue;
                            }
                        };
                        let plan_s =
                            b.time_plan(&plan, KernelStrategy::Auto, 1, 7);
                        println!("[lab]   {job}: percall {:.3} ms, plan \
                                  {:.3} ms", percall * 1e3, plan_s * 1e3);
                        let stem = format!("{}_{}_int{bits}", arch.name(),
                                           kind.label());
                        insert_key(keys, format!("e2e_percall_{stem}_s"),
                                   percall);
                        insert_key(keys, format!("e2e_plan_{stem}_s"), plan_s);
                        jobs.push(JobLine::ok(job));
                    }
                }
            }
        }
    }
}

/// Key name for a hw cycle count.  At the default parallelism the name
/// matches the historical bench contract (`hw_cycles_lenet5_int8`,
/// `hw_cycles_resnet8_mult_int8`); other P get a `_p{P}` suffix.
fn hw_cycles_key(arch: Arch, kind: SimKernel, bits: u32, p: u64) -> String {
    let kind_tag = match kind {
        SimKernel::Adder => String::new(),
        SimKernel::Mult => "_mult".to_string(),
    };
    let p_tag = if p == hwsim::DEFAULT_PARALLELISM {
        String::new()
    } else {
        format!("_p{p}")
    };
    format!("hw_cycles_{}{kind_tag}_int{bits}{p_tag}", arch.name())
}

fn run_hw_family(spec: &SweepSpec, keys: &mut BTreeMap<String, f64>,
                 jobs: &mut Vec<JobLine>) -> Result<()> {
    if spec.measure.hw {
        for &p in &spec.hw_parallelism {
            for &arch in &spec.archs {
                for &kind in &spec.kernels {
                    for &mode in &spec.modes {
                        // hw points exist only where a plan quantizes
                        let Some(bits) = mode.bits() else { continue };
                        let job = format!("hw {} {} int{bits} p{p}",
                                          arch.name(), kind.label());
                        if !QuantPlan::supports(kind, bits) {
                            jobs.push(JobLine::skipped(
                                job,
                                format!("no {} plans at {bits} bits",
                                        kind.label())));
                            continue;
                        }
                        // a failing plan build here is a bug, not a
                        // skip: the hw keys are the CI gate's spine
                        let cost = measure::hw_cycles(arch, kind, bits, p)?;
                        println!("[lab]   {job}: {} cycles/img", cost.cycles);
                        insert_key(keys, hw_cycles_key(arch, kind, bits, p),
                                   cost.cycles as f64);
                        jobs.push(JobLine::ok(job));
                    }
                }
            }
        }
    }
    if spec.measure.ratio_dw16 {
        for &p in &spec.hw_parallelism {
            let job = format!("hw dw16 mult/adder ratio p{p}");
            let (ratio, mult_fmax, adder_fmax) =
                measure::mult_over_adder_dw16(p);
            println!("[lab]   {job}: {ratio:.3}x (mult fmax {mult_fmax:.0} \
                      MHz vs adder {adder_fmax:.0} MHz)");
            let key = if p == hwsim::DEFAULT_PARALLELISM {
                "hw_mult_over_adder_latency".to_string()
            } else {
                format!("hw_mult_over_adder_latency_p{p}")
            };
            insert_key(keys, key, ratio);
            jobs.push(JobLine::ok(job));
        }
    }
    Ok(())
}

fn run_loadtest_family(spec: &SweepSpec, keys: &mut BTreeMap<String, f64>,
                       jobs: &mut Vec<JobLine>) -> Result<()> {
    let Some(lt) = spec.loadtest else { return Ok(()) };
    for &arch in &spec.model_archs {
        for &kind in &spec.kernels {
            for &mode in &spec.modes {
                let name = format!("{}_{}", arch.name(), kind.label());
                let job = format!("loadtest {name} {} qps{}", mode.label(),
                                  lt.qps);
                let mut cfg = FunctionalVariantCfg::synthetic(
                    &name, arch, kind, 42);
                if let Some(bits) = mode.bits() {
                    if !QuantPlan::supports(kind, bits) {
                        jobs.push(JobLine::skipped(
                            job,
                            format!("{} quantization caps at 8-bit operands",
                                    kind.label())));
                        continue;
                    }
                    let (calib, _) =
                        quantrep::calibrate(&cfg.params, arch, kind, 64);
                    cfg.mode = crate::sim::functional::ExecMode::Quant(
                        QuantCfg { bits, mode: Mode::SharedScale });
                    cfg.calib = Some(calib);
                }
                let handle = server::start_functional(
                    vec![cfg], Duration::from_millis(2))?;
                let rep = loadtest::run(&handle, &[name.clone()],
                                        &LoadtestCfg {
                                            qps: lt.qps,
                                            duration: Duration::from_millis(
                                                lt.duration_ms),
                                            replicas: 1,
                                        })?;
                handle.shutdown();
                let o = &rep.variants[&name];
                let stem = format!("lt_{name}_{}", mode.label());
                println!("[lab]   {job}: p50 {}us p99 {}us shed {:.3}",
                         o.lat.quantile_us(0.5), o.lat.quantile_us(0.99),
                         o.shed_rate());
                insert_key(keys, format!("{stem}_p50_us"),
                           o.lat.quantile_us(0.5) as f64);
                insert_key(keys, format!("{stem}_p99_us"),
                           o.lat.quantile_us(0.99) as f64);
                insert_key(keys, format!("{stem}_shed_rate"), o.shed_rate());
                jobs.push(JobLine::ok(job));
            }
        }
    }
    Ok(())
}

/// Compute the gateable ratio keys from the recorded medians — the
/// same derivations the hotpath bench publishes, under the same
/// historical names (`winograd_vs_simd`, `plan_vs_f32`, ...), so the
/// committed gate values carry over unchanged.  Ratios use the spec's
/// first (smallest) batch for layer keys and the model_batch anchors
/// for the e2e keys.
fn derive_keys(spec: &SweepSpec, keys: &mut BTreeMap<String, f64>) {
    let mut derived: Vec<(String, f64)> = Vec::new();
    if let Some(&b0) = spec.batches.first() {
        for &mode in &spec.modes {
            for &kind in &spec.kernels {
                let get = |strategy: &str| -> Option<f64> {
                    keys.get(&format!("layer_{}_{}_{strategy}_b{b0}_s",
                                      mode.label(), kind.label()))
                        .copied()
                };
                let stem = format!("{}_{}", mode.label(), kind.label());
                if let (Some(naive), Some(tiled)) = (get("naive"), get("tiled"))
                {
                    derived.push((format!("{stem}_tiled_vs_naive"),
                                  naive / tiled));
                }
                if let (Some(tiled), Some(simd)) = (get("tiled"), get("simd"))
                {
                    derived.push((format!("{stem}_simd_vs_tiled"),
                                  tiled / simd));
                }
                if mode == LabMode::Int8 && kind == SimKernel::Mult {
                    if let (Some(simd), Some(wino)) =
                        (get("simd"), get("winograd"))
                    {
                        derived.push(("winograd_vs_simd".to_string(),
                                      simd / wino));
                    }
                }
            }
        }
    }
    // whole-model anchor: the lenet5 adder trio under its historical
    // unqualified names
    let e2e = |k: &str| keys.get(k).copied();
    if let (Some(f32_s), Some(plan_s)) = (e2e("e2e_f32_lenet5_adder_s"),
                                          e2e("e2e_plan_lenet5_adder_int8_s"))
    {
        derived.push(("plan_vs_f32".to_string(), f32_s / plan_s));
    }
    if let (Some(percall_s), Some(plan_s)) =
        (e2e("e2e_percall_lenet5_adder_int8_s"),
         e2e("e2e_plan_lenet5_adder_int8_s"))
    {
        derived.push(("plan_vs_percall".to_string(), percall_s / plan_s));
    }
    for (k, v) in derived {
        insert_key(keys, k, v);
    }
}
