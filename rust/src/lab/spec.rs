//! Declarative sweep specs and their content hash.
//!
//! A [`SweepSpec`] names the cartesian dimensions of an experiment
//! (arch × kernel × strategy × mode × threads × batch ×
//! hw-parallelism) plus which measurement families to run.  Specs
//! normalize to a canonical single-line JSON form — dimensions sorted
//! into a fixed enum order and deduped — and the FNV-1a hash of that
//! form is the spec's identity in the store: permuting fields or
//! dimension entries in a spec file can never mint a new run lineage.

use std::fs;

use anyhow::{Context, Result};

use super::fnv64;
use crate::sim::functional::{Arch, KernelStrategy, SimKernel};
use crate::util::json::Json;

pub const SPEC_SCHEMA: &str = "addernet-lab-spec-v1";

/// Numeric execution mode of a sweep point.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LabMode {
    F32,
    Int8,
    Int16,
}

impl LabMode {
    pub const ALL: [LabMode; 3] = [LabMode::F32, LabMode::Int8, LabMode::Int16];

    pub fn label(self) -> &'static str {
        match self {
            LabMode::F32 => "f32",
            LabMode::Int8 => "int8",
            LabMode::Int16 => "int16",
        }
    }

    pub fn parse(s: &str) -> Option<LabMode> {
        match s.trim().to_ascii_lowercase().as_str() {
            "f32" => Some(LabMode::F32),
            "int8" => Some(LabMode::Int8),
            "int16" => Some(LabMode::Int16),
            _ => None,
        }
    }

    /// Quantized bit width; `None` for f32.
    pub fn bits(self) -> Option<u32> {
        match self {
            LabMode::F32 => None,
            LabMode::Int8 => Some(8),
            LabMode::Int16 => Some(16),
        }
    }
}

/// Which measurement families a sweep runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Measure {
    /// Per-strategy wall-clock on the resnet-shape conv layer
    /// (the hotpath bench's L3a fixture).
    pub layer: bool,
    /// Whole-model f32 / per-call / compiled-plan forward medians.
    pub model: bool,
    /// Deterministic hwsim per-image cycle counts per (arch, kernel).
    pub hw: bool,
    /// The dw16 mult-over-adder latency ratio on the resnet8
    /// descriptor (deterministic; the paper's ~1.16x headline).
    pub ratio_dw16: bool,
}

/// Optional open-loop loadtest point (off in the builtin CI specs —
/// serving latency under load is wall-clock and machine-bound).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LoadPoint {
    pub qps: f64,
    pub duration_ms: u64,
}

/// A declarative sweep: dimensions + measurement families.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepSpec {
    pub name: String,
    /// Archs for the hw cycle family.
    pub archs: Vec<Arch>,
    /// Archs for the (slow) whole-model and loadtest families.
    pub model_archs: Vec<Arch>,
    pub kernels: Vec<SimKernel>,
    pub strategies: Vec<KernelStrategy>,
    pub modes: Vec<LabMode>,
    /// Engine-pool worker counts; `0` means "whatever the ambient
    /// `ADDERNET_THREADS` pool has".  The pool is process-wide and
    /// spawned once, so non-ambient counts become skipped jobs with a
    /// note rather than silently mismeasured points.
    pub threads: Vec<usize>,
    /// Layer-fixture batch sizes.
    pub batches: Vec<usize>,
    /// Accelerator parallelism P for the hw families.
    pub hw_parallelism: Vec<u64>,
    /// Batch for the whole-model family (one value: e2e medians are
    /// only comparable at a fixed batch).
    pub model_batch: usize,
    pub measure: Measure,
    pub loadtest: Option<LoadPoint>,
}

impl SweepSpec {
    pub const BUILTINS: &'static [&'static str] = &["ci-sweep", "ci-smoke"];

    /// The CI bench sweep: everything the retired `cargo bench` +
    /// `repro bench check` pipeline measured and gated, as one spec —
    /// layer trios at B=8, the lenet5 whole-model anchor at B=64, hw
    /// cycles for lenet5/cnv6/resnet8 on both kernels, and the dw16
    /// ratio.
    fn ci_sweep() -> SweepSpec {
        SweepSpec {
            name: "ci-sweep".to_string(),
            archs: vec![Arch::Lenet5, Arch::Cnv6, Arch::Resnet8],
            model_archs: vec![Arch::Lenet5],
            kernels: vec![SimKernel::Adder, SimKernel::Mult],
            strategies: vec![KernelStrategy::Naive, KernelStrategy::Tiled,
                             KernelStrategy::Simd, KernelStrategy::Winograd],
            modes: vec![LabMode::F32, LabMode::Int8],
            threads: vec![0],
            batches: vec![8],
            hw_parallelism: vec![1024],
            model_batch: 64,
            measure: Measure { layer: true, model: true, hw: true,
                               ratio_dw16: true },
            loadtest: None,
        }
    }

    /// Deterministic-only smoke: hw cycles + the dw16 ratio, no wall
    /// clocks.  Two back-to-back runs of this spec must `lab diff`
    /// clean bit-for-bit — the f32 CI leg pins exactly that.
    fn ci_smoke() -> SweepSpec {
        SweepSpec {
            name: "ci-smoke".to_string(),
            archs: vec![Arch::Lenet5, Arch::Resnet8],
            model_archs: vec![],
            kernels: vec![SimKernel::Adder, SimKernel::Mult],
            strategies: vec![],
            modes: vec![LabMode::Int8],
            threads: vec![0],
            batches: vec![8],
            hw_parallelism: vec![1024],
            model_batch: 64,
            measure: Measure { layer: false, model: false, hw: true,
                               ratio_dw16: true },
            loadtest: None,
        }
    }

    pub fn builtin(name: &str) -> Option<SweepSpec> {
        match name {
            "ci-sweep" => Some(Self::ci_sweep()),
            "ci-smoke" => Some(Self::ci_smoke()),
            _ => None,
        }
    }

    /// Resolve a `--spec` argument: builtin name first, else a spec
    /// JSON file path.
    pub fn resolve(arg: &str) -> Result<SweepSpec> {
        if let Some(s) = Self::builtin(arg) {
            return Ok(s);
        }
        let text = fs::read_to_string(arg).with_context(|| {
            format!("reading sweep spec {arg} (builtin specs: {})",
                    Self::BUILTINS.join(", "))
        })?;
        Self::from_json(&text)
            .with_context(|| format!("parsing sweep spec {arg}"))
    }

    /// Sort every dimension into its canonical enum order and dedupe.
    /// Hashing normalizes first, so `["mult","adder"]` and
    /// `["adder","mult"]` are the same spec.
    pub fn normalize(&mut self) {
        fn canon<T: Copy + PartialEq>(v: &mut Vec<T>, rank: impl Fn(T) -> usize) {
            v.sort_by_key(|&x| rank(x));
            v.dedup();
        }
        canon(&mut self.archs, arch_rank);
        canon(&mut self.model_archs, arch_rank);
        canon(&mut self.kernels, |k| match k {
            SimKernel::Adder => 0,
            SimKernel::Mult => 1,
        });
        canon(&mut self.strategies, strategy_rank);
        canon(&mut self.modes, |m| match m {
            LabMode::F32 => 0,
            LabMode::Int8 => 1,
            LabMode::Int16 => 2,
        });
        self.threads.sort_unstable();
        self.threads.dedup();
        self.batches.sort_unstable();
        self.batches.dedup();
        self.hw_parallelism.sort_unstable();
        self.hw_parallelism.dedup();
    }

    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(!self.name.is_empty(), "spec needs a name");
        anyhow::ensure!(
            self.name.chars()
                .all(|c| c.is_ascii_lowercase() || c.is_ascii_digit()
                     || c == '-' || c == '_'),
            "spec name {:?} must be [a-z0-9_-]", self.name);
        let m = &self.measure;
        anyhow::ensure!(
            m.layer || m.model || m.hw || m.ratio_dw16 || self.loadtest.is_some(),
            "spec {} enables no measurement family", self.name);
        anyhow::ensure!(!self.threads.is_empty(),
                        "spec {} needs a threads dimension (0 = ambient pool)",
                        self.name);
        if m.layer {
            anyhow::ensure!(
                !self.modes.is_empty() && !self.kernels.is_empty()
                    && !self.strategies.is_empty() && !self.batches.is_empty(),
                "spec {}: the layer family needs modes, kernels, strategies \
                 and batches", self.name);
            anyhow::ensure!(self.batches.iter().all(|&b| b >= 1),
                            "spec {}: batches must be >= 1", self.name);
        }
        if m.model || self.loadtest.is_some() {
            anyhow::ensure!(
                !self.model_archs.is_empty() && !self.kernels.is_empty()
                    && !self.modes.is_empty(),
                "spec {}: the model/loadtest families need model_archs, \
                 kernels and modes", self.name);
            anyhow::ensure!(self.model_batch >= 1,
                            "spec {}: model_batch must be >= 1", self.name);
        }
        if m.hw {
            anyhow::ensure!(
                !self.archs.is_empty() && !self.kernels.is_empty(),
                "spec {}: the hw family needs archs and kernels", self.name);
            anyhow::ensure!(
                self.modes.iter().any(|m| m.bits().is_some()),
                "spec {}: the hw family needs an int mode (plans quantize)",
                self.name);
        }
        if m.hw || m.ratio_dw16 {
            anyhow::ensure!(
                !self.hw_parallelism.is_empty()
                    && self.hw_parallelism.iter().all(|&p| p >= 1),
                "spec {}: the hw families need hw_parallelism >= 1", self.name);
        }
        if let Some(lt) = &self.loadtest {
            anyhow::ensure!(lt.qps > 0.0 && lt.duration_ms >= 1,
                            "spec {}: loadtest needs qps > 0 and duration_ms \
                             >= 1", self.name);
        }
        Ok(())
    }

    /// Canonical single-line JSON — the hash input AND the stored spec
    /// file.  Field order is fixed here; `normalize` fixes dimension
    /// order; together they make the hash insensitive to how a spec
    /// file was typed.
    pub fn canonical_json(&self) -> String {
        fn strs(items: &[&str]) -> String {
            let quoted: Vec<String> =
                items.iter().map(|s| format!("\"{s}\"")).collect();
            format!("[{}]", quoted.join(","))
        }
        fn nums<T: std::fmt::Display>(items: &[T]) -> String {
            let printed: Vec<String> =
                items.iter().map(|n| n.to_string()).collect();
            format!("[{}]", printed.join(","))
        }
        let archs: Vec<&str> = self.archs.iter().map(|a| a.name()).collect();
        let march: Vec<&str> =
            self.model_archs.iter().map(|a| a.name()).collect();
        let kernels: Vec<&str> =
            self.kernels.iter().map(|k| k.label()).collect();
        let strats: Vec<&str> =
            self.strategies.iter().map(|s| s.label()).collect();
        let modes: Vec<&str> = self.modes.iter().map(|m| m.label()).collect();
        let lt = match &self.loadtest {
            None => "null".to_string(),
            Some(l) => format!("{{\"qps\":{},\"duration_ms\":{}}}",
                               l.qps, l.duration_ms),
        };
        format!(
            "{{\"schema\":\"{SPEC_SCHEMA}\",\"name\":\"{}\",\
             \"archs\":{},\"model_archs\":{},\"kernels\":{},\
             \"strategies\":{},\"modes\":{},\"threads\":{},\"batches\":{},\
             \"hw_parallelism\":{},\"model_batch\":{},\
             \"measure\":{{\"layer\":{},\"model\":{},\"hw\":{},\
             \"ratio_dw16\":{}}},\"loadtest\":{}}}",
            self.name, strs(&archs), strs(&march), strs(&kernels),
            strs(&strats), strs(&modes), nums(&self.threads),
            nums(&self.batches), nums(&self.hw_parallelism), self.model_batch,
            self.measure.layer, self.measure.model, self.measure.hw,
            self.measure.ratio_dw16, lt)
    }

    /// Content hash: 16 hex chars of FNV-1a over the normalized
    /// canonical JSON.
    pub fn hash(&self) -> String {
        let mut c = self.clone();
        c.normalize();
        format!("{:016x}", fnv64(c.canonical_json().as_bytes()))
    }

    /// Parse a spec from JSON (the canonical form or any field order).
    /// Unlisted dimensions default to the canonical CI shape: ambient
    /// threads, B=8 layer fixture, P=1024, B=64 whole-model.
    pub fn from_json(text: &str) -> Result<SweepSpec> {
        let j = Json::parse(text)
            .map_err(|e| anyhow::anyhow!("spec JSON: {e:?}"))?;
        let schema = j.at(&["schema"]).and_then(Json::as_str).unwrap_or("");
        anyhow::ensure!(schema == SPEC_SCHEMA,
                        "spec schema {schema:?}, expected {SPEC_SCHEMA:?}");
        let name = j.at(&["name"]).and_then(Json::as_str)
            .context("spec needs a \"name\"")?
            .to_string();
        let parse_list = |key: &str| -> Result<Vec<String>> {
            match j.at(&[key]) {
                None => Ok(Vec::new()),
                Some(v) => {
                    let arr = v.as_arr().with_context(|| {
                        format!("spec field {key:?} must be an array")
                    })?;
                    arr.iter()
                        .map(|e| {
                            e.as_str().map(str::to_string).with_context(|| {
                                format!("spec field {key:?} must hold strings")
                            })
                        })
                        .collect()
                }
            }
        };
        let archs = parse_list("archs")?.iter()
            .map(|s| Arch::parse(s).with_context(|| {
                format!("unknown arch {s:?} (expected {})", Arch::names_label())
            }))
            .collect::<Result<Vec<_>>>()?;
        let model_archs = match j.at(&["model_archs"]) {
            None => archs.clone(),
            Some(_) => parse_list("model_archs")?.iter()
                .map(|s| Arch::parse(s).with_context(|| {
                    format!("unknown model arch {s:?}")
                }))
                .collect::<Result<Vec<_>>>()?,
        };
        let kernels = parse_list("kernels")?.iter()
            .map(|s| SimKernel::parse(s)
                .with_context(|| format!("unknown kernel {s:?} (adder|mult)")))
            .collect::<Result<Vec<_>>>()?;
        let strategies = parse_list("strategies")?.iter()
            .map(|s| KernelStrategy::parse(s)
                .with_context(|| format!("unknown strategy {s:?}")))
            .collect::<Result<Vec<_>>>()?;
        let modes = parse_list("modes")?.iter()
            .map(|s| LabMode::parse(s)
                .with_context(|| format!("unknown mode {s:?} (f32|int8|int16)")))
            .collect::<Result<Vec<_>>>()?;
        let parse_nums = |key: &str, default: Vec<usize>| -> Result<Vec<usize>> {
            match j.at(&[key]) {
                None => Ok(default),
                Some(v) => {
                    let arr = v.as_arr().with_context(|| {
                        format!("spec field {key:?} must be an array")
                    })?;
                    arr.iter()
                        .map(|e| e.as_usize().with_context(|| {
                            format!("spec field {key:?} must hold integers")
                        }))
                        .collect()
                }
            }
        };
        let threads = parse_nums("threads", vec![0])?;
        let batches = parse_nums("batches", vec![8])?;
        let hw_parallelism = parse_nums("hw_parallelism", vec![1024])?
            .into_iter().map(|p| p as u64).collect();
        let model_batch = j.at(&["model_batch"]).and_then(Json::as_usize)
            .unwrap_or(64);
        let mflag = |key: &str| {
            matches!(j.at(&["measure", key]), Some(Json::Bool(true)))
        };
        let measure = Measure {
            layer: mflag("layer"),
            model: mflag("model"),
            hw: mflag("hw"),
            ratio_dw16: mflag("ratio_dw16"),
        };
        let loadtest = match j.at(&["loadtest"]) {
            None | Some(Json::Null) => None,
            Some(l) => Some(LoadPoint {
                qps: l.at(&["qps"]).and_then(Json::as_f64)
                    .context("loadtest.qps must be a number")?,
                duration_ms: l.at(&["duration_ms"]).and_then(Json::as_usize)
                    .context("loadtest.duration_ms must be an integer")?
                    as u64,
            }),
        };
        let spec = SweepSpec {
            name, archs, model_archs, kernels, strategies, modes, threads,
            batches, hw_parallelism, model_batch, measure, loadtest,
        };
        spec.validate()?;
        Ok(spec)
    }
}

fn arch_rank(a: Arch) -> usize {
    Arch::ALL.iter().position(|&x| x == a).unwrap_or(usize::MAX)
}

fn strategy_rank(s: KernelStrategy) -> usize {
    match s {
        KernelStrategy::Naive => 0,
        KernelStrategy::Tiled => 1,
        KernelStrategy::Simd => 2,
        KernelStrategy::Winograd => 3,
        KernelStrategy::Auto => 4,
    }
}
