//! Shared-scaling-factor quantization (paper §3.1).
//!
//! AdderNet's L1 similarity is 1-homogeneous, so if features and weights
//! share ONE power-of-two scale `2^e`, the integer datapath needs no
//! point-alignment shifter: `-Σ|q(x) - q(w)| * 2^e` IS the quantized
//! convolution.  CNN needs (and tolerates) separate per-tensor scales
//! because products compose scales multiplicatively.  Both modes are
//! implemented; the S7 experiment contrasts them.

pub mod plan;

use std::collections::BTreeMap;

pub use plan::QuantPlan;

/// Quantization mode.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// One scale shared by features and weights (the paper's method —
    /// hardware-friendly for the adder kernel).
    SharedScale,
    /// Separate feature/weight scales (CNN-style). For the adder kernel
    /// this forces a point-alignment shift that loses information.
    SeparateScale,
}

/// Integer grid maximum for signed `bits` quantization.
pub fn qmax(bits: u32) -> i32 {
    (1 << (bits - 1)) - 1
}

/// Power-of-two scale exponent: smallest e with qmax * 2^e >= max_abs.
pub fn scale_exp(max_abs: f32, bits: u32) -> i32 {
    let m = (max_abs.max(1e-12) / qmax(bits) as f32).log2();
    m.ceil() as i32
}

/// Round-half-to-even (matches numpy/jnp.round, keeping the Rust
/// functional path bit-identical to the Python oracle).
pub fn round_even(x: f32) -> f32 {
    let r = x.round();
    if (x - x.trunc()).abs() == 0.5 {
        // halfway: pick the even neighbour
        let down = x.trunc();
        let up = down + x.signum();
        if (down as i64) % 2 == 0 { down } else { up }
    } else {
        r
    }
}

/// Quantize one value to the signed integer grid at scale 2^exp.
pub fn quantize(x: f32, exp: i32, bits: u32) -> i32 {
    let s = (exp as f32).exp2();
    let q = round_even(x / s);
    (q as i32).clamp(-qmax(bits), qmax(bits))
}

/// Dequantize.
pub fn dequantize(q: i32, exp: i32) -> f32 {
    q as f32 * (exp as f32).exp2()
}

/// Quantize a slice.
pub fn quantize_slice(xs: &[f32], exp: i32, bits: u32) -> Vec<i32> {
    xs.iter().map(|&x| quantize(x, exp, bits)).collect()
}

pub fn max_abs(xs: &[f32]) -> f32 {
    xs.iter().fold(0f32, |m, &x| m.max(x.abs()))
}

/// Per-layer calibration record: observed feature range + weight range.
#[derive(Debug, Clone, Copy, Default)]
pub struct LayerCalib {
    pub feat_max_abs: f32,
    pub weight_max_abs: f32,
}

impl LayerCalib {
    /// The paper's shared exponent: covers the JOINT range (Fig. 3c).
    pub fn shared_exp(&self, bits: u32) -> i32 {
        scale_exp(self.feat_max_abs.max(self.weight_max_abs), bits)
    }

    /// Separate exponents (feature, weight) for the CNN-style mode.
    pub fn separate_exps(&self, bits: u32) -> (i32, i32) {
        (scale_exp(self.feat_max_abs, bits), scale_exp(self.weight_max_abs, bits))
    }
}

/// Calibration table for a whole model, keyed by conv-layer name.
pub type Calibration = BTreeMap<String, LayerCalib>;

/// Histogram of log2-magnitudes — regenerates Fig. 3(a)/(b): the paper's
/// feature/weight distribution plots that justify the shared scale.
#[derive(Debug, Clone)]
pub struct Log2Histogram {
    /// Bucket k counts values with 2^k <= |x| < 2^(k+1); range [lo, hi).
    pub lo: i32,
    pub hi: i32,
    pub counts: Vec<u64>,
    pub zero_or_tiny: u64,
    pub total: u64,
}

impl Log2Histogram {
    pub fn new(lo: i32, hi: i32) -> Self {
        Self { lo, hi, counts: vec![0; (hi - lo) as usize], zero_or_tiny: 0, total: 0 }
    }

    pub fn add(&mut self, xs: &[f32]) {
        for &x in xs {
            self.total += 1;
            let a = x.abs();
            if a < (self.lo as f32).exp2() {
                self.zero_or_tiny += 1;
                continue;
            }
            let k = a.log2().floor() as i32;
            let idx = (k.clamp(self.lo, self.hi - 1) - self.lo) as usize;
            self.counts[idx] += 1;
        }
    }

    /// Fraction of mass inside [2^a, 2^b) — the "96% of features within
    /// the clip region" style statement of §3.1.
    pub fn fraction_in(&self, a: i32, b: i32) -> f64 {
        let s: u64 = self.counts.iter().enumerate()
            .filter(|(i, _)| {
                let k = self.lo + *i as i32;
                k >= a && k < b
            })
            .map(|(_, c)| *c)
            .sum();
        s as f64 / self.total.max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qmax_values() {
        assert_eq!(qmax(8), 127);
        assert_eq!(qmax(4), 7);
        assert_eq!(qmax(16), 32767);
    }

    #[test]
    fn scale_exp_covers() {
        for bits in [4u32, 6, 8, 16] {
            let e = scale_exp(7.3, bits);
            assert!(qmax(bits) as f32 * (e as f32).exp2() >= 7.3);
            assert!(qmax(bits) as f32 * ((e - 1) as f32).exp2() < 7.3);
        }
    }

    #[test]
    fn round_even_matches_numpy() {
        assert_eq!(round_even(0.5), 0.0);
        assert_eq!(round_even(1.5), 2.0);
        assert_eq!(round_even(2.5), 2.0);
        assert_eq!(round_even(-0.5), 0.0);
        assert_eq!(round_even(-1.5), -2.0);
        assert_eq!(round_even(1.4), 1.0);
        assert_eq!(round_even(-1.6), -2.0);
    }

    #[test]
    fn quantize_clamps() {
        assert_eq!(quantize(1e9, 0, 8), 127);
        assert_eq!(quantize(-1e9, 0, 8), -127);
        assert_eq!(quantize(3.0, 0, 8), 3);
        assert_eq!(quantize(3.0, 1, 8), 2); // 3/2 = 1.5 -> even -> 2
    }

    #[test]
    fn quant_dequant_error_bounded() {
        let exp = -4;
        let s = (exp as f32).exp2();
        for x in [-1.0f32, -0.3, 0.0, 0.11, 0.99] {
            let q = quantize(x, exp, 8);
            assert!((dequantize(q, exp) - x).abs() <= s / 2.0 + 1e-7);
        }
    }

    #[test]
    fn shared_exp_covers_joint_range() {
        let c = LayerCalib { feat_max_abs: 4.0, weight_max_abs: 8.0 };
        let e = c.shared_exp(8);
        assert!(qmax(8) as f32 * (e as f32).exp2() >= 8.0);
        let (ef, ew) = c.separate_exps(8);
        assert!(ef <= e && ew <= e);
    }

    #[test]
    fn histogram_fractions() {
        let mut h = Log2Histogram::new(-8, 4);
        // values spanning 2^-4..2^2 like the paper's Fig 3a
        let xs: Vec<f32> = (0..1000)
            .map(|i| 0.0625 * 1.005f32.powi(i))
            .collect();
        h.add(&xs);
        assert!(h.fraction_in(-5, 3) > 0.9);
        assert_eq!(h.total, 1000);
    }

    #[test]
    fn histogram_handles_zeros() {
        let mut h = Log2Histogram::new(-8, 4);
        h.add(&[0.0, 1e-12, 1.0]);
        assert_eq!(h.zero_or_tiny, 2);
    }
}
