//! Quantization plans: compile `Params` + `Calibration` + `QuantCfg`
//! into an executable integer-serving artifact.
//!
//! The per-call quantized path (`sim::functional::conv2d_quant`) re-grids
//! the SAME weights on every forward pass and round-trips activations
//! through f32 between layers.  A [`QuantPlan`] does the whole
//! compilation once, up front:
//!
//! * **weights** are quantized a single time onto the paper's shared
//!   power-of-two grid (§3.1) and stored as `i32` in HWIO layout;
//! * **batch-norm** is folded into a per-channel integer multiplier +
//!   bias ([`BnFold`]) applied directly to the widened conv
//!   accumulators — the FPGA design's wide fixed-point BN unit;
//! * **inter-layer requantization** is a power-of-two shift
//!   ([`requant_shift`], round-half-to-even): each layer's BN stage
//!   lands activations straight on the NEXT layer's operand grid, so
//!   the datapath between convolutions is shift-only — no multipliers,
//!   mirroring the shift-not-multiply hardware argument the `hw/`
//!   gate-count model quantifies.
//!
//! [`crate::sim::intpath`] executes a plan keeping activations in the
//! i32 domain across the whole conv→BN→ReLU→pool chain; the f32
//! classifier head (a negligible slice of the compute) dequantizes at
//! the logits.

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::nn::graph::{NetGraph, Op};
use crate::nn::Padding;
use crate::quant::{self, Calibration, LayerCalib, Mode};
use crate::sim::functional::{Arch, Params, QuantCfg, SimKernel};
use crate::util::Json;

/// Default fractional bits of the folded BN multiplier.  [`fold_bn`]
/// narrows this per layer when needed so `acc(i32) * mul` always fits
/// i64 with headroom.
pub const BN_FRAC_BITS: u32 = 16;

/// Integer division rounding half to even (`d > 0`) — the integer twin
/// of [`quant::round_even`], exact at every requantization boundary.
pub fn div_round_even(n: i64, d: i64) -> i64 {
    debug_assert!(d > 0, "div_round_even needs a positive divisor");
    let q = n.div_euclid(d);
    let r = n.rem_euclid(d); // 0 <= r < d
    match (2 * r).cmp(&d) {
        std::cmp::Ordering::Greater => q + 1,
        std::cmp::Ordering::Less => q,
        // halfway: land on the even neighbour of {q, q+1}
        std::cmp::Ordering::Equal => q + (q & 1),
    }
}

/// Move an integer onto a grid `shift` bits coarser (positive shift,
/// round-half-to-even) or finer (negative shift, exact) — the pow2
/// inter-layer requantization primitive of the int path.  The
/// finer-grid direction saturates instead of wrapping, so absurd
/// exponent gaps (a corrupt hand-edited calibration table) degrade to
/// clamped activations rather than panics or wrapped values.
pub fn requant_shift(v: i64, shift: i32) -> i64 {
    if shift <= 0 {
        let k = (-shift).min(63) as u32;
        ((v as i128) << k).clamp(i64::MIN as i128, i64::MAX as i128) as i64
    } else {
        div_round_even(v, 1i64 << shift.min(62))
    }
}

/// Batch-norm folded for the integer domain: for a conv accumulator
/// `acc` on grid `2^acc_exp`, channel `c`'s normalized activation on
/// the target grid `2^out_exp` is
///
/// ```text
///   out_q = clamp( (acc * mul[c] + add[c]) >> shift )
/// ```
///
/// with round-half-to-even at the shift.  `mul` carries the BN scale
/// AND the inter-layer grid change, so requantization costs nothing
/// extra; power-of-two BN scales fold to exact powers of two (the
/// exactness property `tests/quant_props.rs` pins).
#[derive(Debug, Clone)]
pub struct BnFold {
    pub mul: Vec<i64>,
    pub add: Vec<i64>,
    pub shift: u32,
}

impl BnFold {
    /// Apply to one accumulator; `qmax` is the activation-register
    /// bound the result saturates to (the executor passes the DW+2
    /// inter-stage register width — see `sim::intpath::HEADROOM_BITS`;
    /// the strict DW clamp happens where operands enter a conv).
    #[inline]
    pub fn apply(&self, acc: i32, c: usize, qmax: i32) -> i32 {
        let v = acc as i64 * self.mul[c] + self.add[c];
        requant_shift(v, self.shift as i32)
            .clamp(-(qmax as i64), qmax as i64) as i32
    }
}

/// Fold eval-mode batch-norm (the exact `batch_norm_eval` f32 formula)
/// into integer per-channel multiplier/bias for accumulators on
/// `2^acc_exp`, producing activations on `2^out_exp`.
pub fn fold_bn(gamma: &[f32], beta: &[f32], mean: &[f32], var: &[f32],
               acc_exp: i32, out_exp: i32) -> Result<BnFold> {
    let c = gamma.len();
    anyhow::ensure!(beta.len() == c && mean.len() == c && var.len() == c,
                    "BN parameter arity mismatch ({c} channels)");
    let eps = 1e-5f32;
    // f32 scale/shift EXACTLY as the f32 path computes them, widened to
    // f64 only for the fold arithmetic.
    let scale: Vec<f32> = (0..c).map(|i| gamma[i] / (var[i] + eps).sqrt()).collect();
    let shift_c: Vec<f32> = (0..c).map(|i| beta[i] - mean[i] * scale[i]).collect();
    let rel = 2f64.powi(acc_exp - out_exp);
    let max_scaled = scale.iter().fold(0f64, |m, &s| m.max((s as f64 * rel).abs()));
    // Widest fractional shift keeping |mul| <= 2^30: acc * mul then
    // stays under 2^61, leaving i64 headroom for the bias.
    let mut s = BN_FRAC_BITS as i32;
    if max_scaled > 0.0 {
        s = s.min(30 - max_scaled.log2().ceil() as i32);
    }
    anyhow::ensure!(s >= 0,
                    "BN fold overflow: |scale| up to {max_scaled:.3e} relating \
                     2^{acc_exp} accumulators to 2^{out_exp} activations");
    let sf = 2f64.powi(s);
    let mul = scale.iter().map(|&v| round_even_i64(v as f64 * rel * sf)).collect();
    let out_step = 2f64.powi(-out_exp);
    let add = shift_c.iter().map(|&v| round_even_i64(v as f64 * sf * out_step)).collect();
    Ok(BnFold { mul, add, shift: s as u32 })
}

/// f64 round-half-to-even to i64 (mirrors [`quant::round_even`]).
fn round_even_i64(x: f64) -> i64 {
    if (x - x.trunc()).abs() == 0.5 {
        let down = x.trunc();
        if (down as i64) % 2 == 0 {
            down as i64
        } else {
            (down + x.signum()) as i64
        }
    } else {
        x.round() as i64
    }
}

/// One conv layer compiled for integer execution.
#[derive(Debug, Clone)]
pub struct ConvPlan {
    pub name: String,
    /// Weights quantized once at build time, HWIO, on `2^w_exp`.
    pub wq: Vec<i32>,
    pub kh: usize,
    pub kw: usize,
    pub cin: usize,
    pub cout: usize,
    pub stride: usize,
    pub padding: Padding,
    /// Grid incoming activations must sit on (== `w_exp` for the
    /// paper's shared-scale adder mode — no point-alignment shifter).
    pub in_exp: i32,
    pub w_exp: i32,
    /// Accumulator grid: adder = the operand grid (1-homogeneous L1);
    /// mult = `in_exp + w_exp` (products compose scales).
    pub acc_exp: i32,
    /// Activation grid after BN+requant == the consumer's operand grid.
    pub out_exp: i32,
    pub bn: BnFold,
}

/// The f32 classifier head, copied out of `Params` so a plan serves
/// without them.
#[derive(Debug, Clone)]
pub struct DensePlan {
    pub name: String,
    pub w: Vec<f32>,
    pub b: Vec<f32>,
    pub din: usize,
    pub dout: usize,
}

/// A fully-compiled integer inference pipeline for one model.
#[derive(Debug, Clone)]
pub struct QuantPlan {
    pub arch: Arch,
    pub kind: SimKernel,
    pub cfg: QuantCfg,
    pub convs: BTreeMap<String, ConvPlan>,
    pub dense: BTreeMap<String, DensePlan>,
    /// Grid the input image is quantized on (the first conv's operand
    /// grid) — the only f32->int boundary of the conv stack.
    pub input_exp: i32,
}

struct Builder<'a> {
    params: &'a Params,
    kind: SimKernel,
    cfg: QuantCfg,
    calib: &'a Calibration,
}

fn p<'p>(params: &'p Params, name: &str) -> Result<(&'p [usize], &'p [f32])> {
    params.get(name)
        .map(|(s, d)| (s.as_slice(), d.as_slice()))
        .ok_or_else(|| anyhow::anyhow!("missing parameter {name}"))
}

impl Builder<'_> {
    fn lc(&self, name: &str) -> Result<&LayerCalib> {
        self.calib.get(name).ok_or_else(|| anyhow::anyhow!(
            "no calibration entry for conv layer {name} (run `repro calibrate`)"))
    }

    /// (in_exp, w_exp, acc_exp) for one conv layer.
    fn grids(&self, name: &str) -> Result<(i32, i32, i32)> {
        let lc = self.lc(name)?;
        Ok(match self.cfg.mode {
            Mode::SharedScale => {
                let e = lc.shared_exp(self.cfg.bits);
                let acc = match self.kind {
                    SimKernel::Adder => e,
                    SimKernel::Mult => 2 * e,
                };
                (e, e, acc)
            }
            Mode::SeparateScale => {
                let (ef, ew) = lc.separate_exps(self.cfg.bits);
                match self.kind {
                    // the adder datapath must point-align: everything
                    // lands on the coarse grid (the §3.1 info loss)
                    SimKernel::Adder => {
                        let coarse = ef.max(ew);
                        (coarse, coarse, coarse)
                    }
                    SimKernel::Mult => (ef, ew, ef + ew),
                }
            }
        })
    }

    fn conv_plan(&self, name: &str, stride: usize, padding: Padding,
                 out_exp: i32) -> Result<ConvPlan> {
        let (ws, wd) = p(self.params, &format!("{name}/conv_w"))?;
        anyhow::ensure!(ws.len() == 4, "conv weight for {name} must be HWIO");
        let (in_exp, w_exp, acc_exp) = self.grids(name)?;
        // Both operands are single-rounded straight onto their plan
        // grid.  For SeparateScale adder plans this differs from the
        // per-call experiment path, which quantizes on the fine grid
        // and then re-grids (double rounding) to model the §3.1
        // alignment loss — a compiled plan has no fine-grid
        // intermediate, so it rounds once and is marginally MORE
        // accurate.  Bit-parity with `conv2d_quant` is guaranteed (and
        // oracle-tested) for SharedScale, the paper's serving mode.
        let wq = quant::quantize_slice(wd, w_exp, self.cfg.bits);
        let (_, gamma) = p(self.params, &format!("{name}/bn_gamma"))?;
        let (_, beta) = p(self.params, &format!("{name}/bn_beta"))?;
        let (_, mean) = p(self.params, &format!("{name}/bn_mean"))?;
        let (_, var) = p(self.params, &format!("{name}/bn_var"))?;
        let bn = fold_bn(gamma, beta, mean, var, acc_exp, out_exp)
            .with_context(|| format!("folding BN for {name}"))?;
        Ok(ConvPlan {
            name: name.into(),
            wq,
            kh: ws[0],
            kw: ws[1],
            cin: ws[2],
            cout: ws[3],
            stride,
            padding,
            in_exp,
            w_exp,
            acc_exp,
            out_exp,
            bn,
        })
    }

    fn dense_plan(&self, name: &str) -> Result<DensePlan> {
        let (ws, wd) = p(self.params, &format!("{name}/dense_w"))?;
        let (_, bd) = p(self.params, &format!("{name}/dense_b"))?;
        anyhow::ensure!(ws.len() == 2, "dense weight for {name} must be (din, dout)");
        Ok(DensePlan {
            name: name.into(),
            w: wd.to_vec(),
            b: bd.to_vec(),
            din: ws[0],
            dout: ws[1],
        })
    }
}

/// Compute each conv's post-BN activation grid (`out_exp`) from a
/// backward walk over the compiled op program: every conv lands its
/// output straight on the operand grid of the NEXT conv downstream
/// (ReLU, pooling, flatten and the residual add all preserve the grid),
/// so inter-layer requantization folds into BN.  A conv feeding the f32
/// head keeps its own grid (the head dequantizes).  Both inputs of a
/// residual add — the main-path conv and the projection shortcut —
/// receive the same target, which is what keeps residual partners on
/// one grid.
fn solve_out_exps(b: &Builder, graph: &NetGraph)
                  -> Result<BTreeMap<String, i32>> {
    let ops = &graph.ops;
    let mut target: Option<i32> = None;
    let mut outs = BTreeMap::new();
    for (i, op) in ops.iter().enumerate().rev() {
        match op {
            // a dense head consumes dequantized f32: no grid constraint
            Op::Dense(_) => target = None,
            Op::ConvBn(c) => {
                let in_e = b.grids(&c.name)?.0;
                outs.insert(c.name.clone(), target.unwrap_or(in_e));
                target = Some(in_e);
            }
            Op::ResidualClose { shortcut } => {
                if target.is_none() {
                    // terminal block (the head dequantizes next): land
                    // the residual on the main-path conv's own operand
                    // grid, for both summands
                    let main = ops[..i].iter().rev()
                        .find_map(|o| match o {
                            Op::ConvBn(c) => Some(c.name.as_str()),
                            _ => None,
                        })
                        .ok_or_else(|| anyhow::anyhow!(
                            "residual block with no main-path conv"))?;
                    target = Some(b.grids(main)?.0);
                }
                if let Some(c) = shortcut {
                    outs.insert(c.name.clone(),
                                target.expect("target set above"));
                }
            }
            // grid-preserving ops: ReLU, pooling, flatten, open bracket
            _ => {}
        }
    }
    Ok(outs)
}

impl QuantPlan {
    /// Compile a plan by walking the architecture's compiled op program
    /// ([`crate::nn::graph`]) — no per-architecture code.  Errors (never
    /// panics) on missing parameters, missing calibration entries or a
    /// BN fold that cannot be represented —
    /// `coordinator::server::start_functional` surfaces these to the
    /// caller instead of bringing a worker down.
    pub fn build(params: &Params, arch: Arch, kind: SimKernel, cfg: QuantCfg,
                 calib: &Calibration) -> Result<QuantPlan> {
        anyhow::ensure!((2..=16).contains(&cfg.bits),
                        "plan supports 2..=16-bit grids, got {}", cfg.bits);
        anyhow::ensure!(
            Self::supports(kind, cfg.bits),
            "mult-kernel plans support at most 8-bit operands (the i32 conv \
             accumulator overflows at int{}); the adder kernel serves all \
             widths", cfg.bits);
        let b = Builder { params, kind, cfg, calib };
        let graph = arch.graph();
        let out_exps = solve_out_exps(&b, graph)?;
        let mut convs = BTreeMap::new();
        let mut dense = BTreeMap::new();
        for spec in graph.conv_specs() {
            convs.insert(
                spec.name.clone(),
                b.conv_plan(&spec.name, spec.stride, spec.padding,
                            out_exps[&spec.name])?);
        }
        for spec in graph.dense_specs() {
            dense.insert(spec.name.clone(), b.dense_plan(&spec.name)?);
        }
        let first = graph.conv_specs().first()
            .map(|c| c.name.clone())
            .ok_or_else(|| anyhow::anyhow!(
                "{}: cannot plan a network with no conv layers", graph.id))?;
        let input_exp = convs[&first].in_exp;
        Ok(QuantPlan { arch, kind, cfg, convs, dense, input_exp })
    }

    /// Whether a plan can be compiled for this kernel/width pair — the
    /// ONE place the policy lives: the adder accumulator is provably
    /// i32-bounded (|acc| <= 2*qmax*K), but MULT tap products reach
    /// qmax^2, so at int16 two taps already overflow i32.
    pub fn supports(kind: SimKernel, bits: u32) -> bool {
        matches!(kind, SimKernel::Adder) || bits <= 8
    }

    /// Integer grid maximum of the plan's serving bit-width.
    pub fn qmax(&self) -> i32 {
        quant::qmax(self.cfg.bits)
    }
}

// ---------------------------------------------------------------------------
// Calibration tables as JSON (repro calibrate <-> repro serve)
// ---------------------------------------------------------------------------

/// Serialize a calibration table.  Plain `{}` float formatting is
/// shortest-round-trip in Rust, so `calibration_from_json` recovers the
/// exact f32 values.
pub fn calibration_to_json(calib: &Calibration) -> String {
    let rows: Vec<String> = calib.iter()
        .map(|(name, lc)| format!(
            "    {:?}: {{\"feat_max_abs\": {}, \"weight_max_abs\": {}}}",
            name, lc.feat_max_abs, lc.weight_max_abs))
        .collect();
    format!("{{\n  \"calibration\": {{\n{}\n  }}\n}}\n", rows.join(",\n"))
}

/// Parse a calibration table written by [`calibration_to_json`].
pub fn calibration_from_json(s: &str) -> Result<Calibration> {
    let j = Json::parse(s).context("parsing calibration JSON")?;
    let obj = j.at(&["calibration"]).and_then(|v| v.as_obj())
        .ok_or_else(|| anyhow::anyhow!(
            "calibration JSON needs a top-level \"calibration\" object"))?;
    let mut calib = Calibration::new();
    for (name, v) in obj {
        let field = |key: &str| -> Result<f32> {
            let x = v.get(key).and_then(|x| x.as_f64()).ok_or_else(
                || anyhow::anyhow!("layer {name}: missing {key}"))? as f32;
            anyhow::ensure!(x.is_finite() && x >= 0.0,
                            "layer {name}: {key} must be finite and >= 0");
            Ok(x)
        };
        calib.insert(name.clone(), LayerCalib {
            feat_max_abs: field("feat_max_abs")?,
            weight_max_abs: field("weight_max_abs")?,
        });
    }
    Ok(calib)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::functional::synth_params;

    #[test]
    fn div_round_even_matches_float_round_even() {
        for n in -2000i64..2000 {
            for d in [1i64, 2, 3, 4, 8, 10, 64] {
                let want = quant::round_even(n as f32 / d as f32) as i64;
                assert_eq!(div_round_even(n, d), want, "{n}/{d}");
            }
        }
    }

    #[test]
    fn requant_shift_directions() {
        assert_eq!(requant_shift(5, 1), 2); // 2.5 -> even 2
        assert_eq!(requant_shift(7, 1), 4); // 3.5 -> even 4
        assert_eq!(requant_shift(-5, 1), -2);
        assert_eq!(requant_shift(3, -2), 12); // finer grid is exact
        assert_eq!(requant_shift(3, 0), 3);
    }

    #[test]
    fn requant_shift_saturates_on_absurd_finer_shifts() {
        // corrupt calibration tables can produce enormous exponent
        // gaps; the finer-grid move must saturate, never wrap or panic
        assert_eq!(requant_shift(1, -63), i64::MAX);
        assert_eq!(requant_shift(-1, -63), i64::MIN);
        assert_eq!(requant_shift(508, -120), i64::MAX);
        assert_eq!(requant_shift(0, -120), 0);
        assert_eq!(requant_shift(1, -62), 1i64 << 62); // still exact in range
    }

    #[test]
    fn fold_bn_identity_is_pure_requant() {
        // gamma=1, beta=0, mean=0, var=1: scale = 1/sqrt(1+eps), so the
        // fold is (almost) a pure grid move; acc on the same grid comes
        // back nearly unchanged.
        let n = 4;
        let f = fold_bn(&vec![1.0; n], &vec![0.0; n], &vec![0.0; n],
                        &vec![1.0; n], -3, -3).unwrap();
        for acc in [-1000i32, -1, 0, 1, 7, 1000] {
            let out = f.apply(acc, 0, i32::MAX);
            assert!((out - acc).abs() <= 1, "{acc} -> {out}");
        }
    }

    #[test]
    fn fold_bn_narrows_fraction_bits_for_big_scales() {
        // A huge scale relating a fine acc grid to a coarse out grid
        // must shrink `shift` instead of overflowing the multiplier.
        let f = fold_bn(&[1.0e5], &[0.0], &[0.0], &[1.0], 0, -4).unwrap();
        assert!(f.shift < BN_FRAC_BITS, "shift {}", f.shift);
        assert!(f.mul[0].abs() <= 1 << 30, "mul {}", f.mul[0]);
    }

    #[test]
    fn fold_bn_rejects_unrepresentable() {
        // scale so large no non-negative shift keeps mul in range
        assert!(fold_bn(&[1.0e20], &[0.0], &[0.0], &[1.0], 0, -20).is_err());
    }

    #[test]
    fn calibration_json_round_trips() {
        let mut c = Calibration::new();
        c.insert("conv1".into(), LayerCalib { feat_max_abs: 1.25, weight_max_abs: 0.375 });
        c.insert("s0b1/c2".into(), LayerCalib { feat_max_abs: 3.0e-5, weight_max_abs: 7.75 });
        let s = calibration_to_json(&c);
        let back = calibration_from_json(&s).unwrap();
        assert_eq!(back.len(), 2);
        for (k, lc) in &c {
            let b = &back[k];
            assert_eq!(b.feat_max_abs, lc.feat_max_abs, "{k}");
            assert_eq!(b.weight_max_abs, lc.weight_max_abs, "{k}");
        }
    }

    #[test]
    fn calibration_json_rejects_garbage() {
        assert!(calibration_from_json("nonsense").is_err());
        assert!(calibration_from_json("{\"x\": 1}").is_err());
        assert!(calibration_from_json(
            "{\"calibration\": {\"c\": {\"feat_max_abs\": 1}}}").is_err());
    }

    fn demo_calib(names: &[&str]) -> Calibration {
        names.iter()
            .map(|n| (n.to_string(),
                      LayerCalib { feat_max_abs: 1.0, weight_max_abs: 0.5 }))
            .collect()
    }

    #[test]
    fn build_lenet_plan_shapes() {
        let params = synth_params(Arch::Lenet5, 9);
        let calib = demo_calib(&["conv1", "conv2"]);
        let cfg = QuantCfg { bits: 8, mode: Mode::SharedScale };
        let plan = QuantPlan::build(&params, Arch::Lenet5, SimKernel::Adder,
                                    cfg, &calib).unwrap();
        assert_eq!(plan.convs.len(), 2);
        assert_eq!(plan.dense.len(), 3);
        let c1 = &plan.convs["conv1"];
        assert_eq!((c1.kh, c1.kw, c1.cin, c1.cout), (5, 5, 1, 6));
        assert_eq!(c1.wq.len(), 5 * 5 * 6);
        // shared adder: operands and accumulator share one grid
        assert_eq!(c1.in_exp, c1.w_exp);
        assert_eq!(c1.acc_exp, c1.in_exp);
        // conv1 requantizes onto conv2's operand grid
        assert_eq!(c1.out_exp, plan.convs["conv2"].in_exp);
        assert_eq!(plan.input_exp, c1.in_exp);
    }

    #[test]
    fn build_resnet_plan_covers_all_blocks() {
        let params = synth_params(Arch::Resnet8, 9);
        let names: Vec<String> = params.keys()
            .filter_map(|k| k.strip_suffix("/conv_w").map(|s| s.to_string()))
            .collect();
        let calib: Calibration = names.iter()
            .map(|n| (n.clone(), LayerCalib { feat_max_abs: 2.0, weight_max_abs: 0.5 }))
            .collect();
        let cfg = QuantCfg { bits: 8, mode: Mode::SharedScale };
        let plan = QuantPlan::build(&params, Arch::Resnet8, SimKernel::Adder,
                                    cfg, &calib).unwrap();
        assert_eq!(plan.convs.len(), names.len());
        // residual partners land on one grid: c2 and sc of the same
        // block always share out_exp
        for (name, cp) in &plan.convs {
            if let Some(pre) = name.strip_suffix("/sc") {
                assert_eq!(cp.out_exp, plan.convs[&format!("{pre}/c2")].out_exp,
                           "{name}");
            }
        }
    }

    #[test]
    fn build_covers_every_graph_arch_with_chained_grids() {
        // The graph walk must plan ANY registered architecture: every
        // conv spec gets a plan, and each conv lands its activations on
        // the grid the next conv consumes (pool/relu/residual preserve
        // grids, the terminal conv keeps its own).
        for arch in [Arch::Lenet5, Arch::Cnv6, Arch::Resnet8, Arch::Resnet32] {
            let params = synth_params(arch, 9);
            let calib: Calibration = params.keys()
                .filter_map(|k| k.strip_suffix("/conv_w"))
                .map(|n| (n.to_string(),
                          LayerCalib { feat_max_abs: 2.0, weight_max_abs: 0.5 }))
                .collect();
            let cfg = QuantCfg { bits: 8, mode: Mode::SharedScale };
            let plan = QuantPlan::build(&params, arch, SimKernel::Adder, cfg,
                                        &calib).unwrap();
            let specs = arch.graph().conv_specs();
            assert_eq!(plan.convs.len(), specs.len(), "{arch:?}");
            assert_eq!(plan.dense.len(), arch.graph().dense_specs().len());
            assert_eq!(plan.input_exp, plan.convs[&specs[0].name].in_exp);
        }
        // cnv6 is a plain stack: the chain is literal neighbour-to-
        // neighbour handoff
        let params = synth_params(Arch::Cnv6, 9);
        let calib: Calibration = (1..=6)
            .map(|i| (format!("c{i}"),
                      LayerCalib { feat_max_abs: 2.0, weight_max_abs: 0.5 }))
            .collect();
        let cfg = QuantCfg { bits: 8, mode: Mode::SharedScale };
        let plan = QuantPlan::build(&params, Arch::Cnv6, SimKernel::Adder, cfg,
                                    &calib).unwrap();
        for i in 1..6 {
            assert_eq!(plan.convs[&format!("c{i}")].out_exp,
                       plan.convs[&format!("c{}", i + 1)].in_exp, "c{i}");
        }
        // terminal conv feeds the head on its own grid
        assert_eq!(plan.convs["c6"].out_exp, plan.convs["c6"].in_exp);
    }

    #[test]
    fn build_errors_on_missing_calibration() {
        let params = synth_params(Arch::Lenet5, 9);
        let calib = demo_calib(&["conv1"]); // conv2 missing
        let cfg = QuantCfg { bits: 8, mode: Mode::SharedScale };
        let err = QuantPlan::build(&params, Arch::Lenet5, SimKernel::Adder,
                                   cfg, &calib).unwrap_err();
        assert!(format!("{err:#}").contains("conv2"), "{err:#}");
    }

    #[test]
    fn build_errors_on_missing_params() {
        let mut params = synth_params(Arch::Lenet5, 9);
        params.remove("conv2/bn_gamma");
        let calib = demo_calib(&["conv1", "conv2"]);
        let cfg = QuantCfg { bits: 8, mode: Mode::SharedScale };
        assert!(QuantPlan::build(&params, Arch::Lenet5, SimKernel::Adder,
                                 cfg, &calib).is_err());
    }

    #[test]
    fn build_rejects_wide_mult_plans() {
        // int16 MULT products overflow the i32 accumulator; the plan
        // compiler must refuse, while int8 mult and int16 adder build.
        let params = synth_params(Arch::Lenet5, 9);
        let calib = demo_calib(&["conv1", "conv2"]);
        let wide = QuantCfg { bits: 16, mode: Mode::SharedScale };
        let err = QuantPlan::build(&params, Arch::Lenet5, SimKernel::Mult,
                                   wide, &calib).unwrap_err();
        assert!(format!("{err:#}").contains("8-bit"), "{err:#}");
        let narrow = QuantCfg { bits: 8, mode: Mode::SharedScale };
        assert!(QuantPlan::build(&params, Arch::Lenet5, SimKernel::Mult,
                                 narrow, &calib).is_ok());
        assert!(QuantPlan::build(&params, Arch::Lenet5, SimKernel::Adder,
                                 wide, &calib).is_ok());
    }
}
