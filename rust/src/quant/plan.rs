//! Quantization plans: compile `Params` + `Calibration` + `QuantCfg`
//! into an executable integer-serving artifact.
//!
//! The per-call quantized path (`sim::functional::conv2d_quant`) re-grids
//! the SAME weights on every forward pass and round-trips activations
//! through f32 between layers.  A [`QuantPlan`] does the whole
//! compilation once, up front:
//!
//! * **weights** are quantized a single time onto the paper's shared
//!   power-of-two grid (§3.1) and stored as `i32` in HWIO layout;
//! * **batch-norm** is folded into a per-channel integer multiplier +
//!   bias ([`BnFold`]) applied directly to the widened conv
//!   accumulators — the FPGA design's wide fixed-point BN unit;
//! * **inter-layer requantization** is a power-of-two shift
//!   ([`requant_shift`], round-half-to-even): each layer's BN stage
//!   lands activations straight on the NEXT layer's operand grid, so
//!   the datapath between convolutions is shift-only — no multipliers,
//!   mirroring the shift-not-multiply hardware argument the `hw/`
//!   gate-count model quantifies.
//!
//! The **dense classifier head** is compiled too ([`DensePlan`]):
//! weights quantized once onto their own static pow2 grid, bias folded
//! onto the i64 accumulator grid, intermediate layers requantizing onto
//! the next layer's calibrated operand grid.  [`crate::sim::intpath`]
//! therefore executes a plan keeping activations in the i32 domain from
//! the input image to the final dense accumulators; f32 appears exactly
//! once, at the logit rescale.  A plan also serializes as a versioned
//! JSON artifact ([`plan_to_json`]/[`plan_from_json`]) so serving can
//! cold-start from the file alone — zero calibration, zero parameter
//! files (`repro plan` / `repro serve --plan`).

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::nn::graph::{DenseSpec, NetGraph, Op};
use crate::nn::Padding;
use crate::quant::{self, Calibration, LayerCalib, Mode};
use crate::sim::functional::{Arch, Params, QuantCfg, SimKernel};
use crate::util::Json;

/// Default fractional bits of the folded BN multiplier.  [`fold_bn`]
/// narrows this per layer when needed so `acc(i32) * mul` always fits
/// i64 with headroom.
pub const BN_FRAC_BITS: u32 = 16;

/// Floor on the dense-head grid exponents.  A degenerate calibration
/// (e.g. an all-zero feature range from identity-BN synthetic weights,
/// or an all-zero weight tensor) would otherwise drive `scale_exp`
/// toward 2^-50-ish grids whose folded bias overflows i64.  Coarsening
/// an exponent never loses range coverage — only resolution, and
/// 2^-24 steps are already far beyond what a <= 16-bit serving width of
/// O(1)-ranged values can use.
pub const DENSE_MIN_EXP: i32 = -24;

/// Exclusive bound on the integer magnitudes a plan serializes: every
/// plan value must survive the JSON number round trip EXACTLY, and JSON
/// numbers are f64, whose exact integer range ends at 2^53 (2^53 + 1
/// already parses to its even neighbour — so the bound is strict, lest
/// a silently-rounded corrupt value slip through import).
pub const MAX_PLAN_INT: i64 = 1 << 53;

/// Integer division rounding half to even (`d > 0`) — the integer twin
/// of [`quant::round_even`], exact at every requantization boundary.
pub fn div_round_even(n: i64, d: i64) -> i64 {
    debug_assert!(d > 0, "div_round_even needs a positive divisor");
    let q = n.div_euclid(d);
    let r = n.rem_euclid(d); // 0 <= r < d
    match (2 * r).cmp(&d) {
        std::cmp::Ordering::Greater => q + 1,
        std::cmp::Ordering::Less => q,
        // halfway: land on the even neighbour of {q, q+1}
        std::cmp::Ordering::Equal => q + (q & 1),
    }
}

/// Move an integer onto a grid `shift` bits coarser (positive shift,
/// round-half-to-even) or finer (negative shift, exact) — the pow2
/// inter-layer requantization primitive of the int path.  The
/// finer-grid direction saturates instead of wrapping, so absurd
/// exponent gaps (a corrupt hand-edited calibration table) degrade to
/// clamped activations rather than panics or wrapped values.
pub fn requant_shift(v: i64, shift: i32) -> i64 {
    if shift <= 0 {
        let k = (-shift).min(63) as u32;
        ((v as i128) << k).clamp(i64::MIN as i128, i64::MAX as i128) as i64
    } else {
        div_round_even(v, 1i64 << shift.min(62))
    }
}

/// Batch-norm folded for the integer domain: for a conv accumulator
/// `acc` on grid `2^acc_exp`, channel `c`'s normalized activation on
/// the target grid `2^out_exp` is
///
/// ```text
///   out_q = clamp( (acc * mul[c] + add[c]) >> shift )
/// ```
///
/// with round-half-to-even at the shift.  `mul` carries the BN scale
/// AND the inter-layer grid change, so requantization costs nothing
/// extra; power-of-two BN scales fold to exact powers of two (the
/// exactness property `tests/quant_props.rs` pins).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BnFold {
    pub mul: Vec<i64>,
    pub add: Vec<i64>,
    pub shift: u32,
}

impl BnFold {
    /// Apply to one accumulator; `qmax` is the activation-register
    /// bound the result saturates to (the executor passes the DW+2
    /// inter-stage register width — see `sim::intpath::HEADROOM_BITS`;
    /// the strict DW clamp happens where operands enter a conv).
    #[inline]
    pub fn apply(&self, acc: i32, c: usize, qmax: i32) -> i32 {
        let v = acc as i64 * self.mul[c] + self.add[c];
        requant_shift(v, self.shift as i32)
            .clamp(-(qmax as i64), qmax as i64) as i32
    }
}

/// Fold eval-mode batch-norm (the exact `batch_norm_eval` f32 formula)
/// into integer per-channel multiplier/bias for accumulators on
/// `2^acc_exp`, producing activations on `2^out_exp`.
pub fn fold_bn(gamma: &[f32], beta: &[f32], mean: &[f32], var: &[f32],
               acc_exp: i32, out_exp: i32) -> Result<BnFold> {
    let c = gamma.len();
    anyhow::ensure!(beta.len() == c && mean.len() == c && var.len() == c,
                    "BN parameter arity mismatch ({c} channels)");
    let eps = 1e-5f32;
    // f32 scale/shift EXACTLY as the f32 path computes them, widened to
    // f64 only for the fold arithmetic.
    let scale: Vec<f32> = (0..c).map(|i| gamma[i] / (var[i] + eps).sqrt()).collect();
    let shift_c: Vec<f32> = (0..c).map(|i| beta[i] - mean[i] * scale[i]).collect();
    let rel = 2f64.powi(acc_exp - out_exp);
    let max_scaled = scale.iter().fold(0f64, |m, &s| m.max((s as f64 * rel).abs()));
    // Widest fractional shift keeping |mul| <= 2^30: acc * mul then
    // stays under 2^61, leaving i64 headroom for the bias.
    let mut s = BN_FRAC_BITS as i32;
    if max_scaled > 0.0 {
        s = s.min(30 - max_scaled.log2().ceil() as i32);
    }
    anyhow::ensure!(s >= 0,
                    "BN fold overflow: |scale| up to {max_scaled:.3e} relating \
                     2^{acc_exp} accumulators to 2^{out_exp} activations");
    let sf = 2f64.powi(s);
    let mul = scale.iter().map(|&v| round_even_i64(v as f64 * rel * sf)).collect();
    let out_step = 2f64.powi(-out_exp);
    let add = shift_c.iter().map(|&v| round_even_i64(v as f64 * sf * out_step)).collect();
    Ok(BnFold { mul, add, shift: s as u32 })
}

/// f64 round-half-to-even to i64 (mirrors [`quant::round_even`]).
fn round_even_i64(x: f64) -> i64 {
    if (x - x.trunc()).abs() == 0.5 {
        let down = x.trunc();
        if (down as i64) % 2 == 0 {
            down as i64
        } else {
            (down + x.signum()) as i64
        }
    } else {
        x.round() as i64
    }
}

/// One conv layer compiled for integer execution.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ConvPlan {
    pub name: String,
    /// Weights quantized once at build time, HWIO, on `2^w_exp`.
    pub wq: Vec<i32>,
    pub kh: usize,
    pub kw: usize,
    pub cin: usize,
    pub cout: usize,
    pub stride: usize,
    pub padding: Padding,
    /// Grid incoming activations must sit on (== `w_exp` for the
    /// paper's shared-scale adder mode — no point-alignment shifter).
    pub in_exp: i32,
    pub w_exp: i32,
    /// Accumulator grid: adder = the operand grid (1-homogeneous L1);
    /// mult = `in_exp + w_exp` (products compose scales).
    pub acc_exp: i32,
    /// Activation grid after BN+requant == the consumer's operand grid.
    pub out_exp: i32,
    pub bn: BnFold,
}

/// One dense (classifier-head) layer compiled for integer execution.
/// The head is multiplicative hardware (a tiny slice of the compute),
/// so scales compose: activations arrive on `2^in_exp`, weights are
/// quantized once onto their own static power-of-two grid `2^w_exp`,
/// and the i64 accumulator therefore sits on `2^acc_exp = 2^(in_exp +
/// w_exp)` with the bias pre-folded onto that grid.  Intermediate
/// layers requantize the accumulator onto the NEXT layer's operand grid
/// (`out_exp = Some(..)`, a pow2 round-to-even shift); the logits layer
/// (`out_exp = None`) dequantizes straight off the accumulator grid —
/// the final requant-to-logits rescale, and the plan path's single
/// int→f32 boundary.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DensePlan {
    pub name: String,
    /// Weights quantized once at build time, (din x dout) row-major, on
    /// `2^w_exp`.
    pub wq: Vec<i32>,
    /// Bias folded onto the accumulator grid `2^acc_exp`.
    pub bq: Vec<i64>,
    pub din: usize,
    pub dout: usize,
    /// Grid incoming activations are shifted onto (clamped to the
    /// serving width) before entering the layer — the same operand
    /// contract the convs have.
    pub in_exp: i32,
    pub w_exp: i32,
    /// `in_exp + w_exp`: products compose scales.
    pub acc_exp: i32,
    /// `Some(grid)` — intermediate layer, requantize onto that grid and
    /// stay integer; `None` — the logits layer, dequantize off
    /// `acc_exp`.
    pub out_exp: Option<i32>,
}

/// A fully-compiled integer inference pipeline for one model.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct QuantPlan {
    pub arch: Arch,
    pub kind: SimKernel,
    pub cfg: QuantCfg,
    pub convs: BTreeMap<String, ConvPlan>,
    pub dense: BTreeMap<String, DensePlan>,
    /// Grid the input image is quantized on (the first conv's operand
    /// grid) — the only f32->int boundary of the conv stack.
    pub input_exp: i32,
}

struct Builder<'a> {
    params: &'a Params,
    kind: SimKernel,
    cfg: QuantCfg,
    calib: &'a Calibration,
}

fn p<'p>(params: &'p Params, name: &str) -> Result<(&'p [usize], &'p [f32])> {
    params.get(name)
        .map(|(s, d)| (s.as_slice(), d.as_slice()))
        .ok_or_else(|| anyhow::anyhow!("missing parameter {name}"))
}

impl Builder<'_> {
    fn lc(&self, name: &str) -> Result<&LayerCalib> {
        self.calib.get(name).ok_or_else(|| anyhow::anyhow!(
            "no calibration entry for conv layer {name} (run `repro calibrate`)"))
    }

    /// (in_exp, w_exp, acc_exp) for one conv layer.
    fn grids(&self, name: &str) -> Result<(i32, i32, i32)> {
        let lc = self.lc(name)?;
        Ok(match self.cfg.mode {
            Mode::SharedScale => {
                let e = lc.shared_exp(self.cfg.bits);
                let acc = match self.kind {
                    SimKernel::Adder => e,
                    SimKernel::Mult => 2 * e,
                };
                (e, e, acc)
            }
            Mode::SeparateScale => {
                let (ef, ew) = lc.separate_exps(self.cfg.bits);
                match self.kind {
                    // the adder datapath must point-align: everything
                    // lands on the coarse grid (the §3.1 info loss)
                    SimKernel::Adder => {
                        let coarse = ef.max(ew);
                        (coarse, coarse, coarse)
                    }
                    SimKernel::Mult => (ef, ew, ef + ew),
                }
            }
        })
    }

    fn conv_plan(&self, name: &str, stride: usize, padding: Padding,
                 out_exp: i32) -> Result<ConvPlan> {
        let (ws, wd) = p(self.params, &format!("{name}/conv_w"))?;
        anyhow::ensure!(ws.len() == 4, "conv weight for {name} must be HWIO");
        let (in_exp, w_exp, acc_exp) = self.grids(name)?;
        // Both operands are single-rounded straight onto their plan
        // grid.  For SeparateScale adder plans this differs from the
        // per-call experiment path, which quantizes on the fine grid
        // and then re-grids (double rounding) to model the §3.1
        // alignment loss — a compiled plan has no fine-grid
        // intermediate, so it rounds once and is marginally MORE
        // accurate.  Bit-parity with `conv2d_quant` is guaranteed (and
        // oracle-tested) for SharedScale, the paper's serving mode.
        let wq = quant::quantize_slice(wd, w_exp, self.cfg.bits);
        let (_, gamma) = p(self.params, &format!("{name}/bn_gamma"))?;
        let (_, beta) = p(self.params, &format!("{name}/bn_beta"))?;
        let (_, mean) = p(self.params, &format!("{name}/bn_mean"))?;
        let (_, var) = p(self.params, &format!("{name}/bn_var"))?;
        let bn = fold_bn(gamma, beta, mean, var, acc_exp, out_exp)
            .with_context(|| format!("folding BN for {name}"))?;
        Ok(ConvPlan {
            name: name.into(),
            wq,
            kh: ws[0],
            kw: ws[1],
            cin: ws[2],
            cout: ws[3],
            stride,
            padding,
            in_exp,
            w_exp,
            acc_exp,
            out_exp,
            bn,
        })
    }

    /// Operand grid of one dense layer: the calibrated feature range
    /// when the table covers it (what `repro calibrate` records since
    /// the head went integer), else the grid the previous stage already
    /// produces — a degraded but always-available fallback for conv-only
    /// calibration tables, where overshooting activations clamp at the
    /// serving width instead of landing on a wider grid.
    fn dense_in_exp(&self, name: &str, incoming: i32) -> i32 {
        match self.calib.get(name) {
            Some(lc) => quant::scale_exp(lc.feat_max_abs, self.cfg.bits)
                .max(DENSE_MIN_EXP),
            None => incoming.max(DENSE_MIN_EXP),
        }
    }

    fn dense_plan(&self, spec: &DenseSpec, in_exp: i32, out_exp: Option<i32>)
                  -> Result<DensePlan> {
        let name = spec.name.as_str();
        let (ws, wd) = p(self.params, &format!("{name}/dense_w"))?;
        let (_, bd) = p(self.params, &format!("{name}/dense_b"))?;
        anyhow::ensure!(ws.len() == 2, "dense weight for {name} must be (din, dout)");
        anyhow::ensure!(ws[0] == spec.din && ws[1] == spec.dout,
                        "dense weight for {name} is {}x{}, graph says {}x{}",
                        ws[0], ws[1], spec.din, spec.dout);
        let bits = self.cfg.bits;
        let w_exp = quant::scale_exp(quant::max_abs(wd), bits)
            .max(DENSE_MIN_EXP);
        let wq = quant::quantize_slice(wd, w_exp, bits);
        let acc_exp = in_exp + w_exp;
        anyhow::ensure!((-120..=120).contains(&acc_exp),
                        "dense layer {name}: accumulator grid 2^{acc_exp} out \
                         of range (corrupt calibration table?)");
        let bstep = 2f64.powi(-acc_exp);
        let bq: Vec<i64> = bd.iter()
            .map(|&v| round_even_i64(v as f64 * bstep))
            .collect();
        anyhow::ensure!(bq.iter().all(|v| v.abs() < MAX_PLAN_INT),
                        "dense layer {name}: folded bias overflows the \
                         exactly-serializable integer range on the \
                         2^{acc_exp} accumulator grid");
        Ok(DensePlan {
            name: name.into(),
            wq,
            bq,
            din: ws[0],
            dout: ws[1],
            in_exp,
            w_exp,
            acc_exp,
            out_exp,
        })
    }
}

/// Compute each conv's post-BN activation grid (`out_exp`) from a
/// backward walk over the compiled op program: every conv lands its
/// output straight on the operand grid of the NEXT conv downstream
/// (ReLU, pooling, flatten and the residual add all preserve the grid),
/// so inter-layer requantization folds into BN.  A conv feeding the f32
/// head keeps its own grid (the head dequantizes).  Both inputs of a
/// residual add — the main-path conv and the projection shortcut —
/// receive the same target, which is what keeps residual partners on
/// one grid.
fn solve_out_exps(b: &Builder, graph: &NetGraph)
                  -> Result<BTreeMap<String, i32>> {
    let ops = &graph.ops;
    let mut target: Option<i32> = None;
    let mut outs = BTreeMap::new();
    for (i, op) in ops.iter().enumerate().rev() {
        match op {
            // the dense head imposes no grid on the conv stack: it
            // shifts its operands onto its own calibrated grid at entry
            // (the head planning lives in `build`)
            Op::Dense(_) => target = None,
            Op::ConvBn(c) => {
                let in_e = b.grids(&c.name)?.0;
                outs.insert(c.name.clone(), target.unwrap_or(in_e));
                target = Some(in_e);
            }
            Op::ResidualClose { shortcut } => {
                if target.is_none() {
                    // terminal block (the head dequantizes next): land
                    // the residual on the main-path conv's own operand
                    // grid, for both summands
                    let main = ops[..i].iter().rev()
                        .find_map(|o| match o {
                            Op::ConvBn(c) => Some(c.name.as_str()),
                            _ => None,
                        })
                        .ok_or_else(|| anyhow::anyhow!(
                            "residual block with no main-path conv"))?;
                    target = Some(b.grids(main)?.0);
                }
                if let Some(c) = shortcut {
                    outs.insert(c.name.clone(),
                                target.expect("target set above"));
                }
            }
            // grid-preserving ops: ReLU, pooling, flatten, open bracket
            _ => {}
        }
    }
    Ok(outs)
}

impl QuantPlan {
    /// Compile a plan by walking the architecture's compiled op program
    /// ([`crate::nn::graph`]) — no per-architecture code.  Errors (never
    /// panics) on missing parameters, missing calibration entries or a
    /// BN fold that cannot be represented —
    /// `coordinator::server::start_functional` surfaces these to the
    /// caller instead of bringing a worker down.
    pub fn build(params: &Params, arch: Arch, kind: SimKernel, cfg: QuantCfg,
                 calib: &Calibration) -> Result<QuantPlan> {
        anyhow::ensure!((2..=16).contains(&cfg.bits),
                        "plan supports 2..=16-bit grids, got {}", cfg.bits);
        anyhow::ensure!(
            Self::supports(kind, cfg.bits),
            "mult-kernel plans support at most 8-bit operands (the i32 conv \
             accumulator overflows at int{}); the adder kernel serves all \
             widths", cfg.bits);
        let b = Builder { params, kind, cfg, calib };
        let graph = arch.graph();
        let out_exps = solve_out_exps(&b, graph)?;
        let mut convs = BTreeMap::new();
        for spec in graph.conv_specs() {
            convs.insert(
                spec.name.clone(),
                b.conv_plan(&spec.name, spec.stride, spec.padding,
                            out_exps[&spec.name])?);
        }
        let first = graph.conv_specs().first()
            .map(|c| c.name.clone())
            .ok_or_else(|| anyhow::anyhow!(
                "{}: cannot plan a network with no conv layers", graph.id))?;
        let input_exp = convs[&first].in_exp;
        // The integer classifier head: activations enter on the grid the
        // conv stack hands over (the LAST conv's out grid — ReLU, pools,
        // flatten and the residual add all preserve it), each layer gets
        // its own calibrated operand grid, intermediates requantize onto
        // the next layer's grid and the final layer carries out_exp =
        // None (dequantize-at-the-logits).
        let head_in = graph.ops.iter().rev()
            .find_map(|op| match op {
                Op::ConvBn(c) => Some(convs[&c.name].out_exp),
                _ => None,
            })
            .unwrap_or(input_exp);
        let dense_specs = graph.dense_specs();
        let mut in_exps = Vec::with_capacity(dense_specs.len());
        let mut chain = head_in;
        for spec in &dense_specs {
            let e = b.dense_in_exp(&spec.name, chain);
            in_exps.push(e);
            chain = e;
        }
        let mut dense = BTreeMap::new();
        for (i, spec) in dense_specs.iter().enumerate() {
            let out_exp = in_exps.get(i + 1).copied();
            dense.insert(spec.name.clone(),
                         b.dense_plan(spec, in_exps[i], out_exp)?);
        }
        Ok(QuantPlan { arch, kind, cfg, convs, dense, input_exp })
    }

    /// Whether a plan can be compiled for this kernel/width pair — the
    /// ONE place the policy lives: the adder accumulator is provably
    /// i32-bounded (|acc| <= 2*qmax*K), but MULT tap products reach
    /// qmax^2, so at int16 two taps already overflow i32.
    pub fn supports(kind: SimKernel, bits: u32) -> bool {
        matches!(kind, SimKernel::Adder) || bits <= 8
    }

    /// Integer grid maximum of the plan's serving bit-width.
    pub fn qmax(&self) -> i32 {
        quant::qmax(self.cfg.bits)
    }
}

// ---------------------------------------------------------------------------
// Calibration tables as JSON (repro calibrate <-> repro serve)
// ---------------------------------------------------------------------------

/// Serialize a calibration table.  Plain `{}` float formatting is
/// shortest-round-trip in Rust, so `calibration_from_json` recovers the
/// exact f32 values.
pub fn calibration_to_json(calib: &Calibration) -> String {
    let rows: Vec<String> = calib.iter()
        .map(|(name, lc)| format!(
            "    {:?}: {{\"feat_max_abs\": {}, \"weight_max_abs\": {}}}",
            name, lc.feat_max_abs, lc.weight_max_abs))
        .collect();
    format!("{{\n  \"calibration\": {{\n{}\n  }}\n}}\n", rows.join(",\n"))
}

/// Parse a calibration table written by [`calibration_to_json`].
pub fn calibration_from_json(s: &str) -> Result<Calibration> {
    let j = Json::parse(s).context("parsing calibration JSON")?;
    let obj = j.at(&["calibration"]).and_then(|v| v.as_obj())
        .ok_or_else(|| anyhow::anyhow!(
            "calibration JSON needs a top-level \"calibration\" object"))?;
    let mut calib = Calibration::new();
    for (name, v) in obj {
        let field = |key: &str| -> Result<f32> {
            let x = v.get(key).and_then(|x| x.as_f64()).ok_or_else(
                || anyhow::anyhow!("layer {name}: missing {key}"))? as f32;
            anyhow::ensure!(x.is_finite() && x >= 0.0,
                            "layer {name}: {key} must be finite and >= 0");
            Ok(x)
        };
        calib.insert(name.clone(), LayerCalib {
            feat_max_abs: field("feat_max_abs")?,
            weight_max_abs: field("weight_max_abs")?,
        });
    }
    Ok(calib)
}

// ---------------------------------------------------------------------------
// Compiled plans as JSON (repro plan <-> repro serve --plan)
// ---------------------------------------------------------------------------

/// Format version of the plan JSON.  Bump on any incompatible change;
/// [`plan_from_json`] refuses other versions with a proper error.
pub const PLAN_JSON_VERSION: i64 = 1;

fn padding_label(p: Padding) -> &'static str {
    match p {
        Padding::Same => "same",
        Padding::Valid => "valid",
    }
}

fn mode_label(m: Mode) -> &'static str {
    match m {
        Mode::SharedScale => "shared",
        Mode::SeparateScale => "separate",
    }
}

fn join_ints<T: std::fmt::Display>(v: &[T]) -> String {
    v.iter().map(|x| x.to_string()).collect::<Vec<_>>().join(",")
}

/// Serialize a compiled plan as versioned JSON — the portable artifact
/// `repro plan` writes and `repro serve --plan` cold-starts from with no
/// calibration table and no parameter files (the quantized weights ARE
/// the plan).  Every field is an integer or a label, so the round trip
/// is exact.
pub fn plan_to_json(plan: &QuantPlan) -> String {
    let conv_rows: Vec<String> = plan.convs.iter()
        .map(|(name, c)| format!(
            "      {:?}: {{\n        \
             \"kh\": {}, \"kw\": {}, \"cin\": {}, \"cout\": {}, \
             \"stride\": {}, \"padding\": {:?},\n        \
             \"in_exp\": {}, \"w_exp\": {}, \"acc_exp\": {}, \
             \"out_exp\": {}, \"bn_shift\": {},\n        \
             \"bn_mul\": [{}],\n        \"bn_add\": [{}],\n        \
             \"wq\": [{}]\n      }}",
            name, c.kh, c.kw, c.cin, c.cout, c.stride,
            padding_label(c.padding), c.in_exp, c.w_exp, c.acc_exp,
            c.out_exp, c.bn.shift, join_ints(&c.bn.mul), join_ints(&c.bn.add),
            join_ints(&c.wq)))
        .collect();
    let dense_rows: Vec<String> = plan.dense.iter()
        .map(|(name, d)| format!(
            "      {:?}: {{\n        \
             \"din\": {}, \"dout\": {},\n        \
             \"in_exp\": {}, \"w_exp\": {}, \"acc_exp\": {}, \
             \"out_exp\": {},\n        \
             \"bq\": [{}],\n        \"wq\": [{}]\n      }}",
            name, d.din, d.dout, d.in_exp, d.w_exp, d.acc_exp,
            d.out_exp.map_or("null".to_string(), |e| e.to_string()),
            join_ints(&d.bq), join_ints(&d.wq)))
        .collect();
    format!(
        "{{\n  \"quant_plan\": {{\n    \
         \"version\": {},\n    \"arch\": {:?},\n    \"kind\": {:?},\n    \
         \"mode\": {:?},\n    \"bits\": {},\n    \"input_exp\": {},\n    \
         \"convs\": {{\n{}\n    }},\n    \"dense\": {{\n{}\n    }}\n  \
         }}\n}}\n",
        PLAN_JSON_VERSION, plan.arch.name(), plan.kind.label(),
        mode_label(plan.cfg.mode), plan.cfg.bits, plan.input_exp,
        conv_rows.join(",\n"), dense_rows.join(",\n"))
}

type JsonObj = std::collections::BTreeMap<String, Json>;

fn jfield<'j>(o: &'j JsonObj, key: &str, what: &str) -> Result<&'j Json> {
    o.get(key).ok_or_else(|| anyhow::anyhow!("{what}: missing field {key:?}"))
}

fn jint(o: &JsonObj, key: &str, what: &str) -> Result<i64> {
    let n = jfield(o, key, what)?.as_f64()
        .ok_or_else(|| anyhow::anyhow!("{what}: {key} must be a number"))?;
    anyhow::ensure!(n.fract() == 0.0 && n.abs() < MAX_PLAN_INT as f64,
                    "{what}: {key} must be an exactly-representable \
                     integer (got {n})");
    Ok(n as i64)
}

fn jusize(o: &JsonObj, key: &str, what: &str) -> Result<usize> {
    let v = jint(o, key, what)?;
    usize::try_from(v)
        .map_err(|_| anyhow::anyhow!("{what}: {key} must be non-negative"))
}

/// Exponents a plan can legitimately carry (the serving grids sit within
/// a few dozen bits of 2^0; anything wider is a corrupt or hand-mangled
/// file and must not reach the executor's shifters).
fn jexp(o: &JsonObj, key: &str, what: &str, bound: i64) -> Result<i32> {
    let v = jint(o, key, what)?;
    anyhow::ensure!(v.abs() <= bound,
                    "{what}: {key} exponent {v} out of range (|e| <= {bound})");
    Ok(v as i32)
}

fn jstr<'j>(o: &'j JsonObj, key: &str, what: &str) -> Result<&'j str> {
    jfield(o, key, what)?.as_str()
        .ok_or_else(|| anyhow::anyhow!("{what}: {key} must be a string"))
}

fn ji64_arr(o: &JsonObj, key: &str, what: &str, len: usize) -> Result<Vec<i64>> {
    let arr = jfield(o, key, what)?.as_arr()
        .ok_or_else(|| anyhow::anyhow!("{what}: {key} must be an array"))?;
    anyhow::ensure!(arr.len() == len,
                    "{what}: {key} has {} entries, expected {len}", arr.len());
    arr.iter()
        .map(|v| {
            let n = v.as_f64().ok_or_else(
                || anyhow::anyhow!("{what}: {key} entries must be numbers"))?;
            anyhow::ensure!(n.fract() == 0.0 && n.abs() < MAX_PLAN_INT as f64,
                            "{what}: {key} entries must be \
                             exactly-representable integers (got {n})");
            Ok(n as i64)
        })
        .collect()
}

fn jq_arr(o: &JsonObj, key: &str, what: &str, len: usize, qmax: i32)
          -> Result<Vec<i32>> {
    let raw = ji64_arr(o, key, what, len)?;
    raw.into_iter()
        .map(|v| {
            anyhow::ensure!(v.abs() <= qmax as i64,
                            "{what}: {key} value {v} outside the int grid \
                             (|q| <= {qmax})");
            Ok(v as i32)
        })
        .collect()
}

/// Parse and validate a plan written by [`plan_to_json`].  Corrupt or
/// mismatched files — wrong version, unknown arch, a layer set that does
/// not match the arch's compiled graph, geometry drift, exponents or
/// quantized values out of range — surface as `anyhow` errors with the
/// offending layer named; nothing here panics.
pub fn plan_from_json(s: &str) -> Result<QuantPlan> {
    let j = Json::parse(s).context("parsing quantization plan JSON")?;
    let p = j.get("quant_plan").and_then(|v| v.as_obj())
        .ok_or_else(|| anyhow::anyhow!(
            "plan JSON needs a top-level \"quant_plan\" object"))?;
    let version = jint(p, "version", "plan")?;
    anyhow::ensure!(version == PLAN_JSON_VERSION,
                    "unsupported plan version {version} (this build reads \
                     version {PLAN_JSON_VERSION}; re-run `repro plan`)");
    let arch_s = jstr(p, "arch", "plan")?;
    let arch = Arch::parse(arch_s).ok_or_else(|| anyhow::anyhow!(
        "plan is for unknown arch {arch_s:?} (this build serves {})",
        Arch::names_label()))?;
    let kind_s = jstr(p, "kind", "plan")?;
    let kind = SimKernel::parse(kind_s).ok_or_else(|| anyhow::anyhow!(
        "plan kind must be adder|mult, got {kind_s:?}"))?;
    let mode = match jstr(p, "mode", "plan")? {
        "shared" => Mode::SharedScale,
        "separate" => Mode::SeparateScale,
        m => anyhow::bail!("plan mode must be shared|separate, got {m:?}"),
    };
    let bits = jint(p, "bits", "plan")?;
    anyhow::ensure!((2..=16).contains(&bits),
                    "plan bits {bits} out of range (2..=16)");
    let bits = bits as u32;
    anyhow::ensure!(QuantPlan::supports(kind, bits),
                    "plan is int{bits} on the mult kernel, which the i32 \
                     conv accumulator cannot serve (mult caps at 8 bits)");
    let qmax = quant::qmax(bits);
    let input_exp = jexp(p, "input_exp", "plan", 64)?;
    let graph = arch.graph();

    let convs_obj = jfield(p, "convs", "plan")?.as_obj()
        .ok_or_else(|| anyhow::anyhow!("plan \"convs\" must be an object"))?;
    let conv_specs = graph.conv_specs();
    anyhow::ensure!(
        convs_obj.len() == conv_specs.len(),
        "plan has {} conv layers, arch {arch_s} has {} (arch mismatch?)",
        convs_obj.len(), conv_specs.len());
    let mut convs = BTreeMap::new();
    for spec in conv_specs {
        let name = spec.name.as_str();
        let what = format!("conv layer {name}");
        let o = convs_obj.get(name)
            .and_then(|v| v.as_obj())
            .ok_or_else(|| anyhow::anyhow!(
                "plan is missing {what} of arch {arch_s} (arch mismatch?)"))?;
        let geom = (jusize(o, "kh", &what)?, jusize(o, "kw", &what)?,
                    jusize(o, "cin", &what)?, jusize(o, "cout", &what)?,
                    jusize(o, "stride", &what)?);
        anyhow::ensure!(
            geom == (spec.kh, spec.kw, spec.cin, spec.cout, spec.stride),
            "{what}: geometry {geom:?} does not match the {arch_s} graph \
             {:?} (plan built for a different architecture?)",
            (spec.kh, spec.kw, spec.cin, spec.cout, spec.stride));
        let padding = match jstr(o, "padding", &what)? {
            "same" => Padding::Same,
            "valid" => Padding::Valid,
            pd => anyhow::bail!("{what}: padding must be same|valid, got {pd:?}"),
        };
        anyhow::ensure!(padding == spec.padding,
                        "{what}: padding does not match the {arch_s} graph");
        let shift = jint(o, "bn_shift", &what)?;
        anyhow::ensure!((0..=62).contains(&shift),
                        "{what}: bn_shift {shift} out of range (0..=62)");
        let mul = ji64_arr(o, "bn_mul", &what, spec.cout)?;
        // fold_bn keeps |mul| <= 2^30 by construction; past 2^31 the
        // executor's `acc(i32) * mul` product can overflow i64, so a
        // corrupt multiplier must be refused here, not wrap at serve
        // time.
        anyhow::ensure!(mul.iter().all(|v| v.abs() <= 1i64 << 31),
                        "{what}: bn_mul out of range (|mul| <= 2^31)");
        let bn = BnFold {
            mul,
            add: ji64_arr(o, "bn_add", &what, spec.cout)?,
            shift: shift as u32,
        };
        convs.insert(name.to_string(), ConvPlan {
            name: name.to_string(),
            wq: jq_arr(o, "wq", &what,
                       spec.kh * spec.kw * spec.cin * spec.cout, qmax)?,
            kh: spec.kh,
            kw: spec.kw,
            cin: spec.cin,
            cout: spec.cout,
            stride: spec.stride,
            padding,
            in_exp: jexp(o, "in_exp", &what, 64)?,
            w_exp: jexp(o, "w_exp", &what, 64)?,
            acc_exp: jexp(o, "acc_exp", &what, 128)?,
            out_exp: jexp(o, "out_exp", &what, 64)?,
            bn,
        });
    }
    let first = graph.conv_specs().first()
        .map(|c| c.name.clone())
        .ok_or_else(|| anyhow::anyhow!(
            "{arch_s}: cannot serve a plan for a network with no convs"))?;
    anyhow::ensure!(convs[&first].in_exp == input_exp,
                    "plan input_exp {input_exp} does not match the first \
                     conv layer's operand grid {}", convs[&first].in_exp);
    // Re-establish the residual-grid invariant `solve_out_exps`
    // guarantees at build time: a projection shortcut must land its
    // output on the SAME grid as the block's main-path conv, because
    // the executor adds the two without a requantization step (it only
    // debug-asserts the match — an untrusted file must not reach it
    // with diverging grids).
    let mut cur_conv: Option<&str> = None;
    for op in &graph.ops {
        match op {
            Op::ConvBn(c) => cur_conv = Some(c.name.as_str()),
            Op::ResidualClose { shortcut: Some(c) } => {
                let main = cur_conv.ok_or_else(|| anyhow::anyhow!(
                    "{arch_s}: residual block with no main-path conv"))?;
                anyhow::ensure!(
                    convs[&c.name].out_exp == convs[main].out_exp,
                    "conv layer {}: residual partners sit on different \
                     grids (2^{} vs {}'s 2^{})", c.name,
                    convs[&c.name].out_exp, main, convs[main].out_exp);
            }
            _ => {}
        }
    }

    let dense_obj = jfield(p, "dense", "plan")?.as_obj()
        .ok_or_else(|| anyhow::anyhow!("plan \"dense\" must be an object"))?;
    let dense_specs = graph.dense_specs();
    anyhow::ensure!(
        dense_obj.len() == dense_specs.len(),
        "plan has {} dense layers, arch {arch_s} has {} (arch mismatch?)",
        dense_obj.len(), dense_specs.len());
    let mut dense = BTreeMap::new();
    for (i, spec) in dense_specs.iter().enumerate() {
        let name = spec.name.as_str();
        let what = format!("dense layer {name}");
        let o = dense_obj.get(name)
            .and_then(|v| v.as_obj())
            .ok_or_else(|| anyhow::anyhow!(
                "plan is missing {what} of arch {arch_s} (arch mismatch?)"))?;
        let (din, dout) = (jusize(o, "din", &what)?, jusize(o, "dout", &what)?);
        anyhow::ensure!((din, dout) == (spec.din, spec.dout),
                        "{what}: shape {din}x{dout} does not match the \
                         {arch_s} graph {}x{}", spec.din, spec.dout);
        let in_exp = jexp(o, "in_exp", &what, 64)?;
        let w_exp = jexp(o, "w_exp", &what, 64)?;
        let acc_exp = jexp(o, "acc_exp", &what, 128)?;
        anyhow::ensure!(acc_exp == in_exp + w_exp,
                        "{what}: accumulator grid {acc_exp} is not in_exp + \
                         w_exp ({} + {})", in_exp, w_exp);
        let last = i + 1 == dense_specs.len();
        let out_exp = if matches!(jfield(o, "out_exp", &what)?, Json::Null) {
            None
        } else {
            Some(jexp(o, "out_exp", &what, 64)?)
        };
        anyhow::ensure!(out_exp.is_none() == last,
                        "{what}: only the final dense layer dequantizes at \
                         the logits (out_exp = null)");
        dense.insert(name.to_string(), DensePlan {
            name: name.to_string(),
            wq: jq_arr(o, "wq", &what, din * dout, qmax)?,
            bq: ji64_arr(o, "bq", &what, dout)?,
            din,
            dout,
            in_exp,
            w_exp,
            acc_exp,
            out_exp,
        });
    }
    Ok(QuantPlan {
        arch,
        kind,
        cfg: QuantCfg { bits, mode },
        convs,
        dense,
        input_exp,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::functional::synth_params;

    #[test]
    fn div_round_even_matches_float_round_even() {
        for n in -2000i64..2000 {
            for d in [1i64, 2, 3, 4, 8, 10, 64] {
                let want = quant::round_even(n as f32 / d as f32) as i64;
                assert_eq!(div_round_even(n, d), want, "{n}/{d}");
            }
        }
    }

    #[test]
    fn requant_shift_directions() {
        assert_eq!(requant_shift(5, 1), 2); // 2.5 -> even 2
        assert_eq!(requant_shift(7, 1), 4); // 3.5 -> even 4
        assert_eq!(requant_shift(-5, 1), -2);
        assert_eq!(requant_shift(3, -2), 12); // finer grid is exact
        assert_eq!(requant_shift(3, 0), 3);
    }

    #[test]
    fn requant_shift_saturates_on_absurd_finer_shifts() {
        // corrupt calibration tables can produce enormous exponent
        // gaps; the finer-grid move must saturate, never wrap or panic
        assert_eq!(requant_shift(1, -63), i64::MAX);
        assert_eq!(requant_shift(-1, -63), i64::MIN);
        assert_eq!(requant_shift(508, -120), i64::MAX);
        assert_eq!(requant_shift(0, -120), 0);
        assert_eq!(requant_shift(1, -62), 1i64 << 62); // still exact in range
    }

    #[test]
    fn fold_bn_identity_is_pure_requant() {
        // gamma=1, beta=0, mean=0, var=1: scale = 1/sqrt(1+eps), so the
        // fold is (almost) a pure grid move; acc on the same grid comes
        // back nearly unchanged.
        let n = 4;
        let f = fold_bn(&vec![1.0; n], &vec![0.0; n], &vec![0.0; n],
                        &vec![1.0; n], -3, -3).unwrap();
        for acc in [-1000i32, -1, 0, 1, 7, 1000] {
            let out = f.apply(acc, 0, i32::MAX);
            assert!((out - acc).abs() <= 1, "{acc} -> {out}");
        }
    }

    #[test]
    fn fold_bn_narrows_fraction_bits_for_big_scales() {
        // A huge scale relating a fine acc grid to a coarse out grid
        // must shrink `shift` instead of overflowing the multiplier.
        let f = fold_bn(&[1.0e5], &[0.0], &[0.0], &[1.0], 0, -4).unwrap();
        assert!(f.shift < BN_FRAC_BITS, "shift {}", f.shift);
        assert!(f.mul[0].abs() <= 1 << 30, "mul {}", f.mul[0]);
    }

    #[test]
    fn fold_bn_rejects_unrepresentable() {
        // scale so large no non-negative shift keeps mul in range
        assert!(fold_bn(&[1.0e20], &[0.0], &[0.0], &[1.0], 0, -20).is_err());
    }

    #[test]
    fn calibration_json_round_trips() {
        let mut c = Calibration::new();
        c.insert("conv1".into(), LayerCalib { feat_max_abs: 1.25, weight_max_abs: 0.375 });
        c.insert("s0b1/c2".into(), LayerCalib { feat_max_abs: 3.0e-5, weight_max_abs: 7.75 });
        let s = calibration_to_json(&c);
        let back = calibration_from_json(&s).unwrap();
        assert_eq!(back.len(), 2);
        for (k, lc) in &c {
            let b = &back[k];
            assert_eq!(b.feat_max_abs, lc.feat_max_abs, "{k}");
            assert_eq!(b.weight_max_abs, lc.weight_max_abs, "{k}");
        }
    }

    #[test]
    fn calibration_json_rejects_garbage() {
        assert!(calibration_from_json("nonsense").is_err());
        assert!(calibration_from_json("{\"x\": 1}").is_err());
        assert!(calibration_from_json(
            "{\"calibration\": {\"c\": {\"feat_max_abs\": 1}}}").is_err());
    }

    fn demo_calib(names: &[&str]) -> Calibration {
        names.iter()
            .map(|n| (n.to_string(),
                      LayerCalib { feat_max_abs: 1.0, weight_max_abs: 0.5 }))
            .collect()
    }

    #[test]
    fn build_lenet_plan_shapes() {
        let params = synth_params(Arch::Lenet5, 9);
        let calib = demo_calib(&["conv1", "conv2"]);
        let cfg = QuantCfg { bits: 8, mode: Mode::SharedScale };
        let plan = QuantPlan::build(&params, Arch::Lenet5, SimKernel::Adder,
                                    cfg, &calib).unwrap();
        assert_eq!(plan.convs.len(), 2);
        assert_eq!(plan.dense.len(), 3);
        let c1 = &plan.convs["conv1"];
        assert_eq!((c1.kh, c1.kw, c1.cin, c1.cout), (5, 5, 1, 6));
        assert_eq!(c1.wq.len(), 5 * 5 * 6);
        // shared adder: operands and accumulator share one grid
        assert_eq!(c1.in_exp, c1.w_exp);
        assert_eq!(c1.acc_exp, c1.in_exp);
        // conv1 requantizes onto conv2's operand grid
        assert_eq!(c1.out_exp, plan.convs["conv2"].in_exp);
        assert_eq!(plan.input_exp, c1.in_exp);
    }

    #[test]
    fn build_resnet_plan_covers_all_blocks() {
        let params = synth_params(Arch::Resnet8, 9);
        let names: Vec<String> = params.keys()
            .filter_map(|k| k.strip_suffix("/conv_w").map(|s| s.to_string()))
            .collect();
        let calib: Calibration = names.iter()
            .map(|n| (n.clone(), LayerCalib { feat_max_abs: 2.0, weight_max_abs: 0.5 }))
            .collect();
        let cfg = QuantCfg { bits: 8, mode: Mode::SharedScale };
        let plan = QuantPlan::build(&params, Arch::Resnet8, SimKernel::Adder,
                                    cfg, &calib).unwrap();
        assert_eq!(plan.convs.len(), names.len());
        // residual partners land on one grid: c2 and sc of the same
        // block always share out_exp
        for (name, cp) in &plan.convs {
            if let Some(pre) = name.strip_suffix("/sc") {
                assert_eq!(cp.out_exp, plan.convs[&format!("{pre}/c2")].out_exp,
                           "{name}");
            }
        }
    }

    #[test]
    fn build_covers_every_graph_arch_with_chained_grids() {
        // The graph walk must plan ANY registered architecture: every
        // conv spec gets a plan, and each conv lands its activations on
        // the grid the next conv consumes (pool/relu/residual preserve
        // grids, the terminal conv keeps its own).
        for arch in [Arch::Lenet5, Arch::Cnv6, Arch::Resnet8, Arch::Resnet32] {
            let params = synth_params(arch, 9);
            let calib: Calibration = params.keys()
                .filter_map(|k| k.strip_suffix("/conv_w"))
                .map(|n| (n.to_string(),
                          LayerCalib { feat_max_abs: 2.0, weight_max_abs: 0.5 }))
                .collect();
            let cfg = QuantCfg { bits: 8, mode: Mode::SharedScale };
            let plan = QuantPlan::build(&params, arch, SimKernel::Adder, cfg,
                                        &calib).unwrap();
            let specs = arch.graph().conv_specs();
            assert_eq!(plan.convs.len(), specs.len(), "{arch:?}");
            assert_eq!(plan.dense.len(), arch.graph().dense_specs().len());
            assert_eq!(plan.input_exp, plan.convs[&specs[0].name].in_exp);
        }
        // cnv6 is a plain stack: the chain is literal neighbour-to-
        // neighbour handoff
        let params = synth_params(Arch::Cnv6, 9);
        let calib: Calibration = (1..=6)
            .map(|i| (format!("c{i}"),
                      LayerCalib { feat_max_abs: 2.0, weight_max_abs: 0.5 }))
            .collect();
        let cfg = QuantCfg { bits: 8, mode: Mode::SharedScale };
        let plan = QuantPlan::build(&params, Arch::Cnv6, SimKernel::Adder, cfg,
                                    &calib).unwrap();
        for i in 1..6 {
            assert_eq!(plan.convs[&format!("c{i}")].out_exp,
                       plan.convs[&format!("c{}", i + 1)].in_exp, "c{i}");
        }
        // terminal conv feeds the head on its own grid
        assert_eq!(plan.convs["c6"].out_exp, plan.convs["c6"].in_exp);
    }

    #[test]
    fn build_errors_on_missing_calibration() {
        let params = synth_params(Arch::Lenet5, 9);
        let calib = demo_calib(&["conv1"]); // conv2 missing
        let cfg = QuantCfg { bits: 8, mode: Mode::SharedScale };
        let err = QuantPlan::build(&params, Arch::Lenet5, SimKernel::Adder,
                                   cfg, &calib).unwrap_err();
        assert!(format!("{err:#}").contains("conv2"), "{err:#}");
    }

    #[test]
    fn build_errors_on_missing_params() {
        let mut params = synth_params(Arch::Lenet5, 9);
        params.remove("conv2/bn_gamma");
        let calib = demo_calib(&["conv1", "conv2"]);
        let cfg = QuantCfg { bits: 8, mode: Mode::SharedScale };
        assert!(QuantPlan::build(&params, Arch::Lenet5, SimKernel::Adder,
                                 cfg, &calib).is_err());
    }

    #[test]
    fn dense_head_chains_grids_and_folds_bias() {
        let params = synth_params(Arch::Lenet5, 9);
        let mut calib = demo_calib(&["conv1", "conv2"]);
        calib.insert("fc1".into(),
                     LayerCalib { feat_max_abs: 2.0, weight_max_abs: 0.5 });
        calib.insert("fc2".into(),
                     LayerCalib { feat_max_abs: 4.0, weight_max_abs: 0.5 });
        calib.insert("fc3".into(),
                     LayerCalib { feat_max_abs: 1.0, weight_max_abs: 0.5 });
        let cfg = QuantCfg { bits: 8, mode: Mode::SharedScale };
        let plan = QuantPlan::build(&params, Arch::Lenet5, SimKernel::Adder,
                                    cfg, &calib).unwrap();
        let fc1 = &plan.dense["fc1"];
        let fc2 = &plan.dense["fc2"];
        let fc3 = &plan.dense["fc3"];
        // calibrated operand grids, intermediates landing on the NEXT
        // layer's grid, the final layer dequantizing at the logits
        assert_eq!(fc1.in_exp, quant::scale_exp(2.0, 8));
        assert_eq!(fc1.out_exp, Some(fc2.in_exp));
        assert_eq!(fc2.out_exp, Some(fc3.in_exp));
        assert_eq!(fc3.out_exp, None);
        // products compose scales; weights sit on their own static grid
        for fc in [fc1, fc2, fc3] {
            assert_eq!(fc.acc_exp, fc.in_exp + fc.w_exp, "{}", fc.name);
            assert!(fc.wq.iter().all(|&v| v.abs() <= quant::qmax(8)),
                    "{}", fc.name);
        }
        assert_eq!(fc1.wq.len(), 400 * 120);
        assert_eq!(fc1.bq.len(), 120);
        // the folded bias reproduces the f32 bias on the acc grid
        let (_, bd) = &params["fc1/dense_b"];
        let step = 2f64.powi(fc1.acc_exp);
        for (q, b) in fc1.bq.iter().zip(bd) {
            assert!((*q as f64 * step - *b as f64).abs() <= step,
                    "{q} vs {b}");
        }
    }

    #[test]
    fn dense_head_falls_back_to_incoming_grid_without_calibration() {
        // conv-only calibration tables (the pre-dense-head format) still
        // build: uncalibrated dense layers inherit the incoming grid.
        let params = synth_params(Arch::Lenet5, 9);
        let calib = demo_calib(&["conv1", "conv2"]);
        let cfg = QuantCfg { bits: 8, mode: Mode::SharedScale };
        let plan = QuantPlan::build(&params, Arch::Lenet5, SimKernel::Adder,
                                    cfg, &calib).unwrap();
        assert_eq!(plan.dense["fc1"].in_exp, plan.convs["conv2"].out_exp);
        assert_eq!(plan.dense["fc2"].in_exp, plan.dense["fc1"].in_exp);
        assert_eq!(plan.dense["fc3"].out_exp, None);
    }

    #[test]
    fn plan_json_round_trips_exactly() {
        for arch in [Arch::Lenet5, Arch::Resnet8] {
            let params = synth_params(arch, 9);
            let calib: Calibration = params.keys()
                .filter_map(|k| k.strip_suffix("/conv_w"))
                .map(|n| (n.to_string(),
                          LayerCalib { feat_max_abs: 2.0, weight_max_abs: 0.5 }))
                .collect();
            for bits in [8u32, 16] {
                let cfg = QuantCfg { bits, mode: Mode::SharedScale };
                let plan = QuantPlan::build(&params, arch, SimKernel::Adder,
                                            cfg, &calib).unwrap();
                let back = plan_from_json(&plan_to_json(&plan))
                    .unwrap_or_else(|e| panic!("{arch:?} int{bits}: {e:#}"));
                assert_eq!(back, plan, "{arch:?} int{bits}");
            }
        }
    }

    #[test]
    fn plan_json_rejects_garbage_and_bad_versions() {
        assert!(plan_from_json("nonsense").is_err());
        assert!(plan_from_json("{}").is_err());
        assert!(plan_from_json("{\"quant_plan\": {}}").is_err());
        let params = synth_params(Arch::Lenet5, 9);
        let calib = demo_calib(&["conv1", "conv2"]);
        let cfg = QuantCfg { bits: 8, mode: Mode::SharedScale };
        let plan = QuantPlan::build(&params, Arch::Lenet5, SimKernel::Adder,
                                    cfg, &calib).unwrap();
        let doc = plan_to_json(&plan);
        let bumped = doc.replace("\"version\": 1", "\"version\": 99");
        let err = plan_from_json(&bumped).unwrap_err();
        assert!(format!("{err:#}").contains("version"), "{err:#}");
    }

    #[test]
    fn build_rejects_wide_mult_plans() {
        // int16 MULT products overflow the i32 accumulator; the plan
        // compiler must refuse, while int8 mult and int16 adder build.
        let params = synth_params(Arch::Lenet5, 9);
        let calib = demo_calib(&["conv1", "conv2"]);
        let wide = QuantCfg { bits: 16, mode: Mode::SharedScale };
        let err = QuantPlan::build(&params, Arch::Lenet5, SimKernel::Mult,
                                   wide, &calib).unwrap_err();
        assert!(format!("{err:#}").contains("8-bit"), "{err:#}");
        let narrow = QuantCfg { bits: 8, mode: Mode::SharedScale };
        assert!(QuantPlan::build(&params, Arch::Lenet5, SimKernel::Mult,
                                 narrow, &calib).is_ok());
        assert!(QuantPlan::build(&params, Arch::Lenet5, SimKernel::Adder,
                                 wide, &calib).is_ok());
    }
}
