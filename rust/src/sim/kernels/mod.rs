//! Kernel-strategy subsystem for the functional-sim hot path.
//!
//! The adder conv's inner loop — accumulate `-|x - w|` (or `x * w`)
//! across taps for a block of output channels — is exactly the shape
//! SIMD absolute-difference/accumulate hardware was built for, and the
//! same loop dominates every bench, report and serving request.  This
//! module makes the inner kernel a first-class, swappable strategy:
//!
//! * [`tiled`] — the cache-blocked scalar kernel from the PR-1 engine
//!   (4 output columns x 64 output channels per pass);
//! * [`simd`] — explicitly lane-structured kernels: fixed chunks of
//!   8 f32 (or i32) output channels with per-column register
//!   accumulators, written so stable-Rust autovectorization emits
//!   packed SIMD (no nightly `std::simd`, no intrinsics);
//! * **naive** — the original 7-deep loop nests in
//!   [`crate::sim::reference`], retained as the in-crate truth.
//!
//! [`KernelStrategy`] selects between them; `Auto` resolves through the
//! `ADDERNET_KERNEL` environment variable and then a shape heuristic.
//! The single dispatch point is `sim::functional::{conv2d_with,
//! conv2d_quant_with, dense_with}` — everything (`Runner`, the serving
//! backend, the CLI, the benches) routes through those three functions.
//! `rust/tests/functional_oracle.rs` pins every strategy against the
//! naive reference: bit-identical on the integer path, within
//! tolerance on f32.

pub(crate) mod simd;
pub(crate) mod tiled;

/// Which similarity the conv kernel computes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimKernel {
    /// AdderNet: out = -sum |x - w|.
    Adder,
    /// CNN: out = sum x * w.
    Mult,
}

impl SimKernel {
    /// CLI/serialization spelling (`adder`/`mult`) — shared by the model
    /// naming convention and the plan JSON codec.
    pub fn label(self) -> &'static str {
        match self {
            SimKernel::Adder => "adder",
            SimKernel::Mult => "mult",
        }
    }

    pub fn parse(s: &str) -> Option<SimKernel> {
        match s.trim().to_ascii_lowercase().as_str() {
            "adder" => Some(SimKernel::Adder),
            "mult" => Some(SimKernel::Mult),
            _ => None,
        }
    }
}

/// How the conv/dense inner kernels execute.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KernelStrategy {
    /// The reference loop nests in [`crate::sim::reference`] — slow,
    /// obviously correct, the oracle every other strategy is tested
    /// against.
    Naive,
    /// Cache-blocked scalar engine (im2col gather + 4x64 tiles).
    Tiled,
    /// Lane-structured autovectorizing kernel (chunks of 8 channels).
    Simd,
    /// Runtime selection: `ADDERNET_KERNEL` env override if set,
    /// else [`simd`] when the channel count fills at least one lane
    /// group, else [`tiled`].
    #[default]
    Auto,
}

/// A concrete strategy after `Auto` resolution.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Resolved {
    Naive,
    Tiled,
    Simd,
}

impl KernelStrategy {
    /// Parse a CLI/env spelling: `naive`, `tiled`, `simd`, `auto`.
    pub fn parse(s: &str) -> Option<KernelStrategy> {
        match s.trim().to_ascii_lowercase().as_str() {
            "naive" => Some(KernelStrategy::Naive),
            "tiled" => Some(KernelStrategy::Tiled),
            "simd" => Some(KernelStrategy::Simd),
            "auto" => Some(KernelStrategy::Auto),
            _ => None,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            KernelStrategy::Naive => "naive",
            KernelStrategy::Tiled => "tiled",
            KernelStrategy::Simd => "simd",
            KernelStrategy::Auto => "auto",
        }
    }

    /// The `ADDERNET_KERNEL` override (the CI matrix and `repro serve`
    /// use it to pin a strategy process-wide).  Unset or unparseable
    /// values fall back to `Auto`; a bad value warns once.
    pub fn from_env() -> KernelStrategy {
        match std::env::var("ADDERNET_KERNEL") {
            Ok(v) => KernelStrategy::parse(&v).unwrap_or_else(|| {
                static WARNED: std::sync::Once = std::sync::Once::new();
                WARNED.call_once(|| {
                    eprintln!("[kernels] ignoring ADDERNET_KERNEL={v:?} \
                               (expected naive|tiled|simd|auto)");
                });
                KernelStrategy::Auto
            }),
            Err(_) => KernelStrategy::Auto,
        }
    }

    /// Resolve to a concrete strategy for a layer with `cout` output
    /// channels.  Selection order for `Auto`: `ADDERNET_KERNEL` env
    /// override, then `Simd` when `cout` fills at least one 8-wide lane
    /// group, else `Tiled` (sub-lane layers gain nothing from the lane
    /// path).  Explicit strategies always win — the oracle tests rely
    /// on that to pin each kernel regardless of the environment.
    pub fn resolve(self, cout: usize) -> Resolved {
        match self {
            KernelStrategy::Naive => Resolved::Naive,
            KernelStrategy::Tiled => Resolved::Tiled,
            KernelStrategy::Simd => Resolved::Simd,
            KernelStrategy::Auto => match KernelStrategy::from_env() {
                KernelStrategy::Auto => {
                    if cout >= simd::LANES {
                        Resolved::Simd
                    } else {
                        Resolved::Tiled
                    }
                }
                pinned => pinned.resolve(cout),
            },
        }
    }
}

/// Gather the im2col patches for one (batch, output-row) pair:
/// `rowbuf[ow * k_taps + (ky * kw + kx) * cin + ci]`, zero-filled at the
/// SAME-padding border.  Interior rows copy whole kw x cin runs.  Shared
/// by the tiled and simd strategies (the naive strategy indexes the
/// input directly).
#[allow(clippy::too_many_arguments)]
pub(crate) fn gather_row<T: Copy + Default>(
    data: &[T], h: usize, w_in: usize, cin: usize, kh: usize, kw: usize,
    b: usize, oh: usize, stride: usize, pt: usize, pl: usize, wo: usize,
    rowbuf: &mut [T],
) {
    let k_taps = kh * kw * cin;
    for ow in 0..wo {
        let patch = &mut rowbuf[ow * k_taps..(ow + 1) * k_taps];
        let x0 = (ow * stride) as isize - pl as isize;
        for ky in 0..kh {
            let iy = (oh * stride + ky) as isize - pt as isize;
            let dst = &mut patch[ky * kw * cin..(ky + 1) * kw * cin];
            if iy < 0 || iy >= h as isize {
                dst.iter_mut().for_each(|v| *v = T::default());
                continue;
            }
            let row_off = (b * h + iy as usize) * w_in;
            if x0 >= 0 && x0 + kw as isize <= w_in as isize {
                let off = (row_off + x0 as usize) * cin;
                dst.copy_from_slice(&data[off..off + kw * cin]);
            } else {
                for kx in 0..kw {
                    let ix = x0 + kx as isize;
                    let d = &mut dst[kx * cin..(kx + 1) * cin];
                    if ix < 0 || ix >= w_in as isize {
                        d.iter_mut().for_each(|v| *v = T::default());
                    } else {
                        let off = (row_off + ix as usize) * cin;
                        d.copy_from_slice(&data[off..off + cin]);
                    }
                }
            }
        }
    }
}

/// Row-kernel signature shared by the tiled and simd strategies: consume
/// one gathered output row (`rowbuf`, `wo * k_taps` wide) against the
/// (k_taps x cout) weight matrix into `out_row` (`wo * cout` wide).
pub(crate) type ConvRow<T> = fn(&[T], usize, &[T], usize, SimKernel, &mut [T]);

/// Dense-kernel signature: one batch row `xrow` (din) against `w`
/// (din x dout) + `bias` into `orow` (dout).
pub(crate) type DenseRow = fn(&[f32], &[f32], &[f32], usize, &mut [f32]);

/// Integer dense-kernel signature: one batch row of i32 operands against
/// the quantized (din x dout) weights, bias pre-folded onto the
/// accumulator grid, widened i64 accumulators out.
pub(crate) type DenseIntRow = fn(&[i32], &[i32], &[i64], usize, &mut [i64]);

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_kernel_parse_round_trips_labels() {
        for k in [SimKernel::Adder, SimKernel::Mult] {
            assert_eq!(SimKernel::parse(k.label()), Some(k));
        }
        assert_eq!(SimKernel::parse(" Mult "), Some(SimKernel::Mult));
        assert_eq!(SimKernel::parse("xnor"), None);
    }

    #[test]
    fn parse_round_trips_labels() {
        for s in [KernelStrategy::Naive, KernelStrategy::Tiled,
                  KernelStrategy::Simd, KernelStrategy::Auto] {
            assert_eq!(KernelStrategy::parse(s.label()), Some(s));
        }
        assert_eq!(KernelStrategy::parse(" SIMD "), Some(KernelStrategy::Simd));
        assert_eq!(KernelStrategy::parse("winograd"), None);
    }

    #[test]
    fn explicit_strategies_resolve_to_themselves() {
        for (s, r) in [(KernelStrategy::Naive, Resolved::Naive),
                       (KernelStrategy::Tiled, Resolved::Tiled),
                       (KernelStrategy::Simd, Resolved::Simd)] {
            assert_eq!(s.resolve(1), r);
            assert_eq!(s.resolve(512), r);
        }
    }

    #[test]
    fn auto_heuristic_by_channel_count() {
        // Only meaningful when the env override is absent; the CI
        // matrix legs pin ADDERNET_KERNEL, so accept the pinned value
        // too rather than mutating the process environment here.
        let expect = match KernelStrategy::from_env() {
            KernelStrategy::Auto => (Resolved::Tiled, Resolved::Simd),
            pinned => (pinned.resolve(1), pinned.resolve(64)),
        };
        assert_eq!(KernelStrategy::Auto.resolve(1), expect.0);
        assert_eq!(KernelStrategy::Auto.resolve(64), expect.1);
    }
}
