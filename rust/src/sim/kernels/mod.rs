//! Kernel-strategy subsystem for the functional-sim hot path.
//!
//! The adder conv's inner loop — accumulate `-|x - w|` (or `x * w`)
//! across taps for a block of output channels — is exactly the shape
//! SIMD absolute-difference/accumulate hardware was built for, and the
//! same loop dominates every bench, report and serving request.  This
//! module makes the inner kernel a first-class, swappable strategy:
//!
//! * [`tiled`] — the cache-blocked scalar kernel from the PR-1 engine
//!   (4 output columns x 64 output channels per pass);
//! * [`simd`] — explicitly lane-structured kernels: fixed chunks of
//!   8 f32 (or i32) output channels with per-column register
//!   accumulators, written so stable-Rust autovectorization emits
//!   packed SIMD (no nightly `std::simd`, no intrinsics);
//! * [`winograd`] — transform-domain F(2x2, 3x3) kernels: the exact
//!   integer mult conv (bit-identical by algebraic exactness — 2.25x
//!   less inner-loop arithmetic on 3x3/stride-1 layers) plus Li
//!   et al.'s approximate l1 adder reformulation behind an explicit
//!   opt-in.  A shape guard ([`winograd::applies`]) confines it to
//!   3x3/stride-1 integer convs; everywhere else (other shapes, f32,
//!   dense) the strategy falls back to the `Auto` heuristic's pick, so
//!   every arch serves end-to-end under `--kernel winograd`;
//! * **naive** — the original 7-deep loop nests in
//!   [`crate::sim::reference`], retained as the in-crate truth.
//!
//! [`KernelStrategy`] selects between them; `Auto` resolves through the
//! `ADDERNET_KERNEL` environment variable and then a shape heuristic.
//! The single dispatch point is `sim::functional::{conv2d_with,
//! conv2d_quant_with, dense_with}` — everything (`Runner`, the serving
//! backend, the CLI, the benches) routes through those three functions.
//! `rust/tests/functional_oracle.rs` pins every strategy against the
//! naive reference: bit-identical on the integer path, within
//! tolerance on f32.

pub(crate) mod simd;
pub(crate) mod tiled;
pub mod winograd;

/// Which similarity the conv kernel computes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimKernel {
    /// AdderNet: out = -sum |x - w|.
    Adder,
    /// CNN: out = sum x * w.
    Mult,
}

impl SimKernel {
    /// CLI/serialization spelling (`adder`/`mult`) — shared by the model
    /// naming convention and the plan JSON codec.
    pub fn label(self) -> &'static str {
        match self {
            SimKernel::Adder => "adder",
            SimKernel::Mult => "mult",
        }
    }

    pub fn parse(s: &str) -> Option<SimKernel> {
        match s.trim().to_ascii_lowercase().as_str() {
            "adder" => Some(SimKernel::Adder),
            "mult" => Some(SimKernel::Mult),
            _ => None,
        }
    }
}

/// How the conv/dense inner kernels execute.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KernelStrategy {
    /// The reference loop nests in [`crate::sim::reference`] — slow,
    /// obviously correct, the oracle every other strategy is tested
    /// against.
    Naive,
    /// Cache-blocked scalar engine (im2col gather + 4x64 tiles).
    Tiled,
    /// Lane-structured autovectorizing kernel (chunks of 8 channels).
    Simd,
    /// Transform-domain F(2x2, 3x3) engine on eligible integer convs
    /// (exact on the mult kernel); the `Auto` heuristic's pick
    /// everywhere the [`winograd::applies`] shape guard says no.
    Winograd,
    /// Runtime selection: `ADDERNET_KERNEL` env override if set,
    /// else [`simd`] when the channel count fills at least one lane
    /// group, else [`tiled`].
    #[default]
    Auto,
}

/// A concrete row/dense strategy after `Auto` resolution.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Resolved {
    Naive,
    Tiled,
    Simd,
}

impl Resolved {
    pub fn label(self) -> &'static str {
        match self {
            Resolved::Naive => "naive",
            Resolved::Tiled => "tiled",
            Resolved::Simd => "simd",
        }
    }
}

/// A concrete conv engine after the shape-aware [`KernelStrategy::
/// resolve_conv`] resolution: either one of the row-kernel strategies,
/// or a whole-tensor Winograd path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ResolvedConv {
    /// Row-gather engines (and the naive oracle loops).
    Row(Resolved),
    /// Exact integer F(2x2, 3x3) transform-domain mult conv.
    Winograd,
    /// Li et al.'s approximate l1 transform-domain adder conv
    /// (explicit opt-in only — never chosen silently).
    WinogradL1,
}

impl ResolvedConv {
    pub fn label(self) -> &'static str {
        match self {
            ResolvedConv::Row(r) => r.label(),
            ResolvedConv::Winograd => "winograd",
            ResolvedConv::WinogradL1 => "winograd_l1",
        }
    }
}

impl KernelStrategy {
    /// Parse a CLI/env spelling: `naive`, `tiled`, `simd`, `winograd`,
    /// `auto`.
    pub fn parse(s: &str) -> Option<KernelStrategy> {
        match s.trim().to_ascii_lowercase().as_str() {
            "naive" => Some(KernelStrategy::Naive),
            "tiled" => Some(KernelStrategy::Tiled),
            "simd" => Some(KernelStrategy::Simd),
            "winograd" => Some(KernelStrategy::Winograd),
            "auto" => Some(KernelStrategy::Auto),
            _ => None,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            KernelStrategy::Naive => "naive",
            KernelStrategy::Tiled => "tiled",
            KernelStrategy::Simd => "simd",
            KernelStrategy::Winograd => "winograd",
            KernelStrategy::Auto => "auto",
        }
    }

    /// The `ADDERNET_KERNEL` override (the CI matrix and `repro serve`
    /// use it to pin a strategy process-wide).  Unset or unparseable
    /// values fall back to `Auto`; a bad value warns once.
    pub fn from_env() -> KernelStrategy {
        match std::env::var("ADDERNET_KERNEL") {
            Ok(v) => KernelStrategy::parse(&v).unwrap_or_else(|| {
                static WARNED: std::sync::Once = std::sync::Once::new();
                WARNED.call_once(|| {
                    eprintln!("[kernels] ignoring ADDERNET_KERNEL={v:?} \
                               (expected naive|tiled|simd|winograd|auto)");
                });
                KernelStrategy::Auto
            }),
            Err(_) => KernelStrategy::Auto,
        }
    }

    /// The `Auto` shape heuristic: `Simd` when `cout` fills at least one
    /// 8-wide lane group, else `Tiled` — also the fallback pick wherever
    /// `Winograd` does not apply (f32, dense, ineligible conv shapes).
    fn heuristic(cout: usize) -> Resolved {
        if cout >= simd::LANES {
            Resolved::Simd
        } else {
            Resolved::Tiled
        }
    }

    /// Resolve to a concrete row/dense strategy for a layer with `cout`
    /// output channels.  Selection order for `Auto`: `ADDERNET_KERNEL`
    /// env override, then the [`Self::heuristic`] shape pick.  Explicit
    /// strategies always win — the oracle tests rely on that to pin each
    /// kernel regardless of the environment.  `Winograd` resolves to the
    /// heuristic pick here: the transform path exists only for eligible
    /// integer convs, which route through [`Self::resolve_conv`]
    /// instead; every other call site (f32 convs, dense layers) gets the
    /// `Auto` fallback this returns.
    pub fn resolve(self, cout: usize) -> Resolved {
        match self {
            KernelStrategy::Naive => Resolved::Naive,
            KernelStrategy::Tiled => Resolved::Tiled,
            KernelStrategy::Simd => Resolved::Simd,
            KernelStrategy::Winograd => Self::heuristic(cout),
            KernelStrategy::Auto => match KernelStrategy::from_env() {
                KernelStrategy::Auto => Self::heuristic(cout),
                pinned => pinned.resolve(cout),
            },
        }
    }

    /// Shape-aware resolution for INTEGER convs — the one place the
    /// Winograd transform path can be chosen.  `Winograd` (explicit or
    /// via the `ADDERNET_KERNEL` pin) takes the transform-domain engine
    /// exactly when the [`winograd::applies`] guard passes AND the
    /// kernel family permits it: the mult conv is algebraically exact;
    /// the adder conv additionally requires the explicit
    /// `ADDERNET_WINOGRAD_ADDER=approx` opt-in (the l1 reformulation is
    /// an approximation, so `Auto`/default dispatch never picks it).
    /// Every other case falls back to [`Self::resolve`]'s pick, which
    /// keeps all registered archs servable under `--kernel winograd`.
    pub fn resolve_conv(self, cout: usize, kh: usize, kw: usize,
                        stride: usize, cin: usize, kind: SimKernel)
                        -> ResolvedConv {
        match self {
            KernelStrategy::Winograd => {
                if winograd::applies(kh, kw, stride, cin) {
                    match kind {
                        SimKernel::Mult => ResolvedConv::Winograd,
                        SimKernel::Adder if winograd::adder_l1_opted_in() => {
                            ResolvedConv::WinogradL1
                        }
                        SimKernel::Adder => {
                            ResolvedConv::Row(Self::heuristic(cout))
                        }
                    }
                } else {
                    ResolvedConv::Row(Self::heuristic(cout))
                }
            }
            KernelStrategy::Auto => match KernelStrategy::from_env() {
                KernelStrategy::Auto => {
                    ResolvedConv::Row(Self::heuristic(cout))
                }
                pinned => pinned.resolve_conv(cout, kh, kw, stride, cin, kind),
            },
            explicit => ResolvedConv::Row(explicit.resolve(cout)),
        }
    }
}

/// Observability hook: count each kernel dispatch by the concrete engine
/// it resolved to — `addernet_kernel_resolved_total{kernel="simd"}` in
/// the global metrics registry.  `Auto` and the Winograd shape guard
/// make the concrete pick invisible from the call site; this (plus the
/// per-layer `kernel` column in `repro profile`) records it.
pub(crate) fn note_resolution(label: &'static str) {
    crate::obs::registry::global()
        .counter(&format!("addernet_kernel_resolved_total{{kernel=\"{label}\"}}"),
                 "kernel dispatches per concrete engine")
        .inc();
}

/// Gather the im2col patches for one (batch, output-row) pair:
/// `rowbuf[ow * k_taps + (ky * kw + kx) * cin + ci]`, zero-filled at the
/// SAME-padding border.  Interior rows copy whole kw x cin runs.  Shared
/// by the tiled and simd strategies (the naive strategy indexes the
/// input directly).
#[allow(clippy::too_many_arguments)]
pub(crate) fn gather_row<T: Copy + Default>(
    data: &[T], h: usize, w_in: usize, cin: usize, kh: usize, kw: usize,
    b: usize, oh: usize, stride: usize, pt: usize, pl: usize, wo: usize,
    rowbuf: &mut [T],
) {
    let k_taps = kh * kw * cin;
    for ow in 0..wo {
        let patch = &mut rowbuf[ow * k_taps..(ow + 1) * k_taps];
        let x0 = (ow * stride) as isize - pl as isize;
        for ky in 0..kh {
            let iy = (oh * stride + ky) as isize - pt as isize;
            let dst = &mut patch[ky * kw * cin..(ky + 1) * kw * cin];
            if iy < 0 || iy >= h as isize {
                dst.iter_mut().for_each(|v| *v = T::default());
                continue;
            }
            let row_off = (b * h + iy as usize) * w_in;
            if x0 >= 0 && x0 + kw as isize <= w_in as isize {
                let off = (row_off + x0 as usize) * cin;
                dst.copy_from_slice(&data[off..off + kw * cin]);
            } else {
                for kx in 0..kw {
                    let ix = x0 + kx as isize;
                    let d = &mut dst[kx * cin..(kx + 1) * cin];
                    if ix < 0 || ix >= w_in as isize {
                        d.iter_mut().for_each(|v| *v = T::default());
                    } else {
                        let off = (row_off + ix as usize) * cin;
                        d.copy_from_slice(&data[off..off + cin]);
                    }
                }
            }
        }
    }
}

/// Row-kernel signature shared by the tiled and simd strategies: consume
/// one gathered output row (`rowbuf`, `wo * k_taps` wide) against the
/// (k_taps x cout) weight matrix into `out_row` (`wo * cout` wide).
pub(crate) type ConvRow<T> = fn(&[T], usize, &[T], usize, SimKernel, &mut [T]);

/// Dense-kernel signature: one batch row `xrow` (din) against `w`
/// (din x dout) + `bias` into `orow` (dout).
pub(crate) type DenseRow = fn(&[f32], &[f32], &[f32], usize, &mut [f32]);

/// Integer dense-kernel signature: one batch row of i32 operands against
/// the quantized (din x dout) weights, bias pre-folded onto the
/// accumulator grid, widened i64 accumulators out.
pub(crate) type DenseIntRow = fn(&[i32], &[i32], &[i64], usize, &mut [i64]);

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_kernel_parse_round_trips_labels() {
        for k in [SimKernel::Adder, SimKernel::Mult] {
            assert_eq!(SimKernel::parse(k.label()), Some(k));
        }
        assert_eq!(SimKernel::parse(" Mult "), Some(SimKernel::Mult));
        assert_eq!(SimKernel::parse("xnor"), None);
    }

    #[test]
    fn parse_round_trips_labels() {
        for s in [KernelStrategy::Naive, KernelStrategy::Tiled,
                  KernelStrategy::Simd, KernelStrategy::Winograd,
                  KernelStrategy::Auto] {
            assert_eq!(KernelStrategy::parse(s.label()), Some(s));
        }
        assert_eq!(KernelStrategy::parse(" SIMD "), Some(KernelStrategy::Simd));
        assert_eq!(KernelStrategy::parse("fft"), None);
    }

    #[test]
    fn explicit_strategies_resolve_to_themselves() {
        for (s, r) in [(KernelStrategy::Naive, Resolved::Naive),
                       (KernelStrategy::Tiled, Resolved::Tiled),
                       (KernelStrategy::Simd, Resolved::Simd)] {
            assert_eq!(s.resolve(1), r);
            assert_eq!(s.resolve(512), r);
        }
    }

    #[test]
    fn auto_heuristic_by_channel_count() {
        // Only meaningful when the env override is absent; the CI
        // matrix legs pin ADDERNET_KERNEL, so accept the pinned value
        // too rather than mutating the process environment here.
        let expect = match KernelStrategy::from_env() {
            KernelStrategy::Auto => (Resolved::Tiled, Resolved::Simd),
            pinned => (pinned.resolve(1), pinned.resolve(64)),
        };
        assert_eq!(KernelStrategy::Auto.resolve(1), expect.0);
        assert_eq!(KernelStrategy::Auto.resolve(64), expect.1);
    }

    #[test]
    fn winograd_resolves_by_shape_and_kind() {
        let w = KernelStrategy::Winograd;
        // eligible integer mult conv -> the exact transform path
        assert_eq!(w.resolve_conv(16, 3, 3, 1, 16, SimKernel::Mult),
                   ResolvedConv::Winograd);
        // adder convs never take the transform path silently (the l1
        // opt-in env is not set in the test environment)
        if !winograd::adder_l1_opted_in() {
            assert_eq!(w.resolve_conv(16, 3, 3, 1, 16, SimKernel::Adder),
                       ResolvedConv::Row(Resolved::Simd));
        }
        // shape-guard fallbacks: 1x1, 5x5, strided, too-wide cin
        for (kh, kw, stride, cin) in
            [(1, 1, 1, 16), (5, 5, 1, 16), (3, 3, 2, 16), (3, 3, 3, 16),
             (3, 3, 1, winograd::MAX_CIN + 1)] {
            assert_eq!(w.resolve_conv(64, kh, kw, stride, cin, SimKernel::Mult),
                       ResolvedConv::Row(Resolved::Simd),
                       "guard failed for k{kh}x{kw} s{stride} cin{cin}");
            assert_eq!(w.resolve_conv(2, kh, kw, stride, cin, SimKernel::Mult),
                       ResolvedConv::Row(Resolved::Tiled));
        }
        // the row-only resolve (f32/dense call sites) takes the
        // heuristic pick, never a transform variant
        assert_eq!(w.resolve(64), Resolved::Simd);
        assert_eq!(w.resolve(2), Resolved::Tiled);
        // explicit row strategies resolve conv shapes to themselves
        assert_eq!(KernelStrategy::Simd.resolve_conv(4, 3, 3, 1, 8,
                                                     SimKernel::Mult),
                   ResolvedConv::Row(Resolved::Simd));
        assert_eq!(KernelStrategy::Naive.resolve_conv(4, 3, 3, 1, 8,
                                                      SimKernel::Adder),
                   ResolvedConv::Row(Resolved::Naive));
    }

    #[test]
    fn resolved_labels_are_distinct() {
        let labels = [ResolvedConv::Row(Resolved::Naive).label(),
                      ResolvedConv::Row(Resolved::Tiled).label(),
                      ResolvedConv::Row(Resolved::Simd).label(),
                      ResolvedConv::Winograd.label(),
                      ResolvedConv::WinogradL1.label()];
        for (i, a) in labels.iter().enumerate() {
            for b in labels.iter().skip(i + 1) {
                assert_ne!(a, b);
            }
        }
    }
}
