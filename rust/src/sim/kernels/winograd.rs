//! Winograd F(2x2, 3x3) transform-domain conv kernels.
//!
//! The minimal-filtering identity `Y = At [ (G g Gt) . (Bt d Bt') ] A`
//! computes a 2x2 output tile of a 3x3/stride-1 convolution with 16
//! elementwise products instead of 36 MACs — a 2.25x cut in inner-loop
//! arithmetic.  Two sub-kernels with different correctness contracts
//! live here:
//!
//! * [`conv2d_int_mult`] — the **exact** integer mult conv.  `B` and `A`
//!   have only 0/±1 entries, so the data and output transforms are
//!   integer sums; `G` has ½ entries, so weights are transformed with
//!   `2G` instead, keeping them integral at 4x scale.  The transform
//!   identity then yields exactly `4 *` the direct i32 conv accumulator,
//!   and the final exact division by 4 restores it — **bit-identical**
//!   to the naive/tiled/simd row kernels, which is what lets
//!   `KernelStrategy::Winograd` slot under the existing int-path oracle
//!   contract with no tolerance.
//!
//! * [`conv2d_int_adder_l1`] — Li et al.'s transform-domain **adder**
//!   reformulation ("Winograd Algorithm for AdderNet", arXiv:2105.05530):
//!   the elementwise product is replaced by `-|u - v|` and the output
//!   transform by `|A|` so it only aggregates.  This is an
//!   **approximation by design** (the l1 metric does not factor through
//!   the Winograd transforms), so it must never silently replace the
//!   exact adder conv: dispatch reaches it only through the explicit
//!   [`adder_l1_opted_in`] opt-in (`ADDERNET_WINOGRAD_ADDER=approx`) on
//!   top of `--kernel winograd`, and it carries its own tolerance-based
//!   oracle in `tests/functional_oracle.rs` instead of the bit-identity
//!   contract.
//!
//! Both kernels apply only to 3x3/stride-1 (dilation-1) convs — the
//! [`applies`] shape guard; `KernelStrategy::resolve_conv` falls back to
//! the `Auto` heuristic's row-kernel pick everywhere else, so every
//! registered arch serves end-to-end under `--kernel winograd`.
//!
//! Transform matrices (F(2x2, 3x3), Lavin & Gray layout):
//!
//! ```text
//! Bt = [1  0 -1  0]    2G = [2  0  0]    At = [1 1  1  0]
//!      [0  1  1  0]         [1  1  1]         [0 1 -1 -1]
//!      [0 -1  1  0]         [1 -1  1]
//!      [0  1  0 -1]         [0  0  2]
//! ```
//!
//! Overflow bounds for the exact path: operands are capped at 8 bits by
//! `QuantPlan::supports` (|q| <= 127), so |U| <= 9*127, |V| <= 4*127 and
//! a transform-domain tap product is <= 36*127^2 = 580_644 — the i32
//! elementwise accumulator is safe up to [`MAX_CIN`] input channels
//! (the shape guard falls back beyond it).  The inverse transform sums
//! up to 9 such accumulators in i64 headroom; the exact /4 lands back on
//! the direct conv's i32 accumulator value.

use crate::util::threads::parallel_chunks;

/// Input-channel cap for the exact mult path's i32 transform-domain
/// accumulator: 36 * 127^2 * 3600 < 2^31.  Registered archs top out at
/// 512 channels; wider convs fall back to the row kernels.
pub const MAX_CIN: usize = 3600;

/// Shape guard: Winograd F(2x2, 3x3) covers exactly the 3x3/stride-1
/// convs (dilation is always 1 in this engine).
pub fn applies(kh: usize, kw: usize, stride: usize, cin: usize) -> bool {
    kh == 3 && kw == 3 && stride == 1 && cin <= MAX_CIN
}

/// The explicit opt-in for the approximate l1 adder reformulation:
/// `ADDERNET_WINOGRAD_ADDER=approx` (read once per process).  Without
/// it, adder convs under `--kernel winograd` keep the exact row-kernel
/// fallback — `Auto` never resolves to the approximation.
pub fn adder_l1_opted_in() -> bool {
    static OPTED: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *OPTED.get_or_init(|| {
        std::env::var("ADDERNET_WINOGRAD_ADDER")
            .map(|v| v.trim().eq_ignore_ascii_case("approx"))
            .unwrap_or(false)
    })
}

/// Transform every (ci, co) 3x3 filter into the 4x4 Winograd domain with
/// `2G` (integral, 4x scale): `U[(pos * cin + ci) * cout + co]`,
/// `pos = 4*r + c`.  The per-position `cin`-major layout matches the
/// elementwise stage's access pattern (broadcast one V value across a
/// contiguous cout row).
fn transform_weights(wdat: &[i32], cin: usize, cout: usize) -> Vec<i32> {
    let mut u = vec![0i32; 16 * cin * cout];
    for ci in 0..cin {
        for co in 0..cout {
            let g = |ky: usize, kx: usize| wdat[((ky * 3 + kx) * cin + ci) * cout + co];
            // t = (2G) . g, one 4-row column per kernel column kx
            let mut t = [[0i32; 3]; 4];
            for (kx, col) in (0..3).map(|kx| (kx, [g(0, kx), g(1, kx), g(2, kx)])) {
                t[0][kx] = 2 * col[0];
                t[1][kx] = col[0] + col[1] + col[2];
                t[2][kx] = col[0] - col[1] + col[2];
                t[3][kx] = 2 * col[2];
            }
            // U = t . (2G)t
            for (r, tr) in t.iter().enumerate() {
                let row = [
                    2 * tr[0],
                    tr[0] + tr[1] + tr[2],
                    tr[0] - tr[1] + tr[2],
                    2 * tr[2],
                ];
                for (c, &v) in row.iter().enumerate() {
                    u[((r * 4 + c) * cin + ci) * cout + co] = v;
                }
            }
        }
    }
    u
}

/// Gather the zero-padded 4x4 x cin input patch for the tile whose
/// top-left output is (2*t, ow0): `patch[(ky * 4 + kx) * cin + ci]`.
#[allow(clippy::too_many_arguments)]
fn gather_patch(xq: &[i32], h: usize, w_in: usize, cin: usize, b: usize,
                t: usize, ow0: usize, pt: usize, pl: usize, patch: &mut [i32]) {
    let x0 = ow0 as isize - pl as isize;
    for ky in 0..4 {
        let iy = (2 * t + ky) as isize - pt as isize;
        let dst = &mut patch[ky * 4 * cin..(ky + 1) * 4 * cin];
        if iy < 0 || iy >= h as isize {
            dst.iter_mut().for_each(|v| *v = 0);
            continue;
        }
        let row_off = (b * h + iy as usize) * w_in;
        if x0 >= 0 && x0 + 4 <= w_in as isize {
            let off = (row_off + x0 as usize) * cin;
            dst.copy_from_slice(&xq[off..off + 4 * cin]);
        } else {
            for kx in 0..4 {
                let ix = x0 + kx as isize;
                let d = &mut dst[kx * cin..(kx + 1) * cin];
                if ix < 0 || ix >= w_in as isize {
                    d.iter_mut().for_each(|v| *v = 0);
                } else {
                    let off = (row_off + ix as usize) * cin;
                    d.copy_from_slice(&xq[off..off + cin]);
                }
            }
        }
    }
}

/// Data transform `V = Bt d B` for every input channel of one gathered
/// patch: `vbuf[pos * cin + ci]`.  Bt entries are 0/±1, so this is pure
/// integer adds.
fn transform_data(patch: &[i32], cin: usize, vbuf: &mut [i32]) {
    for ci in 0..cin {
        let d = |pos: usize| patch[pos * cin + ci];
        // bt = Bt . d (rows), then v = bt . B (columns)
        let mut bt = [0i32; 16];
        for c in 0..4 {
            let (d0, d1, d2, d3) = (d(c), d(4 + c), d(8 + c), d(12 + c));
            bt[c] = d0 - d2;
            bt[4 + c] = d1 + d2;
            bt[8 + c] = d2 - d1;
            bt[12 + c] = d1 - d3;
        }
        for r in 0..4 {
            let (b0, b1, b2, b3) = (bt[4 * r], bt[4 * r + 1], bt[4 * r + 2], bt[4 * r + 3]);
            vbuf[(4 * r) * cin + ci] = b0 - b2;
            vbuf[(4 * r + 1) * cin + ci] = b1 + b2;
            vbuf[(4 * r + 2) * cin + ci] = b2 - b1;
            vbuf[(4 * r + 3) * cin + ci] = b1 - b3;
        }
    }
}

/// One tile-row of the exact mult path: all 2x2 output tiles with top
/// row `2*t` of image `b`, written into `out_rows` (`rows` output rows
/// of `wo * cout`; `rows == 1` drops the tile's bottom row at an odd
/// output-height tail).
#[allow(clippy::too_many_arguments)]
fn tile_row_mult(xq: &[i32], h: usize, w_in: usize, cin: usize, u: &[i32],
                 cout: usize, b: usize, t: usize, pt: usize, pl: usize,
                 wo: usize, out_rows: &mut [i32], rows: usize,
                 patch: &mut [i32], vbuf: &mut [i32], m: &mut [i32]) {
    let mut ow0 = 0;
    while ow0 < wo {
        gather_patch(xq, h, w_in, cin, b, t, ow0, pt, pl, patch);
        transform_data(patch, cin, vbuf);
        // Elementwise stage: 16 independent (cin -> cout) contractions.
        m.iter_mut().for_each(|v| *v = 0);
        for pos in 0..16 {
            let mrow = &mut m[pos * cout..(pos + 1) * cout];
            for ci in 0..cin {
                let xv = vbuf[pos * cin + ci];
                if xv == 0 {
                    continue;
                }
                let urow = &u[(pos * cin + ci) * cout..(pos * cin + ci + 1) * cout];
                for (a, &uv) in mrow.iter_mut().zip(urow) {
                    *a += xv * uv;
                }
            }
        }
        // Inverse transform At M A in i64 headroom; the result is 4x the
        // direct conv accumulator (the 2G weight scaling, twice), so the
        // shift by 2 is exact.
        let cols = if ow0 + 1 < wo { 2 } else { 1 };
        for co in 0..cout {
            let mm = |pos: usize| m[pos * cout + co] as i64;
            let at0 = [mm(0) + mm(4) + mm(8), mm(1) + mm(5) + mm(9),
                       mm(2) + mm(6) + mm(10), mm(3) + mm(7) + mm(11)];
            let at1 = [mm(4) - mm(8) - mm(12), mm(5) - mm(9) - mm(13),
                       mm(6) - mm(10) - mm(14), mm(7) - mm(11) - mm(15)];
            let y = [[at0[0] + at0[1] + at0[2], at0[1] - at0[2] - at0[3]],
                     [at1[0] + at1[1] + at1[2], at1[1] - at1[2] - at1[3]]];
            for (r, yr) in y.iter().enumerate().take(rows) {
                for (c, &v) in yr.iter().enumerate().take(cols) {
                    debug_assert_eq!(v & 3, 0, "winograd 4x output not divisible");
                    out_rows[(r * wo + ow0 + c) * cout + co] = (v >> 2) as i32;
                }
            }
        }
        ow0 += 2;
    }
}

/// Exact integer Winograd mult conv over already-quantized operands —
/// the transform-domain twin of the row-kernel engines in
/// `functional::conv2d_int_with`, bit-identical to them by algebraic
/// exactness (see module docs).  `geom` is conv_geometry's
/// `(pt, pl, ho, wo)`; `wdat` is the HWIO 3x3 filter block.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_int_mult(xq: &[i32], shape: (usize, usize, usize, usize),
                       wdat: &[i32], cin: usize, cout: usize,
                       geom: (usize, usize, usize, usize), max_threads: usize,
                       out: &mut [i32]) {
    let (n, h, w_in, xc) = shape;
    let (pt, pl, ho, wo) = geom;
    assert_eq!(xc, cin, "cin mismatch");
    assert_eq!(wdat.len(), 9 * cin * cout, "winograd expects a 3x3 filter block");
    assert_eq!(out.len(), n * ho * wo * cout, "output size mismatch");
    if out.is_empty() {
        return;
    }
    let u = transform_weights(wdat, cin, cout);
    let row = wo * cout;
    if ho % 2 == 0 {
        // One chunk per tile row: tiles never straddle a chunk (or an
        // image — each image holds ho/2 whole tile rows).
        let tpi = ho / 2;
        parallel_chunks(out, 2 * row, max_threads, |idx, chunk| {
            let (b, t) = (idx / tpi, idx % tpi);
            let mut patch = vec![0i32; 16 * cin];
            let mut vbuf = vec![0i32; 16 * cin];
            let mut m = vec![0i32; 16 * cout];
            tile_row_mult(xq, h, w_in, cin, &u, cout, b, t, pt, pl, wo, chunk,
                          2, &mut patch, &mut vbuf, &mut m);
        });
    } else {
        // Odd output height (test-grid shapes): one chunk per image, the
        // final tile row writes only its top output row.
        parallel_chunks(out, ho * row, max_threads, |b, chunk| {
            let mut patch = vec![0i32; 16 * cin];
            let mut vbuf = vec![0i32; 16 * cin];
            let mut m = vec![0i32; 16 * cout];
            for t in 0..(ho + 1) / 2 {
                let rows = if 2 * t + 1 < ho { 2 } else { 1 };
                let s = &mut chunk[2 * t * row..(2 * t + rows) * row];
                tile_row_mult(xq, h, w_in, cin, &u, cout, b, t, pt, pl, wo, s,
                              rows, &mut patch, &mut vbuf, &mut m);
            }
        });
    }
}

/// Round-half-even division by 4 for the l1 path's 4x-scaled outputs
/// (the exact path divides exactly instead; here the scale mismatch is
/// part of the approximation, so ties break like every other requant
/// step in the int path).
fn div4_round_even(v: i64) -> i64 {
    let q = v >> 2;
    match v & 3 {
        0 | 1 => q,
        2 => q + (q & 1),
        _ => q + 1,
    }
}

/// One tile-row of the approximate l1 adder path: elementwise
/// `-|U - 4V|` in i64, aggregated through `|A|` (all-nonnegative output
/// transform), divided by the 4x weight scale with round-half-even.
#[allow(clippy::too_many_arguments)]
fn tile_row_adder_l1(xq: &[i32], h: usize, w_in: usize, cin: usize, u: &[i32],
                     cout: usize, b: usize, t: usize, pt: usize, pl: usize,
                     wo: usize, out_rows: &mut [i32], rows: usize,
                     patch: &mut [i32], vbuf: &mut [i32], m: &mut [i64]) {
    let mut ow0 = 0;
    while ow0 < wo {
        gather_patch(xq, h, w_in, cin, b, t, ow0, pt, pl, patch);
        transform_data(patch, cin, vbuf);
        m.iter_mut().for_each(|v| *v = 0);
        for pos in 0..16 {
            let mrow = &mut m[pos * cout..(pos + 1) * cout];
            for ci in 0..cin {
                let xv4 = 4 * vbuf[pos * cin + ci];
                let urow = &u[(pos * cin + ci) * cout..(pos * cin + ci + 1) * cout];
                for (a, &uv) in mrow.iter_mut().zip(urow) {
                    *a -= (uv - xv4).abs() as i64;
                }
            }
        }
        let cols = if ow0 + 1 < wo { 2 } else { 1 };
        for co in 0..cout {
            let mm = |pos: usize| m[pos * cout + co];
            // |At| rows: [1 1 1 0] and [0 1 1 1]; |A| columns likewise.
            let a0 = [mm(0) + mm(4) + mm(8), mm(1) + mm(5) + mm(9),
                      mm(2) + mm(6) + mm(10), mm(3) + mm(7) + mm(11)];
            let a1 = [mm(4) + mm(8) + mm(12), mm(5) + mm(9) + mm(13),
                      mm(6) + mm(10) + mm(14), mm(7) + mm(11) + mm(15)];
            let y = [[a0[0] + a0[1] + a0[2], a0[1] + a0[2] + a0[3]],
                     [a1[0] + a1[1] + a1[2], a1[1] + a1[2] + a1[3]]];
            for (r, yr) in y.iter().enumerate().take(rows) {
                for (c, &v) in yr.iter().enumerate().take(cols) {
                    let q = div4_round_even(v)
                        .clamp(i32::MIN as i64, i32::MAX as i64);
                    out_rows[(r * wo + ow0 + c) * cout + co] = q as i32;
                }
            }
        }
        ow0 += 2;
    }
}

/// Approximate l1 transform-domain **adder** conv (Li et al.,
/// arXiv:2105.05530) over already-quantized operands.  NOT bit-identical
/// to the exact adder conv — see module docs for the opt-in and the
/// tolerance oracle.  Same signature contract as [`conv2d_int_mult`].
#[allow(clippy::too_many_arguments)]
pub fn conv2d_int_adder_l1(xq: &[i32], shape: (usize, usize, usize, usize),
                           wdat: &[i32], cin: usize, cout: usize,
                           geom: (usize, usize, usize, usize),
                           max_threads: usize, out: &mut [i32]) {
    let (n, h, w_in, xc) = shape;
    let (pt, pl, ho, wo) = geom;
    assert_eq!(xc, cin, "cin mismatch");
    assert_eq!(wdat.len(), 9 * cin * cout, "winograd expects a 3x3 filter block");
    assert_eq!(out.len(), n * ho * wo * cout, "output size mismatch");
    if out.is_empty() {
        return;
    }
    let u = transform_weights(wdat, cin, cout);
    let row = wo * cout;
    if ho % 2 == 0 {
        let tpi = ho / 2;
        parallel_chunks(out, 2 * row, max_threads, |idx, chunk| {
            let (b, t) = (idx / tpi, idx % tpi);
            let mut patch = vec![0i32; 16 * cin];
            let mut vbuf = vec![0i32; 16 * cin];
            let mut m = vec![0i64; 16 * cout];
            tile_row_adder_l1(xq, h, w_in, cin, &u, cout, b, t, pt, pl, wo,
                              chunk, 2, &mut patch, &mut vbuf, &mut m);
        });
    } else {
        parallel_chunks(out, ho * row, max_threads, |b, chunk| {
            let mut patch = vec![0i32; 16 * cin];
            let mut vbuf = vec![0i32; 16 * cin];
            let mut m = vec![0i64; 16 * cout];
            for t in 0..(ho + 1) / 2 {
                let rows = if 2 * t + 1 < ho { 2 } else { 1 };
                let s = &mut chunk[2 * t * row..(2 * t + rows) * row];
                tile_row_adder_l1(xq, h, w_in, cin, &u, cout, b, t, pt, pl, wo,
                                  s, rows, &mut patch, &mut vbuf, &mut m);
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::XorShift64;

    /// Direct 3x3/stride-1 integer mult conv — the local truth the
    /// transform path must reproduce bit-for-bit.
    fn direct_mult(xq: &[i32], n: usize, h: usize, w_in: usize, cin: usize,
                   wdat: &[i32], cout: usize,
                   geom: (usize, usize, usize, usize)) -> Vec<i32> {
        let (pt, pl, ho, wo) = geom;
        let mut out = vec![0i32; n * ho * wo * cout];
        for b in 0..n {
            for oh in 0..ho {
                for ow in 0..wo {
                    for co in 0..cout {
                        let mut acc = 0i32;
                        for ky in 0..3 {
                            let iy = (oh + ky) as isize - pt as isize;
                            if iy < 0 || iy >= h as isize {
                                continue;
                            }
                            for kx in 0..3 {
                                let ix = (ow + kx) as isize - pl as isize;
                                if ix < 0 || ix >= w_in as isize {
                                    continue;
                                }
                                for ci in 0..cin {
                                    let xv = xq[((b * h + iy as usize) * w_in
                                        + ix as usize) * cin + ci];
                                    let wv = wdat[((ky * 3 + kx) * cin + ci)
                                        * cout + co];
                                    acc += xv * wv;
                                }
                            }
                        }
                        out[((b * ho + oh) * wo + ow) * cout + co] = acc;
                    }
                }
            }
        }
        out
    }

    fn rand_ops(rng: &mut XorShift64, len: usize, amp: f32) -> Vec<i32> {
        (0..len).map(|_| (rng.next_f32_sym(amp)) as i32).collect()
    }

    #[test]
    fn exact_mult_matches_direct_conv_bitwise() {
        let mut rng = XorShift64::new(42);
        // even and odd extents, SAME- and VALID-style paddings
        for &(n, h, w_in, cin, cout, pt, pl) in &[
            (1usize, 4usize, 4usize, 1usize, 1usize, 1usize, 1usize),
            (2, 6, 8, 3, 5, 1, 1),
            (1, 5, 7, 2, 4, 1, 1), // odd output height
            (1, 6, 6, 2, 3, 0, 0), // valid: ho = h - 2
            (1, 3, 3, 1, 2, 0, 0), // single-tile valid
        ] {
            let (ho, wo) = (h + 2 * pt - 2, w_in + 2 * pl - 2);
            let xq = rand_ops(&mut rng, n * h * w_in * cin, 127.0);
            let wdat = rand_ops(&mut rng, 9 * cin * cout, 127.0);
            let want = direct_mult(&xq, n, h, w_in, cin, &wdat, cout,
                                   (pt, pl, ho, wo));
            let mut got = vec![0i32; want.len()];
            conv2d_int_mult(&xq, (n, h, w_in, cin), &wdat, cin, cout,
                            (pt, pl, ho, wo), 1, &mut got);
            assert_eq!(got, want, "shape n{n} h{h} w{w_in} cin{cin} cout{cout}");
            // and identically when the pool is allowed in
            let mut par = vec![0i32; want.len()];
            conv2d_int_mult(&xq, (n, h, w_in, cin), &wdat, cin, cout,
                            (pt, pl, ho, wo), usize::MAX, &mut par);
            assert_eq!(par, want, "parallel mismatch");
        }
    }

    #[test]
    fn shape_guard_covers_only_3x3_stride1() {
        assert!(applies(3, 3, 1, 16));
        assert!(!applies(1, 1, 1, 16));
        assert!(!applies(5, 5, 1, 16));
        assert!(!applies(3, 3, 2, 16));
        assert!(!applies(3, 3, 3, 16));
        assert!(!applies(3, 3, 1, MAX_CIN + 1));
    }

    #[test]
    fn empty_output_is_a_no_op() {
        // kernel larger than a VALID input: conv_geometry yields 0x0
        let xq = vec![1i32; 4];
        let mut out: Vec<i32> = Vec::new();
        conv2d_int_mult(&xq, (1, 2, 2, 1), &[1; 9], 1, 1, (0, 0, 0, 0), 1,
                        &mut out);
        conv2d_int_adder_l1(&xq, (1, 2, 2, 1), &[1; 9], 1, 1, (0, 0, 0, 0), 1,
                            &mut out);
    }

    #[test]
    fn adder_l1_is_deterministic_and_nonpositive() {
        let mut rng = XorShift64::new(7);
        let (n, h, w_in, cin, cout) = (2usize, 6usize, 6usize, 3usize, 4usize);
        let xq = rand_ops(&mut rng, n * h * w_in * cin, 127.0);
        let wdat = rand_ops(&mut rng, 9 * cin * cout, 127.0);
        let geom = (1, 1, h, w_in);
        let mut a = vec![0i32; n * h * w_in * cout];
        let mut b = vec![0i32; n * h * w_in * cout];
        conv2d_int_adder_l1(&xq, (n, h, w_in, cin), &wdat, cin, cout, geom, 1,
                            &mut a);
        conv2d_int_adder_l1(&xq, (n, h, w_in, cin), &wdat, cin, cout, geom,
                            usize::MAX, &mut b);
        assert_eq!(a, b, "thread count changed the l1 result");
        assert!(a.iter().all(|&v| v <= 0), "l1 outputs are -|.| aggregates");
    }

    #[test]
    fn div4_round_even_ties_to_even() {
        assert_eq!(div4_round_even(8), 2);
        assert_eq!(div4_round_even(9), 2);
        assert_eq!(div4_round_even(10), 2); // tie: 2.5 -> 2 (even)
        assert_eq!(div4_round_even(6), 2); // tie: 1.5 -> 2 (even)
        assert_eq!(div4_round_even(11), 3);
        assert_eq!(div4_round_even(-10), -2); // -2.5 -> -2 (even)
        assert_eq!(div4_round_even(-6), -2); // -1.5 -> -2 (even)
        assert_eq!(div4_round_even(-9), -2);
        assert_eq!(div4_round_even(-11), -3);
    }
}
