//! Lane-structured row kernels: explicit chunks-of-8 output channels,
//! written so stable-Rust autovectorization emits packed SIMD.
//!
//! Why this beats the [`super::tiled`] kernel: every inner loop here
//! runs over a **compile-time-fixed** `[T; LANES]` array (no runtime
//! trip count, no tail branch inside the hot loop), the
//! adder-vs-mult dispatch is hoisted out of the tap loop entirely
//! (each variant is its own monomorphic function), and the register
//! block — [`COLS`] output columns x one 8-wide lane group — fits in
//! actual vector registers instead of the tiled kernel's 4 x 64
//! stack-spilled accumulators.  The adder inner op `a - |x - w|` maps
//! to the same subtract/abs/accumulate sequence SAD instructions
//! implement, which is the paper's §2 observation about the hardware
//! datapath, replayed in software.
//!
//! Tap order is ascending (ky, kx, ci) — identical to the naive
//! reference and the tiled kernel — so the f32 path accumulates in the
//! same sequence (no reassociation) and the i32 path is bit-identical
//! by order-independence of integer addition.  Channel and column
//! remainders fall back to scalar tails outside the hot loops.

use super::SimKernel;

/// Output channels per lane group.  Eight f32/i32 = one AVX2 register
/// (two SSE2 registers on the baseline target) — wide enough to
/// vectorize, narrow enough that [`COLS`] column accumulators stay in
/// registers.
pub(crate) const LANES: usize = 8;

/// Output columns accumulated per pass; each shares the streamed
/// weight lane group, so one weight load feeds `COLS` accumulates.
const COLS: usize = 4;

macro_rules! simd_conv_row {
    ($name:ident, $t:ty, $zero:expr, $op:expr) => {
        fn $name(rowbuf: &[$t], k_taps: usize, wdat: &[$t], cout: usize,
                 out_row: &mut [$t]) {
            let wo = out_row.len() / cout;
            let lanes_full = cout - cout % LANES;
            let mut ow = 0;
            // Hot loop: COLS gathered columns x one 8-wide lane group.
            while ow + COLS <= wo {
                let cols: [&[$t]; COLS] = std::array::from_fn(
                    |t| &rowbuf[(ow + t) * k_taps..(ow + t + 1) * k_taps]);
                let mut co0 = 0;
                while co0 < lanes_full {
                    let mut acc = [[$zero; LANES]; COLS];
                    for k in 0..k_taps {
                        let base = k * cout + co0;
                        let wv = <[$t; LANES]>::try_from(
                            &wdat[base..base + LANES]).unwrap();
                        for (col, a) in cols.iter().zip(acc.iter_mut()) {
                            let x = col[k];
                            for (aj, &wj) in a.iter_mut().zip(wv.iter()) {
                                *aj = $op(*aj, x, wj);
                            }
                        }
                    }
                    for (t, a) in acc.iter().enumerate() {
                        let base = (ow + t) * cout + co0;
                        out_row[base..base + LANES].copy_from_slice(a);
                    }
                    co0 += LANES;
                }
                // channel tail (< LANES wide): scalar
                for co in lanes_full..cout {
                    for (t, col) in cols.iter().enumerate() {
                        let mut a = $zero;
                        for k in 0..k_taps {
                            a = $op(a, col[k], wdat[k * cout + co]);
                        }
                        out_row[(ow + t) * cout + co] = a;
                    }
                }
                ow += COLS;
            }
            // column tail (< COLS left): single column, still lane-wide
            while ow < wo {
                let col = &rowbuf[ow * k_taps..(ow + 1) * k_taps];
                let mut co0 = 0;
                while co0 < lanes_full {
                    let mut a = [$zero; LANES];
                    for k in 0..k_taps {
                        let base = k * cout + co0;
                        let wv = <[$t; LANES]>::try_from(
                            &wdat[base..base + LANES]).unwrap();
                        let x = col[k];
                        for (aj, &wj) in a.iter_mut().zip(wv.iter()) {
                            *aj = $op(*aj, x, wj);
                        }
                    }
                    let base = ow * cout + co0;
                    out_row[base..base + LANES].copy_from_slice(&a);
                    co0 += LANES;
                }
                for co in lanes_full..cout {
                    let mut a = $zero;
                    for k in 0..k_taps {
                        a = $op(a, col[k], wdat[k * cout + co]);
                    }
                    out_row[ow * cout + co] = a;
                }
                ow += 1;
            }
        }
    };
}

simd_conv_row!(adder_f32, f32, 0f32, |a: f32, x: f32, w: f32| a - (x - w).abs());
simd_conv_row!(mult_f32, f32, 0f32, |a: f32, x: f32, w: f32| a + x * w);
simd_conv_row!(adder_i32, i32, 0i32, |a: i32, x: i32, w: i32| a - (x - w).abs());
simd_conv_row!(mult_i32, i32, 0i32, |a: i32, x: i32, w: i32| a + x * w);

/// f32 row kernel, simd strategy (kind dispatch hoisted to one match).
pub(crate) fn conv_row_f32(rowbuf: &[f32], k_taps: usize, wdat: &[f32],
                           cout: usize, kind: SimKernel, out_row: &mut [f32]) {
    match kind {
        SimKernel::Adder => adder_f32(rowbuf, k_taps, wdat, cout, out_row),
        SimKernel::Mult => mult_f32(rowbuf, k_taps, wdat, cout, out_row),
    }
}

/// i32 row kernel, simd strategy.
pub(crate) fn conv_row_i32(rowbuf: &[i32], k_taps: usize, wdat: &[i32],
                           cout: usize, kind: SimKernel, out_row: &mut [i32]) {
    match kind {
        SimKernel::Adder => adder_i32(rowbuf, k_taps, wdat, cout, out_row),
        SimKernel::Mult => mult_i32(rowbuf, k_taps, wdat, cout, out_row),
    }
}

/// Dense inner kernel for one batch row: lane-group accumulators seeded
/// from the bias, post-ReLU zero-skip, inputs in ascending order (the
/// reference order).
pub(crate) fn dense_row(xrow: &[f32], w: &[f32], bias: &[f32], dout: usize,
                        orow: &mut [f32]) {
    let lanes_full = dout - dout % LANES;
    let mut co0 = 0;
    while co0 < lanes_full {
        let mut acc = <[f32; LANES]>::try_from(&bias[co0..co0 + LANES]).unwrap();
        for (i, &xv) in xrow.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let base = i * dout + co0;
            let wv = <[f32; LANES]>::try_from(&w[base..base + LANES]).unwrap();
            for (aj, &wj) in acc.iter_mut().zip(wv.iter()) {
                *aj += xv * wj;
            }
        }
        orow[co0..co0 + LANES].copy_from_slice(&acc);
        co0 += LANES;
    }
    for co in lanes_full..dout {
        let mut a = bias[co];
        for (i, &xv) in xrow.iter().enumerate() {
            if xv != 0.0 {
                a += xv * w[i * dout + co];
            }
        }
        orow[co] = a;
    }
}

/// Integer dense inner kernel: lane groups of 8 widened i64 accumulators
/// seeded from the (accumulator-grid) integer bias, i32 operands widened
/// at the multiply (a single int16 tap product already needs more than
/// i32).  Zero-skip and input order match the other strategies, so the
/// i64 sums are identical by order-independence of integer addition.
pub(crate) fn dense_int_row(xrow: &[i32], w: &[i32], bias: &[i64], dout: usize,
                            orow: &mut [i64]) {
    let lanes_full = dout - dout % LANES;
    let mut co0 = 0;
    while co0 < lanes_full {
        let mut acc = <[i64; LANES]>::try_from(&bias[co0..co0 + LANES]).unwrap();
        for (i, &xv) in xrow.iter().enumerate() {
            if xv == 0 {
                continue;
            }
            let xv = xv as i64;
            let base = i * dout + co0;
            let wv = <[i32; LANES]>::try_from(&w[base..base + LANES]).unwrap();
            for (aj, &wj) in acc.iter_mut().zip(wv.iter()) {
                *aj += xv * wj as i64;
            }
        }
        orow[co0..co0 + LANES].copy_from_slice(&acc);
        co0 += LANES;
    }
    for co in lanes_full..dout {
        let mut a = bias[co];
        for (i, &xv) in xrow.iter().enumerate() {
            if xv != 0 {
                a += xv as i64 * w[i * dout + co] as i64;
            }
        }
        orow[co] = a;
    }
}
