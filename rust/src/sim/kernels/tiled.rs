//! Cache-blocked scalar row kernels — the PR-1 engine's inner loops,
//! moved here verbatim when the kernel-strategy subsystem landed.
//!
//! Blocking: `OW_TILE` output columns share each streamed weight row
//! (quartering weight bandwidth) and `COUT_TILE` output channels keep
//! their accumulators on the stack.  Taps run in ascending (ky, kx, ci)
//! order — the reference order — so the f32 path accumulates in exactly
//! the sequence the naive oracle does.

use super::SimKernel;

/// Output-channel tile of the inner kernel (accumulators live on the
/// stack; 64 f32 = two cache lines).
pub(crate) const COUT_TILE: usize = 64;
/// Output-column register blocking: four columns share each streamed
/// weight row, quartering weight bandwidth in the inner loop.
pub(crate) const OW_TILE: usize = 4;

macro_rules! conv_row_kernel {
    ($name:ident, $t:ty, $zero:expr, $adder:expr, $mult:expr) => {
        /// Blocked inner kernel over one gathered output row: OW_TILE
        /// columns x COUT_TILE channels per pass, taps in ascending
        /// (ky, kx, ci) order (the reference order).
        pub(crate) fn $name(rowbuf: &[$t], k_taps: usize, wdat: &[$t], cout: usize,
                            kind: SimKernel, out_row: &mut [$t]) {
            let wo = out_row.len() / cout;
            let mut co0 = 0;
            while co0 < cout {
                let cb = COUT_TILE.min(cout - co0);
                let mut ow = 0;
                while ow + OW_TILE <= wo {
                    let p0 = &rowbuf[ow * k_taps..(ow + 1) * k_taps];
                    let p1 = &rowbuf[(ow + 1) * k_taps..(ow + 2) * k_taps];
                    let p2 = &rowbuf[(ow + 2) * k_taps..(ow + 3) * k_taps];
                    let p3 = &rowbuf[(ow + 3) * k_taps..(ow + 4) * k_taps];
                    let mut a0 = [$zero; COUT_TILE];
                    let mut a1 = [$zero; COUT_TILE];
                    let mut a2 = [$zero; COUT_TILE];
                    let mut a3 = [$zero; COUT_TILE];
                    for k in 0..k_taps {
                        let wrow = &wdat[k * cout + co0..k * cout + co0 + cb];
                        let (x0, x1, x2, x3) = (p0[k], p1[k], p2[k], p3[k]);
                        match kind {
                            SimKernel::Adder => {
                                for (j, &wv) in wrow.iter().enumerate() {
                                    a0[j] = $adder(a0[j], x0, wv);
                                    a1[j] = $adder(a1[j], x1, wv);
                                    a2[j] = $adder(a2[j], x2, wv);
                                    a3[j] = $adder(a3[j], x3, wv);
                                }
                            }
                            SimKernel::Mult => {
                                for (j, &wv) in wrow.iter().enumerate() {
                                    a0[j] = $mult(a0[j], x0, wv);
                                    a1[j] = $mult(a1[j], x1, wv);
                                    a2[j] = $mult(a2[j], x2, wv);
                                    a3[j] = $mult(a3[j], x3, wv);
                                }
                            }
                        }
                    }
                    for (t, acc) in [&a0, &a1, &a2, &a3].into_iter().enumerate() {
                        let base = (ow + t) * cout + co0;
                        out_row[base..base + cb].copy_from_slice(&acc[..cb]);
                    }
                    ow += OW_TILE;
                }
                while ow < wo {
                    let p = &rowbuf[ow * k_taps..(ow + 1) * k_taps];
                    let mut acc = [$zero; COUT_TILE];
                    for (k, &xv) in p.iter().enumerate() {
                        let wrow = &wdat[k * cout + co0..k * cout + co0 + cb];
                        match kind {
                            SimKernel::Adder => {
                                for (j, &wv) in wrow.iter().enumerate() {
                                    acc[j] = $adder(acc[j], xv, wv);
                                }
                            }
                            SimKernel::Mult => {
                                for (j, &wv) in wrow.iter().enumerate() {
                                    acc[j] = $mult(acc[j], xv, wv);
                                }
                            }
                        }
                    }
                    let base = ow * cout + co0;
                    out_row[base..base + cb].copy_from_slice(&acc[..cb]);
                    ow += 1;
                }
                co0 += cb;
            }
        }
    };
}

conv_row_kernel!(conv_row_f32, f32, 0f32,
                 |a: f32, x: f32, w: f32| a - (x - w).abs(),
                 |a: f32, x: f32, w: f32| a + x * w);
conv_row_kernel!(conv_row_i32, i32, 0i32,
                 |a: i32, x: i32, w: i32| a - (x - w).abs(),
                 |a: i32, x: i32, w: i32| a + x * w);

/// Dense inner kernel for one batch row: output-blocked (COUT_TILE wide)
/// with the post-ReLU zero-skip, accumulating inputs in ascending order
/// (the reference order).
pub(crate) fn dense_row(xrow: &[f32], w: &[f32], bias: &[f32], dout: usize,
                        orow: &mut [f32]) {
    let mut co0 = 0;
    while co0 < dout {
        let cb = COUT_TILE.min(dout - co0);
        let mut acc = [0f32; COUT_TILE];
        acc[..cb].copy_from_slice(&bias[co0..co0 + cb]);
        for (i, &xv) in xrow.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let wrow = &w[i * dout + co0..i * dout + co0 + cb];
            for (j, &wv) in wrow.iter().enumerate() {
                acc[j] += xv * wv;
            }
        }
        orow[co0..co0 + cb].copy_from_slice(&acc[..cb]);
        co0 += cb;
    }
}

/// Integer dense inner kernel: i32 operands, widened i64 accumulators
/// seeded from the (accumulator-grid) integer bias.  i64 is required —
/// at int16 a single tap product already reaches 2^30, so any dense row
/// with more than one input would overflow i32.  The post-ReLU zero-skip
/// is exact on integers.
pub(crate) fn dense_int_row(xrow: &[i32], w: &[i32], bias: &[i64], dout: usize,
                            orow: &mut [i64]) {
    let mut co0 = 0;
    while co0 < dout {
        let cb = COUT_TILE.min(dout - co0);
        let mut acc = [0i64; COUT_TILE];
        acc[..cb].copy_from_slice(&bias[co0..co0 + cb]);
        for (i, &xv) in xrow.iter().enumerate() {
            if xv == 0 {
                continue;
            }
            let xv = xv as i64;
            let wrow = &w[i * dout + co0..i * dout + co0 + cb];
            for (j, &wv) in wrow.iter().enumerate() {
                acc[j] += xv * wv as i64;
            }
        }
        orow[co0..co0 + cb].copy_from_slice(&acc[..cb]);
        co0 += cb;
    }
}
