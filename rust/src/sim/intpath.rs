//! Plan-based integer executor: the whole conv→BN→ReLU→pool chain in
//! the i32 domain.
//!
//! The per-call quantized path ([`functional::conv2d_quant`]) is an
//! experiment harness — it quantizes the same weights on every call and
//! dequantizes after every conv.  This module is the serving path: a
//! [`PlanRunner`] executes a pre-compiled
//! [`crate::quant::plan::QuantPlan`], so
//!
//! * weights are already integers (quantized once, at plan build);
//! * activations enter the integer domain ONCE (the input image) and
//!   stay i32 through every conv, folded-BN, ReLU, pooling, residual
//!   AND dense stage — inter-layer requantization is a power-of-two
//!   shift baked into the BN fold (convs) or applied at the dense
//!   boundaries;
//! * f32 reappears only at the very last logit rescale: the final dense
//!   layer's i64 accumulators are dequantized straight off their grid.
//!
//! Convolutions dispatch through [`functional::conv2d_int_with`], so the
//! whole [`KernelStrategy`] subsystem (`Naive`/`Tiled`/`Simd`/`Auto`)
//! serves the int path, and — i32 accumulation being order-independent —
//! the integer stack is bit-identical across strategies
//! (`tests/intpath_oracle.rs` pins this, plus first-layer bit-identity
//! against the per-call reference).
//!
//! Register widths: activations BETWEEN stages live in a register with
//! [`HEADROOM_BITS`] bits of slack over the serving width (DW+2 — the
//! width a 2x2 pool sum needs anyway), because a layer's BN output can
//! legitimately overshoot the range calibrated at the NEXT conv's input
//! (pooling and residual averaging shrink it back).  The strict DW
//! clamp is applied exactly where activations enter a convolution —
//! the same place the per-call path quantize-clamps — so the two paths
//! clip identically.

use crate::nn::graph::{ConvBnSpec, DenseSpec};
use crate::quant;
use crate::quant::plan::{div_round_even, requant_shift, QuantPlan};
use crate::sim::exec::{self, ActStats, Domain, ExecObserver};
use crate::sim::functional::{self, KernelStrategy, QConvW, QDenseW, Tensor};

/// Headroom of the inter-stage activation registers over the serving
/// width: BN outputs, pool sums and residual adds run at DW+2 bits;
/// only conv operands are clamped to DW (see the module docs).
pub const HEADROOM_BITS: u32 = 2;

/// Dense NHWC integer activation tensor on the grid `2^exp`.
#[derive(Debug, Clone, PartialEq)]
pub struct IntTensor {
    pub data: Vec<i32>,
    /// (n, h, w, c); dense activations use (n, 1, 1, c).
    pub shape: (usize, usize, usize, usize),
    /// Value = `data * 2^exp`.
    pub exp: i32,
}

/// Quantize an f32 activation tensor onto `2^exp` — the single
/// f32→int boundary of the plan path (the input image).
pub fn quantize_input(x: &Tensor, exp: i32, bits: u32) -> IntTensor {
    IntTensor {
        data: quant::quantize_slice(&x.data, exp, bits),
        shape: x.shape,
        exp,
    }
}

/// Dequantize (exact: every int value is representable in f32 for
/// serving widths <= 16 bit).
pub fn dequantize(t: &IntTensor) -> Tensor {
    let s = (t.exp as f32).exp2();
    Tensor::new(t.shape, t.data.iter().map(|&q| q as f32 * s).collect())
}

/// Move activations onto the `target` grid: a pure power-of-two shift
/// with round-half-to-even, clamped to the serving width.
pub fn shift_to(t: &IntTensor, target: i32, qmax: i32) -> IntTensor {
    if t.exp == target {
        return t.clone();
    }
    let d = target - t.exp;
    let data = t.data.iter()
        .map(|&v| requant_shift(v as i64, d)
            .clamp(-(qmax as i64), qmax as i64) as i32)
        .collect();
    IntTensor { data, shape: t.shape, exp: target }
}

/// Integer ReLU.
pub fn relu_int(x: &mut IntTensor) {
    for v in x.data.iter_mut() {
        if *v < 0 {
            *v = 0;
        }
    }
}

/// 2x2 average pooling: sum four neighbours and shift by 2 with
/// round-half-to-even — the grid (exp) is unchanged, so pooling costs
/// half a grid step of rounding at most, like the f32 path's pool-then-
/// quantize.
pub fn avg_pool2_int(x: &IntTensor) -> IntTensor {
    let (n, h, w, c) = x.shape;
    let (ho, wo) = (h / 2, w / 2);
    let mut out = vec![0i32; n * ho * wo * c];
    let at = |b: usize, hh: usize, ww: usize, cc: usize| {
        x.data[((b * h + hh) * w + ww) * c + cc] as i64
    };
    for b in 0..n {
        for oh in 0..ho {
            for ow in 0..wo {
                for ci in 0..c {
                    let s = at(b, 2 * oh, 2 * ow, ci)
                        + at(b, 2 * oh, 2 * ow + 1, ci)
                        + at(b, 2 * oh + 1, 2 * ow, ci)
                        + at(b, 2 * oh + 1, 2 * ow + 1, ci);
                    out[((b * ho + oh) * wo + ow) * c + ci] =
                        requant_shift(s, 2) as i32;
                }
            }
        }
    }
    IntTensor { data: out, shape: (n, ho, wo, c), exp: x.exp }
}

/// Global average pooling: wide i64 sum, one round-half-to-even
/// division (an exact shift whenever `h*w` is a power of two — 64 for
/// the ResNet tail).
pub fn global_avg_pool_int(x: &IntTensor) -> IntTensor {
    let (n, h, w, c) = x.shape;
    let px = ((h * w) as i64).max(1);
    let mut out = vec![0i32; n * c];
    for b in 0..n {
        for ci in 0..c {
            let mut s = 0i64;
            for hh in 0..h {
                for ww in 0..w {
                    s += x.data[((b * h + hh) * w + ww) * c + ci] as i64;
                }
            }
            out[b * c + ci] = div_round_even(s, px) as i32;
        }
    }
    IntTensor { data: out, shape: (n, 1, 1, c), exp: x.exp }
}

/// Integer max pooling over the window (grid/exp unchanged; floor
/// geometry like the f32 [`functional::max_pool`]).
pub fn max_pool_int(x: &IntTensor, window: usize, stride: usize) -> IntTensor {
    let (n, h, w, c) = x.shape;
    let (ho, wo) = (h / stride, w / stride);
    let mut out = vec![0i32; n * ho * wo * c];
    for b in 0..n {
        for oh in 0..ho {
            for ow in 0..wo {
                for ci in 0..c {
                    let mut m = i32::MIN;
                    for ky in 0..window {
                        let iy = oh * stride + ky;
                        if iy >= h {
                            break;
                        }
                        for kx in 0..window {
                            let ix = ow * stride + kx;
                            if ix >= w {
                                break;
                            }
                            m = m.max(x.data[((b * h + iy) * w + ix) * c + ci]);
                        }
                    }
                    out[((b * ho + oh) * wo + ow) * c + ci] = m;
                }
            }
        }
    }
    IntTensor { data: out, shape: (n, ho, wo, c), exp: x.exp }
}

/// Activation of the plan domain as it flows through the graph walk:
/// i32 ([`IntTensor`]) through the whole
/// conv→BN→ReLU→pool/residual→flatten→dense stack, f32 only after the
/// FINAL dense layer rescales its accumulators to the logits (the
/// single int→f32 boundary of the plan path).
#[derive(Debug, Clone)]
pub enum IntAct {
    Int(IntTensor),
    F32(Tensor),
}

impl IntAct {
    fn int(self) -> IntTensor {
        match self {
            IntAct::Int(t) => t,
            IntAct::F32(_) => panic!("int-domain op after the f32 head"),
        }
    }

    fn int_ref(&self) -> &IntTensor {
        match self {
            IntAct::Int(t) => t,
            IntAct::F32(_) => panic!("int-domain op after the f32 head"),
        }
    }
}

/// Executes a [`QuantPlan`] under a chosen kernel strategy.  Stateless
/// and `Sync`: serving workers run one per variant.
#[derive(Clone, Copy)]
pub struct PlanRunner<'a> {
    pub plan: &'a QuantPlan,
    pub strategy: KernelStrategy,
}

impl PlanRunner<'_> {
    /// Activation register bound between stages (DW + headroom).
    fn reg_max(&self) -> i32 {
        self.plan.qmax() << HEADROOM_BITS
    }

    /// conv + folded BN: integer in, integer out, landing on the plan's
    /// target grid for this layer.  Inputs arriving on a different grid
    /// (the ResNet shortcut convs) are first requantized by a pow2
    /// shift; operands are then clamped to the serving width — the
    /// exact spot the per-call path quantize-clamps, so both paths clip
    /// identically.  The BN output keeps [`HEADROOM_BITS`] of slack.
    fn conv_block(&self, name: &str, x: &IntTensor) -> IntTensor {
        let lp = self.plan.convs.get(name)
            .unwrap_or_else(|| panic!("plan has no conv layer {name}"));
        let qmax = self.plan.qmax();
        // one pass either way: shift_to's clamp IS the operand clamp
        // (qmax < reg_max, so clamping straight to qmax is identical to
        // clamping the register then the operand width)
        let xin = if x.exp == lp.in_exp {
            let mut t = x.clone();
            for v in t.data.iter_mut() {
                *v = (*v).clamp(-qmax, qmax);
            }
            t
        } else {
            shift_to(x, lp.in_exp, qmax)
        };
        let qw = QConvW {
            data: &lp.wq,
            kh: lp.kh,
            kw: lp.kw,
            cin: lp.cin,
            cout: lp.cout,
        };
        let (mut acc, oshape) = functional::conv2d_int_with(
            self.strategy, &xin.data, xin.shape, &qw, lp.stride, lp.padding,
            self.plan.kind);
        let reg_max = self.reg_max();
        for (i, v) in acc.iter_mut().enumerate() {
            *v = lp.bn.apply(*v, i % lp.cout, reg_max);
        }
        IntTensor { data: acc, shape: oshape, exp: lp.out_exp }
    }

    /// Run the integer forward pass by walking the plan architecture's
    /// compiled op program ([`crate::nn::graph`]); returns f32 logits
    /// (n, 1, 1, 10).  The input image is the single f32→int boundary;
    /// the LAST dense layer's logit rescale is the single int→f32
    /// boundary — everything in between, classifier head included, runs
    /// integer.
    pub fn forward(&self, x: &Tensor) -> Tensor {
        let q = quantize_input(x, self.plan.input_exp, self.plan.cfg.bits);
        let graph = self.plan.arch.graph();
        let mut dom = *self;
        match exec::run_graph(&mut dom, graph, IntAct::Int(q)) {
            IntAct::F32(y) => y,
            // a headless graph ends int-domain: dequantize the features
            IntAct::Int(t) => dequantize(&t),
        }
    }

    /// [`Self::forward`] with a per-op [`ExecObserver`] (profiling /
    /// layer tracing); numerically identical to the unobserved walk.
    pub fn forward_observed(&self, x: &Tensor,
                            obs: &mut dyn ExecObserver) -> Tensor {
        let q = quantize_input(x, self.plan.input_exp, self.plan.cfg.bits);
        let graph = self.plan.arch.graph();
        let mut dom = *self;
        match exec::run_graph_observed(&mut dom, graph, IntAct::Int(q), obs) {
            IntAct::F32(y) => y,
            // a headless graph ends int-domain: dequantize the features
            IntAct::Int(t) => dequantize(&t),
        }
    }

    /// Batched inference over independently-queued images (the serving
    /// hot path — same contract as `Runner::forward_many`).
    pub fn forward_many(&self, images: &[&[f32]],
                        hwc: (usize, usize, usize)) -> Vec<Vec<f32>> {
        if images.is_empty() {
            return Vec::new();
        }
        let x = Self::stack(images, hwc);
        let logits = self.forward(&x);
        Self::split(logits, images.len())
    }

    /// Batched inference with a per-op observer — the traced serving
    /// path.
    pub fn forward_many_observed(&self, images: &[&[f32]],
                                 hwc: (usize, usize, usize),
                                 obs: &mut dyn ExecObserver) -> Vec<Vec<f32>> {
        if images.is_empty() {
            return Vec::new();
        }
        let x = Self::stack(images, hwc);
        let logits = self.forward_observed(&x, obs);
        Self::split(logits, images.len())
    }

    fn stack(images: &[&[f32]], hwc: (usize, usize, usize)) -> Tensor {
        let (h, w, c) = hwc;
        let px = h * w * c;
        let mut data = Vec::with_capacity(images.len() * px);
        for img in images {
            assert_eq!(img.len(), px, "request image size mismatch");
            data.extend_from_slice(img);
        }
        Tensor::new((images.len(), h, w, c), data)
    }

    fn split(logits: Tensor, n: usize) -> Vec<Vec<f32>> {
        let classes = logits.shape.3;
        (0..n)
            .map(|i| logits.data[i * classes..(i + 1) * classes].to_vec())
            .collect()
    }
}

/// The i32 numeric domain: activations stay integer through every conv,
/// folded-BN, ReLU, pooling, residual AND dense stage
/// ([`IntAct::Int`]); only the final dense layer's logit rescale
/// produces f32 ([`IntAct::F32`]).  Like the f32 domain, this is the
/// whole architecture-specific surface — the topology comes from the
/// walk.
impl Domain for PlanRunner<'_> {
    type Act = IntAct;

    fn stats(act: &IntAct) -> ActStats {
        match act {
            IntAct::Int(t) => {
                let n = t.data.len();
                if n == 0 {
                    return ActStats::default();
                }
                // mean |value| in real units: mean |q| * 2^exp
                let sum: f64 =
                    t.data.iter().map(|&v| (v as f64).abs()).sum();
                ActStats {
                    elems: n,
                    mean_abs: sum / n as f64 * (t.exp as f64).exp2(),
                }
            }
            IntAct::F32(t) => {
                let n = t.data.len();
                if n == 0 {
                    return ActStats::default();
                }
                let sum: f64 =
                    t.data.iter().map(|&v| (v as f64).abs()).sum();
                ActStats { elems: n, mean_abs: sum / n as f64 }
            }
        }
    }

    fn conv_bn(&mut self, spec: &ConvBnSpec, x: IntAct) -> IntAct {
        IntAct::Int(self.conv_block(&spec.name, x.int_ref()))
    }

    fn relu(&mut self, x: &mut IntAct) {
        match x {
            IntAct::Int(t) => relu_int(t),
            IntAct::F32(t) => functional::relu(t),
        }
    }

    fn avg_pool2(&mut self, x: &IntAct) -> IntAct {
        IntAct::Int(avg_pool2_int(x.int_ref()))
    }

    fn max_pool(&mut self, window: usize, stride: usize, x: &IntAct) -> IntAct {
        IntAct::Int(max_pool_int(x.int_ref(), window, stride))
    }

    fn global_avg_pool(&mut self, x: &IntAct) -> IntAct {
        IntAct::Int(global_avg_pool_int(x.int_ref()))
    }

    fn flatten(&mut self, x: IntAct) -> IntAct {
        // NHWC row-major == jax reshape; the grid is untouched
        match x {
            IntAct::Int(t) => {
                let (n, h, w, c) = t.shape;
                IntAct::Int(IntTensor {
                    data: t.data,
                    shape: (n, 1, 1, h * w * c),
                    exp: t.exp,
                })
            }
            IntAct::F32(t) => {
                let (n, h, w, c) = t.shape;
                IntAct::F32(Tensor::new((n, 1, 1, h * w * c), t.data))
            }
        }
    }

    fn residual_add(&mut self, shortcut: Option<&ConvBnSpec>, h: IntAct,
                    saved: IntAct) -> IntAct {
        let mut h = h.int();
        let reg_max = self.reg_max();
        // shortcut: a planned conv when the block projects, else the
        // identity shifted onto the sum grid
        let sc = match shortcut {
            Some(spec) => self.conv_block(&spec.name, saved.int_ref()),
            None => shift_to(saved.int_ref(), h.exp, reg_max),
        };
        debug_assert_eq!(h.exp, sc.exp, "{}: residual grids diverge",
                         shortcut.map_or("identity", |s| s.name.as_str()));
        // saturating residual add in the DW+2 register
        for (v, &s2) in h.data.iter_mut().zip(&sc.data) {
            *v = (*v + s2).clamp(-reg_max, reg_max);
        }
        IntAct::Int(h)
    }

    /// Integer dense stage: operands are shifted/clamped onto the
    /// layer's plan grid (the same contract conv operands have), the
    /// strategy-dispatched integer core accumulates in i64 with the
    /// bias pre-folded, and the result either requantizes onto the next
    /// layer's grid (intermediate layers, staying i32) or dequantizes
    /// off the accumulator grid — the final requant-to-logits rescale
    /// and the plan path's ONLY int→f32 boundary.
    fn dense(&mut self, spec: &DenseSpec, x: IntAct) -> IntAct {
        let dp = self.plan.dense.get(&spec.name)
            .unwrap_or_else(|| panic!("plan has no dense layer {}", spec.name));
        let t = x.int();
        let qmax = self.plan.qmax();
        let xin = if t.exp == dp.in_exp {
            let mut t = t;
            for v in t.data.iter_mut() {
                *v = (*v).clamp(-qmax, qmax);
            }
            t
        } else {
            shift_to(&t, dp.in_exp, qmax)
        };
        let (n, h, w, c) = xin.shape;
        assert_eq!(h * w * c, dp.din, "{}: dense input arity mismatch",
                   spec.name);
        let qw = QDenseW { data: &dp.wq, din: dp.din, dout: dp.dout };
        let acc = functional::dense_int_with(self.strategy, &xin.data, n, &qw,
                                             &dp.bq);
        match dp.out_exp {
            Some(oe) => {
                let reg_max = self.reg_max() as i64;
                let d = oe - dp.acc_exp;
                let data = acc.iter()
                    .map(|&a| requant_shift(a, d)
                        .clamp(-reg_max, reg_max) as i32)
                    .collect();
                IntAct::Int(IntTensor {
                    data,
                    shape: (n, 1, 1, dp.dout),
                    exp: oe,
                })
            }
            None => {
                let s = (dp.acc_exp as f32).exp2();
                IntAct::F32(Tensor::new(
                    (n, 1, 1, dp.dout),
                    acc.iter().map(|&a| a as f32 * s).collect()))
            }
        }
    }
}

/// Classification accuracy of a plan over (images, labels).
pub fn plan_accuracy(plan: &QuantPlan, strategy: KernelStrategy,
                     images: &Tensor, labels: &[i32]) -> f64 {
    let runner = PlanRunner { plan, strategy };
    let logits = runner.forward(images);
    let preds = functional::argmax_rows(&logits);
    let correct = preds.iter().zip(labels)
        .filter(|(p, l)| **p == **l as usize)
        .count();
    correct as f64 / labels.len().max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::plan::QuantPlan;
    use crate::quant::{Calibration, LayerCalib, Mode};
    use crate::sim::functional::{synth_params, Arch, QuantCfg, SimKernel};
    use crate::util::XorShift64;

    #[test]
    fn quantize_dequantize_input_round_trip() {
        let x = Tensor::new((1, 2, 2, 1), vec![0.5, -0.25, 0.125, 0.0]);
        let q = quantize_input(&x, -3, 8);
        assert_eq!(q.data, vec![4, -2, 1, 0]);
        let back = dequantize(&q);
        assert_eq!(back.data, x.data);
    }

    #[test]
    fn shift_to_round_trips_on_finer_grids() {
        let t = IntTensor { data: vec![3, -7, 0], shape: (1, 1, 1, 3), exp: -2 };
        let fine = shift_to(&t, -4, 32767);
        assert_eq!(fine.data, vec![12, -28, 0]);
        let back = shift_to(&fine, -2, 32767);
        assert_eq!(back.data, t.data);
    }

    #[test]
    fn shift_to_clamps_to_width() {
        let t = IntTensor { data: vec![100], shape: (1, 1, 1, 1), exp: 0 };
        let fine = shift_to(&t, -4, 127);
        assert_eq!(fine.data, vec![127]); // 1600 clamped to int8 grid
    }

    #[test]
    fn pool_rounds_to_even() {
        // mean of (1, 2, 2, 1) = 1.5 -> even 2; mean of (0,1,0,1) = .5 -> 0
        let x = IntTensor {
            data: vec![1, 2, 2, 1, 0, 1, 0, 1],
            shape: (2, 2, 2, 1),
            exp: -1,
        };
        let p = avg_pool2_int(&x);
        assert_eq!(p.shape, (2, 1, 1, 1));
        assert_eq!(p.data, vec![2, 0]);
        assert_eq!(p.exp, -1);
    }

    #[test]
    fn gap_matches_float_mean() {
        let x = IntTensor {
            data: (1..=16).collect(),
            shape: (1, 4, 4, 1),
            exp: 0,
        };
        let g = global_avg_pool_int(&x);
        // mean(1..=16) = 8.5 -> even 8
        assert_eq!(g.data, vec![8]);
    }

    fn lenet_plan(bits: u32) -> (crate::sim::functional::Params, Calibration, QuantCfg) {
        let params = synth_params(Arch::Lenet5, 3);
        let mut calib = Calibration::new();
        calib.insert("conv1".into(),
                     LayerCalib { feat_max_abs: 1.0, weight_max_abs: 0.5 });
        calib.insert("conv2".into(),
                     LayerCalib { feat_max_abs: 16.0, weight_max_abs: 0.5 });
        (params, calib, QuantCfg { bits, mode: Mode::SharedScale })
    }

    #[test]
    fn plan_forward_shapes_and_finite() {
        let (params, calib, cfg) = lenet_plan(8);
        let plan = QuantPlan::build(&params, Arch::Lenet5, SimKernel::Adder,
                                    cfg, &calib).unwrap();
        let mut rng = XorShift64::new(5);
        let x = Tensor::new((2, 32, 32, 1),
                            (0..2048).map(|_| rng.next_f32_sym(1.0)).collect());
        let r = PlanRunner { plan: &plan, strategy: KernelStrategy::Auto };
        let y = r.forward(&x);
        assert_eq!(y.shape, (2, 1, 1, 10));
        assert!(y.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn plan_forward_many_splits_logits() {
        let (params, calib, cfg) = lenet_plan(8);
        let plan = QuantPlan::build(&params, Arch::Lenet5, SimKernel::Adder,
                                    cfg, &calib).unwrap();
        let r = PlanRunner { plan: &plan, strategy: KernelStrategy::Auto };
        let mut rng = XorShift64::new(8);
        let imgs: Vec<Vec<f32>> = (0..3)
            .map(|_| (0..1024).map(|_| rng.next_f32_sym(1.0)).collect())
            .collect();
        let refs: Vec<&[f32]> = imgs.iter().map(|v| v.as_slice()).collect();
        let many = r.forward_many(&refs, (32, 32, 1));
        assert_eq!(many.len(), 3);
        for (i, img) in imgs.iter().enumerate() {
            let x = Tensor::new((1, 32, 32, 1), img.clone());
            let single = r.forward(&x);
            // the int path is deterministic: batching must be EXACT
            assert_eq!(many[i], single.data, "request {i}");
        }
    }

    #[test]
    fn logits_sit_on_the_final_accumulator_grid() {
        // The head is integer to the logits: every logit must be an
        // exact multiple of the final dense layer's accumulator step
        // (f32 appears only at the last rescale, which is a pow2 move).
        let (params, calib, cfg) = lenet_plan(8);
        let plan = QuantPlan::build(&params, Arch::Lenet5, SimKernel::Adder,
                                    cfg, &calib).unwrap();
        let fc3 = &plan.dense["fc3"];
        assert_eq!(fc3.out_exp, None);
        let step = (fc3.acc_exp as f32).exp2();
        let mut rng = XorShift64::new(12);
        let x = Tensor::new((2, 32, 32, 1),
                            (0..2048).map(|_| rng.next_f32_sym(1.0)).collect());
        let r = PlanRunner { plan: &plan, strategy: KernelStrategy::Auto };
        let y = r.forward(&x);
        for (i, v) in y.data.iter().enumerate() {
            let q = v / step;
            assert_eq!(q.fract(), 0.0, "logit {i} ({v}) off the acc grid");
        }
    }

    #[test]
    fn resnet_plan_runs_end_to_end() {
        let params = synth_params(Arch::Resnet8, 3);
        let calib: Calibration = params.keys()
            .filter_map(|k| k.strip_suffix("/conv_w"))
            .map(|n| (n.to_string(),
                      LayerCalib { feat_max_abs: 4.0, weight_max_abs: 0.5 }))
            .collect();
        let cfg = QuantCfg { bits: 8, mode: Mode::SharedScale };
        let plan = QuantPlan::build(&params, Arch::Resnet8, SimKernel::Adder,
                                    cfg, &calib).unwrap();
        let mut rng = XorShift64::new(6);
        let x = Tensor::new((1, 32, 32, 1),
                            (0..1024).map(|_| rng.next_f32_sym(1.0)).collect());
        let r = PlanRunner { plan: &plan, strategy: KernelStrategy::Auto };
        let y = r.forward(&x);
        assert_eq!(y.shape, (1, 1, 1, 10));
        assert!(y.data.iter().all(|v| v.is_finite()));
    }
}
