//! Generic graph executor: ONE walk over a compiled
//! [`crate::nn::graph::NetGraph`] program, parameterized over a
//! numeric-domain trait.
//!
//! The f32 runner ([`crate::sim::functional::Runner`]) and the plan-based
//! integer runner ([`crate::sim::intpath::PlanRunner`]) are thin
//! [`Domain`] instantiations of the same walk — they supply conv-block,
//! relu, pooling, residual-add and head hooks, and [`run_graph`] supplies
//! the topology.  Executors therefore contain no per-architecture code:
//! registering a new graph serves it across every domain with zero
//! executor edits.

use std::time::{Duration, Instant};

use crate::nn::graph::{ConvBnSpec, DenseSpec, NetGraph, Op};

/// Cheap summary statistics of an op's output activation, captured by
/// the observed walk.  Domains that cannot (or need not) inspect their
/// activation return the default.
#[derive(Debug, Clone, Copy, Default)]
pub struct ActStats {
    pub elems: usize,
    pub mean_abs: f64,
}

/// Observer hook for the instrumented graph walk: called once per op
/// with the op's linear index, its canonical label (mirroring
/// [`crate::nn::graph::NetGraph::to_desc`] naming, so profile rows join
/// against accelerator schedule rows), the wall-clock interval and the
/// output stats.  ONE instrumentation point serves every domain —
/// f32, integer-plan and hardware-sim runners alike.
pub trait ExecObserver {
    fn op_done(&mut self, index: usize, label: &str, start: Instant,
               wall: Duration, stats: ActStats);
}

/// Numeric-domain hooks the graph walk drives.  `Act` is the
/// activation type flowing between ops (dense [`f32` tensors] for the
/// float domain, an i32/f32 two-phase activation for the plan domain).
pub trait Domain {
    type Act: Clone;

    /// Convolution + batch-norm stage (the graph's fused unit).
    fn conv_bn(&mut self, spec: &ConvBnSpec, x: Self::Act) -> Self::Act;
    fn relu(&mut self, x: &mut Self::Act);
    fn avg_pool2(&mut self, x: &Self::Act) -> Self::Act;
    fn max_pool(&mut self, window: usize, stride: usize, x: &Self::Act)
                -> Self::Act;
    fn global_avg_pool(&mut self, x: &Self::Act) -> Self::Act;
    /// NHWC reshape to (n, 1, 1, h*w*c).
    fn flatten(&mut self, x: Self::Act) -> Self::Act;
    /// Close a residual bracket: add `saved` (the activation captured at
    /// `ResidualOpen`, routed through `shortcut` when present) onto the
    /// main-path activation `h`.
    fn residual_add(&mut self, shortcut: Option<&ConvBnSpec>, h: Self::Act,
                    saved: Self::Act) -> Self::Act;
    fn dense(&mut self, spec: &DenseSpec, x: Self::Act) -> Self::Act;

    /// Cheap output stats for the observed walk.  Default: none — the
    /// observer still gets timings and labels.
    fn stats(_act: &Self::Act) -> ActStats {
        ActStats::default()
    }
}

/// Canonical label for an op, mirroring `NetGraph::to_desc` row naming
/// (`pools` is the shared pool counter — both pool kinds draw from it,
/// exactly as the descriptor does).  Projection shortcuts label their
/// residual-close op, so conv rows in the schedule always find a match.
fn op_label(op: &Op, pools: &mut usize) -> String {
    match op {
        Op::ConvBn(spec) => spec.name.clone(),
        Op::Relu => "relu".into(),
        Op::AvgPool2 | Op::MaxPool { .. } => {
            *pools += 1;
            format!("pool{pools}")
        }
        Op::GlobalAvgPool => "gap".into(),
        Op::Flatten => "flatten".into(),
        Op::ResidualOpen => "residual_open".into(),
        Op::ResidualClose { shortcut } => match shortcut {
            Some(c) => c.name.clone(),
            None => "residual_add".into(),
        },
        Op::Dense(spec) => spec.name.clone(),
    }
}

/// Execute a compiled network program in `dom`, from input activation
/// to logits.  Residual brackets nest via a save stack (today's graphs
/// never nest, but the walk does not care).
pub fn run_graph<D: Domain>(dom: &mut D, graph: &NetGraph, x: D::Act)
                            -> D::Act {
    let mut y = x;
    let mut saved: Vec<D::Act> = Vec::new();
    for op in &graph.ops {
        y = match op {
            Op::ConvBn(spec) => dom.conv_bn(spec, y),
            Op::Relu => {
                dom.relu(&mut y);
                y
            }
            Op::AvgPool2 => dom.avg_pool2(&y),
            Op::MaxPool { window, stride } => dom.max_pool(*window, *stride, &y),
            Op::GlobalAvgPool => dom.global_avg_pool(&y),
            Op::Flatten => dom.flatten(y),
            Op::ResidualOpen => {
                saved.push(y.clone());
                y
            }
            Op::ResidualClose { shortcut } => {
                let s = saved.pop()
                    .expect("ResidualClose without ResidualOpen");
                dom.residual_add(shortcut.as_ref(), y, s)
            }
            Op::Dense(spec) => dom.dense(spec, y),
        };
    }
    debug_assert!(saved.is_empty(), "unclosed residual bracket");
    y
}

/// [`run_graph`] with per-op instrumentation: identical walk, but every
/// op is wall-clock timed and reported to `obs` together with its
/// canonical label and output stats.  The unobserved walk stays
/// zero-cost — this is a separate entry point, not a branch in the hot
/// loop.
pub fn run_graph_observed<D: Domain>(dom: &mut D, graph: &NetGraph,
                                     x: D::Act, obs: &mut dyn ExecObserver)
                                     -> D::Act {
    let mut y = x;
    let mut saved: Vec<D::Act> = Vec::new();
    let mut pools = 0usize;
    for (i, op) in graph.ops.iter().enumerate() {
        let label = op_label(op, &mut pools);
        let start = Instant::now();
        y = match op {
            Op::ConvBn(spec) => dom.conv_bn(spec, y),
            Op::Relu => {
                dom.relu(&mut y);
                y
            }
            Op::AvgPool2 => dom.avg_pool2(&y),
            Op::MaxPool { window, stride } => dom.max_pool(*window, *stride, &y),
            Op::GlobalAvgPool => dom.global_avg_pool(&y),
            Op::Flatten => dom.flatten(y),
            Op::ResidualOpen => {
                saved.push(y.clone());
                y
            }
            Op::ResidualClose { shortcut } => {
                let s = saved.pop()
                    .expect("ResidualClose without ResidualOpen");
                dom.residual_add(shortcut.as_ref(), y, s)
            }
            Op::Dense(spec) => dom.dense(spec, y),
        };
        let wall = start.elapsed();
        let stats = D::stats(&y);
        obs.op_done(i, &label, start, wall, stats);
    }
    debug_assert!(saved.is_empty(), "unclosed residual bracket");
    y
}
