//! Accelerator simulation: cycle/resource/power models (Fig. 4/5, §4)
//! plus the bit-accurate functional datapath (quantized inference).
//!
//! The functional datapath has two implementations: the tiled parallel
//! engine in [`functional`] (the serving hot path) and the naive scalar
//! loops in [`reference`] (the in-crate oracle the engine is tested
//! against — see `rust/tests/functional_oracle.rs`).

pub mod accelerator;
pub mod functional;
pub mod onchip;
pub mod reference;

pub use accelerator::{AccelConfig, ResourceBreakdown, RunReport};
pub use functional::{Arch, ExecMode, QuantCfg, Runner, SimKernel, Tensor};
