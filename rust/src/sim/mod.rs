//! Accelerator simulation: cycle/resource/power models (Fig. 4/5, §4)
//! plus the bit-accurate functional datapath (quantized inference).

pub mod accelerator;
pub mod functional;
pub mod onchip;

pub use accelerator::{AccelConfig, ResourceBreakdown, RunReport};
pub use functional::{Arch, ExecMode, QuantCfg, Runner, SimKernel, Tensor};
