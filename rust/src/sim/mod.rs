//! Accelerator simulation: cycle/resource/power models (Fig. 4/5, §4)
//! plus the bit-accurate functional datapath (quantized inference).
//!
//! The functional datapath runs through the [`kernels`] strategy
//! subsystem: a tiled cache-blocked kernel, a lane-structured SIMD
//! kernel, and the naive scalar loops in [`reference`] (the in-crate
//! oracle every strategy is tested against — see
//! `rust/tests/functional_oracle.rs`).  [`functional`] owns the parallel
//! gather engine and the single dispatch point; [`intpath`] executes
//! pre-compiled quantization plans ([`crate::quant::plan`]) with
//! activations kept in the i32 domain across the conv stack (the
//! quantized serving path).

pub mod accelerator;
pub mod functional;
pub mod intpath;
pub mod kernels;
pub mod onchip;
pub mod reference;

pub use accelerator::{AccelConfig, ResourceBreakdown, RunReport};
pub use functional::{Arch, ExecMode, QuantCfg, Runner, Tensor};
pub use intpath::PlanRunner;
pub use kernels::{KernelStrategy, SimKernel};
