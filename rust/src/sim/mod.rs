//! Accelerator simulation: cycle/resource/power models (Fig. 4/5, §4)
//! plus the bit-accurate functional datapath (quantized inference).
//!
//! The functional datapath runs through the [`kernels`] strategy
//! subsystem: a tiled cache-blocked kernel, a lane-structured SIMD
//! kernel, and the naive scalar loops in [`reference`] (the in-crate
//! oracle every strategy is tested against — see
//! `rust/tests/functional_oracle.rs`).  [`functional`] owns the parallel
//! gather engine and the single dispatch point; [`intpath`] executes
//! pre-compiled quantization plans ([`crate::quant::plan`]) with
//! activations kept in the i32 domain across the conv stack (the
//! quantized serving path).  Whole-model topology lives in ONE place —
//! the compiled op programs of [`crate::nn::graph`] — and [`exec`]
//! walks them generically over a numeric-domain trait; the f32
//! [`functional::Runner`] and the i32 [`intpath::PlanRunner`] are thin
//! domain instantiations of that walk.

pub mod accelerator;
pub mod exec;
pub mod functional;
pub mod hwsim;
pub mod intpath;
pub mod kernels;
pub mod onchip;
pub mod reference;

pub use accelerator::{AccelConfig, ResourceBreakdown, RunReport};
pub use functional::{Arch, ExecMode, QuantCfg, Runner, Tensor};
pub use hwsim::{HwCost, HwPlanRunner};
pub use intpath::PlanRunner;
pub use kernels::{KernelStrategy, SimKernel};
