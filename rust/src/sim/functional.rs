//! Bit-accurate functional model of the accelerator datapath.
//!
//! Executes every architecture registered in [`crate::nn::graph`]
//! (LeNet-5, cnv6, ResNet-8/20/32, ...) by walking the compiled op
//! program through the generic executor ([`crate::sim::exec`]) in two
//! modes:
//!
//! * **f32** — mirrors `python/compile/model.py` eval semantics exactly
//!   (cross-validated against the AOT HLO eval graphs in
//!   `rust/tests/integration.rs`), and doubles as the calibration pass
//!   that records per-layer feature ranges.
//! * **quantized** — integer arithmetic through the same widened
//!   accumulator the RTL datapath would use (i32 covers DW + log2(K) for
//!   every supported width, see [`conv2d_quant`]), with the paper's
//!   shared-scaling-factor mode or the CNN-style separate-scale mode
//!   (S7 contrast).
//!
//! This module is the Layer-3 hot path.  Convolutions run through an
//! im2col-style patch gather per output row plus a swappable inner row
//! kernel — the [`super::kernels`] strategy subsystem: `Tiled`
//! (cache-blocked scalar), `Simd` (lane-structured autovectorizing),
//! `Winograd` (transform-domain F(2x2, 3x3) on eligible integer convs,
//! heuristic fallback elsewhere), `Naive` (the [`super::reference`]
//! oracle loops) or `Auto` (env/heuristic selection) — parallelized
//! across batch x output-rows on a scoped worker pool
//! ([`crate::util::threads`]).  [`conv2d_with`], [`conv2d_quant_with`]
//! and [`dense_with`] are the single dispatch point every caller (the
//! [`Runner`], the serving backend, the CLI, the benches) routes
//! through.  All row strategies accumulate taps in the same ascending
//! (ky, kx, ci) order, so the integer path is bit-identical across
//! strategies (i32 accumulation is order-independent) and the f32 path
//! is bit-compatible; the Winograd mult path reaches the same
//! bit-identity by algebraic exactness instead (see
//! [`super::kernels::winograd`]).

use std::collections::BTreeMap;

use crate::nn::graph::{ConvBnSpec, DenseSpec, Op};
use crate::nn::{self, Padding};
use crate::quant::{self, Calibration, LayerCalib, Mode, QuantPlan};
use crate::util::threads::parallel_chunks;
use crate::util::XorShift64;

use super::exec::{self, ActStats, Domain, ExecObserver};
use super::kernels::{self, gather_row, ConvRow, DenseIntRow, DenseRow, Resolved,
                     ResolvedConv};
use super::reference;

pub use super::kernels::{KernelStrategy, SimKernel};
pub use crate::nn::graph::Arch;

/// Dense NHWC tensor (n = batch).
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub data: Vec<f32>,
    /// (n, h, w, c); dense activations use (n, 1, 1, c).
    pub shape: (usize, usize, usize, usize),
}

impl Tensor {
    pub fn new(shape: (usize, usize, usize, usize), data: Vec<f32>) -> Self {
        let (n, h, w, c) = shape;
        assert_eq!(data.len(), n * h * w * c, "tensor size mismatch");
        Self { data, shape }
    }

    pub fn zeros(shape: (usize, usize, usize, usize)) -> Self {
        let (n, h, w, c) = shape;
        Self { data: vec![0.0; n * h * w * c], shape }
    }

    #[inline]
    pub fn at(&self, n: usize, h: usize, w: usize, c: usize) -> f32 {
        let (_, hh, ww, cc) = self.shape;
        self.data[((n * hh + h) * ww + w) * cc + c]
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }
}

/// Quantization configuration for the integer mode.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QuantCfg {
    pub bits: u32,
    pub mode: Mode,
}

/// Convolution weights: (kh, kw, cin, cout) row-major — the layout the
/// manifest records (HWIO, same as the JAX side).
#[derive(Debug, Clone)]
pub struct ConvW<'a> {
    pub data: &'a [f32],
    pub kh: usize,
    pub kw: usize,
    pub cin: usize,
    pub cout: usize,
}

/// Pre-quantized convolution weights, same HWIO layout as [`ConvW`].
/// A [`crate::quant::plan::QuantPlan`] holds these — quantized ONCE at
/// plan-build time instead of on every forward pass.
#[derive(Debug, Clone)]
pub struct QConvW<'a> {
    pub data: &'a [i32],
    pub kh: usize,
    pub kw: usize,
    pub cin: usize,
    pub cout: usize,
}

/// Pre-quantized dense weights, (din x dout) row-major like the f32
/// head.  A [`crate::quant::plan::QuantPlan`]'s dense layers hold these.
#[derive(Debug, Clone)]
pub struct QDenseW<'a> {
    pub data: &'a [i32],
    pub din: usize,
    pub dout: usize,
}

// ---------------------------------------------------------------------------
// Conv engine: gather + strategy-dispatched row kernels
// ---------------------------------------------------------------------------

/// Below this many inner-kernel ops the conv runs single-threaded (spawn
/// overhead would dominate — covers the unit-test-sized shapes).
const PAR_MIN_OPS: usize = 1 << 15;

fn max_threads_for(ops: usize) -> usize {
    if ops < PAR_MIN_OPS { 1 } else { usize::MAX }
}

/// f32 convolution (both kernels), NHWC x HWIO -> NHWC, under the
/// default [`KernelStrategy::Auto`] selection (`ADDERNET_KERNEL`
/// override, else shape heuristic).
pub fn conv2d(x: &Tensor, w: &ConvW, stride: usize, padding: Padding,
              kind: SimKernel) -> Tensor {
    conv2d_with(KernelStrategy::Auto, x, w, stride, padding, kind)
}

/// f32 convolution under an explicit kernel strategy — THE dispatch
/// point: `Naive` routes to the reference loop nests, `Tiled`/`Simd`
/// run the parallel gather engine with that strategy's row kernel.
pub fn conv2d_with(strategy: KernelStrategy, x: &Tensor, w: &ConvW,
                   stride: usize, padding: Padding, kind: SimKernel) -> Tensor {
    // The Winograd transforms reassociate float sums, which would break
    // the f32 path's bit-compatibility contract — f32 convs always run
    // a row strategy (`Winograd` falls back via `resolve`).
    let resolved = strategy.resolve(w.cout);
    kernels::note_resolution(resolved.label());
    let krow: ConvRow<f32> = match resolved {
        Resolved::Naive => return reference::conv2d(x, w, stride, padding, kind),
        Resolved::Tiled => kernels::tiled::conv_row_f32,
        Resolved::Simd => kernels::simd::conv_row_f32,
    };
    let (n, h, w_in, cin) = x.shape;
    assert_eq!(cin, w.cin, "cin mismatch");
    let (pt, pl, ho, wo) = nn::conv_geometry(h, w_in, w.kh, w.kw, stride, padding);
    let cout = w.cout;
    let k_taps = w.kh * w.kw * cin;
    let mut out = Tensor::zeros((n, ho, wo, cout));
    if out.data.is_empty() {
        return out;
    }
    let threads = max_threads_for(n * ho * wo * k_taps * cout);
    let (kh, kw) = (w.kh, w.kw);
    let wdat = w.data;
    parallel_chunks(&mut out.data, wo * cout, threads, |row, chunk| {
        let (b, oh) = (row / ho, row % ho);
        let mut rowbuf = vec![0f32; wo * k_taps];
        gather_row(&x.data, h, w_in, cin, kh, kw, b, oh, stride, pt, pl, wo,
                   &mut rowbuf);
        krow(&rowbuf, k_taps, wdat, cout, kind, chunk);
    });
    out
}

/// Quantize both conv operands per `cfg` + `calib`.  For the adder
/// kernel with separate scales the datapath must point-align before
/// subtracting: re-grid the finer operand onto the coarser grid (this
/// throws away bits — the §3.1 motivation).  Returns (xq, wq,
/// dequantization scale).  Shared by the engine and the naive oracle so
/// both see identical integer operands — which makes this the single
/// choke point where the kernel/width policy ([`QuantPlan::supports`])
/// is enforced for EVERY per-call quantized conv: mult tap products can
/// overflow the i32 accumulator past 8-bit operands, so wider mult
/// grids are refused here instead of silently wrapping.
pub(crate) fn quant_operands(x: &[f32], w: &[f32], kind: SimKernel, cfg: QuantCfg,
                             calib: &LayerCalib) -> (Vec<i32>, Vec<i32>, f32) {
    assert!(QuantPlan::supports(kind, cfg.bits),
            "mult-kernel integer convs cap at 8-bit operands (int{} tap \
             products overflow the i32 accumulator); the adder kernel \
             serves all widths", cfg.bits);
    let (xe, we) = match cfg.mode {
        Mode::SharedScale => {
            let e = calib.shared_exp(cfg.bits);
            (e, e)
        }
        Mode::SeparateScale => calib.separate_exps(cfg.bits),
    };
    let xq = quant::quantize_slice(x, xe, cfg.bits);
    let mut wq = quant::quantize_slice(w, we, cfg.bits);
    let (xq, out_e) = if matches!(kind, SimKernel::Adder) && xe != we {
        let coarse = xe.max(we);
        let xq2 = if xe < we { regrid(&xq, we - xe) } else { xq };
        if we < xe {
            wq = regrid(&wq, xe - we);
        }
        (xq2, coarse)
    } else {
        (xq, xe)
    };
    let pre_scale = match kind {
        SimKernel::Adder => (out_e as f32).exp2(),
        SimKernel::Mult => ((xe + we) as f32).exp2(),
    };
    (xq, wq, pre_scale)
}

/// Integer convolution through the widened datapath.  Inputs are
/// quantized per `cfg` using the layer's calibration; the result is
/// dequantized back to f32 for the downstream (BN/pool) float stages,
/// mirroring the FPGA design where BN runs in a wide fixed-point unit.
///
/// i64 accumulation is never needed: |x op w| * K cannot overflow i32
/// for the supported widths (<= 16 bit inputs, K <= 2^14 taps =>
/// |acc| <= 2*32767*2^14 < 2^31) — the RTL analogue is the adder tree's
/// exact DW + log2(K) bits.
pub fn conv2d_quant(x: &Tensor, w: &ConvW, stride: usize, padding: Padding,
                    kind: SimKernel, cfg: QuantCfg, calib: &LayerCalib) -> Tensor {
    conv2d_quant_with(KernelStrategy::Auto, x, w, stride, padding, kind, cfg, calib)
}

/// Integer convolution under an explicit kernel strategy.  All
/// strategies share [`quant_operands`], so they see identical integer
/// operands and (i32 accumulation being order-independent) must produce
/// bit-identical outputs — the cross-strategy oracle contract.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_quant_with(strategy: KernelStrategy, x: &Tensor, w: &ConvW,
                         stride: usize, padding: Padding, kind: SimKernel,
                         cfg: QuantCfg, calib: &LayerCalib) -> Tensor {
    if matches!(strategy.resolve(w.cout), Resolved::Naive) {
        return reference::conv2d_quant(x, w, stride, padding, kind, cfg, calib);
    }
    let (xq, wq, pre_scale) = quant_operands(&x.data, w.data, kind, cfg, calib);
    let qw = QConvW { data: &wq, kh: w.kh, kw: w.kw, cin: w.cin, cout: w.cout };
    let (acc, oshape) = conv2d_int_with(strategy, &xq, x.shape, &qw, stride,
                                        padding, kind);
    let mut out = Tensor::zeros(oshape);
    for (o, &a) in out.data.iter_mut().zip(&acc) {
        *o = a as f32 * pre_scale;
    }
    out
}

/// Integer convolution over ALREADY-quantized operands — the engine the
/// plan-based int path ([`crate::sim::intpath`]) runs between layers
/// without ever leaving the i32 domain, and the core
/// [`conv2d_quant_with`] routes through after per-call quantization.
/// Returns the raw widened accumulators plus the output shape; callers
/// own the (de)quantization story.  All row strategies accumulate taps
/// in ascending (ky, kx, ci) order, so outputs are bit-identical across
/// `Naive`/`Tiled`/`Simd` (i32 accumulation is order-independent); the
/// `Winograd` strategy reaches the same bit-identity on eligible mult
/// convs by algebraic exactness ([`kernels::winograd`]) and falls back
/// to the `Auto` heuristic's row pick everywhere else (shape guard /
/// adder layers / f32), so it slots under the same oracle contract.
pub fn conv2d_int_with(strategy: KernelStrategy, xq: &[i32],
                       shape: (usize, usize, usize, usize), w: &QConvW,
                       stride: usize, padding: Padding, kind: SimKernel)
                       -> (Vec<i32>, (usize, usize, usize, usize)) {
    let (n, h, w_in, cin) = shape;
    assert_eq!(xq.len(), n * h * w_in * cin, "int tensor size mismatch");
    assert_eq!(cin, w.cin, "cin mismatch");
    let (pt, pl, ho, wo) = nn::conv_geometry(h, w_in, w.kh, w.kw, stride, padding);
    let cout = w.cout;
    let oshape = (n, ho, wo, cout);
    let mut out = vec![0i32; n * ho * wo * cout];
    if out.is_empty() {
        return (out, oshape);
    }
    let resolved = strategy.resolve_conv(cout, w.kh, w.kw, stride, cin, kind);
    kernels::note_resolution(resolved.label());
    let k_taps = w.kh * w.kw * cin;
    let threads = max_threads_for(n * ho * wo * k_taps * cout);
    let krow: ConvRow<i32> = match resolved {
        ResolvedConv::Winograd => {
            kernels::winograd::conv2d_int_mult(xq, shape, w.data, cin, cout,
                                               (pt, pl, ho, wo), threads,
                                               &mut out);
            return (out, oshape);
        }
        ResolvedConv::WinogradL1 => {
            kernels::winograd::conv2d_int_adder_l1(xq, shape, w.data, cin, cout,
                                                   (pt, pl, ho, wo), threads,
                                                   &mut out);
            return (out, oshape);
        }
        ResolvedConv::Row(Resolved::Naive) => {
            naive_conv_int(xq, shape, w, stride, (pt, pl, ho, wo), kind, &mut out);
            return (out, oshape);
        }
        ResolvedConv::Row(Resolved::Tiled) => kernels::tiled::conv_row_i32,
        ResolvedConv::Row(Resolved::Simd) => kernels::simd::conv_row_i32,
    };
    let (kh, kw) = (w.kh, w.kw);
    let wdat = w.data;
    parallel_chunks(&mut out, wo * cout, threads, |row, chunk| {
        let (b, oh) = (row / ho, row % ho);
        let mut rowbuf = vec![0i32; wo * k_taps];
        gather_row(xq, h, w_in, cin, kh, kw, b, oh, stride, pt, pl, wo,
                   &mut rowbuf);
        krow(&rowbuf, k_taps, wdat, cout, kind, chunk);
    });
    (out, oshape)
}

/// Naive 7-deep loop nest over integer operands — the same tap order as
/// [`reference::conv2d_quant`]'s core, so the `Naive` strategy of
/// [`conv2d_int_with`] is the in-crate truth for the int engine too.
#[allow(clippy::too_many_arguments)]
fn naive_conv_int(xq: &[i32], shape: (usize, usize, usize, usize), w: &QConvW,
                  stride: usize, geom: (usize, usize, usize, usize),
                  kind: SimKernel, out: &mut [i32]) {
    let (n, h, w_in, cin) = shape;
    let (pt, pl, ho, wo) = geom;
    let cout = w.cout;
    let mut acc = vec![0i32; cout];
    for b in 0..n {
        for oh in 0..ho {
            for ow in 0..wo {
                acc.iter_mut().for_each(|a| *a = 0);
                for ky in 0..w.kh {
                    let iy = (oh * stride + ky) as isize - pt as isize;
                    let row_inside = iy >= 0 && iy < h as isize;
                    for kx in 0..w.kw {
                        let ix = (ow * stride + kx) as isize - pl as isize;
                        let inside = row_inside && ix >= 0 && ix < w_in as isize;
                        for ci in 0..cin {
                            let xv = if inside {
                                xq[((b * h + iy as usize) * w_in + ix as usize)
                                    * cin + ci]
                            } else {
                                0
                            };
                            let off = ((ky * w.kw + kx) * cin + ci) * cout;
                            let wrow = &w.data[off..off + cout];
                            match kind {
                                SimKernel::Adder => {
                                    for (a, &wv) in acc.iter_mut().zip(wrow) {
                                        *a -= (xv - wv).abs();
                                    }
                                }
                                SimKernel::Mult => {
                                    for (a, &wv) in acc.iter_mut().zip(wrow) {
                                        *a += xv * wv;
                                    }
                                }
                            }
                        }
                    }
                }
                let base = ((b * ho + oh) * wo + ow) * cout;
                out[base..base + cout].copy_from_slice(&acc);
            }
        }
    }
}

/// Re-grid integers onto a grid `shift` bits coarser, rounding to even.
fn regrid(q: &[i32], shift: i32) -> Vec<i32> {
    let s = (shift as f32).exp2();
    q.iter().map(|&v| quant::round_even(v as f32 / s) as i32).collect()
}

// ---------------------------------------------------------------------------
// Float glue layers (mirror layers.py eval semantics)
// ---------------------------------------------------------------------------

pub fn batch_norm_eval(x: &mut Tensor, gamma: &[f32], beta: &[f32],
                       mean: &[f32], var: &[f32]) {
    let (_, _, _, c) = x.shape;
    let eps = 1e-5f32;
    let scale: Vec<f32> = (0..c).map(|i| gamma[i] / (var[i] + eps).sqrt()).collect();
    let shift: Vec<f32> = (0..c).map(|i| beta[i] - mean[i] * scale[i]).collect();
    for (i, v) in x.data.iter_mut().enumerate() {
        let ci = i % c;
        *v = *v * scale[ci] + shift[ci];
    }
}

pub fn relu(x: &mut Tensor) {
    for v in x.data.iter_mut() {
        *v = v.max(0.0);
    }
}

pub fn avg_pool2(x: &Tensor) -> Tensor {
    let (n, h, w, c) = x.shape;
    let (ho, wo) = (h / 2, w / 2);
    let mut out = Tensor::zeros((n, ho, wo, c));
    for b in 0..n {
        for oh in 0..ho {
            for ow in 0..wo {
                for ci in 0..c {
                    let s = x.at(b, 2 * oh, 2 * ow, ci)
                        + x.at(b, 2 * oh, 2 * ow + 1, ci)
                        + x.at(b, 2 * oh + 1, 2 * ow, ci)
                        + x.at(b, 2 * oh + 1, 2 * ow + 1, ci);
                    out.data[((b * ho + oh) * wo + ow) * c + ci] = s / 4.0;
                }
            }
        }
    }
    out
}

pub fn global_avg_pool(x: &Tensor) -> Tensor {
    let (n, h, w, c) = x.shape;
    let mut out = Tensor::zeros((n, 1, 1, c));
    for b in 0..n {
        for ci in 0..c {
            let mut s = 0.0;
            for hh in 0..h {
                for ww in 0..w {
                    s += x.at(b, hh, ww, ci);
                }
            }
            out.data[b * c + ci] = s / (h * w) as f32;
        }
    }
    out
}

/// Window max pooling (floor geometry: out = in / stride; taps past the
/// input edge are skipped).  Only the descriptor-only ImageNet graphs
/// carry a MaxPool op today, but the executor domains stay total.
pub fn max_pool(x: &Tensor, window: usize, stride: usize) -> Tensor {
    let (n, h, w, c) = x.shape;
    let (ho, wo) = (h / stride, w / stride);
    let mut out = Tensor::zeros((n, ho, wo, c));
    for b in 0..n {
        for oh in 0..ho {
            for ow in 0..wo {
                for ci in 0..c {
                    let mut m = f32::NEG_INFINITY;
                    for ky in 0..window {
                        let iy = oh * stride + ky;
                        if iy >= h {
                            break;
                        }
                        for kx in 0..window {
                            let ix = ow * stride + kx;
                            if ix >= w {
                                break;
                            }
                            m = m.max(x.at(b, iy, ix, ci));
                        }
                    }
                    out.data[((b * ho + oh) * wo + ow) * c + ci] = m;
                }
            }
        }
    }
    out
}

/// Dense: x (n, 1, 1, din) @ w (din, dout) + b, under the default
/// [`KernelStrategy::Auto`] selection, parallel over the batch.
pub fn dense(x: &Tensor, w: &[f32], bias: &[f32], dout: usize) -> Tensor {
    dense_with(KernelStrategy::Auto, x, w, bias, dout)
}

/// Dense under an explicit kernel strategy.
pub fn dense_with(strategy: KernelStrategy, x: &Tensor, w: &[f32],
                  bias: &[f32], dout: usize) -> Tensor {
    let resolved = strategy.resolve(dout);
    kernels::note_resolution(resolved.label());
    let krow: DenseRow = match resolved {
        Resolved::Naive => return reference::dense(x, w, bias, dout),
        Resolved::Tiled => kernels::tiled::dense_row,
        Resolved::Simd => kernels::simd::dense_row,
    };
    let (n, h, ww, c) = x.shape;
    let din = h * ww * c;
    assert_eq!(w.len(), din * dout, "dense weight size mismatch");
    assert_eq!(bias.len(), dout, "dense bias size mismatch");
    let mut out = Tensor::zeros((n, 1, 1, dout));
    if out.data.is_empty() {
        return out;
    }
    let threads = max_threads_for(n * din * dout);
    parallel_chunks(&mut out.data, dout, threads, |b, orow| {
        let xrow = &x.data[b * din..(b + 1) * din];
        krow(xrow, w, bias, dout, orow);
    });
    out
}

/// Integer dense over ALREADY-quantized operands — the classifier-head
/// twin of [`conv2d_int_with`], dispatched through the same
/// [`KernelStrategy`] subsystem (`Naive` routes to the reference loop in
/// [`super::reference`]).  `xq` is `n` rows of `w.din` i32 operands;
/// `bias` is the integer bias pre-folded onto the accumulator grid.
/// Returns the raw widened accumulators (one i64 per output — a single
/// int16 tap product already exceeds i32, so the dense accumulator is
/// 64-bit where the conv accumulator's i32 bound sufficed); callers own
/// the requantization story.  All strategies accumulate inputs in
/// ascending order with an exact zero-skip, and i64 integer addition is
/// order-independent, so outputs are bit-identical across
/// `Naive`/`Tiled`/`Simd`.
pub fn dense_int_with(strategy: KernelStrategy, xq: &[i32], n: usize,
                      w: &QDenseW, bias: &[i64]) -> Vec<i64> {
    let (din, dout) = (w.din, w.dout);
    assert_eq!(xq.len(), n * din, "dense int input size mismatch");
    assert_eq!(w.data.len(), din * dout, "dense int weight size mismatch");
    assert_eq!(bias.len(), dout, "dense int bias size mismatch");
    let resolved = strategy.resolve(dout);
    kernels::note_resolution(resolved.label());
    let krow: DenseIntRow = match resolved {
        Resolved::Naive => return reference::dense_int(xq, n, w, bias),
        Resolved::Tiled => kernels::tiled::dense_int_row,
        Resolved::Simd => kernels::simd::dense_int_row,
    };
    let mut out = vec![0i64; n * dout];
    if out.is_empty() {
        return out;
    }
    let threads = max_threads_for(n * din * dout);
    let wdat = w.data;
    parallel_chunks(&mut out, dout, threads, |b, orow| {
        krow(&xq[b * din..(b + 1) * din], wdat, bias, dout, orow);
    });
    out
}

pub fn argmax_rows(x: &Tensor) -> Vec<usize> {
    let (n, _, _, c) = x.shape;
    (0..n)
        .map(|b| {
            let row = &x.data[b * c..(b + 1) * c];
            row.iter().enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap()
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Whole-model runner
// ---------------------------------------------------------------------------

/// Named parameter store (loaded from the manifest init/trained bin).
pub type Params = BTreeMap<String, (Vec<usize>, Vec<f32>)>;

// `Arch` (the runtime-servable architectures) lives in
// `crate::nn::graph` next to the compiled op programs; it is
// re-exported above so existing `sim::functional::Arch` paths keep
// working.

/// How the conv layers execute.  `Quant` here is the PER-CALL
/// experiment path (weights re-quantized each forward, activations
/// f32 between layers); the serving path compiles a
/// [`crate::quant::plan::QuantPlan`] instead and runs it on the
/// i32-domain [`crate::sim::intpath::PlanRunner`] — the functional
/// server does that translation automatically for quantized variants.
#[derive(Debug, Clone, Copy)]
pub enum ExecMode {
    F32,
    Quant(QuantCfg),
}

/// Forward runner over named params; optionally records per-layer input
/// feature ranges (the calibration pass / Fig. 3a probe).  The runner is
/// the f32 instantiation of the generic graph walk
/// ([`crate::sim::exec`]): `forward` executes the architecture's
/// compiled op program, and this struct only supplies the numeric-domain
/// hooks.  For plan-compiled integer serving, see
/// [`crate::sim::intpath::PlanRunner`] — the i32 instantiation of the
/// SAME walk.
pub struct Runner<'a> {
    pub params: &'a Params,
    pub arch: Arch,
    pub kind: SimKernel,
    /// Inner-kernel strategy every conv/dense layer dispatches through
    /// (`Auto` honours the `ADDERNET_KERNEL` override).
    pub strategy: KernelStrategy,
    pub mode: ExecMode,
    pub calib: Option<&'a Calibration>,
    /// When set, feature max-abs (and optional full copies) are recorded.
    pub observe: Option<&'a mut Calibration>,
}

fn lookup<'p>(params: &'p Params, name: &str) -> (&'p [usize], &'p [f32]) {
    let (s, d) = params.get(name)
        .unwrap_or_else(|| panic!("missing param {name}"));
    (s, d)
}

impl<'a> Runner<'a> {
    fn p(&self, name: &str) -> (&'a [usize], &'a [f32]) {
        lookup(self.params, name)
    }

    fn conv_block(&mut self, name: &str, x: Tensor, stride: usize,
                  padding: Padding) -> Tensor {
        let (ws, wd) = lookup(self.params, &format!("{name}/conv_w"));
        let w = ConvW { data: wd, kh: ws[0], kw: ws[1], cin: ws[2], cout: ws[3] };
        if let Some(obs) = self.observe.as_deref_mut() {
            let e = obs.entry(name.to_string()).or_default();
            e.feat_max_abs = e.feat_max_abs.max(quant::max_abs(&x.data));
            e.weight_max_abs = quant::max_abs(w.data);
        }
        let mut y = match self.mode {
            ExecMode::F32 => {
                conv2d_with(self.strategy, &x, &w, stride, padding, self.kind)
            }
            ExecMode::Quant(cfg) => {
                let calib = self.calib.expect("quant mode requires calibration");
                let lc = calib.get(name)
                    .unwrap_or_else(|| panic!("no calibration for {name}"));
                conv2d_quant_with(self.strategy, &x, &w, stride, padding,
                                  self.kind, cfg, lc)
            }
        };
        let (_, g) = self.p(&format!("{name}/bn_gamma"));
        let g = g.to_vec();
        let (_, b) = self.p(&format!("{name}/bn_beta"));
        let b = b.to_vec();
        let (_, m) = self.p(&format!("{name}/bn_mean"));
        let m = m.to_vec();
        let (_, v) = self.p(&format!("{name}/bn_var"));
        let v = v.to_vec();
        batch_norm_eval(&mut y, &g, &b, &m, &v);
        y
    }

    fn dense_layer(&self, name: &str, x: &Tensor) -> Tensor {
        let (ws, wd) = self.p(&format!("{name}/dense_w"));
        let (_, bd) = self.p(&format!("{name}/dense_b"));
        dense_with(self.strategy, x, wd, bd, ws[1])
    }

    /// Run the forward pass by walking the architecture's compiled op
    /// program ([`crate::nn::graph`]); returns logits (n, 1, 1, 10).
    ///
    /// The per-call quantized mode enforces the same kernel/width policy
    /// as [`QuantPlan::build`]: mult-kernel integer convs cap at 8-bit
    /// operands, because their tap products can overflow the i32
    /// accumulator on large-tap layers (the adder kernel — the paper's
    /// datapath — is provably i32-bounded at every supported width).
    pub fn forward(&mut self, x: &Tensor) -> Tensor {
        if let ExecMode::Quant(cfg) = self.mode {
            assert!(QuantPlan::supports(self.kind, cfg.bits),
                    "per-call mult-kernel quantization caps at 8-bit operands \
                     (int{} tap products overflow the i32 conv accumulator); \
                     the adder kernel serves all widths", cfg.bits);
        }
        let graph = self.arch.graph();
        exec::run_graph(self, graph, x.clone())
    }

    /// [`Runner::forward`] with per-op instrumentation: the same walk
    /// through [`exec::run_graph_observed`], reporting every op's
    /// wall-time and output stats to `obs`.
    pub fn forward_observed(&mut self, x: &Tensor, obs: &mut dyn ExecObserver)
                            -> Tensor {
        if let ExecMode::Quant(cfg) = self.mode {
            assert!(QuantPlan::supports(self.kind, cfg.bits),
                    "per-call mult-kernel quantization caps at 8-bit operands \
                     (int{} tap products overflow the i32 conv accumulator); \
                     the adder kernel serves all widths", cfg.bits);
        }
        exec::run_graph_observed(self, self.arch.graph(), x.clone(), obs)
    }

    /// Batched inference over independently-queued images: stack them
    /// into ONE forward pass — amortizing dispatch, patch gathers and
    /// weight streaming across the whole queue (the serving hot path) —
    /// then split the logits back per request.  Each image is `h*w*c`
    /// floats in NHWC order.
    pub fn forward_many(&mut self, images: &[&[f32]],
                        hwc: (usize, usize, usize)) -> Vec<Vec<f32>> {
        if images.is_empty() {
            return Vec::new();
        }
        let (h, w, c) = hwc;
        let px = h * w * c;
        let mut data = Vec::with_capacity(images.len() * px);
        for img in images {
            assert_eq!(img.len(), px, "request image size mismatch");
            data.extend_from_slice(img);
        }
        let x = Tensor::new((images.len(), h, w, c), data);
        let logits = self.forward(&x);
        let classes = logits.shape.3;
        (0..images.len())
            .map(|i| logits.data[i * classes..(i + 1) * classes].to_vec())
            .collect()
    }

    /// [`Runner::forward_many`] with per-op instrumentation: the stacked
    /// batch runs ONE observed walk (each per-layer span covers the
    /// whole batch).
    pub fn forward_many_observed(&mut self, images: &[&[f32]],
                                 hwc: (usize, usize, usize),
                                 obs: &mut dyn ExecObserver) -> Vec<Vec<f32>> {
        if images.is_empty() {
            return Vec::new();
        }
        let (h, w, c) = hwc;
        let px = h * w * c;
        let mut data = Vec::with_capacity(images.len() * px);
        for img in images {
            assert_eq!(img.len(), px, "request image size mismatch");
            data.extend_from_slice(img);
        }
        let x = Tensor::new((images.len(), h, w, c), data);
        let logits = self.forward_observed(&x, obs);
        let classes = logits.shape.3;
        (0..images.len())
            .map(|i| logits.data[i * classes..(i + 1) * classes].to_vec())
            .collect()
    }
}

/// The f32 numeric domain: activations are dense f32 [`Tensor`]s, convs
/// run the engine (per-call-quantized in `Quant` mode), BN is the
/// eval-mode float formula, the head is the dense stack.  This is the
/// whole architecture-specific surface of the runner — the topology
/// itself comes from the graph walk.
impl Domain for Runner<'_> {
    type Act = Tensor;

    fn conv_bn(&mut self, spec: &ConvBnSpec, x: Tensor) -> Tensor {
        self.conv_block(&spec.name, x, spec.stride, spec.padding)
    }

    fn relu(&mut self, x: &mut Tensor) {
        relu(x);
    }

    fn avg_pool2(&mut self, x: &Tensor) -> Tensor {
        avg_pool2(x)
    }

    fn max_pool(&mut self, window: usize, stride: usize, x: &Tensor) -> Tensor {
        max_pool(x, window, stride)
    }

    fn global_avg_pool(&mut self, x: &Tensor) -> Tensor {
        global_avg_pool(x)
    }

    fn flatten(&mut self, x: Tensor) -> Tensor {
        // NHWC row-major == jax reshape
        let (n, h, w, c) = x.shape;
        Tensor::new((n, 1, 1, h * w * c), x.data)
    }

    fn residual_add(&mut self, shortcut: Option<&ConvBnSpec>, h: Tensor,
                    saved: Tensor) -> Tensor {
        let sc = match shortcut {
            Some(spec) => self.conv_bn(spec, saved),
            None => saved,
        };
        let mut sum = h;
        for (v, s) in sum.data.iter_mut().zip(&sc.data) {
            *v += s;
        }
        sum
    }

    fn dense(&mut self, spec: &DenseSpec, x: Tensor) -> Tensor {
        // the calibration pass records dense-layer input/weight ranges
        // too, so `QuantPlan::build` can put the integer classifier head
        // on calibrated grids (layers absent from a table fall back to
        // the incoming grid)
        if let Some(obs) = self.observe.as_deref_mut() {
            let (_, wd) = lookup(self.params, &format!("{}/dense_w", spec.name));
            let e = obs.entry(spec.name.clone()).or_default();
            e.feat_max_abs = e.feat_max_abs.max(quant::max_abs(&x.data));
            e.weight_max_abs = quant::max_abs(wd);
        }
        self.dense_layer(&spec.name, &x)
    }

    fn stats(act: &Tensor) -> ActStats {
        let n = act.data.len();
        if n == 0 {
            return ActStats::default();
        }
        let sum: f64 = act.data.iter().map(|v| v.abs() as f64).sum();
        ActStats { elems: n, mean_abs: sum / n as f64 }
    }
}

/// Classification accuracy of a runner over (images, labels).
pub fn accuracy(runner: &mut Runner, images: &Tensor, labels: &[i32]) -> f64 {
    let logits = runner.forward(images);
    let preds = argmax_rows(&logits);
    let correct = preds.iter().zip(labels)
        .filter(|(p, l)| **p == **l as usize)
        .count();
    correct as f64 / labels.len() as f64
}

// ---------------------------------------------------------------------------
// Synthetic parameters (artifact-free operation)
// ---------------------------------------------------------------------------

fn synth_conv(p: &mut Params, rng: &mut XorShift64, name: &str,
              kh: usize, kw: usize, cin: usize, cout: usize) {
    let n = kh * kw * cin * cout;
    let w: Vec<f32> = (0..n).map(|_| rng.next_f32_sym(0.5)).collect();
    p.insert(format!("{name}/conv_w"), (vec![kh, kw, cin, cout], w));
    p.insert(format!("{name}/bn_gamma"), (vec![cout], vec![1.0; cout]));
    p.insert(format!("{name}/bn_beta"), (vec![cout], vec![0.0; cout]));
    p.insert(format!("{name}/bn_mean"), (vec![cout], vec![0.0; cout]));
    p.insert(format!("{name}/bn_var"), (vec![cout], vec![1.0; cout]));
}

fn synth_dense(p: &mut Params, rng: &mut XorShift64, name: &str,
               din: usize, dout: usize) {
    let w: Vec<f32> = (0..din * dout).map(|_| rng.next_f32_sym(0.5)).collect();
    let b: Vec<f32> = (0..dout).map(|_| rng.next_f32_sym(0.1)).collect();
    p.insert(format!("{name}/dense_w"), (vec![din, dout], w));
    p.insert(format!("{name}/dense_b"), (vec![dout], b));
}

/// Deterministic synthetic parameter set for `arch` (random weights +
/// identity BN stats), shaped for the 32x32x1 synthetic-10 input.  Lets
/// the engine, the functional serving backend and the offline test/bench
/// tiers run with no Python-built artifacts.
///
/// Walks the architecture's compiled op program in forward order — a
/// residual block's projection conv after the block's main-path convs —
/// which is exactly the order the pre-graph synthesizer drew random
/// weights in, so parameter values are bit-identical across the
/// refactor for every pre-existing architecture.
pub fn synth_params(arch: Arch, seed: u64) -> Params {
    let mut rng = XorShift64::new(seed);
    let mut p = Params::new();
    for op in &arch.graph().ops {
        match op {
            Op::ConvBn(c) | Op::ResidualClose { shortcut: Some(c) } => {
                synth_conv(&mut p, &mut rng, &c.name, c.kh, c.kw, c.cin, c.cout);
            }
            Op::Dense(d) => synth_dense(&mut p, &mut rng, &d.name, d.din, d.dout),
            _ => {}
        }
    }
    p
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(shape: (usize, usize, usize, usize), data: Vec<f32>) -> Tensor {
        Tensor::new(shape, data)
    }

    #[test]
    fn adder_conv_known_value() {
        // 1x1 kernel, 1 channel: out = -|x - w|
        let x = t((1, 2, 2, 1), vec![1.0, 2.0, 3.0, 4.0]);
        let wdat = vec![2.5f32];
        let w = ConvW { data: &wdat, kh: 1, kw: 1, cin: 1, cout: 1 };
        let y = conv2d(&x, &w, 1, Padding::Same, SimKernel::Adder);
        assert_eq!(y.data, vec![-1.5, -0.5, -0.5, -1.5]);
    }

    #[test]
    fn mult_conv_matches_manual() {
        // 2x2 valid conv, identity-ish weights
        let x = t((1, 2, 2, 1), vec![1.0, 2.0, 3.0, 4.0]);
        let wdat = vec![1.0f32, 0.0, 0.0, 1.0]; // picks x[0,0] + x[1,1]
        let w = ConvW { data: &wdat, kh: 2, kw: 2, cin: 1, cout: 1 };
        let y = conv2d(&x, &w, 1, Padding::Valid, SimKernel::Mult);
        assert_eq!(y.data, vec![5.0]);
    }

    #[test]
    fn adder_conv_same_padding_counts_pad_weights() {
        // at a padded tap, x=0 contributes -|0 - w| = -|w|
        let x = t((1, 1, 1, 1), vec![0.0]);
        let wdat = vec![1.0f32; 9];
        let w = ConvW { data: &wdat, kh: 3, kw: 3, cin: 1, cout: 1 };
        let y = conv2d(&x, &w, 1, Padding::Same, SimKernel::Adder);
        assert_eq!(y.data, vec![-9.0]);
    }

    #[test]
    fn quant_shared_scale_exact_for_grid_values() {
        // if x and w already sit on the shared grid, int conv == f32 conv
        let x = t((1, 3, 3, 1), (0..9).map(|i| (i as f32) * 0.25 - 1.0).collect());
        let wdat: Vec<f32> = (0..9).map(|i| (i as f32) * 0.25 - 1.0).collect();
        let w = ConvW { data: &wdat, kh: 3, kw: 3, cin: 1, cout: 1 };
        let calib = LayerCalib { feat_max_abs: 1.0, weight_max_abs: 1.0 };
        let cfg = QuantCfg { bits: 8, mode: Mode::SharedScale };
        let q = conv2d_quant(&x, &w, 1, Padding::Valid, SimKernel::Adder, cfg, &calib);
        let f = conv2d(&x, &w, 1, Padding::Valid, SimKernel::Adder);
        for (a, b) in q.data.iter().zip(&f.data) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn quant_error_shrinks_with_bits() {
        let mut rng = crate::util::XorShift64::new(9);
        let x = t((1, 8, 8, 3), (0..192).map(|_| rng.next_f32_sym(2.0)).collect());
        let wdat: Vec<f32> = (0..3 * 3 * 3 * 4).map(|_| rng.next_f32_sym(1.5)).collect();
        let w = ConvW { data: &wdat, kh: 3, kw: 3, cin: 3, cout: 4 };
        let fref = conv2d(&x, &w, 1, Padding::Same, SimKernel::Adder);
        let calib = LayerCalib { feat_max_abs: 2.0, weight_max_abs: 1.5 };
        let mut prev = f64::INFINITY;
        for bits in [4u32, 6, 8, 12] {
            let cfg = QuantCfg { bits, mode: Mode::SharedScale };
            let q = conv2d_quant(&x, &w, 1, Padding::Same, SimKernel::Adder, cfg, &calib);
            let err: f64 = q.data.iter().zip(&fref.data)
                .map(|(a, b)| ((a - b) as f64).abs())
                .sum::<f64>() / q.data.len() as f64;
            assert!(err < prev, "bits={bits} err={err} prev={prev}");
            prev = err;
        }
    }

    #[test]
    fn adder_separate_scale_loses_information() {
        // Ranges differ by 8x: separate scales misalign and the aligned
        // adder result is no better than shared (usually worse).
        let mut rng = crate::util::XorShift64::new(5);
        let x = t((1, 6, 6, 2), (0..72).map(|_| rng.next_f32_sym(0.25)).collect());
        let wdat: Vec<f32> = (0..3 * 3 * 2 * 3).map(|_| rng.next_f32_sym(2.0)).collect();
        let w = ConvW { data: &wdat, kh: 3, kw: 3, cin: 2, cout: 3 };
        let fref = conv2d(&x, &w, 1, Padding::Same, SimKernel::Adder);
        let calib = LayerCalib { feat_max_abs: 0.25, weight_max_abs: 2.0 };
        let err = |mode: Mode| -> f64 {
            let cfg = QuantCfg { bits: 6, mode };
            let q = conv2d_quant(&x, &w, 1, Padding::Same, SimKernel::Adder, cfg, &calib);
            q.data.iter().zip(&fref.data)
                .map(|(a, b)| ((a - b) as f64).abs())
                .sum::<f64>() / q.data.len() as f64
        };
        // separate-then-align must not beat shared for the adder kernel
        assert!(err(Mode::SeparateScale) >= 0.8 * err(Mode::SharedScale));
    }

    #[test]
    fn bn_eval_formula() {
        let mut x = t((1, 1, 1, 2), vec![3.0, -1.0]);
        batch_norm_eval(&mut x, &[2.0, 1.0], &[0.5, 0.0], &[1.0, 0.0], &[4.0, 1.0]);
        let want0 = (3.0 - 1.0) / (4.0f32 + 1e-5).sqrt() * 2.0 + 0.5;
        assert!((x.data[0] - want0).abs() < 1e-5);
    }

    #[test]
    fn pool_and_gap() {
        let x = t((1, 2, 2, 1), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(avg_pool2(&x).data, vec![2.5]);
        assert_eq!(global_avg_pool(&x).data, vec![2.5]);
    }

    #[test]
    fn dense_known() {
        let x = t((1, 1, 1, 2), vec![1.0, 2.0]);
        let w = vec![1.0, 0.0, 0.0, 1.0]; // (2, 2) row-major (din, dout)
        let y = dense(&x, &w, &[0.5, -0.5], 2);
        assert_eq!(y.data, vec![1.5, 1.5]);
    }

    #[test]
    fn argmax() {
        let x = t((2, 1, 1, 3), vec![0.0, 2.0, 1.0, 5.0, -1.0, 0.0]);
        assert_eq!(argmax_rows(&x), vec![1, 0]);
    }

    #[test]
    fn dense_int_known_value_every_strategy() {
        // x rows [1, 2] and [3, -4] against the identity weights + bias:
        // the integer head is exact, so every strategy must agree on the
        // exact accumulators (bias pre-folded, zero-skip included).
        let xq = vec![1, 2, 3, -4, 0, 7];
        let wdat = vec![1, 0, 0, 1];
        let w = QDenseW { data: &wdat, din: 2, dout: 2 };
        let bias = vec![5i64, -5];
        for strat in [KernelStrategy::Naive, KernelStrategy::Tiled,
                      KernelStrategy::Simd, KernelStrategy::Winograd,
                      KernelStrategy::Auto] {
            let out = dense_int_with(strat, &xq, 3, &w, &bias);
            assert_eq!(out, vec![6, -3, 8, -9, 5, 2], "{}", strat.label());
        }
    }

    #[test]
    fn dense_int_accumulates_beyond_i32() {
        // int16 operands: 64 taps of 32767 * 32767 blow through i32 —
        // the widened i64 accumulator must carry the exact sum.
        let din = 64usize;
        let xq = vec![32767i32; din];
        let wdat = vec![32767i32; din];
        let w = QDenseW { data: &wdat, din, dout: 1 };
        for strat in [KernelStrategy::Naive, KernelStrategy::Tiled,
                      KernelStrategy::Simd] {
            let out = dense_int_with(strat, &xq, 1, &w, &[0]);
            assert_eq!(out, vec![din as i64 * 32767 * 32767], "{}", strat.label());
        }
    }

    #[test]
    fn dense_int_strategies_bit_identical_on_random_rows() {
        let mut rng = crate::util::XorShift64::new(17);
        let (n, din, dout) = (3usize, 37, 21); // tile- and lane-unaligned
        let xq: Vec<i32> = (0..n * din)
            .map(|_| (rng.next_f32_sym(1.0) * 127.0) as i32)
            .collect();
        let wdat: Vec<i32> = (0..din * dout)
            .map(|_| (rng.next_f32_sym(1.0) * 127.0) as i32)
            .collect();
        let bias: Vec<i64> = (0..dout)
            .map(|_| (rng.next_f32_sym(1.0) * 1000.0) as i64)
            .collect();
        let w = QDenseW { data: &wdat, din, dout };
        let want = dense_int_with(KernelStrategy::Naive, &xq, n, &w, &bias);
        for strat in [KernelStrategy::Tiled, KernelStrategy::Simd,
                      KernelStrategy::Winograd] {
            assert_eq!(dense_int_with(strat, &xq, n, &w, &bias), want,
                       "{}", strat.label());
        }
    }

    #[test]
    fn max_pool_window_and_tail() {
        // 3x3 input, window 2 stride 2: one output, max of the top-left
        // 2x2 window; the edge row/col is dropped by floor geometry.
        let x = t((1, 3, 3, 1), vec![1.0, 5.0, 9.0,
                                     2.0, 3.0, 8.0,
                                     7.0, 4.0, 6.0]);
        let y = max_pool(&x, 2, 2);
        assert_eq!(y.shape, (1, 1, 1, 1));
        assert_eq!(y.data, vec![5.0]);
        // window larger than the remaining input clips at the edge
        let z = max_pool(&x, 3, 1);
        assert_eq!(z.shape, (1, 3, 3, 1));
        assert_eq!(z.data[0], 9.0);
        assert_eq!(z.data[8], 6.0);
    }

    #[test]
    #[should_panic(expected = "8-bit")]
    fn percall_mult_refuses_int16() {
        // the per-call experiment path enforces QuantPlan::supports —
        // wide mult plans were already refused at plan build.
        let params = synth_params(Arch::Lenet5, 11);
        let calib: Calibration = [("conv1", 1.0f32), ("conv2", 4.0)].iter()
            .map(|&(n, f)| (n.to_string(),
                            LayerCalib { feat_max_abs: f, weight_max_abs: 0.5 }))
            .collect();
        let mut r = Runner {
            params: &params, arch: Arch::Lenet5, kind: SimKernel::Mult,
            strategy: KernelStrategy::Auto,
            mode: ExecMode::Quant(QuantCfg { bits: 16, mode: Mode::SharedScale }),
            calib: Some(&calib), observe: None,
        };
        r.forward(&Tensor::zeros((1, 32, 32, 1)));
    }

    #[test]
    fn synth_params_run_every_arch() {
        for arch in [Arch::Lenet5, Arch::Cnv6, Arch::Resnet8] {
            let params = synth_params(arch, 11);
            let x = Tensor::zeros((2, 32, 32, 1));
            let mut r = Runner {
                params: &params, arch, kind: SimKernel::Adder,
                strategy: KernelStrategy::Auto,
                mode: ExecMode::F32, calib: None, observe: None,
            };
            let y = r.forward(&x);
            assert_eq!(y.shape, (2, 1, 1, 10));
            assert!(y.data.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn forward_many_splits_logits() {
        let params = synth_params(Arch::Lenet5, 3);
        let mut rng = crate::util::XorShift64::new(8);
        let imgs: Vec<Vec<f32>> = (0..3)
            .map(|_| (0..1024).map(|_| rng.next_f32_sym(1.0)).collect())
            .collect();
        let refs: Vec<&[f32]> = imgs.iter().map(|v| v.as_slice()).collect();
        let mut r = Runner {
            params: &params, arch: Arch::Lenet5, kind: SimKernel::Adder,
            strategy: KernelStrategy::Auto,
            mode: ExecMode::F32, calib: None, observe: None,
        };
        let many = r.forward_many(&refs, (32, 32, 1));
        assert_eq!(many.len(), 3);
        for (i, img) in imgs.iter().enumerate() {
            let x = Tensor::new((1, 32, 32, 1), img.clone());
            let single = r.forward(&x);
            for (a, b) in many[i].iter().zip(&single.data) {
                assert!((a - b).abs() <= 1e-5 * b.abs().max(1.0),
                        "req {i}: {a} vs {b}");
            }
        }
    }
}
