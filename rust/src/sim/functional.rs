//! Bit-accurate functional model of the accelerator datapath.
//!
//! Executes LeNet-5 / ResNet-8/20 forward passes in two modes:
//!
//! * **f32** — mirrors `python/compile/model.py` eval semantics exactly
//!   (cross-validated against the AOT HLO eval graphs in
//!   `rust/tests/integration.rs`), and doubles as the calibration pass
//!   that records per-layer feature ranges.
//! * **quantized** — integer arithmetic through the same widened
//!   accumulator the RTL datapath would use (i32 covers DW + log2(K) for
//!   every supported width, see conv2d_quant), with the paper's
//!   shared-scaling-factor mode or the CNN-style separate-scale mode
//!   (S7 contrast).
//!
//! This module is the Layer-3 hot path the §Perf pass optimizes.

use std::collections::BTreeMap;

use crate::nn::Padding;
use crate::quant::{self, Calibration, LayerCalib, Mode};

/// Dense NHWC tensor (n = batch).
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub data: Vec<f32>,
    /// (n, h, w, c); dense activations use (n, 1, 1, c).
    pub shape: (usize, usize, usize, usize),
}

impl Tensor {
    pub fn new(shape: (usize, usize, usize, usize), data: Vec<f32>) -> Self {
        let (n, h, w, c) = shape;
        assert_eq!(data.len(), n * h * w * c, "tensor size mismatch");
        Self { data, shape }
    }

    pub fn zeros(shape: (usize, usize, usize, usize)) -> Self {
        let (n, h, w, c) = shape;
        Self { data: vec![0.0; n * h * w * c], shape }
    }

    #[inline]
    pub fn at(&self, n: usize, h: usize, w: usize, c: usize) -> f32 {
        let (_, hh, ww, cc) = self.shape;
        self.data[((n * hh + h) * ww + w) * cc + c]
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }
}

/// Which similarity the conv kernel computes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimKernel {
    /// AdderNet: out = -sum |x - w|.
    Adder,
    /// CNN: out = sum x * w.
    Mult,
}

/// Quantization configuration for the integer mode.
#[derive(Debug, Clone, Copy)]
pub struct QuantCfg {
    pub bits: u32,
    pub mode: Mode,
}

fn same_pad(in_sz: usize, k: usize, stride: usize) -> (usize, usize) {
    let out = in_sz.div_ceil(stride);
    let total = ((out - 1) * stride + k).saturating_sub(in_sz);
    (total / 2, total - total / 2)
}

/// Convolution weights: (kh, kw, cin, cout) row-major — the layout the
/// manifest records (HWIO, same as the JAX side).
#[derive(Debug, Clone)]
pub struct ConvW<'a> {
    pub data: &'a [f32],
    pub kh: usize,
    pub kw: usize,
    pub cin: usize,
    pub cout: usize,
}

/// f32 convolution (both kernels), NHWC x HWIO -> NHWC.
pub fn conv2d(x: &Tensor, w: &ConvW, stride: usize, padding: Padding,
              kind: SimKernel) -> Tensor {
    let (n, h, ww_in, cin) = x.shape;
    assert_eq!(cin, w.cin);
    let (pt, _pb, pl, _pr, ho, wo) = conv_geom(h, ww_in, w.kh, w.kw, stride, padding);
    let mut out = Tensor::zeros((n, ho, wo, w.cout));
    let cout = w.cout;
    // §Perf: for the adder kernel, a zero-padded tap contributes exactly
    // -sum_ci |w[ky,kx,ci,:]|; precompute those per-tap column sums once
    // so padded border pixels cost O(cout) instead of O(cin*cout).
    let pad_tap: Vec<f32> = if matches!(kind, SimKernel::Adder) {
        let mut v = vec![0f32; w.kh * w.kw * cout];
        for t in 0..w.kh * w.kw {
            for ci in 0..cin {
                let row = &w.data[(t * cin + ci) * cout..(t * cin + ci + 1) * cout];
                for (s, &wv) in v[t * cout..(t + 1) * cout].iter_mut().zip(row) {
                    *s += wv.abs();
                }
            }
        }
        v
    } else {
        Vec::new()
    };
    let mut acc = vec![0f32; cout];
    for b in 0..n {
        for oh in 0..ho {
            for ow in 0..wo {
                acc.iter_mut().for_each(|a| *a = 0.0);
                for ky in 0..w.kh {
                    let iy = (oh * stride + ky) as isize - pt as isize;
                    let row_inside = iy >= 0 && iy < h as isize;
                    for kx in 0..w.kw {
                        let ix = (ow * stride + kx) as isize - pl as isize;
                        if !row_inside || ix < 0 || ix >= ww_in as isize {
                            // SAME zero padding: x = 0 contributes
                            // -|0-w| for adder, nothing for mult.
                            if matches!(kind, SimKernel::Adder) {
                                let t = ky * w.kw + kx;
                                for (a, &s) in acc.iter_mut()
                                    .zip(&pad_tap[t * cout..(t + 1) * cout]) {
                                    *a -= s;
                                }
                            }
                            continue;
                        }
                        let xoff = ((b * h + iy as usize) * ww_in + ix as usize) * cin;
                        let xrow = &x.data[xoff..xoff + cin];
                        for (ci, &xv) in xrow.iter().enumerate() {
                            let wo_ = ((ky * w.kw + kx) * cin + ci) * cout;
                            let wrow = &w.data[wo_..wo_ + cout];
                            match kind {
                                SimKernel::Adder => {
                                    for (a, &wv) in acc.iter_mut().zip(wrow) {
                                        *a -= (xv - wv).abs();
                                    }
                                }
                                SimKernel::Mult => {
                                    if xv != 0.0 {
                                        for (a, &wv) in acc.iter_mut().zip(wrow) {
                                            *a += xv * wv;
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
                let base = ((b * ho + oh) * wo + ow) * cout;
                out.data[base..base + cout].copy_from_slice(&acc);
            }
        }
    }
    out
}

fn conv_geom(h: usize, w: usize, kh: usize, kw: usize, stride: usize,
             padding: Padding) -> (usize, usize, usize, usize, usize, usize) {
    match padding {
        Padding::Same => {
            let (pt, pb) = same_pad(h, kh, stride);
            let (pl, pr) = same_pad(w, kw, stride);
            (pt, pb, pl, pr, h.div_ceil(stride), w.div_ceil(stride))
        }
        Padding::Valid => (0, 0, 0, 0, (h - kh) / stride + 1, (w - kw) / stride + 1),
    }
}

/// Integer convolution through the widened datapath.  Inputs are
/// quantized per `cfg` using the layer's calibration; the result is
/// dequantized back to f32 for the downstream (BN/pool) float stages,
/// mirroring the FPGA design where BN runs in a wide fixed-point unit.
pub fn conv2d_quant(x: &Tensor, w: &ConvW, stride: usize, padding: Padding,
                    kind: SimKernel, cfg: QuantCfg, calib: &LayerCalib) -> Tensor {
    let (n, h, ww_in, cin) = x.shape;
    let cout = w.cout;
    // --- quantize operands -------------------------------------------------
    let (xe, we) = match cfg.mode {
        Mode::SharedScale => {
            let e = calib.shared_exp(cfg.bits);
            (e, e)
        }
        Mode::SeparateScale => calib.separate_exps(cfg.bits),
    };
    let xq = quant::quantize_slice(&x.data, xe, cfg.bits);
    let mut wq = quant::quantize_slice(w.data, we, cfg.bits);
    // For the adder kernel with separate scales the datapath must
    // point-align before subtracting: re-grid the finer operand onto the
    // coarser grid (this throws away bits — the §3.1 motivation).
    let (xq, out_e, prod_e) = if matches!(kind, SimKernel::Adder) && xe != we {
        let coarse = xe.max(we);
        let xq2 = if xe < we { regrid(&xq, we - xe) } else { xq };
        if we < xe {
            wq = regrid(&wq, xe - we);
        }
        (xq2, coarse, 0)
    } else {
        (xq, xe, xe + we)
    };
    let _ = prod_e;
    let (pt, _pb, pl, _pr, ho, wo) = conv_geom(h, ww_in, w.kh, w.kw, stride, padding);
    let mut out = Tensor::zeros((n, ho, wo, cout));
    // §Perf: i64 accumulation is only needed when |x op w| * K can
    // overflow i32 — never for the supported widths (<= 16 bit inputs,
    // K <= 2^14 taps => |acc| <= 2*32767*2^14 < 2^31).  Widened-datapath
    // semantics are identical; the RTL analogue is the adder tree's
    // exact DW + log2(K) bits.
    let mut acc = vec![0i32; cout];
    let pre_scale = match kind {
        SimKernel::Adder => (out_e as f32).exp2(),
        SimKernel::Mult => ((xe + we) as f32).exp2(),
    };
    for b in 0..n {
        for oh in 0..ho {
            for ow in 0..wo {
                acc.iter_mut().for_each(|a| *a = 0);
                for ky in 0..w.kh {
                    let iy = (oh * stride + ky) as isize - pt as isize;
                    let row_inside = iy >= 0 && iy < h as isize;
                    for kx in 0..w.kw {
                        let ix = (ow * stride + kx) as isize - pl as isize;
                        let inside = row_inside && ix >= 0 && ix < ww_in as isize;
                        if !inside && matches!(kind, SimKernel::Mult) {
                            continue; // 0 * w adds nothing
                        }
                        let xrow: &[i32] = if inside {
                            let o = ((b * h + iy as usize) * ww_in + ix as usize) * cin;
                            &xq[o..o + cin]
                        } else {
                            &[]
                        };
                        for ci in 0..cin {
                            let xv = if inside { xrow[ci] } else { 0 };
                            let wo_ = ((ky * w.kw + kx) * cin + ci) * cout;
                            let wrow = &wq[wo_..wo_ + cout];
                            match kind {
                                SimKernel::Adder => {
                                    for (a, &wv) in acc.iter_mut().zip(wrow) {
                                        *a -= (xv - wv).abs();
                                    }
                                }
                                SimKernel::Mult => {
                                    for (a, &wv) in acc.iter_mut().zip(wrow) {
                                        *a += xv * wv;
                                    }
                                }
                            }
                        }
                    }
                }
                let base = ((b * ho + oh) * wo + ow) * cout;
                for (o, &a) in out.data[base..base + cout].iter_mut().zip(acc.iter()) {
                    *o = a as f32 * pre_scale;
                }
            }
        }
    }
    out
}

/// Re-grid integers onto a grid `shift` bits coarser, rounding to even.
fn regrid(q: &[i32], shift: i32) -> Vec<i32> {
    let s = (shift as f32).exp2();
    q.iter().map(|&v| quant::round_even(v as f32 / s) as i32).collect()
}

// ---------------------------------------------------------------------------
// Float glue layers (mirror layers.py eval semantics)
// ---------------------------------------------------------------------------

pub fn batch_norm_eval(x: &mut Tensor, gamma: &[f32], beta: &[f32],
                       mean: &[f32], var: &[f32]) {
    let (_, _, _, c) = x.shape;
    let eps = 1e-5f32;
    let scale: Vec<f32> = (0..c).map(|i| gamma[i] / (var[i] + eps).sqrt()).collect();
    let shift: Vec<f32> = (0..c).map(|i| beta[i] - mean[i] * scale[i]).collect();
    for (i, v) in x.data.iter_mut().enumerate() {
        let ci = i % c;
        *v = *v * scale[ci] + shift[ci];
    }
}

pub fn relu(x: &mut Tensor) {
    for v in x.data.iter_mut() {
        *v = v.max(0.0);
    }
}

pub fn avg_pool2(x: &Tensor) -> Tensor {
    let (n, h, w, c) = x.shape;
    let (ho, wo) = (h / 2, w / 2);
    let mut out = Tensor::zeros((n, ho, wo, c));
    for b in 0..n {
        for oh in 0..ho {
            for ow in 0..wo {
                for ci in 0..c {
                    let s = x.at(b, 2 * oh, 2 * ow, ci)
                        + x.at(b, 2 * oh, 2 * ow + 1, ci)
                        + x.at(b, 2 * oh + 1, 2 * ow, ci)
                        + x.at(b, 2 * oh + 1, 2 * ow + 1, ci);
                    out.data[((b * ho + oh) * wo + ow) * c + ci] = s / 4.0;
                }
            }
        }
    }
    out
}

pub fn global_avg_pool(x: &Tensor) -> Tensor {
    let (n, h, w, c) = x.shape;
    let mut out = Tensor::zeros((n, 1, 1, c));
    for b in 0..n {
        for ci in 0..c {
            let mut s = 0.0;
            for hh in 0..h {
                for ww in 0..w {
                    s += x.at(b, hh, ww, ci);
                }
            }
            out.data[b * c + ci] = s / (h * w) as f32;
        }
    }
    out
}

/// Dense: x (n, 1, 1, din) @ w (din, dout) + b.
pub fn dense(x: &Tensor, w: &[f32], bias: &[f32], dout: usize) -> Tensor {
    let (n, h, ww, c) = x.shape;
    let din = h * ww * c;
    assert_eq!(w.len(), din * dout);
    let mut out = Tensor::zeros((n, 1, 1, dout));
    for b in 0..n {
        let xrow = &x.data[b * din..(b + 1) * din];
        let orow = &mut out.data[b * dout..(b + 1) * dout];
        orow.copy_from_slice(bias);
        for (i, &xv) in xrow.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let wrow = &w[i * dout..(i + 1) * dout];
            for (o, &wv) in orow.iter_mut().zip(wrow) {
                *o += xv * wv;
            }
        }
    }
    out
}

pub fn argmax_rows(x: &Tensor) -> Vec<usize> {
    let (n, _, _, c) = x.shape;
    (0..n)
        .map(|b| {
            let row = &x.data[b * c..(b + 1) * c];
            row.iter().enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap()
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Whole-model runner
// ---------------------------------------------------------------------------

/// Named parameter store (loaded from the manifest init/trained bin).
pub type Params = BTreeMap<String, (Vec<usize>, Vec<f32>)>;

/// Model architectures the functional runner executes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Arch {
    Lenet5,
    Resnet8,
    Resnet20,
}

impl Arch {
    pub fn parse(s: &str) -> Option<Arch> {
        match s {
            "lenet5" => Some(Arch::Lenet5),
            "resnet8" => Some(Arch::Resnet8),
            "resnet20" => Some(Arch::Resnet20),
            _ => None,
        }
    }

    pub fn stages(&self) -> usize {
        match self {
            Arch::Lenet5 => 0,
            Arch::Resnet8 => 1,
            Arch::Resnet20 => 3,
        }
    }
}

/// How the conv layers execute.
#[derive(Debug, Clone, Copy)]
pub enum ExecMode {
    F32,
    Quant(QuantCfg),
}

/// Forward runner over named params; optionally records per-layer input
/// feature ranges (the calibration pass / Fig. 3a probe).
pub struct Runner<'a> {
    pub params: &'a Params,
    pub arch: Arch,
    pub kind: SimKernel,
    pub mode: ExecMode,
    pub calib: Option<&'a Calibration>,
    /// When set, feature max-abs (and optional full copies) are recorded.
    pub observe: Option<&'a mut Calibration>,
}

fn lookup<'p>(params: &'p Params, name: &str) -> (&'p [usize], &'p [f32]) {
    let (s, d) = params.get(name)
        .unwrap_or_else(|| panic!("missing param {name}"));
    (s, d)
}

impl<'a> Runner<'a> {
    fn p(&self, name: &str) -> (&'a [usize], &'a [f32]) {
        lookup(self.params, name)
    }

    fn conv_block(&mut self, name: &str, x: Tensor, stride: usize,
                  padding: Padding) -> Tensor {
        let (ws, wd) = lookup(self.params, &format!("{name}/conv_w"));
        let w = ConvW { data: wd, kh: ws[0], kw: ws[1], cin: ws[2], cout: ws[3] };
        if let Some(obs) = self.observe.as_deref_mut() {
            let e = obs.entry(name.to_string()).or_default();
            e.feat_max_abs = e.feat_max_abs.max(quant::max_abs(&x.data));
            e.weight_max_abs = quant::max_abs(w.data);
        }
        let mut y = match self.mode {
            ExecMode::F32 => conv2d(&x, &w, stride, padding, self.kind),
            ExecMode::Quant(cfg) => {
                let calib = self.calib.expect("quant mode requires calibration");
                let lc = calib.get(name)
                    .unwrap_or_else(|| panic!("no calibration for {name}"));
                conv2d_quant(&x, &w, stride, padding, self.kind, cfg, lc)
            }
        };
        let (_, g) = self.p(&format!("{name}/bn_gamma"));
        let g = g.to_vec();
        let (_, b) = self.p(&format!("{name}/bn_beta"));
        let b = b.to_vec();
        let (_, m) = self.p(&format!("{name}/bn_mean"));
        let m = m.to_vec();
        let (_, v) = self.p(&format!("{name}/bn_var"));
        let v = v.to_vec();
        batch_norm_eval(&mut y, &g, &b, &m, &v);
        y
    }

    fn dense_layer(&self, name: &str, x: &Tensor) -> Tensor {
        let (ws, wd) = self.p(&format!("{name}/dense_w"));
        let (_, bd) = self.p(&format!("{name}/dense_b"));
        dense(x, wd, bd, ws[1])
    }

    /// Run the forward pass; returns logits (n, 1, 1, 10).
    pub fn forward(&mut self, x: &Tensor) -> Tensor {
        match self.arch {
            Arch::Lenet5 => {
                let mut y = self.conv_block("conv1", x.clone(), 1, Padding::Valid);
                relu(&mut y);
                let mut y = avg_pool2(&y);
                y = self.conv_block("conv2", y, 1, Padding::Valid);
                relu(&mut y);
                let y = avg_pool2(&y);
                // flatten (NHWC row-major == jax reshape)
                let (n, h, w, c) = y.shape;
                let y = Tensor::new((n, 1, 1, h * w * c), y.data);
                let mut y = self.dense_layer("fc1", &y);
                relu(&mut y);
                let mut y = self.dense_layer("fc2", &y);
                relu(&mut y);
                self.dense_layer("fc3", &y)
            }
            Arch::Resnet8 | Arch::Resnet20 => {
                let n_blocks = self.arch.stages();
                let mut y = self.conv_block("stem", x.clone(), 1, Padding::Same);
                relu(&mut y);
                let mut cin = 16;
                for (s, cout) in [16usize, 32, 64].into_iter().enumerate() {
                    for b in 0..n_blocks {
                        let pre = format!("s{s}b{b}");
                        let stride = if s > 0 && b == 0 { 2 } else { 1 };
                        let mut h = self.conv_block(&format!("{pre}/c1"),
                                                    y.clone(), stride, Padding::Same);
                        relu(&mut h);
                        let h = self.conv_block(&format!("{pre}/c2"), h, 1,
                                                Padding::Same);
                        let sc = if cin != cout {
                            self.conv_block(&format!("{pre}/sc"), y.clone(),
                                            stride, Padding::Same)
                        } else {
                            y.clone()
                        };
                        let mut sum = h;
                        for (v, s) in sum.data.iter_mut().zip(&sc.data) {
                            *v += s;
                        }
                        relu(&mut sum);
                        y = sum;
                        cin = cout;
                    }
                }
                let y = global_avg_pool(&y);
                self.dense_layer("fc", &y)
            }
        }
    }
}

/// Classification accuracy of a runner over (images, labels).
pub fn accuracy(runner: &mut Runner, images: &Tensor, labels: &[i32]) -> f64 {
    let logits = runner.forward(images);
    let preds = argmax_rows(&logits);
    let correct = preds.iter().zip(labels)
        .filter(|(p, l)| **p == **l as usize)
        .count();
    correct as f64 / labels.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(shape: (usize, usize, usize, usize), data: Vec<f32>) -> Tensor {
        Tensor::new(shape, data)
    }

    #[test]
    fn adder_conv_known_value() {
        // 1x1 kernel, 1 channel: out = -|x - w|
        let x = t((1, 2, 2, 1), vec![1.0, 2.0, 3.0, 4.0]);
        let wdat = vec![2.5f32];
        let w = ConvW { data: &wdat, kh: 1, kw: 1, cin: 1, cout: 1 };
        let y = conv2d(&x, &w, 1, Padding::Same, SimKernel::Adder);
        assert_eq!(y.data, vec![-1.5, -0.5, -0.5, -1.5]);
    }

    #[test]
    fn mult_conv_matches_manual() {
        // 2x2 valid conv, identity-ish weights
        let x = t((1, 2, 2, 1), vec![1.0, 2.0, 3.0, 4.0]);
        let wdat = vec![1.0f32, 0.0, 0.0, 1.0]; // picks x[0,0] + x[1,1]
        let w = ConvW { data: &wdat, kh: 2, kw: 2, cin: 1, cout: 1 };
        let y = conv2d(&x, &w, 1, Padding::Valid, SimKernel::Mult);
        assert_eq!(y.data, vec![5.0]);
    }

    #[test]
    fn adder_conv_same_padding_counts_pad_weights() {
        // at a padded tap, x=0 contributes -|0 - w| = -|w|
        let x = t((1, 1, 1, 1), vec![0.0]);
        let wdat = vec![1.0f32; 9];
        let w = ConvW { data: &wdat, kh: 3, kw: 3, cin: 1, cout: 1 };
        let y = conv2d(&x, &w, 1, Padding::Same, SimKernel::Adder);
        assert_eq!(y.data, vec![-9.0]);
    }

    #[test]
    fn quant_shared_scale_exact_for_grid_values() {
        // if x and w already sit on the shared grid, int conv == f32 conv
        let x = t((1, 3, 3, 1), (0..9).map(|i| (i as f32) * 0.25 - 1.0).collect());
        let wdat: Vec<f32> = (0..9).map(|i| (i as f32) * 0.25 - 1.0).collect();
        let w = ConvW { data: &wdat, kh: 3, kw: 3, cin: 1, cout: 1 };
        let calib = LayerCalib { feat_max_abs: 1.0, weight_max_abs: 1.0 };
        let cfg = QuantCfg { bits: 8, mode: Mode::SharedScale };
        let q = conv2d_quant(&x, &w, 1, Padding::Valid, SimKernel::Adder, cfg, &calib);
        let f = conv2d(&x, &w, 1, Padding::Valid, SimKernel::Adder);
        for (a, b) in q.data.iter().zip(&f.data) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn quant_error_shrinks_with_bits() {
        let mut rng = crate::util::XorShift64::new(9);
        let x = t((1, 8, 8, 3), (0..192).map(|_| rng.next_f32_sym(2.0)).collect());
        let wdat: Vec<f32> = (0..3 * 3 * 3 * 4).map(|_| rng.next_f32_sym(1.5)).collect();
        let w = ConvW { data: &wdat, kh: 3, kw: 3, cin: 3, cout: 4 };
        let fref = conv2d(&x, &w, 1, Padding::Same, SimKernel::Adder);
        let calib = LayerCalib { feat_max_abs: 2.0, weight_max_abs: 1.5 };
        let mut prev = f64::INFINITY;
        for bits in [4u32, 6, 8, 12] {
            let cfg = QuantCfg { bits, mode: Mode::SharedScale };
            let q = conv2d_quant(&x, &w, 1, Padding::Same, SimKernel::Adder, cfg, &calib);
            let err: f64 = q.data.iter().zip(&fref.data)
                .map(|(a, b)| ((a - b) as f64).abs())
                .sum::<f64>() / q.data.len() as f64;
            assert!(err < prev, "bits={bits} err={err} prev={prev}");
            prev = err;
        }
    }

    #[test]
    fn adder_separate_scale_loses_information() {
        // Ranges differ by 8x: separate scales misalign and the aligned
        // adder result is no better than shared (usually worse).
        let mut rng = crate::util::XorShift64::new(5);
        let x = t((1, 6, 6, 2), (0..72).map(|_| rng.next_f32_sym(0.25)).collect());
        let wdat: Vec<f32> = (0..3 * 3 * 2 * 3).map(|_| rng.next_f32_sym(2.0)).collect();
        let w = ConvW { data: &wdat, kh: 3, kw: 3, cin: 2, cout: 3 };
        let fref = conv2d(&x, &w, 1, Padding::Same, SimKernel::Adder);
        let calib = LayerCalib { feat_max_abs: 0.25, weight_max_abs: 2.0 };
        let err = |mode: Mode| -> f64 {
            let cfg = QuantCfg { bits: 6, mode };
            let q = conv2d_quant(&x, &w, 1, Padding::Same, SimKernel::Adder, cfg, &calib);
            q.data.iter().zip(&fref.data)
                .map(|(a, b)| ((a - b) as f64).abs())
                .sum::<f64>() / q.data.len() as f64
        };
        // separate-then-align must not beat shared for the adder kernel
        assert!(err(Mode::SeparateScale) >= 0.8 * err(Mode::SharedScale));
    }

    #[test]
    fn bn_eval_formula() {
        let mut x = t((1, 1, 1, 2), vec![3.0, -1.0]);
        batch_norm_eval(&mut x, &[2.0, 1.0], &[0.5, 0.0], &[1.0, 0.0], &[4.0, 1.0]);
        let want0 = (3.0 - 1.0) / (4.0f32 + 1e-5).sqrt() * 2.0 + 0.5;
        assert!((x.data[0] - want0).abs() < 1e-5);
    }

    #[test]
    fn pool_and_gap() {
        let x = t((1, 2, 2, 1), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(avg_pool2(&x).data, vec![2.5]);
        assert_eq!(global_avg_pool(&x).data, vec![2.5]);
    }

    #[test]
    fn dense_known() {
        let x = t((1, 1, 1, 2), vec![1.0, 2.0]);
        let w = vec![1.0, 0.0, 0.0, 1.0]; // (2, 2) row-major (din, dout)
        let y = dense(&x, &w, &[0.5, -0.5], 2);
        assert_eq!(y.data, vec![1.5, 1.5]);
    }

    #[test]
    fn argmax() {
        let x = t((2, 1, 1, 3), vec![0.0, 2.0, 1.0, 5.0, -1.0, 0.0]);
        assert_eq!(argmax_rows(&x), vec![1, 0]);
    }
}
