//! General-purpose accelerator simulator (paper Fig. 4 + §4 on-board run).
//!
//! Models the ZCU104-class design: a Pin x Pout PE array fed by on-chip
//! ping/pong buffers over AXI from DRAM.  Produces
//!
//! * a **resource breakdown** (conv kernels / adder tree / storage /
//!   control / others) — the component bars of Fig. 4(c1)(c2)(d1)(d2);
//! * a **cycle-level schedule** of a network: per-layer compute vs DMA
//!   cycles with double-buffer overlap — GOPs, latency, utilization
//!   (the §4 on-board numbers and the S8 "this work" row);
//! * a **power report** via `hw::power` — the 2.57 W vs 1.34 W contrast.
//!
//! Scheduling model: convolutions are tiled `ceil(cin*kh*kw / pin)` input
//! groups x `ceil(cout / pout)` output groups; kernel taps are mapped
//! across the Pin lanes (this is how the paper sustains ~97% utilization
//! on layers whose cin is below Pin).

use crate::hw::array::PeArray;
use crate::hw::device::Device;
use crate::hw::kernelcircuit::KernelKind;
use crate::hw::memory::{AxiBus, ZCU104_AXI};
use crate::hw::power::{self, PowerReport};
use crate::hw::timing;
use crate::nn::{pool_out_dim, Layer, NetworkDesc};

/// Accelerator configuration.
#[derive(Debug, Clone, Copy)]
pub struct AccelConfig {
    pub pin: u64,
    pub pout: u64,
    pub dw: u32,
    pub kernel: KernelKind,
    pub device: Device,
    /// Off-chip weights/features (the Fig. 4 design). False = everything
    /// resident on chip (the Fig. 5 regime).
    pub use_dram: bool,
}

impl AccelConfig {
    pub fn zcu104(parallelism: u64, dw: u32, kernel: KernelKind) -> Self {
        // paper geometry: Pin fixed at 64, Pout scales.
        let pin = 64.min(parallelism);
        Self {
            pin,
            pout: (parallelism / pin).max(1),
            dw,
            kernel,
            device: crate::hw::device::ZCU104,
            use_dram: true,
        }
    }

    pub fn array(&self) -> PeArray {
        PeArray::new(self.pin, self.pout, self.dw, self.kernel)
    }

    pub fn parallelism(&self) -> u64 {
        self.pin * self.pout
    }
}

/// LUT breakdown matching the component bars of Fig. 4(c1)/(c2).
#[derive(Debug, Clone, Copy)]
pub struct ResourceBreakdown {
    pub conv_kernel_luts: u64,
    pub adder_tree_luts: u64,
    pub storage_luts: u64,
    pub control_luts: u64,
    pub other_luts: u64,
}

impl ResourceBreakdown {
    pub fn compute_luts(&self) -> u64 {
        self.conv_kernel_luts + self.adder_tree_luts
    }

    pub fn total(&self) -> u64 {
        self.compute_luts() + self.storage_luts + self.control_luts + self.other_luts
    }

    /// Fraction of the whole design occupied by the computation unit
    /// (paper: 50.48% at P=128 -> 83.9% at P=2048 for 16-bit CNN).
    pub fn compute_share(&self) -> f64 {
        self.compute_luts() as f64 / self.total() as f64
    }
}

/// Non-datapath LUTs (buffers, AXI/control FSMs, pool/BN units).
/// Calibrated at DW=16 to the paper's Fig. 4(c1) shares: 50.48% compute
/// at P=128 and 83.9% at P=2048 for the CNN imply a fixed ~31.6 kLUT
/// base plus ~40.7 LUT per lane; narrower datapaths scale the
/// width-proportional part.
fn non_compute_luts(parallelism: u64, dw: u32) -> (u64, u64, u64) {
    let width_scale = 0.35 + 0.65 * dw as f64 / 16.0;
    let base = 31_600.0 * width_scale;
    let per_lane = 40.7 * width_scale;
    let total = base + per_lane * parallelism as f64;
    // Round the whole and the two largest shares, then derive the third
    // as the remainder: the components always reconstruct the rounded
    // total exactly (plain `as u64` truncation let the 0.60/0.25/0.15
    // split drift a few LUTs below it).
    let total_u = total.round() as u64;
    let storage = (0.60 * total).round() as u64;
    let control = (0.25 * total).round() as u64;
    let other = total_u.saturating_sub(storage + control);
    (storage, control, other)
}

/// Synthesize the design: full component breakdown.
pub fn resources(cfg: &AccelConfig) -> ResourceBreakdown {
    let arr = cfg.array();
    let lane = cfg.kernel.lane_cost(cfg.dw).luts;
    let conv_kernel_luts = arr.pin * arr.pout * lane;
    let adder_tree_luts = arr.pout * arr.tree().luts_precise();
    let (storage_luts, control_luts, other_luts) =
        non_compute_luts(cfg.parallelism(), cfg.dw);
    ResourceBreakdown {
        conv_kernel_luts,
        adder_tree_luts,
        storage_luts,
        control_luts,
        other_luts,
    }
}

/// Per-layer schedule record.
#[derive(Debug, Clone)]
pub struct LayerRun {
    pub name: String,
    pub ops: u64,
    pub compute_cycles: u64,
    pub dma_cycles: u64,
    /// max(compute, dma) under double buffering + fixed pipeline fill.
    pub cycles: u64,
    /// Post-conv BN/activation/residual pass (0 for non-conv rows).
    /// `cycles + post_cycles` summed over layers equals the report's
    /// `total_cycles` exactly — the invariant the profiler joins on.
    pub post_cycles: u64,
    pub dram_bytes: u64,
}

/// Whole-network run report (the §4 on-board numbers).
#[derive(Debug, Clone)]
pub struct RunReport {
    pub layers: Vec<LayerRun>,
    pub fmax_mhz: f64,
    pub conv_ops: u64,
    pub total_ops: u64,
    pub conv_cycles: u64,
    pub total_cycles: u64,
    pub dram_bytes: u64,
    pub power: PowerReport,
}

impl RunReport {
    pub fn latency_ms(&self) -> f64 {
        self.total_cycles as f64 / (self.fmax_mhz * 1e3)
    }

    /// Convolution-only throughput (paper: "424 GOPs for the convolution
    /// calculation").  0 for a conv-free network, not NaN.
    pub fn conv_gops(&self) -> f64 {
        if self.conv_cycles == 0 {
            return 0.0;
        }
        self.conv_ops as f64 / (self.conv_cycles as f64 / (self.fmax_mhz * 1e6)) / 1e9
    }

    /// Whole-network throughput ("307 GOPs for the whole network").
    /// 0 for an empty network, not NaN.
    pub fn total_gops(&self) -> f64 {
        if self.total_cycles == 0 {
            return 0.0;
        }
        self.total_ops as f64 / (self.total_cycles as f64 / (self.fmax_mhz * 1e6)) / 1e9
    }

    /// Compute-array duty cycle over the run.  0 when nothing ran, not
    /// NaN (an empty or conv-free schedule draws no datapath power).
    pub fn duty(&self) -> f64 {
        if self.total_cycles == 0 {
            return 0.0;
        }
        self.conv_cycles as f64 / self.total_cycles as f64
    }

    /// Sustained fraction of the array's conv-phase peak rate
    /// (2 ops/lane/cycle across `parallelism` lanes).
    pub fn utilization(&self, parallelism: u64) -> f64 {
        if self.conv_cycles == 0 || parallelism == 0 {
            return 0.0;
        }
        self.conv_ops as f64
            / (2.0 * parallelism as f64 * self.conv_cycles as f64)
    }
}

const PIPELINE_FILL_CYCLES: u64 = 256;

/// Simulate one image through `net` on the configured accelerator.
pub fn run(cfg: &AccelConfig, net: &NetworkDesc) -> RunReport {
    let axi: AxiBus = ZCU104_AXI;
    let fmax = timing::analyse(&cfg.array()).fmax_mhz;
    let bytes_per_el = cfg.dw as u64 / 8;
    // DRAM bandwidth in bytes/cycle at this clock (AXI width also caps).
    let dram_bpc = (cfg.device.dram_bw_bytes_per_s / (fmax * 1e6))
        .min(axi.effective_bytes_per_cycle());

    let mut layers = Vec::new();
    let (mut conv_ops, mut conv_cycles) = (0u64, 0u64);
    let (mut total_ops, mut total_cycles) = (0u64, 0u64);
    let mut dram_total = 0u64;

    for layer in &net.layers {
        let (name, ops, compute, bytes) = match layer {
            Layer::Conv(c) => {
                let taps = (c.cin * c.kh * c.kw) as u64;
                let in_groups = taps.div_ceil(cfg.pin);
                let out_groups = (c.cout as u64).div_ceil(cfg.pout);
                let compute = (c.h_out() * c.w_out()) as u64 * in_groups * out_groups;
                let bytes = if cfg.use_dram {
                    // Weights stream ONCE (tile double-buffered); the
                    // input stays resident if it fits the on-chip
                    // buffers, otherwise it is re-fetched per output
                    // group (the memory-hierarchy trade the paper's §4
                    // deviation discussion is about).
                    let bram_bytes = cfg.device.bram_kbits * 1024 / 8;
                    let reload = if c.input_bytes(cfg.dw) <= bram_bytes * 8 / 10 {
                        1
                    } else {
                        out_groups
                    };
                    c.weight_bytes(cfg.dw)
                        + c.input_bytes(cfg.dw) * reload
                        + c.output_bytes(cfg.dw)
                } else {
                    0
                };
                (c.name.clone(), 2 * c.macs(), compute, bytes)
            }
            Layer::Dense { name, din, dout } => {
                // runs on the same array, memory-bound on weights.
                let macs = (din * dout) as u64;
                let compute = macs.div_ceil(cfg.parallelism());
                let bytes = if cfg.use_dram { macs * bytes_per_el } else { 0 };
                (name.clone(), 2 * macs, compute, bytes)
            }
            Layer::Pool { name, h_in, w_in, ch, stride, window } => {
                // valid-pool output grid — the same geometry the
                // descriptor MAC model and the graph walk use (the old
                // h_in/stride floor overcounted whenever window !=
                // stride or the dims don't divide evenly).
                let outs = (pool_out_dim(*h_in, *window, *stride)
                    * pool_out_dim(*w_in, *window, *stride)
                    * ch) as u64;
                let ops = outs * (window * window) as u64;
                // pool unit processes Pout values per cycle
                (name.clone(), ops, outs.div_ceil(cfg.pout), 0)
            }
            Layer::GlobalPool { name, ch, h_in, w_in } => {
                let ops = (ch * h_in * w_in) as u64;
                (name.clone(), ops, ops.div_ceil(cfg.parallelism()), 0)
            }
        };
        let dma = if bytes == 0 { 0 } else { ((bytes as f64) / dram_bpc).ceil() as u64 };
        // Double buffering overlaps compute and DMA, but per-tile sync
        // and buffer turnaround leave ~15% of the shorter phase exposed.
        let exposed = (0.15 * compute.min(dma) as f64) as u64;
        let cycles = compute.max(dma) + exposed + PIPELINE_FILL_CYCLES;
        let mut post_cycles = 0u64;
        if let Layer::Conv(c) = layer {
            conv_ops += ops;
            conv_cycles += cycles;
            // BN + activation (+ residual add) pass over the outputs runs
            // after the conv at Pout elements/cycle — part of the
            // whole-network time but not of the conv-GOPs measure (this
            // models the paper's 424->307 / 495->358.6 gap).
            post_cycles = (c.h_out() * c.w_out() * c.cout) as u64 / cfg.pout.max(1);
        }
        total_ops += ops;
        total_cycles += cycles + post_cycles;
        dram_total += bytes;
        layers.push(LayerRun {
            name,
            ops,
            compute_cycles: compute,
            dma_cycles: dma,
            cycles,
            post_cycles,
            dram_bytes: bytes,
        });
    }

    let mut report = RunReport {
        layers,
        fmax_mhz: fmax,
        conv_ops,
        total_ops,
        conv_cycles,
        total_cycles,
        dram_bytes: dram_total,
        power: PowerReport::default(),
    };
    let runtime_s = total_cycles as f64 / (fmax * 1e6);
    let duty = report.duty();
    let res = resources(cfg);
    // buffer traffic per cycle: Pin features broadcast to the lanes +
    // Pout partial sums written back (weights are stationary per tile).
    let bram_bps = (cfg.pin + cfg.pout) as f64 * bytes_per_el as f64
        * fmax * 1e6 * duty * 2.0;
    let dram_bps = if runtime_s > 0.0 { dram_total as f64 / runtime_s } else { 0.0 };
    report.power =
        power::power(&cfg.array(), fmax, duty, bram_bps, dram_bps, res.total());
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn;

    fn cfg(kernel: KernelKind, dw: u32) -> AccelConfig {
        AccelConfig::zcu104(1024, dw, kernel)
    }

    /// Fig. 4(c1) anchors: CNN 16-bit compute share ~50% at P=128 and
    /// ~84% at P=2048.
    #[test]
    fn fig4_compute_share_anchors() {
        let share = |p: u64| {
            resources(&AccelConfig::zcu104(p, 16, KernelKind::Mult)).compute_share()
        };
        assert!((share(128) - 0.5048).abs() < 0.03, "P=128 share {}", share(128));
        assert!((share(2048) - 0.839).abs() < 0.03, "P=2048 share {}", share(2048));
        assert!(share(2048) > share(512));
    }

    /// Fig. 4(c3): at P=2048, conv-part saving ~80%, total ~67.6%.
    #[test]
    fn fig4_savings_anchors() {
        let a = resources(&AccelConfig::zcu104(2048, 16, KernelKind::Adder2A));
        let c = resources(&AccelConfig::zcu104(2048, 16, KernelKind::Mult));
        let conv_saving = 1.0 - a.compute_luts() as f64 / c.compute_luts() as f64;
        let total_saving = 1.0 - a.total() as f64 / c.total() as f64;
        assert!((conv_saving - 0.80).abs() < 0.05, "conv {conv_saving:.3}");
        assert!((total_saving - 0.676).abs() < 0.06, "total {total_saving:.3}");
    }

    /// Fig. 4(d): 8-bit savings are smaller than 16-bit (shape claim).
    #[test]
    fn fig4_8bit_smaller_savings() {
        let sav = |dw: u32| {
            let a = resources(&AccelConfig::zcu104(2048, dw, KernelKind::Adder2A));
            let c = resources(&AccelConfig::zcu104(2048, dw, KernelKind::Mult));
            1.0 - a.total() as f64 / c.total() as f64
        };
        assert!(sav(8) < sav(16));
        assert!(sav(8) > 0.40, "8-bit total saving {}", sav(8));
    }

    /// §4 on-board anchors: ResNet-18, P=1024. CNN ~424/307 GOPs at
    /// 214 MHz; AdderNet ~495/358.6 GOPs at 250 MHz; latency ~9.5 ms.
    #[test]
    fn onboard_resnet18_anchors() {
        let net = nn::resnet18();
        let c = run(&cfg(KernelKind::Mult, 16), &net);
        let a = run(&cfg(KernelKind::Adder2A, 16), &net);
        assert!((c.fmax_mhz - 214.0).abs() < 10.0);
        assert!((a.fmax_mhz - 250.0).abs() < 1.0);
        assert!((c.conv_gops() - 424.0).abs() / 424.0 < 0.12, "cnn conv {}", c.conv_gops());
        assert!((a.conv_gops() - 495.0).abs() / 495.0 < 0.12, "adder conv {}", a.conv_gops());
        assert!((c.total_gops() - 307.0).abs() / 307.0 < 0.25, "cnn total {}", c.total_gops());
        assert!((a.total_gops() - 358.6).abs() / 358.6 < 0.25, "adder total {}", a.total_gops());
        assert!((a.latency_ms() - 9.47).abs() / 9.47 < 0.35, "latency {}", a.latency_ms());
    }

    /// §4 power anchors: CNN ~2.57 W vs AdderNet ~1.34 W -> ~48% saving.
    #[test]
    fn onboard_power_saving() {
        let net = nn::resnet18();
        let c = run(&cfg(KernelKind::Mult, 16), &net);
        let a = run(&cfg(KernelKind::Adder2A, 16), &net);
        let saving = 1.0 - a.power.total_w() / c.power.total_w();
        assert!((saving - 0.4785).abs() < 0.15, "power saving {saving:.3}");
    }

    #[test]
    fn utilization_high_on_big_convs() {
        let net = nn::resnet18();
        let r = run(&cfg(KernelKind::Adder2A, 16), &net);
        let peak = 2.0 * 1024.0 * r.fmax_mhz / 1e3; // GOPs
        assert!(r.conv_gops() / peak > 0.9, "conv util {}", r.conv_gops() / peak);
    }

    #[test]
    fn dram_traffic_zero_when_onchip() {
        let mut c = cfg(KernelKind::Adder2A, 16);
        c.use_dram = false;
        let r = run(&c, &nn::lenet5());
        assert_eq!(r.dram_bytes, 0);
        assert_eq!(r.power.dram_w, 0.0);
    }

    /// The 0.60/0.25/0.15 non-compute split must reconstruct its total
    /// exactly at the Fig. 4 anchor configurations (the old truncating
    /// casts dropped up to 2 LUTs).
    #[test]
    fn resource_components_sum_to_total() {
        for p in [128u64, 512, 1024, 2048] {
            for dw in [8u32, 16] {
                for kernel in [KernelKind::Mult, KernelKind::Adder2A] {
                    let r = resources(&AccelConfig::zcu104(p, dw, kernel));
                    let parts = r.conv_kernel_luts + r.adder_tree_luts
                        + r.storage_luts + r.control_luts + r.other_luts;
                    assert_eq!(r.total(), parts, "P={p} dw={dw}");
                    let nc = (r.storage_luts + r.control_luts
                        + r.other_luts) as f64;
                    let storage_share = r.storage_luts as f64 / nc;
                    let control_share = r.control_luts as f64 / nc;
                    assert!((storage_share - 0.60).abs() < 0.01,
                            "storage share {storage_share}");
                    assert!((control_share - 0.25).abs() < 0.01,
                            "control share {control_share}");
                }
            }
        }
    }

    /// Conv-free and empty networks report zeros, not NaN.
    #[test]
    fn conv_free_network_report_is_finite() {
        let c = cfg(KernelKind::Adder2A, 16);
        let pool_only = nn::NetworkDesc {
            name: "pool-only".into(),
            input: (8, 8, 4),
            layers: vec![Layer::Pool {
                name: "pool1".into(), window: 2, stride: 2,
                h_in: 8, w_in: 8, ch: 4,
            }],
        };
        let r = run(&c, &pool_only);
        assert_eq!(r.conv_gops(), 0.0);
        assert_eq!(r.duty(), 0.0);
        assert_eq!(r.utilization(1024), 0.0);
        assert!(r.total_gops() > 0.0);
        assert!(r.power.total_w().is_finite());

        let empty = nn::NetworkDesc {
            name: "empty".into(), input: (1, 1, 1), layers: vec![],
        };
        let e = run(&c, &empty);
        assert_eq!(e.total_gops(), 0.0);
        assert_eq!(e.duty(), 0.0);
        assert!(e.power.total_w().is_finite());
    }

    /// Pool rows schedule the valid-window output grid and keep the
    /// descriptor's layer names (so rows join against graph op names).
    #[test]
    fn pool_rows_use_valid_geometry_and_real_names() {
        let r = run(&cfg(KernelKind::Adder2A, 16), &nn::resnet18());
        let pool = r.layers.iter().find(|l| l.name == "pool1").unwrap();
        // 112 -(3/2 valid)-> 55, not the floor formula's 56.
        assert_eq!(pool.ops, (55 * 55 * 64 * 9) as u64);
        let gap = r.layers.iter().find(|l| l.name == "gap").unwrap();
        assert_eq!(gap.ops, (512 * 7 * 7) as u64);
    }

    #[test]
    fn report_math_consistent() {
        let r = run(&cfg(KernelKind::Adder2A, 16), &nn::lenet5());
        // total includes per-conv post-processing passes on top of the
        // per-layer cycles.
        let sum: u64 = r.layers.iter().map(|l| l.cycles).sum();
        assert!(r.total_cycles >= sum);
        assert!(r.total_cycles < sum + sum / 2);
        // with the post pass accounted per layer the sum is exact
        let exact: u64 = r.layers.iter().map(|l| l.cycles + l.post_cycles).sum();
        assert_eq!(r.total_cycles, exact);
        assert!(r.latency_ms() > 0.0);
    }
}
