//! Cycle-accurate hardware serving backend: execute a compiled
//! [`QuantPlan`] on the accelerator simulator.
//!
//! [`HwPlanRunner`] pairs the two halves the repo grew separately:
//!
//! * the **functional result** comes from [`PlanRunner`] — the i32
//!   integer path whose logits are the correctness oracle (this module
//!   never re-implements arithmetic, so hw-backend logits are
//!   bit-identical to the plan path by construction, and the test suite
//!   asserts it anyway);
//! * the **cost** comes from [`accelerator::run`] driven by the plan's
//!   own geometry: the schedule executes `plan.arch`'s descriptor after
//!   cross-checking every conv/dense layer against the plan's compiled
//!   shapes, at the plan's data width (`cfg.bits`) and kernel circuit
//!   ([`SimKernel::Adder`] → the paper's 2A adder cell,
//!   [`SimKernel::Mult`] → the multiplier baseline).
//!
//! Each inference yields a [`HwCost`] — cycles, DRAM traffic, fmax,
//! latency, intrinsic power and array utilization — the per-request
//! numbers the paper reports per network in §4.  The schedule depends
//! only on (arch, bits, kind), all three pinned across
//! `ServerHandle::swap_plan`, so serving precomputes it once per
//! variant and batch cost is a linear scale of the per-image report.

use anyhow::{bail, Result};

use crate::hw::kernelcircuit::KernelKind;
use crate::nn::Layer;
use crate::quant::plan::QuantPlan;
use crate::sim::accelerator::{self, AccelConfig, RunReport};
use crate::sim::exec::ExecObserver;
use crate::sim::functional::Tensor;
use crate::sim::intpath::PlanRunner;
use crate::sim::kernels::{KernelStrategy, SimKernel};

/// Default PE-array lanes for the serving backend — the §4 on-board
/// configuration (P = 1024: Pin 64 × Pout 16).
pub const DEFAULT_PARALLELISM: u64 = 1024;

/// Hardware cost of executing a batch on the simulated accelerator.
#[derive(Debug, Clone, Copy, Default)]
pub struct HwCost {
    /// Whole-schedule cycles (compute, DMA exposure, pipeline fill,
    /// post-conv BN/activation passes).
    pub cycles: u64,
    /// Cycles spent in conv layers (the paper's conv-GOPs denominator).
    pub conv_cycles: u64,
    /// DMA cycles summed over layers (overlapped under double
    /// buffering; exposed share is inside `cycles`).
    pub dma_cycles: u64,
    /// Off-chip traffic, bytes.
    pub dram_bytes: u64,
    /// Achieved clock after timing analysis of the kernel array, MHz.
    pub fmax_mhz: f64,
    /// Wall-clock at `fmax_mhz`, ms.
    pub latency_ms: f64,
    /// Intrinsic accelerator power (compute + BRAM + DRAM + clock), W.
    pub power_w: f64,
    /// Sustained fraction of the array's conv-phase peak rate.
    pub utilization: f64,
}

impl HwCost {
    /// Cost of running `n` images back-to-back: extensive quantities
    /// (cycles, bytes, latency) scale linearly; rates (fmax, power,
    /// utilization) are per-design constants.
    pub fn scale(&self, n: usize) -> HwCost {
        let n64 = n as u64;
        HwCost {
            cycles: self.cycles * n64,
            conv_cycles: self.conv_cycles * n64,
            dma_cycles: self.dma_cycles * n64,
            dram_bytes: self.dram_bytes * n64,
            latency_ms: self.latency_ms * n as f64,
            ..*self
        }
    }
}

/// Kernel circuit a plan's arithmetic maps to on the array: the adder
/// plans use the paper's minimalist 2-adder cell, the mult plans the
/// conventional multiplier lane.
pub fn kernel_kind(kind: SimKernel) -> KernelKind {
    match kind {
        SimKernel::Adder => KernelKind::Adder2A,
        SimKernel::Mult => KernelKind::Mult,
    }
}

/// ZCU104-class accelerator configuration matching a plan's serving
/// width and kernel circuit.
pub fn accel_config(plan: &QuantPlan, parallelism: u64) -> AccelConfig {
    AccelConfig::zcu104(parallelism, plan.cfg.bits, kernel_kind(plan.kind))
}

/// Build the per-image cycle schedule for a plan: derive the arch
/// descriptor, cross-check it layer-by-layer against the plan's
/// compiled geometry (a plan that disagrees with its own graph must
/// never be costed as if it matched), and run the accelerator model.
pub fn plan_schedule(plan: &QuantPlan,
                     parallelism: u64) -> Result<(AccelConfig, RunReport)> {
    let desc = plan.arch.graph().to_desc();
    let mut convs = 0usize;
    let mut dense = 0usize;
    for layer in &desc.layers {
        match layer {
            Layer::Conv(c) => {
                convs += 1;
                let Some(lp) = plan.convs.get(&c.name) else {
                    bail!("plan {} has no conv layer {}", plan.arch.name(),
                          c.name);
                };
                if (lp.kh, lp.kw, lp.cin, lp.cout) != (c.kh, c.kw, c.cin, c.cout)
                    || lp.stride != c.stride || lp.padding != c.padding
                {
                    bail!("plan {} conv {} geometry {}x{}x{}x{}/s{} diverges \
                           from the graph descriptor", plan.arch.name(),
                          c.name, lp.kh, lp.kw, lp.cin, lp.cout, lp.stride);
                }
            }
            Layer::Dense { name, din, dout } => {
                dense += 1;
                let Some(dp) = plan.dense.get(name) else {
                    bail!("plan {} has no dense layer {name}",
                          plan.arch.name());
                };
                if dp.din != *din || dp.dout != *dout {
                    bail!("plan {} dense {name} is {}x{}, descriptor says \
                           {din}x{dout}", plan.arch.name(), dp.din, dp.dout);
                }
            }
            Layer::Pool { .. } | Layer::GlobalPool { .. } => {}
        }
    }
    if convs != plan.convs.len() || dense != plan.dense.len() {
        bail!("plan {} carries {}+{} layers, descriptor schedules {convs}+{dense}",
              plan.arch.name(), plan.convs.len(), plan.dense.len());
    }
    let cfg = accel_config(plan, parallelism);
    let report = accelerator::run(&cfg, &desc);
    Ok((cfg, report))
}

/// Per-image hardware cost of serving a plan at `parallelism` lanes.
pub fn per_image_cost(plan: &QuantPlan, parallelism: u64) -> Result<HwCost> {
    let (cfg, report) = plan_schedule(plan, parallelism)?;
    Ok(cost_of(&report, cfg.parallelism()))
}

/// Fold a finished schedule into the per-image [`HwCost`] summary.
pub fn cost_of(report: &RunReport, parallelism: u64) -> HwCost {
    HwCost {
        cycles: report.total_cycles,
        conv_cycles: report.conv_cycles,
        dma_cycles: report.layers.iter().map(|l| l.dma_cycles).sum(),
        dram_bytes: report.dram_bytes,
        fmax_mhz: report.fmax_mhz,
        latency_ms: report.latency_ms(),
        power_w: report.power.total_w(),
        utilization: report.utilization(parallelism),
    }
}

/// The hw-sim serving backend: functional logits from the wrapped
/// [`PlanRunner`], cost from the precomputed accelerator schedule.
pub struct HwPlanRunner<'a> {
    inner: PlanRunner<'a>,
    cfg: AccelConfig,
    report: RunReport,
}

impl<'a> HwPlanRunner<'a> {
    pub fn new(plan: &'a QuantPlan, strategy: KernelStrategy,
               parallelism: u64) -> Result<Self> {
        let (cfg, report) = plan_schedule(plan, parallelism)?;
        Ok(Self { inner: PlanRunner { plan, strategy }, cfg, report })
    }

    pub fn config(&self) -> &AccelConfig {
        &self.cfg
    }

    /// The per-image cycle schedule (per-layer rows join the graph's
    /// canonical op names).
    pub fn report(&self) -> &RunReport {
        &self.report
    }

    /// Hardware cost of a batch of `n` images.
    pub fn cost(&self, n: usize) -> HwCost {
        cost_of(&self.report, self.cfg.parallelism()).scale(n)
    }

    /// Forward pass: logits bit-identical to [`PlanRunner::forward`],
    /// plus the batch's hardware cost.
    pub fn forward(&self, x: &Tensor) -> (Tensor, HwCost) {
        let n = x.shape.0;
        (self.inner.forward(x), self.cost(n))
    }

    /// Batched serving entry point — same contract as
    /// [`PlanRunner::forward_many`], with the batch cost alongside.
    pub fn forward_many(&self, images: &[&[f32]],
                        hwc: (usize, usize, usize))
                        -> (Vec<Vec<f32>>, HwCost) {
        (self.inner.forward_many(images, hwc), self.cost(images.len()))
    }

    /// [`Self::forward`] with a per-op [`ExecObserver`]: wall-time per
    /// layer from the observed functional walk, hardware cycles from the
    /// precomputed schedule — the two sides the profiler joins.
    pub fn forward_observed(&self, x: &Tensor,
                            obs: &mut dyn ExecObserver) -> (Tensor, HwCost) {
        let n = x.shape.0;
        (self.inner.forward_observed(x, obs), self.cost(n))
    }

    /// Batched observed entry point (the traced serving path).
    pub fn forward_many_observed(&self, images: &[&[f32]],
                                 hwc: (usize, usize, usize),
                                 obs: &mut dyn ExecObserver)
                                 -> (Vec<Vec<f32>>, HwCost) {
        (self.inner.forward_many_observed(images, hwc, obs),
         self.cost(images.len()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{Calibration, LayerCalib, Mode};
    use crate::sim::functional::{synth_params, Arch, QuantCfg};

    fn lenet_plan(kind: SimKernel, bits: u32) -> QuantPlan {
        let params = synth_params(Arch::Lenet5, 3);
        let mut calib = Calibration::new();
        calib.insert("conv1".into(),
                     LayerCalib { feat_max_abs: 1.0, weight_max_abs: 0.5 });
        calib.insert("conv2".into(),
                     LayerCalib { feat_max_abs: 16.0, weight_max_abs: 0.5 });
        QuantPlan::build(&params, Arch::Lenet5, kind,
                         QuantCfg { bits, mode: Mode::SharedScale }, &calib)
            .unwrap()
    }

    #[test]
    fn kernel_mapping_matches_paper_cells() {
        assert_eq!(kernel_kind(SimKernel::Adder), KernelKind::Adder2A);
        assert_eq!(kernel_kind(SimKernel::Mult), KernelKind::Mult);
    }

    #[test]
    fn config_follows_plan_width_and_kind() {
        let p8 = lenet_plan(SimKernel::Adder, 8);
        let cfg = accel_config(&p8, 1024);
        assert_eq!(cfg.dw, 8);
        assert_eq!(cfg.kernel, KernelKind::Adder2A);
        assert_eq!(cfg.parallelism(), 1024);
        let p16 = lenet_plan(SimKernel::Adder, 16);
        assert_eq!(accel_config(&p16, 256).dw, 16);
    }

    #[test]
    fn cost_scales_linearly_in_batch() {
        let plan = lenet_plan(SimKernel::Adder, 8);
        let one = per_image_cost(&plan, 1024).unwrap();
        assert!(one.cycles > 0);
        assert!(one.latency_ms > 0.0);
        assert!(one.power_w > 0.0);
        assert!(one.utilization > 0.0 && one.utilization <= 1.0);
        let four = one.scale(4);
        assert_eq!(four.cycles, 4 * one.cycles);
        assert_eq!(four.dram_bytes, 4 * one.dram_bytes);
        assert!((four.latency_ms - 4.0 * one.latency_ms).abs() < 1e-12);
        assert_eq!(four.fmax_mhz, one.fmax_mhz);
        assert_eq!(four.power_w, one.power_w);
        assert_eq!(four.utilization, one.utilization);
    }

    #[test]
    fn schedule_rejects_geometry_drift() {
        let mut plan = lenet_plan(SimKernel::Adder, 8);
        plan.convs.get_mut("conv2").unwrap().stride = 2;
        assert!(plan_schedule(&plan, 1024).is_err());
        let mut plan = lenet_plan(SimKernel::Adder, 8);
        plan.convs.remove("conv1");
        assert!(plan_schedule(&plan, 1024).is_err());
        let mut plan = lenet_plan(SimKernel::Adder, 8);
        plan.dense.get_mut("fc1").unwrap().din += 1;
        assert!(plan_schedule(&plan, 1024).is_err());
    }

    #[test]
    fn runner_logits_match_plan_runner() {
        let plan = lenet_plan(SimKernel::Adder, 8);
        let hw = HwPlanRunner::new(&plan, KernelStrategy::Auto, 1024).unwrap();
        let base = PlanRunner { plan: &plan, strategy: KernelStrategy::Auto };
        let mut rng = crate::util::XorShift64::new(11);
        let x = Tensor::new((2, 32, 32, 1),
                            (0..2048).map(|_| rng.next_f32_sym(1.0)).collect());
        let (y, cost) = hw.forward(&x);
        assert_eq!(y.data, base.forward(&x).data);
        assert_eq!(cost.cycles, hw.cost(1).cycles * 2);
    }
}
