//! Fully on-chip LeNet-5 accelerator (paper Fig. 5, Zynq-7020).
//!
//! No DRAM traffic: all weights and intermediate features live in BRAM,
//! each conv layer gets its own dedicated PE group — 6 parallel kernel
//! operators for conv1 (1 in x 6 out) and 96 for conv2 (6 in x 16 out),
//! exactly the paper's §4 geometry.  This isolates the kernel's intrinsic
//! cost: measured savings here approach the theoretical ~81%.

use crate::hw::adder_tree::AdderTree;
use crate::hw::device::{Device, Z7020};
use crate::hw::kernelcircuit::KernelKind;
use crate::nn::{self, Layer};

/// Distributed-RAM / small-SRAM access energy per byte, pJ.  The fully
/// on-chip design keeps features in LUT-RAM right next to the lanes —
/// far cheaper than the block-RAM hierarchy of the DRAM-backed design.
const E_ONCHIP_SRAM_PJ_PER_BYTE: f64 = 0.25;

/// Per-layer resource + energy report.
#[derive(Debug, Clone)]
pub struct OnchipLayer {
    pub name: String,
    /// Parallel kernel lanes (cin * cout for the conv layers).
    pub lanes: u64,
    pub luts: u64,
    /// Energy for one full inference through this layer, pJ.
    pub energy_pj: f64,
}

/// Whole-design report (Fig. 5b/5c rows).
#[derive(Debug, Clone)]
pub struct OnchipReport {
    pub layers: Vec<OnchipLayer>,
    /// Shared logic (pool, FC sequencer, control) LUTs.
    pub shared_luts: u64,
    /// Shared-logic energy per inference, pJ.
    pub shared_energy_pj: f64,
    pub device: Device,
}

impl OnchipReport {
    pub fn total_luts(&self) -> u64 {
        self.layers.iter().map(|l| l.luts).sum::<u64>() + self.shared_luts
    }

    pub fn total_energy_pj(&self) -> f64 {
        self.layers.iter().map(|l| l.energy_pj).sum::<f64>() + self.shared_energy_pj
    }

    pub fn fits(&self) -> bool {
        self.device.fits(self.total_luts(), 0)
    }
}

/// Build the Fig. 5 design for the given kernel and data width.
pub fn design(kernel: KernelKind, dw: u32) -> OnchipReport {
    let net = nn::lenet5();
    let mut layers = Vec::new();
    let mut shared_luts = 0u64;
    let mut shared_energy = 0f64;
    let bytes_per_el = dw as u64 / 8;

    for layer in &net.layers {
        match layer {
            Layer::Conv(c) => {
                // one lane per (cin, cout) pair; kernel taps are serial,
                // so every lane carries a widened accumulator adder; the
                // adder tree reduces the cin partials per output channel;
                // line buffers are per input channel, shared across cout.
                let lanes = (c.cin * c.cout) as u64;
                let lane_cost = kernel.lane_cost(dw);
                let taps_bits = ((c.kh * c.kw) as f64).log2().ceil() as u32;
                let acc_adder = crate::hw::gates::adder_luts(
                    kernel.output_bits(dw) + taps_bits);
                let line_buf = 2 * dw as u64; // SRL line buffer per cin
                let tree = AdderTree::new(c.cin as u64, kernel.output_bits(dw));
                let luts = lanes * (lane_cost.luts + acc_adder + 4)
                    + c.cin as u64 * line_buf
                    + c.cout as u64 * tree.luts_precise();
                // energy: every MAC runs one lane op; tree fires per
                // output pixel per cout; plus BRAM reads of features.
                let macs = c.macs() as f64;
                let tree_fires = (c.h_out() * c.w_out() * c.cout) as f64;
                let sram_bytes =
                    (c.macs() * bytes_per_el) as f64 / c.cout as f64 // feature reads shared over cout lanes
                        + c.output_bytes(dw) as f64;
                let energy = macs * kernel.lane_energy_pj(dw)
                    + tree_fires * tree.energy_pj()
                    + sram_bytes * E_ONCHIP_SRAM_PJ_PER_BYTE;
                layers.push(OnchipLayer { name: c.name.clone(), lanes, luts, energy_pj: energy });
            }
            Layer::Dense { din, dout, .. } => {
                // FC layers run on a small shared sequential MAC unit —
                // identical for both kernels in the paper's design
                // (AdderNet replaces *convolutions*), so it lands in the
                // shared bucket.
                let macs = (din * dout) as f64;
                shared_luts += 4 * dw as u64; // one MAC + addressing
                shared_energy += macs
                    * crate::hw::gates::multiplier_energy_pj(dw)
                    + macs * 2.0 * bytes_per_el as f64 * E_ONCHIP_SRAM_PJ_PER_BYTE;
            }
            Layer::Pool { h_in, w_in, ch, stride, window, .. } => {
                shared_luts += 6 * dw as u64;
                let outs = (nn::pool_out_dim(*h_in, *window, *stride)
                    * nn::pool_out_dim(*w_in, *window, *stride)
                    * ch) as f64;
                shared_energy += outs * crate::hw::gates::adder_energy_pj(dw) * 3.0;
            }
            Layer::GlobalPool { .. } => {}
        }
    }
    // control/BN/IO sequencer: fixed small footprint on the 7020.
    shared_luts += 2_200 + 140 * dw as u64;
    OnchipReport { layers, shared_luts, shared_energy_pj: shared_energy, device: Z7020 }
}

/// Per-layer + total savings of AdderNet vs CNN (Fig. 5b/5c).
#[derive(Debug, Clone)]
pub struct Savings {
    pub conv1_luts: f64,
    pub conv2_luts: f64,
    pub total_luts: f64,
    pub conv1_energy: f64,
    pub conv2_energy: f64,
    pub total_energy: f64,
}

pub fn savings(dw: u32) -> Savings {
    let a = design(KernelKind::Adder2A, dw);
    let c = design(KernelKind::Mult, dw);
    let s = |x: f64, y: f64| 1.0 - x / y;
    Savings {
        conv1_luts: s(a.layers[0].luts as f64, c.layers[0].luts as f64),
        conv2_luts: s(a.layers[1].luts as f64, c.layers[1].luts as f64),
        total_luts: s(a.total_luts() as f64, c.total_luts() as f64),
        conv1_energy: s(a.layers[0].energy_pj, c.layers[0].energy_pj),
        conv2_energy: s(a.layers[1].energy_pj, c.layers[1].energy_pj),
        total_energy: s(a.total_energy_pj(), c.total_energy_pj()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_lane_counts() {
        let d = design(KernelKind::Adder2A, 16);
        assert_eq!(d.layers[0].lanes, 6); // conv1: 1 x 6
        assert_eq!(d.layers[1].lanes, 96); // conv2: 6 x 16
    }

    /// Fig. 5 anchors (16-bit): LUT savings conv1 ~70.3%, conv2 ~80.3%,
    /// total ~71.4%; energy savings conv1 ~70.2%, conv2 ~88.3%,
    /// total ~77.9%.  Model must land in band (±8 points).
    #[test]
    fn fig5_16bit_savings_anchors() {
        let s = savings(16);
        assert!((s.conv1_luts - 0.703).abs() < 0.08, "conv1 luts {:.3}", s.conv1_luts);
        assert!((s.conv2_luts - 0.8032).abs() < 0.08, "conv2 luts {:.3}", s.conv2_luts);
        assert!((s.total_luts - 0.714).abs() < 0.10, "total luts {:.3}", s.total_luts);
        // The residual energy gap vs the paper traces to the uncited
        // 16-bit multiplier energy cell (S4 leaves it blank; we
        // interpolate quadratically at 0.77 pJ, the paper's measured
        // FPGA value is evidently higher).
        assert!((s.conv2_energy - 0.8829).abs() < 0.12, "conv2 e {:.3}", s.conv2_energy);
        assert!((s.total_energy - 0.7791).abs() < 0.20, "total e {:.3}", s.total_energy);
    }

    /// Fig. 5 8-bit shape: savings all smaller than 16-bit, but > 40%.
    #[test]
    fn fig5_8bit_shape() {
        let s8 = savings(8);
        let s16 = savings(16);
        assert!(s8.conv2_luts < s16.conv2_luts);
        assert!(s8.total_luts < s16.total_luts);
        assert!(s8.conv1_luts > 0.30, "conv1 {:.3}", s8.conv1_luts);
        assert!(s8.total_luts > 0.40, "total {:.3}", s8.total_luts);
    }

    /// The design must actually fit the Zynq-7020 for both kernels
    /// (the paper deployed both on the same board).
    #[test]
    fn fits_z7020() {
        assert!(design(KernelKind::Adder2A, 16).fits());
        assert!(design(KernelKind::Mult, 16).fits());
        assert!(design(KernelKind::Adder2A, 8).fits());
    }

    #[test]
    fn conv2_dominates_resources() {
        let d = design(KernelKind::Mult, 16);
        assert!(d.layers[1].luts > d.layers[0].luts);
    }
}
