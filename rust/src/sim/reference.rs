//! Naive scalar reference implementations of the functional datapath.
//!
//! These are the original 7-deep loop nests the optimized engine in
//! [`super::functional`] replaced.  They are deliberately unclever — one
//! output at a time, taps in (ky, kx, ci) order — and serve as the
//! in-crate oracle: `rust/tests/functional_oracle.rs` checks the tiled
//! and simd strategies of [`super::kernels`] against them across a
//! shape grid plus a randomized fuzz pass (f32 within tolerance,
//! integer path bit-identical), and `benches/hotpath.rs` records the
//! per-strategy speedup.  [`crate::sim::KernelStrategy::Naive`]
//! dispatches here, so the oracle is also runnable end-to-end (CI runs
//! the full suite under `ADDERNET_KERNEL=naive`); it is never selected
//! by `Auto`.

use crate::nn::{self, Padding};
use crate::quant::LayerCalib;

use super::functional::{self, ConvW, QDenseW, QuantCfg, SimKernel, Tensor};

/// f32 convolution (both kernels), NHWC x HWIO -> NHWC.  Zero padding
/// contributes `-|0 - w|` per tap for the adder kernel and nothing for
/// the mult kernel, exactly like the optimized engine.
pub fn conv2d(x: &Tensor, w: &ConvW, stride: usize, padding: Padding,
              kind: SimKernel) -> Tensor {
    let (n, h, w_in, cin) = x.shape;
    assert_eq!(cin, w.cin, "cin mismatch");
    let (pt, pl, ho, wo) = nn::conv_geometry(h, w_in, w.kh, w.kw, stride, padding);
    let cout = w.cout;
    let mut out = Tensor::zeros((n, ho, wo, cout));
    let mut acc = vec![0f32; cout];
    for b in 0..n {
        for oh in 0..ho {
            for ow in 0..wo {
                acc.iter_mut().for_each(|a| *a = 0.0);
                for ky in 0..w.kh {
                    let iy = (oh * stride + ky) as isize - pt as isize;
                    let row_inside = iy >= 0 && iy < h as isize;
                    for kx in 0..w.kw {
                        let ix = (ow * stride + kx) as isize - pl as isize;
                        let inside = row_inside && ix >= 0 && ix < w_in as isize;
                        for ci in 0..cin {
                            let xv = if inside {
                                x.data[((b * h + iy as usize) * w_in + ix as usize)
                                    * cin + ci]
                            } else {
                                0.0
                            };
                            let off = ((ky * w.kw + kx) * cin + ci) * cout;
                            let wrow = &w.data[off..off + cout];
                            match kind {
                                SimKernel::Adder => {
                                    for (a, &wv) in acc.iter_mut().zip(wrow) {
                                        *a -= (xv - wv).abs();
                                    }
                                }
                                SimKernel::Mult => {
                                    for (a, &wv) in acc.iter_mut().zip(wrow) {
                                        *a += xv * wv;
                                    }
                                }
                            }
                        }
                    }
                }
                let base = ((b * ho + oh) * wo + ow) * cout;
                out.data[base..base + cout].copy_from_slice(&acc);
            }
        }
    }
    out
}

/// Integer convolution through the widened i32 datapath, naive loops.
/// Shares the operand-quantization step with the optimized engine so any
/// divergence the oracle tests catch is in the compute loops themselves.
pub fn conv2d_quant(x: &Tensor, w: &ConvW, stride: usize, padding: Padding,
                    kind: SimKernel, cfg: QuantCfg, calib: &LayerCalib) -> Tensor {
    let (n, h, w_in, cin) = x.shape;
    assert_eq!(cin, w.cin, "cin mismatch");
    let cout = w.cout;
    let (xq, wq, pre_scale) =
        functional::quant_operands(&x.data, w.data, kind, cfg, calib);
    let (pt, pl, ho, wo) = nn::conv_geometry(h, w_in, w.kh, w.kw, stride, padding);
    let mut out = Tensor::zeros((n, ho, wo, cout));
    let mut acc = vec![0i32; cout];
    for b in 0..n {
        for oh in 0..ho {
            for ow in 0..wo {
                acc.iter_mut().for_each(|a| *a = 0);
                for ky in 0..w.kh {
                    let iy = (oh * stride + ky) as isize - pt as isize;
                    let row_inside = iy >= 0 && iy < h as isize;
                    for kx in 0..w.kw {
                        let ix = (ow * stride + kx) as isize - pl as isize;
                        let inside = row_inside && ix >= 0 && ix < w_in as isize;
                        for ci in 0..cin {
                            let xv = if inside {
                                xq[((b * h + iy as usize) * w_in + ix as usize)
                                    * cin + ci]
                            } else {
                                0
                            };
                            let off = ((ky * w.kw + kx) * cin + ci) * cout;
                            let wrow = &wq[off..off + cout];
                            match kind {
                                SimKernel::Adder => {
                                    for (a, &wv) in acc.iter_mut().zip(wrow) {
                                        *a -= (xv - wv).abs();
                                    }
                                }
                                SimKernel::Mult => {
                                    for (a, &wv) in acc.iter_mut().zip(wrow) {
                                        *a += xv * wv;
                                    }
                                }
                            }
                        }
                    }
                }
                let base = ((b * ho + oh) * wo + ow) * cout;
                for (o, &a) in out.data[base..base + cout].iter_mut().zip(acc.iter()) {
                    *o = a as f32 * pre_scale;
                }
            }
        }
    }
    out
}

/// Integer dense over already-quantized operands, naive row loop: i32
/// operands, widened i64 accumulators seeded from the accumulator-grid
/// integer bias — the oracle of [`functional::dense_int_with`].  Input
/// order and the (exact) zero-skip match the engine strategies.
pub fn dense_int(xq: &[i32], n: usize, w: &QDenseW, bias: &[i64]) -> Vec<i64> {
    let (din, dout) = (w.din, w.dout);
    let mut out = vec![0i64; n * dout];
    for b in 0..n {
        let xrow = &xq[b * din..(b + 1) * din];
        let orow = &mut out[b * dout..(b + 1) * dout];
        orow.copy_from_slice(bias);
        for (i, &xv) in xrow.iter().enumerate() {
            if xv == 0 {
                continue;
            }
            let xv = xv as i64;
            let wrow = &w.data[i * dout..(i + 1) * dout];
            for (o, &wv) in orow.iter_mut().zip(wrow) {
                *o += xv * wv as i64;
            }
        }
    }
    out
}

/// Dense: x (n, 1, 1, din) @ w (din, dout) + b, naive row loop.
pub fn dense(x: &Tensor, w: &[f32], bias: &[f32], dout: usize) -> Tensor {
    let (n, h, ww, c) = x.shape;
    let din = h * ww * c;
    assert_eq!(w.len(), din * dout, "dense weight size mismatch");
    let mut out = Tensor::zeros((n, 1, 1, dout));
    for b in 0..n {
        let xrow = &x.data[b * din..(b + 1) * din];
        let orow = &mut out.data[b * dout..(b + 1) * dout];
        orow.copy_from_slice(bias);
        for (i, &xv) in xrow.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let wrow = &w[i * dout..(i + 1) * dout];
            for (o, &wv) in orow.iter_mut().zip(wrow) {
                *o += xv * wv;
            }
        }
    }
    out
}
