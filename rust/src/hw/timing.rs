//! Static timing analysis: combinational critical path -> achievable fmax.
//!
//! The paper's observation (§4): "the multiplier owns much higher logic
//! gate delay compared to adder, [so] the highest operation frequency of
//! CNN is 214 MHz, and that of AdderNet is 250 MHz".  We model each
//! pipeline stage (kernel stage, tree level stage, control) and take the
//! slowest; frequency is additionally capped by the control/BRAM fabric
//! limit `FMAX_FABRIC_CAP_MHZ` (250 MHz — the AdderNet path is *not*
//! kernel-limited, exactly as in the paper).

use super::adder_tree::AdderTree;
use super::array::PeArray;
use super::gates;

/// Fabric cap from control logic, BRAM access time and clock management —
/// the ceiling any design hits once the datapath is fast enough.
pub const FMAX_FABRIC_CAP_MHZ: f64 = 250.0;

/// Timing report for one accelerator configuration.
#[derive(Debug, Clone, Copy)]
pub struct TimingReport {
    /// Kernel pipeline stage delay, ns.
    pub kernel_stage_ns: f64,
    /// Widest adder-tree level stage delay, ns.
    pub tree_stage_ns: f64,
    /// Resulting critical path (with register + routing margins), ns.
    pub critical_path_ns: f64,
    /// Achievable clock, MHz (after the fabric cap).
    pub fmax_mhz: f64,
}

/// Analyse one PE-array datapath.
pub fn analyse(array: &PeArray) -> TimingReport {
    let kernel_stage = array.kernel.lane_cost(array.dw).delay_ns;
    let tree = AdderTree::new(array.pin, array.kernel.output_bits(array.dw));
    let tree_stage = if array.pin > 1 { tree.level_delay_ns() } else { 0.0 };
    let worst = kernel_stage.max(tree_stage);
    let critical = worst + gates::T_REG_MARGIN_NS + gates::T_ROUTE_NS;
    let fmax = (1000.0 / critical).min(FMAX_FABRIC_CAP_MHZ);
    TimingReport {
        kernel_stage_ns: kernel_stage,
        tree_stage_ns: tree_stage,
        critical_path_ns: critical,
        fmax_mhz: fmax,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::kernelcircuit::KernelKind;

    /// E8 anchor: 16-bit CNN ~214 MHz, 16-bit AdderNet hits the 250 MHz
    /// fabric cap (paper §4, ZCU104, P=1024).
    #[test]
    fn onboard_fmax_anchors() {
        let cnn = analyse(&PeArray::new(64, 16, 16, KernelKind::Mult));
        let adder = analyse(&PeArray::new(64, 16, 16, KernelKind::Adder2A));
        assert!((cnn.fmax_mhz - 214.0).abs() < 10.0, "CNN fmax {}", cnn.fmax_mhz);
        assert!((adder.fmax_mhz - 250.0).abs() < 1e-9, "Adder fmax {}", adder.fmax_mhz);
        // Speed-up ratio ~1.16x (paper conclusion).
        let speedup = adder.fmax_mhz / cnn.fmax_mhz;
        assert!(speedup > 1.10 && speedup < 1.25, "speedup {speedup}");
    }

    #[test]
    fn adder_datapath_not_the_bottleneck() {
        let r = analyse(&PeArray::new(64, 16, 16, KernelKind::Adder2A));
        // The adder kernel's own path supports > 250 MHz; the cap binds.
        assert!(1000.0 / r.critical_path_ns > FMAX_FABRIC_CAP_MHZ);
    }

    #[test]
    fn wider_multiplier_slower() {
        let m8 = analyse(&PeArray::new(64, 16, 8, KernelKind::Mult));
        let m16 = analyse(&PeArray::new(64, 16, 16, KernelKind::Mult));
        assert!(m8.fmax_mhz >= m16.fmax_mhz);
    }

    #[test]
    fn pin_1_has_no_tree_stage() {
        let r = analyse(&PeArray::new(1, 6, 16, KernelKind::Adder2A));
        assert_eq!(r.tree_stage_ns, 0.0);
    }
}
