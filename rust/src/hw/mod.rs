//! FPGA hardware substrate (gate-level resource / energy / timing models).
//!
//! This is the substitute for the paper's Vivado synthesis + on-board
//! measurements (DESIGN.md §2): a from-scratch model of the minimalist
//! AdderNet accelerator and its CNN / shift / XNOR / memristor
//! competitors, calibrated to the paper's own S4 (energy) and S5 (area)
//! anchor tables and to Xilinx LUT6/CARRY4 packing rules.

pub mod adder_tree;
pub mod array;
pub mod device;
pub mod gates;
pub mod kernelcircuit;
pub mod memory;
pub mod power;
pub mod timing;
pub mod units;

pub use adder_tree::AdderTree;
pub use array::PeArray;
pub use device::{Device, Z7020, ZCU104};
pub use kernelcircuit::KernelKind;
pub use units::UnitCost;
