//! The widening reduction tree that sums `fan_in` kernel outputs.
//!
//! This is the datapath behind Eq. (2)/(3)'s second term: a binary tree of
//! adders whose word width grows by one bit per level to hold the exact
//! sum.  Two accounting modes are provided:
//!
//! * [`AdderTree::luts_precise`] — per-level widths `w+1, w+2, ...` (what
//!   an RTL generator would instantiate);
//! * [`AdderTree::luts_paper`]  — the paper's closed form
//!   `(w + log2(fan_in)) * (fan_in - 1)`, which charges every adder the
//!   full final width.  The ablation bench (E16/eq23) quantifies the gap
//!   (paper's form overestimates by up to ~30% at wide fan-in).

use super::gates;
use super::units::{self, UnitCost};

/// A `fan_in`-to-1 pipelined adder reduction tree.
#[derive(Debug, Clone, Copy)]
pub struct AdderTree {
    /// Number of inputs being reduced (Pin in the paper).
    pub fan_in: u64,
    /// Word width of each input, bits.
    pub in_bits: u32,
}

impl AdderTree {
    pub fn new(fan_in: u64, in_bits: u32) -> Self {
        assert!(fan_in >= 1, "fan_in must be >= 1");
        Self { fan_in, in_bits }
    }

    /// Number of tree levels = ceil(log2(fan_in)).
    pub fn levels(&self) -> u32 {
        if self.fan_in <= 1 { 0 } else { 64 - (self.fan_in - 1).leading_zeros() }
    }

    /// Total number of 2-input adders = fan_in - 1 (exact for any fan_in).
    pub fn adder_count(&self) -> u64 {
        self.fan_in - 1
    }

    /// Output word width: in_bits + levels.
    pub fn out_bits(&self) -> u32 {
        self.in_bits + self.levels()
    }

    /// LUTs with exact per-level widths.  Level l (1-based) has
    /// ~fan_in/2^l adders of width in_bits + l.
    pub fn luts_precise(&self) -> u64 {
        let mut remaining = self.fan_in;
        let mut total = 0u64;
        let mut level = 0u32;
        while remaining > 1 {
            level += 1;
            let adders = remaining / 2;
            total += adders * gates::adder_luts(self.in_bits + level);
            remaining = remaining / 2 + remaining % 2;
        }
        total
    }

    /// The paper's closed-form LUT count:
    /// `(in_bits + log2(fan_in)) * (fan_in - 1)`.
    pub fn luts_paper(&self) -> u64 {
        (self.out_bits() as u64) * self.adder_count()
    }

    /// Energy for one full reduction (all fan_in-1 adders fire), pJ.
    pub fn energy_pj(&self) -> f64 {
        let mut remaining = self.fan_in;
        let mut total = 0.0;
        let mut level = 0u32;
        while remaining > 1 {
            level += 1;
            let adders = remaining / 2;
            total += adders as f64 * gates::adder_energy_pj(self.in_bits + level);
            remaining = remaining / 2 + remaining % 2;
        }
        total
    }

    /// Combinational delay of ONE level (the tree is pipelined per level;
    /// the critical path through the tree stage is its widest adder).
    pub fn level_delay_ns(&self) -> f64 {
        gates::adder_delay_ns(self.out_bits())
    }

    /// Aggregate cost with precise widths; delay is a single pipeline
    /// stage (per-level registering assumed, as in the paper's design).
    pub fn cost(&self) -> UnitCost {
        UnitCost {
            luts: self.luts_precise(),
            area_units: self.adder_count() as f64
                * units::adder(self.out_bits()).area_units,
            energy_pj: self.energy_pj(),
            delay_ns: self.level_delay_ns(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_and_counts() {
        let t = AdderTree::new(64, 16);
        assert_eq!(t.levels(), 6);
        assert_eq!(t.adder_count(), 63);
        assert_eq!(t.out_bits(), 22);
        let t1 = AdderTree::new(1, 16);
        assert_eq!(t1.levels(), 0);
        assert_eq!(t1.adder_count(), 0);
        assert_eq!(t1.luts_precise(), 0);
    }

    #[test]
    fn non_power_of_two_fan_in() {
        for f in [3u64, 5, 6, 7, 9, 33, 96] {
            let t = AdderTree::new(f, 8);
            assert_eq!(t.adder_count(), f - 1);
            assert!(t.luts_precise() > 0);
            assert!(t.luts_precise() <= t.luts_paper());
        }
    }

    /// Paper formula is an upper bound within ~30% of the precise widths
    /// (it charges every adder the full final width; the eq23 ablation
    /// bench quantifies this gap per design point).
    #[test]
    fn paper_formula_tight_upper_bound() {
        // Gap grows as width shrinks relative to log2(fan_in): ~23% at
        // (64,16) up to ~51% at (128,8); the eq23 bench reports each
        // design point.
        for (f, w, bound) in [(64u64, 16u32, 1.25), (64, 8, 1.45),
                              (128, 16, 1.30), (128, 8, 1.55)] {
            let t = AdderTree::new(f, w);
            let precise = t.luts_precise() as f64;
            let paper = t.luts_paper() as f64;
            assert!(paper >= precise);
            assert!(paper <= precise * bound, "fan_in={f} w={w}: {paper} vs {precise}");
        }
    }

    /// Eq. (2)/(3) tree terms at the paper's design point.
    #[test]
    fn eq23_tree_terms() {
        // AdderNet tree: inputs are DW+1 wide (kernel adds one bit), the
        // paper's formula uses [DW + log2(Pin)] * (Pin - 1).
        let adder_tree = AdderTree::new(64, 16);
        assert_eq!(adder_tree.luts_paper(), 22 * 63);
        // CNN tree: [2*DW + log2(Pin) - 1] * (Pin - 1): inputs 2*DW wide,
        // the paper drops one bit; mirror its accounting exactly.
        let cnn_in_bits = 2 * 16 - 1;
        let cnn_tree = AdderTree::new(64, cnn_in_bits);
        assert_eq!(cnn_tree.luts_paper(), (2 * 16 + 6 - 1) * 63);
    }

    #[test]
    fn energy_grows_with_fan_in_and_width() {
        assert!(AdderTree::new(64, 16).energy_pj() > AdderTree::new(32, 16).energy_pj());
        assert!(AdderTree::new(64, 16).energy_pj() > AdderTree::new(64, 8).energy_pj());
    }

    #[test]
    fn pipelined_level_delay_smaller_than_full_comb() {
        let t = AdderTree::new(1024, 16);
        assert!(t.level_delay_ns() < t.levels() as f64 * t.level_delay_ns());
    }
}
