//! Gate-level primitives and calibration constants for the FPGA model.
//!
//! Two unit systems coexist (DESIGN.md §5):
//!
//! * **Area units** — the technology-independent gate-equivalent units of
//!   the paper's Supplemental S5 table (Fig. 12, after Thakre &
//!   Srivastava).  Used for the kernel-level comparisons (E10/E11).
//! * **LUTs** — Xilinx 6-input LUT + CARRY4 packing estimates used by the
//!   synthesis emulation (Fig. 4/5, S8).  On Xilinx fabric one
//!   ripple-carry adder bit costs ~1 LUT, a 2:1 mux packs 2 bits/LUT and a
//!   magnitude comparator packs ~2 bits/LUT on the carry chain.
//!
//! * **Energy** — pJ per operation at the paper's S4 table scale
//!   (Fig. 11, after Horowitz ISSCC'14 45 nm).  ASIC-scale switching
//!   energy; the FPGA dynamic-power model multiplies by
//!   [`FPGA_DYNAMIC_FACTOR`] (routing + configuration overhead of
//!   programmable fabric vs. ASIC, ~10x, Kuon & Rose).
//!
//! Everything downstream (trees, arrays, networks) is *derived* from these
//! few anchors; `cargo test -p addernet hw::` pins the anchor cells to the
//! paper's tables.

/// Energy per int-adder operation, pJ, as a function of bit width.
/// Anchors (paper S4 / Horowitz): 8b -> 0.03, 16b -> 0.05, 32b -> 0.09-0.1.
pub fn adder_energy_pj(bits: u32) -> f64 {
    0.0025 * bits as f64 + 0.01
}

/// Energy per magnitude-comparator operation, pJ.
/// Anchors: 1C1A minus adder: 8b ~0.01, 16b ~0.02, 32b ~0.05.
pub fn comparator_energy_pj(bits: u32) -> f64 {
    0.0015 * bits as f64
}

/// Energy per int array-multiplier operation, pJ (quadratic in width).
/// Anchors: 8b -> 0.2, 32b -> 3.1 (paper S4).
pub fn multiplier_energy_pj(bits: u32) -> f64 {
    0.003 * (bits as f64) * (bits as f64)
}

/// Energy per 2:1 mux (whole word), pJ — "much lightweight than other
/// logic parts" (paper S1); modelled at one tenth of a comparator.
pub fn mux_energy_pj(bits: u32) -> f64 {
    0.00015 * bits as f64
}

/// Energy per XNOR-popcount 1-bit kernel op, pJ (paper S4: < 0.01).
pub const XNOR_ENERGY_PJ: f64 = 0.004;

/// Energy per analogue memristor MAC, pJ (paper S4: ~0.01 at 4 bit),
/// EXCLUDING the DAC/ADC periphery which `kernelcircuit` adds explicitly.
pub const MEMRISTOR_MAC_ENERGY_PJ: f64 = 0.01;

/// DAC energy per conversion, pJ (4-6 bit, behavioural).
pub const DAC_ENERGY_PJ: f64 = 0.3;
/// ADC energy per conversion, pJ — SAR ADC, dominates memristor periphery.
pub const ADC_ENERGY_PJ: f64 = 2.0;

/// FP32 energies (paper S4 row "FP32bit": adder 0.9, mult 3.7).
pub const FP32_ADD_ENERGY_PJ: f64 = 0.9;
pub const FP32_MULT_ENERGY_PJ: f64 = 3.7;

/// FPGA dynamic energy overhead vs the ASIC-scale S4 numbers
/// (programmable routing, clock tree, configuration SRAM).
pub const FPGA_DYNAMIC_FACTOR: f64 = 10.0;

// ---------------------------------------------------------------------------
// Area units (paper S5 scale)
// ---------------------------------------------------------------------------

/// S5-scale area of an N-bit ripple-carry adder.
/// Anchors (2A column / 2): 8b -> 36, 16b -> 67, 32b -> 137.
pub fn adder_area_units(bits: u32) -> f64 {
    4.2 * bits as f64 + 2.0
}

/// S5-scale area of an N-bit magnitude comparator.
/// Anchors (1C1A minus adder): 8b -> 22, 16b -> 45, 32b -> 90.
pub fn comparator_area_units(bits: u32) -> f64 {
    2.8 * bits as f64
}

/// S5-scale area of an N x N array multiplier.
/// Anchors: 8b -> 282, 32b -> 3495 (paper S5).
pub fn multiplier_area_units(bits: u32) -> f64 {
    let n = bits as f64;
    3.08 * n * n + 10.6 * n
}

/// S5-scale area of a whole-word 2:1 mux.
pub fn mux_area_units(bits: u32) -> f64 {
    0.9 * bits as f64
}

/// S5-scale area of the 1-bit XNOR kernel (paper S5: ~1).
pub const XNOR_AREA_UNITS: f64 = 1.0;
/// S5-scale area of a 1T1R differential memristor cell (paper S5: ~2).
pub const MEMRISTOR_AREA_UNITS: f64 = 2.0;

// ---------------------------------------------------------------------------
// LUT packing (Xilinx LUT6 + CARRY4)
// ---------------------------------------------------------------------------

/// LUTs for an N-bit adder/subtractor: 1 LUT per bit on the carry chain.
pub fn adder_luts(bits: u32) -> u64 {
    bits as u64
}

/// LUTs for an N-bit magnitude comparator: carry chain packs 2 bits/LUT.
pub fn comparator_luts(bits: u32) -> u64 {
    (bits as u64).div_ceil(2)
}

/// LUTs for an N-bit 2:1 mux: LUT6 packs two 2:1 bit-muxes.
pub fn mux_luts(bits: u32) -> u64 {
    (bits as u64).div_ceil(2)
}

/// LUTs for a LUT-fabric N x N signed multiplier (no DSP, as in the
/// paper's "fair comparison" synthesis): N partial-product rows plus the
/// reduction adders; N*(N+1) matches Vivado LUT-mult estimates within
/// ~10% at 8/16 bit.
pub fn multiplier_luts(bits: u32) -> u64 {
    (bits as u64) * (bits as u64 + 1)
}

/// LUTs for an N-bit serial shift register stage (SRL-based).
pub fn shift_register_luts(bits: u32) -> u64 {
    (bits as u64).div_ceil(2)
}

// ---------------------------------------------------------------------------
// Gate delays (ns) — drives timing.rs static timing analysis
// ---------------------------------------------------------------------------

/// LUT + local routing delay, ns (UltraScale+ -2 speed grade scale).
pub const T_LUT_NS: f64 = 0.35;
/// Per-bit carry-chain delay, ns.
pub const T_CARRY_NS: f64 = 0.02;
/// Clock-to-out + setup + clock skew margin, ns.
pub const T_REG_MARGIN_NS: f64 = 0.55;
/// Global routing margin per pipeline stage, ns.
pub const T_ROUTE_NS: f64 = 0.9;

/// Combinational delay of an N-bit ripple/carry-chain adder.
pub fn adder_delay_ns(bits: u32) -> f64 {
    T_LUT_NS + T_CARRY_NS * bits as f64
}

/// Combinational delay of an N-bit comparator (carry chain, 2 bits/LUT).
pub fn comparator_delay_ns(bits: u32) -> f64 {
    T_LUT_NS + T_CARRY_NS * (bits as f64 / 2.0)
}

/// Combinational delay of a LUT-fabric N x N multiplier: ~1.5*log2(N)
/// LUT levels of partial-product generation + reduction plus a 2N-bit
/// final carry chain.  Calibrated so a 16-bit LUT multiplier stage limits
/// the clock to ~214 MHz (the paper's measured CNN fmax).
pub fn multiplier_delay_ns(bits: u32) -> f64 {
    let levels = 1.5 * (bits as f64).log2().ceil();
    T_LUT_NS * (1.0 + levels) + T_CARRY_NS * (2 * bits) as f64
}

/// Whole-word mux delay.
pub const MUX_DELAY_NS: f64 = 0.25;

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() <= tol * b.abs().max(1e-9)
    }

    /// Pin the energy anchors to the paper's S4 table (Fig. 11).
    #[test]
    fn s4_energy_anchors() {
        // 2A kernel = 2 adders: 8b 0.06, 16b 0.1, 32b 0.2 pJ
        assert!(close(2.0 * adder_energy_pj(8), 0.06, 0.01));
        assert!(close(2.0 * adder_energy_pj(16), 0.10, 0.01));
        assert!(close(2.0 * adder_energy_pj(32), 0.18, 0.15)); // paper 0.2
        // 1C1A kernel = comparator + adder: 8b 0.04, 16b 0.07, 32b 0.14
        assert!(close(comparator_energy_pj(8) + adder_energy_pj(8), 0.042, 0.06));
        assert!(close(comparator_energy_pj(16) + adder_energy_pj(16), 0.074, 0.06));
        assert!(close(comparator_energy_pj(32) + adder_energy_pj(32), 0.138, 0.05));
        // multiplier: 8b 0.2, 32b 3.1
        assert!(close(multiplier_energy_pj(8), 0.2, 0.05));
        assert!(close(multiplier_energy_pj(32), 3.1, 0.01));
    }

    /// Pin the area anchors to the paper's S5 table (Fig. 12).
    #[test]
    fn s5_area_anchors() {
        // 2 Adders column: 8b 72, 16b 134, 32b 274
        assert!(close(2.0 * adder_area_units(8), 72.0, 0.04));
        assert!(close(2.0 * adder_area_units(16), 134.0, 0.04));
        assert!(close(2.0 * adder_area_units(32), 274.0, 0.04));
        // 1C1A column: 8b 58, 16b 112, 32b 227
        assert!(close(comparator_area_units(8) + adder_area_units(8), 58.0, 0.04));
        assert!(close(comparator_area_units(16) + adder_area_units(16), 112.0, 0.04));
        assert!(close(comparator_area_units(32) + adder_area_units(32), 227.0, 0.05));
        // multiplier: 8b 282, 32b 3495
        assert!(close(multiplier_area_units(8), 282.0, 0.02));
        assert!(close(multiplier_area_units(32), 3495.0, 0.02));
    }

    #[test]
    fn adder_cheaper_than_multiplier_at_all_widths() {
        for bits in [4, 8, 12, 16, 24, 32] {
            assert!(2.0 * adder_energy_pj(bits) < multiplier_energy_pj(bits));
            assert!(2 * adder_luts(bits) < multiplier_luts(bits));
            assert!(2.0 * adder_area_units(bits) < multiplier_area_units(bits));
        }
    }

    #[test]
    fn multiplier_slower_than_adder() {
        for bits in [8, 16, 32] {
            assert!(multiplier_delay_ns(bits) > adder_delay_ns(bits));
        }
    }

    #[test]
    fn fp32_anchors() {
        assert!(close(FP32_MULT_ENERGY_PJ / FP32_ADD_ENERGY_PJ, 4.11, 0.01));
    }

    #[test]
    fn lut_packing_monotone() {
        let mut prev = 0;
        for bits in [4, 8, 16, 32] {
            let l = multiplier_luts(bits);
            assert!(l > prev);
            prev = l;
            assert_eq!(adder_luts(bits), bits as u64);
        }
    }
}
