//! FPGA device models: capacity limits the accelerator configurations the
//! simulator will admit (the paper's "parallelism of CNN is restrained to
//! 1024 on ZCU104" observation falls out of these numbers).

/// Static capacities of an FPGA part.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Device {
    pub name: &'static str,
    /// 6-input LUT count.
    pub luts: u64,
    /// Flip-flops.
    pub ffs: u64,
    /// Block RAM capacity, kilobits (BRAM36 x 36 kb).
    pub bram_kbits: u64,
    /// DSP48 slices (unused in the paper's "fair comparison" builds, but
    /// tracked for the S8 utilization row).
    pub dsps: u64,
    /// Static (leakage + PS-subsystem) power floor, W — the "~14 W
    /// embedded system baseline" the paper subtracts.
    pub static_power_w: f64,
    /// Peak off-chip DRAM bandwidth, bytes/s.
    pub dram_bw_bytes_per_s: f64,
}

/// Xilinx Zynq UltraScale+ MPSoC ZCU104 (XCZU7EV-2FFVC1156).
pub const ZCU104: Device = Device {
    name: "ZCU104 (XCZU7EV)",
    luts: 230_400,
    ffs: 460_800,
    bram_kbits: 11_088, // 312 x BRAM36 = 11 Mb
    dsps: 1_728,
    static_power_w: 14.0,
    dram_bw_bytes_per_s: 19.2e9, // PS DDR4-2400 64-bit
};

/// Xilinx Zynq-7020 (XC7Z020, the PYNQ-class part of Fig. 5).
pub const Z7020: Device = Device {
    name: "Zynq-7020 (XC7Z020)",
    luts: 53_200,
    ffs: 106_400,
    bram_kbits: 4_480, // 140 x BRAM36 = 4.9 Mb
    dsps: 220,
    static_power_w: 2.5,
    dram_bw_bytes_per_s: 4.2e9,
};

impl Device {
    /// Fraction of LUTs a design uses; > 1.0 means it does not fit.
    pub fn lut_utilization(&self, luts: u64) -> f64 {
        luts as f64 / self.luts as f64
    }

    /// Whether a design fits with a routing-headroom margin (synthesis
    /// practice: > ~85% LUT utilization fails timing closure).
    pub fn fits(&self, luts: u64, bram_kbits: u64) -> bool {
        self.lut_utilization(luts) <= 0.85
            && bram_kbits as f64 <= self.bram_kbits as f64 * 0.95
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::array::PeArray;
    use crate::hw::kernelcircuit::KernelKind;

    #[test]
    fn capacities_sane() {
        assert!(ZCU104.luts > Z7020.luts);
        assert!(ZCU104.bram_kbits > Z7020.bram_kbits);
    }

    /// Paper §4: on ZCU104 the CNN parallelism is "restrained to 1024";
    /// our capacity model must agree that 16-bit CNN at P=2048 does NOT
    /// fit while AdderNet-equivalent compute at the same P does (it's the
    /// whole point of the minimalist kernel).
    #[test]
    fn zcu104_parallelism_restraint() {
        let cnn_2048 = PeArray::new(64, 32, 16, KernelKind::Mult);
        assert!(!ZCU104.fits(cnn_2048.luts(), 0));
        let adder_2048 = PeArray::new(64, 32, 16, KernelKind::Adder2A);
        assert!(ZCU104.fits(adder_2048.luts(), 0));
    }

    #[test]
    fn utilization_fraction() {
        assert!((ZCU104.lut_utilization(115_200) - 0.5).abs() < 1e-9);
        assert!(!ZCU104.fits(230_400, 0));
    }
}
