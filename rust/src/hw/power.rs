//! Dynamic power model: switching energy x activity + memory traffic +
//! clock/control overhead.
//!
//! The paper's on-board measurement protocol subtracts the ~14 W embedded
//! baseline and reports the *intrinsic convolution power* (CNN 2.57 W vs
//! AdderNet 1.34 W at 214 MHz).  This module reproduces that accounting:
//! `intrinsic = compute + on-chip buffers + off-chip traffic + clock tree`.

use super::array::PeArray;
use super::gates::FPGA_DYNAMIC_FACTOR;
use super::memory;

/// Clock-tree + control dynamic power per LUT at 1 GHz, W (fitted so the
/// non-datapath share of a ~100 kLUT design lands at a few hundred mW,
/// consistent with Vivado XPE defaults for UltraScale+).
pub const CLOCK_W_PER_LUT_GHZ: f64 = 2.2e-6;

/// Breakdown of intrinsic accelerator power, W.
#[derive(Debug, Clone, Copy, Default)]
pub struct PowerReport {
    /// Kernel lanes + adder trees switching power.
    pub compute_w: f64,
    /// On-chip BRAM access power.
    pub bram_w: f64,
    /// Off-chip DRAM + AXI transport power.
    pub dram_w: f64,
    /// Clock tree + control fabric power.
    pub clock_w: f64,
}

impl PowerReport {
    pub fn total_w(&self) -> f64 {
        self.compute_w + self.bram_w + self.dram_w + self.clock_w
    }
}

/// Power of a PE array clocked at `fmax_mhz` with `duty` fraction of
/// cycles doing useful work, plus memory traffic streams.
///
/// * `bram_bytes_per_s` — on-chip buffer read+write traffic.
/// * `dram_bytes_per_s` — off-chip traffic (0 for the Fig. 5 design).
/// * `total_luts` — whole-design LUT count for the clock-tree term.
pub fn power(
    array: &PeArray,
    fmax_mhz: f64,
    duty: f64,
    bram_bytes_per_s: f64,
    dram_bytes_per_s: f64,
    total_luts: u64,
) -> PowerReport {
    let cycles_per_s = fmax_mhz * 1e6;
    let e_cycle_pj = array.energy_per_cycle_pj() * FPGA_DYNAMIC_FACTOR;
    let compute_w = e_cycle_pj * 1e-12 * cycles_per_s * duty;
    let bram_w = bram_bytes_per_s * memory::E_BRAM_PJ_PER_BYTE * 1e-12;
    let dram_w = dram_bytes_per_s
        * (memory::E_DRAM_PJ_PER_BYTE + memory::E_AXI_PJ_PER_BYTE)
        * 1e-12;
    let clock_w = total_luts as f64 * CLOCK_W_PER_LUT_GHZ * (fmax_mhz / 1000.0);
    PowerReport { compute_w, bram_w, dram_w, clock_w }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::kernelcircuit::KernelKind;

    fn onboard(kernel: KernelKind, luts: u64) -> PowerReport {
        // E8 operating point: Pin=64, Pout=16 (P=1024), DW=16, 214 MHz,
        // ~0.9 duty, DRAM streaming ~2.5 GB/s, buffers ~3x DRAM rate.
        let arr = PeArray::new(64, 16, 16, kernel);
        power(&arr, 214.0, 0.9, 7.5e9, 2.5e9, luts)
    }

    /// E8 anchor: CNN ~2.57 W vs AdderNet ~1.34 W intrinsic at 214 MHz —
    /// model must land within 25% and reproduce the ~48% saving.
    #[test]
    fn onboard_power_anchors() {
        let cnn = onboard(KernelKind::Mult, 190_000);
        let adder = onboard(KernelKind::Adder2A, 75_000);
        assert!((cnn.total_w() - 2.57).abs() / 2.57 < 0.25, "cnn {:.2} W", cnn.total_w());
        assert!((adder.total_w() - 1.34).abs() / 1.34 < 0.25, "adder {:.2} W", adder.total_w());
        let saving = 1.0 - adder.total_w() / cnn.total_w();
        assert!((saving - 0.4785).abs() < 0.12, "saving {saving:.3}");
    }

    /// Without DRAM traffic the saving approaches the theoretical ~78-81%
    /// (the Fig. 5 on-chip LeNet regime).
    #[test]
    fn onchip_saving_approaches_theory() {
        let arr_a = PeArray::new(6, 16, 16, KernelKind::Adder2A);
        let arr_c = PeArray::new(6, 16, 16, KernelKind::Mult);
        let a = power(&arr_a, 100.0, 0.9, 1e9, 0.0, arr_a.luts());
        let c = power(&arr_c, 100.0, 0.9, 1e9, 0.0, arr_c.luts());
        let saving = 1.0 - a.total_w() / c.total_w();
        assert!(saving > 0.55, "saving {saving:.3}");
    }

    #[test]
    fn dram_term_scales_linearly() {
        let arr = PeArray::new(64, 16, 16, KernelKind::Adder2A);
        let p1 = power(&arr, 214.0, 0.9, 0.0, 1e9, 100_000);
        let p2 = power(&arr, 214.0, 0.9, 0.0, 2e9, 100_000);
        assert!((p2.dram_w / p1.dram_w - 2.0).abs() < 1e-9);
    }

    #[test]
    fn zero_duty_zero_compute() {
        let arr = PeArray::new(64, 16, 16, KernelKind::Mult);
        let p = power(&arr, 214.0, 0.0, 0.0, 0.0, 0);
        assert_eq!(p.compute_w, 0.0);
        assert_eq!(p.total_w(), 0.0);
    }
}
