//! Datapath units: typed wrappers over the gate-level primitives.
//!
//! Each unit reports its cost in all three currencies (LUTs, S5 area
//! units, pJ/op) plus its combinational delay, so the kernel circuits,
//! adder trees and PE arrays can be composed without re-deriving packing
//! rules.

use super::gates;

/// A hardware cost triple + timing for one datapath unit.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct UnitCost {
    /// Xilinx LUT estimate (synthesis emulation currency).
    pub luts: u64,
    /// Paper-S5-scale technology-independent area units.
    pub area_units: f64,
    /// Switching energy per operation, pJ (ASIC scale; FPGA power model
    /// multiplies by `gates::FPGA_DYNAMIC_FACTOR`).
    pub energy_pj: f64,
    /// Combinational delay, ns.
    pub delay_ns: f64,
}

impl UnitCost {
    pub const ZERO: UnitCost = UnitCost { luts: 0, area_units: 0.0, energy_pj: 0.0, delay_ns: 0.0 };

    /// Series composition: areas add, delays add (same path).
    pub fn series(self, other: UnitCost) -> UnitCost {
        UnitCost {
            luts: self.luts + other.luts,
            area_units: self.area_units + other.area_units,
            energy_pj: self.energy_pj + other.energy_pj,
            delay_ns: self.delay_ns + other.delay_ns,
        }
    }

    /// Parallel composition: areas add, delay is the max path.
    pub fn parallel(self, other: UnitCost) -> UnitCost {
        UnitCost {
            luts: self.luts + other.luts,
            area_units: self.area_units + other.area_units,
            energy_pj: self.energy_pj + other.energy_pj,
            delay_ns: self.delay_ns.max(other.delay_ns),
        }
    }

    /// `n` identical instances operating in parallel.
    pub fn times(self, n: u64) -> UnitCost {
        UnitCost {
            luts: self.luts * n,
            area_units: self.area_units * n as f64,
            energy_pj: self.energy_pj * n as f64,
            delay_ns: self.delay_ns,
        }
    }
}

/// N-bit ripple/carry-chain adder (or subtractor — same fabric cost).
pub fn adder(bits: u32) -> UnitCost {
    UnitCost {
        luts: gates::adder_luts(bits),
        area_units: gates::adder_area_units(bits),
        energy_pj: gates::adder_energy_pj(bits),
        delay_ns: gates::adder_delay_ns(bits),
    }
}

/// N-bit magnitude comparator.
pub fn comparator(bits: u32) -> UnitCost {
    UnitCost {
        luts: gates::comparator_luts(bits),
        area_units: gates::comparator_area_units(bits),
        energy_pj: gates::comparator_energy_pj(bits),
        delay_ns: gates::comparator_delay_ns(bits),
    }
}

/// Whole-word 2:1 multiplexer.
pub fn mux2(bits: u32) -> UnitCost {
    UnitCost {
        luts: gates::mux_luts(bits),
        area_units: gates::mux_area_units(bits),
        energy_pj: gates::mux_energy_pj(bits),
        delay_ns: gates::MUX_DELAY_NS,
    }
}

/// N x N LUT-fabric signed array multiplier (no DSP).
pub fn multiplier(bits: u32) -> UnitCost {
    UnitCost {
        luts: gates::multiplier_luts(bits),
        area_units: gates::multiplier_area_units(bits),
        energy_pj: gates::multiplier_energy_pj(bits),
        delay_ns: gates::multiplier_delay_ns(bits),
    }
}

/// N-bit serial shift register (one stage of a DeepShift barrel path).
pub fn shift_register(bits: u32) -> UnitCost {
    UnitCost {
        luts: gates::shift_register_luts(bits),
        // area/energy scale like a half adder per bit
        area_units: 1.6 * bits as f64,
        energy_pj: 0.001 * bits as f64,
        delay_ns: gates::T_LUT_NS,
    }
}

/// 1-bit XNOR + popcount slice (binary network kernel).
pub fn xnor_cell() -> UnitCost {
    UnitCost {
        luts: 1,
        area_units: gates::XNOR_AREA_UNITS,
        energy_pj: gates::XNOR_ENERGY_PJ,
        delay_ns: gates::T_LUT_NS,
    }
}

/// Differential 1T1R memristor pair performing one analogue MAC.
/// Digital periphery (DAC/ADC) is accounted separately in kernelcircuit.
pub fn memristor_cell() -> UnitCost {
    UnitCost {
        luts: 0, // not fabric logic
        area_units: gates::MEMRISTOR_AREA_UNITS,
        energy_pj: gates::MEMRISTOR_MAC_ENERGY_PJ,
        delay_ns: 1.0, // analogue settling
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn series_adds_delay_parallel_maxes() {
        let a = adder(16);
        let c = comparator(16);
        let s = a.series(c);
        let p = a.parallel(c);
        assert_eq!(s.luts, a.luts + c.luts);
        assert!((s.delay_ns - (a.delay_ns + c.delay_ns)).abs() < 1e-12);
        assert!((p.delay_ns - a.delay_ns.max(c.delay_ns)).abs() < 1e-12);
        assert_eq!(p.luts, s.luts);
    }

    #[test]
    fn times_scales_area_not_delay() {
        let a = adder(8).times(64);
        assert_eq!(a.luts, 64 * adder(8).luts);
        assert!((a.delay_ns - adder(8).delay_ns).abs() < 1e-12);
        assert!((a.energy_pj - 64.0 * adder(8).energy_pj).abs() < 1e-9);
    }

    #[test]
    fn fifty_fold_energy_gap_at_16bit() {
        // Paper §2.2: FIX16 multiply ~15.7x adder energy.
        let ratio = multiplier(16).energy_pj / adder(16).energy_pj;
        assert!(ratio > 10.0 && ratio < 20.0, "ratio {ratio}");
    }

    #[test]
    fn mux_is_lightweight() {
        // S1: "the MUX is much lightweight than other logic parts".
        assert!(mux2(16).energy_pj < 0.1 * adder(16).energy_pj);
        assert!(mux2(16).luts <= comparator(16).luts);
    }

    #[test]
    fn memristor_has_no_fabric_luts() {
        assert_eq!(memristor_cell().luts, 0);
    }
}
