//! On-chip buffering and off-chip traffic models.
//!
//! The paper attributes the gap between the theoretical ~81% saving and
//! the measured 47.85% on ZCU104 to "data move from the outside main
//! memory to the computation part" — so the memory system is modelled
//! explicitly: double-buffered BRAM tiles, AXI burst transfers, and
//! per-byte access energies at the three levels of the hierarchy.

/// Energy per byte moved, pJ — Horowitz ISSCC'14 scale.
pub const E_BRAM_PJ_PER_BYTE: f64 = 4.0;
/// Off-chip DRAM access energy per byte, pJ (DDR4 burst streaming;
/// random-access word energy is ~2.6 nJ/32b but sequential bursts
/// amortise activation to ~1 nJ / 4 B).
pub const E_DRAM_PJ_PER_BYTE: f64 = 250.0;
/// AXI interconnect + PHY energy per byte, pJ.
pub const E_AXI_PJ_PER_BYTE: f64 = 50.0;

/// AXI-full data bus model (paper: AXI-full for weight/feature moves).
#[derive(Debug, Clone, Copy)]
pub struct AxiBus {
    /// Data width in bytes (ZCU104 HP ports: 128-bit = 16 B).
    pub bytes_per_beat: u64,
    /// Parallel HP ports ganged for streaming (ZCU104 exposes 4).
    pub ports: u64,
    /// Beats per burst (AXI4 INCR max 256).
    pub burst_len: u64,
    /// Cycles of address/handshake overhead per burst.
    pub burst_overhead_cycles: u64,
}

pub const ZCU104_AXI: AxiBus =
    AxiBus { bytes_per_beat: 16, ports: 4, burst_len: 64, burst_overhead_cycles: 8 };

impl AxiBus {
    /// Cycles to move `bytes` over ONE port (burst-granular, incl.
    /// handshake overhead).
    pub fn cycles(&self, bytes: u64) -> u64 {
        if bytes == 0 {
            return 0;
        }
        let beats = bytes.div_ceil(self.bytes_per_beat);
        let bursts = beats.div_ceil(self.burst_len);
        beats + bursts * self.burst_overhead_cycles
    }

    /// Effective aggregate bandwidth in bytes/cycle across all ports.
    pub fn effective_bytes_per_cycle(&self) -> f64 {
        let per_burst = self.bytes_per_beat * self.burst_len;
        self.ports as f64 * per_burst as f64
            / (self.burst_len + self.burst_overhead_cycles) as f64
    }
}

/// On-chip buffer plan for one layer tile (double-buffered ping/pong).
#[derive(Debug, Clone, Copy)]
pub struct BufferPlan {
    /// Input-feature tile bytes (one buffer).
    pub in_tile_bytes: u64,
    /// Weight tile bytes.
    pub weight_tile_bytes: u64,
    /// Output tile bytes.
    pub out_tile_bytes: u64,
}

impl BufferPlan {
    /// Total BRAM kilobits with double buffering on inputs + weights.
    pub fn bram_kbits(&self) -> u64 {
        let bytes = 2 * (self.in_tile_bytes + self.weight_tile_bytes) + self.out_tile_bytes;
        (bytes * 8).div_ceil(1024)
    }

    /// BRAM access energy for one fill + drain of the plan, pJ.
    pub fn access_energy_pj(&self) -> f64 {
        (self.in_tile_bytes + self.weight_tile_bytes + self.out_tile_bytes) as f64
            * E_BRAM_PJ_PER_BYTE
    }
}

/// Off-chip traffic summary for a layer / network run.
#[derive(Debug, Clone, Copy, Default)]
pub struct Traffic {
    pub dram_bytes: u64,
}

impl Traffic {
    pub fn add(&mut self, bytes: u64) {
        self.dram_bytes += bytes;
    }

    /// DRAM + AXI energy, pJ.
    pub fn energy_pj(&self) -> f64 {
        self.dram_bytes as f64 * (E_DRAM_PJ_PER_BYTE + E_AXI_PJ_PER_BYTE)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axi_cycles_burst_granular() {
        let bus = ZCU104_AXI;
        assert_eq!(bus.cycles(0), 0);
        // one beat still pays one burst overhead
        assert_eq!(bus.cycles(1), 1 + 8);
        // exactly one full burst: 64 beats + 8
        assert_eq!(bus.cycles(16 * 64), 64 + 8);
        // two bursts
        assert_eq!(bus.cycles(16 * 65), 65 + 16);
    }

    #[test]
    fn effective_bandwidth_below_peak() {
        let bus = ZCU104_AXI;
        let peak = (bus.bytes_per_beat * bus.ports) as f64;
        assert!(bus.effective_bytes_per_cycle() < peak);
        assert!(bus.effective_bytes_per_cycle() > 0.8 * peak);
    }

    #[test]
    fn dram_dominates_energy_hierarchy() {
        assert!(E_DRAM_PJ_PER_BYTE > E_AXI_PJ_PER_BYTE);
        assert!(E_DRAM_PJ_PER_BYTE > 40.0 * E_BRAM_PJ_PER_BYTE);
    }

    #[test]
    fn buffer_plan_double_buffers() {
        let p = BufferPlan { in_tile_bytes: 1024, weight_tile_bytes: 512, out_tile_bytes: 256 };
        // 2*(1024+512)+256 = 3328 bytes = 26624 bits -> 26 kb
        assert_eq!(p.bram_kbits(), 26);
        assert!(p.access_energy_pj() > 0.0);
    }
}
