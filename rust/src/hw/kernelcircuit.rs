//! The five convolution kernels of paper Fig. 1 as circuits.
//!
//! Per-kernel circuit structure (Supplemental S1-S3):
//!
//! * `Adder1C1A` — one comparator + one adder: compare A,B, subtract the
//!   smaller from the larger.  Cheapest area, longer serial path.
//! * `Adder2A`   — two parallel adders (A-B and B-A) + a mux selecting the
//!   positive one.  Faster, slightly larger — the paper's deployed choice.
//! * `Mult`      — one N x N multiplier (classical CNN).
//! * `Shift`     — DeepShift: serial shift register + sign mux; for an
//!   M-bit weight, (M-1) extra adders + M shift register groups.
//! * `Xnor`      — XNOR + popcount bit-slice (binary network).
//! * `Memristor` — differential 1T1R pair + per-lane DAC and shared-column
//!   ADC periphery (the "hidden cost" paper §2.2 calls out).

use super::units::{self, UnitCost};

/// Which similarity circuit a PE lane instantiates.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum KernelKind {
    /// AdderNet, one-comparator-one-adder scheme (S1).
    Adder1C1A,
    /// AdderNet, two-adders scheme (S1) — the paper's deployed design.
    Adder2A,
    /// Classical multiply kernel.
    Mult,
    /// DeepShift with `weight_bits`-bit shift-encoded weights.
    Shift { weight_bits: u32 },
    /// XNOR binary kernel.
    Xnor,
    /// Analogue memristor MAC (1T1R differential).
    Memristor,
}

impl KernelKind {
    pub const ALL_DIGITAL: [KernelKind; 5] = [
        KernelKind::Adder1C1A,
        KernelKind::Adder2A,
        KernelKind::Mult,
        KernelKind::Shift { weight_bits: 6 },
        KernelKind::Xnor,
    ];

    pub fn label(&self) -> String {
        match self {
            KernelKind::Adder1C1A => "AdderNet(1C1A)".into(),
            KernelKind::Adder2A => "AdderNet(2A)".into(),
            KernelKind::Mult => "CNN(mult)".into(),
            KernelKind::Shift { weight_bits } => format!("DeepShift({weight_bits}b)"),
            KernelKind::Xnor => "XNOR(BNN)".into(),
            KernelKind::Memristor => "Memristor".into(),
        }
    }

    /// True if the kernel computes the AdderNet -|a-b| similarity.
    pub fn is_adder(&self) -> bool {
        matches!(self, KernelKind::Adder1C1A | KernelKind::Adder2A)
    }

    /// Output width of one kernel op given `dw`-bit inputs.  The adder
    /// kernel keeps `dw+1` bits; the multiplier doubles the width —
    /// this is what widens the CNN adder tree (Eq. 3's `2*DW` term).
    pub fn output_bits(&self, dw: u32) -> u32 {
        match self {
            KernelKind::Adder1C1A | KernelKind::Adder2A => dw + 1,
            KernelKind::Mult => 2 * dw,
            KernelKind::Shift { .. } => 2 * dw, // post-shift width
            KernelKind::Xnor => 1,
            KernelKind::Memristor => dw, // re-digitised by the ADC
        }
    }

    /// Circuit cost of ONE kernel lane at data width `dw`.
    pub fn lane_cost(&self, dw: u32) -> UnitCost {
        match self {
            KernelKind::Adder1C1A => {
                // comparator gates the subtract order: serial path.
                units::comparator(dw).series(units::adder(dw))
            }
            KernelKind::Adder2A => {
                // two adders in parallel, mux picks the non-negative one.
                units::adder(dw)
                    .parallel(units::adder(dw))
                    .series(units::mux2(dw + 1))
            }
            KernelKind::Mult => units::multiplier(dw),
            KernelKind::Shift { weight_bits } => {
                // M groups of shift registers + sign mux (+ (M-1) adders
                // for multi-bit shift weights, paper §2.1).
                let m = *weight_bits;
                let mut c = units::shift_register(dw).times(m as u64)
                    .series(units::mux2(dw));
                if m > 1 {
                    c = c.series(units::adder(dw).times((m - 1) as u64));
                }
                c
            }
            KernelKind::Xnor => units::xnor_cell(),
            KernelKind::Memristor => units::memristor_cell().times(2), // differential
        }
    }

    /// Per-op energy of a lane including conversion periphery, pJ.
    /// For the memristor this adds the amortised DAC (per input) and ADC
    /// (per output sample) energy the paper's §2.2 identifies as the real
    /// cost of analogue kernels.
    pub fn lane_energy_pj(&self, dw: u32) -> f64 {
        let base = self.lane_cost(dw).energy_pj;
        match self {
            KernelKind::Memristor => {
                base + super::gates::DAC_ENERGY_PJ + super::gates::ADC_ENERGY_PJ / 64.0
            }
            _ => base,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() <= tol * b.abs().max(1e-9)
    }

    /// Reproduce the S4 energy table rows for the kernel circuits.
    #[test]
    fn s4_kernel_energy_rows() {
        assert!(close(KernelKind::Adder1C1A.lane_cost(8).energy_pj, 0.04, 0.1));
        assert!(close(KernelKind::Adder2A.lane_cost(8).energy_pj, 0.06, 0.05));
        assert!(close(KernelKind::Mult.lane_cost(8).energy_pj, 0.2, 0.05));
        assert!(close(KernelKind::Adder1C1A.lane_cost(16).energy_pj, 0.07, 0.07));
        assert!(close(KernelKind::Adder2A.lane_cost(16).energy_pj, 0.10, 0.05));
        assert!(close(KernelKind::Adder1C1A.lane_cost(32).energy_pj, 0.14, 0.05));
        assert!(close(KernelKind::Mult.lane_cost(32).energy_pj, 3.1, 0.02));
    }

    /// Reproduce the S5 area table rows.
    #[test]
    fn s5_kernel_area_rows() {
        assert!(close(KernelKind::Adder1C1A.lane_cost(8).area_units, 58.0, 0.15));
        // 2A carries an extra word mux on top of the paper's bare "2 adders".
        assert!(close(KernelKind::Adder2A.lane_cost(8).area_units, 72.0, 0.15));
        assert!(close(KernelKind::Adder2A.lane_cost(16).area_units, 134.0, 0.15));
        assert!(close(KernelKind::Mult.lane_cost(8).area_units, 282.0, 0.05));
        assert!(close(KernelKind::Mult.lane_cost(32).area_units, 3495.0, 0.05));
    }

    /// S1 trade-off: 1C1A is smaller, 2A is faster.
    #[test]
    fn s1_scheme_tradeoff() {
        for dw in [8, 16, 32] {
            let c1a = KernelKind::Adder1C1A.lane_cost(dw);
            let a2 = KernelKind::Adder2A.lane_cost(dw);
            assert!(c1a.luts <= a2.luts, "1C1A should be smaller at {dw}b");
            assert!(a2.delay_ns < c1a.delay_ns, "2A should be faster at {dw}b");
        }
    }

    /// Paper Fig. 2c ordering: XNOR < memristor-cell < adder < mult.
    #[test]
    fn fig2c_energy_ordering() {
        let dw = 16;
        let xnor = KernelKind::Xnor.lane_energy_pj(1);
        let adder = KernelKind::Adder2A.lane_energy_pj(dw);
        let mult = KernelKind::Mult.lane_energy_pj(dw);
        assert!(xnor < adder && adder < mult);
        // memristor WITH periphery is no longer the cheapest (paper §2.2).
        let mem = KernelKind::Memristor.lane_energy_pj(4);
        assert!(mem > KernelKind::Memristor.lane_cost(4).energy_pj);
    }

    #[test]
    fn output_width_widening() {
        assert_eq!(KernelKind::Adder2A.output_bits(16), 17);
        assert_eq!(KernelKind::Mult.output_bits(16), 32);
        assert_eq!(KernelKind::Xnor.output_bits(16), 1);
    }

    #[test]
    fn shift_multibit_needs_adders() {
        let s1 = KernelKind::Shift { weight_bits: 1 }.lane_cost(16);
        let s6 = KernelKind::Shift { weight_bits: 6 }.lane_cost(16);
        assert!(s6.luts > s1.luts);
        assert!(s6.energy_pj > 5.0 * s1.energy_pj); // paper: 6b ~6x 1b energy
    }
}
