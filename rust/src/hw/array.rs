//! The parallel convolution PE array: `pout` lanes, each reducing `pin`
//! kernel outputs through an adder tree — the structure of paper Fig. 4(a)
//! and the subject of Eq. (2) (AdderNet) and Eq. (3) (CNN).

use super::adder_tree::AdderTree;
use super::kernelcircuit::KernelKind;
use super::units::UnitCost;

/// Geometry + datapath of the compute array.
#[derive(Debug, Clone, Copy)]
pub struct PeArray {
    /// Input channels summed in the tree per output channel (Pin).
    pub pin: u64,
    /// Parallel output channels (Pout).
    pub pout: u64,
    /// Data width of features/weights, bits (DW).
    pub dw: u32,
    /// Which similarity kernel each lane instantiates.
    pub kernel: KernelKind,
}

impl PeArray {
    pub fn new(pin: u64, pout: u64, dw: u32, kernel: KernelKind) -> Self {
        Self { pin, pout, dw, kernel }
    }

    /// Total parallelism P = Pin * Pout (the x-axis of Fig. 4c/d).
    pub fn parallelism(&self) -> u64 {
        self.pin * self.pout
    }

    /// The adder tree each output lane instantiates.
    pub fn tree(&self) -> AdderTree {
        AdderTree::new(self.pin, self.kernel.output_bits(self.dw))
    }

    /// Paper Eq. (2): AdderNet logic consumption
    /// `Pout * {Pin*DW*2 + [DW + log2(Pin)]*(Pin-1)}`.
    pub fn eq2_addernet(pin: u64, pout: u64, dw: u32) -> u64 {
        let log2pin = AdderTree::new(pin, 0).levels() as u64;
        pout * (pin * dw as u64 * 2 + (dw as u64 + log2pin) * (pin - 1))
    }

    /// Paper Eq. (3): CNN logic consumption
    /// `Pout * {Pin*DW*DW + [2*DW + log2(Pin) - 1]*(Pin-1)}`.
    pub fn eq3_cnn(pin: u64, pout: u64, dw: u32) -> u64 {
        let log2pin = AdderTree::new(pin, 0).levels() as u64;
        pout * (pin * dw as u64 * dw as u64
            + (2 * dw as u64 + log2pin - 1) * (pin - 1))
    }

    /// Theoretical AdderNet saving from Eq. (2)/(3):
    /// `1 - eq2/eq3` (the "~81.6% off at DW=16, Pin=64" headline).
    pub fn eq23_saving(pin: u64, dw: u32) -> f64 {
        let a = Self::eq2_addernet(pin, 1, dw) as f64;
        let c = Self::eq3_cnn(pin, 1, dw) as f64;
        1.0 - a / c
    }

    /// Precise LUT count: per-lane kernel circuits + per-output-channel
    /// widening trees (the synthesis-emulation currency of Fig. 4).
    pub fn luts(&self) -> u64 {
        let lane = self.kernel.lane_cost(self.dw).luts;
        let tree = self.tree().luts_precise();
        self.pout * (self.pin * lane + tree)
    }

    /// Paper-formula LUT count (kernel charged `DW*2` / `DW*DW`, tree at
    /// full final width) — kept for the Eq-2/3 ablation.
    pub fn luts_paper(&self) -> u64 {
        match self.kernel {
            KernelKind::Adder2A | KernelKind::Adder1C1A => {
                Self::eq2_addernet(self.pin, self.pout, self.dw)
            }
            KernelKind::Mult => Self::eq3_cnn(self.pin, self.pout, self.dw),
            _ => self.luts(),
        }
    }

    /// Energy for one full array activation (all lanes + trees fire), pJ.
    pub fn energy_per_cycle_pj(&self) -> f64 {
        let lane = self.kernel.lane_energy_pj(self.dw);
        let tree = self.tree().energy_pj();
        self.pout as f64 * (self.pin as f64 * lane + tree)
    }

    /// Aggregate circuit cost (kernel stage + one pipelined tree stage).
    pub fn cost(&self) -> UnitCost {
        let lanes = self.kernel.lane_cost(self.dw).times(self.pin * self.pout);
        let trees = self.tree().cost().times(self.pout);
        // kernel stage and tree stage are separate pipeline stages: the
        // array's combinational path is the max of the two.
        lanes.parallel(trees)
    }

    /// MAC-equivalent operations per cycle (each lane = 1 MAC = 2 ops).
    pub fn ops_per_cycle(&self) -> u64 {
        2 * self.parallelism()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The headline claim: DW=16, Pin=64 => ~81.6% off (paper §4).
    #[test]
    fn eq23_headline_saving() {
        let s = PeArray::eq23_saving(64, 16);
        assert!((s - 0.816).abs() < 0.005, "saving {s}");
    }

    #[test]
    fn eq2_eq3_exact_values() {
        // By-hand values at Pin=64, Pout=1, DW=16:
        // eq2 = 64*16*2 + (16+6)*63 = 2048 + 1386 = 3434
        assert_eq!(PeArray::eq2_addernet(64, 1, 16), 3434);
        // eq3 = 64*256 + (32+6-1)*63 = 16384 + 2331 = 18715
        assert_eq!(PeArray::eq3_cnn(64, 1, 16), 18715);
    }

    #[test]
    fn saving_grows_with_dw() {
        assert!(PeArray::eq23_saving(64, 16) > PeArray::eq23_saving(64, 8));
        assert!(PeArray::eq23_saving(64, 8) > 0.5);
    }

    #[test]
    fn precise_luts_track_paper_formula() {
        for (pin, pout, dw) in [(64u64, 16u64, 16u32), (64, 32, 8), (32, 4, 16)] {
            let adder = PeArray::new(pin, pout, dw, KernelKind::Adder2A);
            let cnn = PeArray::new(pin, pout, dw, KernelKind::Mult);
            let precise = 1.0 - adder.luts() as f64 / cnn.luts() as f64;
            let paper = 1.0 - adder.luts_paper() as f64 / cnn.luts_paper() as f64;
            // same direction, within 12 points of the closed form
            assert!((precise - paper).abs() < 0.12,
                    "pin={pin} dw={dw}: precise {precise:.3} paper {paper:.3}");
            assert!(precise > 0.5);
        }
    }

    #[test]
    fn energy_saving_matches_area_saving_scale() {
        let adder = PeArray::new(64, 16, 16, KernelKind::Adder2A);
        let cnn = PeArray::new(64, 16, 16, KernelKind::Mult);
        let saving = 1.0 - adder.energy_per_cycle_pj() / cnn.energy_per_cycle_pj();
        assert!(saving > 0.6 && saving < 0.95, "energy saving {saving}");
    }

    #[test]
    fn ops_per_cycle() {
        assert_eq!(PeArray::new(64, 16, 16, KernelKind::Adder2A).ops_per_cycle(),
                   2 * 1024);
    }

    #[test]
    fn scaling_linear_in_pout() {
        let a1 = PeArray::new(64, 8, 16, KernelKind::Adder2A).luts();
        let a2 = PeArray::new(64, 16, 16, KernelKind::Adder2A).luts();
        assert_eq!(a2, 2 * a1);
    }
}
