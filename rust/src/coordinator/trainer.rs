//! Training driver: the Rust coordinator owns ALL model state (params +
//! momenta as PJRT literals) and drives the AOT-compiled fused train-step
//! graph.  Python never runs here — the loop is
//! `state <- train_step(state, batch, step)` against artifacts built once
//! by `make artifacts`.

use anyhow::{Context, Result};
use xla::Literal;

use super::manifest::Manifest;
use crate::data::Batch;
use crate::runtime::{self, Runtime};

/// One (step, loss, acc) record of the training history.
#[derive(Debug, Clone, Copy)]
pub struct StepRecord {
    pub step: usize,
    pub loss: f32,
    pub acc: f32,
}

/// Stateful trainer for one (arch, kernel) model.
pub struct Trainer {
    pub arch: String,
    pub kernel: String,
    graph_train: String,
    graph_eval: String,
    /// params + momenta literals, in the exact input order of the graph.
    state: Vec<Literal>,
    n_params: usize,
    n_momenta: usize,
    pub batch_size: usize,
    pub step: usize,
    pub history: Vec<StepRecord>,
    /// Sorted parameter names (layout order == input order).
    param_names: Vec<String>,
    param_shapes: Vec<Vec<usize>>,
}

impl Trainer {
    /// Build a trainer: loads + compiles the train/eval graphs and the
    /// initial parameters.
    pub fn new(manifest: &Manifest, rt: &mut Runtime, arch: &str,
               kernel: &str) -> Result<Trainer> {
        let gname = format!("{arch}_{kernel}_train");
        let ename = format!("{arch}_{kernel}_eval");
        let ginfo = manifest.graph(&gname)?.clone();
        rt.load(&gname, &ginfo.file)?;
        let einfo = manifest.graph(&ename)?.clone();
        rt.load(&ename, &einfo.file)?;

        let layout = manifest.layout(arch)?;
        let init = manifest.read_param_file(arch, &layout.init_file)?;
        let trainable: std::collections::BTreeSet<&String> =
            layout.trainable.iter().collect();

        let mut state = Vec::with_capacity(ginfo.n_params + ginfo.n_momenta);
        let mut param_names = Vec::new();
        let mut param_shapes = Vec::new();
        // params first (sorted order == layout order)
        for (name, shape, data) in &init {
            state.push(runtime::literal_f32(shape, data)?);
            param_names.push(name.clone());
            param_shapes.push(shape.clone());
        }
        // zero momenta for trainable slots, same sorted order
        for (name, shape, _) in &init {
            if trainable.contains(name) {
                let n: usize = shape.iter().product();
                state.push(runtime::literal_f32(shape, &vec![0f32; n])?);
            }
        }
        anyhow::ensure!(state.len() == ginfo.n_params + ginfo.n_momenta,
                        "state arity {} vs manifest {}+{}",
                        state.len(), ginfo.n_params, ginfo.n_momenta);

        Ok(Trainer {
            arch: arch.into(),
            kernel: kernel.into(),
            graph_train: gname,
            graph_eval: ename,
            state,
            n_params: ginfo.n_params,
            n_momenta: ginfo.n_momenta,
            batch_size: ginfo.batch,
            step: 0,
            history: Vec::new(),
            param_names,
            param_shapes,
        })
    }

    /// One fused train step; returns (loss, accuracy-on-batch).
    pub fn train_step(&mut self, rt: &Runtime, batch: &Batch) -> Result<(f32, f32)> {
        anyhow::ensure!(batch.n == self.batch_size,
                        "batch {} != graph batch {}", batch.n, self.batch_size);
        let x = runtime::literal_f32(&[batch.n, 32, 32, 1], &batch.images)?;
        let y = runtime::literal_i32(&[batch.n], &batch.labels)?;
        let step = runtime::literal_scalar_i32(self.step as i32);
        let mut inputs: Vec<&Literal> = self.state.iter().collect();
        inputs.push(&x);
        inputs.push(&y);
        inputs.push(&step);
        let mut outs = rt.execute(&self.graph_train, &inputs)
            .context("train step")?;
        let n_state = self.n_params + self.n_momenta;
        anyhow::ensure!(outs.len() == n_state + 2, "train outputs {}", outs.len());
        let acc = runtime::scalar_f32(&outs[n_state + 1])?;
        let loss = runtime::scalar_f32(&outs[n_state])?;
        outs.truncate(n_state);
        self.state = outs;
        self.step += 1;
        self.history.push(StepRecord { step: self.step, loss, acc });
        Ok((loss, acc))
    }

    /// Evaluate accuracy over a dataset (chunked into graph-batch sizes;
    /// a trailing partial chunk is dropped).
    pub fn evaluate(&self, rt: &Runtime, images: &[f32], labels: &[i32]) -> Result<f64> {
        let b = self.batch_size;
        let n = labels.len() / b * b;
        anyhow::ensure!(n > 0, "eval set smaller than one batch");
        let mut correct = 0usize;
        for c in 0..n / b {
            let xs = &images[c * b * 1024..(c + 1) * b * 1024];
            let x = runtime::literal_f32(&[b, 32, 32, 1], xs)?;
            let mut inputs: Vec<&Literal> = self.state[..self.n_params].iter().collect();
            inputs.push(&x);
            let outs = rt.execute(&self.graph_eval, &inputs)?;
            let logits = runtime::to_vec_f32(&outs[0])?;
            for i in 0..b {
                let row = &logits[i * 10..(i + 1) * 10];
                let pred = row.iter().enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap().0;
                if pred == labels[c * b + i] as usize {
                    correct += 1;
                }
            }
        }
        Ok(correct as f64 / n as f64)
    }

    /// Extract current parameters as named f32 buffers (save / quantize).
    pub fn params_f32(&self) -> Result<Vec<(String, Vec<f32>)>> {
        let mut out = Vec::with_capacity(self.n_params);
        for (i, name) in self.param_names.iter().enumerate() {
            out.push((name.clone(), runtime::to_vec_f32(&self.state[i])?));
        }
        Ok(out)
    }

    /// Save current parameters to `<artifacts>/<file>` in layout order.
    pub fn save_params(&self, manifest: &Manifest, file: &str) -> Result<()> {
        manifest.write_param_file(&self.arch, file, &self.params_f32()?)
    }

    pub fn param_shapes(&self) -> (&[String], &[Vec<usize>]) {
        (&self.param_names, &self.param_shapes)
    }
}
