//! Inference serving: request router + dynamic batcher.
//!
//! Thread architecture (the vendored crate set has no async runtime, so
//! each model variant gets a dedicated OS worker thread):
//!
//! ```text
//!   clients -> ServerHandle.submit(variant, image)
//!           -> router (HashMap<variant, mpsc::Sender>)
//!           -> worker thread [dynamic batcher -> backend]
//!           -> per-request response channel
//! ```
//!
//! Two backends share the router, the batcher and the metrics:
//!
//! * **functional** ([`start_functional`]) — the tiled multi-threaded
//!   functional-sim engine; queued requests are stacked into ONE
//!   batched forward pass, so dispatch, patch gathers and weight
//!   streaming amortize across the whole queue.  Needs no artifacts and
//!   no XLA.  Variants with a quantized [`ExecMode`] are compiled to a
//!   [`QuantPlan`] at startup and served by the i32-domain
//!   [`PlanRunner`] (`repro serve --mode int8`).
//! * **pjrt** ([`start`], `pjrt` feature) — the AOT-compiled eval graph
//!   through the PJRT runtime; PJRT handles are not `Send`, so each
//!   worker constructs its own `Runtime`.
//!
//! The dynamic batcher collects up to the backend's batch size, waiting
//! at most `batch_window` after the first request — the same
//! latency/throughput trade the serving literature (and the vLLM-style
//! router) makes.

use std::collections::HashMap;
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::metrics::ServerMetrics;
use crate::quant::plan::QuantPlan;
use crate::quant::Calibration;
use crate::sim::functional::{self, Arch, ExecMode, KernelStrategy, Params, Runner,
                             SimKernel};
use crate::sim::intpath::PlanRunner;

#[cfg(feature = "pjrt")]
use super::manifest::Manifest;
#[cfg(feature = "pjrt")]
use crate::runtime::{self, Runtime};

/// A single inference request: one NHWC image.
struct Request {
    image: Vec<f32>,
    enqueued: Instant,
    respond: Sender<Response>,
}

/// The response: logits for the 10 classes.
#[derive(Debug, Clone)]
pub struct Response {
    pub logits: Vec<f32>,
    pub queue_time: Duration,
    pub total_time: Duration,
}

/// Handle clients use to submit work and read metrics.
pub struct ServerHandle {
    routes: HashMap<String, Sender<Request>>,
    pub metrics: Arc<Mutex<HashMap<String, ServerMetrics>>>,
    workers: Vec<JoinHandle<()>>,
}

impl ServerHandle {
    /// Submit one image to a variant; returns a receiver for the response.
    pub fn submit(&self, variant: &str, image: Vec<f32>) -> Result<Receiver<Response>> {
        let (tx, rx) = mpsc::channel();
        let route = self.routes.get(variant)
            .ok_or_else(|| anyhow::anyhow!("unknown variant {variant}"))?;
        route.send(Request { image, enqueued: Instant::now(), respond: tx })
            .map_err(|_| anyhow::anyhow!("variant {variant} worker gone"))?;
        Ok(rx)
    }

    pub fn variants(&self) -> Vec<String> {
        self.routes.keys().cloned().collect()
    }

    /// Drop the routes (workers drain + exit) and join the threads.
    pub fn shutdown(mut self) {
        self.routes.clear();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Collect a batch: blocking wait for the first request, then drain up
/// to `max_batch` within `batch_window`.  Returns false on shutdown.
fn collect_batch(rx: &Receiver<Request>, pending: &mut Vec<Request>,
                 max_batch: usize, batch_window: Duration) -> bool {
    match rx.recv() {
        Ok(r) => pending.push(r),
        Err(_) => return false, // all senders dropped: shutdown
    }
    let deadline = Instant::now() + batch_window;
    while pending.len() < max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(r) => pending.push(r),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    true
}

fn record_batch(metrics: &Arc<Mutex<HashMap<String, ServerMetrics>>>,
                name: &str, n: usize, exec_time: Duration) {
    let mut mm = metrics.lock().unwrap();
    let m = mm.entry(name.to_string()).or_default();
    m.batches += 1;
    m.images += n as u64;
    m.requests += n as u64;
    m.exec_lat.record(exec_time);
}

fn respond_all(metrics: &Arc<Mutex<HashMap<String, ServerMetrics>>>,
               name: &str, pending: &mut Vec<Request>, exec_start: Instant,
               logits: impl Fn(usize) -> Vec<f32>) {
    let mut mm = metrics.lock().unwrap();
    let m = mm.entry(name.to_string()).or_default();
    for (i, r) in pending.drain(..).enumerate() {
        let queue_time = exec_start.duration_since(r.enqueued);
        let total_time = r.enqueued.elapsed();
        m.queue_lat.record(queue_time);
        m.e2e_lat.record(total_time);
        let _ = r.respond.send(Response { logits: logits(i), queue_time, total_time });
    }
}

// ---------------------------------------------------------------------------
// Functional-sim backend (always available)
// ---------------------------------------------------------------------------

/// Serving configuration for one functional-sim variant.
#[derive(Debug, Clone)]
pub struct FunctionalVariantCfg {
    /// Route name clients submit to, e.g. "lenet5_adder".
    pub name: String,
    pub arch: Arch,
    pub kind: SimKernel,
    /// Inner-kernel strategy the variant's forward passes run under
    /// (`repro serve --kernel` / `ADDERNET_KERNEL` select it).
    pub strategy: KernelStrategy,
    /// Model parameters (manifest-loaded or synthetic).
    pub params: Params,
    /// f32 or quantized execution.  Quantized variants are compiled to
    /// a [`QuantPlan`] at [`start_functional`] time (weights quantized
    /// once, BN folded, activations i32 end-to-end through the conv
    /// stack) and served by the plan executor — never the per-call
    /// requantizing path.
    pub mode: ExecMode,
    /// Required when `mode` is quantized (`repro calibrate` produces
    /// one; a missing or incomplete table fails `start_functional`) —
    /// unless `plan` is set, which needs no calibration at all.
    pub calib: Option<Calibration>,
    /// Pre-compiled plan (the `repro serve --plan` cold-start path).
    /// When set, the worker serves THIS plan directly — `calib` is not
    /// consulted, no calibration pass runs, and `params` are unused on
    /// the quantized path (the quantized weights live in the plan).
    /// `start_functional` validates that `arch`/`kind` match the plan
    /// and that `mode` is `ExecMode::Quant(plan.cfg)`.
    pub plan: Option<QuantPlan>,
    /// Input (h, w, c); requests must carry h*w*c floats.
    pub input_hwc: (usize, usize, usize),
    /// Dynamic-batch cap (the functional engine takes any batch size;
    /// this bounds per-batch latency).
    pub max_batch: usize,
}

impl FunctionalVariantCfg {
    /// Variant backed by deterministic synthetic weights — lets the
    /// server run with no Python artifacts (demos, tests, load rigs).
    /// Input geometry comes from the architecture's compiled graph, so
    /// any registered `Arch` serves without further configuration.
    pub fn synthetic(name: &str, arch: Arch, kind: SimKernel, seed: u64) -> Self {
        Self {
            name: name.into(),
            arch,
            kind,
            strategy: KernelStrategy::Auto,
            params: functional::synth_params(arch, seed),
            mode: ExecMode::F32,
            calib: None,
            plan: None,
            input_hwc: arch.graph().input,
            max_batch: 32,
        }
    }
}

/// Start the functional-sim server: one worker thread per variant.
///
/// Quantized variants are compiled here, up front: building the
/// [`QuantPlan`] validates the calibration table against the model
/// (every conv layer must be covered) and quantizes the weights ONCE —
/// a misconfigured variant therefore fails this call with a proper
/// error instead of panicking a worker thread later.
pub fn start_functional(variants: Vec<FunctionalVariantCfg>,
                        batch_window: Duration) -> Result<ServerHandle> {
    // An empty variant list must be a startup ERROR, not a silently
    // idle server: callers that filtered every requested variant away
    // (e.g. unservable quant widths) would otherwise green-light a
    // server that can answer nothing.
    anyhow::ensure!(!variants.is_empty(),
                    "no variants to serve (every requested variant was \
                     filtered out, or the model list is empty)");
    let metrics: Arc<Mutex<HashMap<String, ServerMetrics>>> =
        Arc::new(Mutex::new(HashMap::new()));
    let mut routes = HashMap::new();
    let mut workers = Vec::new();
    for mut v in variants {
        anyhow::ensure!(v.max_batch > 0, "variant {}: max_batch must be > 0", v.name);
        let plan = match (v.plan.take(), v.mode) {
            // imported plan: already compiled and validated layer-by-
            // layer against its arch graph; just check it was mounted on
            // a variant that declares the SAME serving config (else the
            // metrics/CLI would claim one mode while the worker serves
            // another).
            (Some(p), mode) => {
                anyhow::ensure!(
                    p.arch == v.arch && p.kind == v.kind,
                    "variant {}: mounted plan was compiled for {}/{}, not \
                     {}/{}", v.name, p.arch.name(), p.kind.label(),
                    v.arch.name(), v.kind.label());
                anyhow::ensure!(
                    matches!(mode, ExecMode::Quant(cfg) if cfg == p.cfg),
                    "variant {}: mounts an int{} plan but declares mode \
                     {:?} — set mode to ExecMode::Quant(plan.cfg)",
                    v.name, p.cfg.bits, mode);
                Some(p)
            }
            (None, ExecMode::F32) => None,
            (None, ExecMode::Quant(cfg)) => {
                let calib = v.calib.as_ref().ok_or_else(|| anyhow::anyhow!(
                    "variant {}: quantized mode requires a calibration table \
                     (run `repro calibrate`, serve with --calib, or mount a \
                     compiled plan via --plan)", v.name))?;
                Some(QuantPlan::build(&v.params, v.arch, v.kind, cfg, calib)
                    .with_context(|| format!(
                        "variant {}: compiling the quantization plan", v.name))?)
            }
        };
        let (tx, rx) = mpsc::channel::<Request>();
        // a duplicate name would silently replace the first variant's
        // route (its worker exits on disconnect while the CLI reports
        // both as serving) — refuse at startup instead
        anyhow::ensure!(routes.insert(v.name.clone(), tx).is_none(),
                        "duplicate variant name {} (e.g. the same plan \
                         file listed twice)", v.name);
        let m = metrics.clone();
        workers.push(std::thread::Builder::new()
            .name(format!("fsim-{}", v.name))
            .spawn(move || functional_worker(v, plan, rx, m, batch_window))?);
    }
    Ok(ServerHandle { routes, metrics, workers })
}

fn functional_worker(cfg: FunctionalVariantCfg, plan: Option<QuantPlan>,
                     rx: Receiver<Request>,
                     metrics: Arc<Mutex<HashMap<String, ServerMetrics>>>,
                     batch_window: Duration) {
    let (h, w, c) = cfg.input_hwc;
    let px = h * w * c;
    let mut pending: Vec<Request> = Vec::with_capacity(cfg.max_batch);
    loop {
        if !collect_batch(&rx, &mut pending, cfg.max_batch, batch_window) {
            return;
        }
        // malformed requests are dropped; their response channel closes,
        // surfacing a recv error to the submitter.
        pending.retain(|r| r.image.len() == px);
        let n = pending.len();
        if n == 0 {
            continue;
        }
        let exec_start = Instant::now();
        let images: Vec<&[f32]> = pending.iter().map(|r| r.image.as_slice()).collect();
        let logits = match plan.as_ref() {
            // int serving: the pre-compiled plan keeps activations i32
            // across the conv stack; no per-call weight requantization.
            Some(p) => PlanRunner { plan: p, strategy: cfg.strategy }
                .forward_many(&images, cfg.input_hwc),
            None => {
                let mut runner = Runner {
                    params: &cfg.params,
                    arch: cfg.arch,
                    kind: cfg.kind,
                    strategy: cfg.strategy,
                    mode: ExecMode::F32,
                    calib: None,
                    observe: None,
                };
                runner.forward_many(&images, cfg.input_hwc)
            }
        };
        drop(images);
        let exec_time = exec_start.elapsed();
        record_batch(&metrics, &cfg.name, n, exec_time);
        respond_all(&metrics, &cfg.name, &mut pending, exec_start,
                    |i| logits[i].clone());
    }
}

// ---------------------------------------------------------------------------
// PJRT backend (`pjrt` feature)
// ---------------------------------------------------------------------------

/// Serving configuration for one PJRT graph variant.
#[cfg(feature = "pjrt")]
#[derive(Debug, Clone)]
pub struct VariantCfg {
    /// Graph base name, e.g. "lenet5_adder".
    pub model: String,
    /// Optional trained-weights file (relative to artifacts/); falls back
    /// to the init file.
    pub weights: Option<String>,
}

/// Start the PJRT server: one worker thread per variant.
#[cfg(feature = "pjrt")]
pub fn start(manifest: &Manifest, variants: &[VariantCfg],
             batch_window: Duration) -> Result<ServerHandle> {
    let metrics: Arc<Mutex<HashMap<String, ServerMetrics>>> =
        Arc::new(Mutex::new(HashMap::new()));
    let mut routes = HashMap::new();
    let mut workers = Vec::new();
    for v in variants {
        let (tx, rx) = mpsc::channel::<Request>();
        routes.insert(v.model.clone(), tx);
        let m = metrics.clone();
        let man = manifest.clone();
        let cfg = v.clone();
        workers.push(std::thread::Builder::new()
            .name(format!("worker-{}", v.model))
            .spawn(move || {
                if let Err(e) = pjrt_worker(man, cfg.clone(), rx, m, batch_window) {
                    eprintln!("[server] worker {} failed: {e:#}", cfg.model);
                }
            })?);
    }
    Ok(ServerHandle { routes, metrics, workers })
}

#[cfg(feature = "pjrt")]
fn pjrt_worker(manifest: Manifest, cfg: VariantCfg, rx: Receiver<Request>,
               metrics: Arc<Mutex<HashMap<String, ServerMetrics>>>,
               batch_window: Duration) -> Result<()> {
    // PJRT handles are not Send: the runtime lives and dies in this thread.
    let mut rt = Runtime::new(manifest.dir.clone())?;
    let gname = format!("{}_eval", cfg.model);
    let ginfo = manifest.graph(&gname)?.clone();
    rt.load(&gname, &ginfo.file)?;
    let batch = ginfo.batch;

    // model params: trained weights if configured, else init
    let layout = manifest.layout(&ginfo.arch)?;
    let wfile = cfg.weights.clone().unwrap_or_else(|| layout.init_file.clone());
    let init = manifest.read_param_file(&ginfo.arch, &wfile)?;
    let params: Vec<xla::Literal> = init.iter()
        .map(|(_, shape, data)| runtime::literal_f32(shape, data))
        .collect::<Result<_>>()?;

    let mut pending: Vec<Request> = Vec::with_capacity(batch);
    loop {
        if !collect_batch(&rx, &mut pending, batch, batch_window) {
            return Ok(());
        }
        // assemble the fixed-size batch (pad with zeros)
        let n = pending.len();
        let mut images = vec![0f32; batch * 1024];
        for (i, r) in pending.iter().enumerate() {
            images[i * 1024..(i + 1) * 1024].copy_from_slice(&r.image);
        }
        let exec_start = Instant::now();
        let x = runtime::literal_f32(&[batch, 32, 32, 1], &images)?;
        let mut inputs: Vec<&xla::Literal> = params.iter().collect();
        inputs.push(&x);
        let outs = rt.execute(&gname, &inputs)?;
        let logits = runtime::to_vec_f32(&outs[0])?;
        let exec_time = exec_start.elapsed();

        record_batch(&metrics, &cfg.model, n, exec_time);
        respond_all(&metrics, &cfg.model, &mut pending, exec_start,
                    |i| logits[i * 10..(i + 1) * 10].to_vec());
    }
}
