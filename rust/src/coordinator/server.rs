//! Inference serving: request router + dynamic batcher.
//!
//! Thread architecture (the vendored crate set has no async runtime, and
//! PJRT handles are not `Send`, so each model variant gets a dedicated
//! OS worker thread that *constructs its own* `Runtime`):
//!
//! ```text
//!   clients -> ServerHandle.submit(variant, image)
//!           -> router (HashMap<variant, mpsc::Sender>)
//!           -> worker thread [dynamic batcher -> PJRT eval graph]
//!           -> per-request response channel
//! ```
//!
//! The dynamic batcher collects up to the graph's fixed batch size,
//! waiting at most `batch_window` after the first request — the same
//! latency/throughput trade the serving literature (and the vLLM-style
//! router) makes.

use std::collections::HashMap;
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::manifest::Manifest;
use super::metrics::ServerMetrics;
use crate::runtime::{self, Runtime};

/// A single inference request: one 32x32x1 image.
struct Request {
    image: Vec<f32>,
    enqueued: Instant,
    respond: Sender<Response>,
}

/// The response: logits for the 10 classes.
#[derive(Debug, Clone)]
pub struct Response {
    pub logits: Vec<f32>,
    pub queue_time: Duration,
    pub total_time: Duration,
}

/// Serving configuration for one variant.
#[derive(Debug, Clone)]
pub struct VariantCfg {
    /// Graph base name, e.g. "lenet5_adder".
    pub model: String,
    /// Optional trained-weights file (relative to artifacts/); falls back
    /// to the init file.
    pub weights: Option<String>,
}

/// Handle clients use to submit work and read metrics.
pub struct ServerHandle {
    routes: HashMap<String, Sender<Request>>,
    pub metrics: Arc<Mutex<HashMap<String, ServerMetrics>>>,
    workers: Vec<JoinHandle<()>>,
}

impl ServerHandle {
    /// Submit one image to a variant; returns a receiver for the response.
    pub fn submit(&self, variant: &str, image: Vec<f32>) -> Result<Receiver<Response>> {
        let (tx, rx) = mpsc::channel();
        let route = self.routes.get(variant)
            .ok_or_else(|| anyhow::anyhow!("unknown variant {variant}"))?;
        route.send(Request { image, enqueued: Instant::now(), respond: tx })
            .map_err(|_| anyhow::anyhow!("variant {variant} worker gone"))?;
        Ok(rx)
    }

    pub fn variants(&self) -> Vec<String> {
        self.routes.keys().cloned().collect()
    }

    /// Drop the routes (workers drain + exit) and join the threads.
    pub fn shutdown(mut self) {
        self.routes.clear();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Start the server: one worker thread per variant.
pub fn start(manifest: &Manifest, variants: &[VariantCfg],
             batch_window: Duration) -> Result<ServerHandle> {
    let metrics: Arc<Mutex<HashMap<String, ServerMetrics>>> =
        Arc::new(Mutex::new(HashMap::new()));
    let mut routes = HashMap::new();
    let mut workers = Vec::new();
    for v in variants {
        let (tx, rx) = mpsc::channel::<Request>();
        routes.insert(v.model.clone(), tx);
        let m = metrics.clone();
        let man = manifest.clone();
        let cfg = v.clone();
        workers.push(std::thread::Builder::new()
            .name(format!("worker-{}", v.model))
            .spawn(move || {
                if let Err(e) = worker_loop(man, cfg.clone(), rx, m, batch_window) {
                    eprintln!("[server] worker {} failed: {e:#}", cfg.model);
                }
            })?);
    }
    Ok(ServerHandle { routes, metrics, workers })
}

fn worker_loop(manifest: Manifest, cfg: VariantCfg, rx: Receiver<Request>,
               metrics: Arc<Mutex<HashMap<String, ServerMetrics>>>,
               batch_window: Duration) -> Result<()> {
    // PJRT handles are not Send: the runtime lives and dies in this thread.
    let mut rt = Runtime::new(manifest.dir.clone())?;
    let gname = format!("{}_eval", cfg.model);
    let ginfo = manifest.graph(&gname)?.clone();
    rt.load(&gname, &ginfo.file)?;
    let batch = ginfo.batch;

    // model params: trained weights if configured, else init
    let layout = manifest.layout(&ginfo.arch)?;
    let wfile = cfg.weights.clone().unwrap_or_else(|| layout.init_file.clone());
    let init = manifest.read_param_file(&ginfo.arch, &wfile)?;
    let params: Vec<xla::Literal> = init.iter()
        .map(|(_, shape, data)| runtime::literal_f32(shape, data))
        .collect::<Result<_>>()?;

    let mut pending: Vec<Request> = Vec::with_capacity(batch);
    loop {
        // blocking wait for the first request of a batch
        match rx.recv() {
            Ok(r) => pending.push(r),
            Err(_) => return Ok(()), // all senders dropped: shutdown
        }
        let deadline = Instant::now() + batch_window;
        while pending.len() < batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => pending.push(r),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        // assemble the fixed-size batch (pad with zeros)
        let n = pending.len();
        let mut images = vec![0f32; batch * 1024];
        for (i, r) in pending.iter().enumerate() {
            images[i * 1024..(i + 1) * 1024].copy_from_slice(&r.image);
        }
        let exec_start = Instant::now();
        let x = runtime::literal_f32(&[batch, 32, 32, 1], &images)?;
        let mut inputs: Vec<&xla::Literal> = params.iter().collect();
        inputs.push(&x);
        let outs = rt.execute(&gname, &inputs)?;
        let logits = runtime::to_vec_f32(&outs[0])?;
        let exec_time = exec_start.elapsed();

        {
            let mut mm = metrics.lock().unwrap();
            let m = mm.entry(cfg.model.clone()).or_default();
            m.batches += 1;
            m.images += n as u64;
            m.requests += n as u64;
            m.exec_lat.record(exec_time);
        }
        for (i, r) in pending.drain(..).enumerate() {
            let queue_time = exec_start.duration_since(r.enqueued);
            let total_time = r.enqueued.elapsed();
            {
                let mut mm = metrics.lock().unwrap();
                let m = mm.entry(cfg.model.clone()).or_default();
                m.queue_lat.record(queue_time);
                m.e2e_lat.record(total_time);
            }
            let _ = r.respond.send(Response {
                logits: logits[i * 10..(i + 1) * 10].to_vec(),
                queue_time,
                total_time,
            });
        }
    }
}
