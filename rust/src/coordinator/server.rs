//! Inference serving: request router, admission control, replica
//! fleets and dynamic batching.
//!
//! Thread architecture (the vendored crate set has no async runtime, so
//! workers are dedicated OS threads):
//!
//! ```text
//!   clients -> ServerHandle.submit(variant, image)   [validated here]
//!           -> per-variant BoundedQueue              [load-sheds when full]
//!           -> N replica workers [dynamic batcher -> backend]
//!           -> per-request response channel
//! ```
//!
//! Every variant owns one bounded MPMC queue ([`super::queue`]) fed by
//! `submit` and drained by `replicas` worker threads.  Admission
//! control happens at `submit`: a malformed request (wrong pixel
//! count) is refused with [`SubmitError::BadRequest`], and a full
//! queue sheds with [`SubmitError::Overloaded`] — the server never
//! queues unboundedly and a client is never left holding a silently
//! dead response channel.  Both events are counted per variant in
//! [`ServerMetrics`].
//!
//! Three backends share the router, the batcher and the metrics:
//!
//! * **functional** ([`start_functional`]) — the tiled multi-threaded
//!   functional-sim engine; queued requests are stacked into ONE
//!   batched forward pass, so dispatch, patch gathers and weight
//!   streaming amortize across the whole queue.  Needs no artifacts and
//!   no XLA.  Variants with a quantized [`ExecMode`] are compiled to a
//!   [`QuantPlan`] at startup and served by the i32-domain
//!   [`PlanRunner`] (`repro serve --mode int8`).  Replica workers share
//!   the persistent conv worker pool (`util/threads.rs`), so scaling
//!   replicas scales batching concurrency without oversubscribing the
//!   engine.
//! * **hwsim** — the functional plan path with the cycle-accurate
//!   accelerator model alongside: setting
//!   [`FunctionalVariantCfg::hw_parallelism`] on a plan-backed variant
//!   precomputes the per-image schedule ([`crate::sim::hwsim`]) at
//!   startup, every [`Response`] carries the request's [`HwCost`], and
//!   batch costs aggregate into [`ServerMetrics`].  Logits are the
//!   SAME plan-runner logits — the hardware model prices requests, it
//!   never changes arithmetic.
//! * **pjrt** ([`start`], `pjrt` feature) — the AOT-compiled eval graph
//!   through the PJRT runtime; PJRT handles are not `Send`, so each
//!   worker constructs its own `Runtime`.
//!
//! **Zero-downtime plan hot-swap**: a quantized variant's compiled
//! [`QuantPlan`] lives behind an `Arc` in a per-variant slot; workers
//! take the CURRENT `Arc` when they start executing a batch, and
//! [`ServerHandle::swap_plan`] atomically replaces the slot while
//! traffic flows — in-flight batches finish on the plan they started
//! with, every batch collected after the swap runs the new plan, and no
//! request is ever dropped or errored by a swap.
//!
//! The dynamic batcher collects up to the backend's batch size, waiting
//! at most `batch_window` after the first request — the same
//! latency/throughput trade the serving literature (and the vLLM-style
//! router) makes.

use std::collections::HashMap;
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::metrics::ServerMetrics;
use super::queue::{BoundedQueue, Pop, PushError};
use crate::obs::registry::Registry;
use crate::obs::trace::{TraceHandle, TraceObserver, TraceSink};
use crate::quant::plan::QuantPlan;
use crate::quant::Calibration;
use crate::sim::functional::{self, Arch, ExecMode, KernelStrategy, Params, Runner,
                             SimKernel};
use crate::sim::hwsim::{self, HwCost};
use crate::sim::intpath::PlanRunner;

#[cfg(feature = "pjrt")]
use super::manifest::Manifest;
#[cfg(feature = "pjrt")]
use crate::runtime::{self, Runtime};

/// Default bounded queue depth per variant (`--queue-depth` overrides).
pub const DEFAULT_QUEUE_DEPTH: usize = 1024;

/// A single inference request: one NHWC image.
struct Request {
    image: Vec<f32>,
    enqueued: Instant,
    respond: Sender<Response>,
}

/// The response: logits for the 10 classes.
#[derive(Debug, Clone)]
pub struct Response {
    pub logits: Vec<f32>,
    pub queue_time: Duration,
    pub total_time: Duration,
    /// Simulated per-image hardware cost (hwsim backend; `None` on the
    /// purely functional and PJRT routes).
    pub hw: Option<HwCost>,
}

/// Typed submission error — callers can tell admission-control sheds
/// apart from malformed requests and routing mistakes (the load-test
/// harness and `drive_load` branch on it).
#[derive(Debug)]
pub enum SubmitError {
    /// No variant with that name is being served.
    UnknownVariant(String),
    /// Admission control: the variant's bounded queue is full.  The
    /// request was shed (counted in `ServerMetrics::shed`) — retry
    /// later or raise the queue depth.
    Overloaded { variant: String, depth: usize },
    /// Malformed request: the image does not match the variant's input
    /// geometry (counted in `ServerMetrics::rejected`).
    BadRequest { variant: String, expected: usize, got: usize },
    /// The server is shutting down; the queue no longer admits work.
    Shutdown(String),
}

impl fmt::Display for SubmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SubmitError::UnknownVariant(v) => write!(f, "unknown variant {v}"),
            SubmitError::Overloaded { variant, depth } => {
                write!(f, "variant {variant}: overloaded — bounded queue full \
                           at depth {depth}, request shed (retry later or \
                           raise --queue-depth)")
            }
            SubmitError::BadRequest { variant, expected, got } => {
                write!(f, "variant {variant}: bad request — expected \
                           {expected} pixels (h*w*c), got {got}")
            }
            SubmitError::Shutdown(v) => {
                write!(f, "variant {v}: server is shutting down")
            }
        }
    }
}

impl std::error::Error for SubmitError {}

/// One metrics shard.  `[0]` in a variant's shard list belongs to the
/// submit side (shed/rejected/swaps); each replica worker records into
/// its own private shard — the serving hot path never contends on one
/// global metrics mutex.  [`ServerHandle::metrics_snapshot`] merges the
/// shards at read time.
type MetricsShard = Arc<Mutex<ServerMetrics>>;

/// Per-variant shared state: the bounded request queue every replica
/// drains, the expected input size `submit` validates against, the
/// per-replica metrics shards and — for quantized variants — the
/// hot-swappable plan slot.
struct VariantState {
    name: String,
    queue: BoundedQueue<Request>,
    /// Pixels (h*w*c) a well-formed request must carry.
    px: usize,
    /// The CURRENT compiled plan for quantized variants (`None` = f32
    /// or PJRT).  Workers clone the `Arc` per batch; `swap_plan`
    /// replaces it atomically under the mutex.
    plan: Option<Mutex<Arc<QuantPlan>>>,
    /// `[0]` = submit-side shard, `[1..]` one per replica.
    shards: Vec<MetricsShard>,
    /// Batches currently executing across this variant's replicas.
    inflight: AtomicU64,
}

fn shard_list(replicas: usize) -> Vec<MetricsShard> {
    (0..=replicas).map(|_| MetricsShard::default()).collect()
}

/// Handle clients use to submit work, swap plans and read metrics.
pub struct ServerHandle {
    variants: HashMap<String, Arc<VariantState>>,
    workers: Mutex<Vec<JoinHandle<()>>>,
    /// Set when the server was started with request tracing on
    /// ([`start_functional_observed`]).
    trace: Option<Arc<TraceSink>>,
}

impl ServerHandle {
    /// Submit one image to a variant; returns a receiver for the
    /// response.  Admission control happens HERE: malformed requests
    /// and overload sheds come back as typed errors immediately — a
    /// submitted request is always answered (barring a worker panic),
    /// never silently dropped.
    pub fn submit(&self, variant: &str,
                  image: Vec<f32>) -> Result<Receiver<Response>, SubmitError> {
        let v = self.variants.get(variant)
            .ok_or_else(|| SubmitError::UnknownVariant(variant.to_string()))?;
        if image.len() != v.px {
            v.shards[0].lock().unwrap().rejected += 1;
            return Err(SubmitError::BadRequest {
                variant: variant.to_string(),
                expected: v.px,
                got: image.len(),
            });
        }
        let (tx, rx) = mpsc::channel();
        let req = Request { image, enqueued: Instant::now(), respond: tx };
        match v.queue.push(req) {
            Ok(()) => Ok(rx),
            Err(PushError::Full(_)) => {
                v.shards[0].lock().unwrap().shed += 1;
                Err(SubmitError::Overloaded {
                    variant: variant.to_string(),
                    depth: v.queue.capacity(),
                })
            }
            Err(PushError::Closed(_)) => {
                Err(SubmitError::Shutdown(variant.to_string()))
            }
        }
    }

    pub fn variants(&self) -> Vec<String> {
        self.variants.keys().cloned().collect()
    }

    /// Pixels per request (h*w*c) the variant expects, if it exists.
    pub fn input_len(&self, variant: &str) -> Option<usize> {
        self.variants.get(variant).map(|v| v.px)
    }

    /// Merge every variant's metrics shards into one per-variant view —
    /// the read side of per-replica recording.
    pub fn metrics_snapshot(&self) -> HashMap<String, ServerMetrics> {
        self.variants.iter()
            .map(|(name, v)| {
                let mut m = ServerMetrics::default();
                for s in &v.shards {
                    m.merge(&s.lock().unwrap());
                }
                (name.clone(), m)
            })
            .collect()
    }

    /// Requests currently queued (admitted, not yet claimed by a
    /// replica) on a variant.
    pub fn queue_depth(&self, variant: &str) -> Option<usize> {
        self.variants.get(variant).map(|v| v.queue.len())
    }

    /// Batches currently executing across a variant's replicas.
    pub fn inflight(&self, variant: &str) -> Option<u64> {
        self.variants.get(variant)
            .map(|v| v.inflight.load(Ordering::Relaxed))
    }

    /// The trace sink, when the server was started with tracing on.
    pub fn trace(&self) -> Option<&Arc<TraceSink>> {
        self.trace.as_ref()
    }

    /// Publish the current serving state into a metrics [`Registry`]:
    /// per-variant counters (requests/images/batches/shed/rejected/
    /// swaps), gauges (queue depth, in-flight batches, busy/idle time,
    /// shed/reject rates, hw cost rates) and the three latency
    /// histograms.  `snapshot()` and `render_prometheus()` on the same
    /// registry then expose identical values.
    pub fn export_registry(&self, reg: &Registry) {
        for (name, v) in &self.variants {
            let mut m = ServerMetrics::default();
            for s in &v.shards {
                m.merge(&s.lock().unwrap());
            }
            let lb = format!("{{variant=\"{name}\"}}");
            let counters: [(&str, &'static str, u64); 6] = [
                ("requests_total", "Requests answered", m.requests),
                ("images_total", "Images executed", m.images),
                ("batches_total", "Batches executed", m.batches),
                ("shed_total", "Submits shed by admission control",
                 m.shed),
                ("rejected_total", "Malformed submits rejected",
                 m.rejected),
                ("plan_swaps_total", "Zero-downtime plan hot-swaps",
                 m.swaps),
            ];
            for (key, help, val) in counters {
                reg.counter(&format!("addernet_{key}{lb}"), help).set(val);
            }
            let gauges: [(&str, &'static str, f64); 6] = [
                ("queue_depth", "Requests currently queued",
                 v.queue.len() as f64),
                ("inflight_batches", "Batches currently executing",
                 v.inflight.load(Ordering::Relaxed) as f64),
                ("busy_seconds", "Replica wall-clock spent executing",
                 m.busy_us as f64 / 1e6),
                ("idle_seconds", "Replica wall-clock spent waiting",
                 m.idle_us as f64 / 1e6),
                ("shed_rate", "Shed fraction of offered submits",
                 m.shed_rate()),
                ("reject_rate", "Rejected fraction of offered submits",
                 m.reject_rate()),
            ];
            for (key, help, val) in gauges {
                reg.gauge(&format!("addernet_{key}{lb}"), help).set(val);
            }
            if m.hw_fmax_mhz != 0.0 {
                reg.counter(&format!("addernet_hw_cycles_total{lb}"),
                            "Simulated accelerator cycles")
                    .set(m.hw_cycles);
                reg.counter(&format!("addernet_hw_dram_bytes_total{lb}"),
                            "Simulated off-chip traffic, bytes")
                    .set(m.hw_dram_bytes);
                reg.gauge(&format!("addernet_hw_power_w{lb}"),
                          "Simulated accelerator power, W")
                    .set(m.hw_power_w);
                reg.gauge(&format!("addernet_hw_fmax_mhz{lb}"),
                          "Simulated achieved clock, MHz")
                    .set(m.hw_fmax_mhz);
            }
            let hists: [(&str, &'static str,
                         &super::metrics::LatencyHistogram); 3] = [
                ("queue_latency_us", "Queue wait per request, µs",
                 &m.queue_lat),
                ("exec_latency_us", "Batch execution time, µs",
                 &m.exec_lat),
                ("e2e_latency_us", "End-to-end request latency, µs",
                 &m.e2e_lat),
            ];
            for (key, help, h) in hists {
                reg.histogram(&format!("addernet_{key}{lb}"), help)
                    .set_from(h);
            }
        }
    }

    /// Zero-downtime plan hot-swap: atomically replace a quantized
    /// variant's compiled [`QuantPlan`] while traffic flows.  The new
    /// plan must target the same arch, kernel and quant config as the
    /// one currently mounted (the same checks `start_functional`
    /// applies to a mounted plan) — a served route never changes
    /// meaning mid-flight.  In-flight batches finish on the old plan;
    /// every request submitted after this returns runs the new one.
    pub fn swap_plan(&self, variant: &str, plan: QuantPlan) -> Result<()> {
        let v = self.variants.get(variant)
            .ok_or_else(|| anyhow::anyhow!("unknown variant {variant}"))?;
        let slot = v.plan.as_ref().ok_or_else(|| anyhow::anyhow!(
            "variant {variant} does not serve a compiled plan (f32 or PJRT \
             route) — hot-swap applies to quantized plan-backed variants"))?;
        let mut cur = slot.lock().unwrap();
        anyhow::ensure!(
            plan.arch == cur.arch && plan.kind == cur.kind,
            "variant {variant}: new plan was compiled for {}/{}, current \
             serves {}/{}", plan.arch.name(), plan.kind.label(),
            cur.arch.name(), cur.kind.label());
        anyhow::ensure!(
            plan.cfg == cur.cfg,
            "variant {variant}: new plan serves int{} ({:?}), current serves \
             int{} ({:?}) — quant config must match for a zero-downtime swap",
            plan.cfg.bits, plan.cfg.mode, cur.cfg.bits, cur.cfg.mode);
        *cur = Arc::new(plan);
        drop(cur);
        v.shards[0].lock().unwrap().swaps += 1;
        Ok(())
    }

    /// Close every variant queue (already-admitted requests are still
    /// answered — workers drain before exiting) and join the worker
    /// threads.  Submissions after this return
    /// [`SubmitError::Shutdown`].
    pub fn shutdown(&self) {
        for v in self.variants.values() {
            v.queue.close();
        }
        let mut ws = self.workers.lock().unwrap();
        for w in ws.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        // A dropped handle must not leak blocked worker threads.
        self.shutdown();
    }
}

/// Collect a batch: blocking wait for the first request, then drain up
/// to `max_batch` within `batch_window`.  Returns false on shutdown
/// (queue closed AND drained); a closing queue still flushes what it
/// holds through one final batch.
fn collect_batch(queue: &BoundedQueue<Request>, pending: &mut Vec<Request>,
                 max_batch: usize, batch_window: Duration) -> bool {
    match queue.pop() {
        Some(r) => pending.push(r),
        None => return false, // closed and drained: worker exits
    }
    let deadline = Instant::now() + batch_window;
    while pending.len() < max_batch {
        match queue.pop_deadline(deadline) {
            Pop::Item(r) => pending.push(r),
            Pop::TimedOut => break,
            // execute what we have; the next collect_batch call exits
            Pop::Closed => break,
        }
    }
    true
}

fn record_batch(shard: &Mutex<ServerMetrics>, n: usize, exec_time: Duration,
                idle: Duration, hw: Option<&HwCost>) {
    let mut m = shard.lock().unwrap();
    m.batches += 1;
    m.images += n as u64;
    m.requests += n as u64;
    m.exec_lat.record(exec_time);
    m.busy_us += exec_time.as_micros() as u64;
    m.idle_us += idle.as_micros() as u64;
    if let Some(cost) = hw {
        m.record_hw(cost);
    }
}

/// Record latencies and deliver responses.  The replica's own metrics
/// shard is locked ONLY while recording the latency histograms — never
/// across the `respond.send` calls or the per-request logit clones —
/// and no other replica ever touches it, so a fleet's responders never
/// serialize on one global mutex.  When tracing, one `request` span per
/// request is recorded AFTER its response was sent: the span starts at
/// submit time, so it covers the full measured end-to-end latency.
fn respond_all(shard: &Mutex<ServerMetrics>, pending: &mut Vec<Request>,
               exec_start: Instant, hw: Option<HwCost>,
               trace: Option<&TraceHandle>,
               logits: impl Fn(usize) -> Vec<f32>) {
    let done: Vec<(Sender<Response>, Duration, Duration, Instant)> =
        pending.drain(..)
            .map(|r| {
                let queue_time = exec_start.duration_since(r.enqueued);
                let total_time = r.enqueued.elapsed();
                (r.respond, queue_time, total_time, r.enqueued)
            })
            .collect();
    {
        let mut m = shard.lock().unwrap();
        for (_, queue_time, total_time, _) in &done {
            m.queue_lat.record(*queue_time);
            m.e2e_lat.record(*total_time);
        }
    } // lock released before any send or logit clone
    for (i, (respond, queue_time, total_time, enqueued)) in
        done.into_iter().enumerate()
    {
        let _ = respond.send(Response {
            logits: logits(i),
            queue_time,
            total_time,
            hw,
        });
        if let Some(t) = trace {
            t.record("request", "serve", enqueued, enqueued.elapsed());
        }
    }
}

// ---------------------------------------------------------------------------
// Functional-sim backend (always available)
// ---------------------------------------------------------------------------

/// Serving configuration for one functional-sim variant.
#[derive(Debug, Clone)]
pub struct FunctionalVariantCfg {
    /// Route name clients submit to, e.g. "lenet5_adder".
    pub name: String,
    pub arch: Arch,
    pub kind: SimKernel,
    /// Inner-kernel strategy the variant's forward passes run under
    /// (`repro serve --kernel` / `ADDERNET_KERNEL` select it).
    pub strategy: KernelStrategy,
    /// Model parameters (manifest-loaded or synthetic).
    pub params: Params,
    /// f32 or quantized execution.  Quantized variants are compiled to
    /// a [`QuantPlan`] at [`start_functional`] time (weights quantized
    /// once, BN folded, activations i32 end-to-end through the conv
    /// stack) and served by the plan executor — never the per-call
    /// requantizing path.
    pub mode: ExecMode,
    /// Required when `mode` is quantized (`repro calibrate` produces
    /// one; a missing or incomplete table fails `start_functional`) —
    /// unless `plan` is set, which needs no calibration at all.
    pub calib: Option<Calibration>,
    /// Pre-compiled plan (the `repro serve --plan` cold-start path).
    /// When set, the worker serves THIS plan directly — `calib` is not
    /// consulted, no calibration pass runs, and `params` are unused on
    /// the quantized path (the quantized weights live in the plan).
    /// `start_functional` validates that `arch`/`kind` match the plan
    /// and that `mode` is `ExecMode::Quant(plan.cfg)`.
    pub plan: Option<QuantPlan>,
    /// Hw-sim backend: PE-array lanes of the simulated accelerator
    /// (`repro serve --backend hwsim`, default
    /// [`hwsim::DEFAULT_PARALLELISM`]).  Requires a plan-backed variant
    /// (quantized mode or a mounted plan) — the per-image schedule is
    /// precomputed at startup and is swap-invariant because `swap_plan`
    /// pins (arch, kernel, quant config).  `None` serves without a
    /// hardware model.
    pub hw_parallelism: Option<u64>,
    /// Input (h, w, c); requests must carry h*w*c floats.
    pub input_hwc: (usize, usize, usize),
    /// Dynamic-batch cap (the functional engine takes any batch size;
    /// this bounds per-batch latency).
    pub max_batch: usize,
    /// Replica workers draining this variant's queue (`--replicas`).
    /// Replicas share the persistent engine pool, so they scale
    /// batch-collection concurrency, not raw thread count.
    pub replicas: usize,
    /// Bounded queue depth; a full queue load-sheds at `submit`
    /// ([`SubmitError::Overloaded`]) instead of queueing unboundedly.
    pub queue_depth: usize,
}

impl FunctionalVariantCfg {
    /// Variant backed by deterministic synthetic weights — lets the
    /// server run with no Python artifacts (demos, tests, load rigs).
    /// Input geometry comes from the architecture's compiled graph, so
    /// any registered `Arch` serves without further configuration.
    pub fn synthetic(name: &str, arch: Arch, kind: SimKernel, seed: u64) -> Self {
        Self {
            name: name.into(),
            arch,
            kind,
            strategy: KernelStrategy::Auto,
            params: functional::synth_params(arch, seed),
            mode: ExecMode::F32,
            calib: None,
            plan: None,
            hw_parallelism: None,
            input_hwc: arch.graph().input,
            max_batch: 32,
            replicas: 1,
            queue_depth: DEFAULT_QUEUE_DEPTH,
        }
    }
}

/// Per-worker immutable config, shared by a variant's replicas.
struct WorkerCfg {
    name: String,
    arch: Arch,
    kind: SimKernel,
    strategy: KernelStrategy,
    params: Params,
    input_hwc: (usize, usize, usize),
    max_batch: usize,
    /// Precomputed per-image accelerator cost (hwsim backend).
    hw_cost: Option<HwCost>,
}

/// Start the functional-sim server: `replicas` worker threads per
/// variant, all draining one bounded per-variant queue.
///
/// Quantized variants are compiled here, up front: building the
/// [`QuantPlan`] validates the calibration table against the model
/// (every conv layer must be covered) and quantizes the weights ONCE —
/// a misconfigured variant therefore fails this call with a proper
/// error instead of panicking a worker thread later.
pub fn start_functional(variants: Vec<FunctionalVariantCfg>,
                        batch_window: Duration) -> Result<ServerHandle> {
    start_functional_observed(variants, batch_window, None)
}

/// [`start_functional`] with request tracing: every worker takes a
/// [`TraceHandle`] on the sink and records `collect`/`exec`/`batch`/
/// per-layer/`request` spans while serving (`repro serve --trace-out`).
pub fn start_functional_observed(variants: Vec<FunctionalVariantCfg>,
                                 batch_window: Duration,
                                 trace: Option<Arc<TraceSink>>)
                                 -> Result<ServerHandle> {
    // An empty variant list must be a startup ERROR, not a silently
    // idle server: callers that filtered every requested variant away
    // (e.g. unservable quant widths) would otherwise green-light a
    // server that can answer nothing.
    anyhow::ensure!(!variants.is_empty(),
                    "no variants to serve (every requested variant was \
                     filtered out, or the model list is empty)");
    let mut routes: HashMap<String, Arc<VariantState>> = HashMap::new();
    let mut workers = Vec::new();
    for mut v in variants {
        anyhow::ensure!(v.max_batch > 0, "variant {}: max_batch must be > 0", v.name);
        anyhow::ensure!(v.replicas > 0, "variant {}: replicas must be > 0", v.name);
        anyhow::ensure!(v.queue_depth > 0,
                        "variant {}: queue_depth must be > 0", v.name);
        let plan = match (v.plan.take(), v.mode) {
            // imported plan: already compiled and validated layer-by-
            // layer against its arch graph; just check it was mounted on
            // a variant that declares the SAME serving config (else the
            // metrics/CLI would claim one mode while the worker serves
            // another).
            (Some(p), mode) => {
                anyhow::ensure!(
                    p.arch == v.arch && p.kind == v.kind,
                    "variant {}: mounted plan was compiled for {}/{}, not \
                     {}/{}", v.name, p.arch.name(), p.kind.label(),
                    v.arch.name(), v.kind.label());
                anyhow::ensure!(
                    matches!(mode, ExecMode::Quant(cfg) if cfg == p.cfg),
                    "variant {}: mounts an int{} plan but declares mode \
                     {:?} — set mode to ExecMode::Quant(plan.cfg)",
                    v.name, p.cfg.bits, mode);
                Some(p)
            }
            (None, ExecMode::F32) => None,
            (None, ExecMode::Quant(cfg)) => {
                let calib = v.calib.as_ref().ok_or_else(|| anyhow::anyhow!(
                    "variant {}: quantized mode requires a calibration table \
                     (run `repro calibrate`, serve with --calib, or mount a \
                     compiled plan via --plan)", v.name))?;
                Some(QuantPlan::build(&v.params, v.arch, v.kind, cfg, calib)
                    .with_context(|| format!(
                        "variant {}: compiling the quantization plan", v.name))?)
            }
        };
        // hwsim: price the variant's schedule ONCE — swap_plan pins
        // (arch, kind, cfg), so the cost model cannot be invalidated by
        // a hot-swap.  An f32 variant has no integer datapath to
        // schedule; refuse it here rather than serving cost-free.
        let hw_cost = match v.hw_parallelism {
            None => None,
            Some(p) => {
                let plan_ref = plan.as_ref().ok_or_else(|| anyhow::anyhow!(
                    "variant {}: the hwsim backend executes compiled plans — \
                     serve a quantized mode or mount one with --plan \
                     (f32 variants have no hardware schedule)", v.name))?;
                Some(hwsim::per_image_cost(plan_ref, p).with_context(|| {
                    format!("variant {}: building the accelerator schedule",
                            v.name)
                })?)
            }
        };
        let (h, w, c) = v.input_hwc;
        let state = Arc::new(VariantState {
            name: v.name.clone(),
            queue: BoundedQueue::new(v.queue_depth),
            px: h * w * c,
            plan: plan.map(|p| Mutex::new(Arc::new(p))),
            shards: shard_list(v.replicas),
            inflight: AtomicU64::new(0),
        });
        // a duplicate name would silently replace the first variant's
        // route (its workers exit on close while the CLI reports both
        // as serving) — refuse at startup instead
        anyhow::ensure!(
            routes.insert(v.name.clone(), Arc::clone(&state)).is_none(),
            "duplicate variant name {} (e.g. the same plan file listed \
             twice)", v.name);
        let replicas = v.replicas;
        let wcfg = Arc::new(WorkerCfg {
            name: v.name.clone(),
            arch: v.arch,
            kind: v.kind,
            strategy: v.strategy,
            params: std::mem::take(&mut v.params),
            input_hwc: v.input_hwc,
            max_batch: v.max_batch,
            hw_cost,
        });
        for r in 0..replicas {
            let wcfg = Arc::clone(&wcfg);
            let state = Arc::clone(&state);
            let shard = Arc::clone(&state.shards[r + 1]);
            let sink = trace.clone();
            workers.push(std::thread::Builder::new()
                .name(format!("fsim-{}-r{r}", wcfg.name))
                .spawn(move || {
                    let th = sink.as_ref()
                        .map(|s| s.handle(&format!("fsim-{}-r{r}", wcfg.name)));
                    functional_worker(&wcfg, &state, &shard, th.as_ref(),
                                      batch_window)
                })?);
        }
    }
    Ok(ServerHandle {
        variants: routes,
        workers: Mutex::new(workers),
        trace,
    })
}

fn functional_worker(cfg: &WorkerCfg, state: &VariantState,
                     shard: &MetricsShard, trace: Option<&TraceHandle>,
                     batch_window: Duration) {
    let mut pending: Vec<Request> = Vec::with_capacity(cfg.max_batch);
    loop {
        let wait_start = Instant::now();
        if !collect_batch(&state.queue, &mut pending, cfg.max_batch, batch_window) {
            return;
        }
        let idle = wait_start.elapsed();
        if let Some(t) = trace {
            t.record("collect", "serve", wait_start, idle);
        }
        state.inflight.fetch_add(1, Ordering::Relaxed);
        let n = pending.len();
        let exec_start = Instant::now();
        let images: Vec<&[f32]> = pending.iter().map(|r| r.image.as_slice()).collect();
        let logits = match state.plan.as_ref() {
            // int serving: the pre-compiled plan keeps activations i32
            // across the conv stack; no per-call weight requantization.
            // Take the CURRENT plan Arc — a concurrent swap_plan
            // becomes visible at the next batch boundary.
            Some(slot) => {
                let plan = Arc::clone(&slot.lock().unwrap());
                let runner =
                    PlanRunner { plan: plan.as_ref(), strategy: cfg.strategy };
                match trace {
                    Some(t) => {
                        let mut obs = TraceObserver { trace: t };
                        runner.forward_many_observed(&images, cfg.input_hwc,
                                                     &mut obs)
                    }
                    None => runner.forward_many(&images, cfg.input_hwc),
                }
            }
            None => {
                let mut runner = Runner {
                    params: &cfg.params,
                    arch: cfg.arch,
                    kind: cfg.kind,
                    strategy: cfg.strategy,
                    mode: ExecMode::F32,
                    calib: None,
                    observe: None,
                };
                match trace {
                    Some(t) => {
                        let mut obs = TraceObserver { trace: t };
                        runner.forward_many_observed(&images, cfg.input_hwc,
                                                     &mut obs)
                    }
                    None => runner.forward_many(&images, cfg.input_hwc),
                }
            }
        };
        drop(images);
        let exec_time = exec_start.elapsed();
        if let Some(t) = trace {
            t.record("exec", "serve", exec_start, exec_time);
        }
        let batch_hw = cfg.hw_cost.map(|c| c.scale(n));
        record_batch(shard, n, exec_time, idle, batch_hw.as_ref());
        respond_all(shard, &mut pending, exec_start, cfg.hw_cost, trace,
                    |i| logits[i].clone());
        if let Some(t) = trace {
            // exec + respond for this batch: contains the exec span
            t.record("batch", "serve", exec_start, exec_start.elapsed());
        }
        state.inflight.fetch_sub(1, Ordering::Relaxed);
    }
}

// ---------------------------------------------------------------------------
// PJRT backend (`pjrt` feature)
// ---------------------------------------------------------------------------

/// Serving configuration for one PJRT graph variant.
#[cfg(feature = "pjrt")]
#[derive(Debug, Clone)]
pub struct VariantCfg {
    /// Graph base name, e.g. "lenet5_adder".
    pub model: String,
    /// Optional trained-weights file (relative to artifacts/); falls back
    /// to the init file.
    pub weights: Option<String>,
}

/// Start the PJRT server: one worker thread per variant.  Input
/// geometry is derived from each variant's eval graph in the manifest
/// (the arch's compiled graph names the (h, w, c) input), never
/// hardcoded; duplicate variant names are refused like
/// [`start_functional`] does.
#[cfg(feature = "pjrt")]
pub fn start(manifest: &Manifest, variants: &[VariantCfg],
             batch_window: Duration) -> Result<ServerHandle> {
    anyhow::ensure!(!variants.is_empty(), "no variants to serve");
    let mut routes: HashMap<String, Arc<VariantState>> = HashMap::new();
    let mut workers = Vec::new();
    for v in variants {
        let gname = format!("{}_eval", v.model);
        let ginfo = manifest.graph(&gname)?;
        let arch = Arch::parse(&ginfo.arch).with_context(|| format!(
            "variant {}: manifest arch {} is not a registered servable arch \
             ({})", v.model, ginfo.arch, Arch::names_label()))?;
        let input_hwc = arch.graph().input;
        let (h, w, c) = input_hwc;
        let state = Arc::new(VariantState {
            name: v.model.clone(),
            queue: BoundedQueue::new(DEFAULT_QUEUE_DEPTH),
            px: h * w * c,
            plan: None,
            shards: shard_list(1),
            inflight: AtomicU64::new(0),
        });
        anyhow::ensure!(
            routes.insert(v.model.clone(), Arc::clone(&state)).is_none(),
            "duplicate variant name {} (listed twice in --models?)", v.model);
        let shard = Arc::clone(&state.shards[1]);
        let man = manifest.clone();
        let cfg = v.clone();
        workers.push(std::thread::Builder::new()
            .name(format!("worker-{}", v.model))
            .spawn(move || {
                if let Err(e) = pjrt_worker(man, &cfg, &state, input_hwc,
                                            &shard, batch_window) {
                    eprintln!("[server] worker {} failed: {e:#}", cfg.model);
                }
            })?);
    }
    Ok(ServerHandle {
        variants: routes,
        workers: Mutex::new(workers),
        trace: None,
    })
}

#[cfg(feature = "pjrt")]
fn pjrt_worker(manifest: Manifest, cfg: &VariantCfg, state: &VariantState,
               input_hwc: (usize, usize, usize), shard: &MetricsShard,
               batch_window: Duration) -> Result<()> {
    // PJRT handles are not Send: the runtime lives and dies in this thread.
    let mut rt = Runtime::new(manifest.dir.clone())?;
    let gname = format!("{}_eval", cfg.model);
    let ginfo = manifest.graph(&gname)?.clone();
    rt.load(&gname, &ginfo.file)?;
    let batch = ginfo.batch;
    let (h, w, c) = input_hwc;
    let px = h * w * c;

    // model params: trained weights if configured, else init
    let layout = manifest.layout(&ginfo.arch)?;
    let wfile = cfg.weights.clone().unwrap_or_else(|| layout.init_file.clone());
    let init = manifest.read_param_file(&ginfo.arch, &wfile)?;
    let params: Vec<xla::Literal> = init.iter()
        .map(|(_, shape, data)| runtime::literal_f32(shape, data))
        .collect::<Result<_>>()?;

    let mut pending: Vec<Request> = Vec::with_capacity(batch);
    loop {
        let wait_start = Instant::now();
        if !collect_batch(&state.queue, &mut pending, batch, batch_window) {
            return Ok(());
        }
        let idle = wait_start.elapsed();
        // assemble the fixed-size batch (pad with zeros)
        let n = pending.len();
        let mut images = vec![0f32; batch * px];
        for (i, r) in pending.iter().enumerate() {
            images[i * px..(i + 1) * px].copy_from_slice(&r.image);
        }
        let exec_start = Instant::now();
        let x = runtime::literal_f32(&[batch, h, w, c], &images)?;
        let mut inputs: Vec<&xla::Literal> = params.iter().collect();
        inputs.push(&x);
        let outs = rt.execute(&gname, &inputs)?;
        let logits = runtime::to_vec_f32(&outs[0])?;
        let exec_time = exec_start.elapsed();

        record_batch(shard, n, exec_time, idle, None);
        respond_all(shard, &mut pending, exec_start, None, None,
                    |i| logits[i * 10..(i + 1) * 10].to_vec());
    }
}
