//! Bounded multi-producer / multi-consumer request queue — the
//! admission-control primitive of the serving fleet.
//!
//! `std::sync::mpsc` channels are unbounded and single-consumer: under
//! overload they queue without limit (latency grows until the process
//! dies), and a `Receiver` cannot be shared by N replica workers.  This
//! queue fixes both:
//!
//! * **bounded depth** — [`BoundedQueue::push`] never blocks and never
//!   queues past `capacity`; a full queue sheds the item back to the
//!   caller as [`PushError::Full`] so the submitter gets an explicit
//!   `Overloaded` error instead of unbounded latency;
//! * **MPMC** — any number of replica workers block in
//!   [`BoundedQueue::pop`] / [`BoundedQueue::pop_deadline`] on the same
//!   queue; each item is claimed by exactly one worker;
//! * **drain-on-close** — [`BoundedQueue::close`] refuses new pushes
//!   but lets poppers empty what was already admitted, so a server
//!   shutdown still answers every in-flight request before the workers
//!   exit.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Instant;

/// Why a push was refused.  The shed item rides along so callers can
/// recover it without a clone.
pub enum PushError<T> {
    /// Admission control: the queue already holds `capacity` items.
    Full(T),
    /// The queue was closed (server shutdown).
    Closed(T),
}

/// Outcome of a deadline-bounded pop.
pub enum Pop<T> {
    Item(T),
    /// The deadline passed with the queue empty (and still open).
    TimedOut,
    /// The queue is closed AND drained — the worker should exit.
    Closed,
}

struct State<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// Bounded MPMC queue: `Mutex<VecDeque>` + condvar.  The serving hot
/// path holds the lock only for a push/pop of one element, so worker
/// contention is bounded by queue churn, never by inference time.
pub struct BoundedQueue<T> {
    state: Mutex<State<T>>,
    readers: Condvar,
    capacity: usize,
}

impl<T> BoundedQueue<T> {
    pub fn new(capacity: usize) -> BoundedQueue<T> {
        assert!(capacity > 0, "queue capacity must be positive");
        BoundedQueue {
            state: Mutex::new(State { items: VecDeque::new(), closed: false }),
            readers: Condvar::new(),
            capacity,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Currently queued (admitted, not yet claimed) items.
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Non-blocking admission: enqueue `item`, or shed it when the
    /// queue is full or closed.  Never waits.
    pub fn push(&self, item: T) -> Result<(), PushError<T>> {
        let mut st = self.state.lock().unwrap();
        if st.closed {
            return Err(PushError::Closed(item));
        }
        if st.items.len() >= self.capacity {
            return Err(PushError::Full(item));
        }
        st.items.push_back(item);
        drop(st);
        self.readers.notify_one();
        Ok(())
    }

    /// Blocking pop: waits until an item is available or the queue is
    /// closed and drained (`None` — the worker-exit signal).
    pub fn pop(&self) -> Option<T> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(x) = st.items.pop_front() {
                return Some(x);
            }
            if st.closed {
                return None;
            }
            st = self.readers.wait(st).unwrap();
        }
    }

    /// Pop with a deadline — the batch-window primitive.  Items still
    /// queued when the queue closes are drained before `Closed` is
    /// reported.
    pub fn pop_deadline(&self, deadline: Instant) -> Pop<T> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(x) = st.items.pop_front() {
                return Pop::Item(x);
            }
            if st.closed {
                return Pop::Closed;
            }
            let now = Instant::now();
            if now >= deadline {
                return Pop::TimedOut;
            }
            let (guard, timeout) =
                self.readers.wait_timeout(st, deadline - now).unwrap();
            st = guard;
            if timeout.timed_out() {
                // One re-check after expiry: an item may have landed in
                // the wake-up race, and a close must still drain first.
                if let Some(x) = st.items.pop_front() {
                    return Pop::Item(x);
                }
                if st.closed {
                    return Pop::Closed;
                }
                return Pop::TimedOut;
            }
        }
    }

    /// Close the queue: every later push is refused, every queued item
    /// is still handed to poppers, and blocked poppers wake up.
    pub fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        drop(st);
        self.readers.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn push_pop_fifo() {
        let q = BoundedQueue::new(8);
        for i in 0..5 {
            q.push(i).map_err(|_| ()).unwrap();
        }
        assert_eq!(q.len(), 5);
        for i in 0..5 {
            assert_eq!(q.pop(), Some(i));
        }
        assert!(q.is_empty());
    }

    #[test]
    fn full_queue_sheds_instead_of_queueing() {
        let q = BoundedQueue::new(2);
        q.push(1).map_err(|_| ()).unwrap();
        q.push(2).map_err(|_| ()).unwrap();
        match q.push(3) {
            Err(PushError::Full(v)) => assert_eq!(v, 3),
            _ => panic!("third push must shed"),
        }
        // popping frees capacity again
        assert_eq!(q.pop(), Some(1));
        q.push(3).map_err(|_| ()).unwrap();
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn close_refuses_pushes_but_drains_poppers() {
        let q = BoundedQueue::new(4);
        q.push(10).map_err(|_| ()).unwrap();
        q.push(11).map_err(|_| ()).unwrap();
        q.close();
        match q.push(12) {
            Err(PushError::Closed(v)) => assert_eq!(v, 12),
            _ => panic!("push after close must be refused"),
        }
        // already-admitted items still drain, then poppers see the end
        assert_eq!(q.pop(), Some(10));
        assert_eq!(q.pop(), Some(11));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn pop_deadline_times_out_on_empty_open_queue() {
        let q: BoundedQueue<u32> = BoundedQueue::new(4);
        let t0 = Instant::now();
        match q.pop_deadline(t0 + Duration::from_millis(20)) {
            Pop::TimedOut => {}
            _ => panic!("empty open queue must time out"),
        }
        assert!(t0.elapsed() >= Duration::from_millis(20));
    }

    #[test]
    fn pop_unblocks_on_concurrent_push_and_close() {
        let q = std::sync::Arc::new(BoundedQueue::new(4));
        let q2 = std::sync::Arc::clone(&q);
        let popper = std::thread::spawn(move || {
            let mut got = Vec::new();
            while let Some(v) = q2.pop() {
                got.push(v);
            }
            got
        });
        std::thread::sleep(Duration::from_millis(10));
        q.push(7).map_err(|_| ()).unwrap();
        std::thread::sleep(Duration::from_millis(10));
        q.close();
        assert_eq!(popper.join().unwrap(), vec![7]);
    }

    #[test]
    fn mpmc_each_item_claimed_once() {
        const N: usize = 200;
        let q = std::sync::Arc::new(BoundedQueue::new(N));
        let workers: Vec<_> = (0..4)
            .map(|_| {
                let q = std::sync::Arc::clone(&q);
                std::thread::spawn(move || {
                    let mut got = Vec::new();
                    while let Some(v) = q.pop() {
                        got.push(v);
                    }
                    got
                })
            })
            .collect();
        for i in 0..N {
            q.push(i).map_err(|_| ()).unwrap();
        }
        q.close();
        let mut all: Vec<usize> = workers
            .into_iter()
            .flat_map(|w| w.join().unwrap())
            .collect();
        all.sort_unstable();
        assert_eq!(all, (0..N).collect::<Vec<_>>());
    }
}
