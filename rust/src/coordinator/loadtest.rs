//! Open-loop load-test harness (`repro loadtest`) — synthetic traffic
//! at a controlled QPS against a live [`ServerHandle`], with
//! p50/p99/shed-rate persisted to JSON so serving regressions are
//! CI-gateable like the kernel ratios.
//!
//! The driver is **open-loop**: request `i` is scheduled at
//! `t0 + i/qps` regardless of how fast responses come back, which is
//! what exposes queueing collapse — a closed-loop driver (submit, wait,
//! repeat) self-throttles to the server's capacity and can never
//! observe overload.  Shed requests are NEVER retried: the shed rate at
//! a given QPS is the measurement, not an error to paper over.
//!
//! Latencies are taken from [`Response::total_time`] (stamped by the
//! server between enqueue and response assembly), so the collector
//! thread's drain order cannot skew the histograms.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::mpsc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::metrics::LatencyHistogram;
use super::server::{Response, ServerHandle, SubmitError};
use crate::util::json::Json;
use crate::util::threads;

pub const SCHEMA: &str = "addernet-loadtest-v1";

/// Load profile for one run.
#[derive(Debug, Clone)]
pub struct LoadtestCfg {
    /// Aggregate request rate across all variants (round-robin).
    pub qps: f64,
    pub duration: Duration,
    /// Replica count the server was started with — recorded in the
    /// report (the harness itself does not spawn servers).
    pub replicas: usize,
}

/// Per-variant outcome counters; `sent == ok + shed + rejected + errors`.
#[derive(Debug, Clone, Default)]
pub struct VariantOutcome {
    pub sent: u64,
    pub ok: u64,
    /// Admission-control sheds (`SubmitError::Overloaded`).
    pub shed: u64,
    /// Malformed-request rejects (`SubmitError::BadRequest`) — a
    /// harness bug if nonzero, kept separate from `errors` so the
    /// report says so.
    pub rejected: u64,
    /// Everything that should never happen under load: unknown
    /// variants, shutdown errors, dropped response channels.
    pub errors: u64,
    /// Peak queue depth observed at submit time — how deep the variant's
    /// bounded queue got under this load.
    pub peak_queue: u64,
    /// End-to-end latency of `ok` responses.
    pub lat: LatencyHistogram,
}

impl VariantOutcome {
    pub fn shed_rate(&self) -> f64 {
        if self.sent == 0 { 0.0 } else { self.shed as f64 / self.sent as f64 }
    }
}

#[derive(Debug, Clone)]
pub struct LoadtestReport {
    pub requested_qps: f64,
    pub achieved_qps: f64,
    pub wall: Duration,
    /// Persistent engine-pool workers the replicas shared.
    pub pool_workers: usize,
    pub replicas: usize,
    pub variants: BTreeMap<String, VariantOutcome>,
}

/// Deterministic synthetic image — the load generator must not depend
/// on artifacts or RNG state (same traffic every run, every machine).
fn synth_image(px: usize, i: u64) -> Vec<f32> {
    (0..px)
        .map(|j| {
            let v = (i.wrapping_mul(31).wrapping_add(j as u64 * 7)) % 97;
            v as f32 / 97.0 - 0.5
        })
        .collect()
}

/// Drive `cfg.qps` of round-robin traffic at `handle` for
/// `cfg.duration`.  Returns the merged outcome; the handle stays up
/// (callers own startup/shutdown, so one server can be probed at
/// several rates).
pub fn run(handle: &ServerHandle, variants: &[String],
           cfg: &LoadtestCfg) -> Result<LoadtestReport> {
    anyhow::ensure!(!variants.is_empty(), "loadtest needs at least one variant");
    anyhow::ensure!(cfg.qps > 0.0, "qps must be > 0");
    let total = ((cfg.qps * cfg.duration.as_secs_f64()).round() as u64).max(1);

    // one image per variant is enough: submit clones it
    let mut images = Vec::with_capacity(variants.len());
    for (vi, v) in variants.iter().enumerate() {
        let px = handle.input_len(v)
            .with_context(|| format!("variant {v} is not served by this handle"))?;
        images.push(synth_image(px, vi as u64));
    }

    // the collector drains response receivers off the submit path so a
    // slow response never stalls the open-loop schedule
    let (cx, crx) = mpsc::channel::<(usize, mpsc::Receiver<Response>)>();
    let nvar = variants.len();
    let collector = std::thread::spawn(move || {
        let mut out: Vec<VariantOutcome> = vec![VariantOutcome::default(); nvar];
        while let Ok((vi, rx)) = crx.recv() {
            match rx.recv() {
                Ok(resp) => {
                    out[vi].ok += 1;
                    out[vi].lat.record(resp.total_time);
                }
                // worker died / response channel dropped: a real error,
                // never silently absorbed
                Err(_) => out[vi].errors += 1,
            }
        }
        out
    });

    let mut submit_side: Vec<VariantOutcome> = vec![VariantOutcome::default(); nvar];
    let t0 = Instant::now();
    for i in 0..total {
        // open loop: request i fires at t0 + i/qps, behind or not
        let target = t0 + Duration::from_secs_f64(i as f64 / cfg.qps);
        let now = Instant::now();
        if target > now {
            std::thread::sleep(target - now);
        }
        let vi = (i as usize) % nvar;
        submit_side[vi].sent += 1;
        let depth = handle.queue_depth(&variants[vi]).unwrap_or(0) as u64;
        submit_side[vi].peak_queue = submit_side[vi].peak_queue.max(depth);
        match handle.submit(&variants[vi], images[vi].clone()) {
            Ok(rx) => {
                // collector gone (panic) => count as error below via join
                let _ = cx.send((vi, rx));
            }
            Err(SubmitError::Overloaded { .. }) => submit_side[vi].shed += 1,
            Err(SubmitError::BadRequest { .. }) => submit_side[vi].rejected += 1,
            Err(_) => submit_side[vi].errors += 1,
        }
    }
    drop(cx); // collector drains the in-flight tail, then exits
    let collected = collector.join()
        .map_err(|_| anyhow::anyhow!("loadtest collector thread panicked"))?;
    let wall = t0.elapsed();

    let mut out = BTreeMap::new();
    for (vi, v) in variants.iter().enumerate() {
        let mut o = submit_side[vi].clone();
        o.ok = collected[vi].ok;
        o.errors += collected[vi].errors;
        o.lat = collected[vi].lat.clone();
        out.insert(v.clone(), o);
    }
    Ok(LoadtestReport {
        requested_qps: cfg.qps,
        achieved_qps: total as f64 / wall.as_secs_f64().max(1e-9),
        wall,
        pool_workers: threads::pool_workers(),
        replicas: cfg.replicas,
        variants: out,
    })
}

impl LoadtestReport {
    /// Hand-assembled JSON (no serializer is vendored); keys and shape
    /// are part of the CI artifact contract, checked by [`check`].
    pub fn to_json(&self) -> String {
        let mut ventries = Vec::new();
        for (name, o) in &self.variants {
            ventries.push(format!(
                "    \"{name}\": {{\"sent\": {}, \"ok\": {}, \"shed\": {}, \
                 \"rejected\": {}, \"errors\": {}, \"shed_rate\": {:.4}, \
                 \"peak_queue\": {}, \"p50_us\": {}, \"p99_us\": {}, \
                 \"max_us\": {}, \"mean_us\": {:.1}}}",
                o.sent, o.ok, o.shed, o.rejected, o.errors, o.shed_rate(),
                o.peak_queue, o.lat.quantile_us(0.5), o.lat.quantile_us(0.99),
                o.lat.max_us(), o.lat.mean_us()));
        }
        format!(
            "{{\n  \"schema\": \"{SCHEMA}\",\n  \"requested_qps\": {:.1},\n  \
             \"achieved_qps\": {:.1},\n  \"wall_s\": {:.3},\n  \
             \"pool_workers\": {},\n  \"replicas\": {},\n  \"variants\": {{\n{}\n  }}\n}}\n",
            self.requested_qps, self.achieved_qps, self.wall.as_secs_f64(),
            self.pool_workers, self.replicas, ventries.join(",\n"))
    }

    pub fn write_json(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json())
            .with_context(|| format!("writing {}", path.display()))?;
        Ok(())
    }
}

/// Optional SLO bounds for [`check`] (`repro loadtest check
/// --p99-slo-ms --max-shed-rate`).  `None` fields gate nothing beyond
/// the structural checks.
#[derive(Debug, Clone, Copy, Default)]
pub struct CheckSlo {
    /// Fail any variant whose p99 end-to-end latency exceeds this, ms.
    pub p99_slo_ms: Option<f64>,
    /// Fail any variant whose shed rate exceeds this fraction.
    pub max_shed_rate: Option<f64>,
}

/// CI gate over a persisted report (`repro loadtest check --file`):
/// every variant must show zero errors, at least one OK response, and a
/// nonzero p99 — a run that shed 100% or answered nothing fails loudly.
/// `slo` optionally adds p99-latency and shed-rate ceilings.
pub fn check(path: &Path, slo: &CheckSlo) -> Result<()> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let j = Json::parse(&text)
        .map_err(|e| anyhow::anyhow!("parsing {}: {e:?}", path.display()))?;
    let schema = j.at(&["schema"]).and_then(Json::as_str).unwrap_or("");
    anyhow::ensure!(schema == SCHEMA,
                    "{}: schema {schema:?}, expected {SCHEMA:?}", path.display());
    let vnode = j.at(&["variants"])
        .ok_or_else(|| anyhow::anyhow!("{}: no variants object", path.display()))?;
    // A report whose every variant was skipped serializes an EMPTY
    // variants container — that is an all-skipped drive and must fail
    // the gate, whether the writer emitted `{}` or `[]`.
    if let Json::Arr(items) = vnode {
        anyhow::ensure!(!items.is_empty(),
                        "{}: empty variants array — an all-skipped drive \
                         must fail the gate", path.display());
        anyhow::bail!("{}: variants must be an object keyed by variant name, \
                       not an array", path.display());
    }
    let vars = vnode.as_obj()
        .ok_or_else(|| anyhow::anyhow!("{}: no variants object", path.display()))?;
    anyhow::ensure!(!vars.is_empty(),
                    "{}: empty variants object — an all-skipped drive must \
                     fail the gate", path.display());
    for (name, v) in vars {
        let num = |k: &str| -> Result<f64> {
            v.at(&[k]).and_then(Json::as_f64).ok_or_else(|| anyhow::anyhow!(
                "{}: variant {name} missing numeric {k}", path.display()))
        };
        let (ok, errors, rejected) = (num("ok")?, num("errors")?, num("rejected")?);
        let p99 = num("p99_us")?;
        let shed_rate = v.at(&["shed_rate"]).and_then(Json::as_f64).unwrap_or(0.0);
        anyhow::ensure!(errors == 0.0, "variant {name}: {errors} errors");
        anyhow::ensure!(rejected == 0.0,
                        "variant {name}: {rejected} malformed-request rejects");
        anyhow::ensure!(ok > 0.0, "variant {name}: no OK responses");
        anyhow::ensure!(p99 > 0.0, "variant {name}: p99 is 0µs — latencies \
                                    were not recorded");
        if let Some(slo_ms) = slo.p99_slo_ms {
            anyhow::ensure!(p99 <= slo_ms * 1000.0,
                            "variant {name}: p99 {p99}µs exceeds the \
                             {slo_ms}ms SLO");
        }
        if let Some(max) = slo.max_shed_rate {
            anyhow::ensure!(shed_rate <= max,
                            "variant {name}: shed rate {shed_rate:.4} exceeds \
                             the {max:.4} ceiling");
        }
        println!("loadtest check: {name} OK (ok={ok}, shed_rate={shed_rate}, \
                  p99={p99}µs)");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_report() -> LoadtestReport {
        let mut lat = LatencyHistogram::new();
        for us in [400u64, 900, 1500] {
            lat.record(Duration::from_micros(us));
        }
        let mut variants = BTreeMap::new();
        variants.insert("lenet5_adder".to_string(), VariantOutcome {
            sent: 5, ok: 3, shed: 2, rejected: 0, errors: 0, peak_queue: 4,
            lat,
        });
        LoadtestReport {
            requested_qps: 200.0,
            achieved_qps: 198.5,
            wall: Duration::from_millis(2500),
            pool_workers: 7,
            replicas: 2,
            variants,
        }
    }

    #[test]
    fn report_json_roundtrip_passes_check() {
        let r = sample_report();
        let j = Json::parse(&r.to_json()).expect("report JSON parses");
        assert_eq!(j.at(&["schema"]).and_then(Json::as_str), Some(SCHEMA));
        assert_eq!(j.at(&["variants", "lenet5_adder", "shed"])
                       .and_then(Json::as_usize), Some(2));
        let p99 = j.at(&["variants", "lenet5_adder", "p99_us"])
            .and_then(Json::as_f64).unwrap();
        assert!(p99 > 0.0 && p99 <= 1500.0, "p99 {p99} must be clamped to max");
        assert_eq!(j.at(&["variants", "lenet5_adder", "peak_queue"])
                       .and_then(Json::as_usize), Some(4));
        let path = std::env::temp_dir()
            .join(format!("addernet-loadtest-{}.json", std::process::id()));
        r.write_json(&path).unwrap();
        check(&path, &CheckSlo::default()).expect("clean report passes the gate");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn slo_bounds_gate_p99_and_shed_rate() {
        let r = sample_report(); // p99 1500µs, shed rate 0.4
        let path = std::env::temp_dir()
            .join(format!("addernet-loadtest-slo-{}.json", std::process::id()));
        r.write_json(&path).unwrap();
        let loose = CheckSlo { p99_slo_ms: Some(10.0), max_shed_rate: Some(0.5) };
        check(&path, &loose).expect("within SLO must pass");
        let tight_lat = CheckSlo { p99_slo_ms: Some(0.001), max_shed_rate: None };
        assert!(check(&path, &tight_lat).is_err(), "p99 over SLO must fail");
        let tight_shed = CheckSlo { p99_slo_ms: None, max_shed_rate: Some(0.1) };
        assert!(check(&path, &tight_shed).is_err(),
                "shed rate over ceiling must fail");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn check_rejects_errors_and_empty_runs() {
        let mut r = sample_report();
        r.variants.get_mut("lenet5_adder").unwrap().errors = 1;
        let path = std::env::temp_dir()
            .join(format!("addernet-loadtest-bad-{}.json", std::process::id()));
        r.write_json(&path).unwrap();
        assert!(check(&path, &CheckSlo::default()).is_err(),
                "errors > 0 must fail the gate");
        let mut r = sample_report();
        r.variants.get_mut("lenet5_adder").unwrap().ok = 0;
        r.write_json(&path).unwrap();
        assert!(check(&path, &CheckSlo::default()).is_err(),
                "ok == 0 must fail the gate");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn check_rejects_empty_variants_in_every_spelling() {
        // An all-skipped drive serializes no variant outcomes.  Every
        // shape that can reach disk — `{}`, `[]`, or a missing key —
        // must hard-error, never pass as "nothing to check".
        let dir = std::env::temp_dir();
        let pid = std::process::id();
        for (tag, variants) in [("obj", "{}"), ("arr", "[]")] {
            let path = dir.join(format!("addernet-loadtest-empty-{tag}-{pid}.json"));
            let doc = format!(
                "{{\"schema\": \"{SCHEMA}\", \"requested_qps\": 100, \
                 \"achieved_qps\": 0, \"wall_ms\": 10, \"pool_workers\": 1, \
                 \"replicas\": 1, \"variants\": {variants}}}");
            std::fs::write(&path, doc).unwrap();
            let err = check(&path, &CheckSlo::default())
                .expect_err("empty variants must fail the gate");
            assert!(format!("{err:#}").contains("empty variants"),
                    "[{tag}] error should name the empty container: {err:#}");
            std::fs::remove_file(&path).ok();
        }
        let path = dir.join(format!("addernet-loadtest-novariants-{pid}.json"));
        std::fs::write(&path, format!("{{\"schema\": \"{SCHEMA}\"}}")).unwrap();
        assert!(check(&path, &CheckSlo::default()).is_err(),
                "missing variants key must fail the gate");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn shed_rate_math() {
        let o = VariantOutcome { sent: 8, shed: 2, ..Default::default() };
        assert!((o.shed_rate() - 0.25).abs() < 1e-9);
        assert_eq!(VariantOutcome::default().shed_rate(), 0.0);
    }
}
