//! Layer-3 coordinator: manifest loading, the training driver that owns
//! all model state, the serving router + dynamic batcher, and metrics.
//!
//! The trainer and the PJRT serving backend need the `pjrt` feature; the
//! functional-sim serving backend is always available.

pub mod manifest;
pub mod metrics;
pub mod server;
#[cfg(feature = "pjrt")]
pub mod trainer;

pub use manifest::Manifest;
pub use metrics::{LatencyHistogram, ServerMetrics};
pub use server::{FunctionalVariantCfg, ServerHandle};
#[cfg(feature = "pjrt")]
pub use server::VariantCfg;
#[cfg(feature = "pjrt")]
pub use trainer::Trainer;
