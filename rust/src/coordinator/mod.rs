//! Layer-3 coordinator: manifest loading, the training driver that owns
//! all model state, the serving router + dynamic batcher, and metrics.

pub mod manifest;
pub mod metrics;
pub mod server;
pub mod trainer;

pub use manifest::Manifest;
pub use metrics::{LatencyHistogram, ServerMetrics};
pub use server::{ServerHandle, VariantCfg};
pub use trainer::Trainer;
