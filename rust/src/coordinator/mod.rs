//! Layer-3 coordinator: manifest loading, the training driver that owns
//! all model state, the serving router + dynamic batcher (replica
//! fleets, bounded-queue admission control, plan hot-swap), the
//! open-loop load-test harness, and metrics.
//!
//! The trainer and the PJRT serving backend need the `pjrt` feature; the
//! functional-sim serving backend is always available.

pub mod loadtest;
pub mod manifest;
pub mod metrics;
pub mod queue;
pub mod server;
#[cfg(feature = "pjrt")]
pub mod trainer;

pub use loadtest::{LoadtestCfg, LoadtestReport};
pub use manifest::Manifest;
pub use metrics::{LatencyHistogram, ServerMetrics};
pub use queue::BoundedQueue;
pub use server::{FunctionalVariantCfg, Response, ServerHandle, SubmitError};
#[cfg(feature = "pjrt")]
pub use server::VariantCfg;
#[cfg(feature = "pjrt")]
pub use trainer::Trainer;
