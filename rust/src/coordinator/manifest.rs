//! Manifest loader: the single source of truth the AOT pipeline
//! (python/compile/aot.py) writes about every exported graph — input and
//! output orders, parameter layouts, training hyper-parameters.

use std::collections::BTreeMap;
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::sim::functional::Params;
use crate::util::json::Json;

/// One tensor slot in the flat init/trained parameter file.
#[derive(Debug, Clone)]
pub struct ParamSlot {
    pub name: String,
    pub shape: Vec<usize>,
    /// Offset in f32 elements.
    pub offset: usize,
    pub size: usize,
}

/// Parameter layout for one architecture.
#[derive(Debug, Clone)]
pub struct ParamLayout {
    pub init_file: String,
    pub slots: Vec<ParamSlot>,
    pub trainable: Vec<String>,
}

impl ParamLayout {
    pub fn total_elems(&self) -> usize {
        self.slots.iter().map(|s| s.size).sum()
    }

    pub fn slot(&self, name: &str) -> Option<&ParamSlot> {
        self.slots.iter().find(|s| s.name == name)
    }
}

/// One exported graph.
#[derive(Debug, Clone)]
pub struct GraphInfo {
    pub name: String,
    pub file: String,
    pub kind: String,
    pub arch: String,
    pub kernel: String,
    pub batch: usize,
    pub total_steps: usize,
    pub base_lr: f64,
    pub n_params: usize,
    pub n_momenta: usize,
    pub input_order: Vec<String>,
    pub output_order: Vec<String>,
    /// Output (shape, dtype) pairs.
    pub outputs: Vec<(Vec<usize>, String)>,
    /// Probe graphs: conv layer names in output order.
    pub layers: Vec<String>,
}

/// The whole artifacts manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub graphs: BTreeMap<String, GraphInfo>,
    pub params: BTreeMap<String, ParamLayout>,
    pub impl_name: String,
}

fn str_list(j: Option<&Json>) -> Vec<String> {
    j.and_then(|v| v.as_arr())
        .map(|a| a.iter().filter_map(|x| x.as_str().map(String::from)).collect())
        .unwrap_or_default()
}

fn usize_list(j: &Json) -> Vec<usize> {
    j.as_arr().map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
        .unwrap_or_default()
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load<P: AsRef<Path>>(dir: P) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;

        let mut graphs = BTreeMap::new();
        for (name, g) in j.get("graphs").and_then(|x| x.as_obj())
            .context("manifest missing graphs")? {
            let outputs = g.get("outputs").and_then(|x| x.as_arr()).map(|arr| {
                arr.iter()
                    .map(|o| {
                        let shape = o.get("shape").map(usize_list).unwrap_or_default();
                        let dt = o.get("dtype").and_then(|d| d.as_str())
                            .unwrap_or("f32").to_string();
                        (shape, dt)
                    })
                    .collect()
            }).unwrap_or_default();
            graphs.insert(name.clone(), GraphInfo {
                name: name.clone(),
                file: g.get("file").and_then(|x| x.as_str()).unwrap_or("").into(),
                kind: g.get("kind").and_then(|x| x.as_str()).unwrap_or("").into(),
                arch: g.get("arch").and_then(|x| x.as_str()).unwrap_or("").into(),
                kernel: g.get("kernel").and_then(|x| x.as_str()).unwrap_or("").into(),
                batch: g.get("batch").and_then(|x| x.as_usize()).unwrap_or(0),
                total_steps: g.get("total_steps").and_then(|x| x.as_usize()).unwrap_or(0),
                base_lr: g.get("base_lr").and_then(|x| x.as_f64()).unwrap_or(0.0),
                n_params: g.get("n_params").and_then(|x| x.as_usize()).unwrap_or(0),
                n_momenta: g.get("n_momenta").and_then(|x| x.as_usize()).unwrap_or(0),
                input_order: str_list(g.get("input_order")),
                output_order: str_list(g.get("output_order")),
                outputs,
                layers: str_list(g.get("layers")),
            });
        }

        let mut params = BTreeMap::new();
        for (arch, p) in j.get("params").and_then(|x| x.as_obj())
            .context("manifest missing params")? {
            let slots = p.get("layout").and_then(|x| x.as_arr()).map(|arr| {
                arr.iter()
                    .map(|s| ParamSlot {
                        name: s.get("name").and_then(|x| x.as_str()).unwrap_or("").into(),
                        shape: s.get("shape").map(usize_list).unwrap_or_default(),
                        offset: s.get("offset").and_then(|x| x.as_usize()).unwrap_or(0),
                        size: s.get("size").and_then(|x| x.as_usize()).unwrap_or(0),
                    })
                    .collect::<Vec<_>>()
            }).unwrap_or_default();
            params.insert(arch.clone(), ParamLayout {
                init_file: p.get("init_file").and_then(|x| x.as_str()).unwrap_or("").into(),
                slots,
                trainable: str_list(p.get("trainable")),
            });
        }

        Ok(Manifest {
            dir,
            graphs,
            params,
            impl_name: j.get("impl").and_then(|x| x.as_str()).unwrap_or("?").into(),
        })
    }

    pub fn graph(&self, name: &str) -> Result<&GraphInfo> {
        self.graphs.get(name)
            .ok_or_else(|| anyhow::anyhow!("graph {name} not in manifest"))
    }

    pub fn layout(&self, arch: &str) -> Result<&ParamLayout> {
        self.params.get(arch)
            .ok_or_else(|| anyhow::anyhow!("arch {arch} not in manifest"))
    }

    /// Read a flat f32 parameter file into per-slot buffers.
    pub fn read_param_file(&self, arch: &str, file: &str) -> Result<Vec<(String, Vec<usize>, Vec<f32>)>> {
        let layout = self.layout(arch)?;
        let bytes = fs::read(self.dir.join(file))
            .with_context(|| format!("reading {file}"))?;
        anyhow::ensure!(bytes.len() == layout.total_elems() * 4,
                        "param file {} has {} bytes, expected {}",
                        file, bytes.len(), layout.total_elems() * 4);
        let mut out = Vec::with_capacity(layout.slots.len());
        for s in &layout.slots {
            let start = s.offset * 4;
            let data: Vec<f32> = bytes[start..start + s.size * 4]
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect();
            out.push((s.name.clone(), s.shape.clone(), data));
        }
        Ok(out)
    }

    /// Load a parameter file as the functional simulator's `Params` map.
    pub fn read_params(&self, arch: &str, file: &str) -> Result<Params> {
        Ok(self.read_param_file(arch, file)?
            .into_iter()
            .map(|(n, s, d)| (n, (s, d)))
            .collect())
    }

    /// Write per-slot buffers back to a flat f32 file (trained weights).
    pub fn write_param_file(&self, arch: &str, file: &str,
                            bufs: &[(String, Vec<f32>)]) -> Result<()> {
        let layout = self.layout(arch)?;
        let mut flat = vec![0f32; layout.total_elems()];
        for (name, data) in bufs {
            let slot = layout.slot(name)
                .ok_or_else(|| anyhow::anyhow!("unknown slot {name}"))?;
            anyhow::ensure!(data.len() == slot.size, "slot {name} size mismatch");
            flat[slot.offset..slot.offset + slot.size].copy_from_slice(data);
        }
        let bytes: Vec<u8> = flat.iter().flat_map(|f| f.to_le_bytes()).collect();
        fs::write(self.dir.join(file), bytes)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn art_dir() -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        art_dir().join("manifest.json").exists()
    }

    #[test]
    fn loads_real_manifest() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let m = Manifest::load(art_dir()).unwrap();
        assert!(m.graphs.contains_key("lenet5_adder_train"));
        assert!(m.graphs.contains_key("l1gemm_demo"));
        let g = m.graph("lenet5_adder_train").unwrap();
        assert_eq!(g.kind, "train");
        assert_eq!(g.input_order.len(), g.n_params + g.n_momenta + 3);
        assert_eq!(g.output_order.last().unwrap(), "acc");
    }

    #[test]
    fn param_layout_contiguous_and_loadable() {
        if !have_artifacts() {
            return;
        }
        let m = Manifest::load(art_dir()).unwrap();
        let layout = m.layout("lenet5").unwrap();
        let mut off = 0;
        for s in &layout.slots {
            assert_eq!(s.offset, off, "{}", s.name);
            assert_eq!(s.size, s.shape.iter().product::<usize>());
            off += s.size;
        }
        let init = m.read_params("lenet5", &layout.init_file.clone()).unwrap();
        assert!(init.contains_key("conv1/conv_w"));
        let (shape, data) = &init["conv1/conv_w"];
        assert_eq!(shape, &vec![5, 5, 1, 6]);
        assert_eq!(data.len(), 150);
        // BN gammas must be exactly 1.0 at init
        assert!(init["conv1/bn_gamma"].1.iter().all(|&v| v == 1.0));
    }

    #[test]
    fn param_file_roundtrip() {
        if !have_artifacts() {
            return;
        }
        let m = Manifest::load(art_dir()).unwrap();
        let layout = m.layout("lenet5").unwrap().clone();
        let init = m.read_param_file("lenet5", &layout.init_file).unwrap();
        let bufs: Vec<(String, Vec<f32>)> =
            init.iter().map(|(n, _, d)| (n.clone(), d.clone())).collect();
        m.write_param_file("lenet5", "test_roundtrip.bin", &bufs).unwrap();
        let back = m.read_param_file("lenet5", "test_roundtrip.bin").unwrap();
        for ((n1, _, d1), (n2, _, d2)) in init.iter().zip(&back) {
            assert_eq!(n1, n2);
            assert_eq!(d1, d2);
        }
        let _ = std::fs::remove_file(art_dir().join("test_roundtrip.bin"));
    }
}
