//! Latency/throughput metrics for the serving path.

use std::time::Duration;

use crate::sim::hwsim::HwCost;

/// Online latency histogram with fixed log-spaced buckets (µs scale).
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    /// bucket i counts latencies in [2^i, 2^(i+1)) microseconds.
    buckets: Vec<u64>,
    count: u64,
    sum_us: u64,
    max_us: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self { buckets: vec![0; 32], count: 0, sum_us: 0, max_us: 0 }
    }

    pub fn record(&mut self, d: Duration) {
        let us = d.as_micros() as u64;
        let idx = (64 - us.max(1).leading_zeros() as usize - 1).min(31);
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum_us += us;
        self.max_us = self.max_us.max(us);
    }

    /// Rebuild from raw parts — the bridge the lock-free
    /// [`crate::obs::registry::AtomicHistogram`] snapshots across.
    /// `buckets` must use the same 32-bucket log layout.
    pub fn from_parts(buckets: Vec<u64>, count: u64, sum_us: u64,
                      max_us: u64) -> Self {
        assert_eq!(buckets.len(), 32, "histogram bucket layout mismatch");
        Self { buckets, count, sum_us, max_us }
    }

    /// Fold another histogram into this one (per-replica shard merge:
    /// buckets and counters add, the max takes the max).
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (b, o) in self.buckets.iter_mut().zip(&other.buckets) {
            *b += o;
        }
        self.count += other.count;
        self.sum_us += other.sum_us;
        self.max_us = self.max_us.max(other.max_us);
    }

    pub fn bucket_counts(&self) -> &[u64] {
        &self.buckets
    }

    pub fn sum_us(&self) -> u64 {
        self.sum_us
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_us(&self) -> f64 {
        if self.count == 0 { 0.0 } else { self.sum_us as f64 / self.count as f64 }
    }

    pub fn max_us(&self) -> u64 {
        self.max_us
    }

    /// Approximate quantile from the bucket upper edges, clamped to the
    /// maximum observed latency — a bucket's upper edge can exceed
    /// every sample that landed in it (e.g. one 700µs sample reports a
    /// p99 of 1024µs unclamped), and the top bucket is open-ended (its
    /// nominal edge 2^32µs under-reports nothing but over-reports
    /// wildly), so both resolve to `max_us`.
    pub fn quantile_us(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = (q * self.count as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                if i + 1 >= self.buckets.len() {
                    // open-ended top bucket: the true edge is max_us
                    return self.max_us;
                }
                return (1u64 << (i + 1)).min(self.max_us);
            }
        }
        self.max_us
    }
}

/// Serving-side aggregate counters.
#[derive(Debug, Clone, Default)]
pub struct ServerMetrics {
    pub requests: u64,
    pub images: u64,
    pub batches: u64,
    /// Admission-control sheds: submits refused `Overloaded` because
    /// the variant's bounded queue was full.
    pub shed: u64,
    /// Malformed requests refused at submit (wrong pixel count).
    pub rejected: u64,
    /// Successful zero-downtime plan hot-swaps on this variant.
    pub swaps: u64,
    pub queue_lat: LatencyHistogram,
    pub exec_lat: LatencyHistogram,
    pub e2e_lat: LatencyHistogram,
    /// Simulated-accelerator cycles accumulated across every served
    /// image (hwsim backend only; zero elsewhere).
    pub hw_cycles: u64,
    /// Simulated off-chip traffic, bytes.
    pub hw_dram_bytes: u64,
    /// Accumulated simulated wall-clock at the design's fmax, ms.
    pub hw_latency_ms: f64,
    /// Per-design gauges — constant over a variant's lifetime because
    /// `swap_plan` pins (arch, kernel, quant config).
    pub hw_power_w: f64,
    pub hw_utilization: f64,
    pub hw_fmax_mhz: f64,
    /// Wall-clock a replica spent executing/responding, µs (summed
    /// across replicas at snapshot time).
    pub busy_us: u64,
    /// Wall-clock a replica spent waiting for work, µs.
    pub idle_us: u64,
}

impl ServerMetrics {
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 { 0.0 } else { self.images as f64 / self.batches as f64 }
    }

    /// Fold one batch's simulated-hardware cost into the aggregates.
    pub fn record_hw(&mut self, cost: &HwCost) {
        self.hw_cycles += cost.cycles;
        self.hw_dram_bytes += cost.dram_bytes;
        self.hw_latency_ms += cost.latency_ms;
        self.hw_power_w = cost.power_w;
        self.hw_utilization = cost.utilization;
        self.hw_fmax_mhz = cost.fmax_mhz;
    }

    /// Mean simulated latency per served image, ms (0 when the variant
    /// runs a backend without a hardware model).
    pub fn hw_latency_per_image_ms(&self) -> f64 {
        if self.images == 0 { 0.0 } else { self.hw_latency_ms / self.images as f64 }
    }

    /// Fold a per-replica (or submit-side) shard into this aggregate.
    /// Counters and histograms add; the per-design gauges are constant
    /// across shards of one variant, so any non-zero shard wins.
    pub fn merge(&mut self, o: &ServerMetrics) {
        self.requests += o.requests;
        self.images += o.images;
        self.batches += o.batches;
        self.shed += o.shed;
        self.rejected += o.rejected;
        self.swaps += o.swaps;
        self.queue_lat.merge(&o.queue_lat);
        self.exec_lat.merge(&o.exec_lat);
        self.e2e_lat.merge(&o.e2e_lat);
        self.hw_cycles += o.hw_cycles;
        self.hw_dram_bytes += o.hw_dram_bytes;
        self.hw_latency_ms += o.hw_latency_ms;
        if o.hw_fmax_mhz != 0.0 {
            self.hw_power_w = o.hw_power_w;
            self.hw_utilization = o.hw_utilization;
            self.hw_fmax_mhz = o.hw_fmax_mhz;
        }
        self.busy_us += o.busy_us;
        self.idle_us += o.idle_us;
    }

    /// Fraction of admitted+refused submits that were load-shed.
    pub fn shed_rate(&self) -> f64 {
        let offered = self.requests + self.shed + self.rejected;
        if offered == 0 { 0.0 } else { self.shed as f64 / offered as f64 }
    }

    /// Fraction of offered submits rejected as malformed.
    pub fn reject_rate(&self) -> f64 {
        let offered = self.requests + self.shed + self.rejected;
        if offered == 0 { 0.0 } else { self.rejected as f64 / offered as f64 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_records() {
        let mut h = LatencyHistogram::new();
        for us in [10u64, 100, 1000, 1000, 10_000] {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 5);
        assert!(h.mean_us() > 1000.0 && h.mean_us() < 4000.0);
        assert!(h.quantile_us(0.5) >= 512 && h.quantile_us(0.5) <= 2048);
        assert_eq!(h.max_us(), 10_000);
    }

    #[test]
    fn empty_histogram() {
        let h = LatencyHistogram::new();
        assert_eq!(h.quantile_us(0.99), 0);
        assert_eq!(h.mean_us(), 0.0);
    }

    #[test]
    fn quantile_monotone() {
        let mut h = LatencyHistogram::new();
        for i in 1..=1000u64 {
            h.record(Duration::from_micros(i));
        }
        assert!(h.quantile_us(0.5) <= h.quantile_us(0.9));
        assert!(h.quantile_us(0.9) <= h.quantile_us(0.999));
    }

    #[test]
    fn quantile_never_exceeds_observed_max() {
        // one 700µs sample lands in bucket [512, 1024): the unclamped
        // upper edge (1024) exceeds every latency actually observed
        let mut h = LatencyHistogram::new();
        h.record(Duration::from_micros(700));
        for q in [0.5, 0.9, 0.99, 0.999] {
            assert!(h.quantile_us(q) <= h.max_us(),
                    "q{q}: {} > max {}", h.quantile_us(q), h.max_us());
        }
        assert_eq!(h.quantile_us(0.99), 700);
        // mixed: the 700µs tail must still not over-report
        h.record(Duration::from_micros(10));
        h.record(Duration::from_micros(20));
        assert!(h.quantile_us(0.999) <= 700);
    }

    #[test]
    fn hw_aggregates_accumulate() {
        let mut m = ServerMetrics::default();
        let cost = HwCost {
            cycles: 1000, conv_cycles: 800, dma_cycles: 300,
            dram_bytes: 4096, fmax_mhz: 250.0, latency_ms: 0.004,
            power_w: 1.34, utilization: 0.95,
        };
        m.record_hw(&cost);
        m.record_hw(&cost.scale(3));
        m.images = 4;
        assert_eq!(m.hw_cycles, 4000);
        assert_eq!(m.hw_dram_bytes, 4 * 4096);
        assert!((m.hw_latency_ms - 0.016).abs() < 1e-12);
        assert_eq!(m.hw_power_w, 1.34);
        assert_eq!(m.hw_fmax_mhz, 250.0);
        assert!((m.hw_latency_per_image_ms() - 0.004).abs() < 1e-12);
        assert_eq!(ServerMetrics::default().hw_latency_per_image_ms(), 0.0);
    }

    #[test]
    fn histogram_merge_equals_combined_stream() {
        let (mut a, mut b, mut whole) =
            (LatencyHistogram::new(), LatencyHistogram::new(),
             LatencyHistogram::new());
        for us in [10u64, 100, 700, 1000] {
            a.record(Duration::from_micros(us));
            whole.record(Duration::from_micros(us));
        }
        for us in [5u64, 5000, 50_000] {
            b.record(Duration::from_micros(us));
            whole.record(Duration::from_micros(us));
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert_eq!(a.sum_us(), whole.sum_us());
        assert_eq!(a.max_us(), whole.max_us());
        assert_eq!(a.bucket_counts(), whole.bucket_counts());
        for q in [0.5, 0.9, 0.99] {
            assert_eq!(a.quantile_us(q), whole.quantile_us(q));
        }
    }

    #[test]
    fn server_metrics_merge_folds_shards() {
        let mut agg = ServerMetrics::default();
        let submit = ServerMetrics {
            shed: 3, rejected: 1, ..Default::default()
        };
        let mut replica = ServerMetrics {
            requests: 6, images: 6, batches: 2, busy_us: 900, idle_us: 100,
            hw_fmax_mhz: 250.0, hw_power_w: 1.34, ..Default::default()
        };
        replica.e2e_lat.record(Duration::from_micros(250));
        agg.merge(&submit);
        agg.merge(&replica);
        assert_eq!(agg.shed, 3);
        assert_eq!(agg.requests, 6);
        assert_eq!(agg.e2e_lat.count(), 1);
        assert_eq!(agg.busy_us, 900);
        assert_eq!(agg.hw_fmax_mhz, 250.0);
        assert!((agg.shed_rate() - 0.3).abs() < 1e-12);
        assert!((agg.reject_rate() - 0.1).abs() < 1e-12);
        assert_eq!(ServerMetrics::default().shed_rate(), 0.0);
    }

    #[test]
    fn top_bucket_reports_max_not_edge() {
        // 5000s = 5e9µs exceeds 2^32µs, landing in the open-ended top
        // bucket (index 31) whose nominal upper edge would both
        // over-report (2^32) and under-report (the sample is beyond it)
        let huge = Duration::from_secs(5000);
        let mut h = LatencyHistogram::new();
        h.record(huge);
        assert_eq!(h.quantile_us(0.5), huge.as_micros() as u64);
        assert_eq!(h.quantile_us(0.999), h.max_us());
    }
}
