//! Bench E8 (§4 on-board): ResNet-18 on the ZCU104 accelerator model —
//! fmax / GOPs / power for CNN vs AdderNet, plus a parallelism-scaling
//! series and simulator throughput.

mod common;

use addernet::hw::KernelKind;
use addernet::nn;
use addernet::report::fpga;
use addernet::sim::accelerator::{self, AccelConfig};

fn main() {
    println!("=== bench onboard_resnet18 (E8) ===");
    fpga::onboard().print();

    // scaling series: throughput & power vs parallelism
    let net = nn::resnet18();
    println!("scaling (16-bit AdderNet, ResNet-18):");
    println!("  {:>6} {:>10} {:>10} {:>10} {:>8}", "P", "conv GOPs", "total GOPs",
             "lat ms", "power W");
    for p in [256u64, 512, 1024, 2048] {
        let r = accelerator::run(&AccelConfig::zcu104(p, 16, KernelKind::Adder2A), &net);
        println!("  {:>6} {:>10.0} {:>10.0} {:>10.2} {:>8.2}",
                 p, r.conv_gops(), r.total_gops(), r.latency_ms(),
                 r.power.total_w());
    }

    let cfg = AccelConfig::zcu104(1024, 16, KernelKind::Adder2A);
    let (med, _) = common::time_it(3, 20, || {
        std::hint::black_box(accelerator::run(&cfg, &net));
    });
    common::report("cycle-level resnet18 simulation", med, 1.0, "run");
}
