//! Bench E4/E12/E13 (Fig. 3d, S6, S7): shared-scale quantization sweep —
//! prints the accuracy tables when artifacts exist and times the int8
//! functional datapath against fp32 (the software proxy for the hardware
//! energy claim).  Without artifacts the timing section still runs, on
//! deterministic synthetic weights.

mod common;

use addernet::coordinator::Manifest;
use addernet::data;
use addernet::quant::Mode;
use addernet::report::quantrep;
use addernet::sim::functional::{self, Arch, ExecMode, KernelStrategy, QuantCfg,
                                Runner, SimKernel, Tensor};

fn main() {
    println!("=== bench fig3_quant (E4/E12/E13) ===");
    let art = std::path::Path::new("artifacts");
    let params = match Manifest::load(art) {
        Ok(manifest) => {
            match quantrep::fig3d(art, "lenet5", 192) {
                Ok(t) => t.print(),
                Err(e) => println!("fig3d skipped: {e:#}"),
            }
            match quantrep::s7(art, "lenet5", 192) {
                Ok(t) => t.print(),
                Err(e) => println!("s7 skipped: {e:#}"),
            }
            quantrep::load_params(&manifest, "lenet5", "adder")
                .map(|(p, _)| p)
                .unwrap_or_else(|_| functional::synth_params(Arch::Lenet5, 42))
        }
        Err(_) => {
            println!("no artifacts/ — accuracy tables skipped; timing runs on \
                      synthetic weights");
            functional::synth_params(Arch::Lenet5, 42)
        }
    };

    // datapath timing: fp32 vs int8/int16 functional forward
    let (calib, _) = quantrep::calibrate(&params, Arch::Lenet5, SimKernel::Adder, 64);
    let b = data::eval_set(64, 5);
    let x = Tensor::new((64, 32, 32, 1), b.images);
    println!("functional LeNet-5 forward (B=64):");
    for (name, mode) in [
        ("fp32", ExecMode::F32),
        ("int8 shared", ExecMode::Quant(QuantCfg { bits: 8, mode: Mode::SharedScale })),
        ("int16 shared", ExecMode::Quant(QuantCfg { bits: 16, mode: Mode::SharedScale })),
    ] {
        let (med, _) = common::time_it(1, 5, || {
            let mut r = Runner {
                params: &params, arch: Arch::Lenet5, kind: SimKernel::Adder,
                strategy: KernelStrategy::Auto,
                mode, calib: Some(&calib), observe: None,
            };
            std::hint::black_box(r.forward(&x));
        });
        common::report(name, med, 64.0, "img");
    }
}
