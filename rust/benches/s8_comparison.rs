//! Bench E14 (S8/Fig. 13): FPGA accelerator comparison table — cited
//! literature rows plus this repro's measured row from the simulator,
//! for several candidate networks.

use addernet::hw::KernelKind;
use addernet::nn;
use addernet::report::fpga;
use addernet::sim::accelerator::{self, AccelConfig};
use addernet::util::table::{f, Table};

fn main() {
    println!("=== bench s8_comparison (E14) ===");
    fpga::s8().print();

    // our simulator's rows for the other S8 workloads, for context
    let mut t = Table::new(
        "this repro's model across S8 workloads (AdderNet P=1024, 16-bit)",
        &["model", "GOP", "latency ms", "GOPS", "power W"],
    );
    for name in ["alexnet", "vgg16", "resnet18", "resnet50"] {
        let net = nn::by_name(name).unwrap();
        let r = accelerator::run(&AccelConfig::zcu104(1024, 16, KernelKind::Adder2A), &net);
        t.row(&[net.name.clone(), f(net.gops(), 2), f(r.latency_ms(), 2),
                f(r.total_gops(), 1), f(r.power.total_w(), 2)]);
    }
    t.print();
}
