//! Bench E1/E2 (Fig. 2): kernel comparison.
//!
//! * prints the Fig. 2(a/b) accuracy table (measured rows filled by
//!   `repro train` / train_e2e) and the Fig. 2(c) energy table;
//! * times the functional adder vs mult convolution on the LeNet-5
//!   conv2 workload (the software analogue of the kernel-cost claim).

mod common;

use addernet::report::{kernels, Results};
use addernet::sim::functional::{conv2d, ConvW, SimKernel, Tensor};
use addernet::sim::reference;
use addernet::util::XorShift64;

fn main() {
    println!("=== bench fig2_kernels (E1/E2) ===");
    kernels::fig2(&Results::load("artifacts")).print();
    kernels::fig2c().print();

    // functional-kernel throughput on the conv2 workload (B=32)
    let mut rng = XorShift64::new(1);
    let x = Tensor::new((32, 14, 14, 6),
                        (0..32 * 14 * 14 * 6).map(|_| rng.next_f32_sym(1.0)).collect());
    let wdat: Vec<f32> = (0..5 * 5 * 6 * 16).map(|_| rng.next_f32_sym(1.0)).collect();
    let w = ConvW { data: &wdat, kh: 5, kw: 5, cin: 6, cout: 16 };
    let macs = 32.0 * 10.0 * 10.0 * 5.0 * 5.0 * 6.0 * 16.0;
    println!("functional conv2 (B=32, 5x5, 6->16):");
    for (name, kind) in [("adder", SimKernel::Adder), ("mult", SimKernel::Mult)] {
        let (med, _) = common::time_it(2, 10, || {
            let y = conv2d(&x, &w, 1, addernet::nn::Padding::Valid, kind);
            std::hint::black_box(y);
        });
        common::report(name, med, macs, "MAC");
        let (naive, _) = common::time_it(1, 5, || {
            let y = reference::conv2d(&x, &w, 1, addernet::nn::Padding::Valid, kind);
            std::hint::black_box(y);
        });
        common::report(&format!("{name} (naive reference)"), naive, macs, "MAC");
    }
}
