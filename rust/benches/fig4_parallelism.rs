//! Bench E6/E7 (Fig. 4): parallelism sweep of the synthesized accelerator
//! components + savings, for 16-bit and 8-bit datapaths, plus the Eq. 2/3
//! closed-form vs precise-widths ablation (E16).

mod common;

use addernet::hw::array::PeArray;
use addernet::hw::KernelKind;
use addernet::report::fpga;
use addernet::sim::accelerator::{self, AccelConfig};

fn main() {
    println!("=== bench fig4_parallelism (E6/E7/E16) ===");
    for dw in [16u32, 8] {
        fpga::fig4_components(dw, KernelKind::Mult).print();
        fpga::fig4_components(dw, KernelKind::Adder2A).print();
        fpga::fig4_savings(dw).print();
    }
    fpga::eq23().print();

    // ablation: paper closed-form vs precise per-level tree widths
    println!("Eq.2/3 ablation — closed form vs precise widths (saving delta):");
    for (pin, dw) in [(64u64, 16u32), (64, 8), (128, 16)] {
        let a = PeArray::new(pin, 1, dw, KernelKind::Adder2A);
        let c = PeArray::new(pin, 1, dw, KernelKind::Mult);
        let paper = 1.0 - a.luts_paper() as f64 / c.luts_paper() as f64;
        let precise = 1.0 - a.luts() as f64 / c.luts() as f64;
        println!("  Pin={pin:4} DW={dw:2}: paper {:.1}%  precise {:.1}%  delta {:+.1}pp",
                 paper * 100.0, precise * 100.0, (precise - paper) * 100.0);
    }

    // model-evaluation throughput (the sweep itself is the workload)
    let (med, _) = common::time_it(3, 20, || {
        for p in [128u64, 512, 2048] {
            for k in [KernelKind::Adder2A, KernelKind::Mult] {
                std::hint::black_box(
                    accelerator::resources(&AccelConfig::zcu104(p, 16, k)));
            }
        }
    });
    common::report("resource model (6 configs)", med, 6.0, "cfg");
}
