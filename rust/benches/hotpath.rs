//! §Perf hot-path microbenchmarks (the before/after log lives in
//! EXPERIMENTS.md §Perf):
//!
//!   L3a: functional adder/mult conv (f32 + int) — the quantized-
//!        inference datapath, measured per kernel strategy: the naive
//!        reference (the oracle of tests/functional_oracle.rs), the
//!        tiled cache-blocked engine and the lane-structured simd
//!        kernel.  Records tiled-vs-naive AND simd-vs-tiled speedups —
//!        the simd-vs-tiled median on the ResNet-shape layer is the
//!        kernel-strategy acceptance number (target >= 1.3x) — plus the
//!        int8/int16-vs-f32 throughput ratios on the tiled and simd
//!        strategies (the quantized-serving acceptance number:
//!        int8 >= f32), plus the Winograd transform-domain engine on
//!        the int8 mult path (`winograd_vs_simd`, target >= 1.2x —
//!        F(2x2, 3x3) does ~2.25x fewer inner products per output);
//!   L3a2: whole-model serving comparison — f32 vs per-call int8 vs
//!        the plan-compiled int8 path (weights quantized once,
//!        activations i32 across the conv stack);
//!   L3b: dataset generator (streams every training batch);
//!   L3c: PJRT execute round-trip (train step + eval) when artifacts
//!        are present and the crate is built with --features pjrt — the
//!        training/serving hot loop.
//!
//! The fixtures and timing loops live in `addernet::lab::measure` —
//! the SAME cores the `repro lab` experiment runner executes, so a
//! bench row and the lab's recorded key for the same point can never
//! measure different things.  The per-strategy medians and the derived
//! ratios are also written as JSON (default `target/hotpath.json`,
//! override with `HOTPATH_JSON`) for the legacy `repro bench check`
//! path; CI now gates through `repro lab run` + `lab check`.

mod common;

use addernet::data;
use addernet::lab::measure;
use addernet::quant::plan::QuantPlan;
use addernet::quant::Mode;
use addernet::report::quantrep;
use addernet::sim::functional::{synth_params, Arch, ExecMode, KernelStrategy,
                                QuantCfg, Runner, SimKernel, Tensor};
use addernet::sim::intpath::PlanRunner;

/// One measured row: (json_key, naive_s, tiled_s, simd_s).
type Row = (String, f64, f64, f64);

fn bench_strategy_trio(lb: &measure::LayerBench, name: &str, json_key: &str,
                       kind: SimKernel, quant: Option<QuantCfg>,
                       rows: &mut Vec<Row>) {
    let time = |strat: KernelStrategy, warmup: usize, iters: usize| match quant
    {
        None => lb.time_f32(strat, kind, warmup, iters),
        Some(cfg) => lb.time_quant(strat, kind, cfg, warmup, iters),
    };
    let naive = time(KernelStrategy::Naive, 1, 5);
    let tiled = time(KernelStrategy::Tiled, 2, 9);
    let simd = time(KernelStrategy::Simd, 2, 9);
    let macs = lb.macs();
    common::report(&format!("{name} (naive reference)"), naive, macs, "MAC");
    common::report(&format!("{name} (tiled engine)"), tiled, macs, "MAC");
    common::report(&format!("{name} (simd kernel)"), simd, macs, "MAC");
    println!("  {name:44} tiled vs naive {:>6.1}x | simd vs tiled {:>5.2}x",
             naive / tiled, tiled / simd);
    rows.push((json_key.to_string(), naive, tiled, simd));
}

fn main() {
    println!("=== bench hotpath (§Perf) ===");
    let mut rows: Vec<Row> = Vec::new();

    // L3a: resnet-shape conv (the heaviest functional-sim layer),
    // per kernel strategy — the lab's shared B=8 fixture.
    let lb = measure::LayerBench::new(8);
    println!("functional conv 3x3 16->16 (B=8, 32x32), naive vs tiled vs simd:");
    for (name, key, kind) in [("f32 adder", "f32_adder", SimKernel::Adder),
                              ("f32 mult", "f32_mult", SimKernel::Mult)] {
        bench_strategy_trio(&lb, name, key, kind, None, &mut rows);
    }
    for (name, key, bits) in [("int8 adder", "int8_adder", 8u32),
                              ("int16 adder", "int16_adder", 16)] {
        let cfg = QuantCfg { bits, mode: Mode::SharedScale };
        bench_strategy_trio(&lb, name, key, SimKernel::Adder, Some(cfg),
                            &mut rows);
    }

    // int8 mult trio plus the Winograd transform-domain engine, which
    // is exact (bit-identical) on the integer mult path and so can be
    // gated as a straight speedup: winograd_vs_simd is this layer's
    // acceptance ratio (>= 1.2x).
    let cfg8 = QuantCfg { bits: 8, mode: Mode::SharedScale };
    bench_strategy_trio(&lb, "int8 mult", "int8_mult", SimKernel::Mult,
                        Some(cfg8), &mut rows);
    let wino_s = lb.time_quant(KernelStrategy::Winograd, SimKernel::Mult,
                               cfg8, 2, 9);
    common::report("int8 mult (winograd engine)", wino_s, lb.macs(), "MAC");

    // derived: int-vs-f32 throughput on the engine strategies — the
    // quantized-serving acceptance ratio (int8 >= 1.0x means the int
    // datapath is at least as fast as f32).
    let mut derived: Vec<(String, f64)> = Vec::new();
    let find = |k: &str| rows.iter().find(|r| r.0 == k).cloned().unwrap();
    let f32a = find("f32_adder");
    for (key, row) in [("int8", find("int8_adder")), ("int16", find("int16_adder"))] {
        println!("  {key} vs f32 adder conv: tiled {:>5.2}x | simd {:>5.2}x",
                 f32a.2 / row.2, f32a.3 / row.3);
        derived.push((format!("{key}_vs_f32_tiled"), f32a.2 / row.2));
        derived.push((format!("{key}_vs_f32_simd"), f32a.3 / row.3));
    }
    let m8 = find("int8_mult");
    println!("  winograd vs simd (int8 mult conv): {:>5.2}x", m8.3 / wino_s);
    derived.push(("int8_mult_winograd_s".to_string(), wino_s));
    derived.push(("winograd_vs_simd".to_string(), m8.3 / wino_s));

    // L3a2: whole-model serving — f32 vs per-call int8 vs the compiled
    // QuantPlan int8 path (no per-call weight requantization,
    // activations i32 across the conv stack).
    let params = synth_params(Arch::Lenet5, 42);
    let (mcalib, _) = quantrep::calibrate(&params, Arch::Lenet5, SimKernel::Adder, 32);
    let ev = data::eval_set(64, 5);
    let xin = Tensor::new((64, 32, 32, 1), ev.images);
    let qcfg = QuantCfg { bits: 8, mode: Mode::SharedScale };
    let plan = QuantPlan::build(&params, Arch::Lenet5, SimKernel::Adder, qcfg,
                                &mcalib).unwrap();
    println!("whole-model LeNet-5 forward (B=64):");
    let (f32_s, _) = common::time_it(1, 7, || {
        let mut r = Runner {
            params: &params, arch: Arch::Lenet5, kind: SimKernel::Adder,
            strategy: KernelStrategy::Auto, mode: ExecMode::F32,
            calib: None, observe: None,
        };
        std::hint::black_box(r.forward(&xin));
    });
    common::report("f32 engine", f32_s, 64.0, "img");
    let (percall_s, _) = common::time_it(1, 7, || {
        let mut r = Runner {
            params: &params, arch: Arch::Lenet5, kind: SimKernel::Adder,
            strategy: KernelStrategy::Auto, mode: ExecMode::Quant(qcfg),
            calib: Some(&mcalib), observe: None,
        };
        std::hint::black_box(r.forward(&xin));
    });
    common::report("int8 per-call (requantizes weights)", percall_s, 64.0, "img");
    let (plan_s, _) = common::time_it(1, 7, || {
        let r = PlanRunner { plan: &plan, strategy: KernelStrategy::Auto };
        std::hint::black_box(r.forward(&xin));
    });
    common::report("int8 plan (i32 end-to-end)", plan_s, 64.0, "img");
    println!("  plan vs per-call {:>5.2}x | plan vs f32 {:>5.2}x",
             percall_s / plan_s, f32_s / plan_s);
    derived.push(("e2e_f32_s".to_string(), f32_s));
    derived.push(("e2e_int8_percall_s".to_string(), percall_s));
    derived.push(("e2e_int8_plan_s".to_string(), plan_s));
    derived.push(("plan_vs_percall".to_string(), percall_s / plan_s));
    derived.push(("plan_vs_f32".to_string(), f32_s / plan_s));

    // int16 plan: since the dense head went integer the adder path is
    // plan-servable at 16 bits end-to-end — record it next to int8.
    let qcfg16 = QuantCfg { bits: 16, mode: Mode::SharedScale };
    let plan16 = QuantPlan::build(&params, Arch::Lenet5, SimKernel::Adder,
                                  qcfg16, &mcalib).unwrap();
    let (plan16_s, _) = common::time_it(1, 7, || {
        let r = PlanRunner { plan: &plan16, strategy: KernelStrategy::Auto };
        std::hint::black_box(r.forward(&xin));
    });
    common::report("int16 plan (integer to the logits)", plan16_s, 64.0, "img");
    derived.push(("e2e_int16_plan_s".to_string(), plan16_s));
    derived.push(("int16_plan_vs_f32".to_string(), f32_s / plan16_s));

    // the graph-described cnv6 architecture rides the same harness with
    // zero executor/bench edits beyond this measurement
    let params6 = synth_params(Arch::Cnv6, 42);
    let (calib6, _) = quantrep::calibrate(&params6, Arch::Cnv6,
                                          SimKernel::Adder, 16);
    let plan6 = QuantPlan::build(&params6, Arch::Cnv6, SimKernel::Adder, qcfg,
                                 &calib6).unwrap();
    let (cnv6_s, _) = common::time_it(1, 5, || {
        let r = PlanRunner { plan: &plan6, strategy: KernelStrategy::Auto };
        std::hint::black_box(r.forward(&xin));
    });
    common::report("cnv6 int8 plan (graph-described arch)", cnv6_s, 64.0, "img");
    derived.push(("e2e_cnv6_int8_plan_s".to_string(), cnv6_s));

    // Simulated-accelerator cycle counts for the serving plans (hwsim
    // backend, P=1024), through the lab's deterministic measurement
    // cores — the exact numbers `repro lab run` records and `lab diff`
    // pins bit-for-bit.  Unlike the wall-clock medians these gate as
    // absolutes; the committed ratio gate rides on
    // hw_mult_over_adder_latency.
    let hwp = addernet::sim::hwsim::DEFAULT_PARALLELISM;
    let hw_lenet = measure::hw_cycles(Arch::Lenet5, SimKernel::Adder, 8, hwp)
        .unwrap();
    let hw_cnv6 = measure::hw_cycles(Arch::Cnv6, SimKernel::Adder, 8, hwp)
        .unwrap();
    let hw_r8a = measure::hw_cycles(Arch::Resnet8, SimKernel::Adder, 8, hwp)
        .unwrap();
    let hw_r8m = measure::hw_cycles(Arch::Resnet8, SimKernel::Mult, 8, hwp)
        .unwrap();
    println!("hwsim cycles/img (P={hwp}): lenet5 {} | cnv6 {} | resnet8 adder \
              {} | resnet8 mult {}",
             hw_lenet.cycles, hw_cnv6.cycles, hw_r8a.cycles, hw_r8m.cycles);
    derived.push(("hw_cycles_lenet5_int8".to_string(), hw_lenet.cycles as f64));
    derived.push(("hw_cycles_cnv6_int8".to_string(), hw_cnv6.cycles as f64));
    derived.push(("hw_cycles_resnet8_int8".to_string(), hw_r8a.cycles as f64));
    derived.push(("hw_cycles_resnet8_mult_int8".to_string(), hw_r8m.cycles as f64));
    // The adder array closes timing at a higher fmax, but at the 8-bit
    // datapath BOTH designs hit the 250 MHz fabric cap — which is why
    // the int8 cycle keys above are legitimately equal and why the
    // ratio used to read 1.0.  The paper's ~1.16x mult latency penalty
    // only shows where the mult critical path is the fmax limiter, so
    // measure it at the 16-bit datapath on the resnet8 descriptor.
    let (ratio16, mult_fmax, adder_fmax) = measure::mult_over_adder_dw16(hwp);
    println!("  dw16 mult-vs-adder latency (resnet8 descriptor): {ratio16:.3}x \
              (mult fmax {mult_fmax:.0} MHz vs adder {adder_fmax:.0} MHz)");
    derived.push(("hw_mult_over_adder_latency".to_string(), ratio16));

    write_json(&rows, &derived);

    // L3b: dataset generator
    let (med, _) = common::time_it(2, 10, || {
        std::hint::black_box(data::generate(256, 7, 0));
    });
    common::report("dataset generator (256 imgs)", med, 256.0, "img");

    // L3c: PJRT round-trips
    pjrt_round_trips();
}

/// Persist the per-strategy medians (seconds) + derived speedups
/// (int-vs-f32 per strategy, whole-model plan-vs-per-call).  No JSON
/// writer is vendored, so the record is assembled by hand — keys and
/// shape are part of the CI artifact contract.
fn write_json(rows: &[Row], derived: &[(String, f64)]) {
    let path = std::env::var("HOTPATH_JSON")
        .unwrap_or_else(|_| "target/hotpath.json".to_string());
    let mut entries = Vec::new();
    for (key, naive, tiled, simd) in rows {
        entries.push(format!(
            "    \"{key}\": {{\"naive_s\": {naive:.6e}, \"tiled_s\": {tiled:.6e}, \
             \"simd_s\": {simd:.6e}, \"tiled_vs_naive\": {:.3}, \
             \"simd_vs_tiled\": {:.3}}}",
            naive / tiled, tiled / simd));
    }
    let dentries: Vec<String> = derived.iter()
        .map(|(k, v)| format!("    \"{k}\": {v:.6e}"))
        .collect();
    let doc = format!(
        "{{\n  \"bench\": \"hotpath\",\n  \
         \"layer\": \"conv3x3 16->16 B=8 32x32 (resnet shape)\",\n  \
         \"kernel_env\": \"{}\",\n  \"results\": {{\n{}\n  }},\n  \
         \"derived\": {{\n{}\n  }}\n}}\n",
        KernelStrategy::from_env().label(),
        entries.join(",\n"),
        dentries.join(",\n"));
    if let Some(dir) = std::path::Path::new(&path).parent() {
        let _ = std::fs::create_dir_all(dir);
    }
    match std::fs::write(&path, doc) {
        Ok(()) => println!("  (per-strategy medians written to {path})"),
        Err(e) => eprintln!("  (could not write {path}: {e})"),
    }
}

#[cfg(feature = "pjrt")]
fn pjrt_round_trips() {
    use addernet::coordinator::{Manifest, Trainer};
    use addernet::runtime::Runtime;

    let art = std::path::Path::new("artifacts");
    if let Ok(manifest) = Manifest::load(art) {
        let mut rt = Runtime::new(art).unwrap();
        let mut trainer = Trainer::new(&manifest, &mut rt, "lenet5", "adder").unwrap();
        let mut stream = data::BatchStream::new(9, trainer.batch_size);
        let batch = stream.next_batch();
        let (med, _) = common::time_it(2, 10, || {
            trainer.train_step(&rt, &batch).unwrap();
        });
        common::report("PJRT train step (lenet5 adder, B=32)", med, 32.0, "img");

        let ev = data::eval_set(32, 5);
        let (med, _) = common::time_it(2, 10, || {
            std::hint::black_box(trainer.evaluate(&rt, &ev.images, &ev.labels).unwrap());
        });
        common::report("PJRT eval (lenet5 adder, B=32)", med, 32.0, "img");
    } else {
        println!("  (no artifacts/ — PJRT round-trip benches skipped)");
    }
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_round_trips() {
    println!("  (built without --features pjrt — PJRT round-trip benches skipped)");
}
