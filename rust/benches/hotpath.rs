//! §Perf hot-path microbenchmarks (the before/after log lives in
//! EXPERIMENTS.md §Perf):
//!
//!   L3a: functional adder/mult conv (f32 + int) — the quantized-
//!        inference datapath, measured per kernel strategy: the naive
//!        reference (the oracle of tests/functional_oracle.rs), the
//!        tiled cache-blocked engine and the lane-structured simd
//!        kernel.  Records tiled-vs-naive AND simd-vs-tiled speedups —
//!        the simd-vs-tiled median on the ResNet-shape layer is the
//!        kernel-strategy acceptance number (target >= 1.3x);
//!   L3b: dataset generator (streams every training batch);
//!   L3c: PJRT execute round-trip (train step + eval) when artifacts
//!        are present and the crate is built with --features pjrt — the
//!        training/serving hot loop.
//!
//! The per-strategy medians are also written as JSON (default
//! `target/hotpath.json`, override with `HOTPATH_JSON`) so CI can
//! persist the record as an artifact.

mod common;

use addernet::quant::{LayerCalib, Mode};
use addernet::sim::functional::{conv2d_quant_with, conv2d_with, ConvW,
                                KernelStrategy, QuantCfg, SimKernel, Tensor};
use addernet::util::XorShift64;
use addernet::{data, nn};

/// One measured row: (json_key, naive_s, tiled_s, simd_s).
type Row = (String, f64, f64, f64);

fn bench_strategy_trio(name: &str, json_key: &str,
                       mut run: impl FnMut(KernelStrategy), macs: f64,
                       rows: &mut Vec<Row>) {
    let (naive, _) = common::time_it(1, 5, || run(KernelStrategy::Naive));
    let (tiled, _) = common::time_it(2, 9, || run(KernelStrategy::Tiled));
    let (simd, _) = common::time_it(2, 9, || run(KernelStrategy::Simd));
    common::report(&format!("{name} (naive reference)"), naive, macs, "MAC");
    common::report(&format!("{name} (tiled engine)"), tiled, macs, "MAC");
    common::report(&format!("{name} (simd kernel)"), simd, macs, "MAC");
    println!("  {name:44} tiled vs naive {:>6.1}x | simd vs tiled {:>5.2}x",
             naive / tiled, tiled / simd);
    rows.push((json_key.to_string(), naive, tiled, simd));
}

fn main() {
    println!("=== bench hotpath (§Perf) ===");
    let mut rng = XorShift64::new(1);
    let mut rows: Vec<Row> = Vec::new();

    // L3a: resnet-shape conv (the heaviest functional-sim layer),
    // per kernel strategy.
    let x = Tensor::new((8, 32, 32, 16),
                        (0..8 * 32 * 32 * 16).map(|_| rng.next_f32_sym(1.0)).collect());
    let wdat: Vec<f32> = (0..3 * 3 * 16 * 16).map(|_| rng.next_f32_sym(1.0)).collect();
    let w = ConvW { data: &wdat, kh: 3, kw: 3, cin: 16, cout: 16 };
    let macs = 8.0 * 32.0 * 32.0 * 9.0 * 16.0 * 16.0;
    println!("functional conv 3x3 16->16 (B=8, 32x32), naive vs tiled vs simd:");
    for (name, key, kind) in [("f32 adder", "f32_adder", SimKernel::Adder),
                              ("f32 mult", "f32_mult", SimKernel::Mult)] {
        bench_strategy_trio(name, key, |strat| {
            std::hint::black_box(conv2d_with(strat, &x, &w, 1, nn::Padding::Same,
                                             kind));
        }, macs, &mut rows);
    }
    let calib = LayerCalib { feat_max_abs: 1.0, weight_max_abs: 1.0 };
    for (name, key, bits) in [("int8 adder", "int8_adder", 8u32),
                              ("int16 adder", "int16_adder", 16)] {
        let cfg = QuantCfg { bits, mode: Mode::SharedScale };
        bench_strategy_trio(name, key, |strat| {
            std::hint::black_box(conv2d_quant_with(
                strat, &x, &w, 1, nn::Padding::Same, SimKernel::Adder, cfg,
                &calib));
        }, macs, &mut rows);
    }
    write_json(&rows);

    // L3b: dataset generator
    let (med, _) = common::time_it(2, 10, || {
        std::hint::black_box(data::generate(256, 7, 0));
    });
    common::report("dataset generator (256 imgs)", med, 256.0, "img");

    // L3c: PJRT round-trips
    pjrt_round_trips();
}

/// Persist the per-strategy medians (seconds) + derived speedups.  No
/// JSON writer is vendored, so the record is assembled by hand — keys
/// and shape are part of the CI artifact contract.
fn write_json(rows: &[Row]) {
    let path = std::env::var("HOTPATH_JSON")
        .unwrap_or_else(|_| "target/hotpath.json".to_string());
    let mut entries = Vec::new();
    for (key, naive, tiled, simd) in rows {
        entries.push(format!(
            "    \"{key}\": {{\"naive_s\": {naive:.6e}, \"tiled_s\": {tiled:.6e}, \
             \"simd_s\": {simd:.6e}, \"tiled_vs_naive\": {:.3}, \
             \"simd_vs_tiled\": {:.3}}}",
            naive / tiled, tiled / simd));
    }
    let doc = format!(
        "{{\n  \"bench\": \"hotpath\",\n  \
         \"layer\": \"conv3x3 16->16 B=8 32x32 (resnet shape)\",\n  \
         \"kernel_env\": \"{}\",\n  \"results\": {{\n{}\n  }}\n}}\n",
        KernelStrategy::from_env().label(),
        entries.join(",\n"));
    if let Some(dir) = std::path::Path::new(&path).parent() {
        let _ = std::fs::create_dir_all(dir);
    }
    match std::fs::write(&path, doc) {
        Ok(()) => println!("  (per-strategy medians written to {path})"),
        Err(e) => eprintln!("  (could not write {path}: {e})"),
    }
}

#[cfg(feature = "pjrt")]
fn pjrt_round_trips() {
    use addernet::coordinator::{Manifest, Trainer};
    use addernet::runtime::Runtime;

    let art = std::path::Path::new("artifacts");
    if let Ok(manifest) = Manifest::load(art) {
        let mut rt = Runtime::new(art).unwrap();
        let mut trainer = Trainer::new(&manifest, &mut rt, "lenet5", "adder").unwrap();
        let mut stream = data::BatchStream::new(9, trainer.batch_size);
        let batch = stream.next_batch();
        let (med, _) = common::time_it(2, 10, || {
            trainer.train_step(&rt, &batch).unwrap();
        });
        common::report("PJRT train step (lenet5 adder, B=32)", med, 32.0, "img");

        let ev = data::eval_set(32, 5);
        let (med, _) = common::time_it(2, 10, || {
            std::hint::black_box(trainer.evaluate(&rt, &ev.images, &ev.labels).unwrap());
        });
        common::report("PJRT eval (lenet5 adder, B=32)", med, 32.0, "img");
    } else {
        println!("  (no artifacts/ — PJRT round-trip benches skipped)");
    }
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_round_trips() {
    println!("  (built without --features pjrt — PJRT round-trip benches skipped)");
}
