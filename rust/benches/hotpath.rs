//! §Perf hot-path microbenchmarks (the before/after log lives in
//! EXPERIMENTS.md §Perf):
//!
//!   L3a: functional adder/mult conv (f32 + int) — the quantized-
//!        inference datapath, measured as tiled parallel engine vs the
//!        retained naive reference (the oracle of
//!        tests/functional_oracle.rs); the speedup is recorded here;
//!   L3b: dataset generator (streams every training batch);
//!   L3c: PJRT execute round-trip (train step + eval) when artifacts
//!        are present and the crate is built with --features pjrt — the
//!        training/serving hot loop.

mod common;

use addernet::quant::{LayerCalib, Mode};
use addernet::sim::functional::{conv2d, conv2d_quant, ConvW, QuantCfg, SimKernel, Tensor};
use addernet::sim::reference;
use addernet::util::XorShift64;
use addernet::{data, nn};

fn main() {
    println!("=== bench hotpath (§Perf) ===");
    let mut rng = XorShift64::new(1);

    // L3a: resnet-shape conv (the heaviest functional-sim layer),
    // engine vs naive reference.
    let x = Tensor::new((8, 32, 32, 16),
                        (0..8 * 32 * 32 * 16).map(|_| rng.next_f32_sym(1.0)).collect());
    let wdat: Vec<f32> = (0..3 * 3 * 16 * 16).map(|_| rng.next_f32_sym(1.0)).collect();
    let w = ConvW { data: &wdat, kh: 3, kw: 3, cin: 16, cout: 16 };
    let macs = 8.0 * 32.0 * 32.0 * 9.0 * 16.0 * 16.0;
    println!("functional conv 3x3 16->16 (B=8, 32x32), engine vs naive reference:");
    for (name, kind) in [("f32 adder", SimKernel::Adder), ("f32 mult", SimKernel::Mult)] {
        let (naive, _) = common::time_it(1, 5, || {
            std::hint::black_box(reference::conv2d(&x, &w, 1, nn::Padding::Same, kind));
        });
        let (engine, _) = common::time_it(2, 8, || {
            std::hint::black_box(conv2d(&x, &w, 1, nn::Padding::Same, kind));
        });
        common::report(&format!("{name} (naive reference)"), naive, macs, "MAC");
        common::report(&format!("{name} (tiled engine)"), engine, macs, "MAC");
        println!("  {name:44} speedup {:>8.1}x", naive / engine);
    }
    let calib = LayerCalib { feat_max_abs: 1.0, weight_max_abs: 1.0 };
    for (name, bits) in [("int8 adder", 8u32), ("int16 adder", 16)] {
        let cfg = QuantCfg { bits, mode: Mode::SharedScale };
        let (naive, _) = common::time_it(1, 5, || {
            std::hint::black_box(reference::conv2d_quant(
                &x, &w, 1, nn::Padding::Same, SimKernel::Adder, cfg, &calib));
        });
        let (engine, _) = common::time_it(2, 8, || {
            std::hint::black_box(conv2d_quant(&x, &w, 1, nn::Padding::Same,
                                              SimKernel::Adder, cfg, &calib));
        });
        common::report(&format!("{name} (naive reference)"), naive, macs, "MAC");
        common::report(&format!("{name} (tiled engine)"), engine, macs, "MAC");
        println!("  {name:44} speedup {:>8.1}x", naive / engine);
    }

    // L3b: dataset generator
    let (med, _) = common::time_it(2, 10, || {
        std::hint::black_box(data::generate(256, 7, 0));
    });
    common::report("dataset generator (256 imgs)", med, 256.0, "img");

    // L3c: PJRT round-trips
    pjrt_round_trips();
}

#[cfg(feature = "pjrt")]
fn pjrt_round_trips() {
    use addernet::coordinator::{Manifest, Trainer};
    use addernet::runtime::Runtime;

    let art = std::path::Path::new("artifacts");
    if let Ok(manifest) = Manifest::load(art) {
        let mut rt = Runtime::new(art).unwrap();
        let mut trainer = Trainer::new(&manifest, &mut rt, "lenet5", "adder").unwrap();
        let mut stream = data::BatchStream::new(9, trainer.batch_size);
        let batch = stream.next_batch();
        let (med, _) = common::time_it(2, 10, || {
            trainer.train_step(&rt, &batch).unwrap();
        });
        common::report("PJRT train step (lenet5 adder, B=32)", med, 32.0, "img");

        let ev = data::eval_set(32, 5);
        let (med, _) = common::time_it(2, 10, || {
            std::hint::black_box(trainer.evaluate(&rt, &ev.images, &ev.labels).unwrap());
        });
        common::report("PJRT eval (lenet5 adder, B=32)", med, 32.0, "img");
    } else {
        println!("  (no artifacts/ — PJRT round-trip benches skipped)");
    }
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_round_trips() {
    println!("  (built without --features pjrt — PJRT round-trip benches skipped)");
}
