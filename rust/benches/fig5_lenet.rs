//! Bench E9 (Fig. 5): fully on-chip LeNet-5 accelerator — per-layer LUT
//! and energy savings at 16 and 8 bit, plus S1 scheme ablation.

mod common;

use addernet::hw::KernelKind;
use addernet::report::{fpga, kernels};
use addernet::sim::onchip;

fn main() {
    println!("=== bench fig5_lenet (E9/E16) ===");
    for t in fpga::fig5() {
        t.print();
    }
    kernels::s1().print();

    // ablation: deploying 1C1A instead of 2A in the Fig. 5 design
    println!("S1 ablation — Fig. 5 design with 1C1A vs 2A kernels (16-bit):");
    let a2 = onchip::design(KernelKind::Adder2A, 16);
    let c1a = onchip::design(KernelKind::Adder1C1A, 16);
    println!("  2A  : {} LUTs, {:.1} nJ/inference", a2.total_luts(),
             a2.total_energy_pj() / 1e3);
    println!("  1C1A: {} LUTs, {:.1} nJ/inference  ({:.1}% fewer LUTs, \
              longer critical path)",
             c1a.total_luts(), c1a.total_energy_pj() / 1e3,
             (1.0 - c1a.total_luts() as f64 / a2.total_luts() as f64) * 100.0);

    let (med, _) = common::time_it(3, 20, || {
        std::hint::black_box(onchip::savings(16));
        std::hint::black_box(onchip::savings(8));
    });
    common::report("onchip design model (2 widths)", med, 2.0, "design");
}
