//! Shared micro-bench harness (criterion is not in the offline vendored
//! set): median-of-N wall-clock timing with warm-up.
//!
//! The timing loop itself lives in `addernet::lab::measure` — ONE
//! implementation shared by the benches and the `repro lab` experiment
//! runner — and this module just re-exports it for the bench binaries.

/// Time `f` `iters` times after `warmup` runs; returns (median_s, mean_s).
pub fn time_it<F: FnMut()>(warmup: usize, iters: usize, f: F) -> (f64, f64) {
    addernet::lab::measure::time_it(warmup, iters, f)
}

/// Pretty-print one benchmark line.
pub fn report(name: &str, median_s: f64, work_items: f64, unit: &str) {
    let rate = work_items / median_s;
    let (val, scale) = if rate > 1e9 {
        (rate / 1e9, "G")
    } else if rate > 1e6 {
        (rate / 1e6, "M")
    } else if rate > 1e3 {
        (rate / 1e3, "K")
    } else {
        (rate, "")
    };
    println!("  {name:44} {:>10.3} ms   {val:>8.2} {scale}{unit}/s",
             median_s * 1e3);
}
