//! Shared micro-bench harness (criterion is not in the offline vendored
//! set): median-of-N wall-clock timing with warm-up.

use std::time::Instant;

/// Time `f` `iters` times after `warmup` runs; returns (median_s, mean_s).
pub fn time_it<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> (f64, f64) {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = samples[samples.len() / 2];
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    (median, mean)
}

/// Pretty-print one benchmark line.
pub fn report(name: &str, median_s: f64, work_items: f64, unit: &str) {
    let rate = work_items / median_s;
    let (val, scale) = if rate > 1e9 {
        (rate / 1e9, "G")
    } else if rate > 1e6 {
        (rate / 1e6, "M")
    } else if rate > 1e3 {
        (rate / 1e3, "K")
    } else {
        (rate, "")
    };
    println!("  {name:44} {:>10.3} ms   {val:>8.2} {scale}{unit}/s",
             median_s * 1e3);
}
