//! Bench E10/E11 (S4/S5): kernel energy and area tables, model vs the
//! paper's anchor cells, across all five kernel families.

use addernet::report::kernels;

fn main() {
    println!("=== bench s4_s5_tables (E10/E11) ===");
    kernels::s4().print();
    kernels::s5().print();
    kernels::fig2c().print();
}
