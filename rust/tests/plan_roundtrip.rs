//! Round-trip tests for the portable `QuantPlan` artifact
//! (`quant::plan::{plan_to_json, plan_from_json}` — the `repro plan` /
//! `repro serve --plan` cold-start path):
//!
//! * **export → import exactness** — the parsed plan equals the built
//!   plan field-for-field, and serves BIT-identical logits under every
//!   `KernelStrategy` (the whole pipeline is integer, so there is no
//!   tolerance to hide behind);
//! * **mutation grid** — truncated JSON, version bumps, arch mismatches,
//!   deleted layers, out-of-range exponents and out-of-grid quantized
//!   weights all surface as clean `anyhow` errors, never panics: a
//!   corrupt plan file must fail serving startup, not a worker thread.

use addernet::quant::plan::{plan_from_json, plan_to_json, QuantPlan};
use addernet::quant::Mode;
use addernet::report::quantrep;
use addernet::sim::functional::{synth_params, Arch, KernelStrategy, Params,
                                QuantCfg, SimKernel, Tensor};
use addernet::sim::intpath::PlanRunner;
use addernet::util::{Json, XorShift64};

const STRATEGIES: [KernelStrategy; 4] = [
    KernelStrategy::Naive,
    KernelStrategy::Tiled,
    KernelStrategy::Simd,
    KernelStrategy::Auto,
];

fn built_plan(arch: Arch, bits: u32) -> (Params, QuantPlan) {
    let params = synth_params(arch, 42);
    let (calib, _) = quantrep::calibrate(&params, arch, SimKernel::Adder, 16);
    let cfg = QuantCfg { bits, mode: Mode::SharedScale };
    let plan = QuantPlan::build(&params, arch, SimKernel::Adder, cfg, &calib)
        .unwrap();
    (params, plan)
}

fn err_of(s: &str) -> String {
    match plan_from_json(s) {
        Ok(_) => panic!("corrupt plan imported cleanly"),
        Err(e) => format!("{e:#}"),
    }
}

#[test]
fn export_import_is_field_exact_for_every_arch_and_width() {
    for arch in [Arch::Lenet5, Arch::Cnv6, Arch::Resnet8] {
        for bits in [8u32, 16] {
            let (_, plan) = built_plan(arch, bits);
            let doc = plan_to_json(&plan);
            let back = plan_from_json(&doc)
                .unwrap_or_else(|e| panic!("{arch:?} int{bits}: {e:#}"));
            assert_eq!(back, plan, "{arch:?} int{bits}");
        }
    }
}

#[test]
fn imported_plan_serves_bit_identically_across_strategies() {
    for (arch, bits) in [(Arch::Lenet5, 8u32), (Arch::Lenet5, 16),
                         (Arch::Resnet8, 8)] {
        let (_, plan) = built_plan(arch, bits);
        let imported = plan_from_json(&plan_to_json(&plan)).unwrap();
        let mut rng = XorShift64::new(77);
        let x = Tensor::new((2, 32, 32, 1),
                            (0..2048).map(|_| rng.next_f32_sym(1.0)).collect());
        for strat in STRATEGIES {
            let want = PlanRunner { plan: &plan, strategy: strat }.forward(&x);
            let got = PlanRunner { plan: &imported, strategy: strat }
                .forward(&x);
            assert_eq!(got.shape, want.shape,
                       "{arch:?} int{bits} [{}]", strat.label());
            assert_eq!(got.data, want.data,
                       "{arch:?} int{bits} [{}]: imported plan must serve \
                        bit-identical logits", strat.label());
        }
    }
}

#[test]
fn truncated_json_errors_cleanly() {
    let (_, plan) = built_plan(Arch::Lenet5, 8);
    let doc = plan_to_json(&plan);
    for cut in [0, 1, 10, doc.len() / 2, doc.len() - 2] {
        assert!(plan_from_json(&doc[..cut]).is_err(), "cut at {cut}");
    }
    assert!(plan_from_json("").is_err());
    assert!(plan_from_json("nonsense").is_err());
    assert!(plan_from_json("{\"quant_plan\": 3}").is_err());
}

#[test]
fn version_bump_errors_cleanly() {
    let (_, plan) = built_plan(Arch::Lenet5, 8);
    let doc = plan_to_json(&plan);
    assert!(doc.contains("\"version\": 1"), "serializer format drifted");
    let err = err_of(&doc.replace("\"version\": 1", "\"version\": 2"));
    assert!(err.contains("version"), "{err}");
}

#[test]
fn arch_mismatch_errors_cleanly() {
    // a lenet5 plan relabelled as resnet8 has none of resnet8's layers
    let (_, plan) = built_plan(Arch::Lenet5, 8);
    let doc = plan_to_json(&plan);
    let err = err_of(&doc.replace("\"arch\": \"lenet5\"",
                                  "\"arch\": \"resnet8\""));
    assert!(err.contains("mismatch") || err.contains("missing"), "{err}");
    // and an arch this build does not serve at all
    let err = err_of(&doc.replace("\"arch\": \"lenet5\"",
                                  "\"arch\": \"lenet9000\""));
    assert!(err.contains("unknown arch"), "{err}");
}

/// Parse-level surgery: reserialize the JSON with one field mangled, so
/// the mutation hits exactly the target (string replacement cannot
/// reliably single out one layer's field).
fn mutate(doc: &str, f: impl FnOnce(&mut std::collections::BTreeMap<String, Json>))
          -> String {
    let parsed = Json::parse(doc).unwrap();
    let mut root = match parsed {
        Json::Obj(m) => m,
        _ => panic!("plan JSON is not an object"),
    };
    let mut qp = match root.remove("quant_plan").unwrap() {
        Json::Obj(m) => m,
        _ => panic!("quant_plan is not an object"),
    };
    f(&mut qp);
    root.insert("quant_plan".into(), Json::Obj(qp));
    Json::Obj(root).to_string()
}

fn layer_mut<'m>(qp: &'m mut std::collections::BTreeMap<String, Json>,
                 section: &str, layer: &str)
                 -> &'m mut std::collections::BTreeMap<String, Json> {
    match qp.get_mut(section).unwrap() {
        Json::Obj(layers) => match layers.get_mut(layer).unwrap() {
            Json::Obj(o) => o,
            _ => panic!("{layer} is not an object"),
        },
        _ => panic!("{section} is not an object"),
    }
}

#[test]
fn missing_layer_errors_cleanly() {
    let (_, plan) = built_plan(Arch::Lenet5, 8);
    let doc = plan_to_json(&plan);
    let err = err_of(&mutate(&doc, |qp| {
        if let Json::Obj(layers) = qp.get_mut("convs").unwrap() {
            layers.remove("conv2").unwrap();
        }
    }));
    assert!(err.contains("conv2") || err.contains("conv layers"), "{err}");
    let err = err_of(&mutate(&doc, |qp| {
        if let Json::Obj(layers) = qp.get_mut("dense").unwrap() {
            layers.remove("fc2").unwrap();
        }
    }));
    assert!(err.contains("fc2") || err.contains("dense layers"), "{err}");
}

#[test]
fn out_of_range_exponents_and_shifts_error_cleanly() {
    let (_, plan) = built_plan(Arch::Lenet5, 8);
    let doc = plan_to_json(&plan);
    // top-level input grid
    let err = err_of(&mutate(&doc, |qp| {
        qp.insert("input_exp".into(), Json::Num(999.0));
    }));
    assert!(err.contains("out of range") || err.contains("does not match"),
            "{err}");
    // a conv operand grid
    let err = err_of(&mutate(&doc, |qp| {
        layer_mut(qp, "convs", "conv2").insert("in_exp".into(),
                                               Json::Num(-700.0));
    }));
    assert!(err.contains("out of range"), "{err}");
    // the folded-BN shifter width
    let err = err_of(&mutate(&doc, |qp| {
        layer_mut(qp, "convs", "conv1").insert("bn_shift".into(),
                                               Json::Num(63.0));
    }));
    assert!(err.contains("bn_shift"), "{err}");
    // a dense accumulator grid inconsistent with in_exp + w_exp
    let err = err_of(&mutate(&doc, |qp| {
        layer_mut(qp, "dense", "fc1").insert("acc_exp".into(),
                                             Json::Num(0.0));
    }));
    assert!(err.contains("accumulator grid"), "{err}");
}

#[test]
fn out_of_grid_weights_and_geometry_drift_error_cleanly() {
    let (_, plan) = built_plan(Arch::Lenet5, 8);
    let doc = plan_to_json(&plan);
    // an int8 plan smuggling a 100000-valued weight
    let err = err_of(&mutate(&doc, |qp| {
        let o = layer_mut(qp, "convs", "conv1");
        if let Json::Arr(wq) = o.get_mut("wq").unwrap() {
            wq[0] = Json::Num(100000.0);
        }
    }));
    assert!(err.contains("outside the int grid"), "{err}");
    // geometry drift: conv1 claiming a different channel count
    let err = err_of(&mutate(&doc, |qp| {
        layer_mut(qp, "convs", "conv1").insert("cout".into(), Json::Num(7.0));
    }));
    assert!(err.contains("geometry"), "{err}");
    // a non-integer number where the integer grid lives
    let err = err_of(&mutate(&doc, |qp| {
        let o = layer_mut(qp, "dense", "fc3");
        if let Json::Arr(bq) = o.get_mut("bq").unwrap() {
            bq[0] = Json::Num(1.5);
        }
    }));
    assert!(err.contains("integer"), "{err}");
}

#[test]
fn overflowing_bn_multiplier_errors_cleanly() {
    // fold_bn keeps |mul| <= 2^30; a corrupt 2^45 multiplier would
    // overflow the executor's i64 `acc * mul` product and must be
    // refused at import, not wrap at serve time.
    let (_, plan) = built_plan(Arch::Lenet5, 8);
    let doc = plan_to_json(&plan);
    let err = err_of(&mutate(&doc, |qp| {
        let o = layer_mut(qp, "convs", "conv1");
        if let Json::Arr(mul) = o.get_mut("bn_mul").unwrap() {
            mul[0] = Json::Num((1i64 << 45) as f64);
        }
    }));
    assert!(err.contains("bn_mul"), "{err}");
}

#[test]
fn diverging_residual_grids_error_cleanly() {
    // the executor adds main-path and shortcut activations WITHOUT a
    // requant step, so an imported plan whose projection shortcut lands
    // on a different grid must be refused (build guarantees equality;
    // untrusted files must re-prove it).
    let (_, plan) = built_plan(Arch::Resnet8, 8);
    let doc = plan_to_json(&plan);
    let shifted = plan.convs["s1b0/sc"].out_exp + 3;
    let err = err_of(&mutate(&doc, |qp| {
        layer_mut(qp, "convs", "s1b0/sc")
            .insert("out_exp".into(), Json::Num(shifted as f64));
    }));
    assert!(err.contains("residual partners"), "{err}");
}

#[test]
fn wide_mult_plans_refused_at_import() {
    // hand-forge the headers of an int16 MULT plan: it must be refused
    // before any layer validation work happens
    let (_, plan) = built_plan(Arch::Lenet5, 16);
    let doc = plan_to_json(&plan);
    let err = err_of(&doc.replace("\"kind\": \"adder\"", "\"kind\": \"mult\""));
    assert!(err.contains("mult"), "{err}");
}
