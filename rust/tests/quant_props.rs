//! Property-style tests (seeded `XorShift64`) for the shared-scaling
//! quantization layer (paper §3.1): round-half-to-even behaviour,
//! quantize/dequantize round-trip bounds, scale-exponent coverage of the
//! joint range, the SharedScale-vs-SeparateScale adder-kernel
//! divergence the S7 experiment contrasts, and the plan compiler's
//! integer primitives — requantization boundaries round to even exactly
//! like the float reference, and power-of-two BN scales fold EXACTLY
//! (the shift-not-multiply hardware claim).  The cross-`KernelStrategy`
//! coverage of the same machinery lives in `tests/intpath_oracle.rs`,
//! which pins the folded pipeline per strategy.

use addernet::nn::Padding;
use addernet::quant::plan::{div_round_even, fold_bn, requant_shift};
use addernet::quant::{
    self, dequantize, qmax, quantize, round_even, scale_exp, LayerCalib, Mode,
};
use addernet::sim::functional::{conv2d, conv2d_quant, ConvW, QuantCfg, SimKernel, Tensor};
use addernet::util::XorShift64;

#[test]
fn round_even_halfway_grid() {
    // Every k + 0.5 halfway case in a wide integer range must land on
    // the EVEN neighbour (numpy/jnp.round semantics).
    for k in -200i32..200 {
        let x = k as f32 + 0.5;
        let r = round_even(x);
        assert_eq!(r as i64 % 2, 0, "round_even({x}) = {r} is odd");
        assert!((r - x).abs() <= 0.5 + 1e-6, "round_even({x}) = {r} too far");
    }
}

#[test]
fn round_even_matches_nearest_off_halfway() {
    // Away from halfway points round_even is plain nearest-int rounding.
    let mut rng = XorShift64::new(11);
    for _ in 0..2000 {
        let x = rng.next_f32_sym(500.0);
        if (x - x.trunc()).abs() == 0.5 {
            continue;
        }
        assert_eq!(round_even(x), x.round(), "x = {x}");
    }
}

#[test]
fn quantize_dequantize_round_trip_bounded() {
    // |dequantize(quantize(x)) - x| <= half a grid step for every x the
    // chosen exponent covers, at several widths and ranges.
    let mut rng = XorShift64::new(22);
    for bits in [4u32, 8, 16] {
        for max_abs in [0.37f32, 1.9, 77.0] {
            let e = scale_exp(max_abs, bits);
            let step = (e as f32).exp2();
            for _ in 0..200 {
                let x = rng.next_f32_sym(max_abs);
                let q = quantize(x, e, bits);
                assert!(q.abs() <= qmax(bits), "bits {bits}: q {q} out of grid");
                let back = dequantize(q, e);
                assert!((back - x).abs() <= step / 2.0 + max_abs * 1e-6,
                        "bits {bits} max {max_abs}: {x} -> {back}");
            }
        }
    }
}

#[test]
fn scale_exp_covers_joint_range() {
    // The shared exponent must cover max(feat, weight) and be minimal;
    // the separate exponents never exceed it.
    let mut rng = XorShift64::new(33);
    for bits in [4u32, 8, 16] {
        for _ in 0..100 {
            let feat = (rng.next_f32_sym(6.0)).exp2();
            let weight = (rng.next_f32_sym(6.0)).exp2();
            let c = LayerCalib { feat_max_abs: feat, weight_max_abs: weight };
            let e = c.shared_exp(bits);
            let cover = qmax(bits) as f32 * (e as f32).exp2();
            assert!(cover >= feat.max(weight),
                    "bits {bits}: 2^{e} grid misses {}", feat.max(weight));
            let under = qmax(bits) as f32 * ((e - 1) as f32).exp2();
            assert!(under < feat.max(weight), "bits {bits}: exponent {e} not minimal");
            let (ef, ew) = c.separate_exps(bits);
            assert!(ef <= e && ew <= e);
        }
    }
}

#[test]
fn quantize_slice_matches_scalar() {
    let mut rng = XorShift64::new(44);
    let xs: Vec<f32> = (0..500).map(|_| rng.next_f32_sym(3.0)).collect();
    let q = quant::quantize_slice(&xs, -3, 8);
    for (x, qq) in xs.iter().zip(&q) {
        assert_eq!(*qq, quantize(*x, -3, 8));
    }
}

/// The §S7 contrast on random layers: when feature and weight ranges
/// diverge (here 8x), the CNN-style separate-scale mode forces the adder
/// datapath to point-align (losing bits), so its error vs the f32
/// reference cannot be meaningfully better than the paper's shared
/// scale — aggregated across layers to keep the property robust.
#[test]
fn shared_vs_separate_scale_adder_divergence() {
    let mut shared_sum = 0f64;
    let mut separate_sum = 0f64;
    for seed in [5u64, 17, 91] {
        let mut rng = XorShift64::new(seed);
        let x = Tensor::new((1, 6, 6, 2),
                            (0..72).map(|_| rng.next_f32_sym(0.25)).collect());
        let wdat: Vec<f32> = (0..3 * 3 * 2 * 3).map(|_| rng.next_f32_sym(2.0)).collect();
        let w = ConvW { data: &wdat, kh: 3, kw: 3, cin: 2, cout: 3 };
        let fref = conv2d(&x, &w, 1, Padding::Same, SimKernel::Adder);
        let calib = LayerCalib { feat_max_abs: 0.25, weight_max_abs: 2.0 };
        let mean_err = |mode: Mode| -> f64 {
            let cfg = QuantCfg { bits: 6, mode };
            let q = conv2d_quant(&x, &w, 1, Padding::Same, SimKernel::Adder, cfg,
                                 &calib);
            q.data.iter().zip(&fref.data)
                .map(|(a, b)| ((a - b) as f64).abs())
                .sum::<f64>() / q.data.len() as f64
        };
        shared_sum += mean_err(Mode::SharedScale);
        separate_sum += mean_err(Mode::SeparateScale);
    }
    assert!(shared_sum > 0.0, "6-bit quantization should not be exact");
    assert!(separate_sum >= 0.8 * shared_sum,
            "separate-then-align ({separate_sum}) should not beat shared \
             ({shared_sum}) for the adder kernel");
}

/// Integer requantization (the plan path's inter-layer pow2 shift) must
/// round half to even EXACTLY like the float reference at every
/// boundary — otherwise the int path drifts from the per-call path one
/// half-step at a time.
#[test]
fn requant_shift_rounds_to_even_like_float_reference() {
    // exhaustive small range: every halfway case for shifts 0..=8
    for s in 0..=8i32 {
        let step = (s as f32).exp2();
        for v in -2048i64..=2048 {
            let want = round_even(v as f32 / step) as i64;
            assert_eq!(requant_shift(v, s), want, "v={v} s={s}");
        }
    }
    // random wide values, still exactly representable in f32
    let mut rng = XorShift64::new(77);
    for s in 0..=12i32 {
        let step = (s as f32).exp2();
        for _ in 0..500 {
            let v = (rng.next_f32_sym(1.0) * (1i64 << 22) as f32) as i64;
            let want = round_even(v as f32 / step) as i64;
            assert_eq!(requant_shift(v, s), want, "v={v} s={s}");
        }
    }
    // general divisors (the non-pow2 global-average-pool case)
    for d in [3i64, 5, 6, 7, 9, 12] {
        for n in -500i64..=500 {
            let want = round_even(n as f32 / d as f32) as i64;
            assert_eq!(div_round_even(n, d), want, "n={n} d={d}");
        }
    }
}

/// Negative shifts (moving onto a FINER grid) are exact: requantizing
/// down and back up is the identity.
#[test]
fn requant_shift_finer_grid_is_exact() {
    let mut rng = XorShift64::new(88);
    for _ in 0..1000 {
        let v = (rng.next_f32_sym(1.0) * 1e6) as i64;
        for k in 1..=8i32 {
            assert_eq!(requant_shift(requant_shift(v, -k), k), v, "v={v} k={k}");
        }
    }
}

/// BN-fold exactness: when the BN scale is an exact power of two
/// (gamma = sqrt(var+eps) * 2^k) and the shift sits on the output grid
/// (beta = t * 2^out_exp, mean = 0), the folded integer BN reproduces
/// `acc * 2^(k + acc_exp - out_exp) + t` with NO rounding anywhere —
/// the multiplier degenerates to a shift, which is the §3 minimalist-
/// hardware argument executed in software.
#[test]
fn bn_fold_exact_for_pow2_scales() {
    let mut rng = XorShift64::new(55);
    let eps = 1e-5f32;
    for case in 0..100 {
        let k = (case % 5) as i32 - 2; // -2..=2
        let acc_exp = -((case % 7) as i32) - 1; // -7..=-1
        let d = (case % 3) as i32; // k + acc_exp - out_exp in 0..=2
        let out_exp = acc_exp + k - d;
        let var = rng.next_f32_sym(1.0).abs() + 0.5;
        let gamma = (var + eps).sqrt() * (k as f32).exp2();
        let t = (rng.next_f32_sym(1.0) * 50.0) as i64; // integer shift
        let beta = t as f32 * (out_exp as f32).exp2();
        let fold = fold_bn(&[gamma], &[beta], &[0.0], &[var], acc_exp, out_exp)
            .unwrap();
        for acc in [-2000i32, -64, -3, 0, 1, 17, 500, 1999] {
            let want = acc as i64 * (1i64 << d) + t;
            assert_eq!(fold.apply(acc, 0, 32767) as i64, want,
                       "case {case}: k={k} d={d} acc={acc}");
        }
    }
}

/// For the mult kernel separate scales are the natural choice: both
/// modes stay finite and the separate mode tracks the f32 reference.
#[test]
fn separate_scale_sane_for_mult_kernel() {
    let mut rng = XorShift64::new(61);
    let x = Tensor::new((1, 6, 6, 2),
                        (0..72).map(|_| rng.next_f32_sym(0.25)).collect());
    let wdat: Vec<f32> = (0..3 * 3 * 2 * 3).map(|_| rng.next_f32_sym(2.0)).collect();
    let w = ConvW { data: &wdat, kh: 3, kw: 3, cin: 2, cout: 3 };
    let fref = conv2d(&x, &w, 1, Padding::Same, SimKernel::Mult);
    let calib = LayerCalib { feat_max_abs: 0.25, weight_max_abs: 2.0 };
    let cfg = QuantCfg { bits: 8, mode: Mode::SeparateScale };
    let q = conv2d_quant(&x, &w, 1, Padding::Same, SimKernel::Mult, cfg, &calib);
    let denom: f64 = fref.data.iter().map(|v| (*v as f64).abs()).sum::<f64>()
        / fref.data.len() as f64;
    let err: f64 = q.data.iter().zip(&fref.data)
        .map(|(a, b)| ((a - b) as f64).abs())
        .sum::<f64>() / q.data.len() as f64;
    assert!(err <= 0.25 * denom.max(1e-3),
            "int8 separate-scale mult err {err} vs signal {denom}");
}

/// Grid-chaining property over the layer-graph IR: for EVERY registered
/// runtime architecture, a compiled plan's requantization chain is
/// closed — each conv lands its activations exactly on the operand grid
/// of the conv that consumes them (grid-preserving ops in between), and
/// the two inputs of every residual add sit on one grid.  This is the
/// shift-only inter-layer datapath claim, stated over the op program
/// instead of per-architecture.
#[test]
fn plan_grids_chain_over_every_graph_arch() {
    use addernet::nn::graph::{Arch, Op};
    use addernet::quant::{Calibration, QuantPlan};
    use addernet::sim::functional::synth_params;

    for arch in Arch::ALL {
        let params = synth_params(arch, 17);
        // deliberately NON-uniform ranges so consecutive layers sit on
        // different grids and the chain actually has to requantize
        let calib: Calibration = params.keys()
            .filter_map(|k| k.strip_suffix("/conv_w"))
            .enumerate()
            .map(|(i, n)| (n.to_string(), LayerCalib {
                feat_max_abs: 0.5 * ((i % 4) + 1) as f32,
                weight_max_abs: 0.5,
            }))
            .collect();
        let cfg = QuantCfg { bits: 8, mode: Mode::SharedScale };
        let plan = QuantPlan::build(&params, arch, SimKernel::Adder, cfg,
                                    &calib).unwrap();
        // walk the program tracking the live grid; pools/relu/flatten
        // preserve it, convs must consume exactly what the chain holds
        let mut grid: Option<i32> = Some(plan.input_exp);
        let mut saved: Vec<Option<i32>> = Vec::new();
        for op in &arch.graph().ops {
            match op {
                Op::ConvBn(c) => {
                    let cp = &plan.convs[&c.name];
                    assert_eq!(Some(cp.in_exp), grid,
                               "{}: {} consumes a grid nobody produced",
                               arch.name(), c.name);
                    grid = Some(cp.out_exp);
                }
                Op::ResidualOpen => saved.push(grid),
                Op::ResidualClose { shortcut } => {
                    let at_open = saved.pop().unwrap();
                    assert!(at_open.is_some(), "{}: open inside the head",
                            arch.name());
                    if let Some(c) = shortcut {
                        // the projection conv may shift its INPUT onto
                        // its own operand grid (the executor requantizes
                        // at conv entry), but its OUTPUT must land on
                        // the main path's grid: the add is single-grid
                        let cp = &plan.convs[&c.name];
                        assert_eq!(Some(cp.out_exp), grid,
                                   "{}: residual partners diverge at {}",
                                   arch.name(), c.name);
                    }
                    // identity shortcuts are shifted onto `grid` by the
                    // executor, so the add is single-grid either way
                }
                Op::Dense(_) => grid = None, // f32 head: grid-free
                _ => {}
            }
        }
    }
}
