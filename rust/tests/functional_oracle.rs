//! Oracle tests: the tiled multi-threaded functional engine vs the
//! retained naive reference (`addernet::sim::reference`) across a grid
//! of shapes — kernels 1x1/3x3/5x5, strides 1-2, Same/Valid padding,
//! channel counts that do and don't divide the engine tiles, batch 1
//! and 8.  f32 within 1e-5 (relative), integer path bit-identical.

use addernet::nn::Padding;
use addernet::quant::{LayerCalib, Mode};
use addernet::sim::functional::{
    self, conv2d, conv2d_quant, dense, Arch, ConvW, ExecMode, QuantCfg, Runner,
    SimKernel, Tensor,
};
use addernet::sim::reference;
use addernet::util::XorShift64;

fn rand_vec(rng: &mut XorShift64, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| rng.next_f32_sym(scale)).collect()
}

fn assert_close(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!((x - y).abs() <= 1e-5 * y.abs().max(1.0),
                "{what}: element {i}: engine {x} vs reference {y}");
    }
}

/// Shape grid shared by the f32 and integer sweeps.  Channel pairs
/// include counts far below, equal to, and not divisible by the
/// engine's 64-wide output tile and 4-wide column tile.
fn shape_grid() -> Vec<(usize, usize, usize, usize, usize, usize, Padding)> {
    // (h, w, k, stride, cin, cout, padding)
    let mut grid = Vec::new();
    for &k in &[1usize, 3, 5] {
        for &stride in &[1usize, 2] {
            for &padding in &[Padding::Same, Padding::Valid] {
                for &(cin, cout) in &[(1usize, 1usize), (3, 5), (16, 16), (7, 13)] {
                    grid.push((8, 8, k, stride, cin, cout, padding));
                }
            }
        }
    }
    // odd spatial extents exercise the SAME-padding borders + remainders
    grid.push((9, 7, 3, 2, 4, 66, Padding::Same));
    grid.push((11, 5, 5, 1, 2, 65, Padding::Valid));
    grid
}

#[test]
fn conv2d_f32_matches_reference_grid() {
    let mut rng = XorShift64::new(101);
    for (h, w, k, stride, cin, cout, padding) in shape_grid() {
        for batch in [1usize, 8] {
            let x = Tensor::new((batch, h, w, cin),
                                rand_vec(&mut rng, batch * h * w * cin, 1.5));
            let wdat = rand_vec(&mut rng, k * k * cin * cout, 1.0);
            let cw = ConvW { data: &wdat, kh: k, kw: k, cin, cout };
            for kind in [SimKernel::Adder, SimKernel::Mult] {
                let got = conv2d(&x, &cw, stride, padding, kind);
                let want = reference::conv2d(&x, &cw, stride, padding, kind);
                assert_eq!(got.shape, want.shape);
                assert_close(&got.data, &want.data,
                             &format!("f32 {kind:?} k{k} s{stride} {padding:?} \
                                       {cin}->{cout} b{batch}"));
            }
        }
    }
}

#[test]
fn conv2d_quant_bit_identical_to_reference() {
    let mut rng = XorShift64::new(202);
    let calib = LayerCalib { feat_max_abs: 1.5, weight_max_abs: 1.0 };
    for (h, w, k, stride, cin, cout, padding) in shape_grid() {
        for batch in [1usize, 8] {
            let x = Tensor::new((batch, h, w, cin),
                                rand_vec(&mut rng, batch * h * w * cin, 1.5));
            let wdat = rand_vec(&mut rng, k * k * cin * cout, 1.0);
            let cw = ConvW { data: &wdat, kh: k, kw: k, cin, cout };
            for kind in [SimKernel::Adder, SimKernel::Mult] {
                for bits in [8u32, 16] {
                    let cfg = QuantCfg { bits, mode: Mode::SharedScale };
                    let got = conv2d_quant(&x, &cw, stride, padding, kind, cfg, &calib);
                    let want = reference::conv2d_quant(&x, &cw, stride, padding,
                                                       kind, cfg, &calib);
                    assert_eq!(got.shape, want.shape);
                    // integer accumulation is order-independent: the
                    // engine must be EXACTLY the reference.
                    assert_eq!(got.data, want.data,
                               "int{bits} {kind:?} k{k} s{stride} {padding:?} \
                                {cin}->{cout} b{batch}");
                }
            }
        }
    }
}

#[test]
fn conv2d_quant_separate_scale_bit_identical() {
    // The point-alignment (regrid) path of the separate-scale adder mode
    // must also agree bit-exactly between engine and reference.
    let mut rng = XorShift64::new(303);
    let calib = LayerCalib { feat_max_abs: 0.25, weight_max_abs: 2.0 };
    let x = Tensor::new((2, 8, 8, 3), rand_vec(&mut rng, 2 * 8 * 8 * 3, 0.25));
    let wdat = rand_vec(&mut rng, 3 * 3 * 3 * 7, 2.0);
    let cw = ConvW { data: &wdat, kh: 3, kw: 3, cin: 3, cout: 7 };
    for kind in [SimKernel::Adder, SimKernel::Mult] {
        for bits in [6u32, 8] {
            let cfg = QuantCfg { bits, mode: Mode::SeparateScale };
            let got = conv2d_quant(&x, &cw, 1, Padding::Same, kind, cfg, &calib);
            let want = reference::conv2d_quant(&x, &cw, 1, Padding::Same, kind,
                                               cfg, &calib);
            assert_eq!(got.data, want.data, "separate {kind:?} int{bits}");
        }
    }
}

#[test]
fn dense_matches_reference() {
    let mut rng = XorShift64::new(404);
    for (n, din, dout) in [(1usize, 37usize, 13usize), (8, 400, 120), (3, 64, 130)] {
        let x = Tensor::new((n, 1, 1, din), rand_vec(&mut rng, n * din, 1.0));
        let w = rand_vec(&mut rng, din * dout, 0.7);
        let bias = rand_vec(&mut rng, dout, 0.3);
        let got = dense(&x, &w, &bias, dout);
        let want = reference::dense(&x, &w, &bias, dout);
        assert_eq!(got.shape, want.shape);
        assert_close(&got.data, &want.data, &format!("dense {n}x{din}->{dout}"));
    }
}

#[test]
fn dense_handles_zero_activations() {
    // The sparse-skip in the reference and the engine must agree when
    // activations contain exact zeros (post-ReLU reality).
    let x = Tensor::new((2, 1, 1, 6),
                        vec![0.0, 1.0, 0.0, -2.0, 0.0, 0.5,
                             0.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
    let mut rng = XorShift64::new(505);
    let w = rand_vec(&mut rng, 6 * 9, 1.0);
    let bias = rand_vec(&mut rng, 9, 1.0);
    let got = dense(&x, &w, &bias, 9);
    let want = reference::dense(&x, &w, &bias, 9);
    assert_close(&got.data, &want.data, "dense with zeros");
    // the all-zero row must reduce to the bias
    assert_close(&got.data[9..], &bias, "all-zero row == bias");
}

#[test]
fn engine_thread_count_does_not_change_results() {
    // Same conv on the parallel path vs a big enough workload to engage
    // multiple threads: determinism is part of the engine contract.
    let mut rng = XorShift64::new(606);
    let x = Tensor::new((4, 32, 32, 16), rand_vec(&mut rng, 4 * 32 * 32 * 16, 1.0));
    let wdat = rand_vec(&mut rng, 3 * 3 * 16 * 16, 1.0);
    let cw = ConvW { data: &wdat, kh: 3, kw: 3, cin: 16, cout: 16 };
    let a = conv2d(&x, &cw, 1, Padding::Same, SimKernel::Adder);
    let b = conv2d(&x, &cw, 1, Padding::Same, SimKernel::Adder);
    assert_eq!(a.data, b.data);
    let want = reference::conv2d(&x, &cw, 1, Padding::Same, SimKernel::Adder);
    assert_close(&a.data, &want.data, "large parallel conv");
}

#[test]
fn quantized_forward_runs_on_synthetic_params() {
    // End-to-end: calibrate + quantized forward through the engine on
    // synthetic weights, fully offline.
    let params = functional::synth_params(Arch::Lenet5, 77);
    let mut rng = XorShift64::new(707);
    let x = Tensor::new((4, 32, 32, 1), rand_vec(&mut rng, 4 * 1024, 1.0));
    let mut calib = addernet::quant::Calibration::new();
    {
        let mut r = Runner {
            params: &params, arch: Arch::Lenet5, kind: SimKernel::Adder,
            mode: ExecMode::F32, calib: None, observe: Some(&mut calib),
        };
        r.forward(&x);
    }
    assert!(calib.contains_key("conv1") && calib.contains_key("conv2"));
    let cfg = QuantCfg { bits: 8, mode: Mode::SharedScale };
    let mut rq = Runner {
        params: &params, arch: Arch::Lenet5, kind: SimKernel::Adder,
        mode: ExecMode::Quant(cfg), calib: Some(&calib), observe: None,
    };
    let y = rq.forward(&x);
    assert_eq!(y.shape, (4, 1, 1, 10));
    assert!(y.data.iter().all(|v| v.is_finite()));
}
