//! Oracle tests: every kernel strategy (`Naive`, `Tiled`, `Simd`,
//! `Winograd`, plus the `Auto` selector) vs the retained naive
//! reference (`addernet::sim::reference`).
//!
//! `Winograd` rides the same grids as the row strategies: on eligible
//! integer mult convs (3x3/stride-1) it takes the exact transform-
//! domain path, everywhere else (f32, adder without the l1 opt-in,
//! ineligible shapes) it falls back to the Auto heuristic's pick — so
//! the bit-identity contract below covers both the transform and the
//! shape guard.
//!
//! Three tiers:
//! * a deterministic shape grid — kernels 1x1/3x3/5x5, strides 1-2,
//!   Same/Valid padding, channel counts that do and don't divide the
//!   tiled 64-wide and simd 8-wide blocks, batch 1 and 8;
//! * an edge grid — 1x1 kernels, stride 3, kernels larger than the
//!   input (all-padding rows / zero-output VALID), single-channel and
//!   single-batch degenerates;
//! * a randomized LCG-driven fuzz pass (~50 configs, no external
//!   deps) over shape/stride/padding/bit-width.
//!
//! Contract: f32 within 1e-4 of the reference (all strategies
//! accumulate taps in the same (ky, kx, ci) order, so in practice they
//! are far tighter), integer path **bit-identical** for every
//! `SimKernel` kind.

use addernet::nn::Padding;
use addernet::quant::{LayerCalib, Mode};
use addernet::sim::functional::{
    self, conv2d_quant_with, conv2d_with, dense, dense_with, Arch, ConvW, ExecMode,
    KernelStrategy, QuantCfg, Runner, SimKernel, Tensor,
};
use addernet::sim::reference;
use addernet::util::XorShift64;

/// The concrete strategies pinned against the reference.  `Naive`
/// dispatches *to* the reference, so its rows double as a dispatch
/// test; `Tiled`, `Simd` and `Winograd` are the real subjects.
const STRATEGIES: [KernelStrategy; 5] = [
    KernelStrategy::Naive,
    KernelStrategy::Tiled,
    KernelStrategy::Simd,
    KernelStrategy::Winograd,
    KernelStrategy::Auto,
];

fn rand_vec(rng: &mut XorShift64, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| rng.next_f32_sym(scale)).collect()
}

fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!((x - y).abs() <= tol * y.abs().max(1.0),
                "{what}: element {i}: strategy {x} vs reference {y}");
    }
}

/// One conv case checked across every strategy: f32 within `tol`,
/// integer path (for each of `bits`) bit-identical.  The single
/// comparison loop every test tier (grids, edge cases, fuzz) goes
/// through.
#[allow(clippy::too_many_arguments)]
fn check_all_strategies(x: &Tensor, cw: &ConvW, stride: usize, padding: Padding,
                        kind: SimKernel, tol: f32, bits: &[u32], mode: Mode,
                        calib: &LayerCalib, what: &str) {
    let want = reference::conv2d(x, cw, stride, padding, kind);
    for strat in STRATEGIES {
        let got = conv2d_with(strat, x, cw, stride, padding, kind);
        assert_eq!(got.shape, want.shape, "{what} [{}]", strat.label());
        assert_close(&got.data, &want.data, tol,
                     &format!("{what} [f32 {}]", strat.label()));
    }
    for &b in bits {
        // kernel/width policy (QuantPlan::supports, enforced by every
        // model-level path): mult integer convs cap at 8-bit operands —
        // their tap products can overflow i32, so wider mult grids are
        // refused upstream and not exercised here.
        if matches!(kind, SimKernel::Mult) && b > 8 {
            continue;
        }
        let cfg = QuantCfg { bits: b, mode };
        let want = reference::conv2d_quant(x, cw, stride, padding, kind, cfg, calib);
        for strat in STRATEGIES {
            let got = conv2d_quant_with(strat, x, cw, stride, padding, kind,
                                        cfg, calib);
            assert_eq!(got.shape, want.shape, "{what} [int{b} {}]", strat.label());
            // integer accumulation is order-independent: every strategy
            // must be EXACTLY the reference.
            assert_eq!(got.data, want.data, "{what} [int{b} {}]", strat.label());
        }
    }
}

/// Shape grid shared by the f32 and integer sweeps.  Channel pairs
/// include counts far below, equal to, and not divisible by the tiled
/// 64-wide output tile, the tiled 4-wide column tile and the simd
/// 8-wide lane group.
fn shape_grid() -> Vec<(usize, usize, usize, usize, usize, usize, Padding)> {
    // (h, w, k, stride, cin, cout, padding)
    let mut grid = Vec::new();
    for &k in &[1usize, 3, 5] {
        for &stride in &[1usize, 2] {
            for &padding in &[Padding::Same, Padding::Valid] {
                for &(cin, cout) in &[(1usize, 1usize), (3, 5), (16, 16), (7, 13)] {
                    grid.push((8, 8, k, stride, cin, cout, padding));
                }
            }
        }
    }
    // odd spatial extents exercise the SAME-padding borders + remainders
    grid.push((9, 7, 3, 2, 4, 66, Padding::Same));
    grid.push((11, 5, 5, 1, 2, 65, Padding::Valid));
    grid
}

#[test]
fn conv2d_f32_matches_reference_grid() {
    let mut rng = XorShift64::new(101);
    let calib = LayerCalib { feat_max_abs: 1.5, weight_max_abs: 1.0 };
    for (h, w, k, stride, cin, cout, padding) in shape_grid() {
        for batch in [1usize, 8] {
            let x = Tensor::new((batch, h, w, cin),
                                rand_vec(&mut rng, batch * h * w * cin, 1.5));
            let wdat = rand_vec(&mut rng, k * k * cin * cout, 1.0);
            let cw = ConvW { data: &wdat, kh: k, kw: k, cin, cout };
            for kind in [SimKernel::Adder, SimKernel::Mult] {
                check_all_strategies(
                    &x, &cw, stride, padding, kind, 1e-5, &[],
                    Mode::SharedScale, &calib,
                    &format!("f32 {kind:?} k{k} s{stride} {padding:?} \
                              {cin}->{cout} b{batch}"));
            }
        }
    }
}

#[test]
fn conv2d_quant_bit_identical_to_reference() {
    let mut rng = XorShift64::new(202);
    let calib = LayerCalib { feat_max_abs: 1.5, weight_max_abs: 1.0 };
    for (h, w, k, stride, cin, cout, padding) in shape_grid() {
        for batch in [1usize, 8] {
            let x = Tensor::new((batch, h, w, cin),
                                rand_vec(&mut rng, batch * h * w * cin, 1.5));
            let wdat = rand_vec(&mut rng, k * k * cin * cout, 1.0);
            let cw = ConvW { data: &wdat, kh: k, kw: k, cin, cout };
            for kind in [SimKernel::Adder, SimKernel::Mult] {
                check_all_strategies(
                    &x, &cw, stride, padding, kind, 1e-5, &[8, 16],
                    Mode::SharedScale, &calib,
                    &format!("quant {kind:?} k{k} s{stride} {padding:?} \
                              {cin}->{cout} b{batch}"));
            }
        }
    }
}

#[test]
fn conv2d_quant_separate_scale_bit_identical() {
    // The point-alignment (regrid) path of the separate-scale adder mode
    // must also agree bit-exactly between every strategy and the
    // reference.
    let mut rng = XorShift64::new(303);
    let calib = LayerCalib { feat_max_abs: 0.25, weight_max_abs: 2.0 };
    let x = Tensor::new((2, 8, 8, 3), rand_vec(&mut rng, 2 * 8 * 8 * 3, 0.25));
    let wdat = rand_vec(&mut rng, 3 * 3 * 3 * 7, 2.0);
    let cw = ConvW { data: &wdat, kh: 3, kw: 3, cin: 3, cout: 7 };
    for kind in [SimKernel::Adder, SimKernel::Mult] {
        check_all_strategies(&x, &cw, 1, Padding::Same, kind, 1e-5, &[6, 8],
                             Mode::SeparateScale, &calib,
                             &format!("separate-scale {kind:?}"));
    }
}

// ---------------------------------------------------------------------------
// Edge-case shape grid: the tail-handling paths the base grid misses
// ---------------------------------------------------------------------------

#[test]
fn conv2d_edge_shapes_all_strategies() {
    let mut rng = XorShift64::new(404);
    let calib = LayerCalib { feat_max_abs: 1.5, weight_max_abs: 1.0 };
    // (batch, h, w, kh, kw, stride, cin, cout, padding)
    let cases: &[(usize, usize, usize, usize, usize, usize, usize, usize, Padding)] = &[
        // 1x1 kernel: pure channel mixing, no spatial window
        (2, 7, 7, 1, 1, 1, 3, 9, Padding::Same),
        (1, 6, 6, 1, 1, 2, 8, 8, Padding::Valid),
        // stride 3: output grids that skip most input columns
        (2, 9, 9, 3, 3, 3, 2, 10, Padding::Same),
        (1, 10, 7, 3, 3, 3, 4, 5, Padding::Valid),
        (1, 12, 12, 5, 5, 3, 1, 17, Padding::Same),
        // kernel larger than the input: SAME keeps the grid and every
        // window includes all-padding rows
        (1, 3, 3, 5, 5, 1, 2, 9, Padding::Same),
        (2, 2, 4, 5, 3, 1, 3, 8, Padding::Same),
        (1, 1, 1, 3, 3, 1, 4, 11, Padding::Same),
        // non-square kernels hit the kh != kw gather paths
        (1, 8, 8, 1, 5, 2, 2, 12, Padding::Same),
        (1, 8, 8, 5, 1, 1, 2, 6, Padding::Valid),
        // single-channel / single-batch / single-pixel degenerates
        (1, 5, 5, 3, 3, 1, 1, 1, Padding::Same),
        (1, 1, 9, 1, 3, 1, 1, 8, Padding::Same),
        (3, 4, 1, 3, 1, 2, 5, 3, Padding::Same),
    ];
    for &(batch, h, w, kh, kw, stride, cin, cout, padding) in cases {
        let x = Tensor::new((batch, h, w, cin),
                            rand_vec(&mut rng, batch * h * w * cin, 1.5));
        let wdat = rand_vec(&mut rng, kh * kw * cin * cout, 1.0);
        let cw = ConvW { data: &wdat, kh, kw, cin, cout };
        for kind in [SimKernel::Adder, SimKernel::Mult] {
            check_all_strategies(
                &x, &cw, stride, padding, kind, 1e-4, &[8, 16],
                Mode::SharedScale, &calib,
                &format!("edge {kind:?} b{batch} {h}x{w} k{kh}x{kw} s{stride} \
                          {cin}->{cout} {padding:?}"));
        }
    }
}

#[test]
fn conv2d_valid_kernel_larger_than_input_yields_empty() {
    // VALID with k > input: zero outputs, identical (empty) results
    // everywhere instead of a usize-underflow panic.
    let mut rng = XorShift64::new(505);
    let x = Tensor::new((2, 3, 3, 2), rand_vec(&mut rng, 2 * 3 * 3 * 2, 1.0));
    let wdat = rand_vec(&mut rng, 5 * 5 * 2 * 4, 1.0);
    let cw = ConvW { data: &wdat, kh: 5, kw: 5, cin: 2, cout: 4 };
    let want = reference::conv2d(&x, &cw, 1, Padding::Valid, SimKernel::Adder);
    assert_eq!(want.shape, (2, 0, 0, 4));
    assert!(want.data.is_empty());
    for strat in STRATEGIES {
        let got = conv2d_with(strat, &x, &cw, 1, Padding::Valid, SimKernel::Adder);
        assert_eq!(got.shape, want.shape, "{}", strat.label());
        assert!(got.data.is_empty(), "{}", strat.label());
    }
}

// ---------------------------------------------------------------------------
// Randomized cross-strategy oracle (deterministic LCG, no new deps)
// ---------------------------------------------------------------------------

/// Knuth MMIX LCG — deliberately independent of `util::XorShift64` so
/// the fuzz stream is not correlated with the data stream.
struct Lcg(u64);

impl Lcg {
    fn next_u64(&mut self) -> u64 {
        self.0 = self.0
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        self.0 >> 11
    }

    /// Uniform in `lo..=hi`.
    fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + (self.next_u64() as usize) % (hi - lo + 1)
    }

    fn coin(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }
}

#[test]
fn randomized_cross_strategy_oracle() {
    let mut lcg = Lcg(0x5eed_2024);
    let mut rng = XorShift64::new(909);
    let mut zero_output_cases = 0usize;
    for case in 0..50 {
        let batch = lcg.range(1, 3);
        let h = lcg.range(1, 12);
        let w = lcg.range(1, 12);
        let kh = lcg.range(1, 5);
        let kw = lcg.range(1, 5);
        let stride = lcg.range(1, 3);
        let padding = if lcg.coin() { Padding::Same } else { Padding::Valid };
        let cin = lcg.range(1, 8);
        let cout = lcg.range(1, 70);
        let bits = [4u32, 8, 16][lcg.range(0, 2)];
        let mode = if lcg.coin() { Mode::SharedScale } else { Mode::SeparateScale };
        let feat_scale = [0.25f32, 1.0, 2.0][lcg.range(0, 2)];
        let calib = LayerCalib { feat_max_abs: feat_scale, weight_max_abs: 1.0 };

        let x = Tensor::new((batch, h, w, cin),
                            rand_vec(&mut rng, batch * h * w * cin, feat_scale));
        let wdat = rand_vec(&mut rng, kh * kw * cin * cout, 1.0);
        let cw = ConvW { data: &wdat, kh, kw, cin, cout };
        if reference::conv2d(&x, &cw, stride, padding, SimKernel::Adder)
            .data.is_empty()
        {
            zero_output_cases += 1;
        }
        for kind in [SimKernel::Adder, SimKernel::Mult] {
            check_all_strategies(
                &x, &cw, stride, padding, kind, 1e-4, &[bits], mode, &calib,
                &format!("fuzz#{case} {kind:?} b{batch} {h}x{w} k{kh}x{kw} \
                          s{stride} {cin}->{cout} {padding:?} {mode:?}"));
        }
    }
    // the sampler must keep most cases non-degenerate
    assert!(zero_output_cases < 25, "sampler degenerated: {zero_output_cases}/50");
}

// ---------------------------------------------------------------------------
// Winograd: explicit shape-guard cases + the opt-in l1 reformulation
// ---------------------------------------------------------------------------

#[test]
fn winograd_shape_guard_falls_back_bit_identically() {
    // The cases the guard must refuse: 1x1 (no spatial window), 5x5,
    // stride 2 and 3, kernel larger than the input, non-square 3x1.
    // Each must produce EXACTLY the reference on the int path — the
    // fallback is the Auto heuristic's row kernel, not a different
    // numeric contract.
    let mut rng = XorShift64::new(4242);
    let calib = LayerCalib { feat_max_abs: 1.5, weight_max_abs: 1.0 };
    let cfg = QuantCfg { bits: 8, mode: Mode::SharedScale };
    // (h, w, kh, kw, stride, cin, cout, padding)
    let cases: &[(usize, usize, usize, usize, usize, usize, usize, Padding)] = &[
        (8, 8, 1, 1, 1, 4, 12, Padding::Same),
        (8, 8, 5, 5, 1, 2, 9, Padding::Same),
        (8, 8, 3, 3, 2, 4, 16, Padding::Same),
        (9, 9, 3, 3, 3, 2, 10, Padding::Valid),
        (2, 2, 3, 3, 1, 3, 8, Padding::Same),
        (8, 8, 3, 1, 1, 2, 6, Padding::Same),
    ];
    for &(h, w, kh, kw, stride, cin, cout, padding) in cases {
        let x = Tensor::new((2, h, w, cin),
                            rand_vec(&mut rng, 2 * h * w * cin, 1.5));
        let wdat = rand_vec(&mut rng, kh * kw * cin * cout, 1.0);
        let cw = ConvW { data: &wdat, kh, kw, cin, cout };
        for kind in [SimKernel::Adder, SimKernel::Mult] {
            let want = reference::conv2d_quant(&x, &cw, stride, padding, kind,
                                               cfg, &calib);
            let got = conv2d_quant_with(KernelStrategy::Winograd, &x, &cw,
                                        stride, padding, kind, cfg, &calib);
            let what = format!("winograd guard {kind:?} k{kh}x{kw} s{stride} \
                                {cin}->{cout} {padding:?}");
            assert_eq!(got.shape, want.shape, "{what}");
            assert_eq!(got.data, want.data, "{what}");
        }
    }
}

#[test]
fn winograd_l1_adder_is_opt_in_only() {
    use addernet::sim::kernels::{winograd, ResolvedConv};
    // The l1 reformulation is an approximation by design, so neither
    // `Auto` nor plain `--kernel winograd` may silently route an adder
    // conv through it — only the explicit ADDERNET_WINOGRAD_ADDER
    // opt-in does.  (Guarded so a developer running the suite WITH the
    // opt-in set doesn't see a false failure.)
    if winograd::adder_l1_opted_in() {
        return;
    }
    for strat in [KernelStrategy::Auto, KernelStrategy::Winograd] {
        let r = strat.resolve_conv(16, 3, 3, 1, 16, SimKernel::Adder);
        assert!(!matches!(r, ResolvedConv::WinogradL1),
                "{} resolved an adder conv to the l1 approximation \
                 without the opt-in", strat.label());
    }
    // the mult path takes the exact transform on the same shape
    assert!(matches!(
        KernelStrategy::Winograd.resolve_conv(16, 3, 3, 1, 16, SimKernel::Mult),
        ResolvedConv::Winograd));
}

#[test]
fn winograd_l1_adder_tolerance_oracle() {
    use addernet::sim::kernels::winograd;
    // The l1 reformulation (Li et al., arXiv:2105.05530) aggregates
    // -|U - 4V| in the transform domain, which does NOT equal the
    // spatial -sum|x - w| — so its oracle is tolerance- and
    // property-based instead of bit-identity:
    //  * deterministic across thread counts,
    //  * every output is a nonpositive l1-style score,
    //  * jointly doubling inputs and weights doubles every output up to
    //    the divide-by-4 rounding (|err| <= 2),
    //  * total magnitude tracks the exact spatial adder conv within a
    //    generous band on random int8-range data (same taps, different
    //    aggregation order).
    let (n, h, w, cin, cout) = (2usize, 8usize, 8usize, 4usize, 6usize);
    let (pt, pl, ho, wo) = (1usize, 1usize, 8usize, 8usize); // 3x3/s1 SAME
    let mut rng = XorShift64::new(9090);
    let xq: Vec<i32> =
        (0..n * h * w * cin).map(|_| (rng.next_f32_sym(50.0)) as i32).collect();
    let wq: Vec<i32> =
        (0..9 * cin * cout).map(|_| (rng.next_f32_sym(50.0)) as i32).collect();

    let mut got = vec![0i32; n * ho * wo * cout];
    winograd::conv2d_int_adder_l1(&xq, (n, h, w, cin), &wq, cin, cout,
                                  (pt, pl, ho, wo), 1, &mut got);
    let mut got_mt = vec![0i32; got.len()];
    winograd::conv2d_int_adder_l1(&xq, (n, h, w, cin), &wq, cin, cout,
                                  (pt, pl, ho, wo), usize::MAX, &mut got_mt);
    assert_eq!(got, got_mt, "l1 kernel must be thread-count deterministic");
    assert!(got.iter().all(|&v| v <= 0), "l1 outputs are -|.| aggregates");

    // homogeneity: doubling both operands doubles the pre-division
    // accumulator exactly, so outputs match 2x up to div4 rounding
    let xq2: Vec<i32> = xq.iter().map(|v| v * 2).collect();
    let wq2: Vec<i32> = wq.iter().map(|v| v * 2).collect();
    let mut got2 = vec![0i32; got.len()];
    winograd::conv2d_int_adder_l1(&xq2, (n, h, w, cin), &wq2, cin, cout,
                                  (pt, pl, ho, wo), 1, &mut got2);
    for (i, (&y2, &y)) in got2.iter().zip(&got).enumerate() {
        assert!((y2 as i64 - 2 * y as i64).abs() <= 2,
                "homogeneity violated at {i}: 2x-input {y2} vs 2*{y}");
    }

    // spatial l1 truth for the tracking band
    let mut spatial = vec![0i64; got.len()];
    for b in 0..n {
        for oy in 0..ho {
            for ox in 0..wo {
                for co in 0..cout {
                    let mut acc = 0i64;
                    for ky in 0..3 {
                        for kx in 0..3 {
                            let iy = (oy + ky) as isize - pt as isize;
                            let ix = (ox + kx) as isize - pl as isize;
                            for ci in 0..cin {
                                let xv = if iy >= 0 && (iy as usize) < h
                                    && ix >= 0 && (ix as usize) < w
                                {
                                    xq[((b * h + iy as usize) * w
                                        + ix as usize) * cin + ci]
                                } else {
                                    0
                                };
                                let wv = wq[((ky * 3 + kx) * cin + ci) * cout
                                            + co];
                                acc -= (xv as i64 - wv as i64).abs();
                            }
                        }
                    }
                    spatial[((b * ho + oy) * wo + ox) * cout + co] = acc;
                }
            }
        }
    }
    let e_wino: f64 = got.iter().map(|&v| (v as f64).abs()).sum();
    let e_spatial: f64 = spatial.iter().map(|&v| (v as f64).abs()).sum();
    assert!(e_wino > 0.0 && e_spatial > 0.0);
    let ratio = e_wino / e_spatial;
    assert!((0.1..=10.0).contains(&ratio),
            "transform-domain l1 energy drifted from the spatial adder \
             conv: ratio {ratio:.3}");
}

// ---------------------------------------------------------------------------
// Dense
// ---------------------------------------------------------------------------

#[test]
fn dense_matches_reference_all_strategies() {
    let mut rng = XorShift64::new(606);
    for (n, din, dout) in [(1usize, 37usize, 13usize), (8, 400, 120), (3, 64, 130),
                           (2, 16, 7), (1, 5, 1)] {
        let x = Tensor::new((n, 1, 1, din), rand_vec(&mut rng, n * din, 1.0));
        let w = rand_vec(&mut rng, din * dout, 0.7);
        let bias = rand_vec(&mut rng, dout, 0.3);
        let want = reference::dense(&x, &w, &bias, dout);
        for strat in STRATEGIES {
            let got = dense_with(strat, &x, &w, &bias, dout);
            assert_eq!(got.shape, want.shape);
            assert_close(&got.data, &want.data, 1e-5,
                         &format!("dense {} {n}x{din}->{dout}", strat.label()));
        }
    }
}

#[test]
fn dense_handles_zero_activations() {
    // The sparse-skip in the reference and every strategy must agree
    // when activations contain exact zeros (post-ReLU reality).
    let x = Tensor::new((2, 1, 1, 6),
                        vec![0.0, 1.0, 0.0, -2.0, 0.0, 0.5,
                             0.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
    let mut rng = XorShift64::new(505);
    let w = rand_vec(&mut rng, 6 * 9, 1.0);
    let bias = rand_vec(&mut rng, 9, 1.0);
    let want = reference::dense(&x, &w, &bias, 9);
    for strat in STRATEGIES {
        let got = dense_with(strat, &x, &w, &bias, 9);
        assert_close(&got.data, &want.data, 1e-5,
                     &format!("dense with zeros [{}]", strat.label()));
        // the all-zero row must reduce to the bias
        assert_close(&got.data[9..], &bias, 1e-5,
                     &format!("all-zero row == bias [{}]", strat.label()));
    }
    // the default-strategy wrapper routes through the same dispatch
    let got = dense(&x, &w, &bias, 9);
    assert_close(&got.data, &want.data, 1e-5, "dense default wrapper");
}

// ---------------------------------------------------------------------------
// Engine determinism + end-to-end
// ---------------------------------------------------------------------------

#[test]
fn engine_thread_count_does_not_change_results() {
    // Same conv twice on a workload big enough to engage multiple
    // threads: determinism is part of the engine contract, for every
    // strategy.
    let mut rng = XorShift64::new(707);
    let x = Tensor::new((4, 32, 32, 16), rand_vec(&mut rng, 4 * 32 * 32 * 16, 1.0));
    let wdat = rand_vec(&mut rng, 3 * 3 * 16 * 16, 1.0);
    let cw = ConvW { data: &wdat, kh: 3, kw: 3, cin: 16, cout: 16 };
    let want = reference::conv2d(&x, &cw, 1, Padding::Same, SimKernel::Adder);
    for strat in STRATEGIES {
        let a = conv2d_with(strat, &x, &cw, 1, Padding::Same, SimKernel::Adder);
        let b = conv2d_with(strat, &x, &cw, 1, Padding::Same, SimKernel::Adder);
        assert_eq!(a.data, b.data, "{}", strat.label());
        assert_close(&a.data, &want.data, 1e-5,
                     &format!("large parallel conv [{}]", strat.label()));
    }
}

// ---------------------------------------------------------------------------
// Golden pre/post-refactor equivalence: the graph-driven Runner vs a
// literal transcription of the pre-graph hand-coded forward walks
// ---------------------------------------------------------------------------

/// Residual-net block tables (prefix, stride, has projection shortcut)
/// written out literally — the topology as the pre-graph executors
/// hard-coded it, kept here as the golden oracle for the graph walk.
const RESNET8_BLOCKS: &[(&str, usize, bool)] = &[
    ("s0b0", 1, false),
    ("s1b0", 2, true),
    ("s2b0", 2, true),
];

const RESNET20_BLOCKS: &[(&str, usize, bool)] = &[
    ("s0b0", 1, false),
    ("s0b1", 1, false),
    ("s0b2", 1, false),
    ("s1b0", 2, true),
    ("s1b1", 1, false),
    ("s1b2", 1, false),
    ("s2b0", 2, true),
    ("s2b1", 1, false),
    ("s2b2", 1, false),
];

fn legacy_conv_block(params: &functional::Params, strategy: KernelStrategy,
                     kind: SimKernel, name: &str, x: &Tensor, stride: usize,
                     padding: Padding) -> Tensor {
    let (ws, wd) = &params[&format!("{name}/conv_w")];
    let w = ConvW { data: wd, kh: ws[0], kw: ws[1], cin: ws[2], cout: ws[3] };
    let mut y = conv2d_with(strategy, x, &w, stride, padding, kind);
    let g = &params[&format!("{name}/bn_gamma")].1;
    let b = &params[&format!("{name}/bn_beta")].1;
    let m = &params[&format!("{name}/bn_mean")].1;
    let v = &params[&format!("{name}/bn_var")].1;
    functional::batch_norm_eval(&mut y, g, b, m, v);
    y
}

fn legacy_dense(params: &functional::Params, strategy: KernelStrategy,
                name: &str, x: &Tensor) -> Tensor {
    let (ws, wd) = &params[&format!("{name}/dense_w")];
    let bd = &params[&format!("{name}/dense_b")].1;
    dense_with(strategy, x, wd, bd, ws[1])
}

/// The pre-graph `Runner::forward` LeNet-5 arm, verbatim.
fn legacy_forward_lenet(params: &functional::Params, strategy: KernelStrategy,
                        kind: SimKernel, x: &Tensor) -> Tensor {
    let mut y = legacy_conv_block(params, strategy, kind, "conv1", x, 1,
                                  Padding::Valid);
    functional::relu(&mut y);
    let mut y = functional::avg_pool2(&y);
    y = legacy_conv_block(params, strategy, kind, "conv2", &y, 1,
                          Padding::Valid);
    functional::relu(&mut y);
    let y = functional::avg_pool2(&y);
    let (n, h, w, c) = y.shape;
    let y = Tensor::new((n, 1, 1, h * w * c), y.data);
    let mut y = legacy_dense(params, strategy, "fc1", &y);
    functional::relu(&mut y);
    let mut y = legacy_dense(params, strategy, "fc2", &y);
    functional::relu(&mut y);
    legacy_dense(params, strategy, "fc3", &y)
}

/// The pre-graph `Runner::forward` ResNet arm, verbatim, driven by a
/// literal block table.
fn legacy_forward_resnet(params: &functional::Params, strategy: KernelStrategy,
                         kind: SimKernel, x: &Tensor,
                         blocks: &[(&str, usize, bool)]) -> Tensor {
    let mut y = legacy_conv_block(params, strategy, kind, "stem", x, 1,
                                  Padding::Same);
    functional::relu(&mut y);
    for &(pre, stride, has_sc) in blocks {
        let mut h = legacy_conv_block(params, strategy, kind,
                                      &format!("{pre}/c1"), &y, stride,
                                      Padding::Same);
        functional::relu(&mut h);
        let h = legacy_conv_block(params, strategy, kind,
                                  &format!("{pre}/c2"), &h, 1, Padding::Same);
        let sc = if has_sc {
            legacy_conv_block(params, strategy, kind, &format!("{pre}/sc"),
                              &y, stride, Padding::Same)
        } else {
            y.clone()
        };
        let mut sum = h;
        for (v, s) in sum.data.iter_mut().zip(&sc.data) {
            *v += s;
        }
        functional::relu(&mut sum);
        y = sum;
    }
    let y = functional::global_avg_pool(&y);
    legacy_dense(params, strategy, "fc", &y)
}

/// The graph-driven `Runner` must reproduce the legacy hand-coded walks
/// BIT-IDENTICALLY (same primitives, same order => same f32 bits) for
/// every pre-existing architecture and every kernel strategy.
#[test]
fn graph_walk_bit_identical_to_legacy_f32_walk() {
    let mut rng = XorShift64::new(1234);
    let x = Tensor::new((1, 32, 32, 1), rand_vec(&mut rng, 1024, 1.0));
    for (arch, blocks) in [
        (Arch::Lenet5, None),
        (Arch::Resnet8, Some(RESNET8_BLOCKS)),
        (Arch::Resnet20, Some(RESNET20_BLOCKS)),
    ] {
        let params = functional::synth_params(arch, 42);
        for strat in STRATEGIES {
            let want = match blocks {
                None => legacy_forward_lenet(&params, strat, SimKernel::Adder,
                                             &x),
                Some(b) => legacy_forward_resnet(&params, strat,
                                                 SimKernel::Adder, &x, b),
            };
            let mut r = Runner {
                params: &params, arch, kind: SimKernel::Adder, strategy: strat,
                mode: ExecMode::F32, calib: None, observe: None,
            };
            let got = r.forward(&x);
            assert_eq!(got.shape, want.shape, "{arch:?} [{}]", strat.label());
            assert_eq!(got.data, want.data,
                       "{arch:?} [{}]: graph-walk f32 logits must be \
                        bit-identical to the legacy walk", strat.label());
        }
    }
}

/// Same golden contract for the per-call quantized mode (int8 adder).
#[test]
fn graph_walk_bit_identical_to_legacy_percall_quant_walk() {
    let mut rng = XorShift64::new(1235);
    let x = Tensor::new((1, 32, 32, 1), rand_vec(&mut rng, 1024, 1.0));
    let params = functional::synth_params(Arch::Lenet5, 42);
    let calib: addernet::quant::Calibration = ["conv1", "conv2"].iter()
        .map(|n| (n.to_string(),
                  LayerCalib { feat_max_abs: 2.0, weight_max_abs: 0.5 }))
        .collect();
    let cfg = QuantCfg { bits: 8, mode: Mode::SharedScale };
    for strat in STRATEGIES {
        // legacy walk: per-call quantized conv blocks, f32 between
        let lc1 = &calib["conv1"];
        let (ws, wd) = &params["conv1/conv_w"];
        let w1 = ConvW { data: wd, kh: ws[0], kw: ws[1], cin: ws[2], cout: ws[3] };
        let mut y = conv2d_quant_with(strat, &x, &w1, 1, Padding::Valid,
                                      SimKernel::Adder, cfg, lc1);
        functional::batch_norm_eval(
            &mut y, &params["conv1/bn_gamma"].1, &params["conv1/bn_beta"].1,
            &params["conv1/bn_mean"].1, &params["conv1/bn_var"].1);
        functional::relu(&mut y);
        let y = functional::avg_pool2(&y);
        let lc2 = &calib["conv2"];
        let (ws, wd) = &params["conv2/conv_w"];
        let w2 = ConvW { data: wd, kh: ws[0], kw: ws[1], cin: ws[2], cout: ws[3] };
        let mut y = conv2d_quant_with(strat, &y, &w2, 1, Padding::Valid,
                                      SimKernel::Adder, cfg, lc2);
        functional::batch_norm_eval(
            &mut y, &params["conv2/bn_gamma"].1, &params["conv2/bn_beta"].1,
            &params["conv2/bn_mean"].1, &params["conv2/bn_var"].1);
        functional::relu(&mut y);
        let y = functional::avg_pool2(&y);
        let (n, h, w, c) = y.shape;
        let y = Tensor::new((n, 1, 1, h * w * c), y.data);
        let mut y = legacy_dense(&params, strat, "fc1", &y);
        functional::relu(&mut y);
        let mut y = legacy_dense(&params, strat, "fc2", &y);
        functional::relu(&mut y);
        let want = legacy_dense(&params, strat, "fc3", &y);

        let mut r = Runner {
            params: &params, arch: Arch::Lenet5, kind: SimKernel::Adder,
            strategy: strat, mode: ExecMode::Quant(cfg),
            calib: Some(&calib), observe: None,
        };
        let got = r.forward(&x);
        assert_eq!(got.data, want.data,
                   "per-call quant graph walk [{}] diverged", strat.label());
    }
}

#[test]
fn quantized_forward_runs_on_synthetic_params() {
    // End-to-end: calibrate + quantized forward through the engine on
    // synthetic weights, fully offline; every strategy produces the
    // same logits because the integer path is bit-identical and the
    // float glue layers are shared.
    let params = functional::synth_params(Arch::Lenet5, 77);
    let mut rng = XorShift64::new(808);
    let x = Tensor::new((4, 32, 32, 1), rand_vec(&mut rng, 4 * 1024, 1.0));
    let mut calib = addernet::quant::Calibration::new();
    {
        let mut r = Runner {
            params: &params, arch: Arch::Lenet5, kind: SimKernel::Adder,
            strategy: KernelStrategy::Auto,
            mode: ExecMode::F32, calib: None, observe: Some(&mut calib),
        };
        r.forward(&x);
    }
    assert!(calib.contains_key("conv1") && calib.contains_key("conv2"));
    let cfg = QuantCfg { bits: 8, mode: Mode::SharedScale };
    let mut logits_by_strategy = Vec::new();
    for strat in STRATEGIES {
        let mut rq = Runner {
            params: &params, arch: Arch::Lenet5, kind: SimKernel::Adder,
            strategy: strat,
            mode: ExecMode::Quant(cfg), calib: Some(&calib), observe: None,
        };
        let y = rq.forward(&x);
        assert_eq!(y.shape, (4, 1, 1, 10));
        assert!(y.data.iter().all(|v| v.is_finite()));
        logits_by_strategy.push(y.data);
    }
    for (i, l) in logits_by_strategy.iter().enumerate().skip(1) {
        assert_close(l, &logits_by_strategy[0], 1e-4,
                     &format!("whole-model logits [{}]", STRATEGIES[i].label()));
    }
}
