//! Gate-count and power-model sanity invariants on the `hw/` substrate
//! and the accelerator simulator: the adder kernel must be cheaper than
//! the multiplier in the direction of the paper's ~81%-off claim, the
//! ZCU104 geometry rules must hold, and the cycle schedule must be
//! monotone in layer size.

use addernet::hw::array::PeArray;
use addernet::hw::KernelKind;
use addernet::nn::{ConvLayer, Layer, NetworkDesc, Padding};
use addernet::sim::accelerator::{self, AccelConfig};

#[test]
fn adder_kernel_cheaper_than_mult_at_int8_int16() {
    for dw in [8u32, 16] {
        let mult = KernelKind::Mult.lane_cost(dw);
        for adder in [KernelKind::Adder1C1A, KernelKind::Adder2A] {
            let a = adder.lane_cost(dw);
            assert!(a.luts < mult.luts,
                    "{adder:?} {dw}b: {} LUTs !< mult {}", a.luts, mult.luts);
            assert!(a.energy_pj < mult.energy_pj,
                    "{adder:?} {dw}b: {} pJ !< mult {}", a.energy_pj, mult.energy_pj);
            assert!(a.area_units < mult.area_units);
        }
    }
}

#[test]
fn array_lut_saving_in_paper_direction() {
    // Paper headline: Eq. 2/3 at Pin=64, DW=16 give ~81.6% off; the
    // precise per-level-width accounting stays in the same direction.
    let s = PeArray::eq23_saving(64, 16);
    assert!((0.78..=0.85).contains(&s), "eq23 saving {s}");
    for dw in [8u32, 16] {
        let a = PeArray::new(64, 16, dw, KernelKind::Adder2A).luts();
        let c = PeArray::new(64, 16, dw, KernelKind::Mult).luts();
        let saving = 1.0 - a as f64 / c as f64;
        assert!(saving > 0.5, "DW={dw}: precise LUT saving {saving}");
    }
}

#[test]
fn zcu104_geometry_invariants() {
    for p in [1u64, 2, 8, 32, 64, 128, 512, 1024, 2048] {
        let cfg = AccelConfig::zcu104(p, 16, KernelKind::Adder2A);
        assert!(cfg.pin <= 64, "P={p}: pin {} > 64", cfg.pin);
        assert!(cfg.pout >= 1, "P={p}: pout {}", cfg.pout);
        assert_eq!(cfg.pin * cfg.pout, p,
                   "P={p}: pin {} * pout {} != P", cfg.pin, cfg.pout);
        assert_eq!(cfg.parallelism(), p);
    }
}

/// One-conv-layer network for the schedule monotonicity sweeps.
fn single_conv_net(h: usize, cin: usize, cout: usize) -> NetworkDesc {
    NetworkDesc {
        name: format!("probe_{h}_{cin}_{cout}"),
        input: (h, h, cin),
        layers: vec![Layer::Conv(ConvLayer {
            name: "conv".into(),
            kh: 3,
            kw: 3,
            cin,
            cout,
            h_in: h,
            w_in: h,
            stride: 1,
            padding: Padding::Same,
        })],
    }
}

#[test]
fn cycle_schedule_monotone_in_spatial_size() {
    let cfg = AccelConfig::zcu104(1024, 16, KernelKind::Adder2A);
    let mut prev = 0u64;
    for h in [8usize, 16, 32, 64] {
        let r = accelerator::run(&cfg, &single_conv_net(h, 16, 32));
        assert!(r.total_cycles >= prev,
                "h={h}: cycles {} < previous {prev}", r.total_cycles);
        assert!(r.latency_ms() > 0.0);
        prev = r.total_cycles;
    }
}

#[test]
fn cycle_schedule_monotone_in_channels() {
    let cfg = AccelConfig::zcu104(1024, 16, KernelKind::Adder2A);
    let mut prev = 0u64;
    for cout in [16usize, 32, 64, 128] {
        let r = accelerator::run(&cfg, &single_conv_net(32, 16, cout));
        assert!(r.total_cycles >= prev,
                "cout={cout}: cycles {} < previous {prev}", r.total_cycles);
        prev = r.total_cycles;
    }
}

#[test]
fn power_model_components_sane() {
    let net = addernet::nn::resnet18();
    let adder = accelerator::run(&AccelConfig::zcu104(1024, 16, KernelKind::Adder2A), &net);
    let mult = accelerator::run(&AccelConfig::zcu104(1024, 16, KernelKind::Mult), &net);
    for r in [&adder, &mult] {
        assert!(r.power.compute_w > 0.0);
        assert!(r.power.bram_w >= 0.0);
        assert!(r.power.dram_w > 0.0, "DRAM-backed run must burn DRAM power");
        assert!(r.power.clock_w > 0.0);
        assert!(r.power.total_w().is_finite());
    }
    // the paper's direction: AdderNet strictly cheaper than CNN on the
    // same workload + geometry, both in power and in achievable clock.
    assert!(adder.power.total_w() < mult.power.total_w());
    assert!(adder.fmax_mhz >= mult.fmax_mhz);
}

#[test]
fn simulator_deterministic() {
    let cfg = AccelConfig::zcu104(512, 8, KernelKind::Adder2A);
    let net = single_conv_net(32, 16, 32);
    let a = accelerator::run(&cfg, &net);
    let b = accelerator::run(&cfg, &net);
    assert_eq!(a.total_cycles, b.total_cycles);
    assert_eq!(a.dram_bytes, b.dram_bytes);
    assert_eq!(a.power.total_w(), b.power.total_w());
}
