//! Cross-layer naming and geometry consistency for the layer-graph IR:
//! the compiled op programs in `nn::graph` are the single encoding of
//! every topology, so the runtime parameter store, the quantization
//! planner and the derived hardware descriptors must all resolve the
//! SAME canonical layer names (`s0b0/c1` — no more `s0b0c1` report-side
//! scheme).

use std::collections::BTreeSet;

use addernet::nn;
use addernet::nn::graph::{self, Arch};
use addernet::sim::functional::synth_params;

/// Every graph conv/dense name resolves in BOTH `Params` (the runtime
/// store) and `NetworkDesc` (the hardware/report descriptor), and the
/// parameter store contains nothing the graph does not name.
#[test]
fn graph_layer_names_resolve_in_params_and_desc() {
    for arch in Arch::ALL {
        let g = arch.graph();
        let params = synth_params(arch, 1);
        let desc = nn::by_name(arch.name()).unwrap();
        let desc_convs: BTreeSet<&str> =
            desc.conv_layers().map(|c| c.name.as_str()).collect();
        let specs = g.conv_specs();
        assert_eq!(specs.len(), desc_convs.len(),
                   "{}: conv count diverges between graph and desc",
                   arch.name());
        for c in &specs {
            for suffix in ["conv_w", "bn_gamma", "bn_beta", "bn_mean",
                           "bn_var"] {
                assert!(params.contains_key(&format!("{}/{suffix}", c.name)),
                        "{}: {}/{suffix} missing from Params",
                        arch.name(), c.name);
            }
            assert!(desc_convs.contains(c.name.as_str()),
                    "{}: conv {} missing from NetworkDesc",
                    arch.name(), c.name);
        }
        for d in g.dense_specs() {
            assert!(params.contains_key(&format!("{}/dense_w", d.name)),
                    "{}: dense {} missing from Params", arch.name(), d.name);
            assert!(params.contains_key(&format!("{}/dense_b", d.name)));
        }
        // no orphans: every parameter belongs to a graph-named layer
        let graph_names: BTreeSet<&str> = specs.iter()
            .map(|c| c.name.as_str())
            .chain(g.dense_specs().iter().map(|d| d.name.as_str()))
            .collect();
        for key in params.keys() {
            let (layer, _) = key.rsplit_once('/')
                .unwrap_or_else(|| panic!("unscoped param key {key}"));
            assert!(graph_names.contains(layer),
                    "{}: orphan parameter {key}", arch.name());
        }
    }
}

/// The runtime naming scheme (`s0b0/c1`) IS the descriptor naming
/// scheme — the old report-side `s0b0c1` spelling is gone everywhere.
#[test]
fn residual_desc_names_use_runtime_scheme() {
    for id in ["resnet8", "resnet20", "resnet32", "resnet18", "resnet50"] {
        let desc = nn::by_name(id).unwrap();
        for c in desc.conv_layers() {
            if c.name == "stem" {
                continue;
            }
            assert!(c.name.contains('/'),
                    "{id}: conv {} does not use the s#b#/c# scheme", c.name);
        }
    }
}

/// Derived descriptors stay geometrically sane for every registry
/// entry, servable or descriptor-only.
#[test]
fn derived_descriptors_have_positive_geometry() {
    for g in graph::all() {
        let d = g.to_desc();
        assert!(!d.layers.is_empty(), "{}", g.id);
        assert!(d.ops() > 0, "{}", g.id);
        assert!(d.params() > 0, "{}", g.id);
        for c in d.conv_layers() {
            assert!(c.h_out() > 0 && c.w_out() > 0, "{}: {}", g.id, c.name);
        }
    }
}

/// The deeper graph-described resnet32 scales as expected relative to
/// resnet20 (same family, 5 blocks per stage instead of 3).
#[test]
fn resnet32_scales_past_resnet20() {
    let r20 = nn::by_name("resnet20").unwrap();
    let r32 = nn::by_name("resnet32").unwrap();
    assert!(r32.params() > r20.params());
    assert!(r32.ops() > r20.ops());
    assert_eq!(r32.conv_layers().count(), 1 + 15 * 2 + 2);
}
