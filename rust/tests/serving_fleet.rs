//! Fleet-serving tests: replica workers, bounded-queue admission
//! control (load-shedding), zero-downtime plan hot-swap, and the
//! batcher/shutdown edge cases.  Everything here runs offline — the
//! tiled engine + synthetic weights need neither XLA nor artifacts.

use std::time::Duration;

use addernet::coordinator::server::{self, SubmitError};
use addernet::data;
use addernet::quant::plan::QuantPlan;
use addernet::quant::Mode;
use addernet::report::quantrep;
use addernet::sim::functional::{synth_params, Arch, ExecMode, KernelStrategy,
                                QuantCfg, SimKernel, Tensor};
use addernet::sim::intpath::PlanRunner;

const QCFG: QuantCfg = QuantCfg { bits: 8, mode: Mode::SharedScale };

/// Build an int8 plan for lenet5/adder from the given synthetic seed.
fn int8_plan(seed: u64) -> QuantPlan {
    let params = synth_params(Arch::Lenet5, seed);
    let (calib, _) = quantrep::calibrate(&params, Arch::Lenet5,
                                         SimKernel::Adder, 16);
    QuantPlan::build(&params, Arch::Lenet5, SimKernel::Adder, QCFG, &calib)
        .unwrap()
}

/// Variant config mounting `plan` under `name` with `replicas` workers.
fn plan_variant(name: &str, plan: QuantPlan,
                replicas: usize) -> server::FunctionalVariantCfg {
    let mut cfg = server::FunctionalVariantCfg::synthetic(
        name, Arch::Lenet5, SimKernel::Adder, 42);
    cfg.mode = ExecMode::Quant(QCFG);
    cfg.plan = Some(plan);
    cfg.replicas = replicas;
    cfg
}

fn direct_logits(plan: &QuantPlan, image: &[f32]) -> Vec<f32> {
    let runner = PlanRunner { plan, strategy: KernelStrategy::Auto };
    runner.forward(&Tensor::new((1, 32, 32, 1), image.to_vec())).data
}

/// N replicas draining one queue serve the int path bit-identically to
/// a direct plan execution: the plan path is deterministic, so neither
/// replica scheduling nor batch splits may change a single logit.
#[test]
fn replicas_serve_int8_bit_identical() {
    let plan = int8_plan(42);
    let handle = server::start_functional(
        vec![plan_variant("lenet5_adder_int8", plan.clone(), 4)],
        Duration::from_millis(1)).unwrap();
    let b = data::eval_set(32, 31);
    let mut rxs = Vec::new();
    for i in 0..32 {
        let img = b.images[i * 1024..(i + 1) * 1024].to_vec();
        rxs.push((i, handle.submit("lenet5_adder_int8", img).unwrap()));
    }
    for (i, rx) in rxs {
        let resp = rx.recv().unwrap();
        let want = direct_logits(&plan, &b.images[i * 1024..(i + 1) * 1024]);
        assert_eq!(resp.logits, want, "request {i}");
    }
    // all 32 answered across the replica fleet, latencies recorded
    // (merged over the per-replica metric shards)
    let metrics = handle.metrics_snapshot();
    let m = &metrics["lenet5_adder_int8"];
    assert_eq!(m.requests, 32);
    assert_eq!(m.e2e_lat.count(), 32);
    handle.shutdown();
}

/// Zero-downtime hot-swap under live traffic: continuous submits while
/// `swap_plan` replaces the int8 plan.  Zero requests are dropped or
/// errored; every response is bit-identical to plan A or plan B run
/// directly; everything submitted after the swap returns is exactly
/// plan B — matching a cold-start server mounted on B from the outset.
#[test]
fn hot_swap_under_live_traffic() {
    let plan_a = int8_plan(42);
    let plan_b = int8_plan(1337); // different weights, same arch/kind/cfg
    let b = data::eval_set(24, 7);
    let img = |i: usize| b.images[i * 1024..(i + 1) * 1024].to_vec();

    let handle = server::start_functional(
        vec![plan_variant("lenet5_adder_int8", plan_a.clone(), 2)],
        Duration::from_millis(1)).unwrap();

    // pre-swap burst: must be exactly plan A
    let pre: Vec<_> = (0..8)
        .map(|i| (i, handle.submit("lenet5_adder_int8", img(i)).unwrap()))
        .collect();
    for (i, rx) in pre {
        let resp = rx.recv().expect("pre-swap request dropped");
        assert_eq!(resp.logits, direct_logits(&plan_a, &img(i)), "pre {i}");
    }

    // in-flight burst, then swap while it is (potentially) queued
    let mid: Vec<_> = (8..16)
        .map(|i| (i, handle.submit("lenet5_adder_int8", img(i)).unwrap()))
        .collect();
    handle.swap_plan("lenet5_adder_int8", plan_b.clone()).unwrap();
    // post-swap burst: the swap returned before these were submitted,
    // so they MUST execute under plan B
    let post: Vec<_> = (16..24)
        .map(|i| (i, handle.submit("lenet5_adder_int8", img(i)).unwrap()))
        .collect();

    for (i, rx) in mid {
        let resp = rx.recv().expect("in-flight request dropped by swap");
        let a = direct_logits(&plan_a, &img(i));
        let bb = direct_logits(&plan_b, &img(i));
        assert!(resp.logits == a || resp.logits == bb,
                "mid {i}: response matches neither plan exactly");
    }
    let mut post_logits = Vec::new();
    for (i, rx) in post {
        let resp = rx.recv().expect("post-swap request dropped");
        assert_eq!(resp.logits, direct_logits(&plan_b, &img(i)), "post {i}");
        post_logits.push((i, resp.logits));
    }
    assert_eq!(handle.metrics_snapshot()["lenet5_adder_int8"].swaps, 1);
    handle.shutdown();

    // a cold-start server on plan B answers bit-identically to the
    // swapped server's post-swap responses
    let cold = server::start_functional(
        vec![plan_variant("lenet5_adder_int8", plan_b, 2)],
        Duration::from_millis(1)).unwrap();
    for (i, swapped) in post_logits {
        let rx = cold.submit("lenet5_adder_int8", img(i)).unwrap();
        assert_eq!(rx.recv().unwrap().logits, swapped, "cold-start {i}");
    }
    cold.shutdown();
}

/// swap_plan validates exactly like start_functional: unknown variants,
/// f32 (plan-less) variants, and arch/kind/cfg mismatches are refused
/// with proper errors, and the running plan is left untouched.
#[test]
fn hot_swap_validates_plan_compatibility() {
    let plan_a = int8_plan(42);
    let f32_cfg = server::FunctionalVariantCfg::synthetic(
        "lenet5_f32", Arch::Lenet5, SimKernel::Adder, 42);
    let handle = server::start_functional(
        vec![plan_variant("lenet5_adder_int8", plan_a.clone(), 1), f32_cfg],
        Duration::from_millis(1)).unwrap();

    let err = handle.swap_plan("nope", plan_a.clone()).unwrap_err();
    assert!(format!("{err:#}").contains("unknown variant"), "{err:#}");

    let err = handle.swap_plan("lenet5_f32", plan_a.clone()).unwrap_err();
    assert!(format!("{err:#}").contains("plan"), "{err:#}");

    // same arch/kind but a different quant width must be refused: the
    // route's serving contract (its name says int8) cannot change
    let params = synth_params(Arch::Lenet5, 42);
    let (calib, _) = quantrep::calibrate(&params, Arch::Lenet5,
                                         SimKernel::Adder, 16);
    let wide = QuantPlan::build(&params, Arch::Lenet5, SimKernel::Adder,
                                QuantCfg { bits: 16, mode: Mode::SharedScale },
                                &calib).unwrap();
    let err = handle.swap_plan("lenet5_adder_int8", wide).unwrap_err();
    assert!(format!("{err:#}").contains("int16"), "{err:#}");

    // traffic still flows on the original plan after every refusal
    let b = data::eval_set(1, 3);
    let rx = handle.submit("lenet5_adder_int8", b.images[..1024].to_vec())
        .unwrap();
    assert_eq!(rx.recv().unwrap().logits,
               direct_logits(&plan_a, &b.images[..1024]));
    assert_eq!(handle.metrics_snapshot()["lenet5_adder_int8"].swaps, 0);
    handle.shutdown();
}

/// Admission control at full queue depth: a burst far beyond
/// queue_depth gets explicit `Overloaded` errors immediately (no hang,
/// no unbounded queueing), the shed count lands in `ServerMetrics`, and
/// every ADMITTED request is still answered with recorded p50/p99.
#[test]
fn overload_sheds_with_explicit_errors() {
    // resnet8 forwards take milliseconds; a burst of 24 submits takes
    // microseconds — with queue_depth 4 and max_batch 1 the queue MUST
    // overflow mid-burst
    let mut cfg = server::FunctionalVariantCfg::synthetic(
        "resnet8_adder", Arch::Resnet8, SimKernel::Adder, 42);
    cfg.max_batch = 1;
    cfg.queue_depth = 4;
    let handle = server::start_functional(vec![cfg],
                                          Duration::from_millis(1)).unwrap();
    let b = data::eval_set(1, 11);
    let img = b.images[..1024].to_vec();
    let mut admitted = Vec::new();
    let mut shed = 0u64;
    for i in 0..24 {
        match handle.submit("resnet8_adder", img.clone()) {
            Ok(rx) => admitted.push((i, rx)),
            Err(SubmitError::Overloaded { variant, depth }) => {
                assert_eq!(variant, "resnet8_adder");
                assert_eq!(depth, 4);
                shed += 1;
            }
            Err(e) => panic!("unexpected submit error: {e}"),
        }
    }
    assert!(shed >= 1, "24-deep burst into a depth-4 queue must shed");
    // every admitted request is answered — a shed never takes a
    // neighbour down with it
    for (i, rx) in admitted {
        let resp = rx.recv().unwrap_or_else(|_| panic!("admitted {i} dropped"));
        assert_eq!(resp.logits.len(), 10);
    }
    let metrics = handle.metrics_snapshot();
    let m = &metrics["resnet8_adder"];
    assert_eq!(m.shed, shed, "metrics must count exactly the observed sheds");
    assert_eq!(m.requests + m.shed, 24);
    assert!(m.e2e_lat.quantile_us(0.5) > 0, "p50 recorded");
    assert!(m.e2e_lat.quantile_us(0.99) >= m.e2e_lat.quantile_us(0.5));
    handle.shutdown();
}

/// Shutdown with requests in flight: every already-admitted request is
/// still answered (drain-on-close), shutdown does not hang, and later
/// submits fail with an explicit Shutdown error.
#[test]
fn shutdown_delivers_in_flight_then_refuses() {
    let cfg = server::FunctionalVariantCfg::synthetic(
        "lenet5_adder", Arch::Lenet5, SimKernel::Adder, 42);
    let handle = server::start_functional(vec![cfg],
                                          Duration::from_millis(1)).unwrap();
    let b = data::eval_set(8, 13);
    let rxs: Vec<_> = (0..8)
        .map(|i| handle.submit("lenet5_adder",
                               b.images[i * 1024..(i + 1) * 1024].to_vec())
            .unwrap())
        .collect();
    handle.shutdown(); // joins workers: queue is closed AND drained here
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv()
            .unwrap_or_else(|_| panic!("in-flight request {i} not answered"));
        assert_eq!(resp.logits.len(), 10);
    }
    match handle.submit("lenet5_adder", vec![0.0; 1024]) {
        Err(SubmitError::Shutdown(v)) => assert_eq!(v, "lenet5_adder"),
        Ok(_) => panic!("submit after shutdown must fail"),
        Err(e) => panic!("expected Shutdown error, got: {e}"),
    }
}

/// Batch-window edges, pinned via the batches counter: requests spaced
/// far beyond the window each get their own batch (expiry fires), while
/// requests inside one long window share a batch.
#[test]
fn batch_window_expiry_and_merge() {
    // slow trickle: 3 requests, 60ms apart, 2ms window -> 3 batches
    let cfg = server::FunctionalVariantCfg::synthetic(
        "lenet5_adder", Arch::Lenet5, SimKernel::Adder, 42);
    let handle = server::start_functional(vec![cfg],
                                          Duration::from_millis(2)).unwrap();
    let b = data::eval_set(3, 17);
    for i in 0..3 {
        let rx = handle.submit("lenet5_adder",
                               b.images[i * 1024..(i + 1) * 1024].to_vec())
            .unwrap();
        rx.recv().unwrap(); // wait the response out: the batch is sealed
        if i < 2 {
            std::thread::sleep(Duration::from_millis(60));
        }
    }
    {
        let metrics = handle.metrics_snapshot();
        let m = &metrics["lenet5_adder"];
        assert_eq!(m.batches, 3, "trickled requests must not share a batch");
        assert_eq!(m.images, 3);
    }
    handle.shutdown();

    // merge: 2 requests 10ms apart inside a 400ms window -> 1 batch
    let cfg = server::FunctionalVariantCfg::synthetic(
        "lenet5_adder", Arch::Lenet5, SimKernel::Adder, 42);
    let handle = server::start_functional(vec![cfg],
                                          Duration::from_millis(400)).unwrap();
    let rx1 = handle.submit("lenet5_adder", b.images[..1024].to_vec()).unwrap();
    std::thread::sleep(Duration::from_millis(10));
    let rx2 = handle.submit("lenet5_adder",
                            b.images[1024..2048].to_vec()).unwrap();
    rx1.recv().unwrap();
    rx2.recv().unwrap();
    {
        let metrics = handle.metrics_snapshot();
        let m = &metrics["lenet5_adder"];
        assert_eq!(m.batches, 1, "both requests fit one window");
        assert_eq!(m.images, 2);
    }
    handle.shutdown();
}

/// The open-loop loadtest harness drives a live mixed fleet (f32 +
/// int8-plan variants), reports only successes, and its JSON artifact
/// passes the CI gate.
#[test]
fn loadtest_end_to_end_against_mixed_fleet() {
    use addernet::coordinator::loadtest;

    let mut f32_cfg = server::FunctionalVariantCfg::synthetic(
        "lenet5_adder", Arch::Lenet5, SimKernel::Adder, 42);
    f32_cfg.replicas = 2;
    let int_cfg = plan_variant("lenet5_adder_int8", int8_plan(42), 2);
    let handle = server::start_functional(vec![f32_cfg, int_cfg],
                                          Duration::from_millis(1)).unwrap();
    let names = vec!["lenet5_adder".to_string(), "lenet5_adder_int8".to_string()];
    let report = loadtest::run(&handle, &names, &loadtest::LoadtestCfg {
        qps: 400.0,
        duration: Duration::from_millis(250),
        replicas: 2,
    }).unwrap();
    handle.shutdown();

    let total: u64 = report.variants.values().map(|o| o.sent).sum();
    assert_eq!(total, 100, "open loop: qps * duration requests, exactly");
    for (name, o) in &report.variants {
        assert_eq!(o.errors, 0, "{name}: errors under a healthy fleet");
        assert_eq!(o.rejected, 0, "{name}: the rig never sends bad pixels");
        assert_eq!(o.ok + o.shed, o.sent, "{name}: every request accounted for");
        assert!(o.ok > 0, "{name}: some requests must land");
        if o.ok > 0 {
            assert!(o.lat.quantile_us(0.99) > 0, "{name}: p99 recorded");
        }
    }
    let path = std::env::temp_dir()
        .join(format!("addernet-fleet-loadtest-{}.json", std::process::id()));
    report.write_json(&path).unwrap();
    // the gate passes only when no variant shed 100% — tolerate sheds
    // by construction: queue depth is the default 1024 >> 100 requests
    loadtest::check(&path, &loadtest::CheckSlo::default()).unwrap();
    std::fs::remove_file(&path).ok();
}
