//! Lab-store tier tests: spec-hash stability, record write→read
//! roundtrips, store immutability (dedupe, never overwrite), and
//! `lab diff` determinism on the hwsim cycle keys — the properties the
//! CI gate (`repro lab run` + `lab check`) stands on.

use std::collections::BTreeMap;
use std::path::PathBuf;

use addernet::lab::diff::{check_records, diff_records, promote};
use addernet::lab::job::{run_spec, RunOutcome};
use addernet::lab::spec::{LabMode, Measure, SweepSpec};
use addernet::lab::store::{EnvInfo, JobLine, RunRecord, Store};
use addernet::lab::{fnv64, gate_class, is_deterministic, GateClass};
use addernet::sim::functional::{Arch, SimKernel};

/// Fresh per-test store directory (tests run in parallel).
fn temp_store(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("addernet-lab-test-{tag}-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

/// A deterministic-only spec: one hwsim cycle point, no wall clocks —
/// fast and bit-reproducible, so generations must diff clean.
fn hw_only_spec(name: &str) -> SweepSpec {
    SweepSpec {
        name: name.to_string(),
        archs: vec![Arch::Lenet5],
        model_archs: vec![],
        kernels: vec![SimKernel::Adder],
        strategies: vec![],
        modes: vec![LabMode::Int8],
        threads: vec![0],
        batches: vec![8],
        hw_parallelism: vec![1024],
        model_batch: 64,
        measure: Measure { layer: false, model: false, hw: true,
                           ratio_dw16: false },
        loadtest: None,
    }
}

fn sample_record(run_id: &str) -> RunRecord {
    let mut keys = BTreeMap::new();
    keys.insert("hw_cycles_lenet5_int8".to_string(), 4442.0);
    keys.insert("layer_int8_adder_simd_b8_s".to_string(), 0.043_217_651);
    keys.insert("winograd_vs_simd".to_string(), 0.1 + 0.2); // not 0.3 exactly
    RunRecord {
        run_id: run_id.to_string(),
        spec_name: "test".to_string(),
        spec_hash: "00112233aabbccdd".to_string(),
        env_fp: "deadbeef".to_string(),
        created_unix: 1_700_000_000,
        env: EnvInfo::current().to_map(),
        jobs: vec![
            JobLine::ok("hw lenet5 adder int8 p1024".to_string()),
            JobLine::skipped("layer int16 mult tiled b8".to_string(),
                             "mult \"quantization\" caps at 8-bit \\ operands"
                                 .to_string()),
        ],
        keys,
        promoted_from: None,
    }
}

#[test]
fn fnv64_reference_vectors() {
    assert_eq!(fnv64(b""), 0xcbf2_9ce4_8422_2325);
    assert_eq!(fnv64(b"a"), 0xaf63_dc4c_8601_ec8c);
    assert_eq!(fnv64(b"addernet-lab-v1"), 0xe486_dcb4_376f_9076);
}

#[test]
fn gate_classification_matches_the_bench_contract() {
    // ceilings: deterministic cycle counts
    assert_eq!(gate_class("hw_cycles_lenet5_int8"), GateClass::Ceiling);
    assert_eq!(gate_class("hw_cycles_resnet8_mult_int8"), GateClass::Ceiling);
    // floors: ratio keys + the dw16 latency ratio
    assert_eq!(gate_class("hw_mult_over_adder_latency"), GateClass::Floor);
    assert_eq!(gate_class("hw_mult_over_adder_latency_p256"), GateClass::Floor);
    assert_eq!(gate_class("winograd_vs_simd"), GateClass::Floor);
    assert_eq!(gate_class("f32_adder_tiled_vs_naive"), GateClass::Floor);
    assert_eq!(gate_class("plan_vs_f32"), GateClass::Floor);
    // info: raw medians and loadtest percentiles never gate
    assert_eq!(gate_class("layer_int8_adder_simd_b8_s"), GateClass::Info);
    assert_eq!(gate_class("e2e_plan_lenet5_adder_int8_s"), GateClass::Info);
    assert_eq!(gate_class("lt_lenet5_adder_int8_p99_us"), GateClass::Info);
    // determinism is exactly the hwsim family
    assert!(is_deterministic("hw_cycles_cnv6_int8"));
    assert!(is_deterministic("hw_mult_over_adder_latency"));
    assert!(!is_deterministic("winograd_vs_simd"));
    assert!(!is_deterministic("layer_f32_adder_naive_b8_s"));
}

#[test]
fn spec_hash_ignores_field_and_dimension_order() {
    // same spec typed two ways: scrambled field order AND scrambled
    // dimension order must hash identically after normalization
    let a = SweepSpec::from_json(
        r#"{"schema": "addernet-lab-spec-v1",
            "kernels": ["mult", "adder"],
            "archs": ["resnet8", "lenet5"],
            "modes": ["int8"],
            "measure": {"hw": true},
            "name": "order-test"}"#).unwrap();
    let b = SweepSpec::from_json(
        r#"{"schema": "addernet-lab-spec-v1",
            "name": "order-test",
            "archs": ["lenet5", "resnet8", "lenet5"],
            "kernels": ["adder", "mult"],
            "modes": ["int8"],
            "measure": {"hw": true}}"#).unwrap();
    assert_eq!(a.hash(), b.hash(),
               "field/dimension permutations must not mint a new lineage");
    assert_eq!(a.hash().len(), 16);
    assert!(a.hash().chars().all(|c| c.is_ascii_hexdigit()));

    // a real content change must move the hash
    let mut c = a.clone();
    c.hw_parallelism = vec![256];
    assert_ne!(a.hash(), c.hash());

    // builtins resolve and hash stably against themselves
    let s1 = SweepSpec::resolve("ci-sweep").unwrap();
    let s2 = SweepSpec::resolve("ci-sweep").unwrap();
    assert_eq!(s1.hash(), s2.hash());
    assert_ne!(s1.hash(), SweepSpec::resolve("ci-smoke").unwrap().hash());
}

#[test]
fn spec_json_defaults_mirror_the_ci_shape() {
    let s = SweepSpec::from_json(
        r#"{"schema": "addernet-lab-spec-v1", "name": "min",
            "archs": ["lenet5"], "kernels": ["adder"], "modes": ["int8"],
            "measure": {"hw": true}}"#).unwrap();
    assert_eq!(s.threads, vec![0]);
    assert_eq!(s.batches, vec![8]);
    assert_eq!(s.hw_parallelism, vec![1024]);
    assert_eq!(s.model_batch, 64);
    assert_eq!(s.model_archs, s.archs, "model_archs defaults to archs");
    // a spec with no measurement family is rejected, not silently empty
    assert!(SweepSpec::from_json(
        r#"{"schema": "addernet-lab-spec-v1", "name": "empty",
            "archs": ["lenet5"], "kernels": ["adder"],
            "modes": ["int8"]}"#).is_err());
}

#[test]
fn record_roundtrips_bit_exactly() {
    let rec = sample_record("00112233aabbccdd-deadbeef-g1");
    let parsed = RunRecord::from_json(&rec.to_json()).unwrap();
    assert_eq!(parsed, rec,
               "write -> read must be a fixed point (incl. escaped notes \
                and non-representable-in-decimal floats)");
    // and the re-serialization is byte-stable
    assert_eq!(parsed.to_json(), rec.to_json());
    // the awkward float survived exactly (0.1 + 0.2 != 0.3 in f64)
    assert_eq!(parsed.keys["winograd_vs_simd"], 0.1 + 0.2);
    assert_eq!(rec.jobs_ok(), 1);
    assert_eq!(rec.jobs_skipped(), 1);
}

#[test]
fn store_is_append_only_with_prefix_loads() {
    let root = temp_store("store");
    let store = Store::open(&root).unwrap();
    let rec = sample_record("00112233aabbccdd-deadbeef-g1");
    store.put_run(&rec).unwrap();

    // immutability: the same run id can never be written twice
    let err = store.put_run(&rec).expect_err("overwrite must be refused");
    assert!(format!("{err:#}").contains("append-only"),
            "error should say why: {err:#}");

    // exact and unique-prefix loads resolve
    assert_eq!(store.load("00112233aabbccdd-deadbeef-g1").unwrap(), rec);
    assert_eq!(store.load("00112233").unwrap(), rec);

    // a second generation makes the short prefix ambiguous
    let mut g2 = rec.clone();
    g2.run_id = "00112233aabbccdd-deadbeef-g2".to_string();
    store.put_run(&g2).unwrap();
    assert!(store.load("00112233").is_err(), "ambiguous prefix must error");
    assert_eq!(store.load("00112233aabbccdd-deadbeef-g2").unwrap(), g2);
    assert!(store.load("ffffffff").is_err(), "no match must error");

    // list is oldest-first and latest() newest-first
    let listed = store.list().unwrap();
    assert_eq!(listed.len(), 2);
    assert_eq!(listed[0].run_id, rec.run_id);
    assert_eq!(store.latest(1).unwrap()[0].run_id, g2.run_id);
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn run_spec_dedupes_and_forces_new_generations() {
    let root = temp_store("dedupe");
    let store = Store::open(&root).unwrap();
    let spec = hw_only_spec("test-hw");

    let first = match run_spec(&store, &spec, false).unwrap() {
        RunOutcome::Ran(r) => r,
        RunOutcome::Deduped(_) => panic!("empty store cannot dedupe"),
    };
    assert!(first.run_id.ends_with("-g1"));
    assert!(first.keys.contains_key("hw_cycles_lenet5_int8"),
            "hw family must record the historical cycle key");
    assert_eq!(first.env_fp, EnvInfo::current().fingerprint());

    // identical spec + environment: deduped, nothing re-measured,
    // nothing overwritten
    match run_spec(&store, &spec, false).unwrap() {
        RunOutcome::Deduped(r) => assert_eq!(r, first),
        RunOutcome::Ran(_) => panic!("identical re-run must dedupe"),
    }
    assert_eq!(store.list().unwrap().len(), 1);

    // --force appends generation 2 alongside, never over, g1
    let second = match run_spec(&store, &spec, true).unwrap() {
        RunOutcome::Ran(r) => r,
        RunOutcome::Deduped(_) => panic!("--force must re-measure"),
    };
    assert!(second.run_id.ends_with("-g2"));
    assert_eq!(store.list().unwrap().len(), 2);
    assert_eq!(store.load(&first.run_id).unwrap(), first,
               "g1 must be untouched after the forced g2");
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn diff_pins_deterministic_keys_exactly() {
    let root = temp_store("diff");
    let store = Store::open(&root).unwrap();
    let spec = hw_only_spec("test-diff");
    let g1 = run_spec(&store, &spec, true).unwrap().record().clone();
    let g2 = run_spec(&store, &spec, true).unwrap().record().clone();

    // hwsim is pure arithmetic: two generations agree bit-for-bit
    assert_eq!(g1.keys, g2.keys,
               "hw-only generations must record identical keys");
    let clean = diff_records(&g1, &g2);
    assert!(clean.drift().is_empty(),
            "back-to-back runs must diff clean on deterministic keys");

    // any bit-level change on an hw_ key IS drift — no tolerance
    let mut tampered = g2.clone();
    let v = tampered.keys["hw_cycles_lenet5_int8"];
    tampered.keys.insert("hw_cycles_lenet5_int8".to_string(), v + 1.0);
    let drifted = diff_records(&g1, &tampered);
    let drift = drifted.drift();
    assert_eq!(drift.len(), 1);
    assert_eq!(drift[0].key, "hw_cycles_lenet5_int8");
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn check_records_enforces_floors_ceilings_and_presence() {
    let mut baseline = sample_record("baseline-test-g1");
    baseline.keys.clear();
    baseline.keys.insert("winograd_vs_simd".to_string(), 2.0);
    baseline.keys.insert("hw_cycles_lenet5_int8".to_string(), 1000.0);
    baseline.keys.insert("layer_int8_adder_simd_b8_s".to_string(), 5.0);

    let mut current = sample_record("current-test-g1");
    current.keys.clear();
    current.keys.insert("winograd_vs_simd".to_string(), 1.9);
    current.keys.insert("hw_cycles_lenet5_int8".to_string(), 1100.0);

    // inside the 25% band on both gates; the info key is never
    // required, so its absence from current is fine
    let (_, failed, gated) = check_records(&current, &baseline, 0.25).unwrap();
    assert!(failed.is_empty(), "within tolerance must pass: {failed:?}");
    assert_eq!(gated, 2, "exactly the floor + ceiling keys gate");

    // floor breach: 1.4 < 2.0 * 0.75
    current.keys.insert("winograd_vs_simd".to_string(), 1.4);
    let (_, failed, _) = check_records(&current, &baseline, 0.25).unwrap();
    assert_eq!(failed.len(), 1);
    assert!(failed[0].contains("winograd_vs_simd"));

    // ceiling breach: 1300 > 1000 * 1.25
    current.keys.insert("winograd_vs_simd".to_string(), 2.0);
    current.keys.insert("hw_cycles_lenet5_int8".to_string(), 1300.0);
    let (_, failed, _) = check_records(&current, &baseline, 0.25).unwrap();
    assert_eq!(failed.len(), 1);
    assert!(failed[0].contains("hw_cycles_lenet5_int8"));

    // a missing gated key is a hard error, not a silent pass
    current.keys.remove("hw_cycles_lenet5_int8");
    assert!(check_records(&current, &baseline, 0.25).is_err());

    // tolerance domain is [0, 1)
    assert!(check_records(&baseline, &baseline, 1.5).is_err());
    assert!(check_records(&baseline, &baseline, -0.1).is_err());
}

#[test]
fn promote_cuts_a_gated_baseline_with_provenance() {
    let run = sample_record("00112233aabbccdd-deadbeef-g3");
    let base = promote(&run, false);
    assert_eq!(base.run_id, format!("baseline-{}", run.run_id));
    assert_eq!(base.promoted_from.as_deref(), Some(run.run_id.as_str()));
    assert!(base.jobs.is_empty(), "baselines carry keys, not job logs");
    assert!(base.keys.contains_key("hw_cycles_lenet5_int8"));
    assert!(base.keys.contains_key("winograd_vs_simd"));
    assert!(!base.keys.contains_key("layer_int8_adder_simd_b8_s"),
            "info keys are dropped unless --all-keys");
    let all = promote(&run, true);
    assert_eq!(all.keys.len(), run.keys.len());
    // the promoted record itself roundtrips — it is what gets committed
    assert_eq!(RunRecord::from_json(&base.to_json()).unwrap(), base);
}

#[test]
fn committed_ci_baseline_parses_and_gates() {
    // the actual file CI hands to `lab check --baseline`
    let text = std::fs::read_to_string("lab_baseline.json").unwrap();
    let baseline = RunRecord::from_json(&text).unwrap();
    assert_eq!(baseline.spec_name, "ci-sweep");
    let gated: Vec<&String> = baseline.keys.keys()
        .filter(|k| gate_class(k) != GateClass::Info)
        .collect();
    assert_eq!(gated.len(), baseline.keys.len(),
               "every committed baseline key must actually gate");
    assert_eq!(gated.len(), 11,
               "the migrated gate set is the bench check's 7 floors + 4 \
                ceilings");
    // a run equal to the baseline passes its own gate
    let (_, failed, gated_n) =
        check_records(&baseline, &baseline, 0.25).unwrap();
    assert!(failed.is_empty());
    assert_eq!(gated_n, 11);
}
