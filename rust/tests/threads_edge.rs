//! Thread-pool edge cases: `ADDERNET_THREADS` overrides must never
//! change results or deadlock, including the degenerate settings —
//! a single thread, a thread count far above the row count (the pool
//! must clamp to the chunk count instead of parking idle workers), an
//! explicit `0` (clamps to 1) and garbage (falls back to the machine
//! parallelism).
//!
//! Everything lives in ONE `#[test]` because the cases mutate the
//! process environment; the test harness would otherwise interleave
//! them with each other (and with any other test in this binary).

use addernet::nn::Padding;
use addernet::sim::functional::{conv2d_with, dense_with, ConvW, KernelStrategy,
                                SimKernel, Tensor};
use addernet::sim::reference;
use addernet::util::XorShift64;

fn rand_vec(rng: &mut XorShift64, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| rng.next_f32_sym(scale)).collect()
}

fn assert_close(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!((x - y).abs() <= 1e-5 * y.abs().max(1.0),
                "{what}: element {i}: {x} vs {y}");
    }
}

#[test]
fn thread_overrides_do_not_change_results_or_deadlock() {
    let mut rng = XorShift64::new(4242);

    // Large enough to cross the engine's parallel threshold, with only
    // 8 output rows — so "64 threads" heavily oversubscribes the row
    // count and the pool must clamp.
    let x_big = Tensor::new((1, 8, 32, 16), rand_vec(&mut rng, 8 * 32 * 16, 1.0));
    let w_big = rand_vec(&mut rng, 3 * 3 * 16 * 32, 1.0);
    let cw_big = ConvW { data: &w_big, kh: 3, kw: 3, cin: 16, cout: 32 };
    // Small enough to stay on the inline path regardless of settings.
    let x_small = Tensor::new((1, 4, 4, 2), rand_vec(&mut rng, 4 * 4 * 2, 1.0));
    let w_small = rand_vec(&mut rng, 3 * 3 * 2 * 9, 1.0);
    let cw_small = ConvW { data: &w_small, kh: 3, kw: 3, cin: 2, cout: 9 };
    // Dense: batch 3 = 3 chunks, another easy-to-oversubscribe split.
    let xd = Tensor::new((3, 1, 1, 64), rand_vec(&mut rng, 3 * 64, 1.0));
    let wd = rand_vec(&mut rng, 64 * 40, 0.5);
    let bd = rand_vec(&mut rng, 40, 0.3);

    let want_big = reference::conv2d(&x_big, &cw_big, 1, Padding::Same,
                                     SimKernel::Adder);
    let want_small = reference::conv2d(&x_small, &cw_small, 1, Padding::Same,
                                       SimKernel::Adder);
    let want_dense = reference::dense(&xd, &wd, &bd, 40);

    // None = unset (machine default); the rest exercise the clamps.
    let settings: [Option<&str>; 5] = [None, Some("1"), Some("64"), Some("0"),
                                       Some("not-a-number")];
    for setting in settings {
        match setting {
            Some(v) => std::env::set_var("ADDERNET_THREADS", v),
            None => std::env::remove_var("ADDERNET_THREADS"),
        }
        let label = setting.unwrap_or("<unset>");
        for strat in [KernelStrategy::Tiled, KernelStrategy::Simd] {
            let got = conv2d_with(strat, &x_big, &cw_big, 1, Padding::Same,
                                  SimKernel::Adder);
            assert_close(&got.data, &want_big.data,
                         &format!("big conv [{} threads={label}]", strat.label()));
            let got = conv2d_with(strat, &x_small, &cw_small, 1, Padding::Same,
                                  SimKernel::Adder);
            assert_close(&got.data, &want_small.data,
                         &format!("small conv [{} threads={label}]", strat.label()));
            let got = dense_with(strat, &xd, &wd, &bd, 40);
            assert_close(&got.data, &want_dense.data,
                         &format!("dense [{} threads={label}]", strat.label()));
        }
    }
    std::env::remove_var("ADDERNET_THREADS");
}
