//! Observability integration tests: registry snapshot/Prometheus
//! agreement, atomic-vs-locked histogram equivalence under concurrent
//! hammering, serve-driven Chrome traces whose spans nest and cover the
//! measured end-to-end latency, and per-layer profiles whose cycle
//! column sums to the accelerator schedule's total exactly.

use std::sync::Arc;
use std::time::{Duration, Instant};

use addernet::coordinator::server;
use addernet::coordinator::LatencyHistogram;
use addernet::data;
use addernet::obs::profile;
use addernet::obs::registry::{AtomicHistogram, Registry};
use addernet::obs::trace::{Span, TraceSink};
use addernet::quant::plan::QuantPlan;
use addernet::quant::Mode;
use addernet::report::quantrep;
use addernet::sim::functional::{synth_params, Arch, ExecMode, KernelStrategy,
                                QuantCfg, SimKernel, Tensor};
use addernet::sim::hwsim;
use addernet::util::json::Json;

const QCFG: QuantCfg = QuantCfg { bits: 8, mode: Mode::SharedScale };

/// Build an int8 plan for `arch`/adder from synthetic weights.
fn int8_plan(arch: Arch, seed: u64) -> QuantPlan {
    let params = synth_params(arch, seed);
    let (calib, _) = quantrep::calibrate(&params, arch, SimKernel::Adder, 8);
    QuantPlan::build(&params, arch, SimKernel::Adder, QCFG, &calib).unwrap()
}

/// Variant config mounting `plan` under `name` with `replicas` workers.
fn plan_variant(name: &str, plan: QuantPlan,
                replicas: usize) -> server::FunctionalVariantCfg {
    let mut cfg = server::FunctionalVariantCfg::synthetic(
        name, plan.arch, SimKernel::Adder, 42);
    cfg.mode = ExecMode::Quant(QCFG);
    cfg.plan = Some(plan);
    cfg.replicas = replicas;
    cfg
}

/// The snapshot JSON layout is the `addernet-metrics-v1` contract:
/// exactly the four top-level sections, histogram entries with the six
/// summary fields, values readable back out of the rendered text.
#[test]
fn snapshot_json_schema_is_stable() {
    let r = Registry::new();
    r.counter("obs_requests_total", "requests").add(7);
    r.gauge("obs_depth", "queue depth").set(3.0);
    r.histogram("obs_lat_us", "latency").record_us(250);
    let j = Json::parse(&r.snapshot().to_string()).unwrap();
    let top = j.as_obj().unwrap();
    let keys: Vec<&str> = top.keys().map(|k| k.as_str()).collect();
    assert_eq!(keys, ["counters", "gauges", "histograms", "schema"]);
    assert_eq!(j.get("schema").unwrap().as_str(),
               Some(addernet::obs::registry::SCHEMA));
    assert_eq!(j.at(&["counters", "obs_requests_total"]).unwrap().as_usize(),
               Some(7));
    assert_eq!(j.at(&["gauges", "obs_depth"]).unwrap().as_f64(), Some(3.0));
    let h = j.at(&["histograms", "obs_lat_us"]).unwrap().as_obj().unwrap();
    let hkeys: Vec<&str> = h.keys().map(|k| k.as_str()).collect();
    assert_eq!(hkeys,
               ["count", "max_us", "mean_us", "p50_us", "p99_us", "sum_us"]);
    assert_eq!(j.at(&["histograms", "obs_lat_us", "count"]).unwrap().as_usize(),
               Some(1));
}

/// Prometheus text: one sample line per metric, HELP/TYPE once per
/// family even when several label sets share the base name.
#[test]
fn prometheus_one_sample_per_metric_no_duplicate_help() {
    let r = Registry::new();
    r.counter("obs_req_total{variant=\"a\"}", "requests").add(1);
    r.counter("obs_req_total{variant=\"b\"}", "requests").add(2);
    r.gauge("obs_depth{variant=\"a\"}", "queue depth").set(4.0);
    r.histogram("obs_lat_us{variant=\"a\"}", "latency").record_us(100);
    let text = r.render_prometheus();
    assert_eq!(text.matches("# HELP obs_req_total ").count(), 1);
    assert_eq!(text.matches("# TYPE obs_req_total ").count(), 1);
    assert_eq!(text.matches("obs_req_total{variant=\"a\"} ").count(), 1);
    assert_eq!(text.matches("obs_req_total{variant=\"b\"} ").count(), 1);
    assert!(text.contains("obs_req_total{variant=\"a\"} 1\n"));
    assert!(text.contains("obs_req_total{variant=\"b\"} 2\n"));
    assert!(text.contains("obs_depth{variant=\"a\"} 4\n"));
    // the histogram renders as a summary: two quantiles + sum + count
    assert_eq!(text.matches("obs_lat_us{variant=\"a\",quantile=").count(), 2);
    assert!(text.contains("obs_lat_us_count{variant=\"a\"} 1\n"));
}

/// Four threads hammering one lock-free histogram record exactly what a
/// single locked histogram sees from the combined stream: same buckets,
/// same count/sum/max, same quantiles.
#[test]
fn atomic_histogram_matches_locked_under_4_threads() {
    let seqs: Vec<Vec<u64>> = (0..4u64)
        .map(|t| (0..2000u64).map(|i| (i * 37 + t * 13) % 100_000 + 1).collect())
        .collect();
    let a = AtomicHistogram::new();
    std::thread::scope(|scope| {
        for seq in &seqs {
            let a = &a;
            scope.spawn(move || {
                for &us in seq {
                    a.record_us(us);
                }
            });
        }
    });
    let mut l = LatencyHistogram::new();
    for seq in &seqs {
        for &us in seq {
            l.record(Duration::from_micros(us));
        }
    }
    let s = a.snapshot();
    assert_eq!(s.count(), 8000);
    assert_eq!(s.count(), l.count());
    assert_eq!(s.sum_us(), l.sum_us());
    assert_eq!(s.max_us(), l.max_us());
    assert_eq!(s.bucket_counts(), l.bucket_counts());
    for q in [0.5, 0.9, 0.99] {
        assert_eq!(s.quantile_us(q), l.quantile_us(q));
    }
}

/// Serve with a trace sink attached: the export is valid Chrome trace
/// JSON, spans nest (layer within exec within batch, exec within its
/// request), and the request spans cover >= 99% of the latency the
/// client measured end to end.
#[test]
fn serve_trace_spans_nest_and_cover_e2e() {
    let n = 8usize;
    let sink = TraceSink::new();
    let handle = server::start_functional_observed(
        vec![plan_variant("lenet5_adder_int8", int8_plan(Arch::Lenet5, 42), 1)],
        Duration::from_millis(5), Some(Arc::clone(&sink))).unwrap();
    let b = data::eval_set(n, 19);
    let mut pending = Vec::new();
    for i in 0..n {
        let t0 = Instant::now();
        let rx = handle.submit("lenet5_adder_int8",
                               b.images[i * 1024..(i + 1) * 1024].to_vec())
            .unwrap();
        pending.push((t0, rx));
    }
    let mut measured_us = 0.0f64;
    for (t0, rx) in pending {
        rx.recv().unwrap();
        measured_us += t0.elapsed().as_secs_f64() * 1e6;
    }
    handle.shutdown();

    let spans = sink.spans();
    // one request span per answered request, recorded at respond time
    // with ts = enqueue and dur = enqueue -> response sent, so the span
    // set covers (essentially all of) the client-measured e2e window
    let reqs: Vec<_> = spans.iter().filter(|r| r.2.name == "request").collect();
    assert_eq!(reqs.len(), n);
    let span_us: f64 = reqs.iter().map(|r| r.2.dur_us as f64).sum();
    assert!(span_us >= 0.99 * measured_us,
            "request spans cover {span_us:.0}us of {measured_us:.0}us \
             measured e2e (< 99%)");

    let within = |i: &Span, o: &Span| {
        o.ts_us <= i.ts_us && i.ts_us + i.dur_us <= o.ts_us + o.dur_us
    };
    let execs: Vec<_> = spans.iter().filter(|r| r.2.name == "exec").collect();
    let batches: Vec<_> = spans.iter().filter(|r| r.2.name == "batch").collect();
    assert!(!execs.is_empty() && !batches.is_empty());
    for e in &execs {
        assert!(batches.iter().any(|bt| bt.0 == e.0 && within(&e.2, &bt.2)),
                "exec span outside every batch span");
        assert!(reqs.iter().any(|r| r.0 == e.0 && within(&e.2, &r.2)),
                "exec span outside every request span");
    }
    // per-layer spans from the observed graph walk ride inside exec
    // (2us slack: ts and dur truncate to whole microseconds separately)
    let layers: Vec<_> = spans.iter().filter(|r| r.2.cat == "layer").collect();
    assert!(!layers.is_empty(), "layer spans missing from the trace");
    for l in &layers {
        assert!(execs.iter().any(|e| e.0 == l.0
                                 && e.2.ts_us <= l.2.ts_us
                                 && l.2.ts_us + l.2.dur_us
                                    <= e.2.ts_us + e.2.dur_us + 2),
                "layer span outside every exec span");
    }
    // the export parses as Chrome trace JSON with thread metadata
    let j = Json::parse(&sink.export_json()).unwrap();
    let events = j.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(events.iter().any(|e| e.get("ph").and_then(|p| p.as_str())
                              == Some("M")));
    assert!(events.iter()
        .filter(|e| e.get("ph").and_then(|p| p.as_str()) == Some("X"))
        .count() >= spans.len());
    assert_eq!(j.get("droppedSpans").unwrap().as_usize(), Some(0));
}

/// `snapshot()` and `render_prometheus()` are two views of one registry:
/// after exporting merged serving metrics, every counter and gauge in
/// the JSON appears in the text with the identical value, and the
/// counters agree with `metrics_snapshot()`.
#[test]
fn registry_snapshot_and_prometheus_agree_after_serving() {
    let n = 8usize;
    let handle = server::start_functional(
        vec![plan_variant("lenet5_adder_int8", int8_plan(Arch::Lenet5, 42), 2)],
        Duration::from_millis(1)).unwrap();
    let b = data::eval_set(n, 29);
    let rxs: Vec<_> = (0..n)
        .map(|i| handle.submit("lenet5_adder_int8",
                               b.images[i * 1024..(i + 1) * 1024].to_vec())
            .unwrap())
        .collect();
    for rx in rxs {
        rx.recv().unwrap();
    }
    let reg = Registry::new();
    handle.export_registry(&reg);
    let m = handle.metrics_snapshot();
    handle.shutdown();

    let j = Json::parse(&reg.snapshot().to_string()).unwrap();
    let text = reg.render_prometheus();
    let counters = j.get("counters").unwrap().as_obj().unwrap();
    assert!(!counters.is_empty());
    for (name, v) in counters {
        let line = format!("{} {}\n", name, v.as_f64().unwrap() as u64);
        assert!(text.contains(&line), "prometheus missing: {line}");
    }
    let gauges = j.get("gauges").unwrap().as_obj().unwrap();
    assert!(!gauges.is_empty());
    for (name, v) in gauges {
        let got: f64 = text.lines()
            .find_map(|l| l.strip_prefix(&format!("{name} ")))
            .unwrap_or_else(|| panic!("prometheus missing gauge {name}"))
            .parse().unwrap();
        assert_eq!(got, v.as_f64().unwrap(), "{name} differs across views");
    }
    // the exported counters are the merged per-replica shard totals
    let label = "addernet_requests_total{variant=\"lenet5_adder_int8\"}";
    assert_eq!(counters[label].as_f64(),
               Some(m["lenet5_adder_int8"].requests as f64));
    assert_eq!(m["lenet5_adder_int8"].requests, n as u64);
    let e2e = "addernet_e2e_latency_us{variant=\"lenet5_adder_int8\"}";
    assert_eq!(j.at(&["histograms", e2e, "count"]).unwrap().as_usize(),
               Some(n));
    assert!(text.contains(
        "addernet_e2e_latency_us_count{variant=\"lenet5_adder_int8\"} 8\n"));
}

/// The resnet8 int8 profile joins measured wall-us rows against the
/// plan schedule by graph op name, and the cycle column sums to the
/// independently-built schedule's `total_cycles` EXACTLY.
#[test]
fn resnet8_profile_cycle_column_sums_to_schedule_total() {
    let plan = int8_plan(Arch::Resnet8, 42);
    let b = data::eval_set(1, 23);
    let x = Tensor::new((1, 32, 32, 1), b.images[..1024].to_vec());
    let p = profile::profile_plan(&plan, KernelStrategy::Auto, 1024, &x)
        .unwrap();
    assert_eq!(p.arch, "resnet8");
    assert_eq!(p.mode, "int8");
    assert_eq!(p.hw_layer_cycle_sum(), p.hw_total_cycles);
    let (_cfg, report) = hwsim::plan_schedule(&plan, 1024).unwrap();
    assert_eq!(p.hw_total_cycles, Some(report.total_cycles));
    // the conv stack joined: plenty of rows carry cycles, the residual
    // bookkeeping rows don't
    assert!(p.layers.iter().filter(|l| l.hw_cycles.is_some()).count() >= 8);
    assert!(p.layers.iter().any(|l| l.hw_cycles.is_none()));
    assert!(p.wall_us_total > 0.0);
}
