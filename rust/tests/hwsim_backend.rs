//! Hardware-serving backend tests: (a) HwPlanRunner logits are
//! bit-identical to PlanRunner across every servable arch and kernel
//! strategy, (b) the accelerator's per-layer op accounting agrees with
//! the graph-derived MAC model for every registered network, and
//! (c) the §4 ResNet-18 paper anchors hold through the `report fpga`
//! path.  Everything runs offline on synthetic weights.

use addernet::hw::KernelKind;
use addernet::nn::{self, Layer};
use addernet::quant::plan::QuantPlan;
use addernet::quant::Mode;
use addernet::report::{fpga, quantrep};
use addernet::sim::accelerator::{self, AccelConfig};
use addernet::sim::functional::{synth_params, Arch, QuantCfg, SimKernel,
                                Tensor};
use addernet::sim::hwsim::{self, HwPlanRunner};
use addernet::sim::intpath::PlanRunner;
use addernet::sim::kernels::KernelStrategy;
use addernet::util::XorShift64;

/// The serving matrix the hwsim backend covers: adder int8/int16 plus
/// the mult int8 baseline (mult plans cap at 8-bit operands).
const MATRIX: &[(SimKernel, Mode, u32)] = &[
    (SimKernel::Adder, Mode::SharedScale, 8),
    (SimKernel::Adder, Mode::SharedScale, 16),
    (SimKernel::Mult, Mode::SeparateScale, 8),
];

fn build_plan(arch: Arch, kind: SimKernel, mode: Mode, bits: u32) -> QuantPlan {
    let params = synth_params(arch, 42);
    let (calib, _) = quantrep::calibrate(&params, arch, kind, 8);
    QuantPlan::build(&params, arch, kind, QuantCfg { bits, mode }, &calib)
        .unwrap()
}

fn batch(arch: Arch, n: usize, seed: u64) -> Tensor {
    let (h, w, c) = arch.graph().input;
    let mut rng = XorShift64::new(seed);
    Tensor::new((n, h, w, c),
                (0..n * h * w * c).map(|_| rng.next_f32_sym(1.0)).collect())
}

/// (a) Logit bit-identity: the hw backend wraps the plan path, so for
/// every servable arch and every matrix cell the logits must match the
/// PlanRunner exactly — not approximately.
#[test]
fn hw_logits_bit_identical_across_archs() {
    for arch in Arch::ALL {
        for &(kind, mode, bits) in MATRIX {
            assert!(QuantPlan::supports(kind, bits));
            let plan = build_plan(arch, kind, mode, bits);
            let hw = HwPlanRunner::new(&plan, KernelStrategy::Auto,
                                       hwsim::DEFAULT_PARALLELISM).unwrap();
            let base = PlanRunner { plan: &plan, strategy: KernelStrategy::Auto };
            let x = batch(arch, 2, 7 + bits as u64);
            let (y, cost) = hw.forward(&x);
            assert_eq!(y.data, base.forward(&x).data,
                       "{} {} int{bits}", arch.name(), kind.label());
            assert!(cost.cycles > 0 && cost.latency_ms > 0.0);
            assert!(cost.power_w > 0.0 && cost.fmax_mhz > 0.0);
            assert!(cost.utilization > 0.0 && cost.utilization <= 1.0,
                    "{} util {}", arch.name(), cost.utilization);
        }
    }
}

/// (a) continued: strategy invariance — every inner-kernel strategy
/// yields the same logits through the hw backend (the integer path is
/// deterministic regardless of loop structure).
#[test]
fn hw_logits_strategy_invariant() {
    let plan = build_plan(Arch::Lenet5, SimKernel::Adder, Mode::SharedScale, 8);
    let x = batch(Arch::Lenet5, 3, 11);
    let reference = PlanRunner { plan: &plan, strategy: KernelStrategy::Naive }
        .forward(&x);
    for strategy in [KernelStrategy::Naive, KernelStrategy::Tiled,
                     KernelStrategy::Simd, KernelStrategy::Auto] {
        let hw = HwPlanRunner::new(&plan, strategy,
                                   hwsim::DEFAULT_PARALLELISM).unwrap();
        let (y, _) = hw.forward(&x);
        assert_eq!(y.data, reference.data, "{strategy:?}");
    }
}

/// The batched serving entry point agrees with the tensor path and
/// costs scale linearly with batch size.
#[test]
fn hw_forward_many_matches_forward() {
    let plan = build_plan(Arch::Resnet8, SimKernel::Adder, Mode::SharedScale, 8);
    let hw = HwPlanRunner::new(&plan, KernelStrategy::Auto, 1024).unwrap();
    let hwc = Arch::Resnet8.graph().input;
    let x = batch(Arch::Resnet8, 2, 5);
    let per = hwc.0 * hwc.1 * hwc.2;
    let imgs: Vec<&[f32]> = (0..2).map(|i| &x.data[i * per..(i + 1) * per])
        .collect();
    let (logits, cost) = hw.forward_many(&imgs, hwc);
    let (y, tcost) = hw.forward(&x);
    assert_eq!(logits.concat(), y.data);
    assert_eq!(cost.cycles, tcost.cycles);
    assert_eq!(cost.cycles, hw.cost(1).cycles * 2);
}

/// (b) Geometry consistency: for every registered network the
/// accelerator's per-layer rows must join the descriptor by name and
/// agree with the graph-derived op counts (convs/dense run 2 ops per
/// MAC; pool rows count one op per window element).
#[test]
fn accelerator_ops_match_graph_macs_all_networks() {
    for g in nn::graph::all() {
        let desc = g.to_desc();
        let cfg = AccelConfig::zcu104(1024, 16, KernelKind::Adder2A);
        let report = accelerator::run(&cfg, &desc);
        assert_eq!(report.layers.len(), desc.layers.len(), "{}", g.id);
        let mut conv_ops = 0u64;
        for (layer, row) in desc.layers.iter().zip(&report.layers) {
            assert_eq!(row.name, layer.name(), "{}", g.id);
            match layer {
                Layer::Conv(c) => {
                    assert_eq!(row.ops, 2 * c.macs(), "{} {}", g.id, row.name);
                    conv_ops += row.ops;
                }
                Layer::Dense { din, dout, .. } => {
                    assert_eq!(row.ops, 2 * (din * dout) as u64,
                               "{} {}", g.id, row.name);
                }
                // pool macs are ops/2 rounded down; tolerate the odd op
                Layer::Pool { .. } | Layer::GlobalPool { .. } => {
                    assert!(row.ops / 2 == layer.macs(),
                            "{} {}: {} ops vs {} macs",
                            g.id, row.name, row.ops, layer.macs());
                }
            }
        }
        assert_eq!(report.conv_ops, conv_ops, "{}", g.id);
        assert_eq!(report.total_ops,
                   report.layers.iter().map(|l| l.ops).sum::<u64>(),
                   "{}", g.id);
    }
}

/// (b) continued: the plan-driven schedule is the same schedule the
/// descriptor produces directly — hwsim adds validation, not geometry.
#[test]
fn plan_schedule_equals_descriptor_run() {
    let plan = build_plan(Arch::Resnet8, SimKernel::Adder, Mode::SharedScale, 8);
    let (cfg, from_plan) = hwsim::plan_schedule(&plan, 1024).unwrap();
    let direct = accelerator::run(&cfg, &Arch::Resnet8.graph().to_desc());
    assert_eq!(from_plan.total_cycles, direct.total_cycles);
    assert_eq!(from_plan.total_ops, direct.total_ops);
    assert_eq!(from_plan.dram_bytes, direct.dram_bytes);
}

/// (c) §4 paper anchors through the report path: ResNet-18 at P=1024,
/// 16-bit — conv/total GOPs, latency and power for both kernels, at the
/// same tolerances the accelerator unit tests pin.
#[test]
fn report_path_holds_paper_anchors() {
    let (c, a) = fpga::onboard_runs();
    assert!((c.conv_gops() - 424.0).abs() / 424.0 < 0.12, "cnn conv {}", c.conv_gops());
    assert!((a.conv_gops() - 495.0).abs() / 495.0 < 0.12, "adder conv {}", a.conv_gops());
    assert!((c.total_gops() - 307.0).abs() / 307.0 < 0.25, "cnn total {}", c.total_gops());
    assert!((a.total_gops() - 358.6).abs() / 358.6 < 0.25, "adder total {}", a.total_gops());
    assert!((a.latency_ms() - 9.47).abs() / 9.47 < 0.35, "latency {}", a.latency_ms());
    let saving = 1.0 - a.power.total_w() / c.power.total_w();
    assert!((saving - 0.4785).abs() < 0.15, "power saving {saving:.3}");
    // the JSON artifact carries the same anchor pair
    let rows = vec![fpga::plan_hw_row(
        &build_plan(Arch::Lenet5, SimKernel::Adder, Mode::SharedScale, 8),
        1024).unwrap()];
    let doc = fpga::fpga_report_json(&rows, 1024);
    let j = addernet::util::Json::parse(&doc).unwrap();
    let jg = j.at(&["anchors_resnet18", "addernet", "total_gops"])
        .unwrap().as_f64().unwrap();
    assert!((jg - a.total_gops()).abs() < 0.01);
}

/// The serving-side cost precomputation (`per_image_cost`) refuses
/// plans whose geometry drifted from their arch graph and scales
/// linearly — the contract `start_functional` relies on.
#[test]
fn serving_cost_contract() {
    let plan = build_plan(Arch::Cnv6, SimKernel::Adder, Mode::SharedScale, 8);
    let one = hwsim::per_image_cost(&plan, 1024).unwrap();
    let eight = one.scale(8);
    assert_eq!(eight.cycles, 8 * one.cycles);
    assert_eq!(eight.power_w, one.power_w);
    let mut bad = plan.clone();
    let first = bad.convs.keys().next().unwrap().clone();
    bad.convs.remove(&first);
    assert!(hwsim::per_image_cost(&bad, 1024).is_err());
}
