//! Integration tests across the three layers.
//!
//! Two independent gates keep `cargo test -q` green everywhere:
//!
//! * tests that execute AOT graphs need the PJRT runtime and are compiled
//!   only with the `pjrt` feature;
//! * tests that read Python-built artifacts degrade to a skip-with-message
//!   when `artifacts/manifest.json` is absent.
//!
//! The functional serving tests at the bottom run unconditionally — the
//! tiled engine + synthetic weights need neither XLA nor artifacts.

use std::path::{Path, PathBuf};

use addernet::coordinator::{server, Manifest};
use addernet::data;
use addernet::quant::plan::QuantPlan;
use addernet::quant::Mode;
use addernet::report::quantrep;
use addernet::sim::functional::{self, Arch, ExecMode, KernelStrategy, QuantCfg,
                                Runner, SimKernel, Tensor};
use addernet::sim::intpath::PlanRunner;

#[cfg(feature = "pjrt")]
use addernet::coordinator::Trainer;
#[cfg(feature = "pjrt")]
use addernet::runtime::{self, Runtime};
#[cfg(feature = "pjrt")]
use addernet::util::XorShift64;

fn art_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

macro_rules! require_artifacts {
    () => {
        if !art_dir().join("manifest.json").exists() {
            eprintln!("SKIP: no artifacts (run `make artifacts`)");
            return;
        }
    };
}

/// L1 <-> L3: the Pallas L1-GEMM demo graph must match the Rust oracle.
#[cfg(feature = "pjrt")]
#[test]
fn pallas_l1gemm_matches_rust_oracle() {
    require_artifacts!();
    let manifest = Manifest::load(art_dir()).unwrap();
    let mut rt = Runtime::new(art_dir()).unwrap();
    let g = manifest.graph("l1gemm_demo").unwrap().clone();
    rt.load("l1gemm_demo", &g.file).unwrap();
    let (m, k, n) = (16usize, 32, 8);
    let mut rng = XorShift64::new(3);
    let a: Vec<f32> = (0..m * k).map(|_| rng.next_f32_sym(3.0)).collect();
    let b: Vec<f32> = (0..k * n).map(|_| rng.next_f32_sym(3.0)).collect();
    let outs = rt.execute("l1gemm_demo", &[
        runtime::literal_f32(&[m, k], &a).unwrap(),
        runtime::literal_f32(&[k, n], &b).unwrap(),
    ]).unwrap();
    let got = runtime::to_vec_f32(&outs[0]).unwrap();
    for i in 0..m {
        for j in 0..n {
            let want: f32 = -(0..k).map(|kk| (a[i * k + kk] - b[kk * n + j]).abs()).sum::<f32>();
            assert!((got[i * n + j] - want).abs() < 1e-3,
                    "({i},{j}): {} vs {want}", got[i * n + j]);
        }
    }
}

/// Matmul demo graph vs naive Rust matmul.
#[cfg(feature = "pjrt")]
#[test]
fn pallas_matmul_matches_rust_oracle() {
    require_artifacts!();
    let manifest = Manifest::load(art_dir()).unwrap();
    let mut rt = Runtime::new(art_dir()).unwrap();
    let g = manifest.graph("matmul_demo").unwrap().clone();
    rt.load("matmul_demo", &g.file).unwrap();
    let (m, k, n) = (16usize, 32, 8);
    let mut rng = XorShift64::new(5);
    let a: Vec<f32> = (0..m * k).map(|_| rng.next_f32_sym(1.0)).collect();
    let b: Vec<f32> = (0..k * n).map(|_| rng.next_f32_sym(1.0)).collect();
    let outs = rt.execute("matmul_demo", &[
        runtime::literal_f32(&[m, k], &a).unwrap(),
        runtime::literal_f32(&[k, n], &b).unwrap(),
    ]).unwrap();
    let got = runtime::to_vec_f32(&outs[0]).unwrap();
    for i in 0..m {
        for j in 0..n {
            let want: f32 = (0..k).map(|kk| a[i * k + kk] * b[kk * n + j]).sum();
            assert!((got[i * n + j] - want).abs() < 1e-3);
        }
    }
}

/// L2 <-> L3: the Rust functional simulator's f32 forward must match the
/// AOT HLO eval graph on the SAME parameters and inputs — this pins the
/// bit-accurate datapath to the JAX model for both kernels.
#[cfg(feature = "pjrt")]
#[test]
fn functional_forward_matches_hlo_eval() {
    require_artifacts!();
    let manifest = Manifest::load(art_dir()).unwrap();
    let mut rt = Runtime::new(art_dir()).unwrap();
    for kernel in ["adder", "mult"] {
        let gname = format!("lenet5_{kernel}_eval");
        let g = manifest.graph(&gname).unwrap().clone();
        rt.load(&gname, &g.file).unwrap();
        let layout = manifest.layout("lenet5").unwrap().clone();
        let raw = manifest.read_param_file("lenet5", &layout.init_file).unwrap();
        let lits: Vec<xla::Literal> = raw.iter()
            .map(|(_, s, d)| runtime::literal_f32(s, d).unwrap())
            .collect();
        let batch = data::eval_set(g.batch, 13);
        let x = runtime::literal_f32(&[g.batch, 32, 32, 1], &batch.images).unwrap();
        let mut inputs: Vec<&xla::Literal> = lits.iter().collect();
        inputs.push(&x);
        let hlo_logits = runtime::to_vec_f32(&rt.execute(&gname, &inputs).unwrap()[0]).unwrap();

        let params = manifest.read_params("lenet5", &layout.init_file).unwrap();
        let xt = Tensor::new((g.batch, 32, 32, 1), batch.images.clone());
        let kind = if kernel == "adder" { SimKernel::Adder } else { SimKernel::Mult };
        let mut runner = Runner {
            params: &params, arch: Arch::Lenet5, kind,
            strategy: KernelStrategy::Auto,
            mode: ExecMode::F32, calib: None, observe: None,
        };
        let rust_logits = runner.forward(&xt);
        let mut max_err = 0f32;
        for (a, b) in hlo_logits.iter().zip(&rust_logits.data) {
            max_err = max_err.max((a - b).abs());
        }
        assert!(max_err < 2e-3, "{kernel}: max logits err {max_err}");
    }
}

/// L3 trainer: loss decreases over a few steps and state feeds back.
#[cfg(feature = "pjrt")]
#[test]
fn trainer_loss_decreases() {
    require_artifacts!();
    let manifest = Manifest::load(art_dir()).unwrap();
    let mut rt = Runtime::new(art_dir()).unwrap();
    let mut trainer = Trainer::new(&manifest, &mut rt, "lenet5", "adder").unwrap();
    let mut stream = data::BatchStream::new(21, trainer.batch_size);
    let batch = stream.next_batch();
    let (l0, _) = trainer.train_step(&rt, &batch).unwrap();
    let mut last = l0;
    for _ in 0..8 {
        let (l, _) = trainer.train_step(&rt, &batch).unwrap();
        last = l;
    }
    assert!(last < l0 * 0.7, "loss {l0} -> {last}");
    assert_eq!(trainer.history.len(), 9);
    assert_eq!(trainer.step, 9);
}

/// Trainer evaluate() matches manual argmax over the eval graph.
#[cfg(feature = "pjrt")]
#[test]
fn trainer_eval_matches_direct_graph_eval() {
    require_artifacts!();
    let manifest = Manifest::load(art_dir()).unwrap();
    let mut rt = Runtime::new(art_dir()).unwrap();
    let trainer = Trainer::new(&manifest, &mut rt, "lenet5", "mult").unwrap();
    let ev = data::eval_set(trainer.batch_size, 17);
    let acc = trainer.evaluate(&rt, &ev.images, &ev.labels).unwrap();
    assert!((0.0..=1.0).contains(&acc));
}

/// Quantization pipeline end-to-end on init weights: monotone-ish in bits
/// and int16 ~= fp32.  Needs artifacts but no XLA.
#[test]
fn quant_pipeline_int16_close_to_fp32() {
    require_artifacts!();
    let manifest = Manifest::load(art_dir()).unwrap();
    let layout = manifest.layout("lenet5").unwrap().clone();
    let params = manifest.read_params("lenet5", &layout.init_file).unwrap();
    let (calib, fp32) = quantrep::calibrate(&params, Arch::Lenet5,
                                            SimKernel::Adder, 96);
    assert!(!calib.is_empty());
    let a16 = quantrep::quant_accuracy(
        &params, Arch::Lenet5, SimKernel::Adder, &calib,
        functional::QuantCfg { bits: 16, mode: addernet::quant::Mode::SharedScale },
        96);
    assert!((a16 - fp32).abs() < 0.05, "fp32 {fp32} int16 {a16}");
}

/// Probe graph layer count matches the manifest's layer list.
#[cfg(feature = "pjrt")]
#[test]
fn probe_graph_layer_arity() {
    require_artifacts!();
    let manifest = Manifest::load(art_dir()).unwrap();
    let g = manifest.graph("lenet5_adder_probe").unwrap().clone();
    assert_eq!(g.layers, vec!["conv1".to_string(), "conv2".to_string()]);
    let mut rt = Runtime::new(art_dir()).unwrap();
    rt.load("probe", &g.file).unwrap();
    let layout = manifest.layout("lenet5").unwrap().clone();
    let raw = manifest.read_param_file("lenet5", &layout.init_file).unwrap();
    let lits: Vec<xla::Literal> = raw.iter()
        .map(|(_, s, d)| runtime::literal_f32(s, d).unwrap())
        .collect();
    let b = data::eval_set(g.batch, 23);
    let x = runtime::literal_f32(&[g.batch, 32, 32, 1], &b.images).unwrap();
    let mut inputs: Vec<&xla::Literal> = lits.iter().collect();
    inputs.push(&x);
    // outputs: one flattened feature tensor per conv layer + the logits
    let feats = rt.execute("probe", &inputs).unwrap();
    assert_eq!(feats.len(), g.layers.len() + 1);
    // conv1 input is the image batch itself
    assert_eq!(feats[0].element_count(), g.batch * 32 * 32);
    // last output is the logits
    assert_eq!(feats.last().unwrap().element_count(), g.batch * 10);
}

/// The PJRT serving stack answers correctly routed batched requests.
#[cfg(feature = "pjrt")]
#[test]
fn server_round_trip() {
    require_artifacts!();
    let manifest = Manifest::load(art_dir()).unwrap();
    let variants = vec![addernet::coordinator::VariantCfg {
        model: "lenet5_mult".into(),
        weights: None,
    }];
    let handle = server::start(
        &manifest, &variants, std::time::Duration::from_millis(1)).unwrap();
    let b = data::eval_set(8, 31);
    let mut rxs = Vec::new();
    for i in 0..8 {
        rxs.push(handle.submit("lenet5_mult",
                               b.images[i * 1024..(i + 1) * 1024].to_vec()).unwrap());
    }
    for rx in rxs {
        let resp = rx.recv().unwrap();
        assert_eq!(resp.logits.len(), 10);
        assert!(resp.logits.iter().all(|v| v.is_finite()));
    }
    assert!(handle.submit("nope", vec![0.0; 1024]).is_err());
    handle.shutdown();
}

/// Whole-flow smoke: train a few steps, save, reload via manifest, and
/// check the functional sim accepts the saved parameters.
#[cfg(feature = "pjrt")]
#[test]
fn save_reload_roundtrip() {
    require_artifacts!();
    let manifest = Manifest::load(art_dir()).unwrap();
    let mut rt = Runtime::new(art_dir()).unwrap();
    let mut trainer = Trainer::new(&manifest, &mut rt, "lenet5", "adder").unwrap();
    let mut stream = data::BatchStream::new(77, trainer.batch_size);
    for _ in 0..3 {
        let b = stream.next_batch();
        trainer.train_step(&rt, &b).unwrap();
    }
    trainer.save_params(&manifest, "test_ckpt.bin").unwrap();
    let params = manifest.read_params("lenet5", "test_ckpt.bin").unwrap();
    let ev = data::eval_set(16, 41);
    let x = Tensor::new((16, 32, 32, 1), ev.images);
    let mut runner = Runner {
        params: &params, arch: Arch::Lenet5, kind: SimKernel::Adder,
        strategy: KernelStrategy::Auto,
        mode: ExecMode::F32, calib: None, observe: None,
    };
    let acc = functional::accuracy(&mut runner, &x, &ev.labels);
    assert!((0.0..=1.0).contains(&acc));
    let _ = std::fs::remove_file(art_dir().join("test_ckpt.bin"));
}

// ---------------------------------------------------------------------------
// Functional serving backend: fully offline (no artifacts, no XLA)
// ---------------------------------------------------------------------------

/// The functional-sim server batches queued requests through one
/// `forward_many` pass and answers each with 10 finite logits.
#[test]
fn functional_server_round_trip() {
    let variants = vec![
        server::FunctionalVariantCfg::synthetic(
            "lenet5_adder", Arch::Lenet5, SimKernel::Adder, 42),
        server::FunctionalVariantCfg::synthetic(
            "lenet5_mult", Arch::Lenet5, SimKernel::Mult, 42),
    ];
    let handle = server::start_functional(
        variants, std::time::Duration::from_millis(2)).unwrap();
    let b = data::eval_set(16, 31);
    let mut rxs = Vec::new();
    for i in 0..16 {
        let v = if i % 2 == 0 { "lenet5_adder" } else { "lenet5_mult" };
        rxs.push(handle.submit(v,
                               b.images[i * 1024..(i + 1) * 1024].to_vec()).unwrap());
    }
    for rx in rxs {
        let resp = rx.recv().unwrap();
        assert_eq!(resp.logits.len(), 10);
        assert!(resp.logits.iter().all(|v| v.is_finite()));
    }
    assert!(handle.submit("nope", vec![0.0; 1024]).is_err());
    {
        let metrics = handle.metrics_snapshot();
        let m = &metrics["lenet5_adder"];
        assert_eq!(m.images, 8);
        assert!(m.batches >= 1 && m.batches <= 8, "batches {}", m.batches);
    }
    handle.shutdown();
}

/// Batched responses match a direct single-image forward pass through
/// the same synthetic weights — the batcher must not change results.
#[test]
fn functional_server_matches_direct_forward() {
    let cfg = server::FunctionalVariantCfg::synthetic(
        "lenet5_adder", Arch::Lenet5, SimKernel::Adder, 7);
    let params = cfg.params.clone();
    let handle = server::start_functional(
        vec![cfg], std::time::Duration::from_millis(1)).unwrap();
    let b = data::eval_set(4, 9);
    let mut rxs = Vec::new();
    for i in 0..4 {
        rxs.push(handle.submit("lenet5_adder",
                               b.images[i * 1024..(i + 1) * 1024].to_vec()).unwrap());
    }
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv().unwrap();
        let x = Tensor::new((1, 32, 32, 1),
                            b.images[i * 1024..(i + 1) * 1024].to_vec());
        let mut runner = Runner {
            params: &params, arch: Arch::Lenet5, kind: SimKernel::Adder,
            strategy: KernelStrategy::Auto,
            mode: ExecMode::F32, calib: None, observe: None,
        };
        let direct = runner.forward(&x);
        for (a, d) in resp.logits.iter().zip(&direct.data) {
            assert!((a - d).abs() <= 1e-5 * d.abs().max(1.0), "req {i}: {a} vs {d}");
        }
    }
    handle.shutdown();
}

/// An int8 variant is compiled to a QuantPlan at server start and
/// served through the i32-domain executor: responses are finite,
/// correctly shaped, and EXACTLY equal to a direct plan execution (the
/// int path is deterministic, so batching cannot change results).
#[test]
fn functional_server_serves_int8_plan_variant() {
    let mut cfg = server::FunctionalVariantCfg::synthetic(
        "lenet5_adder_int8", Arch::Lenet5, SimKernel::Adder, 42);
    let (calib, _) = quantrep::calibrate(&cfg.params, Arch::Lenet5,
                                         SimKernel::Adder, 32);
    let qcfg = QuantCfg { bits: 8, mode: Mode::SharedScale };
    cfg.mode = ExecMode::Quant(qcfg);
    cfg.calib = Some(calib.clone());
    let params = cfg.params.clone();
    let handle = server::start_functional(
        vec![cfg], std::time::Duration::from_millis(1)).unwrap();
    let b = data::eval_set(6, 31);
    let mut rxs = Vec::new();
    for i in 0..6 {
        rxs.push(handle.submit("lenet5_adder_int8",
                               b.images[i * 1024..(i + 1) * 1024].to_vec()).unwrap());
    }
    let plan = QuantPlan::build(&params, Arch::Lenet5, SimKernel::Adder, qcfg,
                                &calib).unwrap();
    let runner = PlanRunner { plan: &plan, strategy: KernelStrategy::Auto };
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv().unwrap();
        assert_eq!(resp.logits.len(), 10);
        assert!(resp.logits.iter().all(|v| v.is_finite()));
        let x = Tensor::new((1, 32, 32, 1),
                            b.images[i * 1024..(i + 1) * 1024].to_vec());
        let direct = runner.forward(&x);
        assert_eq!(resp.logits, direct.data, "request {i}");
    }
    handle.shutdown();
}

/// EVERY registered architecture serves end-to-end — f32 AND a compiled
/// int8 plan — through the functional backend.  Iterating `Arch::ALL`
/// means a newly registered arch cannot be left out of the smoke test:
/// if it cannot calibrate, compile a plan or answer requests, this
/// fails.
#[test]
fn all_registered_archs_serve_f32_and_int8() {
    for arch in addernet::sim::functional::Arch::ALL {
        let name = format!("{}_adder", arch.name());
        let f32_cfg = server::FunctionalVariantCfg::synthetic(
            &name, arch, SimKernel::Adder, 42);
        let (h, w, c) = f32_cfg.input_hwc;
        let px = h * w * c;
        let int_name = format!("{name}_int8");
        let mut int_cfg = server::FunctionalVariantCfg::synthetic(
            &int_name, arch, SimKernel::Adder, 42);
        let (calib, _) = quantrep::calibrate(&int_cfg.params, arch,
                                             SimKernel::Adder, 4);
        int_cfg.mode = ExecMode::Quant(QuantCfg { bits: 8,
                                                  mode: Mode::SharedScale });
        int_cfg.calib = Some(calib);
        let handle = server::start_functional(
            vec![f32_cfg, int_cfg], std::time::Duration::from_millis(1))
            .unwrap_or_else(|e| panic!("{}: start_functional: {e:#}",
                                       arch.name()));
        let b = data::eval_set(2, 19);
        for v in [&name, &int_name] {
            let rx = handle.submit(v, b.images[..px].to_vec()).unwrap();
            let resp = rx.recv()
                .unwrap_or_else(|_| panic!("{v}: no response"));
            assert_eq!(resp.logits.len(), 10, "{v}");
            assert!(resp.logits.iter().all(|l| l.is_finite()), "{v}");
        }
        handle.shutdown();
    }
}

/// A variant mounted with a pre-compiled (exported + re-imported) plan
/// serves with NO calibration table at all — the `repro plan` /
/// `serve --plan` cold-start path — and answers exactly like a direct
/// execution of the originally-built plan.
#[test]
fn functional_server_serves_imported_plan_without_calibration() {
    use addernet::quant::plan::{plan_from_json, plan_to_json};

    let mut cfg = server::FunctionalVariantCfg::synthetic(
        "lenet5_adder_plan", Arch::Lenet5, SimKernel::Adder, 42);
    let (calib, _) = quantrep::calibrate(&cfg.params, Arch::Lenet5,
                                         SimKernel::Adder, 16);
    let qcfg = QuantCfg { bits: 8, mode: Mode::SharedScale };
    let built = QuantPlan::build(&cfg.params, Arch::Lenet5, SimKernel::Adder,
                                 qcfg, &calib).unwrap();
    let imported = plan_from_json(&plan_to_json(&built)).unwrap();
    cfg.mode = ExecMode::Quant(qcfg);
    cfg.calib = None; // the whole point: zero calibration at startup
    cfg.plan = Some(imported);
    let handle = server::start_functional(
        vec![cfg], std::time::Duration::from_millis(1)).unwrap();
    let b = data::eval_set(4, 23);
    let mut rxs = Vec::new();
    for i in 0..4 {
        rxs.push(handle.submit("lenet5_adder_plan",
                               b.images[i * 1024..(i + 1) * 1024].to_vec())
            .unwrap());
    }
    let runner = PlanRunner { plan: &built, strategy: KernelStrategy::Auto };
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv().unwrap();
        let x = Tensor::new((1, 32, 32, 1),
                            b.images[i * 1024..(i + 1) * 1024].to_vec());
        let direct = runner.forward(&x);
        assert_eq!(resp.logits, direct.data, "request {i}");
    }
    handle.shutdown();
}

/// An empty variant list is a startup ERROR: a caller that filtered
/// every requested variant away must not green-light an idle server
/// (the `repro serve` exit-code contract CI relies on).
#[test]
fn start_functional_rejects_empty_variant_list() {
    match server::start_functional(Vec::new(),
                                   std::time::Duration::from_millis(1)) {
        Ok(_) => panic!("empty variant list must not start a server"),
        Err(e) => assert!(format!("{e:#}").contains("no variants"), "{e:#}"),
    }
}

/// Duplicate variant names fail startup: silently replacing a route
/// would drop one variant's worker while the CLI reports both serving
/// (easy to hit via `serve --plan a.json,a.json`).
#[test]
fn start_functional_rejects_duplicate_variant_names() {
    let a = server::FunctionalVariantCfg::synthetic(
        "lenet5_adder", Arch::Lenet5, SimKernel::Adder, 42);
    let b = server::FunctionalVariantCfg::synthetic(
        "lenet5_adder", Arch::Lenet5, SimKernel::Adder, 43);
    match server::start_functional(vec![a, b],
                                   std::time::Duration::from_millis(1)) {
        Ok(_) => panic!("duplicate variant names must not start a server"),
        Err(e) => assert!(format!("{e:#}").contains("duplicate"), "{e:#}"),
    }
}

/// A plan mounted on the wrong variant (different arch) fails startup
/// with a proper error instead of serving garbage.
#[test]
fn start_functional_rejects_mismatched_plan() {
    let params = addernet::sim::functional::synth_params(Arch::Lenet5, 42);
    let (calib, _) = quantrep::calibrate(&params, Arch::Lenet5,
                                         SimKernel::Adder, 8);
    let qcfg = QuantCfg { bits: 8, mode: Mode::SharedScale };
    let lenet_plan = QuantPlan::build(&params, Arch::Lenet5, SimKernel::Adder,
                                      qcfg, &calib).unwrap();
    let mut cfg = server::FunctionalVariantCfg::synthetic(
        "resnet8_adder", Arch::Resnet8, SimKernel::Adder, 42);
    cfg.mode = ExecMode::Quant(qcfg);
    cfg.plan = Some(lenet_plan);
    match server::start_functional(vec![cfg],
                                   std::time::Duration::from_millis(1)) {
        Ok(_) => panic!("mismatched plan must not start a server"),
        Err(e) => assert!(format!("{e:#}").contains("compiled for"), "{e:#}"),
    }
}

/// Misconfigured quantized variants fail `start_functional` with a
/// proper error — no worker is spawned, nothing panics.
#[test]
fn functional_server_rejects_misconfigured_quant_variants() {
    // ServerHandle is not Debug, so unwrap_err() is unavailable
    let expect_err = |r: anyhow::Result<server::ServerHandle>| -> String {
        match r {
            Ok(_) => panic!("misconfigured variant should fail start_functional"),
            Err(e) => format!("{e:#}"),
        }
    };

    // quantized mode with no calibration table at all
    let mut cfg = server::FunctionalVariantCfg::synthetic(
        "lenet5_adder_int8", Arch::Lenet5, SimKernel::Adder, 42);
    cfg.mode = ExecMode::Quant(QuantCfg { bits: 8, mode: Mode::SharedScale });
    cfg.calib = None;
    let err = expect_err(server::start_functional(
        vec![cfg], std::time::Duration::from_millis(1)));
    assert!(err.contains("calibration"), "{err}");

    // a table that does not cover every conv layer fails plan compilation
    let mut cfg = server::FunctionalVariantCfg::synthetic(
        "lenet5_adder_int8", Arch::Lenet5, SimKernel::Adder, 42);
    let (mut calib, _) = quantrep::calibrate(&cfg.params, Arch::Lenet5,
                                             SimKernel::Adder, 8);
    calib.remove("conv2");
    cfg.mode = ExecMode::Quant(QuantCfg { bits: 8, mode: Mode::SharedScale });
    cfg.calib = Some(calib);
    let err = expect_err(server::start_functional(
        vec![cfg], std::time::Duration::from_millis(1)));
    assert!(err.contains("conv2"), "{err}");
}

/// A malformed request (wrong pixel count) is refused AT SUBMIT with an
/// error naming expected vs got — never silently dropped via a closed
/// channel — it is counted in `ServerMetrics::rejected`, and
/// well-formed requests still succeed.
#[test]
fn functional_server_rejects_malformed_requests_at_submit() {
    let cfg = server::FunctionalVariantCfg::synthetic(
        "lenet5_adder", Arch::Lenet5, SimKernel::Adder, 3);
    let handle = server::start_functional(
        vec![cfg], std::time::Duration::from_millis(1)).unwrap();
    match handle.submit("lenet5_adder", vec![0.0; 17]) {
        Ok(_) => panic!("malformed request must be refused at submit"),
        Err(e @ server::SubmitError::BadRequest { .. }) => {
            let msg = e.to_string();
            assert!(msg.contains("1024") && msg.contains("17"),
                    "error must name expected vs got: {msg}");
        }
        Err(e) => panic!("expected BadRequest, got: {e}"),
    }
    let good = handle.submit("lenet5_adder", vec![0.0; 1024]).unwrap();
    assert_eq!(good.recv().unwrap().logits.len(), 10);
    assert_eq!(handle.metrics_snapshot()["lenet5_adder"].rejected, 1);
    handle.shutdown();
}
